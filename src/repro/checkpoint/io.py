"""Pytree checkpointing on npz (offline container: no orbax/msgpack).

Leaves are stored flat under '/'-joined key paths inside one compressed
``.npz``; dtypes (incl. bfloat16, stored as uint16 bit patterns) and the
treedef round-trip exactly.  Restore-into-structure (``load_pytree(like=)``)
validates path sets and shapes so a checkpoint from a different config fails
loudly rather than silently mis-assigning tensors.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_BF16_TAG = "__bf16__"


def _flatten_with_paths(tree: PyTree) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k)) for k in path
        )
        out[key] = leaf
    return out


def save_pytree(path: str, tree: PyTree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays, meta = {}, {}
    for k, v in flat.items():
        arr = np.asarray(v)
        if arr.dtype == jnp.bfloat16:
            arrays[k] = arr.view(np.uint16)
            meta[k] = _BF16_TAG
        else:
            arrays[k] = arr
    arrays["__meta__"] = np.frombuffer(json.dumps(meta).encode(), np.uint8)
    np.savez_compressed(path, **arrays)


def load_pytree(path: str, like: Optional[PyTree] = None) -> PyTree:
    """Load a checkpoint.  With ``like``, returns the same structure as
    ``like`` with values replaced; without, returns a flat {path: array}."""
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        flat = {}
        for k in z.files:
            if k == "__meta__":
                continue
            arr = z[k]
            if meta.get(k) == _BF16_TAG:
                arr = arr.view(jnp.bfloat16)
            flat[k] = arr
    if like is None:
        return flat

    want = _flatten_with_paths(like)
    missing = set(want) - set(flat)
    extra = set(flat) - set(want)
    if missing or extra:
        raise ValueError(
            f"checkpoint/structure mismatch: missing={sorted(missing)[:5]} "
            f"extra={sorted(extra)[:5]}"
        )
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for pathkeys, leaf in leaves_paths:
        key = "/".join(
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k)) for k in pathkeys
        )
        arr = flat[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        new_leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def save_train_state(path: str, state) -> None:
    save_pytree(path, state._asdict() if hasattr(state, "_asdict") else state)


def restore_train_state(path: str, like) -> Any:
    loaded = load_pytree(path, like._asdict() if hasattr(like, "_asdict") else like)
    return type(like)(**loaded) if hasattr(like, "_asdict") else loaded
