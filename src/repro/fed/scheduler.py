"""Round scheduling: sync and async/stale federated rounds (DESIGN.md §9).

A :class:`RoundScheduler` wires a :class:`~repro.fed.server.ParameterServer`
to a :class:`~repro.fed.clients.ClientPool` through a
:class:`~repro.core.channel.FedWireChannel` (DESIGN.md §12 — the channel
owns the compress → pack → decode → aggregate → broadcast → meter loop;
the scheduler owns *time*: cohort sampling and replica staleness) and
drives communication rounds:

  sync    every cohort member trains from the CURRENT broadcast replica Ŵ
          (it "downloads" the newest model when sampled); the server
          aggregates with ``mean``/``weighted``.
  async   sampled members start from stale replicas Ŵ_{r−s} (s drawn
          uniformly from [0, max_staleness], deterministic per round) —
          simulating clients whose round trip spans several server rounds.
          Pair with the server's ``staleness`` aggregator so stale
          gradients are discounted by the closed form
          :func:`repro.fed.server.staleness_weights`.

Every round is metered both directions in the channel's
:class:`~repro.core.ledger.BandwidthLedger`: framed bytes, measured payload
bits, and the analytic Eq. 1/Eq. 5 prediction, upstream (summed over the
cohort) and downstream (per recipient × cohort size).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import FedWireChannel
from repro.fed.clients import ClientPool
from repro.fed.faults import FaultSchedule, ServerKilled
from repro.fed.server import ParameterServer

PyTree = Any


@dataclasses.dataclass(eq=False)
class RoundScheduler:
    server: ParameterServer
    pool: ClientPool
    cohort_size: int
    mode: str = "sync"  # "sync" | "async"
    max_staleness: int = 0
    seed: int = 0
    # elasticity (DESIGN.md §14): abort uploads whose simulated duration
    # profile.delay × fault-slowdown exceeds the timeout; inject the
    # seeded fault schedule (None → failure-free, the original behavior)
    straggler_timeout: Optional[float] = None
    faults: Optional[FaultSchedule] = None

    def __post_init__(self) -> None:
        if self.mode not in ("sync", "async"):
            raise ValueError(f"mode must be 'sync' or 'async', got {self.mode!r}")
        if self.mode == "sync":
            self.max_staleness = 0
        self.channel = FedWireChannel(server=self.server, pool=self.pool)
        # ring of past replicas Ŵ_{r−s}; entries are immutable pytree refs
        self._snapshots: deque = deque(maxlen=self.max_staleness + 1)
        # rejoin bookkeeping: the round each client last downloaded a
        # replica, and the round of its last FAILED participation (cleared
        # on success) — a rejoining failed client re-enters at staleness
        # round − last_download instead of the random draw
        self._last_download: Dict[int, int] = {}
        self._failed: Dict[int, int] = {}
        # kill_server faults fire ONCE: the fired set is checkpointed, so
        # a resumed run sails past the kill that produced its checkpoint
        self._kills_fired: Set[Tuple[int, str]] = set()
        self.channel.init_state()

    @property
    def ledger(self):
        """The channel's bandwidth ledger (back-compat alias)."""
        return self.channel.ledger

    # ------------------------------------------------------------ one round

    def step(self, round_idx: int) -> dict:
        """Sample a cohort, pick (possibly stale) starts, and hand the
        round to the wire channel (run + pack + aggregate + broadcast +
        meter).

        With a fault schedule attached: dropped clients are excluded
        before download (their pool state, and their replica, stay put);
        a scheduled server kill raises :class:`ServerKilled` either at the
        round boundary (``pre_round``) or mid-round after partial
        aggregation (``post_aggregate`` — finish via
        :meth:`resume_pending` after restoring a checkpoint)."""
        kill = None
        if self.faults is not None:
            kill = self.faults.kill_at(round_idx)
            if kill is not None:
                if (round_idx, kill) in self._kills_fired:
                    kill = None  # resumed past this kill already
                else:
                    self._kills_fired.add((round_idx, kill))
                    if kill == "pre_round":
                        raise ServerKilled(round_idx, "pre_round")

        self._snapshots.appendleft(self.server.estimate)
        cohort = self.pool.sample_cohort(round_idx, self.cohort_size)
        dropped = (
            self.faults.drops_at(round_idx) if self.faults is not None
            else frozenset()
        )
        dropped = sorted(dropped & {int(c) for c in cohort})
        participants = np.asarray(
            [c for c in cohort if int(c) not in set(dropped)], np.int64
        )
        staleness = self._draw_staleness(round_idx, participants.size)
        if self.mode == "async" and self._failed:
            # rejoin semantics: a client whose LAST attempt failed still
            # holds the replica of its last successful download — override
            # the random draw with its true staleness (capped by the ring)
            cap = min(self.max_staleness, len(self._snapshots) - 1)
            for j, cid in enumerate(participants):
                if int(cid) in self._failed:
                    last_dl = self._last_download.get(int(cid))
                    s = cap if last_dl is None else min(round_idx - last_dl, cap)
                    staleness[j] = max(0, s)
        # download bookkeeping happens at round start: every participant
        # pulls a replica before training (stragglers/corrupt included —
        # their DOWNLOAD is real even when their upload fails)
        for cid in dropped:
            self._failed[int(cid)] = round_idx
        for cid in participants:
            self._last_download[int(cid)] = round_idx

        if self.mode == "sync" or participants.size == 0:
            start = self.server.estimate  # shared: everyone pulls Ŵ_r
        else:
            start = jax.tree.map(
                lambda *leaves: jnp.stack(leaves),
                *[self._snapshots[s] for s in staleness],
            )

        m = self.channel.round_exchange(
            round_idx, participants, start, staleness,
            faults=self.faults, straggler_timeout=self.straggler_timeout,
            kill_step=kill,
        )
        m["dropped"] = dropped
        self._bookkeep_failures(round_idx, m)
        return m

    def _bookkeep_failures(self, round_idx: int, m: dict) -> None:
        for cid in m.get("stragglers", ()) or ():
            self._failed[int(cid)] = round_idx
        for cid in m.get("rejected", ()) or ():
            self._failed[int(cid)] = round_idx
        for cid in m.get("accepted", ()) or ():
            self._failed.pop(int(cid), None)

    def resume_pending(self) -> Optional[dict]:
        """Finish a round interrupted by a ``post_aggregate`` kill (the
        aggregated-but-unbroadcast half survives checkpoint/restore in
        ``channel._pending``).  Returns the round metrics, or None when
        nothing is pending."""
        pending = self.channel._pending
        if pending is None:
            return None
        m = self.channel._finish_round(pending)
        m["dropped"] = sorted(
            self.faults.drops_at(m["round"]) if self.faults is not None
            else ()
        )
        self._bookkeep_failures(m["round"], m)
        return m

    # ------------------------------------------------------------- full run

    def run(self, n_rounds: int, log_every: int = 0,
            start_round: int = 0) -> dict:
        """Drive rounds ``start_round..n_rounds−1``; returns a column-major
        history merged with the ledger's byte accounting.  A resumed run
        passes ``start_round`` = the next round its checkpoint owes (after
        :meth:`resume_pending` for mid-round checkpoints)."""
        hist: dict = {"round": [], "loss": [], "update_norm": [],
                      "mean_staleness": []}
        for r in range(start_round, n_rounds):
            m = self.step(r)
            hist["round"].append(r)
            hist["loss"].append(m["loss"])
            hist["update_norm"].append(m["update_norm"])
            hist["mean_staleness"].append(float(np.mean(m["staleness"])))
            if log_every and (r + 1) % log_every == 0:
                t = self.ledger.totals()
                print(
                    f"round {r+1:4d}  loss {m['loss']:.4f}  "
                    f"up {t['up_bytes']/1e3:.1f} kB  "
                    f"down {t['down_bytes']/1e3:.1f} kB"
                )
        hist.update({f"wire_{k}": v for k, v in self.ledger.history().items()})
        hist.update(self.ledger.totals())
        return hist

    # ------------------------------------------------------------- plumbing

    def _draw_staleness(self, round_idx: int, k: int) -> np.ndarray:
        if self.mode == "sync" or self.max_staleness == 0:
            return np.zeros((k,), np.int64)
        cap = min(self.max_staleness, len(self._snapshots) - 1)
        rng = np.random.default_rng([self.seed, round_idx, 7])
        return rng.integers(0, cap + 1, size=k)
