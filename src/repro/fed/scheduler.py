"""Round scheduling: sync and async/stale federated rounds (DESIGN.md §9).

A :class:`RoundScheduler` wires a :class:`~repro.fed.server.ParameterServer`
to a :class:`~repro.fed.clients.ClientPool` through a
:class:`~repro.core.channel.FedWireChannel` (DESIGN.md §12 — the channel
owns the compress → pack → decode → aggregate → broadcast → meter loop;
the scheduler owns *time*: cohort sampling and replica staleness) and
drives communication rounds:

  sync    every cohort member trains from the CURRENT broadcast replica Ŵ
          (it "downloads" the newest model when sampled); the server
          aggregates with ``mean``/``weighted``.
  async   sampled members start from stale replicas Ŵ_{r−s} (s drawn
          uniformly from [0, max_staleness], deterministic per round) —
          simulating clients whose round trip spans several server rounds.
          Pair with the server's ``staleness`` aggregator so stale
          gradients are discounted by the closed form
          :func:`repro.fed.server.staleness_weights`.

Every round is metered both directions in the channel's
:class:`~repro.core.ledger.BandwidthLedger`: framed bytes, measured payload
bits, and the analytic Eq. 1/Eq. 5 prediction, upstream (summed over the
cohort) and downstream (per recipient × cohort size).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import FedWireChannel
from repro.fed.clients import ClientPool
from repro.fed.server import ParameterServer

PyTree = Any


@dataclasses.dataclass(eq=False)
class RoundScheduler:
    server: ParameterServer
    pool: ClientPool
    cohort_size: int
    mode: str = "sync"  # "sync" | "async"
    max_staleness: int = 0
    seed: int = 0

    def __post_init__(self) -> None:
        if self.mode not in ("sync", "async"):
            raise ValueError(f"mode must be 'sync' or 'async', got {self.mode!r}")
        if self.mode == "sync":
            self.max_staleness = 0
        self.channel = FedWireChannel(server=self.server, pool=self.pool)
        # ring of past replicas Ŵ_{r−s}; entries are immutable pytree refs
        self._snapshots: deque = deque(maxlen=self.max_staleness + 1)
        self.channel.init_state()

    @property
    def ledger(self):
        """The channel's bandwidth ledger (back-compat alias)."""
        return self.channel.ledger

    # ------------------------------------------------------------ one round

    def step(self, round_idx: int) -> dict:
        """Sample a cohort, pick (possibly stale) starts, and hand the
        round to the wire channel (run + pack + aggregate + broadcast +
        meter)."""
        self._snapshots.appendleft(self.server.estimate)
        cohort = self.pool.sample_cohort(round_idx, self.cohort_size)
        staleness = self._draw_staleness(round_idx, cohort.size)

        if self.mode == "sync":
            start = self.server.estimate  # shared: everyone pulls Ŵ_r
        else:
            start = jax.tree.map(
                lambda *leaves: jnp.stack(leaves),
                *[self._snapshots[s] for s in staleness],
            )

        return self.channel.round_exchange(round_idx, cohort, start, staleness)

    # ------------------------------------------------------------- full run

    def run(self, n_rounds: int, log_every: int = 0) -> dict:
        """Drive ``n_rounds`` rounds; returns a column-major history merged
        with the ledger's byte accounting."""
        hist: dict = {"round": [], "loss": [], "update_norm": [],
                      "mean_staleness": []}
        for r in range(n_rounds):
            m = self.step(r)
            hist["round"].append(r)
            hist["loss"].append(m["loss"])
            hist["update_norm"].append(m["update_norm"])
            hist["mean_staleness"].append(float(np.mean(m["staleness"])))
            if log_every and (r + 1) % log_every == 0:
                t = self.ledger.totals()
                print(
                    f"round {r+1:4d}  loss {m['loss']:.4f}  "
                    f"up {t['up_bytes']/1e3:.1f} kB  "
                    f"down {t['down_bytes']/1e3:.1f} kB"
                )
        hist.update({f"wire_{k}": v for k, v in self.ledger.history().items()})
        hist.update(self.ledger.totals())
        return hist

    # ------------------------------------------------------------- plumbing

    def _draw_staleness(self, round_idx: int, k: int) -> np.ndarray:
        if self.mode == "sync" or self.max_staleness == 0:
            return np.zeros((k,), np.int64)
        cap = min(self.max_staleness, len(self._snapshots) - 1)
        rng = np.random.default_rng([self.seed, round_idx, 7])
        return rng.integers(0, cap + 1, size=k)
