"""Back-compat re-export: the bandwidth ledger moved into the channel
protocol layer (:mod:`repro.core.ledger`, DESIGN.md §12) so measured-vs-
analytic Eq. 1/Eq. 5 accounting is uniform across the local, GSPMD, and
federated backends — not a fed-only feature.  Existing
``repro.fed.ledger`` imports keep working unchanged.
"""
from repro.core.ledger import BandwidthLedger, RoundRecord

__all__ = ["BandwidthLedger", "RoundRecord"]
