"""Federated orchestration subsystem (DESIGN.md §9).

Layers the paper's §I parameter-server deployment on top of the staged
codec/wire stack:

  :mod:`repro.fed.server`     ParameterServer — decode SBW1 uploads,
                              pluggable aggregation, server-side residual,
                              compressed downstream broadcast
  :mod:`repro.fed.clients`    ClientPool — partial participation over
                              heterogeneous client profiles, each cohort
                              one vmapped/``lax.scan`` step
  :mod:`repro.fed.scheduler`  RoundScheduler — sync and async/stale rounds
  :mod:`repro.fed.ledger`     BandwidthLedger — bidirectional measured vs
                              analytic (Eq. 1/Eq. 5) byte accounting

Entry points: ``python -m repro.launch.fed`` (CLI) and
``examples/federated_wire.py`` (minimal script).
"""
from repro.fed.clients import ClientPool, ClientProfile, CohortResult
from repro.fed.ledger import BandwidthLedger, RoundRecord
from repro.fed.scheduler import RoundScheduler
from repro.fed.server import (
    AGGREGATORS,
    Broadcast,
    ClientUpdate,
    ParameterServer,
    staleness_weights,
)

__all__ = [
    "AGGREGATORS",
    "BandwidthLedger",
    "Broadcast",
    "ClientPool",
    "ClientProfile",
    "ClientUpdate",
    "CohortResult",
    "ParameterServer",
    "RoundRecord",
    "RoundScheduler",
    "staleness_weights",
]
