"""Federated orchestration subsystem (DESIGN.md §9).

Layers the paper's §I parameter-server deployment on top of the staged
codec/wire stack:

  :mod:`repro.fed.server`     ParameterServer — decode SBW1 uploads,
                              pluggable aggregation, server-side residual,
                              compressed downstream broadcast
  :mod:`repro.fed.clients`    ClientPool — partial participation over
                              heterogeneous client profiles, each cohort
                              one vmapped/``lax.scan`` step
  :mod:`repro.fed.scheduler`  RoundScheduler — sync and async/stale rounds,
                              dropout/rejoin + straggler timeouts
  :mod:`repro.fed.faults`     FaultSchedule — deterministic, seeded fault
                              injection (drop/slow/corrupt/kill_server)
  :mod:`repro.fed.checkpoint` save/restore the WHOLE federation state,
                              bit-identical resume (mid-round included)
  :mod:`repro.fed.ledger`     BandwidthLedger — bidirectional measured vs
                              analytic (Eq. 1/Eq. 5) byte accounting

Entry points: ``python -m repro.launch.fed`` (CLI) and
``examples/federated_wire.py`` (minimal script).
"""
from repro.fed.checkpoint import restore_fed_state, save_fed_state
from repro.fed.clients import (
    CLIENT_STORES,
    ClientPool,
    ClientProfile,
    CohortResult,
    SpilledClientStore,
)
from repro.fed.faults import (
    KILL_STEPS,
    NO_FAULTS,
    FaultSchedule,
    ServerKilled,
)
from repro.fed.ledger import BandwidthLedger, RoundRecord
from repro.fed.scheduler import RoundScheduler
from repro.fed.server import (
    AGGREGATORS,
    Broadcast,
    ClientUpdate,
    ParameterServer,
    staleness_weights,
)

__all__ = [
    "AGGREGATORS",
    "BandwidthLedger",
    "Broadcast",
    "CLIENT_STORES",
    "ClientPool",
    "ClientProfile",
    "ClientUpdate",
    "CohortResult",
    "FaultSchedule",
    "KILL_STEPS",
    "NO_FAULTS",
    "ParameterServer",
    "RoundRecord",
    "RoundScheduler",
    "ServerKilled",
    "SpilledClientStore",
    "restore_fed_state",
    "save_fed_state",
]
