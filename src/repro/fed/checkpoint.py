"""Whole-federation checkpointing: kill the server, restart, continue
bit-identically (DESIGN.md §14).

``repro.checkpoint.io`` snapshots a *trainer* — params plus one replicated
optimizer/compressor state.  A federated run is a bigger closure: master
weights W, the replica Ŵ, the server-side downstream residual/rng, the
DeltaLog (replica + held blob window), every client's optimizer +
compressor state, the scheduler's staleness snapshot ring and rejoin
bookkeeping, the channel's per-client sync horizon, the full bandwidth
ledger, and — for a mid-round kill — the aggregated-but-unbroadcast
pending round.  :func:`save_fed_state` captures ALL of it into one
compressed ``.npz``; :func:`restore_fed_state` writes it back into a
freshly-built scheduler of the same spec, after which
``resume_pending()`` + ``run(..., start_round=...)`` continues the
trajectory bit-for-bit (``tests/test_checkpoint_resume.py`` pins this
against an uninterrupted run, ledger totals and DeltaLog contents
included).

Array payloads ride the same npz + '/'-joined-path layout as
``repro.checkpoint.io`` (bfloat16 as uint16 bit patterns); everything
non-array — round counters, ledger rows, fault bookkeeping, the pending
round — is one JSON blob under ``__fedmeta__``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import _flatten_with_paths
from repro.core.ledger import BandwidthLedger, RoundRecord
from repro.core.policy import CompressorState

PyTree = Any

FORMAT = "fedckpt-v1"
_BF16_TAG = "__bf16__"


def _fixed_tree(sched) -> Dict[str, Any]:
    """The checkpoint's template-shaped half: every array whose shape is
    determined by the run spec (so restore can validate against a freshly
    built scheduler).  Variable-size payloads — snapshot ring, DeltaLog
    window — are keyed separately."""
    server = sched.server
    down = server._down_state
    return {
        "server": {"params": server.params, "estimate": server.estimate},
        "down": {"residual": down.residual, "rng": down.rng, "step": down.step},
        "pool": sched.pool.export_state(),
    }


def _key_of(pathkeys) -> str:
    return "/".join(
        k.key if hasattr(k, "key") else str(getattr(k, "idx", k))
        for k in pathkeys
    )


def save_fed_state(path: str, sched, rounds_done: Optional[int] = None) -> None:
    """Checkpoint a :class:`~repro.fed.scheduler.RoundScheduler` (server +
    pool + channel + log) to ``path``.  ``rounds_done`` records how many
    rounds completed (a mid-round kill counts its round as NOT done —
    ``resume_pending`` finishes it after restore)."""
    arrays: Dict[str, np.ndarray] = {}
    bf16 = []

    def put(key: str, value) -> None:
        arr = np.asarray(jax.device_get(value))
        if arr.dtype == jnp.bfloat16:
            arrays[key] = arr.view(np.uint16)
            bf16.append(key)
        else:
            arrays[key] = arr

    for k, v in _flatten_with_paths(_fixed_tree(sched)).items():
        put(f"fixed/{k}", v)

    for k, snap in enumerate(sched._snapshots):
        for i, leaf in enumerate(jax.tree.leaves(snap)):
            put(f"snap/{k}/{i}", leaf)

    log = getattr(sched.server, "delta_log", None)
    log_meta = None
    if log is not None:
        st = log.state_dict()
        for i, rep in enumerate(st["replica"]):
            put(f"log/replica/{i}", rep)
        for j, (_, blob, _) in enumerate(st["entries"]):
            arrays[f"log/blob/{j}"] = np.frombuffer(blob, np.uint8)
        log_meta = {
            "head": st["head"],
            "entry_rounds": [r for r, _, _ in st["entries"]],
            "entry_bits": [b for _, _, b in st["entries"]],
        }

    ch = sched.channel
    meta = {
        "format": FORMAT,
        "bf16": bf16,
        "rounds_done": rounds_done,
        "n_snapshots": len(sched._snapshots),
        "last_download": {str(k): int(v) for k, v in sched._last_download.items()},
        "failed": {str(k): int(v) for k, v in sched._failed.items()},
        "kills_fired": sorted([int(r), s] for r, s in sched._kills_fired),
        "last_sync": {str(k): int(v) for k, v in ch._last_sync.items()},
        "pending": ch._pending,
        "ledger": [dataclasses.asdict(rec) for rec in ch.ledger.records],
        "log": log_meta,
    }
    arrays["__fedmeta__"] = np.frombuffer(json.dumps(meta).encode(), np.uint8)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez_compressed(path, **arrays)


def restore_fed_state(path: str, sched) -> dict:
    """Restore :func:`save_fed_state` output into ``sched`` — a freshly
    built scheduler of the SAME run spec (shapes are validated against its
    template state).  Returns the checkpoint meta (``rounds_done``,
    whether a ``pending`` mid-round payload was restored, ...)."""
    with np.load(path) as z:
        meta = json.loads(bytes(z["__fedmeta__"]).decode())
        data = {k: z[k] for k in z.files if k != "__fedmeta__"}
    if meta.get("format") != FORMAT:
        raise ValueError(
            f"{path} is not a {FORMAT} checkpoint (format={meta.get('format')!r})"
        )
    bf16 = set(meta.get("bf16", []))

    def get(key: str) -> np.ndarray:
        if key not in data:
            raise ValueError(f"checkpoint {path} is missing array {key!r}")
        arr = data[key]
        return arr.view(jnp.bfloat16) if key in bf16 else arr

    # -- template-shaped half: restore into the fresh scheduler's structure
    tmpl = _fixed_tree(sched)
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(tmpl)
    new_leaves = []
    for pathkeys, leaf in leaves_paths:
        key = f"fixed/{_key_of(pathkeys)}"
        arr = get(key)
        if tuple(np.shape(arr)) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch at {key}: checkpoint {np.shape(arr)} vs "
                f"template {np.shape(leaf)}"
            )
        new_leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)

    server = sched.server
    server.params = jax.tree.map(
        lambda t, a: jnp.asarray(a, t.dtype),
        tmpl["server"]["params"], tree["server"]["params"],
    )
    server.estimate = jax.tree.map(
        lambda a: jnp.asarray(a, jnp.float32), tree["server"]["estimate"]
    )
    down = tree["down"]
    server._down_state = CompressorState(
        residual=jax.tree.map(jnp.asarray, down["residual"]),
        rng=jnp.asarray(down["rng"]),
        step=jnp.asarray(down["step"]),
    )
    sched.pool.import_state(tree["pool"])

    # -- staleness snapshot ring (saved newest-first, deque iteration order)
    est_leaves, est_def = jax.tree.flatten(server.estimate)
    sched._snapshots.clear()
    for k in range(int(meta["n_snapshots"])):
        leaves = [
            jnp.asarray(get(f"snap/{k}/{i}"), jnp.float32)
            for i in range(len(est_leaves))
        ]
        sched._snapshots.append(jax.tree.unflatten(est_def, leaves))

    # -- DeltaLog: replica set directly, window entries re-decoded from
    #    their stored bytes through the same down-wire contract
    log = getattr(server, "delta_log", None)
    if (log is None) != (meta["log"] is None):
        raise ValueError(
            "checkpoint and scheduler disagree on delta_horizon "
            f"(checkpoint log: {meta['log'] is not None}, "
            f"scheduler log: {log is not None})"
        )
    if log is not None:
        lm = meta["log"]
        log.restore(
            {
                "head": lm["head"],
                "replica": [
                    get(f"log/replica/{i}") for i in range(len(log._replica))
                ],
                "entries": [
                    (r, get(f"log/blob/{j}").tobytes(), b)
                    for j, (r, b) in enumerate(
                        zip(lm["entry_rounds"], lm["entry_bits"])
                    )
                ],
            },
            wire_for_round=server.down_wire,
        )

    # -- bookkeeping: rejoin maps, fired kills, sync horizon, ledger, pending
    sched._last_download = {
        int(k): int(v) for k, v in meta["last_download"].items()
    }
    sched._failed = {int(k): int(v) for k, v in meta["failed"].items()}
    sched._kills_fired = {(int(r), str(s)) for r, s in meta["kills_fired"]}
    ch = sched.channel
    ch._last_sync = {int(k): int(v) for k, v in meta["last_sync"].items()}
    ch._pending = meta["pending"]
    ch.ledger = BandwidthLedger()
    for rec in meta["ledger"]:
        rec = dict(rec)
        rec["cohort"] = tuple(int(c) for c in rec["cohort"])
        ch.ledger.record(RoundRecord(**rec))
    return meta
