"""Deterministic fault injection for the federated backend (DESIGN.md §14).

Elasticity claims are bit-level claims in an error-feedback system: a
client that misses a round must leave its residual/momentum EXACTLY as it
was, partial aggregation must equal the survivors-only aggregation, and a
server resumed mid-round must continue bit-identically.  None of that can
be tested with best-effort retries and wall clocks — so faults here are
*data*, not chance: a frozen, seeded :class:`FaultSchedule` names exactly
which client fails how in which round, and every consumer (scheduler,
channel, tests, benchmarks, the ``--faults`` flag) replays the same
schedule to the byte.

Four fault kinds:

  drop      (round, client) — the client is offline for the round: it is
            excluded before download, sends nothing, costs nothing, and
            its pool state is untouched.
  slow      (round, client, slowdown) — the client's simulated round
            duration is ``profile.delay × slowdown`` time units; with a
            scheduler ``straggler_timeout`` set, durations above the
            timeout abort the upload (work done, bytes wasted, state
            rolled back — DGC's partial-participation hazard).
  corrupt   (round, client) — the upload is damaged in flight
            (:meth:`FaultSchedule.corrupt_blob`: seeded truncation + byte
            flips); the server's decode rejects it, aggregation proceeds
            over the survivors, and the sender's state is rolled back.
  kill_server  (round, step) — the server process dies at ``step``
            ("pre_round": at the round boundary, before any work;
            "post_aggregate": mid-round, after partial aggregation but
            before the broadcast), raising :class:`ServerKilled` for the
            driver to checkpoint/resume against.

The schedule is JSON round-trippable (``to_json`` / ``from_json`` /
``parse``) so ``--faults`` can take an inline object or a committed file.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, FrozenSet, Optional, Tuple

import numpy as np

KILL_STEPS = ("pre_round", "post_aggregate")


class ServerKilled(RuntimeError):
    """Raised when a ``kill_server`` fault fires.  Carries the round and
    step so the driver knows what checkpoint state to expect."""

    def __init__(self, round_idx: int, step: str) -> None:
        super().__init__(
            f"server killed at round {round_idx} ({step}); checkpoint and "
            "resume via repro.fed.checkpoint"
        )
        self.round_idx = int(round_idx)
        self.step = step


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A frozen, seeded schedule of injected faults.

    ``drops``/``corrupt`` are (round, client) pairs, ``slow`` is
    (round, client, slowdown) triples, ``kill_server`` is (round, step)
    pairs with step in :data:`KILL_STEPS`.  ``seed`` feeds
    :meth:`corrupt_blob`'s byte damage (per (seed, round, client), so two
    runs of the same schedule corrupt identically).
    """

    seed: int = 0
    drops: Tuple[Tuple[int, int], ...] = ()
    slow: Tuple[Tuple[int, int, float], ...] = ()
    corrupt: Tuple[Tuple[int, int], ...] = ()
    kill_server: Tuple[Tuple[int, str], ...] = ()

    def __post_init__(self) -> None:
        # normalize JSON-born lists into hashable tuples, validating as we go
        object.__setattr__(self, "drops", tuple(
            (int(r), int(c)) for r, c in self.drops
        ))
        object.__setattr__(self, "slow", tuple(
            (int(r), int(c), float(s)) for r, c, s in self.slow
        ))
        for r, c, s in self.slow:
            if s < 1.0:
                raise ValueError(f"slowdown must be >= 1, got {s} at round {r}")
        object.__setattr__(self, "corrupt", tuple(
            (int(r), int(c)) for r, c in self.corrupt
        ))
        kills = tuple((int(r), str(step)) for r, step in self.kill_server)
        for r, step in kills:
            if step not in KILL_STEPS:
                raise ValueError(
                    f"unknown kill_server step {step!r}; have {KILL_STEPS}"
                )
        rounds = [r for r, _ in kills]
        if len(set(rounds)) != len(rounds):
            raise ValueError("at most one kill_server fault per round")
        object.__setattr__(self, "kill_server", kills)

    # ------------------------------------------------------------- queries

    def drops_at(self, round_idx: int) -> FrozenSet[int]:
        return frozenset(c for r, c in self.drops if r == round_idx)

    def corrupts_at(self, round_idx: int) -> FrozenSet[int]:
        return frozenset(c for r, c in self.corrupt if r == round_idx)

    def slowdown_of(self, round_idx: int, client_id: int) -> float:
        """Simulated duration multiplier for one client this round (1.0
        when no ``slow`` fault names it)."""
        out = 1.0
        for r, c, s in self.slow:
            if r == round_idx and c == client_id:
                out = max(out, s)
        return out

    def kill_at(self, round_idx: int) -> Optional[str]:
        """The kill step scheduled for this round, or None."""
        for r, step in self.kill_server:
            if r == round_idx:
                return step
        return None

    def last_round(self) -> int:
        """Highest round any fault names (−1 for an empty schedule)."""
        rounds = (
            [r for r, _ in self.drops] + [r for r, _, _ in self.slow]
            + [r for r, _ in self.corrupt] + [r for r, _ in self.kill_server]
        )
        return max(rounds) if rounds else -1

    # ------------------------------------------------------ blob corruption

    def corrupt_blob(self, blob: bytes, round_idx: int, client_id: int) -> bytes:
        """Damage one upload buffer deterministically: truncate somewhere
        past the magic (a truncated SBW1 read always trips a length check
        → the server MUST reject it) and flip a few surviving bytes (the
        ``test_wire_fuzz`` hardening surface).  Seeded per
        (schedule seed, round, client)."""
        if len(blob) < 8:
            return b""  # nothing meaningful to keep
        rng = np.random.default_rng([self.seed, round_idx, client_id, 0xFA])
        cut = int(rng.integers(4, len(blob)))  # always loses >= 1 byte
        out = bytearray(blob[:cut])
        for pos in rng.integers(0, max(cut, 1), size=int(rng.integers(1, 4))):
            out[int(pos)] ^= int(rng.integers(1, 256))
        return bytes(out)

    # ------------------------------------------------------------ (de)spec

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(dataclasses.asdict(self), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "FaultSchedule":
        data = json.loads(text)
        if not isinstance(data, dict):
            raise ValueError(
                f"FaultSchedule JSON must be an object, got {type(data)}"
            )
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(data) - known
        if unknown:
            raise ValueError(
                f"unknown FaultSchedule fields {sorted(unknown)}; "
                f"have {sorted(known)}"
            )
        return cls(**data)

    @classmethod
    def parse(cls, spec: str) -> "FaultSchedule":
        """``--faults`` surface: an inline JSON object or a path to one."""
        text = spec
        if not spec.lstrip().startswith("{"):
            if not os.path.exists(spec):
                raise ValueError(
                    f"--faults wants inline JSON or a file path; {spec!r} "
                    "is neither"
                )
            with open(spec) as f:
                text = f.read()
        return cls.from_json(text)


#: the schedule that injects nothing — the failure-free reference
NO_FAULTS = FaultSchedule()


def straggler_ids(
    schedule: Optional[FaultSchedule],
    round_idx: int,
    ids,
    delays: Dict[int, int],
    timeout: Optional[float],
) -> FrozenSet[int]:
    """Clients whose simulated duration ``delay × slowdown`` exceeds the
    straggler timeout this round (empty without a timeout)."""
    if timeout is None:
        return frozenset()
    sched = schedule if schedule is not None else NO_FAULTS
    return frozenset(
        int(c) for c in ids
        if delays[int(c)] * sched.slowdown_of(round_idx, int(c)) > timeout
    )
