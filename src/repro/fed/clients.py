"""Client cohorts: partial participation over a heterogeneous client pool
(DESIGN.md §9).

A :class:`ClientPool` holds the per-client state of M federated clients —
local optimizer state, compressor state (error-feedback residual, RNG) —
stacked along a leading client axis, exactly the layout
:class:`repro.train.trainer.DSGDTrainer` uses.  Each round the scheduler
samples a *cohort* (partial participation) and the pool executes every
sampled client's local training + compression as ONE jitted
``vmap``-over-members / ``lax.scan``-over-local-steps call instead of a
per-client Python loop — the O(clients) interpreter overhead of the old
``examples/federated_wire.py`` collapses into a single dispatch.

Heterogeneity is expressed with :class:`ClientProfile`\\ s: client ``c`` is
bound to ``profiles[c % len(profiles)]``, which pins its communication
delay (temporal sparsity) and upstream gradient sparsity — the two axes of
the paper's §III trade-off.  Members of a cohort are grouped by profile and
each group runs as one vmapped step (delay and per-leaf rates are static
under jit, so they cannot vary *inside* a vmap).

Cohort sampling is deterministic: round ``r`` of a pool seeded ``s`` draws
its cohort (and nothing else) from ``np.random.default_rng([s, r])``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import CompressionPolicy, CompressorState, ResolvedPolicy
from repro.data.synthetic import Task
from repro.models.model import Model
from repro.optim.optimizers import Optimizer

PyTree = Any


class ClientProfile(NamedTuple):
    """Static per-client hyper-parameters (hashable → usable under jit).

    delay:    local optimizer steps per round (communication delay n).
    sparsity: upstream gradient sparsity rate p for this client's uploads.
    weight:   relative dataset size, for sample-weighted aggregation.
    """

    delay: int = 1
    sparsity: float = 0.01
    weight: float = 1.0


class CohortResult(NamedTuple):
    """One sampled cohort's outputs, per member (aligned lists/arrays)."""

    client_ids: Tuple[int, ...]
    ctrees: List[PyTree]  # compressed update pytrees (LeafCompressed leaves)
    losses: np.ndarray  # (K,) mean loss over each member's delay window
    bits_analytic: np.ndarray  # (K,) Eq. 1 upstream bits per member
    rates: Tuple[float, ...]  # per-member upstream sparsity rate
    weights: Tuple[float, ...]  # per-member aggregation sample weight


def stack_clients(tree: PyTree, k: int) -> PyTree:
    """Broadcast a single pytree to a leading k-member axis."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (k,) + x.shape).copy(), tree
    )


@dataclasses.dataclass(eq=False)  # id-hash → usable as a jit static arg
class ClientPool:
    model: Model
    optimizer: Optimizer
    policy: CompressionPolicy
    task: Task
    n_clients: int
    lr: Callable[[jax.Array], jax.Array]
    profiles: Tuple[ClientProfile, ...] = (ClientProfile(),)
    seed: int = 0
    # None → keep the policy's own flag; True/False → force the flat-buffer
    # fast path (core/flat.py §10) for every member's compression.  The
    # pooled residual then has shape (n_clients, n_pad) instead of a
    # stacked per-leaf pytree — gather/scatter and the vmapped group step
    # are layout-agnostic, so nothing else changes.
    fast: Optional[bool] = None

    def __post_init__(self) -> None:
        if self.n_clients < 1:
            raise ValueError("need at least one client")
        if self.fast is not None and self.fast != self.policy.fast:
            self.policy = dataclasses.replace(self.policy, fast=self.fast)
        for prof in self.profiles:
            if prof.delay < 1:
                raise ValueError(
                    f"profile delay must be >= 1, got {prof.delay} "
                    "(delay=0 would upload an untrained zero delta)"
                )
        self._resolved: Optional[ResolvedPolicy] = None
        self._opt_states: PyTree = None
        self._comp_state: Optional[CompressorState] = None
        self._ref_leaf_shape: Optional[Tuple[int, ...]] = None

    # ------------------------------------------------------------ lifecycle

    def resolved(self, params: PyTree) -> ResolvedPolicy:
        if self._resolved is None:
            # shared with the server via the once-per-topology cache
            from repro.core.channel import resolve_cached

            self._resolved = resolve_cached(self.policy, params)
        return self._resolved

    def init(self, params: PyTree, rng: Optional[jax.Array] = None) -> None:
        """Allocate per-client optimizer/compressor state (leading N axis)."""
        if rng is None:
            rng = jax.random.PRNGKey(self.seed)
        resolved = self.resolved(params)
        self._opt_states = stack_clients(self.optimizer.init(params), self.n_clients)
        comp = resolved.init_state(params)
        self._comp_state = CompressorState(
            residual=stack_clients(comp.residual, self.n_clients),
            rng=jax.random.split(rng, self.n_clients),
            step=jnp.zeros((self.n_clients,), jnp.int32),
        )

    def profile_of(self, client_id: int) -> ClientProfile:
        return self.profiles[client_id % len(self.profiles)]

    # ------------------------------------------------------------- sampling

    def sample_cohort(self, round_idx: int, cohort_size: int) -> np.ndarray:
        """Deterministic partial participation: ``cohort_size`` distinct
        clients drawn from ``default_rng([seed, round])``, ascending ids."""
        k = min(cohort_size, self.n_clients)
        rng = np.random.default_rng([self.seed, round_idx])
        return np.sort(rng.choice(self.n_clients, size=k, replace=False))

    # ----------------------------------------------------------- cohort step

    def run_cohort(
        self,
        round_idx: int,
        cohort_ids: Sequence[int],
        start_params: PyTree,
    ) -> CohortResult:
        """Execute one sampled cohort.

        ``start_params`` is either one shared pytree (sync rounds: every
        member trains from the current broadcast estimate) or a pytree with
        a leading member axis aligned with ``cohort_ids`` (async rounds:
        stale members start from older estimates).

        Members are grouped by profile; each group is one jitted
        vmap/scan step.  Per-client optimizer and compressor state is
        gathered for the cohort and scattered back afterwards.
        """
        if self._comp_state is None:
            raise RuntimeError("ClientPool.init(params) must run first")
        ids = np.asarray(cohort_ids, np.int32)
        k_total = ids.size
        stacked_start = self._has_member_axis(start_params, k_total)
        resolved = self._resolved

        ctrees: List[PyTree] = [None] * k_total
        losses = np.zeros((k_total,), np.float64)
        bits = np.zeros((k_total,), np.float64)

        for prof_i, prof in enumerate(self.profiles):
            member_pos = np.nonzero(ids % len(self.profiles) == prof_i)[0]
            if member_pos.size == 0:
                continue
            group_ids = ids[member_pos]
            gidx = jnp.asarray(group_ids)
            if stacked_start:
                group_start = jax.tree.map(
                    lambda x: x[jnp.asarray(member_pos)], start_params
                )
            else:
                group_start = start_params  # broadcast inside the vmapped step
            opt_g, comp_g = self._gather_states(
                self._opt_states, self._comp_state, gidx
            )
            batch = self._group_batch(round_idx, group_ids, prof.delay)
            rates = resolved.rates(prof.sparsity, round_idx)
            ctree_g, opt_g, comp_g, loss_g, bits_g = self._group_step(
                group_start, opt_g, comp_g, batch,
                jnp.asarray(round_idx * prof.delay, jnp.int32),
                n_delay=prof.delay, rates=rates, shared_start=not stacked_start,
            )
            self._opt_states, self._comp_state = self._scatter_states(
                self._opt_states, self._comp_state, gidx, opt_g, comp_g
            )
            # one device→host transfer for the whole group, then cheap
            # numpy slicing per member (pack works on numpy anyway)
            ctree_np, loss_np, bits_np = jax.device_get((ctree_g, loss_g, bits_g))
            for j, pos in enumerate(member_pos):
                ctrees[int(pos)] = jax.tree.map(lambda x: x[j], ctree_np)
                losses[int(pos)] = loss_np[j]
                bits[int(pos)] = bits_np[j]

        profs = [self.profile_of(int(c)) for c in ids]
        return CohortResult(
            client_ids=tuple(int(c) for c in ids),
            ctrees=ctrees,
            losses=losses,
            bits_analytic=bits,
            rates=tuple(p.sparsity for p in profs),
            weights=tuple(p.weight * p.delay for p in profs),
        )

    @partial(jax.jit, static_argnames=("self", "n_delay", "rates", "shared_start"))
    def _group_step(
        self,
        start_params: PyTree,  # (K, ...) per-member starts, or shared (sync)
        opt_states: PyTree,  # (K, ...)
        comp_states: CompressorState,  # (K, ...)
        batch: PyTree,  # (K, n_delay, B, ...)
        iteration: jax.Array,
        *,
        n_delay: int,
        rates: Tuple[float, ...],
        shared_start: bool = False,
    ) -> tuple:
        """One profile group's round: vmapped local training (scan over the
        delay window) + per-member compression with error feedback, the same
        Alg. 1 l.10-14 structure as ``DSGDTrainer.round_step``."""
        resolved = self._resolved

        def local(params0, opt_state, comp_state, client_batch):
            def one(carry, micro):
                p, os, it = carry
                loss, g = jax.value_and_grad(self.model.loss_fn)(p, micro)
                p2, os2 = self.optimizer.apply(os, g, p, self.lr(it), it)
                return (p2, os2, it + 1), loss

            (p_new, os_new, _), step_losses = jax.lax.scan(
                one, (params0, opt_state, iteration), client_batch
            )
            delta = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                p_new, params0,
            )
            ctree, dense, comp_state = resolved.compress(delta, comp_state, rates)
            # momentum masking at transmitted coordinates (supplement A)
            transmitted = jax.tree.map(lambda d: (d != 0).astype(jnp.float32), dense)
            os_new = self.optimizer.mask(os_new, transmitted)
            # mean over the whole delay window, not the last local step
            return ctree, os_new, comp_state, jnp.mean(step_losses), resolved.total_bits(ctree)

        in_axes = (None if shared_start else 0, 0, 0, 0)
        return jax.vmap(local, in_axes=in_axes)(
            start_params, opt_states, comp_states, batch
        )

    # ------------------------------------------------------------- plumbing

    @partial(jax.jit, static_argnames=("self",))
    def _gather_states(self, opt_full, comp_full, gidx):
        """Pull one cohort group's rows out of the pooled state (one fused
        dispatch — per-leaf eager gathers dominate round time otherwise)."""
        opt_g = jax.tree.map(lambda x: x[gidx], opt_full)
        comp_g = CompressorState(
            residual=jax.tree.map(lambda x: x[gidx], comp_full.residual),
            rng=comp_full.rng[gidx],
            step=comp_full.step[gidx],
        )
        return opt_g, comp_g

    @partial(jax.jit, static_argnames=("self",))
    def _scatter_states(self, opt_full, comp_full, gidx, opt_upd, comp_upd):
        """Write a group's updated rows back (one fused dispatch)."""
        opt_full = jax.tree.map(
            lambda full, upd: full.at[gidx].set(upd), opt_full, opt_upd
        )
        comp_full = CompressorState(
            residual=jax.tree.map(
                lambda full, upd: full.at[gidx].set(upd),
                comp_full.residual, comp_upd.residual,
            ),
            rng=comp_full.rng.at[gidx].set(comp_upd.rng),
            step=comp_full.step.at[gidx].set(comp_upd.step),
        )
        return opt_full, comp_full

    def _group_batch(self, round_idx: int, ids: np.ndarray, delay: int) -> PyTree:
        """(K, delay, B, ...) microbatches for one profile group — the same
        (client, local-step) layout as :func:`repro.data.client_batches`,
        generated in ONE dispatch when the task supports ``sample_many``."""
        if self.task.sample_many is not None:
            clients = np.repeat(ids, delay)
            micro = np.tile(round_idx * delay + np.arange(delay), ids.size)
            flat = self.task.sample_many(micro, clients)  # (K·D, B, ...)
            return jax.tree.map(
                lambda x: x.reshape((ids.size, delay) + x.shape[1:]), flat
            )
        steps = []
        for d in range(delay):
            per = [self.task.sample(round_idx * delay + d, int(c)) for c in ids]
            steps.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
        return jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *steps)

    def _has_member_axis(self, start_params: PyTree, k: int) -> bool:
        """True when ``start_params`` already carries a leading cohort axis."""
        if self._ref_leaf_shape is None:
            shapes = jax.eval_shape(self.model.init, jax.random.PRNGKey(0))
            self._ref_leaf_shape = tuple(jax.tree.leaves(shapes)[0].shape)
        got = tuple(jax.tree.leaves(start_params)[0].shape)
        return got == (k,) + self._ref_leaf_shape
