"""Client cohorts: partial participation over a heterogeneous client pool
(DESIGN.md §9).

A :class:`ClientPool` holds the per-client state of M federated clients —
local optimizer state, compressor state (error-feedback residual, RNG) —
stacked along a leading client axis, exactly the layout
:class:`repro.train.trainer.DSGDTrainer` uses.  Each round the scheduler
samples a *cohort* (partial participation) and the pool executes every
sampled client's local training + compression as ONE jitted
``vmap``-over-members / ``lax.scan``-over-local-steps call instead of a
per-client Python loop — the O(clients) interpreter overhead of the old
``examples/federated_wire.py`` collapses into a single dispatch.

Heterogeneity is expressed with :class:`ClientProfile`\\ s: client ``c`` is
bound to ``profiles[c % len(profiles)]``, which pins its communication
delay (temporal sparsity) and upstream gradient sparsity — the two axes of
the paper's §III trade-off.  Members of a cohort are grouped by profile and
each group runs as one vmapped step (delay and per-leaf rates are static
under jit, so they cannot vary *inside* a vmap).

Cohort sampling is deterministic: round ``r`` of a pool seeded ``s`` draws
its cohort (and nothing else) from ``np.random.default_rng([s, r])``.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import tempfile
from functools import partial
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import CompressionPolicy, CompressorState, ResolvedPolicy
from repro.data.synthetic import Task
from repro.models.model import Model
from repro.optim.optimizers import Optimizer

PyTree = Any


class ClientProfile(NamedTuple):
    """Static per-client hyper-parameters (hashable → usable under jit).

    delay:    local optimizer steps per round (communication delay n).
    sparsity: upstream gradient sparsity rate p for this client's uploads.
    weight:   relative dataset size, for sample-weighted aggregation.
    """

    delay: int = 1
    sparsity: float = 0.01
    weight: float = 1.0


class CohortResult(NamedTuple):
    """One sampled cohort's outputs, per member (aligned lists/arrays)."""

    client_ids: Tuple[int, ...]
    ctrees: List[PyTree]  # compressed update pytrees (LeafCompressed leaves)
    losses: np.ndarray  # (K,) mean loss over each member's delay window
    bits_analytic: np.ndarray  # (K,) Eq. 1 upstream bits per member
    rates: Tuple[float, ...]  # per-member upstream sparsity rate
    weights: Tuple[float, ...]  # per-member aggregation sample weight


def stack_clients(tree: PyTree, k: int) -> PyTree:
    """Broadcast a single pytree to a leading k-member axis."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (k,) + x.shape).copy(), tree
    )


CLIENT_STORES = ("device", "host", "memmap")


class SpilledClientStore:
    """Per-client pool state spilled OFF the accelerator (DESIGN.md §14).

    ``device`` pools hold every client's optimizer + compressor state as
    stacked device arrays — O(n_clients · model) device memory, the wall
    between the 10–100 client regime and the paper's 10k–1M populations.
    This store keeps the same leading-N layout in plain host numpy
    (``kind="host"``) or lazily-allocated on-disk ``.npy`` memmaps
    (``kind="memmap"``): the zero-filled state of never-sampled clients
    costs no resident pages, and a cohort tile's rows page in/out on
    gather/scatter.  Zero-initialized leaves (momentum, residual, step)
    are never written at init, so a fresh 1M-client memmap pool is a
    handful of sparse files plus the (N, 2) RNG key table.
    """

    def __init__(
        self,
        opt_row: PyTree,
        comp_row: CompressorState,
        rng_rows: jax.Array,
        *,
        n_clients: int,
        kind: str = "host",
        directory: Optional[str] = None,
    ) -> None:
        if kind not in ("host", "memmap"):
            raise ValueError(f"spilled store kind must be host|memmap, got {kind!r}")
        self.kind = kind
        self.n_clients = int(n_clients)
        if kind == "memmap":
            directory = directory or tempfile.mkdtemp(prefix="repro-clients-")
            os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self._n_files = itertools.count()
        self._opt = jax.tree.map(self._alloc, opt_row)
        self._residual = jax.tree.map(self._alloc, comp_row.residual)
        rng_np = np.asarray(jax.device_get(rng_rows))
        self._rng = self._alloc_raw(rng_np.shape, rng_np.dtype)
        self._rng[:] = rng_np  # the one leaf that is never zero
        self._step = self._alloc_raw((self.n_clients,), np.int32)

    def _alloc_raw(self, shape, dtype) -> np.ndarray:
        if self.kind == "host":
            return np.zeros(shape, dtype)
        path = os.path.join(self.directory, f"leaf{next(self._n_files)}.npy")
        return np.lib.format.open_memmap(path, mode="w+", dtype=dtype,
                                         shape=shape)

    def _alloc(self, row) -> np.ndarray:
        row = np.asarray(jax.device_get(row))
        arr = self._alloc_raw((self.n_clients,) + row.shape, row.dtype)
        if np.any(row):  # nonzero template → must materialize every row
            arr[:] = row
        return arr

    @property
    def nbytes(self) -> int:
        """Logical size of the pooled state (memmaps are sparse: resident
        bytes stay far below this until rows are actually written)."""
        leaves = jax.tree.leaves((self._opt, self._residual))
        return int(sum(x.nbytes for x in leaves)
                   + self._rng.nbytes + self._step.nbytes)

    # ------------------------------------------------------ gather/scatter

    def gather(self, ids: np.ndarray) -> Tuple[PyTree, CompressorState]:
        """One tile's rows, host → device."""
        opt_g = jax.tree.map(lambda x: jnp.asarray(x[ids]), self._opt)
        comp_g = CompressorState(
            residual=jax.tree.map(lambda x: jnp.asarray(x[ids]), self._residual),
            rng=jnp.asarray(self._rng[ids]),
            step=jnp.asarray(self._step[ids]),
        )
        return opt_g, comp_g

    def scatter(self, ids: np.ndarray, opt_g: PyTree,
                comp_g: CompressorState) -> None:
        """Write a tile's updated rows back (device → host; duplicate ids
        from tile padding carry identical rows, so last-write-wins is
        deterministic)."""
        opt_g, comp_g = jax.device_get((opt_g, comp_g))
        jax.tree.map(lambda full, upd: full.__setitem__(ids, upd),
                     self._opt, opt_g)
        jax.tree.map(lambda full, upd: full.__setitem__(ids, upd),
                     self._residual, comp_g.residual)
        self._rng[ids] = comp_g.rng
        self._step[ids] = comp_g.step

    # ------------------------------------------------------- checkpointing

    def export(self) -> Dict[str, Any]:
        """Materialized host copies of the full pooled state."""
        return {
            "opt": jax.tree.map(np.array, self._opt),
            "residual": jax.tree.map(np.array, self._residual),
            "rng": np.array(self._rng),
            "step": np.array(self._step),
        }

    def import_(self, state: Dict[str, Any]) -> None:
        jax.tree.map(lambda full, v: full.__setitem__(slice(None), v),
                     self._opt, state["opt"])
        jax.tree.map(lambda full, v: full.__setitem__(slice(None), v),
                     self._residual, state["residual"])
        self._rng[:] = state["rng"]
        self._step[:] = state["step"]


@dataclasses.dataclass(eq=False)  # id-hash → usable as a jit static arg
class ClientPool:
    model: Model
    optimizer: Optimizer
    policy: CompressionPolicy
    task: Task
    n_clients: int
    lr: Callable[[jax.Array], jax.Array]
    profiles: Tuple[ClientProfile, ...] = (ClientProfile(),)
    seed: int = 0
    # None → keep the policy's own flag; True/False → force the flat-buffer
    # fast path (core/flat.py §10) for every member's compression.  The
    # pooled residual then has shape (n_clients, n_pad) instead of a
    # stacked per-leaf pytree — gather/scatter and the vmapped group step
    # are layout-agnostic, so nothing else changes.
    fast: Optional[bool] = None
    # streaming/tiled cohort executor (DESIGN.md §14): cap the member axis
    # of one compiled step at `cohort_tile` (None → whole profile group in
    # one vmap, the original behavior).  Short tiles are padded by
    # repeating their last member, so every tile shares ONE compiled
    # shape; padded outputs are discarded and padded scatters rewrite the
    # identical row.  Peak per-round device state is O(tile), not
    # O(cohort).
    cohort_tile: Optional[int] = None
    # where the pooled per-client state lives between rounds: "device"
    # (stacked jnp arrays, the original layout), "host" (numpy), or
    # "memmap" (on-disk, lazily allocated — the 10k–1M client regime)
    store: str = "device"
    store_dir: Optional[str] = None  # memmap backing directory

    def __post_init__(self) -> None:
        if self.n_clients < 1:
            raise ValueError("need at least one client")
        if self.fast is not None and self.fast != self.policy.fast:
            self.policy = dataclasses.replace(self.policy, fast=self.fast)
        if self.store not in CLIENT_STORES:
            raise ValueError(
                f"unknown client store {self.store!r}; have {CLIENT_STORES}"
            )
        if self.cohort_tile is not None and self.cohort_tile < 1:
            raise ValueError(f"cohort_tile must be >= 1, got {self.cohort_tile}")
        for prof in self.profiles:
            if prof.delay < 1:
                raise ValueError(
                    f"profile delay must be >= 1, got {prof.delay} "
                    "(delay=0 would upload an untrained zero delta)"
                )
        self._resolved: Optional[ResolvedPolicy] = None
        self._opt_states: PyTree = None
        self._comp_state: Optional[CompressorState] = None
        self._spill: Optional[SpilledClientStore] = None
        self._ref_leaf_shape: Optional[Tuple[int, ...]] = None

    # ------------------------------------------------------------ lifecycle

    def resolved(self, params: PyTree) -> ResolvedPolicy:
        if self._resolved is None:
            # shared with the server via the once-per-topology cache
            from repro.core.channel import resolve_cached

            self._resolved = resolve_cached(self.policy, params)
        return self._resolved

    def init(self, params: PyTree, rng: Optional[jax.Array] = None) -> None:
        """Allocate per-client optimizer/compressor state (leading N axis):
        stacked device arrays for the "device" store, one
        :class:`SpilledClientStore` otherwise."""
        if rng is None:
            rng = jax.random.PRNGKey(self.seed)
        resolved = self.resolved(params)
        opt_row = self.optimizer.init(params)
        comp_row = resolved.init_state(params)
        rng_rows = jax.random.split(rng, self.n_clients)
        if self.store == "device":
            self._opt_states = stack_clients(opt_row, self.n_clients)
            self._comp_state = CompressorState(
                residual=stack_clients(comp_row.residual, self.n_clients),
                rng=rng_rows,
                step=jnp.zeros((self.n_clients,), jnp.int32),
            )
            self._spill = None
        else:
            self._spill = SpilledClientStore(
                opt_row, comp_row, rng_rows, n_clients=self.n_clients,
                kind=self.store, directory=self.store_dir,
            )
            self._opt_states = self._comp_state = None

    @property
    def initialized(self) -> bool:
        return self._comp_state is not None or self._spill is not None

    def state_nbytes(self) -> int:
        """Logical bytes of the pooled per-client state, all clients."""
        if self._spill is not None:
            return self._spill.nbytes
        if self._comp_state is None:
            raise RuntimeError("ClientPool.init(params) must run first")
        leaves = jax.tree.leaves(
            (self._opt_states, self._comp_state.residual)
        ) + [self._comp_state.rng, self._comp_state.step]
        return int(sum(x.nbytes for x in leaves))

    def profile_of(self, client_id: int) -> ClientProfile:
        return self.profiles[client_id % len(self.profiles)]

    # ------------------------------------------------------------- sampling

    def sample_cohort(self, round_idx: int, cohort_size: int) -> np.ndarray:
        """Deterministic partial participation: ``cohort_size`` distinct
        clients drawn from ``default_rng([seed, round])``, ascending ids."""
        k = min(cohort_size, self.n_clients)
        rng = np.random.default_rng([self.seed, round_idx])
        return np.sort(rng.choice(self.n_clients, size=k, replace=False))

    # ----------------------------------------------------------- cohort step

    def run_cohort(
        self,
        round_idx: int,
        cohort_ids: Sequence[int],
        start_params: PyTree,
    ) -> CohortResult:
        """Execute one sampled cohort.

        ``start_params`` is either one shared pytree (sync rounds: every
        member trains from the current broadcast estimate) or a pytree with
        a leading member axis aligned with ``cohort_ids`` (async rounds:
        stale members start from older estimates).

        Members are grouped by profile; each group runs as jitted
        vmap/scan steps over tiles of at most ``cohort_tile`` members
        (the whole group at once when ``cohort_tile`` is None — the
        original one-giant-vmap layout).  Per-client optimizer and
        compressor state is gathered per tile and scattered back
        afterwards, so a spilled store only ever materializes one tile
        on device.
        """
        if not self.initialized:
            raise RuntimeError("ClientPool.init(params) must run first")
        ids = np.asarray(cohort_ids, np.int32)
        k_total = ids.size
        stacked_start = self._has_member_axis(start_params, k_total)
        resolved = self._resolved

        ctrees: List[PyTree] = [None] * k_total
        losses = np.zeros((k_total,), np.float64)
        bits = np.zeros((k_total,), np.float64)

        for prof_i, prof in enumerate(self.profiles):
            member_pos = np.nonzero(ids % len(self.profiles) == prof_i)[0]
            if member_pos.size == 0:
                continue
            rates = resolved.rates(prof.sparsity, round_idx)
            tile = (
                member_pos.size if self.cohort_tile is None
                else min(self.cohort_tile, member_pos.size)
            )
            for t0 in range(0, member_pos.size, tile):
                pos_t = member_pos[t0:t0 + tile]
                pad = tile - pos_t.size
                # pad short (final) tiles by repeating the last member so
                # every tile traces ONE shape; the duplicate rows compute
                # identical values, their scatter rewrites the same row,
                # and their outputs are discarded below
                pos_pad = (
                    np.concatenate([pos_t, np.repeat(pos_t[-1:], pad)])
                    if pad else pos_t
                )
                group_ids = ids[pos_pad]
                gidx = jnp.asarray(group_ids)
                if stacked_start:
                    group_start = jax.tree.map(
                        lambda x: x[jnp.asarray(pos_pad)], start_params
                    )
                else:
                    group_start = start_params  # broadcast inside the vmap
                opt_g, comp_g = self._gather(gidx)
                batch = self._group_batch(round_idx, group_ids, prof.delay)
                ctree_g, opt_g, comp_g, loss_g, bits_g = self._group_step(
                    group_start, opt_g, comp_g, batch,
                    jnp.asarray(round_idx * prof.delay, jnp.int32),
                    n_delay=prof.delay, rates=rates,
                    shared_start=not stacked_start,
                )
                self._scatter(gidx, opt_g, comp_g)
                # one device→host transfer for the whole tile, then cheap
                # numpy slicing per member (pack works on numpy anyway)
                ctree_np, loss_np, bits_np = jax.device_get(
                    (ctree_g, loss_g, bits_g)
                )
                for j, pos in enumerate(pos_t):
                    ctrees[int(pos)] = jax.tree.map(lambda x: x[j], ctree_np)
                    losses[int(pos)] = loss_np[j]
                    bits[int(pos)] = bits_np[j]

        profs = [self.profile_of(int(c)) for c in ids]
        return CohortResult(
            client_ids=tuple(int(c) for c in ids),
            ctrees=ctrees,
            losses=losses,
            bits_analytic=bits,
            rates=tuple(p.sparsity for p in profs),
            weights=tuple(p.weight * p.delay for p in profs),
        )

    @partial(jax.jit, static_argnames=("self", "n_delay", "rates", "shared_start"))
    def _group_step(
        self,
        start_params: PyTree,  # (K, ...) per-member starts, or shared (sync)
        opt_states: PyTree,  # (K, ...)
        comp_states: CompressorState,  # (K, ...)
        batch: PyTree,  # (K, n_delay, B, ...)
        iteration: jax.Array,
        *,
        n_delay: int,
        rates: Tuple[float, ...],
        shared_start: bool = False,
    ) -> tuple:
        """One profile group's round: vmapped local training (scan over the
        delay window) + per-member compression with error feedback, the same
        Alg. 1 l.10-14 structure as ``DSGDTrainer.round_step``."""
        resolved = self._resolved

        def local(params0, opt_state, comp_state, client_batch):
            def one(carry, micro):
                p, os, it = carry
                loss, g = jax.value_and_grad(self.model.loss_fn)(p, micro)
                p2, os2 = self.optimizer.apply(os, g, p, self.lr(it), it)
                return (p2, os2, it + 1), loss

            (p_new, os_new, _), step_losses = jax.lax.scan(
                one, (params0, opt_state, iteration), client_batch
            )
            delta = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                p_new, params0,
            )
            ctree, dense, comp_state = resolved.compress(delta, comp_state, rates)
            # momentum masking at transmitted coordinates (supplement A)
            transmitted = jax.tree.map(lambda d: (d != 0).astype(jnp.float32), dense)
            os_new = self.optimizer.mask(os_new, transmitted)
            # mean over the whole delay window, not the last local step
            return ctree, os_new, comp_state, jnp.mean(step_losses), resolved.total_bits(ctree)

        in_axes = (None if shared_start else 0, 0, 0, 0)
        return jax.vmap(local, in_axes=in_axes)(
            start_params, opt_states, comp_states, batch
        )

    # ------------------------------------------------------------- plumbing

    @partial(jax.jit, static_argnames=("self",))
    def _gather_states(self, opt_full, comp_full, gidx):
        """Pull one cohort group's rows out of the pooled state (one fused
        dispatch — per-leaf eager gathers dominate round time otherwise)."""
        opt_g = jax.tree.map(lambda x: x[gidx], opt_full)
        comp_g = CompressorState(
            residual=jax.tree.map(lambda x: x[gidx], comp_full.residual),
            rng=comp_full.rng[gidx],
            step=comp_full.step[gidx],
        )
        return opt_g, comp_g

    @partial(jax.jit, static_argnames=("self",))
    def _scatter_states(self, opt_full, comp_full, gidx, opt_upd, comp_upd):
        """Write a group's updated rows back (one fused dispatch)."""
        opt_full = jax.tree.map(
            lambda full, upd: full.at[gidx].set(upd), opt_full, opt_upd
        )
        comp_full = CompressorState(
            residual=jax.tree.map(
                lambda full, upd: full.at[gidx].set(upd),
                comp_full.residual, comp_upd.residual,
            ),
            rng=comp_full.rng.at[gidx].set(comp_upd.rng),
            step=comp_full.step.at[gidx].set(comp_upd.step),
        )
        return opt_full, comp_full

    def _gather(self, gidx) -> Tuple[PyTree, CompressorState]:
        """Store-dispatching tile gather (device fancy-index vs spill read)."""
        if self._spill is not None:
            return self._spill.gather(np.asarray(gidx))
        return self._gather_states(self._opt_states, self._comp_state, gidx)

    def _scatter(self, gidx, opt_g: PyTree, comp_g: CompressorState) -> None:
        if self._spill is not None:
            self._spill.scatter(np.asarray(gidx), opt_g, comp_g)
            return
        self._opt_states, self._comp_state = self._scatter_states(
            self._opt_states, self._comp_state, gidx, opt_g, comp_g
        )

    # --------------------------------------------------- rollback/checkpoint

    def snapshot_clients(self, ids: Sequence[int]) -> Dict[str, Any]:
        """Host copies of the named clients' rows, BEFORE a round touches
        them — the elasticity rollback unit: a client whose participation
        fails (straggler abort, corrupt upload) is restored from this, so
        a failed round leaves its residual/momentum/rng bit-identical to
        never having run (DESIGN.md §14)."""
        ids = np.asarray(ids, np.int32)
        if ids.size == 0:
            return {"ids": ids, "opt": None, "comp": None}
        opt_g, comp_g = self._gather(jnp.asarray(ids))
        opt_g, comp_g = jax.device_get((opt_g, comp_g))
        return {"ids": ids.copy(), "opt": opt_g, "comp": comp_g}

    def restore_clients(self, snap: Dict[str, Any],
                        only: Optional[Sequence[int]] = None) -> None:
        """Write snapshotted rows back; ``only`` restricts the restore to a
        subset of the snapshot's clients (the ones that actually failed)."""
        ids = np.asarray(snap["ids"], np.int32)
        if ids.size == 0:
            return
        keep = np.arange(ids.size)
        if only is not None:
            only_set = {int(c) for c in only}
            keep = np.asarray(
                [i for i, c in enumerate(ids) if int(c) in only_set], np.int64
            )
            if keep.size == 0:
                return
        sel = jnp.asarray(keep)
        opt_g = jax.tree.map(lambda x: jnp.asarray(x)[sel], snap["opt"])
        comp = snap["comp"]
        comp_g = CompressorState(
            residual=jax.tree.map(lambda x: jnp.asarray(x)[sel], comp.residual),
            rng=jnp.asarray(comp.rng)[sel],
            step=jnp.asarray(comp.step)[sel],
        )
        self._scatter(jnp.asarray(ids[keep]), opt_g, comp_g)

    def export_state(self) -> Dict[str, Any]:
        """The full pooled state as host numpy (fed checkpoint payload)."""
        if not self.initialized:
            raise RuntimeError("ClientPool.init(params) must run first")
        if self._spill is not None:
            return self._spill.export()
        comp = self._comp_state
        return {
            "opt": jax.tree.map(np.asarray, jax.device_get(self._opt_states)),
            "residual": jax.tree.map(
                np.asarray, jax.device_get(comp.residual)
            ),
            "rng": np.asarray(jax.device_get(comp.rng)),
            "step": np.asarray(jax.device_get(comp.step)),
        }

    def import_state(self, state: Dict[str, Any]) -> None:
        """Restore a full pooled state exported by :meth:`export_state`."""
        if not self.initialized:
            raise RuntimeError("ClientPool.init(params) must run first")
        if self._spill is not None:
            self._spill.import_(state)
            return
        self._opt_states = jax.tree.map(jnp.asarray, state["opt"])
        self._comp_state = CompressorState(
            residual=jax.tree.map(jnp.asarray, state["residual"]),
            rng=jnp.asarray(state["rng"]),
            step=jnp.asarray(state["step"]),
        )

    def _group_batch(self, round_idx: int, ids: np.ndarray, delay: int) -> PyTree:
        """(K, delay, B, ...) microbatches for one profile group — the same
        (client, local-step) layout as :func:`repro.data.client_batches`,
        generated in ONE dispatch when the task supports ``sample_many``."""
        if self.task.sample_many is not None:
            clients = np.repeat(ids, delay)
            micro = np.tile(round_idx * delay + np.arange(delay), ids.size)
            flat = self.task.sample_many(micro, clients)  # (K·D, B, ...)
            return jax.tree.map(
                lambda x: x.reshape((ids.size, delay) + x.shape[1:]), flat
            )
        steps = []
        for d in range(delay):
            per = [self.task.sample(round_idx * delay + d, int(c)) for c in ids]
            steps.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
        return jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *steps)

    def _has_member_axis(self, start_params: PyTree, k: int) -> bool:
        """True when ``start_params`` already carries a leading cohort axis."""
        if self._ref_leaf_shape is None:
            shapes = jax.eval_shape(self.model.init, jax.random.PRNGKey(0))
            self._ref_leaf_shape = tuple(jax.tree.leaves(shapes)[0].shape)
        got = tuple(jax.tree.leaves(start_params)[0].shape)
        return got == (k,) + self._ref_leaf_shape
