"""Parameter server: decode real SBW1 uploads, aggregate, re-compress the
broadcast (DESIGN.md §9).

The server side of the paper's §I deployment.  It consumes *bytes* — every
client upload is a packed :mod:`repro.core.wire` buffer, decoded through
the shared (model config, policy, rate) contract — aggregates the decoded
updates with a pluggable strategy, applies them to the master weights W,
and then sends the downstream direction through the SAME codec machinery:

    ΔW_down = W − Ŵ + (server residual)     Ŵ = the clients' replica
    ΔW*_down = compress(ΔW_down);  residual ← ΔW_down − ΔW*_down
    Ŵ ← Ŵ + ΔW*_down;   broadcast pack(ΔW*_down)

so downstream bytes are metered (measured AND analytic Eq. 1/Eq. 5) exactly
like upstream ones, and clients can reconstruct Ŵ from the wire alone.
The residual makes downstream compression lossless *in the limit*: what a
sparse broadcast drops this round is re-queued for the next (Eq. 2 applied
server-side).

Aggregation strategies (``AGGREGATORS``):

  mean        ΔW = (1/K) Σ_i ΔW*_i                        (Alg. 1 l.17)
  weighted    ΔW = Σ_i (n_i / Σ_j n_j) ΔW*_i              (FedAvg-style)
  staleness   ΔW = Σ_i w_i ΔW*_i,  w_i ∝ n_i (1+s_i)^−β   (async, stale
              gradients discounted polynomially — ``staleness_weights``)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import resolve_cached
from repro.obs import NULL_TELEMETRY
from repro.core.policy import (
    CompressionPolicy,
    CompressorState,
    ResolvedPolicy,
)
from repro.core.stages import LeafCompressed
from repro.core.wire import Wire, wire_for

PyTree = Any


class ClientUpdate(NamedTuple):
    """One client's round contribution as it arrives at the server."""

    client_id: int
    blob: bytes  # packed SBW1 buffer — the only payload that crosses
    rate: float  # upstream sparsity rate (part of the shared contract)
    weight: float = 1.0  # sample count for weighted aggregation
    staleness: int = 0  # rounds since the weights this update was computed on


class Broadcast(NamedTuple):
    """One round's downstream message plus its byte accounting."""

    blob: bytes
    dense: PyTree  # decoded ΔW*_down (identical to what unpack(blob) yields)
    bits_analytic: float
    bits_measured: float


def staleness_weights(
    staleness: Sequence[int], beta: float, base: Optional[Sequence[float]] = None
) -> np.ndarray:
    """Closed-form async aggregation weights: w_i ∝ base_i · (1+s_i)^−β,
    normalized to sum to 1."""
    s = np.asarray(staleness, np.float64)
    w = (1.0 + s) ** (-float(beta))
    if base is not None:
        w = w * np.asarray(base, np.float64)
    return w / w.sum()


def _mean_weights(ups: Sequence[ClientUpdate], beta: float) -> np.ndarray:
    return np.full((len(ups),), 1.0 / len(ups))


def _sample_weights(ups: Sequence[ClientUpdate], beta: float) -> np.ndarray:
    w = np.asarray([u.weight for u in ups], np.float64)
    return w / w.sum()


def _staleness_weights(ups: Sequence[ClientUpdate], beta: float) -> np.ndarray:
    return staleness_weights(
        [u.staleness for u in ups], beta, [u.weight for u in ups]
    )


AGGREGATORS = {
    "mean": _mean_weights,
    "weighted": _sample_weights,
    "staleness": _staleness_weights,
}


@dataclasses.dataclass(eq=False)
class ParameterServer:
    """Master weights + bidirectional codec endpoints.

    ``up_policy`` must be the same :class:`CompressionPolicy` the clients
    compress with (the shared wire contract); ``down_policy`` defaults to
    it, and ``down_sparsity`` trades broadcast bytes against replica lag
    (1.0 → dense broadcast, the classic FL assumption).
    """

    params: PyTree
    up_policy: CompressionPolicy
    down_policy: Optional[CompressionPolicy] = None
    down_sparsity: float = 1.0
    aggregator: str = "mean"
    staleness_beta: float = 0.5
    delta_horizon: Optional[int] = None  # rounds kept in the DeltaLog

    def __post_init__(self) -> None:
        self.telemetry = NULL_TELEMETRY  # the run layer swaps in an enabled one
        if self.aggregator not in AGGREGATORS:
            raise KeyError(
                f"unknown aggregator {self.aggregator!r}; have {sorted(AGGREGATORS)}"
            )
        if self.down_policy is None:
            # dense broadcast (the classic FL assumption) cannot ride a
            # sparse-position codec: at p=1 there are no gaps to Golomb-code
            if self.down_sparsity >= 1.0:
                self.down_policy = CompressionPolicy.single(
                    "dense32", name="dense-down"
                )
            else:
                self.down_policy = self.up_policy
        # resolved ONCE per (policy, topology) — server rebuilds on profile
        # changes share the bound engine (and its flat spaces / jit caches)
        # with the client pool instead of re-resolving every time
        self._up_resolved: ResolvedPolicy = resolve_cached(
            self.up_policy, self.params
        )
        self._down_resolved: ResolvedPolicy = resolve_cached(
            self.down_policy, self.params
        )
        f32 = jax.tree.map(lambda x: x.astype(jnp.float32), self.params)
        self._down_state: CompressorState = self._down_resolved.init_state(f32)
        # the clients' replica Ŵ — advanced ONLY by broadcast wire content
        self.estimate: PyTree = f32
        self._wires: Dict[Tuple[Tuple[float, ...], bool], Wire] = {}
        # optional round-indexed broadcast log (serve/deltalog.py): every
        # broadcast is appended so receivers lagging k rounds can pull a
        # stacked catch-up instead of k re-broadcasts or a full resync
        self.delta_log = None
        if self.delta_horizon is not None:
            from repro.serve.deltalog import DeltaLog

            self.delta_log = DeltaLog(f32, horizon=int(self.delta_horizon))

    # ------------------------------------------------------------- wiring

    def _wire(self, resolved: ResolvedPolicy, rate: float, round_idx: int) -> Wire:
        rates = resolved.rates(rate, round_idx)
        key = (rates, resolved is self._down_resolved)
        if key not in self._wires:
            self._wires[key] = wire_for(resolved, self.params, rate, round_idx)
        return self._wires[key]

    def up_wire(self, rate: float, round_idx: int = 0) -> Wire:
        """The upstream decode contract for one client rate this round."""
        return self._wire(self._up_resolved, rate, round_idx)

    def down_wire(self, round_idx: int = 0) -> Wire:
        return self._wire(self._down_resolved, self.down_sparsity, round_idx)

    # ------------------------------------------------------------ receiving

    def receive(self, uploads: Sequence[ClientUpdate], round_idx: int) -> dict:
        """Decode every upload from bytes, aggregate the survivors, apply.

        Corrupt/truncated buffers (``Wire.unpack`` raises ``ValueError``)
        are REJECTED per upload, not fatal: aggregation weights are
        computed over the decoded survivors only, so a round with rejects
        is bitwise identical to receiving just the survivors — partial
        aggregation IS survivors-only aggregation by construction (the
        elasticity contract ``tests/test_faults.py`` pins).  A round with
        zero survivors applies a zero update.  Decode failures touch no
        server state: params/estimate/residual advance only by accepted
        content.

        Returns the round's upstream accounting:
        ``{"up_bits_measured", "weights", "update_norm", "accepted",
        "rejected"}`` — bit accounting covers ACCEPTED uploads only (the
        channel meters rejected bytes as wasted).
        """
        measured = 0.0
        decoded: list = []
        rejected: list = []
        tel = self.telemetry
        with tel.span("decode", round=round_idx, uploads=len(uploads)):
            for u in uploads:
                wire = self.up_wire(u.rate, round_idx)
                try:
                    comps = wire.unpack_compressed(u.blob)
                except ValueError:
                    rejected.append(int(u.client_id))
                    continue
                measured += sum(
                    float(l.nbits)
                    for l in jax.tree.leaves(
                        comps, is_leaf=lambda x: isinstance(x, LeafCompressed)
                    )
                )
                decoded.append((u, wire.dense_of(comps)))
            survivors = [u for u, _ in decoded]
            weights = (
                AGGREGATORS[self.aggregator](survivors, self.staleness_beta)
                if survivors else np.zeros((0,), np.float64)
            )
            agg: Optional[PyTree] = None
            for (u, update), w in zip(decoded, weights):
                scaled = jax.tree.map(lambda x: float(w) * np.asarray(x, np.float64), update)
                agg = scaled if agg is None else jax.tree.map(np.add, agg, scaled)
        with tel.span("apply", round=round_idx):
            if agg is not None:
                self.params = jax.tree.map(
                    lambda p, u: (p.astype(jnp.float32) + jnp.asarray(u, jnp.float32)).astype(p.dtype),
                    self.params, agg,
                )
                tel.fence(self.params)
        norm = 0.0 if agg is None else float(
            np.sqrt(sum(float(np.sum(np.square(x))) for x in jax.tree.leaves(agg)))
        )
        return {
            "up_bits_measured": measured,
            "weights": weights,
            "update_norm": norm,
            "accepted": [int(u.client_id) for u in survivors],
            "rejected": rejected,
        }

    # ---------------------------------------------------------- broadcasting

    def broadcast(self, round_idx: int) -> Broadcast:
        """Compress W − Ŵ through the downstream policy and emit bytes.

        The server-side residual (inside ``_down_state``) carries whatever a
        sparse broadcast dropped into the next round; the replica Ŵ advances
        by exactly the decoded wire content, so server and clients stay
        byte-consistent.
        """
        gap = jax.tree.map(
            lambda w, e: w.astype(jnp.float32) - e, self.params, self.estimate
        )
        # the gap W − Ŵ already contains every previously-unsent coordinate
        # (Ŵ only ever advanced by transmitted content), and compress() adds
        # its stored residual back in — so feed it the residual-free part,
        # keeping acc == gap and the invariant  W − Ŵ == residual  exact
        if self._down_resolved.any_residual:
            residual = self._down_state.residual
            space = (
                self._down_resolved.flat_space(self.params)
                if self._down_resolved.policy.fast else None
            )
            if space is not None:
                # fast-path state keeps the residual in the flat §10
                # layout; view it as a pytree for the gap subtraction
                residual = space.unflatten(residual, cast=False)
            delta = jax.tree.map(
                lambda g, r: g - r.astype(jnp.float32), gap, residual
            )
        else:
            delta = gap
        rates = self._down_resolved.rates(self.down_sparsity, round_idx)
        with self.telemetry.span("select_quantize", round=round_idx, side="down"):
            ctree, dense, self._down_state = self._down_resolved.compress(
                delta, self._down_state, rates
            )
            self.telemetry.fence(dense)
        with self.telemetry.span("encode", round=round_idx, side="down"):
            wire = self.down_wire(round_idx)
            blob, bits = wire.pack_with_bits(ctree)
        self.estimate = jax.tree.map(jnp.add, self.estimate, dense)
        analytic = float(self._down_resolved.total_bits(ctree))
        if self.delta_log is not None:
            # the log decodes the blob through the same wire a receiver
            # uses, so its replica trajectory is the receivers', bit-exact
            self.delta_log.append(round_idx, blob, wire, bits_analytic=analytic)
        return Broadcast(
            blob=blob,
            dense=dense,
            bits_analytic=analytic,
            bits_measured=float(bits),
        )

    @property
    def down_residual(self) -> PyTree:
        """Server-side error-feedback accumulator (Eq. 2, downstream),
        always viewed as a pytree (fast-path state stores it flat)."""
        residual = self._down_state.residual
        space = (
            self._down_resolved.flat_space(self.params)
            if self._down_resolved.policy.fast else None
        )
        if space is not None:
            return space.unflatten(residual, cast=False)
        return residual
