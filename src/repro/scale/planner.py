"""Zoo-wide execution planner (ISSUE 10 tentpole, DESIGN.md §15).

Every config in ``repro/configs/`` gets ONE schema-versioned trajectory
record — bits-per-step and step-time — produced in whichever of three
modes its size permits:

``real``
    N measured rounds through :func:`repro.run.build_run` on the local
    backend (the preset's executable variant — assigned archs run their
    ``reduced()`` stand-in, paper archs run full size), wire metering on,
    and the analytic cost model reconciled BIT-EXACTLY against the
    measured :class:`~repro.core.ledger.BandwidthLedger` totals.
``dryrun``
    the FULL config abstract-evaluated (``jax.eval_shape`` — zero
    allocation), PartitionSpecs derived on a device-free
    :class:`~repro.scale.costs.StubMesh`, exchange volume priced per
    (leaf, shard, scan-row), and step time estimated from the
    :mod:`repro.launch.roofline` peak terms.
``analytic``
    cost model only, from ``cfg.param_count()`` — the 400B tier where
    even abstract leaf enumeration is not worth the trace time.

Classification is by host-memory budget: a config goes ``real`` when its
executable variant's working set (params + per-client residual +
optimizer slots + one gradient copy) fits ``budget_mb``, ``dryrun``
while its full parameter count stays under ``DRYRUN_PARAM_CAP``, and
``analytic`` beyond that.  ``--mode`` forces any mode for any config.

NOTE: this module must never import :mod:`repro.launch.dryrun` — that
module sets ``XLA_FLAGS`` (512 fake hosts) at import time, which would
poison a planner process that later wants a real run.
"""
from __future__ import annotations

import functools
import time
from typing import Optional, Sequence

import jax
import numpy as np

from repro.configs.base import (
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    PAPER_ARCHS,
    ModelConfig,
    get_config,
    reduced,
)
from repro.core.policy import CompressionPolicy, LeafPlan, moe_rules
from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    model_flops_for,
)
from repro.scale import costs
from repro.scale.costs import OPT_SLOTS, StubMesh

SCHEMA = 1
MODES = ("real", "dryrun", "analytic")
ALL_ARCHS = PAPER_ARCHS + ASSIGNED_ARCHS

# real-mode default: enough for the paper's own models (LeNet5 ~82 MB,
# CharLSTM ~23 MB, WordLSTM ~5 MB working set at 4 clients) while the
# reduced assigned stand-ins (~98-226 MB vocab-heavy trees) stay in
# dryrun — CI's real tier must stay a seconds-scale smoke.
DEFAULT_BUDGET_MB = 96
# beyond ~60B analytic params even abstract shape enumeration is noise:
# llama4_maverick_400b_a17b is the designated analytic-tier proof-point
DRYRUN_PARAM_CAP = 60e9

# families build_preset can actually train as a local run (the cnn branch
# only has a task for lenet5's 28×28 grayscale preset)
_REAL_PRESETS = {"lenet5", "charlstm"}
_REAL_FAMILIES = {"decoder", "encdec", "lstm"}


def policy_for(cfg: ModelConfig, compressor: str = "sbc",
               moe_aware: bool = True) -> CompressionPolicy:
    """The policy a config is priced (and run) under: the compressor's own
    policy, plus the §15 MoE rules when the config routes experts."""
    from repro.core.api import make_compressor
    from repro.run.build import as_policy

    pol = as_policy(make_compressor(compressor))
    if moe_aware and cfg.moe_experts:
        return CompressionPolicy(
            default=pol.default,
            rules=moe_rules(cfg.moe_experts, cfg.moe_top_k) + pol.rules,
            name=f"{pol.name}+moe",
            fast=pol.fast,
        )
    return pol


def executable_config(name: str) -> ModelConfig:
    """What a ``real`` run of ``name`` actually trains (preset semantics:
    paper models full-size, assigned archs reduced)."""
    cfg = get_config(name)
    return cfg if name in _REAL_PRESETS else reduced(cfg)


@functools.lru_cache(maxsize=64)
def executable_param_count(name: str) -> int:
    """EXACT parameter count of the executable variant, from abstract
    leaf shapes (``cfg.param_count()`` is a transformer-family estimate —
    meaningless for the cnn/lstm paper models the real tier cares about)."""
    from repro.models.model import build_model

    cfg = executable_config(name)
    params = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
    return int(sum(int(np.prod(x.shape)) if x.shape else 1
                   for x in jax.tree_util.tree_leaves(params)))


def host_working_set_bytes(name: str, clients: int = 4) -> int:
    """Steady-state f32 bytes a local-backend run of ``name`` holds:
    server params + per-client (gradient, residual, optimizer slots)."""
    cfg = executable_config(name)
    slots = OPT_SLOTS.get(cfg.local_opt, 1)
    return 4 * executable_param_count(name) * (1 + clients * (2 + slots))


def classify(name: str, *, budget_mb: int = DEFAULT_BUDGET_MB,
             mode: Optional[str] = None) -> tuple[str, str]:
    """(mode, reason) for one config."""
    if mode:
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; have {MODES}")
        return mode, "forced by --mode"
    cfg = get_config(name)
    runnable = name in _REAL_PRESETS or cfg.family in _REAL_FAMILIES
    if runnable:
        ws = host_working_set_bytes(name)
        if ws <= budget_mb * (1 << 20):
            return "real", (
                f"executable working set {ws / 2**20:.1f} MB ≤ "
                f"budget {budget_mb} MB"
            )
    if cfg.param_count() <= DRYRUN_PARAM_CAP:
        why = "" if runnable else f"no local preset for family {cfg.family!r}; "
        return "dryrun", (
            why + f"{cfg.param_count() / 1e9:.1f}B params ≤ "
            f"{DRYRUN_PARAM_CAP / 1e9:.0f}B dryrun cap"
        )
    return "analytic", (
        f"{cfg.param_count() / 1e9:.0f}B params above the dryrun cap"
    )


# ------------------------------------------------------------------ modes


def _roofline(cfg: ModelConfig, param_bytes: int, exchange_bits: float,
              n_dev: int) -> dict:
    """Deterministic peak-rate step-time terms (no compile, no HLO):
    compute at bf16 peak, weight traffic at HBM peak, exchange at ICI
    peak — the same constants :func:`repro.launch.roofline.analyze`
    grounds its measured numbers in."""
    shape = INPUT_SHAPES["train_4k"]
    flops = model_flops_for(cfg, shape, "train")
    compute_s = flops / (n_dev * PEAK_FLOPS)
    memory_s = 2.0 * param_bytes / (n_dev * HBM_BW)
    exchange_s = (exchange_bits / 8.0) / (n_dev * ICI_BW)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "exchange_s": exchange_s,
        "step_s": max(compute_s, memory_s) + exchange_s,
    }


def _base_record(name: str, cfg: ModelConfig, mode: str, reason: str,
                 compressor: str, sparsity: float, clients: int) -> dict:
    return {
        "schema": SCHEMA,
        "arch": name,
        "family": cfg.family,
        "mode": mode,
        "reason": reason,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "compressor": compressor,
        "sparsity": sparsity,
        "clients": clients,
        "mesh": list(StubMesh().devices.shape),
    }


def plan_analytic(name: str, *, compressor: str = "sbc",
                  sparsity: float = 0.001, clients: int = 4,
                  reason: str = "") -> dict:
    """Mode 3: price from the analytic parameter count alone."""
    cfg = get_config(name)
    pol = policy_for(cfg, compressor)
    n = cfg.param_count()
    plan = LeafPlan(path="params", codec=pol.default, sparsity=None,
                    schedule=None)
    up = costs.leaf_bits(plan, n, sparsity)
    rec = _base_record(name, cfg, "analytic", reason, compressor, sparsity,
                       clients)
    rec.update(
        n_leaves=None,
        up_bits_per_step=up,
        up_bits_f32_ledger=float(np.float32(up)),
        dense_bits=32.0 * n,
        compression_rate=32.0 * n / max(up, 1.0),
        framing_bytes=None,
        param_bytes=4 * n,
        residual_bytes=4 * n,
        optimizer_bytes=4 * n * OPT_SLOTS.get(cfg.local_opt, 1),
        exchange_bits_per_step=None,
        roofline_est=_roofline(cfg, 4 * n, up, int(np.prod(
            StubMesh().devices.shape))),
        reconciles=bool(np.isfinite(up) and up > 0.0),
    )
    return rec


def plan_dryrun(name: str, *, compressor: str = "sbc",
                sparsity: float = 0.001, clients: int = 4,
                reason: str = "") -> dict:
    """Mode 2: abstract-eval the FULL config, derive PartitionSpecs on the
    stub mesh, price per leaf.  Zero parameter allocation."""
    from repro.models.model import build_model

    cfg = get_config(name)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pol = policy_for(cfg, compressor)
    resolved = pol.resolve(params)
    leaves = jax.tree_util.tree_leaves(params)
    mesh = StubMesh()
    specs = jax.tree_util.tree_leaves(
        model.param_specs(params, mesh), is_leaf=lambda x: hasattr(x, "index")
    )
    rates = resolved.rates(sparsity)
    report = costs.price(
        resolved, leaves, rates, opt=cfg.local_opt,
        paths=[p.path for p in resolved.plans], specs=specs, mesh=mesh,
    )
    rec = _base_record(name, cfg, "dryrun", reason, compressor, sparsity,
                       clients)
    rec.update(
        n_leaves=report.n_leaves,
        up_bits_per_step=report.up_bits_per_client,
        up_bits_f32_ledger=report.up_bits_f32_ledger,
        dense_bits=report.dense_bits,
        compression_rate=report.compression_rate,
        framing_bytes=report.framing_bytes,
        param_bytes=report.param_bytes,
        residual_bytes=report.residual_bytes,
        optimizer_bytes=report.optimizer_bytes,
        exchange_bits_per_step=report.exchange_bits,
        roofline_est=_roofline(
            cfg, report.param_bytes, report.exchange_bits,
            int(np.prod(mesh.devices.shape)),
        ),
        # internal sanity: the f32 ledger emulation must track the f64
        # walk to float32 resolution over the whole tree
        reconciles=bool(
            abs(report.up_bits_f32_ledger - report.up_bits_per_client)
            <= 1e-4 * max(report.up_bits_per_client, 1.0)
        ),
    )
    return rec


def plan_real(name: str, *, compressor: str = "sbc", sparsity: float = 0.001,
              clients: int = 4, rounds: int = 8, reason: str = "",
              telemetry: bool = False, seed: int = 0):
    """Mode 1: run N measured rounds and reconcile the cost model
    BIT-EXACTLY against the ledger.  Returns (record, run) — the run so
    callers can export its telemetry."""
    from repro.run import RunSpec, build_run

    spec = RunSpec(
        preset=name, backend="local", rounds=rounds, batch=16,
        seq_len=32, clients=clients, delay=1, sparsity=sparsity,
        compressor=compressor, fast=False, measure_wire=True,
        telemetry=telemetry, seed=seed,
    )
    run = build_run(spec)
    state = run.init()
    step_ms = []
    for r in range(rounds):
        t0 = time.perf_counter()
        state, m = run.step(state, r)
        jax.block_until_ready(m["loss"])
        step_ms.append(1e3 * (time.perf_counter() - t0))
    if telemetry:
        run.telemetry.metrics.ingest_ledger(run.ledger)

    # --- the reconcile: replay the device's f32 accumulation on the host
    resolved = run.trainer.resolved(state.params)
    sizes = [int(np.prod(np.shape(x)) or 1)
             for x in jax.tree_util.tree_leaves(state.params)]
    predicted = 0.0
    f64_per_client = 0.0
    for r in range(rounds):
        f64, f32 = costs.upstream_bits(resolved, sizes,
                                       resolved.rates(sparsity, r))
        predicted += float(f32) * clients  # what record_round stores
        f64_per_client = f64
    totals = run.ledger.totals()
    measured = totals["up_bits_analytic"]

    cfg = executable_config(name)
    full = get_config(name)
    report = costs.price(resolved,
                         jax.tree_util.tree_leaves(state.params),
                         resolved.rates(sparsity, rounds - 1),
                         opt=full.local_opt)
    rec = _base_record(name, full, "real", reason, compressor, sparsity,
                       clients)
    rec.update(
        n_leaves=report.n_leaves,
        up_bits_per_step=f64_per_client,
        up_bits_f32_ledger=report.up_bits_f32_ledger,
        dense_bits=report.dense_bits,
        compression_rate=report.compression_rate,
        framing_bytes=report.framing_bytes,
        param_bytes=report.param_bytes,
        residual_bytes=report.residual_bytes,
        optimizer_bytes=report.optimizer_bytes,
        exchange_bits_per_step=None,
        roofline_est=None,
        reconciles=bool(predicted == measured),  # BIT-exact, not approx
        real={
            "executed_params": int(sum(sizes)),
            "executed_arch": cfg.name,
            "rounds": rounds,
            "up_bits_ledger": measured,
            "up_bits_predicted": predicted,
            "up_bytes_measured": totals.get("up_bytes", 0),
            "measured_ratio": (
                8.0 * totals.get("up_bytes", 0) / measured if measured else None
            ),
            "step_ms_mean": float(np.mean(step_ms[1:] or step_ms)),
            "step_ms_warm": step_ms[0],
        },
    )
    return rec, run


# ------------------------------------------------------------------ driver


def plan(name: str, *, mode: Optional[str] = None,
         budget_mb: int = DEFAULT_BUDGET_MB, compressor: str = "sbc",
         sparsity: float = 0.001, clients: int = 4, rounds: int = 8,
         telemetry: bool = False):
    """One config → (record, run-or-None)."""
    picked, reason = classify(name, budget_mb=budget_mb, mode=mode)
    kw = dict(compressor=compressor, sparsity=sparsity, clients=clients,
              reason=reason)
    if picked == "real":
        return plan_real(name, rounds=rounds, telemetry=telemetry, **kw)
    if picked == "dryrun":
        return plan_dryrun(name, **kw), None
    return plan_analytic(name, **kw), None


def plan_zoo(names: Optional[Sequence[str]] = None, *,
             budget_mb: int = DEFAULT_BUDGET_MB, mode: Optional[str] = None,
             compressor: str = "sbc", sparsity: float = 0.001,
             clients: int = 4, rounds: int = 8) -> list[dict]:
    """Trajectory records for the whole zoo (or ``names``), real-capable
    configs first so compile caches warm before the abstract tiers."""
    out = []
    for name in names or ALL_ARCHS:
        rec, _ = plan(name, mode=mode, budget_mb=budget_mb,
                      compressor=compressor, sparsity=sparsity,
                      clients=clients, rounds=rounds)
        out.append(rec)
    return out
