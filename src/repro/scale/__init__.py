"""repro.scale — billion-parameter proof-point planner (DESIGN.md §15).

Prices the paper's communication model at production scale without
production hardware: an analytic cost model cross-checked bit-exactly
against the measured ledger on small configs, extrapolated through
abstract-eval dryruns to the zoo's 20-400B tier.

  PYTHONPATH=src python -m repro.scale --all
  PYTHONPATH=src python -m repro.scale --config gemma3_1b --mode analytic
  PYTHONPATH=src python -m repro.scale --config mixtral_8x7b --policy-grid
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional

from repro.scale import costs, planner
from repro.scale.costs import CostReport, StubMesh, price
from repro.scale.planner import (
    ALL_ARCHS,
    DEFAULT_BUDGET_MB,
    SCHEMA,
    classify,
    plan,
    plan_analytic,
    plan_dryrun,
    plan_real,
    plan_zoo,
    policy_for,
)

__all__ = [
    "costs", "planner", "CostReport", "StubMesh", "price", "ALL_ARCHS",
    "DEFAULT_BUDGET_MB", "SCHEMA", "classify", "plan", "plan_analytic",
    "plan_dryrun", "plan_real", "plan_zoo", "policy_for", "build_parser",
    "main",
]

# the --policy-grid sweep: registered compressors the wire supports
GRID = ("sbc", "topk", "variance", "signsgd")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.scale",
        description="zoo-wide bits-per-step × step-time trajectory planner",
    )
    ap.add_argument("--all", action="store_true",
                    help="plan every config in the zoo")
    ap.add_argument("--config", action="append", default=[],
                    help="plan one config (repeatable)")
    ap.add_argument("--mode", choices=planner.MODES, default=None,
                    help="force real | dryrun | analytic (default: classify "
                         "by host-memory budget)")
    ap.add_argument("--policy-grid", action="store_true",
                    help="price each config under the compressor grid "
                         "instead of emitting trajectory records")
    ap.add_argument("--rounds", type=int, default=8,
                    help="measured rounds for real-mode runs")
    ap.add_argument("--budget-mb", type=int, default=DEFAULT_BUDGET_MB,
                    help="host-memory budget for the real tier")
    ap.add_argument("--sparsity", type=float, default=0.001,
                    help="global upload rate p")
    ap.add_argument("--compressor", default="sbc",
                    help="registered compressor to price/run")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--telemetry", action="store_true",
                    help="attach repro.obs to real runs and export "
                         "trace/metrics next to the records")
    ap.add_argument("--out-dir", default=None,
                    help="write scale_zoo.json (+ telemetry artifacts) "
                         "here; default: print only")
    return ap


def _fmt_bits(b: Optional[float]) -> str:
    if b is None:
        return "-"
    for unit, div in (("Gb", 1e9), ("Mb", 1e6), ("kb", 1e3)):
        if b >= div:
            return f"{b / div:.2f} {unit}"
    return f"{b:.0f} b"


def _step_time(rec: dict) -> str:
    if rec.get("real"):
        return f"{rec['real']['step_ms_mean']:.1f} ms*"
    rf = rec.get("roofline_est")
    return f"{1e3 * rf['step_s']:.2f} ms^" if rf else "-"


def _render(records: list[dict]) -> None:
    from repro.obs import render_table

    rows = []
    for r in records:
        rows.append([
            r["arch"], r["mode"], f"{r['params'] / 1e6:,.1f}M",
            _fmt_bits(r["up_bits_per_step"]),
            f"×{r['compression_rate']:,.0f}",
            _fmt_bits(r.get("exchange_bits_per_step")),
            _step_time(r),
            "✓" if r["reconciles"] else "✗",
        ])
    print(render_table(
        ["arch", "mode", "params", "up bits/step", "rate",
         "mesh exchange", "step time", "recon"],
        rows,
        title="repro.scale — bits-per-step × step-time (* measured, ^ roofline)",
    ))


def _render_grid(names: list[str], args) -> None:
    from repro.obs import render_table

    rows = []
    for name in names:
        mode, _ = classify(name, budget_mb=args.budget_mb, mode=args.mode)
        if mode == "real":
            mode = "dryrun"  # grid pricing is abstract; never trains ×|GRID|
        for comp in GRID:
            rec, _ = plan(name, mode=mode, budget_mb=args.budget_mb,
                          compressor=comp, sparsity=args.sparsity,
                          clients=args.clients)
            rows.append([
                name, comp, _fmt_bits(rec["up_bits_per_step"]),
                f"×{rec['compression_rate']:,.0f}",
                _fmt_bits(rec.get("exchange_bits_per_step")),
            ])
    print(render_table(
        ["arch", "policy", "up bits/step", "rate", "mesh exchange"],
        rows, title=f"repro.scale --policy-grid (p={args.sparsity})",
    ))


def main(argv=None) -> list[dict]:
    args = build_parser().parse_args(argv)
    names = list(args.config) or (ALL_ARCHS if args.all else None)
    if names is None:
        build_parser().error("pass --all or --config <arch>")
    bad = [n for n in names if n not in ALL_ARCHS]
    if bad:
        build_parser().error(f"unknown configs {bad}; have {ALL_ARCHS}")

    if args.policy_grid:
        _render_grid(names, args)
        return []

    if args.out_dir:
        os.makedirs(args.out_dir, exist_ok=True)
    records = []
    for name in names:
        rec, run = plan(
            name, mode=args.mode, budget_mb=args.budget_mb,
            compressor=args.compressor, sparsity=args.sparsity,
            clients=args.clients, rounds=args.rounds,
            telemetry=args.telemetry,
        )
        records.append(rec)
        if run is not None and args.telemetry and args.out_dir:
            from repro.obs import finish_run

            finish_run(
                run.telemetry,
                trace=os.path.join(args.out_dir, f"{name}.trace.json"),
                metrics_out=os.path.join(
                    args.out_dir, f"{name}.metrics.jsonl"),
                meta={"arch": name, "mode": rec["mode"],
                      "rounds": args.rounds},
                print_summary=False,
            )

    _render(records)
    if args.out_dir:
        path = os.path.join(args.out_dir, "scale_zoo.json")
        with open(path, "w") as f:
            json.dump(records, f, indent=1, sort_keys=True)
        print(f"wrote {len(records)} trajectory records → {path}")
    return records
