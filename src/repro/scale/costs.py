"""Analytic communication/memory cost model (ISSUE 10, DESIGN.md §15).

Prices one training step of any (config, policy, mesh) triple WITHOUT
running it, from leaf shapes + :class:`~repro.core.policy.ResolvedPolicy`
rates + mesh sharding:

* **upstream bits** — the exact Eq. 1 walk the channels meter: per leaf,
  ``encoder.position_bits(n, k, p) + quantizer.value_bits(k)`` with
  ``k = k_for(n, p)`` (Golomb leaves price ``k·E[bits/pos]`` from Eq. 5);
  dense leaves ``value_bits(n)``; skip leaves 0.  Two accumulations are
  reported: the float64 truth, and a float32 sequential accumulation in
  plan order — the *device* sums per-leaf ``nbits`` as f32 scalars
  (`LeafCompressed.nbits`), so the f32 variant is what
  ``BandwidthLedger.up_bits_analytic`` records, bit for bit;
* **SBW1 framing** — the wire container's 8-byte header + 4-byte
  per-leaf length prefix (:mod:`repro.core.wire`);
* **residual / optimizer memory** — error-feedback and momentum/Adam
  slot bytes per client;
* **sharded exchange volume** — the per-(leaf, shard, scan-row) table
  :class:`~repro.core.channel.ShardedGspmdChannel` prices on a GSPMD
  mesh, generalized to any codec: ``L·S·(position_bits(n_loc, k_loc, p)
  + value_bits(k_loc))``, with shard counts derived from the model's
  PartitionSpec rules on a device-free stub mesh.

Cross-checked bit-exactly against the measured ledger in
``tests/test_scale_costs.py`` (acceptance criterion 3).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import LeafPlan, ResolvedPolicy
from repro.core.stages import k_for

# SBW1 container framing (repro.core.wire): magic + u32 leaf count, then a
# u32 payload-length prefix per leaf.
SBW1_HEADER_BYTES = 8
SBW1_PER_LEAF_BYTES = 4

# optimizer slot count per parameter (f32 slots per weight)
OPT_SLOTS = {"sgd": 0, "momentum": 1, "adam": 2, "adamw": 2}


def leaf_bits(plan: LeafPlan, n: int, rate: float) -> float:
    """Eq. 1 upstream bits for one n-entry leaf at ``rate`` (float64).

    Mirrors :func:`repro.core.channel.analytic_bits` exactly — any drift
    between the two is a bug, held by the reconcile tests.
    """
    codec = plan.codec
    if codec.skip:
        return 0.0
    if codec.selector.dense:
        return float(codec.quantizer.value_bits(n))
    k = k_for(n, rate)
    return float(
        codec.encoder.position_bits(n, k, rate) + codec.quantizer.value_bits(k)
    )


def upstream_bits(
    resolved: ResolvedPolicy, sizes: Sequence[int], rates: Sequence[float]
) -> Tuple[float, float]:
    """(float64 per-client bits, float32-ledger per-client bits).

    The second value replays the device accumulation: each leaf's nbits
    is cast to f32 (``jnp.asarray(nbits, jnp.float32)`` in
    ``Codec.compress_leaf``) and summed sequentially in plan order
    (``ResolvedPolicy.total_bits``), so it equals the per-round
    ``bits_per_client`` the local channel hands the ledger.
    """
    f64 = 0.0
    f32 = np.float32(0.0)
    for plan, n, p in zip(resolved.plans, sizes, rates):
        nb = leaf_bits(plan, int(n), float(p))
        f64 += nb
        f32 = f32 + np.float32(nb)
    return f64, float(f32)


def framing_bytes(n_leaves: int) -> int:
    """SBW1 container overhead for one packed client upload."""
    return SBW1_HEADER_BYTES + SBW1_PER_LEAF_BYTES * n_leaves


def memory_bytes(
    resolved: ResolvedPolicy, sizes: Sequence[int], *, opt: str = "momentum"
) -> dict:
    """Per-client steady-state memory: params, error-feedback residual
    (f32, only for leaves whose codec uses it), optimizer slots."""
    n_params = int(sum(int(s) for s in sizes))
    residual = sum(
        4 * int(n)
        for plan, n in zip(resolved.plans, sizes)
        if plan.codec.use_residual
    ) if resolved.any_residual else 0
    slots = OPT_SLOTS.get(opt, 1)
    return {
        "param_bytes": 4 * n_params,
        "residual_bytes": int(residual),
        "optimizer_bytes": 4 * n_params * slots,
    }


# ---------------------------------------------------------------- sharded


class StubMesh:
    """Device-free stand-in for ``jax.sharding.Mesh``: the PartitionSpec
    rules in :mod:`repro.models.model` read only ``axis_names`` and
    ``devices.shape``, so spec derivation for a 256-chip production mesh
    needs no devices at all (the planner's dryrun/analytic trick)."""

    def __init__(self, shape=(16, 16), axis_names=("data", "model")):
        self.axis_names = tuple(axis_names)
        self.devices = np.zeros(tuple(shape), dtype=np.int8)

    @property
    def shape_map(self) -> dict:
        return dict(zip(self.axis_names, self.devices.shape))


def _n_shards(spec, axis_size: dict) -> int:
    """Total shard count a PartitionSpec induces (product of mesh axis
    sizes over every named axis in the spec)."""
    total = 1
    for entry in tuple(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for ax in axes:
            total *= int(axis_size.get(ax, 1))
    return total


def sharded_exchange_bits(
    resolved: ResolvedPolicy,
    leaves: Sequence,
    paths: Sequence[str],
    specs: Sequence,
    rates: Sequence[float],
    mesh: StubMesh,
) -> float:
    """Per-step exchange volume on a GSPMD mesh (float64 bits).

    The per-(leaf, shard, scan-row) pricing of
    ``ShardedGspmdChannel.bits``: each shard compresses its local slice
    independently (local k, one per-row scalar), scanned stacks price one
    row per superblock layer.  Dense leaves exchange their full 32-bit
    payload once; skip leaves cost nothing.
    """
    axis_size = mesh.shape_map
    total = 0.0
    for plan, leaf, path, spec, rate in zip(
        resolved.plans, leaves, paths, specs, rates
    ):
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        codec = plan.codec
        if codec.skip:
            continue
        if codec.selector.dense:
            total += 32.0 * size
            continue
        scanned = "stack/scan" in path or path.startswith("scan")
        shape = tuple(leaf.shape)
        L = shape[0] if scanned and len(shape) > 1 else 1
        S = _n_shards(spec, axis_size)
        n_loc = max(1, size // (L * S))
        k_loc = max(1, min(n_loc, int(round(rate * n_loc))))
        total += L * S * float(
            codec.encoder.position_bits(n_loc, k_loc, rate)
            + codec.quantizer.value_bits(k_loc)
        )
    return total


# ------------------------------------------------------------- full report


@dataclasses.dataclass(frozen=True)
class CostReport:
    """One priced (config, policy, mesh) triple."""

    n_params: int
    n_leaves: int
    up_bits_per_client: float  # float64 Eq. 1 truth
    up_bits_f32_ledger: float  # what BandwidthLedger.up_bits_analytic sees
    dense_bits: float  # 32-bit baseline upload
    framing_bytes: int  # SBW1 container overhead per upload
    param_bytes: int
    residual_bytes: int
    optimizer_bytes: int
    exchange_bits: Optional[float] = None  # sharded per-step volume

    @property
    def compression_rate(self) -> float:
        return self.dense_bits / max(self.up_bits_per_client, 1.0)

    def as_record(self) -> dict:
        d = dataclasses.asdict(self)
        d["compression_rate"] = self.compression_rate
        return d


def price(
    resolved: ResolvedPolicy,
    leaves: Sequence,
    rates: Sequence[float],
    *,
    opt: str = "momentum",
    paths: Optional[Sequence[str]] = None,
    specs: Optional[Sequence] = None,
    mesh: Optional[StubMesh] = None,
) -> CostReport:
    """Price one step.  ``leaves`` may be arrays or ShapeDtypeStructs —
    only shapes are read.  Pass paths+specs+mesh for the sharded exchange
    term."""
    sizes = [int(np.prod(x.shape)) if x.shape else 1 for x in leaves]
    f64, f32 = upstream_bits(resolved, sizes, rates)
    mem = memory_bytes(resolved, sizes, opt=opt)
    exchange = None
    if specs is not None and mesh is not None and paths is not None:
        exchange = sharded_exchange_bits(
            resolved, leaves, paths, specs, rates, mesh
        )
    return CostReport(
        n_params=int(sum(sizes)),
        n_leaves=len(sizes),
        up_bits_per_client=f64,
        up_bits_f32_ledger=f32,
        dense_bits=32.0 * float(sum(sizes)),
        framing_bytes=framing_bytes(len(sizes)),
        exchange_bits=exchange,
        **mem,
    )
