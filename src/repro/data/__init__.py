from repro.data.synthetic import (
    client_batches,
    make_classification_task,
    make_lm_task,
    make_non_iid_lm_task,
    split_among_clients,
)

__all__ = [
    "make_lm_task",
    "make_non_iid_lm_task",
    "make_classification_task",
    "split_among_clients",
    "client_batches",
]
