"""Deterministic synthetic data pipelines with per-client sharding.

The paper's experiments split a dataset into M equal shards, one per client
(homogeneous/IID split).  This container is offline, so every reproduction
task uses a *synthetic but genuinely learnable* stand-in with the same
interface, seeded deterministically:

  * LM task ("markov"): a fixed random first-order Markov chain over the
    vocabulary with temperature-controlled entropy.  A model that learns the
    transition table reaches the chain's entropy floor; an untrained model
    sits at ln(V).  This gives convergence curves with real headroom, which
    is what the Table II / Fig. 5-6 analogues need.
  * LM task ("affine"): x_{t+1} = (a·x_t + b) mod V — near-zero achievable
    loss, used by fast smoke/integration tests.
  * Classification ("blobs"): Gaussian class blobs in pixel space (LeNet /
    ResNet shapes) — fixed class means with additive noise.

Batches are generated on the fly from a counter-based PRNG (jax.random.fold_in)
so the pipeline is stateless, reproducible, and infinite.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

PyTree = dict


@dataclasses.dataclass(frozen=True)
class Task:
    """A data source: ``sample(step, client) -> dict`` plus metadata.

    ``sample_many(steps, clients)``, when present, generates the batches of
    many (step, client) pairs in ONE jitted dispatch with a leading pair
    axis — byte-identical streams to per-pair ``sample`` calls (same
    fold-in key construction), but without O(pairs) Python dispatch
    overhead.  The federated cohort runner and ``client_batches`` prefer it.
    """

    name: str
    sample: Callable[[int, int], PyTree]  # (step, client) -> batch dict
    vocab_size: int = 0
    n_classes: int = 0
    entropy_floor: float = 0.0  # achievable loss (nats/token) for LM tasks
    sample_many: Optional[Callable] = None  # (steps[N], clients[N]) -> dict


# ------------------------------------------------------------------ LM tasks


def make_lm_task(
    *,
    vocab: int,
    batch: int,
    seq_len: int,
    kind: str = "markov",
    temperature: float = 1.0,
    seed: int = 0,
    extra_fields: Optional[Callable[[jax.Array], PyTree]] = None,
) -> Task:
    """Next-token prediction: ``labels[t] = tokens[t+1]`` at every position."""
    base = jax.random.PRNGKey(seed)
    floor = 0.0

    if kind == "markov":
        logits = jax.random.normal(jax.random.fold_in(base, 17), (vocab, vocab))
        logits = logits / max(temperature, 1e-3)
        probs = jax.nn.softmax(logits, axis=-1)
        # entropy floor ≈ mean row entropy (stationary dist of a dense random
        # chain is near-uniform)
        row_ent = -jnp.sum(probs * jnp.log(probs + 1e-12), axis=-1)
        floor = float(jnp.mean(row_ent))
        log_probs = jnp.log(probs)

        def gen_tokens(rng: jax.Array) -> jax.Array:
            def step(tok, r):
                nxt = jax.random.categorical(r, log_probs[tok])
                return nxt, nxt

            r0, rs = jax.random.split(rng)
            start = jax.random.randint(r0, (batch,), 0, vocab)
            keys = jax.random.split(rs, seq_len)
            _, toks = jax.lax.scan(step, start, keys)  # (S, B)
            return jnp.concatenate([start[None], toks], axis=0).T  # (B, S+1)

    elif kind == "affine":
        a, b = 3, 7

        def gen_tokens(rng: jax.Array) -> jax.Array:
            x0 = jax.random.randint(rng, (batch,), 0, vocab)

            def step(x, _):
                nxt = (a * x + b) % vocab
                return nxt, nxt

            _, xs = jax.lax.scan(step, x0, None, length=seq_len)  # (S, B)
            return jnp.concatenate([x0[None], xs], axis=0).T  # (B, S+1)

    else:
        raise ValueError(f"unknown LM task kind {kind!r}")

    gen_tokens = jax.jit(gen_tokens)

    def _key(step, client):
        return jax.random.fold_in(jax.random.fold_in(base, 1000 + client), step)

    def sample(step: int, client: int) -> PyTree:
        rng = _key(step, client)
        toks = gen_tokens(rng)  # (B, S+1)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if extra_fields is not None:
            out.update(extra_fields(rng))
        return out

    @jax.jit
    def _many(steps: jax.Array, clients: jax.Array) -> PyTree:
        rngs = jax.vmap(_key)(steps, clients)
        toks = jax.vmap(gen_tokens)(rngs)  # (N, B, S+1)
        out = {"tokens": toks[:, :, :-1], "labels": toks[:, :, 1:]}
        if extra_fields is not None:
            out.update(jax.vmap(extra_fields)(rngs))
        return out

    def sample_many(steps, clients) -> PyTree:
        return _many(jnp.asarray(steps, jnp.int32), jnp.asarray(clients, jnp.int32))

    return Task(name=f"lm_{kind}", sample=sample, vocab_size=vocab,
                entropy_floor=floor, sample_many=sample_many)


# ----------------------------------------------------------- non-IID shards


def make_non_iid_lm_task(
    *,
    vocab: int,
    batch: int,
    seq_len: int,
    n_clients: int,
    skew: float = 2.0,
    temperature: float = 1.0,
    seed: int = 0,
) -> Task:
    """Non-IID client shards for federated runs (DESIGN.md §9).

    Client ``c`` samples from its OWN first-order Markov chain, an
    interpolation between one shared global chain and a client-private
    chain:  ``logits_c = (1−λ)·global + λ·private_c`` with
    ``λ = skew / (1 + skew)``.  ``skew=0`` degenerates to the IID split of
    :func:`make_lm_task`; larger skew pushes clients toward disjoint
    transition structure, the pathological-FL setting where naive averaging
    and sparse updates interact worst.

    The stacked transition table is ``(n_clients, V, V)`` f32 — intended
    for the small-vocab federated presets, not 32k-vocab LMs.
    """
    base = jax.random.PRNGKey(seed)
    lam = float(skew) / (1.0 + float(skew))
    g = jax.random.normal(jax.random.fold_in(base, 17), (vocab, vocab))
    priv = jax.random.normal(
        jax.random.fold_in(base, 29), (n_clients, vocab, vocab)
    )
    logits = ((1.0 - lam) * g[None] + lam * priv) / max(temperature, 1e-3)
    probs = jax.nn.softmax(logits, axis=-1)
    row_ent = -jnp.sum(probs * jnp.log(probs + 1e-12), axis=-1)
    floor = float(jnp.mean(row_ent))
    log_probs = jnp.log(probs)  # (C, V, V)

    @jax.jit
    def gen_tokens(rng: jax.Array, client: jax.Array) -> jax.Array:
        table = log_probs[client]

        def step(tok, r):
            nxt = jax.random.categorical(r, table[tok])
            return nxt, nxt

        r0, rs = jax.random.split(rng)
        start = jax.random.randint(r0, (batch,), 0, vocab)
        keys = jax.random.split(rs, seq_len)
        _, toks = jax.lax.scan(step, start, keys)  # (S, B)
        return jnp.concatenate([start[None], toks], axis=0).T  # (B, S+1)

    def _key(step, client):
        return jax.random.fold_in(jax.random.fold_in(base, 3000 + client), step)

    def sample(step: int, client: int) -> PyTree:
        toks = gen_tokens(_key(step, client),
                          jnp.asarray(client % n_clients, jnp.int32))
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    @jax.jit
    def _many(steps: jax.Array, clients: jax.Array) -> PyTree:
        rngs = jax.vmap(_key)(steps, clients)
        toks = jax.vmap(gen_tokens)(rngs, clients % n_clients)
        return {"tokens": toks[:, :, :-1], "labels": toks[:, :, 1:]}

    def sample_many(steps, clients) -> PyTree:
        return _many(jnp.asarray(steps, jnp.int32), jnp.asarray(clients, jnp.int32))

    return Task(
        name=f"lm_markov_noniid{n_clients}", sample=sample, vocab_size=vocab,
        entropy_floor=floor, sample_many=sample_many,
    )


# --------------------------------------------------------- classification


def make_classification_task(
    *,
    n_classes: int,
    img_size: int,
    channels: int,
    batch: int,
    noise: float = 0.35,
    seed: int = 0,
) -> Task:
    """Gaussian class-blob images: class c has a fixed mean image; samples
    add isotropic noise."""
    base = jax.random.PRNGKey(seed)
    means = (
        jax.random.normal(jax.random.fold_in(base, 23), (n_classes, img_size, img_size, channels))
        * 0.5
    )

    @jax.jit
    def gen(rng: jax.Array) -> tuple[jax.Array, jax.Array]:
        r1, r2 = jax.random.split(rng)
        labels = jax.random.randint(r1, (batch,), 0, n_classes)
        imgs = means[labels] + noise * jax.random.normal(
            r2, (batch, img_size, img_size, channels)
        )
        return imgs, labels

    def _key(step, client):
        return jax.random.fold_in(jax.random.fold_in(base, 2000 + client), step)

    def sample(step: int, client: int) -> PyTree:
        imgs, labels = gen(_key(step, client))
        return {"images": imgs, "labels": labels}

    @jax.jit
    def _many(steps: jax.Array, clients: jax.Array) -> PyTree:
        imgs, labels = jax.vmap(lambda s, c: gen(_key(s, c)))(steps, clients)
        return {"images": imgs, "labels": labels}

    def sample_many(steps, clients) -> PyTree:
        return _many(jnp.asarray(steps, jnp.int32), jnp.asarray(clients, jnp.int32))

    return Task(name="blobs", sample=sample, n_classes=n_classes,
                sample_many=sample_many)


# ------------------------------------------------------- client-sharded view


def split_among_clients(task: Task, n_clients: int) -> Callable[[int], PyTree]:
    """``batch_fn(round) -> dict`` with a leading client axis.

    Each client sees a disjoint stream (folded-in client id), mirroring the
    paper's balanced IID shard split.
    """

    def batch_fn(round_idx: int) -> PyTree:
        per = [task.sample(round_idx, c) for c in range(n_clients)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    return batch_fn


def client_batches(task: Task, n_clients: int, n_delay: int) -> Callable[[int], PyTree]:
    """Like :func:`split_among_clients` but with a local-step (delay) axis:
    returns (clients, n_delay, batch, ...) — one microbatch per local step.

    When the task exposes ``sample_many`` the whole (clients × delay) grid
    is generated in one jitted dispatch (identical streams, see Task)."""
    import numpy as np

    def batch_fn(round_idx: int) -> PyTree:
        if task.sample_many is not None:
            clients = np.repeat(np.arange(n_clients), n_delay)
            micro = np.tile(round_idx * n_delay + np.arange(n_delay), n_clients)
            flat = task.sample_many(micro, clients)  # (C·D, B, ...)
            return jax.tree.map(
                lambda x: x.reshape((n_clients, n_delay) + x.shape[1:]), flat
            )
        steps = []
        for d in range(n_delay):
            per = [task.sample(round_idx * n_delay + d, c) for c in range(n_clients)]
            steps.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
        return jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *steps)

    return batch_fn
