"""Deterministic synthetic data pipelines with per-client sharding.

The paper's experiments split a dataset into M equal shards, one per client
(homogeneous/IID split).  This container is offline, so every reproduction
task uses a *synthetic but genuinely learnable* stand-in with the same
interface, seeded deterministically:

  * LM task ("markov"): a fixed random first-order Markov chain over the
    vocabulary with temperature-controlled entropy.  A model that learns the
    transition table reaches the chain's entropy floor; an untrained model
    sits at ln(V).  This gives convergence curves with real headroom, which
    is what the Table II / Fig. 5-6 analogues need.
  * LM task ("affine"): x_{t+1} = (a·x_t + b) mod V — near-zero achievable
    loss, used by fast smoke/integration tests.
  * Classification ("blobs"): Gaussian class blobs in pixel space (LeNet /
    ResNet shapes) — fixed class means with additive noise.

Batches are generated on the fly from a counter-based PRNG (jax.random.fold_in)
so the pipeline is stateless, reproducible, and infinite.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

PyTree = dict


@dataclasses.dataclass(frozen=True)
class Task:
    """A data source: ``sample(step, client) -> dict`` plus metadata."""

    name: str
    sample: Callable[[int, int], PyTree]  # (step, client) -> batch dict
    vocab_size: int = 0
    n_classes: int = 0
    entropy_floor: float = 0.0  # achievable loss (nats/token) for LM tasks


# ------------------------------------------------------------------ LM tasks


def make_lm_task(
    *,
    vocab: int,
    batch: int,
    seq_len: int,
    kind: str = "markov",
    temperature: float = 1.0,
    seed: int = 0,
    extra_fields: Optional[Callable[[jax.Array], PyTree]] = None,
) -> Task:
    """Next-token prediction: ``labels[t] = tokens[t+1]`` at every position."""
    base = jax.random.PRNGKey(seed)
    floor = 0.0

    if kind == "markov":
        logits = jax.random.normal(jax.random.fold_in(base, 17), (vocab, vocab))
        logits = logits / max(temperature, 1e-3)
        probs = jax.nn.softmax(logits, axis=-1)
        # entropy floor ≈ mean row entropy (stationary dist of a dense random
        # chain is near-uniform)
        row_ent = -jnp.sum(probs * jnp.log(probs + 1e-12), axis=-1)
        floor = float(jnp.mean(row_ent))
        log_probs = jnp.log(probs)

        def gen_tokens(rng: jax.Array) -> jax.Array:
            def step(tok, r):
                nxt = jax.random.categorical(r, log_probs[tok])
                return nxt, nxt

            r0, rs = jax.random.split(rng)
            start = jax.random.randint(r0, (batch,), 0, vocab)
            keys = jax.random.split(rs, seq_len)
            _, toks = jax.lax.scan(step, start, keys)  # (S, B)
            return jnp.concatenate([start[None], toks], axis=0).T  # (B, S+1)

    elif kind == "affine":
        a, b = 3, 7

        def gen_tokens(rng: jax.Array) -> jax.Array:
            x0 = jax.random.randint(rng, (batch,), 0, vocab)

            def step(x, _):
                nxt = (a * x + b) % vocab
                return nxt, nxt

            _, xs = jax.lax.scan(step, x0, None, length=seq_len)  # (S, B)
            return jnp.concatenate([x0[None], xs], axis=0).T  # (B, S+1)

    else:
        raise ValueError(f"unknown LM task kind {kind!r}")

    gen_tokens = jax.jit(gen_tokens)

    def sample(step: int, client: int) -> PyTree:
        rng = jax.random.fold_in(jax.random.fold_in(base, 1000 + client), step)
        toks = gen_tokens(rng)  # (B, S+1)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if extra_fields is not None:
            out.update(extra_fields(rng))
        return out

    return Task(name=f"lm_{kind}", sample=sample, vocab_size=vocab, entropy_floor=floor)


# --------------------------------------------------------- classification


def make_classification_task(
    *,
    n_classes: int,
    img_size: int,
    channels: int,
    batch: int,
    noise: float = 0.35,
    seed: int = 0,
) -> Task:
    """Gaussian class-blob images: class c has a fixed mean image; samples
    add isotropic noise."""
    base = jax.random.PRNGKey(seed)
    means = (
        jax.random.normal(jax.random.fold_in(base, 23), (n_classes, img_size, img_size, channels))
        * 0.5
    )

    @jax.jit
    def gen(rng: jax.Array) -> tuple[jax.Array, jax.Array]:
        r1, r2 = jax.random.split(rng)
        labels = jax.random.randint(r1, (batch,), 0, n_classes)
        imgs = means[labels] + noise * jax.random.normal(
            r2, (batch, img_size, img_size, channels)
        )
        return imgs, labels

    def sample(step: int, client: int) -> PyTree:
        rng = jax.random.fold_in(jax.random.fold_in(base, 2000 + client), step)
        imgs, labels = gen(rng)
        return {"images": imgs, "labels": labels}

    return Task(name="blobs", sample=sample, n_classes=n_classes)


# ------------------------------------------------------- client-sharded view


def split_among_clients(task: Task, n_clients: int) -> Callable[[int], PyTree]:
    """``batch_fn(round) -> dict`` with a leading client axis.

    Each client sees a disjoint stream (folded-in client id), mirroring the
    paper's balanced IID shard split.
    """

    def batch_fn(round_idx: int) -> PyTree:
        per = [task.sample(round_idx, c) for c in range(n_clients)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    return batch_fn


def client_batches(task: Task, n_clients: int, n_delay: int) -> Callable[[int], PyTree]:
    """Like :func:`split_among_clients` but with a local-step (delay) axis:
    returns (clients, n_delay, batch, ...) — one microbatch per local step."""

    def batch_fn(round_idx: int) -> PyTree:
        steps = []
        for d in range(n_delay):
            per = [task.sample(round_idx * n_delay + d, c) for c in range(n_clients)]
            steps.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
        return jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *steps)

    return batch_fn
