"""Packed wire format: LeafCompressed pytrees ⇄ actual bytes (DESIGN.md §5).

``Wire.pack`` serializes a compressed update into one contiguous byte
buffer — Golomb position bitstreams (Alg. 3), sign/ternary/level bitfields,
and per-tensor scalars all become real ``uint8`` payloads — and
``Wire.unpack`` decodes it back to the identical dense pytree a receiver
needs.  This is what lets ``bits_per_client`` be *measured* off the buffer
instead of only computed from Eq. 1; tests reconcile the two.

Layout (all little-endian scalars, np.packbits big-endian bitfields):

    header:  b"SBW1"  u32 n_leaves
    leaf i:  u32 payload_bytes, then the payload:
      skip                  → (empty)
      sparse positions      → golomb: u32 bit_count + packed bitstream
                              bitmask: ceil(n/8) mask bytes
                              raw16/raw32/seed: k fixed-width indices
      sparse values         → identity: k f32 | binarize: 1 f32 (μ)
                              sign: f32 scale + k sign bits
      dense payloads        → identity: n f32
                              sign: f32 scale + n sign bits
                              two_means: f32 μ⁺, f32 μ⁻ + n side bits
                              ternary: f32 s + n 2-bit codes
                              stochastic: f32 norm + n sign bits
                                          + n ceil(log2(L+1))-bit levels

Sparse values ride in ascending-position order (Golomb decode emits sorted
positions), so pack sorts (idx, vals) jointly.  ``measured_bits`` counts
exact payload bits before byte padding — the number Eq. 1 meters; the
framing (magic + lengths) is transport overhead and excluded.

Known analytic-vs-wire divergences (deliberate, also noted in stages.py):
``seed`` ships explicit raw32 indices (analytic: one shared 32-bit seed);
``ternary`` packs 2 bits/entry (analytic: log2 3 ≈ 1.58 — an arithmetic
coder would close the gap); ``stochastic`` packs sign+⌈log2(L+1)⌉ bits
(analytic: log2(2L+1)); ``raw16`` auto-widens to u32 for leaves over 2^16
entries (the Table I accounting's own blind spot).
"""
from __future__ import annotations

import dataclasses
import math
import struct
from typing import Any, NamedTuple, Tuple

import jax
import numpy as np

from repro.core import golomb
from repro.core.codec import Codec
from repro.core.policy import ResolvedPolicy
from repro.core.stages import LeafCompressed, k_for

PyTree = Any

MAGIC = b"SBW1"


class LeafSpec(NamedTuple):
    """Static per-leaf decode contract: everything a receiver must already
    know (from the shared policy + model config) to parse the payload."""

    path: str
    shape: Tuple[int, ...]
    selector: str
    quantizer: str
    encoder: str
    p: float
    levels: int = 0  # stochastic-quantizer code range (0 = n/a)

    @property
    def n(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def k(self) -> int:
        if self.selector == "skip":
            return 0
        if self.selector == "dense":
            return self.n
        return k_for(self.n, self.p)


def spec_for(path: str, shape: Tuple[int, ...], codec: Codec, p: float) -> LeafSpec:
    return LeafSpec(
        path=path,
        shape=tuple(shape),
        selector=codec.selector.name,
        quantizer=codec.quantizer.name,
        encoder=codec.encoder.name,
        p=float(p),
        levels=int(codec.quantizer.levels),
    )


# ------------------------------------------------------------- bit plumbing


def _pack_bits(bits: np.ndarray) -> bytes:
    return np.packbits(bits.astype(np.uint8)).tobytes() if bits.size else b""


def _need(payload: bytes, nbytes: int, what: str) -> None:
    """Clean ValueError instead of a struct.error / short-read crash when a
    truncated or corrupted buffer asks for more payload than exists."""
    if len(payload) < nbytes:
        raise ValueError(
            f"truncated SBW1 leaf payload: {what} needs {nbytes} bytes, "
            f"have {len(payload)}"
        )


def _unpack_bits(buf: bytes, count: int) -> np.ndarray:
    if count == 0:
        return np.zeros((0,), np.uint8)
    return np.unpackbits(np.frombuffer(buf, np.uint8))[:count]


def _pack_codes(codes: np.ndarray, width: int) -> bytes:
    """Fixed-width big-endian bitfield of small unsigned ints."""
    if codes.size == 0 or width == 0:
        return b""
    shifts = np.arange(width - 1, -1, -1)
    bits = ((codes[:, None].astype(np.int64) >> shifts[None, :]) & 1).reshape(-1)
    return _pack_bits(bits)


def _unpack_codes(buf: bytes, count: int, width: int) -> np.ndarray:
    if count == 0 or width == 0:
        return np.zeros((count,), np.int64)
    bits = _unpack_bits(buf, count * width).reshape(count, width).astype(np.int64)
    weights = 1 << np.arange(width - 1, -1, -1)
    return bits @ weights


def _f32(x) -> bytes:
    return struct.pack("<f", float(x))


def _code_width(levels: int) -> int:
    return max(1, math.ceil(math.log2(levels + 1)))


def _nbytes(bits: int) -> int:
    return (bits + 7) // 8


# ------------------------------------------------------------ leaf pack side


def pack_leaf(
    comp: LeafCompressed, spec: LeafSpec, golomb_payload=None
) -> Tuple[bytes, int]:
    """Serialize one compressed leaf → (payload bytes, exact payload bits).

    The exact bit count is pre-byte-padding: Golomb bitstream length,
    1 bit per sign/side, ⌈log2⌉ bits per code, 32 per f32 scalar.
    ``golomb_payload`` is an optional precomputed ``(packed bytes, bits)``
    position stream (the device-pack path) used in place of the host
    encoder for golomb leaves.
    """
    if spec.selector == "skip":
        return b"", 0
    if spec.selector == "dense":
        return _pack_dense(comp, spec)
    return _pack_sparse(comp, spec, golomb_payload)


def _pack_sparse(
    comp: LeafCompressed, spec: LeafSpec, golomb_payload=None
) -> Tuple[bytes, int]:
    idx = np.asarray(comp.idx, np.int64)
    order = np.argsort(idx, kind="stable")
    idx = idx[order]
    vals = np.asarray(comp.vals, np.float32)
    if vals.size:
        vals = vals[order]
    k = idx.size

    # ---- positions
    if spec.encoder == "golomb":
        if golomb_payload is not None:
            packed, pos_bits = golomb_payload
        else:
            packed, pos_bits = golomb.encode_positions_packed(idx, spec.p)
        pos = struct.pack("<I", pos_bits) + packed
    elif spec.encoder == "bitmask":
        mask = np.zeros((spec.n,), np.uint8)
        mask[idx] = 1
        pos = _pack_bits(mask)
        pos_bits = spec.n
    elif spec.encoder == "raw16":
        # the paper's naive 16-bit width only addresses 2^16 entries; wider
        # leaves auto-widen to u32 on the wire (analytic stays 16k — the
        # Table I accounting's own blind spot, see module docstring)
        if spec.n <= (1 << 16):
            pos = idx.astype("<u2").tobytes()
            pos_bits = 16 * k
        else:
            pos = idx.astype("<u4").tobytes()
            pos_bits = 32 * k
    elif spec.encoder in ("raw32", "seed"):
        pos = idx.astype("<u4").tobytes()
        pos_bits = 32 * k
    else:
        raise NotImplementedError(f"no wire form for encoder {spec.encoder!r}")

    # ---- values
    if spec.quantizer == "identity":
        val = vals.astype("<f4").tobytes()
        val_bits = 32 * k
    elif spec.quantizer == "binarize":
        val = _f32(comp.mean)
        val_bits = 32
    elif spec.quantizer == "sign":
        val = _f32(comp.mean) + _pack_bits(vals > 0)
        val_bits = 32 + k
    else:
        raise NotImplementedError(
            f"no sparse wire form for quantizer {spec.quantizer!r}"
        )
    return pos + val, pos_bits + val_bits


def _pack_dense(comp: LeafCompressed, spec: LeafSpec) -> Tuple[bytes, int]:
    dense = np.asarray(comp.dense, np.float32)
    n = spec.n
    if spec.quantizer == "identity":
        return dense.astype("<f4").tobytes(), 32 * n
    if spec.quantizer == "sign":
        return _f32(comp.mean) + _pack_bits(dense > 0), 32 + n
    if spec.quantizer == "two_means":
        mu_p, mu_n = np.float32(dense.max()), np.float32(dense.min())
        return _f32(mu_p) + _f32(mu_n) + _pack_bits(dense == mu_p), 64 + n
    if spec.quantizer == "ternary":
        codes = (np.sign(dense) + 1).astype(np.int64)  # {0,1,2}
        return _f32(comp.mean) + _pack_codes(codes, 2), 32 + 2 * n
    if spec.quantizer == "stochastic":
        norm = np.float32(comp.mean)
        w = _code_width(spec.levels)
        q = np.rint(np.abs(dense) * spec.levels / norm).astype(np.int64)
        payload = _f32(norm) + _pack_bits(dense > 0) + _pack_codes(q, w)
        return payload, 32 + n + w * n
    raise NotImplementedError(f"no dense wire form for quantizer {spec.quantizer!r}")


# ---------------------------------------------------------- leaf unpack side


def unpack_leaf(payload: bytes, spec: LeafSpec) -> LeafCompressed:
    """Parse one leaf payload back to a numpy LeafCompressed (idx ascending).

    ``nbits`` carries the exact measured payload bits, so a re-pack of the
    result is byte-identical and the measured size is queryable downstream.
    """
    if spec.selector == "skip":
        return LeafCompressed(
            idx=np.zeros((0,), np.int32), vals=np.zeros((0,), np.float32),
            mean=np.float32(0), dense=np.zeros((0,), np.float32),
            nbits=np.float32(0),
        )
    if spec.selector == "dense":
        return _unpack_dense(payload, spec)
    return _unpack_sparse(payload, spec)


def _unpack_sparse(payload: bytes, spec: LeafSpec) -> LeafCompressed:
    k, off = spec.k, 0
    if spec.encoder == "golomb":
        _need(payload, 4, "golomb bit count")
        (bit_count,) = struct.unpack_from("<I", payload, 0)
        off = 4 + _nbytes(bit_count)
        _need(payload, off, f"golomb bitstream of {bit_count} bits")
        bits = _unpack_bits(payload[4:off], bit_count)
        idx = golomb.decode_positions(bits, spec.p).astype(np.int32)
        pos_bits = bit_count
    elif spec.encoder == "bitmask":
        off = _nbytes(spec.n)
        _need(payload, off, f"{spec.n}-bit mask")
        mask = _unpack_bits(payload[:off], spec.n)
        idx = np.nonzero(mask)[0].astype(np.int32)
        pos_bits = spec.n
    elif spec.encoder == "raw16":
        if spec.n <= (1 << 16):
            off = 2 * k
            _need(payload, off, f"{k} u16 positions")
            idx = np.frombuffer(payload, "<u2", count=k).astype(np.int32)
            pos_bits = 16 * k
        else:  # auto-widened on pack (see _pack_sparse)
            off = 4 * k
            _need(payload, off, f"{k} u32 positions")
            idx = np.frombuffer(payload, "<u4", count=k).astype(np.int32)
            pos_bits = 32 * k
    elif spec.encoder in ("raw32", "seed"):
        off = 4 * k
        _need(payload, off, f"{k} u32 positions")
        idx = np.frombuffer(payload, "<u4", count=k).astype(np.int32)
        pos_bits = 32 * k
    else:
        raise NotImplementedError(f"no wire form for encoder {spec.encoder!r}")
    if idx.size and (int(idx.min()) < 0 or int(idx.max()) >= spec.n):
        # corrupted position stream: decoded indices outside the tensor
        raise ValueError(
            f"corrupt SBW1 positions for {spec.path!r}: index range "
            f"[{int(idx.min())}, {int(idx.max())}] outside [0, {spec.n})"
        )
    k = idx.size  # authoritative once positions are decoded

    mean = np.float32(0)
    vals = np.zeros((0,), np.float32)
    if spec.quantizer == "identity":
        _need(payload, off + 4 * k, f"{k} f32 values")
        vals = np.frombuffer(payload, "<f4", count=k, offset=off).copy()
        val_bits = 32 * k
    elif spec.quantizer == "binarize":
        _need(payload, off + 4, "binarize mean")
        (m,) = struct.unpack_from("<f", payload, off)
        mean = np.float32(m)
        val_bits = 32
    elif spec.quantizer == "sign":
        _need(payload, off + 4 + _nbytes(k), f"sign scale + {k} sign bits")
        (m,) = struct.unpack_from("<f", payload, off)
        mean = np.float32(m)
        signs = _unpack_bits(payload[off + 4:], k).astype(np.float32)
        vals = np.where(signs > 0, mean, -mean).astype(np.float32)
        val_bits = 32 + k
    else:
        raise NotImplementedError(
            f"no sparse wire form for quantizer {spec.quantizer!r}"
        )
    return LeafCompressed(
        idx=idx, vals=vals, mean=mean, dense=np.zeros((0,), np.float32),
        nbits=np.float32(pos_bits + val_bits),
    )


def _unpack_dense(payload: bytes, spec: LeafSpec) -> LeafCompressed:
    n = spec.n
    empty_i = np.zeros((0,), np.int32)
    empty_f = np.zeros((0,), np.float32)
    if spec.quantizer == "identity":
        _need(payload, 4 * n, f"{n} f32 values")
        dense = np.frombuffer(payload, "<f4", count=n).copy()
        return LeafCompressed(empty_i, empty_f, np.float32(0), dense,
                              np.float32(32 * n))
    if spec.quantizer == "sign":
        _need(payload, 4 + _nbytes(n), f"sign scale + {n} sign bits")
        (scale,) = struct.unpack_from("<f", payload, 0)
        scale = np.float32(scale)
        signs = _unpack_bits(payload[4:], n).astype(np.float32)
        dense = np.where(signs > 0, scale, -scale).astype(np.float32)
        return LeafCompressed(empty_i, empty_f, scale, dense,
                              np.float32(32 + n))
    if spec.quantizer == "two_means":
        _need(payload, 8 + _nbytes(n), f"two means + {n} side bits")
        mu_p, mu_n = struct.unpack_from("<ff", payload, 0)
        side = _unpack_bits(payload[8:], n)
        dense = np.where(side > 0, np.float32(mu_p), np.float32(mu_n)).astype(
            np.float32
        )
        return LeafCompressed(empty_i, empty_f, np.float32(mu_p), dense,
                              np.float32(64 + n))
    if spec.quantizer == "ternary":
        _need(payload, 4 + _nbytes(2 * n), f"ternary scale + {n} 2-bit codes")
        (scale,) = struct.unpack_from("<f", payload, 0)
        scale = np.float32(scale)
        codes = _unpack_codes(payload[4:], n, 2) - 1  # {-1,0,1}
        dense = (scale * codes.astype(np.float32)).astype(np.float32)
        return LeafCompressed(empty_i, empty_f, scale, dense,
                              np.float32(32 + 2 * n))
    if spec.quantizer == "stochastic":
        w = _code_width(spec.levels)
        _need(payload, 4 + _nbytes(n) + _nbytes(w * n),
              f"qsgd norm + {n} sign bits + {n} {w}-bit codes")
        (norm,) = struct.unpack_from("<f", payload, 0)
        norm = np.float32(norm)
        sign_bytes = _nbytes(n)
        signs = _unpack_bits(payload[4:4 + sign_bytes], n).astype(np.float32)
        q = _unpack_codes(payload[4 + sign_bytes:], n, w).astype(np.float32)
        sgn = np.where(signs > 0, np.float32(1), np.float32(-1))
        # same op order as the quantizer: ((norm · sign) · q) / levels, all f32
        dense = ((norm * sgn) * q / np.float32(spec.levels)).astype(np.float32)
        return LeafCompressed(empty_i, empty_f, norm, dense,
                              np.float32(32 + n + w * n))
    raise NotImplementedError(f"no dense wire form for quantizer {spec.quantizer!r}")


def leaf_dense(comp: LeafCompressed, spec: LeafSpec) -> np.ndarray:
    """Dense reconstruction of one unpacked leaf, reshaped to spec.shape."""
    if comp.dense.size:
        out = np.asarray(comp.dense, np.float32)
    else:
        out = np.zeros((spec.n,), np.float32)
        if comp.vals.size:
            out[np.asarray(comp.idx)] = comp.vals
        elif comp.idx.size:
            out[np.asarray(comp.idx)] = comp.mean
    return out.reshape(spec.shape)


# ------------------------------------------------------------- message level


@dataclasses.dataclass(frozen=True)
class Wire:
    """A pack/unpack contract bound to one pytree structure + policy.

    Both ends build the same Wire from the shared (model config, policy,
    round rates); only payload bytes cross the network.
    """

    specs: Tuple[LeafSpec, ...]
    treedef: Any

    def _leaves(self, tree: PyTree) -> list:
        return self.treedef.flatten_up_to(tree)

    def pack(self, compressed: PyTree) -> bytes:
        """Compressed pytree → one framed byte buffer."""
        return self.pack_with_bits(compressed)[0]

    def pack_with_bits(
        self, compressed: PyTree, *, device_pack: bool = False,
        interpret=None,
    ) -> Tuple[bytes, int]:
        """Pack and return (buffer, exact payload bits) in one pass — the
        bits are what ``measured_bits`` reports, without re-serializing.

        ``device_pack=True`` produces every golomb position stream with
        the fused select→pack Pallas kernel (:mod:`repro.kernels.pack`)
        instead of the host numpy encoder; the serialized buffer is
        byte-identical, but the bytes come off the device as a single
        big-endian word-buffer copy (``golomb.packed_words_to_bytes``).
        """
        leaves = self._leaves(compressed)
        out = [MAGIC, struct.pack("<I", len(leaves))]
        total_bits = 0
        for comp, spec in zip(leaves, self.specs):
            payload_pos = None
            if device_pack and spec.encoder == "golomb" and spec.selector != "skip":
                payload_pos = _device_golomb_payload(comp, spec, interpret)
            payload, bits = pack_leaf(_to_numpy(comp), spec, payload_pos)
            total_bits += bits
            out.append(struct.pack("<I", len(payload)))
            out.append(payload)
        return b"".join(out), total_bits

    def pack_device(self, compressed: PyTree, *, interpret=None) -> bytes:
        """Device-side ``pack``: byte-identical output, golomb position
        streams packed on-device (one fused select→pack launch per leaf)."""
        return self.pack_with_bits(
            compressed, device_pack=True, interpret=interpret
        )[0]

    def unpack(self, data: bytes) -> PyTree:
        """Byte buffer → dense update pytree (numpy float32 leaves)."""
        return self.dense_of(self.unpack_compressed(data))

    def dense_of(self, comps: PyTree) -> PyTree:
        """Dense reconstruction of an already-unpacked compressed pytree
        (lets a server decode once and reuse the parse for bit accounting)."""
        dense = [
            leaf_dense(c, s) for c, s in zip(self._leaves(comps), self.specs)
        ]
        return jax.tree.unflatten(self.treedef, dense)

    def unpack_compressed(self, data: bytes) -> PyTree:
        """Byte buffer → pytree of numpy LeafCompressed (for re-pack tests
        and servers that aggregate in compressed form)."""
        if len(data) < 8:
            raise ValueError(
                f"truncated SBW1 buffer: {len(data)} bytes, header needs 8"
            )
        if data[:4] != MAGIC:
            raise ValueError("bad wire magic; not an SBW1 buffer")
        (n_leaves,) = struct.unpack_from("<I", data, 4)
        if n_leaves != len(self.specs):
            raise ValueError(
                f"buffer has {n_leaves} leaves, spec expects {len(self.specs)}"
            )
        off, comps = 8, []
        for i, spec in enumerate(self.specs):
            if off + 4 > len(data):
                raise ValueError(
                    f"truncated SBW1 buffer: leaf {i} length field at byte "
                    f"{off} past end ({len(data)} bytes)"
                )
            (ln,) = struct.unpack_from("<I", data, off)
            off += 4
            if off + ln > len(data):
                raise ValueError(
                    f"truncated SBW1 buffer: leaf {i} payload of {ln} bytes "
                    f"at byte {off} past end ({len(data)} bytes)"
                )
            try:
                comps.append(unpack_leaf(data[off:off + ln], spec))
            except (ValueError, NotImplementedError):
                raise
            except Exception as e:
                # any residual parse crash on adversarial bytes surfaces as
                # a clean decode error, never an uncaught IndexError etc.
                raise ValueError(
                    f"corrupt SBW1 leaf payload for {spec.path!r}: {e!r}"
                ) from e
            off += ln
        return jax.tree.unflatten(self.treedef, comps)

    def measured_bits(self, compressed: PyTree) -> int:
        """Exact payload bits (pre byte-padding, no framing) — the measured
        counterpart of Eq. 1's analytic ``nbits`` sum."""
        total = 0
        for comp, spec in zip(self._leaves(compressed), self.specs):
            _, bits = pack_leaf(_to_numpy(comp), spec)
            total += bits
        return total

    def packed_bytes(self, compressed: PyTree) -> int:
        return len(self.pack(compressed))


def _to_numpy(comp: LeafCompressed) -> LeafCompressed:
    return LeafCompressed(*(np.asarray(x) for x in comp))


def _device_golomb_payload(
    comp: LeafCompressed, spec: LeafSpec, interpret=None
) -> Tuple[bytes, int]:
    """One leaf's golomb position payload off the device packer.

    Builds the selection mask from the surviving indices and runs the
    fused select→pack kernel; the returned bytes are the big-endian view
    of the ``uint32`` word buffer, truncated to ``ceil(bits/8)`` — the
    device-to-bytes copy that replaces the host ``np.packbits`` path.
    """
    import jax.numpy as jnp

    from repro.kernels.ops import on_tpu
    from repro.kernels.pack import seg_select_pack

    idx = np.asarray(comp.idx)
    k = int(idx.size)
    if k == 0:
        return b"", 0
    if interpret is None:
        interpret = not on_tpu()
    mask = jnp.zeros((spec.n,), jnp.int32).at[jnp.asarray(idx, jnp.int32)].set(1)
    words, nbits = seg_select_pack(
        mask[None], k=k, bstar=golomb.golomb_bstar(spec.p), interpret=interpret
    )
    nb = int(nbits[0])
    return golomb.packed_words_to_bytes(np.asarray(jax.device_get(words[0])), nb), nb


def wire_for(
    resolved: ResolvedPolicy,
    like: PyTree,
    global_rate: float = 1.0,
    round_idx: int = 0,
) -> Wire:
    """Build the Wire for a resolved policy over a concrete pytree."""
    leaves = resolved._leaves_of(like)
    rates = resolved.rates(global_rate, round_idx)
    specs = tuple(
        spec_for(plan.path, tuple(getattr(leaf, "shape", np.shape(leaf))), plan.codec, p)
        for plan, leaf, p in zip(resolved.plans, leaves, rates)
    )
    return Wire(specs=specs, treedef=resolved.treedef)
