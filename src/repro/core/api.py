"""Compressor API for communication-efficient DSGD (paper Alg. 1).

The core abstraction is the staged codec pipeline (DESIGN.md §2-§5):

  :mod:`repro.core.stages`  Selector → Quantizer → Encoder stage registry
  :mod:`repro.core.codec`   Codec: one composed per-leaf method
  :mod:`repro.core.policy`  CompressionPolicy: per-leaf codecs by path regex
  :mod:`repro.core.wire`    pack/unpack: compressed pytrees ⇄ real bytes

This module keeps the original *compressor* surface as a thin shim over
that pipeline: :func:`get_compressor` returns a :class:`Compressor` that
wraps a single-codec policy, with the same ``compress_leaf`` /
``decompress_leaf`` / ``compress`` / ``decompress`` / ``init_state``
methods the seed API had — existing call sites and configs
(``--compressor sbc``) keep working unchanged.

Everything is functional and jit/vmap-friendly: compressor state
(residuals, RNG, round counters) is an explicit pytree threaded through
``compress``; ``vmap`` over a leading *client* axis gives the per-client
compression of paper Alg. 1 lines 10-14.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax

from repro.core.codec import Codec
from repro.core.policy import (
    CompressionPolicy,
    CompressorState,
    PolicyRule,
    ResolvedPolicy,
)
from repro.core.stages import LeafCompressed, decompress_leaf, k_for

PyTree = Any

__all__ = [
    "Compressor",
    "CompressorState",
    "CompressionPolicy",
    "PolicyRule",
    "LeafCompressed",
    "register",
    "get_compressor",
    "make_compressor",
    "available",
    "k_for",
]


@dataclasses.dataclass(frozen=True)
class Compressor:
    """A named compression method — a single-codec (or richer) policy with
    the legacy per-leaf/per-tree call surface.

    ``compress_leaf``/``decompress_leaf`` operate on the policy's *default*
    codec; ``compress``/``decompress`` resolve the full policy per leaf, so
    a Compressor built from a multi-rule policy applies per-leaf codecs
    transparently through the old entry points.
    """

    name: str
    policy: CompressionPolicy

    # ------------------------------------------------------------- builders

    @classmethod
    def from_codec(
        cls, name: str, codec: Union[str, Codec], **kw: Any
    ) -> "Compressor":
        return cls(name=name, policy=CompressionPolicy.single(codec, name=name, **kw))

    @classmethod
    def from_policy(cls, name: str, policy: CompressionPolicy) -> "Compressor":
        return cls(name=name, policy=policy)

    # ---------------------------------------------------------- inspection

    @property
    def codec(self) -> Codec:
        return self.policy.default

    @property
    def use_residual(self) -> bool:
        return self.codec.use_residual

    @property
    def stochastic(self) -> bool:
        return self.codec.stochastic

    # ------------------------------------------------------------ leaf API

    def compress_leaf(
        self, flat: jax.Array, p: float, rng: Optional[jax.Array]
    ) -> LeafCompressed:
        return self.codec.compress_leaf(flat, p, rng)

    def decompress_leaf(self, comp: LeafCompressed, n: int) -> jax.Array:
        return decompress_leaf(comp, n)

    # ---------------------------------------------------------- pytree API

    def resolve(self, tree: PyTree) -> ResolvedPolicy:
        return self.policy.resolve(tree)

    def init_state(
        self, params: PyTree, rng: Optional[jax.Array] = None
    ) -> CompressorState:
        return self.policy.resolve(params).init_state(params, rng)

    def compress(
        self,
        delta: PyTree,
        state: CompressorState,
        sparsity: Union[float, Tuple[float, ...]],
    ) -> tuple:
        """Compress a full update pytree with error feedback (Eq. 2).

        ``sparsity``: the global rate (per-leaf rule overrides win), or an
        explicit per-leaf rate tuple from ``ResolvedPolicy.rates``.

        Per-round schedules cannot be evaluated here — ``state.step`` is a
        traced array, and silently pinning every round to the round-0 rate
        would ship the warm-up rate forever.  A schedule-bearing policy must
        be driven with an explicit per-round rate tuple (``DSGDTrainer.fit``
        does this each round); a bare float raises.
        """
        resolved = self.policy.resolve(delta)
        if isinstance(sparsity, tuple):
            rates = sparsity
        else:
            scheduled = [p.path for p in resolved.plans if p.schedule is not None]
            if scheduled:
                raise ValueError(
                    "policy attaches per-round sparsity schedules to "
                    f"{scheduled[:3]}…; pass resolve(delta).rates(p, round) "
                    "instead of a bare float so the schedule advances"
                )
            rates = resolved.rates(float(sparsity))
        return resolved.compress(delta, state, rates)

    def decompress(self, compressed: PyTree, like: PyTree) -> PyTree:
        """Reconstruct a dense update pytree from the wire form.

        Reconstructs through ``like``'s treedef, so a structure mismatch
        between the two trees raises instead of silently mispairing leaves.
        """
        return self.policy.resolve(like).decompress(compressed, like)

    def total_bits(self, compressed: PyTree) -> jax.Array:
        """Sum of analytic wire bits across leaves (Eq. 1 inner term)."""
        comp_leaves = jax.tree.leaves(
            compressed, is_leaf=lambda x: isinstance(x, LeafCompressed)
        )
        return sum(c.nbits for c in comp_leaves)


# --------------------------------------------------------------- registry

_REGISTRY: Dict[str, Callable[..., Compressor]] = {}


def register(name: str) -> Callable:
    def deco(factory: Callable[..., Compressor]) -> Callable[..., Compressor]:
        _REGISTRY[name] = factory
        return factory

    return deco


def make_compressor(name: str, **kwargs: Any) -> Compressor:
    """Instantiate a registered compressor by name (the registry lookup
    behind ``RunSpec.compressor`` / ``--compressor``)."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown compressor {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)


def get_compressor(name: str, **kwargs: Any) -> Compressor:
    """Legacy name for :func:`make_compressor` (the seed API surface).

    Survives as a documented shim — same registry, same Compressor,
    bit-identical behavior — but new code should either name the
    compressor in a :class:`~repro.run.RunSpec` or call
    :func:`make_compressor`.
    """
    warnings.warn(
        "get_compressor() is the legacy seed surface; name the compressor "
        "in a repro.run.RunSpec (spec.compressor) or call "
        "repro.core.api.make_compressor() (same registry, bit-identical)",
        DeprecationWarning,
        stacklevel=2,
    )
    return make_compressor(name, **kwargs)


def available() -> list:
    return sorted(_REGISTRY)
