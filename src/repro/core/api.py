"""Compressor API for communication-efficient DSGD (paper Alg. 1).

A *compressor* maps a weight-update pytree ``delta`` (ΔW in the paper) to a
:class:`CompressedUpdate` — a fixed-shape pytree that (a) can be exchanged
over the mesh with far fewer bytes than the dense update and (b) can be
deterministically decompressed back to a dense pytree on every receiver.

Everything here is functional and jit/vmap-friendly: compressor state
(residuals, RNG, round counters) is an explicit pytree threaded through
``compress``.  ``vmap`` over a leading *client* axis gives the per-client
compression of paper Alg. 1 lines 10-14.

Registry: concrete compressors register under a string name so configs can
select them (``--compressor sbc``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class LeafCompressed(NamedTuple):
    """Compressed form of ONE flattened tensor.

    Exactly one of the value encodings is "live" per method; dead fields are
    zero-size arrays so the pytree structure stays static under jit.

    idx:  int32[k]   positions of surviving entries (sorted not required)
    vals: f32[k] | f32[0]   per-entry values (Gradient Dropping / DGC)
    mean: f32[]      single signed mean value (SBC: ±μ, 0 value bits)
    dense: f32[n] | f32[0]  dense payload (sign/ternary/quantized methods)
    nbits: f32[]     analytic wire size of this leaf for this round (Eq. 1)
    """

    idx: jax.Array
    vals: jax.Array
    mean: jax.Array
    dense: jax.Array
    nbits: jax.Array


class CompressorState(NamedTuple):
    """Per-client compressor state threaded through training.

    residual: pytree like params — error-feedback accumulator (Eq. 2).
    rng:      PRNG key for stochastic quantizers (TernGrad/QSGD).
    step:     round counter (drives sparsity / warm-up schedules).
    """

    residual: PyTree
    rng: jax.Array
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class Compressor:
    """A concrete compression method.

    compress_leaf(flat_delta, p, rng) -> LeafCompressed
    decompress_leaf(LeafCompressed, n) -> f32[n]

    use_residual: whether error feedback (Eq. 2) wraps compression.
    name: registry key.
    """

    name: str
    compress_leaf: Callable[..., LeafCompressed]
    decompress_leaf: Callable[[LeafCompressed, int], jax.Array]
    use_residual: bool = True
    stochastic: bool = False

    # ---------------------------------------------------------- pytree API

    def init_state(self, params: PyTree, rng: Optional[jax.Array] = None) -> CompressorState:
        residual = jax.tree.map(jnp.zeros_like, params) if self.use_residual else ()
        if rng is None:
            rng = jax.random.PRNGKey(0)
        return CompressorState(residual=residual, rng=rng, step=jnp.zeros((), jnp.int32))

    def compress(
        self,
        delta: PyTree,
        state: CompressorState,
        sparsity: float,
    ) -> tuple[PyTree, PyTree, CompressorState]:
        """Compress a full update pytree with error feedback.

        Returns (compressed_tree, dense_tree, new_state) where
        ``compressed_tree`` has a LeafCompressed at every leaf, and
        ``dense_tree`` is the locally-decompressed ΔW* (what the residual
        subtracts; receivers reconstruct the same thing from the wire form).
        """
        leaves, treedef = jax.tree.flatten(delta)
        rngs = jax.random.split(state.rng, len(leaves) + 1)
        next_rng, leaf_rngs = rngs[0], rngs[1:]

        res_leaves = (
            jax.tree.leaves(state.residual) if self.use_residual else [None] * len(leaves)
        )

        comp_leaves, dense_leaves, new_res = [], [], []
        for leaf, res, lr in zip(leaves, res_leaves, leaf_rngs):
            flat = leaf.reshape(-1).astype(jnp.float32)
            acc = flat + res.reshape(-1) if res is not None else flat  # Alg.1 l.10
            comp = self.compress_leaf(acc, sparsity, lr)
            dense = self.decompress_leaf(comp, flat.shape[0])
            comp_leaves.append(comp)
            dense_leaves.append(dense.reshape(leaf.shape).astype(leaf.dtype))
            if res is not None:
                new_res.append((acc - dense).reshape(leaf.shape).astype(res.dtype))

        # no-error-feedback methods preserve the incoming residual pytree
        # unchanged, so compressors can be mixed over one TrainState (e.g.
        # the §III hybrid temporal/gradient schedules)
        residual = (
            jax.tree.unflatten(treedef, new_res) if self.use_residual
            else state.residual
        )
        new_state = CompressorState(residual=residual, rng=next_rng, step=state.step + 1)
        return (
            jax.tree.unflatten(treedef, comp_leaves),
            jax.tree.unflatten(treedef, dense_leaves),
            new_state,
        )

    def decompress(self, compressed: PyTree, like: PyTree) -> PyTree:
        """Reconstruct a dense update pytree from the wire form."""

        def leaf_fn(comp: LeafCompressed, ref: jax.Array) -> jax.Array:
            n = ref.size
            return self.decompress_leaf(comp, n).reshape(ref.shape).astype(ref.dtype)

        comp_leaves = jax.tree.leaves(compressed, is_leaf=lambda x: isinstance(x, LeafCompressed))
        ref_leaves, treedef = jax.tree.flatten(like)
        return jax.tree.unflatten(
            treedef, [leaf_fn(c, r) for c, r in zip(comp_leaves, ref_leaves)]
        )

    def total_bits(self, compressed: PyTree) -> jax.Array:
        """Sum of analytic wire bits across leaves (Eq. 1 inner term)."""
        comp_leaves = jax.tree.leaves(compressed, is_leaf=lambda x: isinstance(x, LeafCompressed))
        return sum(c.nbits for c in comp_leaves)


# --------------------------------------------------------------- registry

_REGISTRY: Dict[str, Callable[..., Compressor]] = {}


def register(name: str) -> Callable:
    def deco(factory: Callable[..., Compressor]) -> Callable[..., Compressor]:
        _REGISTRY[name] = factory
        return factory

    return deco


def get_compressor(name: str, **kwargs: Any) -> Compressor:
    if name not in _REGISTRY:
        raise KeyError(f"unknown compressor {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)


def available() -> list[str]:
    return sorted(_REGISTRY)


# ------------------------------------------------------ shared leaf helpers

def empty_like_fields(n: int) -> dict:
    """Zero-size placeholders for dead LeafCompressed fields."""
    return dict(
        idx=jnp.zeros((0,), jnp.int32),
        vals=jnp.zeros((0,), jnp.float32),
        mean=jnp.zeros((), jnp.float32),
        dense=jnp.zeros((0,), jnp.float32),
    )


def k_for(n: int, p: float) -> int:
    """Number of surviving entries at sparsity rate p (at least 1)."""
    return max(1, min(n, int(round(p * n))))
