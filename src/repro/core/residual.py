"""Residual accumulation (error feedback) — paper Eq. 2 and Theorem II.1.

    R_τ = R_{τ-1} + ΔW_τ − ΔW*_τ

Theorem II.1: if transferred updates are restricted to a metric subspace S,
then ΔW*_T = Proj_S(R_{T-1} + ΔW_T) uniquely minimizes the accumulated error
‖Σ_t (ΔW_t − ΔW*_t)‖ over S — i.e. error feedback keeps the compressed
optimization path the orthogonal projection of the uncompressed one.

The mechanics live in :meth:`repro.core.api.Compressor.compress`; this module
provides the standalone primitives plus the projection utilities the theorem
property-test (tests/test_residual.py) exercises.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def residual_update(residual: PyTree, delta: PyTree, transferred: PyTree) -> PyTree:
    """Eq. 2: R ← R + ΔW − ΔW*."""
    return jax.tree.map(lambda r, d, t: r + d - t, residual, delta, transferred)


def accumulated_error(deltas: jax.Array, transferred: jax.Array) -> jax.Array:
    """‖Σ_t (ΔW_t − ΔW*_t)‖ for stacked (T, n) histories (Eq. 4)."""
    return jnp.linalg.norm(jnp.sum(deltas - transferred, axis=0))


def project_fixed_support(vec: jax.Array, support: jax.Array) -> jax.Array:
    """Orthogonal projection onto S = {x : x_i = 0 for i ∉ support}.

    A fixed-support sparse set IS a linear subspace, so this is the exact
    setting of Theorem II.1; tests verify no other element of S beats it.
    """
    return jnp.where(support, vec, 0.0)


def topk_projection(vec: jax.Array, k: int) -> jax.Array:
    """Best k-sparse approximation (projection onto the k-sparse union-of-
    subspaces); top-k-by-magnitude with true values — what Gradient Dropping
    transfers, and the per-round optimal ΔW* of Theorem II.1 given the
    residual-accumulated input."""
    _, idx = jax.lax.top_k(jnp.abs(vec), k)
    return jnp.zeros_like(vec).at[idx].set(vec[idx])
