"""Per-leaf compression policies (DESIGN.md §3).

A :class:`CompressionPolicy` assigns every leaf of a parameter pytree its
own codec, sparsity schedule, and skip/dense-fallback rule by matching the
leaf's *path* ("decoder/layer0/attn/wq", "embed/bias", …) against ordered
regex rules — the mechanism DGC-style recipes need ("biases and norms go
dense, matrices get 0.1% top-k with warm-up").

``CompressionPolicy.resolve(tree)`` binds the rules to a concrete pytree
structure, producing a :class:`ResolvedPolicy` — the compression *engine*
that threads error feedback (Eq. 2) per leaf and is what the trainer and
the :class:`~repro.core.api.Compressor` shim drive.

Sparsity rates are resolved OUTSIDE jit (schedules take a python round
index and return python floats) and enter the traced computation as static
per-leaf constants, so shapes stay fixed and per-round rate changes are
ordinary retraces.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codec import Codec, make_codec
from repro.core.stages import decompress_leaf

PyTree = Any

# The DGC recipe's "small leaves ride dense" path pattern (biases, norm
# scales) — the one policy rule every launcher/example/benchmark shares.
DENSE_SMALL_PATTERN = r"(^|/)(bias|scale|norm[^/]*)(/|$)"

# MoE leaf paths as repro.models.moe lays them out: stacked expert weights
# ("moe/up", "moe/gate", "moe/down", leading E axis) and the dense router.
MOE_EXPERT_PATTERN = r"(^|/)moe/(up|gate|down)(/|$)"
MOE_ROUTER_PATTERN = r"(^|/)moe/router(/|$)"


class CompressorState(NamedTuple):
    """Per-client compressor state threaded through training.

    residual: pytree like params — error-feedback accumulator (Eq. 2);
              ``()`` when no leaf's codec uses error feedback.
    rng:      PRNG key for stochastic selectors/quantizers.
    step:     round counter (traced; sparsity/warm-up schedules are
              evaluated host-side per round via ``ResolvedPolicy.rates``,
              not from this array).
    """

    residual: PyTree
    rng: jax.Array
    step: jax.Array


def path_str(path: Sequence) -> str:
    """Render a jax key-path as the "a/b/0/w" strings rules match against."""
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


@dataclasses.dataclass(frozen=True)
class PolicyRule:
    """First matching rule wins (``re.search`` against the leaf path).

    codec:    named codec / "sel|quant|enc" spec / Codec; None keeps the
              policy default codec ("skip" and "dense32" are the skip and
              dense-fallback shortcuts).
    sparsity: fixed per-leaf rate override (None → schedule / global rate).
    schedule: round → rate callable (e.g. DGC warm-up); overrides the
              global rate but loses to a fixed ``sparsity``.
    rate_scale: multiplier applied to whichever rate wins above — the
              MoE "reduced-k" knob (top_k/E for expert leaves whose
              gradients routing already sparsified).  It composes with
              schedules and the global rate instead of overriding them.
    """

    pattern: str
    codec: Union[str, Codec, None] = None
    sparsity: Optional[float] = None
    schedule: Optional[Callable[[int], float]] = None
    rate_scale: float = 1.0


class LeafPlan(NamedTuple):
    """One leaf's bound compression plan."""

    path: str
    codec: Codec
    sparsity: Optional[float]
    schedule: Optional[Callable[[int], float]]
    rate_scale: float = 1.0

    def rate(self, global_rate: float, round_idx: int = 0) -> float:
        if self.sparsity is not None:
            base = float(self.sparsity)
        elif self.schedule is not None:
            base = float(self.schedule(round_idx))
        else:
            base = float(global_rate)
        return min(1.0, base * float(self.rate_scale))


@dataclasses.dataclass(frozen=True)
class CompressionPolicy:
    """Ordered regex rules over a default codec.

    ``fast=True`` opts the resolved policy into the device-resident
    flat-buffer fast path (:mod:`repro.core.flat`, DESIGN.md §10): the
    whole per-round compression runs as one cached jitted call over a
    single flat buffer, with the error-feedback residual stored flat.
    Output is bit-identical to the per-leaf path; policies containing a
    codec with no flat form fall back to the per-leaf path silently.
    """

    default: Codec
    rules: Tuple[PolicyRule, ...] = ()
    name: str = "policy"
    fast: bool = False

    def plan_for(self, path: str) -> LeafPlan:
        for rule in self.rules:
            if re.search(rule.pattern, path):
                codec = (
                    self.default if rule.codec is None else make_codec(rule.codec)
                )
                return LeafPlan(path, codec, rule.sparsity, rule.schedule,
                                rule.rate_scale)
        return LeafPlan(path, self.default, None, None)

    def resolve(self, tree: PyTree) -> "ResolvedPolicy":
        """Bind rules to a concrete pytree structure (paths + treedef)."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        plans = tuple(self.plan_for(path_str(path)) for path, _ in flat)
        return ResolvedPolicy(policy=self, treedef=treedef, plans=plans)

    # convenience used by shims / single-codec call sites
    @classmethod
    def single(cls, codec: Union[str, Codec], name: str = "", **kw) -> "CompressionPolicy":
        c = make_codec(codec, **kw)
        return cls(default=c, rules=(), name=name or c.spec)


def moe_rules(
    experts: int,
    top_k: int = 2,
    *,
    pattern: str = MOE_EXPERT_PATTERN,
    encoder: str = "golomb",
    use_residual: bool = True,
) -> Tuple[PolicyRule, ...]:
    """MoE-aware policy rules (prepend to any policy's rule tuple).

    Routing already sparsifies expert gradients: each step only ``top_k``
    of ``experts`` experts see tokens, the rest accumulate exact zeros.
    Two consequences, encoded as two rules:

    * expert stacks (``moe/up|gate|down``) select with the
      :func:`~repro.core.stages.make_expert_topk_selector` per-expert
      quota (no hot expert crowds the others out; unrouted all-zero
      experts lose every contested slot — skip-if-unrouted) and carry a
      ``rate_scale = top_k/experts`` reduced-k multiplier, since only
      that fraction of the stack holds signal in expectation;
    * the router (``moe/router``) rides dense — it is tiny, every token
      touches it, and quantizing it destabilizes routing.
    """
    scale = min(1.0, float(top_k) / float(max(1, experts)))
    codec = make_codec(
        f"expert_topk|identity|{encoder}",
        experts=experts, use_residual=use_residual,
    )
    return (
        PolicyRule(MOE_ROUTER_PATTERN, codec="dense32"),
        PolicyRule(pattern, codec=codec, rate_scale=scale),
    )


@dataclasses.dataclass(frozen=True)
class ResolvedPolicy:
    """A policy bound to one pytree structure — the compression engine.

    All methods are functional and jit/vmap-friendly; per-leaf rates enter
    as static python floats (see module docstring).
    """

    policy: CompressionPolicy
    treedef: Any
    plans: Tuple[LeafPlan, ...]

    @property
    def any_residual(self) -> bool:
        return any(p.codec.use_residual for p in self.plans)

    @property
    def any_stochastic(self) -> bool:
        return any(p.codec.stochastic for p in self.plans)

    @property
    def fast_compatible(self) -> bool:
        """True when every leaf's codec has a flat-buffer form, i.e. a
        ``fast=True`` policy will actually take the fast path."""
        from repro.core import flat

        return flat.supports(self)

    def flat_space(self, like: PyTree):
        """The :class:`~repro.core.flat.FlatParamSpace` binding this policy
        to ``like``'s leaf layout (cached per layout; None if unsupported).

        Non-float32 leaves fall back to the per-leaf path: the flat
        residual buffer is f32, while the legacy path re-quantizes the
        residual to the leaf dtype every round (e.g. the bf16-residual
        configs of DESIGN.md §8) — taking the fast path there would
        silently change the error-feedback trajectory.
        """
        from repro.core import flat

        if not flat.supports(self):
            return None
        leaves = self._leaves_of(like)
        dtypes = [
            x.dtype if hasattr(x, "dtype") else jnp.asarray(x).dtype
            for x in leaves
        ]
        if any(d != jnp.float32 for d in dtypes):
            return None
        key = tuple(
            (tuple(getattr(x, "shape", np.shape(x))), d)
            for x, d in zip(leaves, dtypes)
        )
        cache = getattr(self, "_flat_cache", None)
        if cache is None:
            cache = {}
            object.__setattr__(self, "_flat_cache", cache)
        space = cache.get(key)
        if space is None:
            space = flat.FlatParamSpace.for_resolved(self, like)
            cache[key] = space
        return space

    def rates(
        self, global_rate: float, round_idx: int = 0
    ) -> Tuple[float, ...]:
        """Per-leaf sparsity rates for this round (static, hashable).

        Memoized: schedule-free policies resolve to the same tuple every
        round, so callers that rebuild the tuple per round (wire caches,
        the fed server's per-upload decode contract) hit a dict instead of
        re-walking the plans — part of the resolve-once-per-topology
        contract of :func:`repro.core.channel.resolve_cached`.
        """
        scheduled = any(p.schedule is not None for p in self.plans)
        key = (float(global_rate), round_idx if scheduled else 0)
        cache = getattr(self, "_rates_cache", None)
        if cache is None:
            cache = {}
            object.__setattr__(self, "_rates_cache", cache)
        got = cache.get(key)
        if got is None:
            got = tuple(p.rate(global_rate, round_idx) for p in self.plans)
            cache[key] = got
        return got

    # ----------------------------------------------------------- lifecycle

    def init_state(
        self, params: PyTree, rng: Optional[jax.Array] = None
    ) -> CompressorState:
        if self.any_residual:
            space = self.flat_space(params) if self.policy.fast else None
            if space is not None:
                # fast path: the residual lives in the flat §10 layout and
                # never round-trips through the per-leaf pytree
                residual = space.zeros_residual()
            else:
                residual = jax.tree.map(jnp.zeros_like, params)
        else:
            residual = ()
        if rng is None:
            rng = jax.random.PRNGKey(0)
        return CompressorState(residual=residual, rng=rng, step=jnp.zeros((), jnp.int32))

    def _leaves_of(self, tree: PyTree) -> list:
        """Flatten ``tree`` through OUR treedef — raises on structure
        mismatch instead of silently mispairing leaves."""
        return self.treedef.flatten_up_to(tree)

    def compress(
        self,
        delta: PyTree,
        state: CompressorState,
        rates: Union[float, Tuple[float, ...]],
    ) -> tuple:
        """Compress a full update pytree with per-leaf error feedback.

        Returns (compressed_tree, dense_tree, new_state): ``compressed_tree``
        has a LeafCompressed at every leaf; ``dense_tree`` is the locally
        decompressed ΔW* (what the residual subtracts; receivers reconstruct
        the identical thing from the wire form).
        """
        leaves = self._leaves_of(delta)
        if not isinstance(rates, tuple):
            rates = (float(rates),) * len(leaves)
        if len(rates) != len(self.plans):
            raise ValueError(
                f"got {len(rates)} rates for {len(self.plans)} leaves"
            )
        if self.policy.fast:
            space = self.flat_space(delta)
            if space is not None:
                # device-resident flat-buffer fast path (§10): one cached
                # jitted call for the whole pytree, bit-identical output
                return space.compress(delta, state, rates)
        rngs = jax.random.split(state.rng, len(leaves) + 1)
        next_rng, leaf_rngs = rngs[0], rngs[1:]
        res_leaves = (
            self._leaves_of(state.residual)
            if self.any_residual
            else [None] * len(leaves)
        )

        comp_leaves, dense_leaves, new_res = [], [], []
        for plan, leaf, res, p, lr in zip(
            self.plans, leaves, res_leaves, rates, leaf_rngs
        ):
            flat = leaf.reshape(-1).astype(jnp.float32)
            use_res = plan.codec.use_residual and res is not None
            acc = flat + res.reshape(-1).astype(jnp.float32) if use_res else flat
            comp = plan.codec.compress_leaf(acc, p, lr)
            dense = decompress_leaf(comp, flat.shape[0])
            comp_leaves.append(comp)
            dense_leaves.append(dense.reshape(leaf.shape).astype(leaf.dtype))
            if res is not None:
                new_res.append(
                    (acc - dense).reshape(leaf.shape).astype(res.dtype)
                    if use_res
                    else res  # residual-free codecs leave their slot intact
                )

        residual = (
            jax.tree.unflatten(self.treedef, new_res)
            if self.any_residual
            else state.residual
        )
        new_state = CompressorState(
            residual=residual, rng=next_rng, step=state.step + 1
        )
        return (
            jax.tree.unflatten(self.treedef, comp_leaves),
            jax.tree.unflatten(self.treedef, dense_leaves),
            new_state,
        )

    def decompress(self, compressed: PyTree, like: PyTree) -> PyTree:
        """Reconstruct a dense update pytree from the wire form.

        Both trees are flattened through the resolved treedef, so a
        mismatched structure raises instead of silently mispairing.
        """
        comp_leaves = self._leaves_of(compressed)
        ref_leaves = self._leaves_of(like)
        out = [
            decompress_leaf(c, r.size).reshape(r.shape).astype(r.dtype)
            for c, r in zip(comp_leaves, ref_leaves)
        ]
        return jax.tree.unflatten(self.treedef, out)

    def total_bits(self, compressed: PyTree) -> jax.Array:
        """Sum of analytic wire bits across leaves (Eq. 1 inner term)."""
        return sum(c.nbits for c in self._leaves_of(compressed))

    # ------------------------------------------------------------ summaries

    def describe(self) -> str:
        """Human-readable per-leaf codec table (launchers print this)."""
        lines = [f"policy {self.policy.name!r}: {len(self.plans)} leaves"]
        for p in self.plans:
            extra = ""
            if p.sparsity is not None:
                extra = f"  p={p.sparsity}"
            elif p.schedule is not None:
                extra = "  p=schedule"
            if p.rate_scale != 1.0:
                extra += f"  rate×{p.rate_scale:g}"
            lines.append(f"  {p.path:<48s} {p.codec.spec}{extra}")
        return "\n".join(lines)
