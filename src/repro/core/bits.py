"""Bit accounting — paper Eq. 1 and Table I.

    b_total = O( N_iter · f  ·  |ΔW≠0| · (b̄_pos + b̄_val)  ·  K )

``f`` is the communication frequency (1/n for delay n), ``|ΔW≠0|`` the number
of surviving entries, and K the receiving-node count (1 for a server upload,
M−1 for all-to-all; we report per-upload bits like the paper and expose K).

These analytic numbers are validated against the exact Golomb bitstream
(tests/test_golomb.py) and against the LeafCompressed ``nbits`` fields.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.golomb import expected_position_bits

DENSE_VALUE_BITS = 32.0
NAIVE_POS_BITS = 16.0


@dataclasses.dataclass(frozen=True)
class MethodBits:
    """Asymptotic per-method accounting (one Table I column)."""

    name: str
    temporal_sparsity: float  # f, fraction of iterations that communicate
    gradient_sparsity: float  # fraction of entries that survive
    value_bits: float  # b̄_val per surviving entry
    position_bits: float  # b̄_pos per surviving entry

    def bits_per_iteration(self, n_params: int) -> float:
        """Expected uplink bits per forward-backward pass (Eq. 1 / N_iter)."""
        per_comm = (
            self.gradient_sparsity * n_params * (self.value_bits + self.position_bits)
        )
        # per-tensor scalar overheads (means/norms) are O(#tensors) and
        # negligible at the asymptotic level of Table I.
        return self.temporal_sparsity * per_comm

    def compression_rate(self, n_params: int) -> float:
        base = DENSE_VALUE_BITS * n_params
        return base / self.bits_per_iteration(n_params)


def table1_row(
    name: str,
    *,
    delay: int = 1,
    sparsity: float = 1.0,
    value_bits: float = DENSE_VALUE_BITS,
    golomb: bool = False,
) -> MethodBits:
    if golomb:
        pos = expected_position_bits(sparsity)
    elif sparsity < 1.0:
        pos = NAIVE_POS_BITS
    else:
        pos = 0.0
    return MethodBits(
        name=name,
        temporal_sparsity=1.0 / delay,
        gradient_sparsity=sparsity,
        value_bits=value_bits,
        position_bits=pos,
    )


def paper_table1() -> list[MethodBits]:
    """The columns of Table I with the paper's representative settings."""
    return [
        table1_row("baseline"),
        table1_row("signsgd", value_bits=1.0),
        table1_row("qsgd", value_bits=4.0),
        table1_row("terngrad", value_bits=math.log2(3.0)),
        table1_row("gradient_dropping", sparsity=0.001),
        table1_row("dgc", sparsity=0.001),
        table1_row("federated_averaging", delay=100),
        table1_row("sbc1", delay=1, sparsity=0.001, value_bits=0.0, golomb=True),
        table1_row("sbc2", delay=10, sparsity=0.01, value_bits=0.0, golomb=True),
        table1_row("sbc3", delay=100, sparsity=0.01, value_bits=0.0, golomb=True),
    ]


def sbc_bits_per_round(n_params: int, p: float) -> float:
    """Exact expected wire bits for one SBC message over n_params entries."""
    k = max(1, min(n_params, round(p * n_params)))
    return k * expected_position_bits(p) + 32.0


def total_upload_bits(
    *, n_params: int, n_iterations: int, delay: int, bits_per_comm: float
) -> float:
    """Eq. 1 total for one client over a training run (K = 1 server)."""
    rounds = n_iterations / delay
    return rounds * bits_per_comm
