"""Golomb position coding (paper Alg. 3 / Alg. 4 and Eq. 5).

Under the paper's model, the gaps between surviving positions of a top-p%
sparsified tensor are geometric with success probability p, so Golomb coding
with parameter ``b* = 1 + floor(log2(log(phi-1)/log(1-p)))`` (phi the golden
ratio) is the optimal prefix code.  Eq. 5 gives the expected bits/position:

    b̄_pos = b* + 1 / (1 - (1-p)^(2^b*))

This module implements BOTH:
  * the analytic model (``expected_position_bits``) used in-graph for the
    bit accounting of Eq. 1, and
  * the exact bitstream encoder/decoder (numpy, host-side) used as the wire
    format by the federated launcher and validated by round-trip tests.

The bitstream layout per position gap d (>=1):  q = (d-1) // 2^b* unary ones,
a terminating 0, then b* binary bits of r = (d-1) % 2^b*.
"""
from __future__ import annotations

import bisect
import math

import numpy as np

PHI = (math.sqrt(5.0) + 1.0) / 2.0


def golomb_bstar(p: float) -> int:
    """Optimal Golomb parameter b* for sparsity rate p (paper Alg. 3 l.4)."""
    if not 0.0 < p < 1.0:
        raise ValueError(f"sparsity rate must be in (0,1), got {p}")
    b = 1 + math.floor(math.log2(math.log(PHI - 1.0) / math.log(1.0 - p)))
    return max(0, int(b))


def expected_position_bits(p: float) -> float:
    """Eq. 5: average bits to encode one non-zero position at sparsity p.

    p ≥ 1 means a dense update — positions are predetermined and cost 0
    bits (Eq. 1's dense case), which also covers schedules that move
    through the fully-dense corner of the §III trade-off grid.
    """
    if p >= 1.0:
        return 0.0
    b = golomb_bstar(p)
    return b + 1.0 / (1.0 - (1.0 - p) ** (2.0**b))


# ------------------------------------------------------------------ encode


def encode_positions(indices: np.ndarray, p: float) -> np.ndarray:
    """Alg. 3: encode sorted non-zero positions as a Golomb bitstream.

    Returns a uint8 array of BITS (one bit per entry; packing to bytes is
    ``np.packbits`` at the transport layer — bit count is what Eq. 1 meters).

    Vectorized: per gap d the codeword is q unary ones, a 0, then b* binary
    bits of r, with q = (d−1) div 2^b*, r = (d−1) mod 2^b*.  We compute all
    codeword offsets with a cumsum and scatter ones/remainder bits at once.
    """
    indices = np.sort(np.asarray(indices, dtype=np.int64))
    if indices.size == 0:
        return np.zeros((0,), np.uint8)
    bstar = golomb_bstar(p)
    gaps = np.diff(np.concatenate([[-1], indices]))  # ≥ 1
    dm1 = gaps - 1
    q = dm1 >> bstar
    r = dm1 & ((1 << bstar) - 1) if bstar else np.zeros_like(dm1)

    lengths = q + 1 + bstar
    starts = np.concatenate([[0], np.cumsum(lengths[:-1])])
    total = int(starts[-1] + lengths[-1])
    out = np.zeros((total,), np.uint8)

    # unary prefixes: ones on [start, start+q) for every codeword
    if q.sum() > 0:
        ones_idx = np.repeat(starts, q) + _ragged_arange(q)
        out[ones_idx] = 1
    # binary remainders (big-endian), bit j of codeword i at start+q+1+j
    if bstar:
        shifts = np.arange(bstar - 1, -1, -1)
        bits = (r[:, None] >> shifts[None, :]) & 1  # (n, bstar)
        base = (starts + q + 1)[:, None] + np.arange(bstar)[None, :]
        out[base.reshape(-1)] = bits.astype(np.uint8).reshape(-1)
    return out


def _ragged_arange(counts: np.ndarray) -> np.ndarray:
    """concatenate([arange(c) for c in counts]) without a Python loop."""
    total = int(counts.sum())
    ends = np.cumsum(counts)
    out = np.arange(total)
    out -= np.repeat(ends - counts, counts)
    return out


def encode_positions_packed(indices: np.ndarray, p: float) -> tuple[bytes, int]:
    """Alg. 3 straight to transport form: (packed bytes, exact bit count).

    One whole-array encode + one ``np.packbits`` — no per-position Python
    round-trip, so ``Wire.pack`` can consume device output (a numpy view of
    the compressed indices) directly.  The bit count is pre-byte-padding,
    i.e. the number Eq. 1 meters.
    """
    bits = encode_positions(indices, p)
    if bits.size == 0:
        return b"", 0
    return np.packbits(bits).tobytes(), int(bits.size)


def packed_words_to_bytes(words: np.ndarray, nbits: int) -> bytes:
    """Device word buffer → transport bytes, byte-identical to
    :func:`encode_positions_packed`.

    The device packers (:mod:`repro.kernels.pack`) put stream bit ``b``
    in word ``b >> 5`` at bit position ``31 - (b & 31)``, so a
    big-endian byte view truncated to ``ceil(nbits/8)`` IS the
    ``np.packbits`` output — this is the whole device-to-bytes copy.
    """
    if nbits <= 0:
        return b""
    return np.ascontiguousarray(
        np.asarray(words, dtype=np.uint32)
    ).astype(">u4").tobytes()[: -(-int(nbits) // 8)]


def decode_positions(msg: np.ndarray, p: float) -> np.ndarray:
    """Alg. 4: decode a Golomb bitstream back to absolute positions.

    Per-codeword parse: a codeword starts with a unary run of ones, so the
    first 0 at/after the cursor is its terminator (zeros inside remainder
    fields are skipped, never scanned).  The remainder value after EVERY
    zero is precomputed with one vectorized matmul, so the sequential scan
    touches only Python ints + ``bisect`` — this is the parameter-server
    hot path (one decode per sparse leaf per client upload).
    """
    bstar = golomb_bstar(p)
    msg = np.asarray(msg, dtype=np.uint8)
    n = msg.shape[0]
    zeros = np.nonzero(msg == 0)[0]
    if zeros.size == 0:
        return np.zeros((0,), dtype=np.int64)
    if bstar:
        # remainder bits following each candidate terminator, vectorized
        idx = zeros[:, None] + 1 + np.arange(bstar)[None, :]
        bits = np.where(idx < n, msg[np.minimum(idx, n - 1)], 0)
        rems = (bits @ (1 << np.arange(bstar - 1, -1, -1))).tolist()
    else:
        rems = [0] * zeros.size
    zlist = zeros.tolist()
    nz = len(zlist)

    out: list[int] = []
    c, j, zi = 0, -1, 0
    while c < n:
        zi = bisect.bisect_left(zlist, c, zi)
        if zi >= nz:
            break  # trailing ones without terminator: not a codeword
        z = zlist[zi]
        if z + bstar >= n and bstar:
            # remainder field runs past the stream: truncated/corrupt buffer
            raise ValueError(
                f"truncated Golomb stream: codeword at bit {c} needs "
                f"{bstar} remainder bits past position {z}"
            )
        j = j + ((z - c) << bstar) + rems[zi] + 1
        out.append(j)
        c = z + 1 + bstar
    return np.asarray(out, dtype=np.int64)


# ------------------------------------------------- full-message wire format


def encode_sbc_message(indices: np.ndarray, mean: float, p: float) -> dict:
    """Wire form of one SBC-compressed tensor: Golomb positions + 1 float.

    Mirrors the paper's "positions + one mean value per tensor" message.
    """
    bits = encode_positions(indices, p)
    return {
        "positions": np.packbits(bits) if bits.size else np.zeros((0,), np.uint8),
        "nbits_positions": int(bits.size),
        "mean": float(mean),
        "p": float(p),
    }


def decode_sbc_message(msg: dict, n: int) -> np.ndarray:
    bits = np.unpackbits(msg["positions"])[: msg["nbits_positions"]]
    idx = decode_positions(bits, msg["p"])
    dense = np.zeros((n,), np.float32)
    dense[idx] = msg["mean"]
    return dense


def message_bits(msg: dict) -> int:
    """Total wire bits of one encoded tensor (positions + 32-bit mean)."""
    return msg["nbits_positions"] + 32
