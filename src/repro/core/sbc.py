"""Sparse Binary Compression — paper Alg. 2.

Per flattened tensor ΔW with sparsity rate p:

  1. val⁺ ← top_{p%}(ΔW),  val⁻ ← top_{p%}(−ΔW)
  2. μ⁺ ← mean(val⁺),  μ⁻ ← mean(val⁻)
  3. if μ⁺ > μ⁻:  ΔW* = μ⁺ at the positions of val⁺   (all else 0)
     else:        ΔW* = −μ⁻ at the positions of val⁻
  4. wire form: k positions (Golomb-coded, Eq. 5) + ONE 32-bit mean
     → 0 value bits per surviving entry.

Implementation note (recorded in DESIGN.md): the paper states step 3 as a
threshold mask ``ΔW ≥ min(val⁺)``; we keep the exact top-k *indices* instead,
which selects exactly k entries and is identical up to ties. This also makes
the wire form a fixed-shape (idx[k], mean) pair, which is what lets the
exchange lower to a small all-gather in XLA.

Error feedback (Eq. 2) is applied by :class:`repro.core.api.Compressor`.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import api
from repro.core.golomb import expected_position_bits


def sbc_compress_leaf(flat: jax.Array, p: float, rng: jax.Array) -> api.LeafCompressed:
    del rng  # deterministic
    n = flat.shape[0]
    k = api.k_for(n, p)

    val_pos, idx_pos = jax.lax.top_k(flat, k)
    val_neg, idx_neg = jax.lax.top_k(-flat, k)
    mu_pos = jnp.mean(val_pos)  # Alg. 2 l.4
    mu_neg = jnp.mean(val_neg)

    pos_wins = mu_pos > mu_neg  # Alg. 2 l.5
    idx = jnp.where(pos_wins, idx_pos, idx_neg).astype(jnp.int32)
    mean = jnp.where(pos_wins, mu_pos, -mu_neg).astype(jnp.float32)

    nbits = jnp.asarray(k * expected_position_bits(p) + 32.0, jnp.float32)
    return api.LeafCompressed(
        idx=idx,
        vals=jnp.zeros((0,), jnp.float32),
        mean=mean,
        dense=jnp.zeros((0,), jnp.float32),
        nbits=nbits,
    )


def sbc_decompress_leaf(comp: api.LeafCompressed, n: int) -> jax.Array:
    return jnp.zeros((n,), jnp.float32).at[comp.idx].set(comp.mean)


@api.register("sbc")
def make_sbc(**_: object) -> api.Compressor:
    return api.Compressor(
        name="sbc",
        compress_leaf=sbc_compress_leaf,
        decompress_leaf=sbc_decompress_leaf,
        use_residual=True,
        stochastic=False,
    )


# ------------------------------------------------------------------ presets
# The paper's three evaluated configurations (§IV-B): (delay n, sparsity p).
SBC_PRESETS: dict[str, tuple[int, float]] = {
    "sbc1": (1, 0.001),   # no delay, 0.1% gradient sparsity
    "sbc2": (10, 0.01),   # 10-step delay, 1% sparsity
    "sbc3": (100, 0.01),  # 100-step delay, 1% sparsity
}
