"""Sparse Binary Compression — paper Alg. 2 as a staged codec.

Per flattened tensor ΔW with sparsity rate p:

  1. val⁺ ← top_{p%}(ΔW),  val⁻ ← top_{p%}(−ΔW)
  2. μ⁺ ← mean(val⁺),  μ⁻ ← mean(val⁻)
  3. if μ⁺ > μ⁻:  ΔW* = μ⁺ at the positions of val⁺   (all else 0)
     else:        ΔW* = −μ⁻ at the positions of val⁻
  4. wire form: k positions (Golomb-coded, Eq. 5) + ONE 32-bit mean
     → 0 value bits per surviving entry.

In the stage pipeline that is exactly the composition

    topk_signed  →  binarize  →  golomb
    (steps 1,3)     (step 2)      (step 4)

so SBC is registered as that codec rather than a bespoke compressor; the
variants the §III trade-off grid needs (e.g. SBC values without
binarization, or bitmask positions at high p) are one stage swap away.

Implementation note (DESIGN.md §6): the paper states step 3 as a threshold
mask ``ΔW ≥ min(val⁺)``; we keep the exact top-k *indices* instead, which
selects exactly k entries and is identical up to ties.  This also makes
the wire form a fixed-shape (idx[k], mean) pair, which is what lets the
exchange lower to a small all-gather in XLA.

Error feedback (Eq. 2) is applied by the policy engine
(:meth:`repro.core.policy.ResolvedPolicy.compress`).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core import api
from repro.core.codec import Codec, register_codec
from repro.core.stages import (
    LeafCompressed,
    decompress_leaf,
    get_encoder,
    get_quantizer,
    get_selector,
)


@register_codec("sbc")
def make_sbc_codec(**_: object) -> Codec:
    return Codec(
        selector=get_selector("topk_signed"),
        quantizer=get_quantizer("binarize"),
        encoder=get_encoder("golomb"),
        use_residual=True,
    )


SBC_CODEC = make_sbc_codec()


# ------------------------------------------------------- seed-API functions
# Kept as the canonical single-tensor entry points (tests + quickstart).


def sbc_compress_leaf(
    flat: jax.Array, p: float, rng: Optional[jax.Array]
) -> LeafCompressed:
    return SBC_CODEC.compress_leaf(flat, p, rng)


def sbc_decompress_leaf(comp: LeafCompressed, n: int) -> jax.Array:
    return decompress_leaf(comp, n)


@api.register("sbc")
def make_sbc(**_: object) -> api.Compressor:
    return api.Compressor.from_codec("sbc", SBC_CODEC)


# ------------------------------------------------------------------ presets
# The paper's three evaluated configurations (§IV-B): (delay n, sparsity p).
SBC_PRESETS: dict = {
    "sbc1": (1, 0.001),   # no delay, 0.1% gradient sparsity
    "sbc2": (10, 0.01),   # 10-step delay, 1% sparsity
    "sbc3": (100, 0.01),  # 100-step delay, 1% sparsity
}
