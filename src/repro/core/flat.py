"""Device-resident flat-buffer compression fast path (DESIGN.md §10).

:class:`FlatParamSpace` flattens a parameter pytree ONCE into a single
contiguous block-padded f32 buffer with static per-leaf segment metadata
(offset, size, sparsity rate, survivor count) and then runs the whole
per-round compression as ONE cached jitted call, instead of the per-leaf
Python loop of jnp dispatches in :meth:`ResolvedPolicy.compress`.

Two engines share the layout:

``compress``   the *exact* engine — per-segment two-sided top-k selection
               (``lax.top_k`` on static segment slices), one fused scatter
               building ΔW* for every leaf at once, and a single flat
               residual update.  Output is **bit-identical** to the legacy
               per-leaf path: same LeafCompressed trees (same indices, same
               μ down to the sign of −0.0), same SBW1 bytes after
               ``Wire.pack``, same residuals.  This is what ``fast=True``
               policies dispatch to.

``compress_hist``  the *device* engine — the segment-aware Pallas kernels
               (:mod:`repro.kernels.flat`): two-pass histogram threshold
               selection, masked moments, fused binarize+residual, each
               launched ONCE over the flat buffer.  Approximate survivor
               counts (like :func:`repro.kernels.ops.sbc_compress_hist`,
               whose per-leaf semantics it reproduces); runs interpret-mode
               on CPU, ``interpret=False`` on TPU.

Layout contract (stable; documented in DESIGN.md §10):

  * leaf i's flat segment lives at ``[offset_i, offset_i + size_i)`` where
    ``offset_i`` is block-aligned (blocks of ``bm·lanes`` elements) and the
    tail up to the next block boundary is zero;
  * the error-feedback residual is stored IN THIS LAYOUT as one f32 array —
    compressor state never round-trips through the per-leaf pytree between
    rounds;
  * pytrees cross the boundary only at ``flatten``/``unflatten``.

The speedup is structural, not numeric: the eager per-leaf path (how
``fed.server.ParameterServer.broadcast`` turns around a round) pays one
dispatch per jnp op per leaf; the flat path pays one cached jitted call
for the whole parameter set.  ``benchmarks/compress_e2e.py`` measures both.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.golomb import golomb_bstar
from repro.core.stages import LeafCompressed, k_for
from repro.kernels.flat import seg_binarize_apply, seg_hist2side, seg_moments
from repro.kernels.hist2side import SPAN_OCTAVES, bucket_lower_edges
from repro.kernels.ops import _side_threshold, on_tpu
from repro.kernels.pack import (
    bits_from_positions,
    golomb_decode_rows,
    row_words,
    seg_packbits,
)

PyTree = Any


def _pad_maps(
    offsets: Sequence[int], sizes: Sequence[int], n_pad: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Padded-position → raw-concat position map + validity mask: turns
    flatten into ONE gather + ONE select instead of a pad+concat per
    segment (pad slots gather position 0 and are masked to zero)."""
    pad_to_raw = np.zeros((n_pad,), np.int32)
    pad_valid = np.zeros((n_pad,), bool)
    raw = 0
    for off, size in zip(offsets, sizes):
        pad_to_raw[off:off + size] = np.arange(raw, raw + size, dtype=np.int32)
        pad_valid[off:off + size] = True
        raw += size
    return pad_to_raw, pad_valid


def _flatten_padded(leaves, pad_to_raw, pad_valid, contiguous: bool) -> jax.Array:
    """Flatten ``leaves`` into the block-padded layout described by the
    maps of :func:`_pad_maps` (identical math to the original per-space
    flatten — shared by :class:`FlatParamSpace` and the sharded space)."""
    raw = [jnp.asarray(leaf).reshape(-1).astype(jnp.float32) for leaf in leaves]
    raw_flat = jnp.concatenate(raw) if len(raw) > 1 else raw[0]
    if contiguous:
        return raw_flat
    gathered = jnp.take(raw_flat, jnp.asarray(pad_to_raw), mode="clip")
    return jnp.where(jnp.asarray(pad_valid), gathered, 0.0)


def _hist_pipeline(
    acc_flat: jax.Array,
    bounds: Sequence[Tuple[int, int]],
    ks: Sequence[int],
    rates: Sequence[float],
    seg_of_block: np.ndarray,
    n_blocks: int,
    bm: int,
    lanes: int,
    nbins: int,
    interpret: bool,
) -> Tuple[jax.Array, jax.Array, dict]:
    """The three segment-aware Pallas passes over one flat buffer.

    ``bounds`` is the static per-segment ``(offset, size)`` table.  Shared
    by :meth:`FlatParamSpace.compress_hist` (per-leaf segments) and
    :meth:`ShardedFlatParamSpace.exchange_local_hist` (per-shard
    segments inside ``shard_map``); per-segment semantics match
    :func:`repro.kernels.ops.sbc_compress_hist` bit for bit at matching
    tiles.  Returns ``(delta_star_flat, residual_flat, stats)``.
    """
    from repro.core.golomb import expected_position_bits

    nseg = len(bounds)
    xpad = acc_flat.reshape(n_blocks * bm, lanes)
    sob = jnp.asarray(seg_of_block, jnp.float32)[:, None]

    # per-segment |x| range for the coarse pass (same rule as
    # ops.sbc_compress_hist; max is order-independent → exact)
    absmax = jnp.stack([
        jnp.max(jnp.abs(acc_flat[off:off + size])) for off, size in bounds
    ]) + 1e-30
    lo0 = absmax * 2.0 ** -SPAN_OCTAVES
    hi0 = absmax * 1.0001

    def block_params(*cols, seg: bool = True):
        rows = [c[seg_of_block][:, None] for c in cols]
        if seg:
            rows = [sob] + rows
        return jnp.concatenate(rows, axis=1)

    kf = jnp.asarray(ks, jnp.float32)
    vthresh = jax.vmap(_side_threshold)
    vedges = jax.vmap(lambda lo, hi: bucket_lower_edges(lo, hi, nbins))

    h1 = seg_hist2side(
        xpad, block_params(lo0, hi0, lo0, hi0), nseg=nseg, nbins=nbins,
        bm=bm, lanes=lanes, interpret=interpret,
    )
    edges0 = vedges(lo0, hi0)
    lo_p, hi_p, above_p = vthresh(h1[:, 0], edges0, kf)
    lo_n, hi_n, above_n = vthresh(h1[:, 1], edges0, kf)

    h2 = seg_hist2side(
        xpad, block_params(lo_p, hi_p, lo_n, hi_n), nseg=nseg, nbins=nbins,
        bm=bm, lanes=lanes, interpret=interpret,
    )
    t_pos, _, _ = vthresh(h2[:, 0], vedges(lo_p, hi_p), kf - above_p)
    t_neg, _, _ = vthresh(h2[:, 1], vedges(lo_n, hi_n), kf - above_n)

    mom = seg_moments(
        xpad, block_params(t_pos, t_neg), nseg=nseg,
        bm=bm, lanes=lanes, interpret=interpret,
    )
    mu_pos = mom[:, 0, 0] / jnp.maximum(mom[:, 0, 1], 1.0)
    mu_neg = -mom[:, 1, 0] / jnp.maximum(mom[:, 1, 1], 1.0)
    pos_wins = mu_pos > mu_neg
    mu = jnp.where(pos_wins, mu_pos, -mu_neg)
    count = jnp.where(pos_wins, mom[:, 0, 1], mom[:, 1, 1])

    out_pad, res_pad = seg_binarize_apply(
        xpad,
        block_params(t_pos, t_neg, mu, pos_wins.astype(jnp.float32),
                     seg=False),
        bm=bm, lanes=lanes, interpret=interpret,
    )
    ebits = jnp.asarray(
        [expected_position_bits(min(p, 1.0)) for p in rates], jnp.float32
    )
    stats = {"mu": mu, "count": count, "nbits": count * ebits + 32.0}
    return out_pad.reshape(-1), res_pad.reshape(-1), stats

def supports(resolved) -> bool:
    """True when every leaf of the resolved policy has a flat-fast codec
    (``Codec.flat_kind`` is not None for every plan)."""
    return all(p.codec.flat_kind is not None for p in resolved.plans)


class Segment(NamedTuple):
    """Static per-leaf slot in the flat buffer."""

    path: str
    shape: Tuple[int, ...]
    dtype: Any
    size: int
    offset: int  # block-aligned start in the padded flat buffer
    kind: str  # "sbc" | "dense" | "skip"
    use_residual: bool


@dataclasses.dataclass(eq=False)
class FlatParamSpace:
    """One policy bound to one pytree layout, flattened to a single buffer.

    Built lazily by :meth:`ResolvedPolicy.flat_space` the first time a
    ``fast=True`` policy compresses; construction needs only leaf shapes,
    so it works under tracing.  ``bm``/``lanes`` fix the block size of the
    padded layout (and the Pallas tile of the ``compress_hist`` engine) —
    they must match between the two engines because the residual buffer is
    shared.
    """

    resolved: Any  # ResolvedPolicy (duck-typed; no import cycle)
    segments: Tuple[Segment, ...]
    bm: int = 8
    lanes: int = 128

    def __post_init__(self) -> None:
        per_block = self.bm * self.lanes
        self.n_blocks = sum(
            max(1, -(-s.size // per_block)) for s in self.segments
        )
        self.n_pad = self.n_blocks * per_block
        self.n_total = sum(s.size for s in self.segments)
        # static per-block segment ids (one leaf per block, by construction)
        seg_of_block = np.zeros((self.n_blocks,), np.int32)
        res_mask = np.zeros((self.n_pad,), bool)
        dense_mask = np.zeros((self.n_pad,), bool)
        for i, s in enumerate(self.segments):
            blk0 = s.offset // per_block
            nblk = max(1, -(-s.size // per_block))
            seg_of_block[blk0:blk0 + nblk] = i
            if s.use_residual:
                res_mask[s.offset:s.offset + s.size] = True
            if s.kind == "dense":
                dense_mask[s.offset:s.offset + s.size] = True
        self.seg_of_block = seg_of_block
        self._res_mask = res_mask
        self._dense_mask = dense_mask
        self._pad_to_raw, self._pad_valid = _pad_maps(
            [s.offset for s in self.segments],
            [s.size for s in self.segments],
            self.n_pad,
        )
        # pad slots self-maintain zeros under acc/dense/residual updates, so
        # the mask-free fast branch only needs every LEAF to use residuals
        self._all_residual = all(s.use_residual for s in self.segments)
        self._jitted: Dict[tuple, Any] = {}

    # ------------------------------------------------------------- building

    @classmethod
    def for_resolved(
        cls, resolved, like: PyTree, *, bm: int = 8, lanes: int = 128
    ) -> "FlatParamSpace":
        """Bind ``resolved`` to the concrete leaf shapes of ``like``."""
        leaves = resolved._leaves_of(like)
        per_block = bm * lanes
        segs: List[Segment] = []
        off = 0
        for plan, leaf in zip(resolved.plans, leaves):
            kind = plan.codec.flat_kind
            if kind is None:
                raise ValueError(
                    f"leaf {plan.path!r} codec {plan.codec.spec!r} has no "
                    "flat fast path; guard with repro.core.flat.supports()"
                )
            shape = tuple(getattr(leaf, "shape", np.shape(leaf)))
            size = int(np.prod(shape)) if shape else 1
            segs.append(Segment(
                path=plan.path, shape=shape, dtype=leaf.dtype, size=size,
                offset=off, kind=kind, use_residual=plan.codec.use_residual,
            ))
            off += max(1, -(-size // per_block)) * per_block
        return cls(resolved=resolved, segments=tuple(segs), bm=bm, lanes=lanes)

    # --------------------------------------------------------- flat plumbing

    def flatten(self, tree: PyTree) -> jax.Array:
        """Pytree → one block-padded f32 buffer (the §10 layout)."""
        return self._flatten_leaves(self.resolved._leaves_of(tree))

    def _flatten_leaves(self, leaves) -> jax.Array:
        return _flatten_padded(
            leaves, self._pad_to_raw, self._pad_valid,
            contiguous=self.n_pad == self.n_total,
        )

    def unflatten(self, flat: jax.Array, cast: bool = True) -> PyTree:
        """Flat buffer → pytree (inverse of :meth:`flatten`)."""
        out = []
        for seg in self.segments:
            piece = flat[seg.offset:seg.offset + seg.size].reshape(seg.shape)
            out.append(piece.astype(seg.dtype) if cast else piece)
        return jax.tree.unflatten(self.resolved.treedef, out)

    def zeros_residual(self) -> jax.Array:
        return jnp.zeros((self.n_pad,), jnp.float32)

    def _check_rates(self, rates) -> Tuple[float, ...]:
        if not isinstance(rates, tuple):
            rates = (float(rates),) * len(self.segments)
        if len(rates) != len(self.segments):
            raise ValueError(
                f"got {len(rates)} rates for {len(self.segments)} leaves"
            )
        return tuple(float(r) for r in rates)

    def _ks(self, rates: Tuple[float, ...]) -> Tuple[int, ...]:
        return tuple(
            0 if s.kind == "skip"
            else s.size if s.kind == "dense"
            else k_for(s.size, p)
            for s, p in zip(self.segments, rates)
        )

    # ------------------------------------------------------------ exact path

    def compress(self, delta: PyTree, state, rates) -> tuple:
        """Drop-in, bit-identical replacement for the per-leaf
        ``ResolvedPolicy.compress`` — same (ctree, dense_tree, new_state)
        contract, with ``new_state.residual`` kept in the flat layout."""
        rates = self._check_rates(rates)
        fn = self._jitted.get(("exact", rates))
        if fn is None:
            fn = jax.jit(lambda leaves, res, rng:
                         self._compress_exact(leaves, res, rng, rates))
            self._jitted[("exact", rates)] = fn
        leaves = self.resolved._leaves_of(delta)
        residual = state.residual if self.resolved.any_residual else None
        ctree_leaves, dense_leaves, new_res, next_rng = fn(
            leaves, residual, state.rng
        )
        new_state = state._replace(
            residual=new_res if new_res is not None else state.residual,
            rng=next_rng,
            step=state.step + 1,
        )
        return (
            jax.tree.unflatten(self.resolved.treedef, ctree_leaves),
            jax.tree.unflatten(self.resolved.treedef, dense_leaves),
            new_state,
        )

    def _compress_exact(self, leaves, residual, rng, rates):
        segs, ks = self.segments, self._ks(rates)
        # residual-accumulate in ONE flat op (Eq. 2 gather phase)
        delta_flat = self._flatten_leaves(leaves)
        if residual is None:
            acc_flat = delta_flat
        elif self._all_residual:
            acc_flat = delta_flat + residual
        else:
            acc_flat = delta_flat + jnp.where(
                jnp.asarray(self._res_mask), residual, 0.0
            )

        # per-segment exact two-sided top-k (paper Alg. 2 l.1-5).  The
        # selection math is identical to the topk_signed selector, so idx,
        # μ, and the pos/neg side decision match the legacy path bit for bit.
        comp_leaves: List[Optional[LeafCompressed]] = [None] * len(segs)
        gidx, gmu = [], []
        for i, (seg, k, p) in enumerate(zip(segs, ks, rates)):
            acc = acc_flat[seg.offset:seg.offset + seg.size]
            if seg.kind == "skip":
                comp_leaves[i] = LeafCompressed(
                    idx=jnp.zeros((0,), jnp.int32),
                    vals=jnp.zeros((0,), jnp.float32),
                    mean=jnp.zeros((), jnp.float32),
                    dense=jnp.zeros((0,), jnp.float32),
                    nbits=jnp.zeros((), jnp.float32),
                )
                continue
            if seg.kind == "dense":
                codec = self.resolved.plans[i].codec
                comp_leaves[i] = LeafCompressed(
                    idx=jnp.zeros((0,), jnp.int32),
                    vals=jnp.zeros((0,), jnp.float32),
                    mean=jnp.zeros((), jnp.float32),
                    dense=acc,
                    nbits=jnp.asarray(codec.quantizer.value_bits(k), jnp.float32),
                )
                continue
            val_pos, idx_pos = jax.lax.top_k(acc, k)
            val_neg, idx_neg = jax.lax.top_k(-acc, k)
            pos_wins = jnp.mean(val_pos) > jnp.mean(val_neg)
            idx = jnp.where(pos_wins, idx_pos, idx_neg).astype(jnp.int32)
            # μ re-gathers the winning side's ORIGINAL values, exactly like
            # the topk_signed selector + binarize quantizer composition —
            # down to the sign of −0.0 on an all-zero leaf
            mu = jnp.mean(acc[idx])
            codec = self.resolved.plans[i].codec
            nbits = (codec.encoder.position_bits(seg.size, k, p)
                     + codec.quantizer.value_bits(k))
            comp_leaves[i] = LeafCompressed(
                idx=idx,
                vals=jnp.zeros((0,), jnp.float32),
                mean=mu.astype(jnp.float32),
                dense=jnp.zeros((0,), jnp.float32),
                nbits=jnp.asarray(nbits, jnp.float32),
            )
            gidx.append(idx + seg.offset)
            gmu.append(jnp.broadcast_to(mu, (k,)))

        # ΔW* for EVERY sparse leaf in one fused scatter; dense segments
        # pass their acc through via ONE static-mask select (not a chain of
        # per-leaf update-slices); skip segments stay zero.
        dense_flat = jnp.zeros((self.n_pad,), jnp.float32)
        if gidx:
            dense_flat = dense_flat.at[jnp.concatenate(gidx)].set(
                jnp.concatenate(gmu)
            )
        if self._dense_mask.any():
            dense_flat = jnp.where(
                jnp.asarray(self._dense_mask), acc_flat, dense_flat
            )

        # single flat residual update (Eq. 2 scatter phase)
        new_res = None
        if residual is not None:
            if self._all_residual:
                new_res = acc_flat - dense_flat
            else:
                new_res = jnp.where(
                    jnp.asarray(self._res_mask), acc_flat - dense_flat, residual
                )

        dense_leaves = [
            dense_flat[s.offset:s.offset + s.size].reshape(s.shape).astype(s.dtype)
            for s in segs
        ]
        # advance the RNG exactly like the per-leaf path (one split per
        # leaf + carry), so fast/legacy state trajectories stay identical
        next_rng = jax.random.split(rng, len(segs) + 1)[0]
        return comp_leaves, dense_leaves, new_res, next_rng

    # ----------------------------------------------------------- hist engine

    def compress_hist(
        self,
        delta: PyTree,
        state,
        rates,
        *,
        nbins: int = 128,
        interpret: Optional[bool] = None,
    ) -> tuple:
        """Histogram-threshold SBC over the flat buffer — the Pallas engine.

        Per-segment semantics match :func:`repro.kernels.ops.sbc_compress_hist`
        (approximate survivor counts; exact residual identity acc = ΔW* + R),
        but the three passes launch ONCE each over the whole parameter set.
        Requires an all-"sbc" policy.  Returns ``(dense_tree, new_state,
        stats)`` with per-segment ``stats = {mu, count, nbits}``.
        """
        if any(s.kind != "sbc" for s in self.segments):
            raise ValueError(
                "compress_hist needs an all-SBC policy; dense/skip leaves "
                "belong to the exact engine"
            )
        rates = self._check_rates(rates)
        if interpret is None:
            interpret = not on_tpu()
        key = ("hist", rates, nbins, bool(interpret))
        fn = self._jitted.get(key)
        if fn is None:
            fn = jax.jit(lambda leaves, res: self._compress_hist(
                leaves, res, rates, nbins, interpret))
            self._jitted[key] = fn
        leaves = self.resolved._leaves_of(delta)
        residual = state.residual if self.resolved.any_residual else None
        dense_flat, new_res, stats = fn(leaves, residual)
        new_state = state._replace(
            residual=new_res if new_res is not None else state.residual,
            rng=jax.random.split(state.rng, len(self.segments) + 1)[0],
            step=state.step + 1,
        )
        return self.unflatten(dense_flat), new_state, stats

    def _compress_hist(self, leaves, residual, rates, nbins, interpret):
        delta_flat = self._flatten_leaves(leaves)
        acc_flat = delta_flat if residual is None else delta_flat + residual
        dense_flat, res_flat, stats = _hist_pipeline(
            acc_flat,
            bounds=[(s.offset, s.size) for s in self.segments],
            ks=self._ks(rates),
            rates=rates,
            seg_of_block=self.seg_of_block,
            n_blocks=self.n_blocks,
            bm=self.bm,
            lanes=self.lanes,
            nbins=nbins,
            interpret=interpret,
        )
        new_res = res_flat if residual is not None else None
        return dense_flat, new_res, stats


# ===================================================================== sharded


class DistSegment(NamedTuple):
    """Static per-(leaf, shard) slot in the per-device local flat buffer.

    ``shape`` is the LOCAL body shape of one shard of the leaf (no client
    dim); replicated leaves carry their full shape on every shard.  The
    per-row survivor count ``k`` uses the dist backend's rule
    ``max(1, min(n_loc, round(p · n_loc)))`` so selection matches the
    per-leaf ``_sbc_local`` exchange bit for bit.
    """

    path: str
    shape: Tuple[int, ...]  # local body shape (one shard)
    rows: int  # L (scan superblock dim; 1 for unscanned leaves)
    n_loc: int  # per-row local length
    offset: int  # block-aligned start in the local flat buffer
    kind: str  # "sparse" | "dense" | "skip"
    rate: float  # per-leaf sparsity rate (static)
    k: int  # per-row survivors (0 for dense/skip)
    n_shards: int  # distinct shards of the GLOBAL leaf (for Eq. 1 bits)
    global_size: int


@dataclasses.dataclass(eq=False)
class ShardedFlatParamSpace:
    """The §11 sharded twin of :class:`FlatParamSpace` (DESIGN.md §11).

    One per-DEVICE block-padded flat buffer holding every local leaf
    shard; the global residual/acc buffer has shape
    ``(n_clients, shards_per_client, n_pad)`` and carries a
    ``NamedSharding`` of ``P(client_axes, shard_axes, None)`` over the
    mesh, so each device owns exactly its ``(1, 1, n_pad)`` slice.  All
    ``exchange_local*`` methods are meant to run INSIDE ``shard_map``:
    each device compresses its own shard of the one flat buffer and the
    exchange is one ``all_gather`` of packed (positions, μ) flat
    segments — not per-leaf collectives.

    Selection/aggregation math mirrors the per-leaf ``_sbc_local`` /
    ``_dense_local`` shard_map kernels of ``repro.launch.dist`` exactly
    (same per-row top-k, same client-order scatter accumulation, same
    sequential per-axis collectives), so the aggregated update, the
    residual, and the Eq. 1/Eq. 5 bit counts are bit-identical to the
    per-leaf path.
    """

    segments: Tuple[DistSegment, ...]
    client_axes: Tuple[str, ...]
    shard_axes: Tuple[str, ...]
    n_clients: int
    shards_per_client: int
    bm: int = 8
    lanes: int = 128

    def __post_init__(self) -> None:
        per_block = self.bm * self.lanes
        sizes = [s.rows * s.n_loc for s in self.segments]
        self.n_blocks = sum(max(1, -(-sz // per_block)) for sz in sizes)
        self.n_pad = self.n_blocks * per_block
        self.n_total = sum(sizes)
        seg_of_block = np.zeros((self.n_blocks,), np.int32)
        dense_mask = np.zeros((self.n_pad,), bool)
        for i, (s, sz) in enumerate(zip(self.segments, sizes)):
            blk0 = s.offset // per_block
            nblk = max(1, -(-sz // per_block))
            seg_of_block[blk0:blk0 + nblk] = i
            if s.kind == "dense":
                dense_mask[s.offset:s.offset + sz] = True
        self.seg_of_block = seg_of_block
        self._pad_to_raw, self._pad_valid = _pad_maps(
            [s.offset for s in self.segments], sizes, self.n_pad
        )
        self._dense_idx = np.flatnonzero(dense_mask).astype(np.int32)
        # static maps for the packed sparse exchange: every (row, k-slot)
        # of every sparse segment gets one position slot; ``_pos_row``
        # maps it to its row's slot in the packed μ stream
        self._sparse = tuple(s for s in self.segments if s.kind == "sparse")
        pos_row: List[np.ndarray] = []
        mu_slot = 0
        for s in self._sparse:
            pos_row.append(
                np.repeat(np.arange(mu_slot, mu_slot + s.rows, dtype=np.int32),
                          s.k)
            )
            mu_slot += s.rows
        self.n_mu = mu_slot
        self._pos_row = (
            np.concatenate(pos_row) if pos_row else np.zeros((0,), np.int32)
        )
        self.n_pos = int(self._pos_row.shape[0])
        # device-pack layout: one packed uint32 Golomb stream per
        # (segment, row), capacity-padded to whole words so the
        # concatenated word buffer — and every row's slice of it — is
        # static.  ``(b*, words/row, word offset)`` per sparse segment.
        winfo: List[Tuple[int, int, int]] = []
        woff = 0
        for s in self._sparse:
            b = golomb_bstar(s.rate)
            w = row_words(s.n_loc, s.k, b)
            winfo.append((b, w, woff))
            woff += s.rows * w
        self._pack_info = tuple(winfo)
        self.n_pack_words = woff

    # ------------------------------------------------------------- building

    @classmethod
    def build(
        cls,
        entries: Sequence[dict],
        *,
        client_axes: Tuple[str, ...],
        shard_axes: Tuple[str, ...],
        n_clients: int,
        shards_per_client: int,
        bm: int = 8,
        lanes: int = 128,
    ) -> "ShardedFlatParamSpace":
        """``entries``: per-leaf dicts with keys ``path``, ``shape``
        (local body shape), ``rows``, ``kind``, ``rate``, ``n_shards``,
        ``global_size`` (plain data — the launch layer computes local
        shapes from the mesh + PartitionSpecs, core stays mesh-free)."""
        per_block = bm * lanes
        segs: List[DistSegment] = []
        off = 0
        for e in entries:
            size = int(np.prod(e["shape"])) if e["shape"] else 1
            rows = int(e["rows"])
            n_loc = size // rows
            k = (
                max(1, min(n_loc, int(round(e["rate"] * n_loc))))
                if e["kind"] == "sparse" else 0
            )
            segs.append(DistSegment(
                path=e["path"], shape=tuple(e["shape"]), rows=rows,
                n_loc=n_loc, offset=off, kind=e["kind"],
                rate=float(e["rate"]), k=k, n_shards=int(e["n_shards"]),
                global_size=int(e["global_size"]),
            ))
            off += max(1, -(-size // per_block)) * per_block
        return cls(
            segments=tuple(segs), client_axes=tuple(client_axes),
            shard_axes=tuple(shard_axes), n_clients=int(n_clients),
            shards_per_client=int(shards_per_client), bm=bm, lanes=lanes,
        )

    # --------------------------------------------------------- flat plumbing

    def flatten_local(self, bodies) -> jax.Array:
        """Local leaf shards (in segment order) → one local flat buffer."""
        return _flatten_padded(
            bodies, self._pad_to_raw, self._pad_valid,
            contiguous=self.n_pad == self.n_total,
        )

    def unflatten_local(self, flat: jax.Array) -> List[jax.Array]:
        """Local flat buffer → list of local body arrays (segment order)."""
        return [
            flat[s.offset:s.offset + s.rows * s.n_loc].reshape(s.shape)
            for s in self.segments
        ]

    def zeros_residual(self) -> jax.Array:
        """The flat sharded error-feedback state (host-side layout)."""
        return jnp.zeros(
            (self.n_clients, self.shards_per_client, self.n_pad), jnp.float32
        )

    # ------------------------------------------------------- bit accounting

    def bits_per_client(self) -> float:
        """Static Eq. 1 wire bits per client per round, summed over the
        per-(segment, shard) counts: sparse segments pay
        ``rows · n_shards · (k · b̄_pos(p) + 32)`` (Eq. 5 Golomb positions
        + one 32-bit μ per (row, shard)), dense segments 32 bits/entry,
        skipped segments 0 — the same totals as the per-leaf loop."""
        from repro.core.golomb import expected_position_bits

        total = 0.0
        for s in self.segments:
            if s.kind == "sparse":
                total += s.rows * s.n_shards * (
                    s.k * expected_position_bits(s.rate) + 32.0
                )
            elif s.kind == "dense":
                total += 32.0 * s.global_size
        return total

    # ------------------------------------------------------- exact exchange

    def exchange_local(
        self,
        bodies,
        res_flat: Optional[jax.Array],
        *,
        device_pack: bool = False,
        interpret: Optional[bool] = None,
    ) -> tuple:
        """Inside shard_map: compress this device's shard of every leaf
        and exchange.  Returns ``(mean_flat, own_flat, new_res_flat)`` —
        the aggregated update, this client's ΔW*, and the new residual,
        all in the local flat layout.

        Per-(segment, shard, row) exact two-sided top-k (paper Alg. 2,
        identical math to ``_sbc_local``); THE exchange is one
        ``all_gather`` of the packed global positions + one of the packed
        μ stream per client axis, followed by one fused scatter per
        client (scanned in client order, so float accumulation matches
        the per-leaf path bit for bit).  Dense segments ride one
        ``pmean`` of the packed dense slice; skip segments move nothing
        and keep their full update in the residual.

        ``device_pack=True`` replaces the position gather with the wire
        form itself: every (segment, row)'s surviving positions are
        Golomb-packed on-device into ``uint32`` words (one
        :func:`~repro.kernels.pack.seg_packbits` launch over the whole
        local stream), the all_gather moves those word buffers
        (≈ b̄(p) bits/position instead of 32), and receivers recover
        positions with the pointer-doubling device decoder.  Returns two
        extra outputs ``(words u32[n_pack_words], nbits i32[n_mu])`` —
        this shard's packed streams + exact per-row bit counts, which
        are byte-identical to the host ``encode_positions_packed`` and
        feed the per-client wire metering.  The aggregated update,
        residual, and ΔW* are bit-identical to ``device_pack=False``.
        """
        acc = self.flatten_local(bodies)
        if res_flat is not None:
            acc = res_flat + acc

        pos_parts, mu_parts, idx_parts = [], [], []
        for s in self._sparse:
            x = acc[s.offset:s.offset + s.rows * s.n_loc].reshape(
                s.rows, s.n_loc
            )
            k = s.k

            def one_layer(_, x_row, k=k):
                val_pos, idx_pos = jax.lax.top_k(x_row, k)
                val_neg, idx_neg = jax.lax.top_k(-x_row, k)
                mu_pos, mu_neg = jnp.mean(val_pos), jnp.mean(val_neg)
                pos_wins = mu_pos > mu_neg
                idx = jnp.where(pos_wins, idx_pos, idx_neg).astype(jnp.int32)
                mu = jnp.where(pos_wins, mu_pos, -mu_neg).astype(jnp.float32)
                return None, (idx, mu)

            _, (idx, mu) = jax.lax.scan(one_layer, None, x)
            base = s.offset + np.arange(s.rows, dtype=np.int32) * s.n_loc
            pos_parts.append((idx + jnp.asarray(base)[:, None]).reshape(-1))
            mu_parts.append(mu)
            idx_parts.append(idx)

        own = jnp.zeros((self.n_pad,), jnp.float32)
        if pos_parts:
            pos = jnp.concatenate(pos_parts)
            mu = jnp.concatenate(mu_parts)
            pos_row = jnp.asarray(self._pos_row)
            own = own.at[pos].set(jnp.take(mu, pos_row))
        if self._dense_idx.size:
            dense_idx = jnp.asarray(self._dense_idx)
            dvals = acc[dense_idx]
            own = own.at[dense_idx].set(dvals)

        words = nbits = None
        if device_pack:
            if interpret is None:
                interpret = not on_tpu()
            words, nbits = self._pack_local(idx_parts, interpret)

        if self.client_axes and self.n_clients > 1 and pos_parts:
            # THE exchange: the packed (positions, μ) streams cross the
            # client axes once, not once per leaf.  With device_pack the
            # position stream IS the wire form — packed uint32 Golomb
            # word buffers (≈ b̄(p) bits/position) instead of raw 32-bit
            # index arrays.
            gsrc = words if device_pack else pos
            gmu = mu
            for ax in self.client_axes:
                gsrc = jax.lax.all_gather(gsrc, ax)
                gmu = jax.lax.all_gather(gmu, ax)
            gmu = gmu.reshape(self.n_clients, self.n_mu)
            if device_pack:
                gpos = self._decode_gathered(
                    gsrc.reshape(self.n_clients, self.n_pack_words)
                )
            else:
                gpos = gsrc.reshape(self.n_clients, self.n_pos)

            def add_client(buf, ci):
                vals = jnp.take(gmu[ci], pos_row) / self.n_clients
                return buf.at[gpos[ci]].add(vals), None

            mean, _ = jax.lax.scan(
                add_client, jnp.zeros((self.n_pad,), jnp.float32),
                jnp.arange(self.n_clients),
            )
        else:
            mean = own
        if self._dense_idx.size and self.client_axes:
            dv = dvals
            for ax in self.client_axes:
                dv = jax.lax.pmean(dv, ax)
            mean = mean.at[dense_idx].set(dv)

        new_res = acc - own if res_flat is not None else None
        if device_pack:
            return mean, own, new_res, words, nbits
        return mean, own, new_res

    # ------------------------------------------------- device wire packing

    def _pack_local(self, idx_parts: List[jax.Array], interpret: bool) -> tuple:
        """This shard's survivors → (packed u32 words, per-row bit counts).

        Builds every (segment, row)'s Golomb bit stream at its static
        offset in one concatenated bit buffer, then folds bits into
        ``uint32`` words with ONE ``seg_packbits`` launch over the whole
        flat set — the wire bytes for this shard, produced on-device.
        """
        if not idx_parts:
            return (jnp.zeros((0,), jnp.uint32), jnp.zeros((0,), jnp.int32))
        chunks, nb_parts = [], []
        for s, (b, w, _), idx_s in zip(self._sparse, self._pack_info, idx_parts):
            bits_s, nb_s = jax.vmap(
                lambda p, b=b, cap=32 * w: bits_from_positions(
                    p, bstar=b, cap32=cap
                )
            )(jnp.sort(idx_s, axis=1))
            chunks.append(bits_s.reshape(-1))
            nb_parts.append(nb_s)
        allbits = jnp.concatenate(chunks)
        pad = -allbits.shape[0] % (32 * self.lanes)
        if pad:
            allbits = jnp.concatenate(
                [allbits, jnp.zeros((pad,), allbits.dtype)]
            )
        planes = allbits.reshape(-1, 32).T
        words = seg_packbits(planes, lanes=self.lanes, interpret=interpret)
        return words[: self.n_pack_words], jnp.concatenate(nb_parts)

    def _decode_gathered(self, gw: jax.Array) -> jax.Array:
        """Gathered word buffers u32[C, n_pack_words] → global positions
        i32[C, n_pos] via the pointer-doubling Golomb decoder, segment by
        segment (each has its own static k, b*, and row stride)."""
        gpos_parts = []
        for s, (b, w, off) in zip(self._sparse, self._pack_info):
            seg_w = gw[:, off:off + s.rows * w].reshape(
                self.n_clients, s.rows, w
            )
            ploc = golomb_decode_rows(seg_w, k=s.k, bstar=b)
            base = s.offset + np.arange(s.rows, dtype=np.int32) * s.n_loc
            gpos_parts.append(
                (ploc + jnp.asarray(base)[None, :, None]).reshape(
                    self.n_clients, -1
                )
            )
        return jnp.concatenate(gpos_parts, axis=1)

    # -------------------------------------------------------- hist exchange

    def exchange_local_hist(
        self,
        bodies,
        res_flat: Optional[jax.Array],
        *,
        nbins: int = 128,
        interpret: Optional[bool] = None,
    ) -> tuple:
        """Inside shard_map: the segment-aware Pallas passes
        (:mod:`repro.kernels.flat`) over this device's local flat buffer
        — one launch per pass per device, per-(segment, shard) μ±.

        Approximate survivor counts (histogram thresholds, like
        ``ops.sbc_compress_hist``); the exchange is a ``pmean`` of the
        binarized ΔW* over the client axes (no packed positions stream —
        that needs the exact engine).  Requires an all-sparse policy.
        """
        if any(s.kind != "sparse" for s in self.segments):
            raise ValueError(
                "exchange_local_hist needs an all-SBC policy; dense/skip "
                "leaves belong to the exact engine"
            )
        if interpret is None:
            interpret = not on_tpu()
        acc = self.flatten_local(bodies)
        if res_flat is not None:
            acc = res_flat + acc
        own, res, _stats = _hist_pipeline(
            acc,
            bounds=[(s.offset, s.rows * s.n_loc) for s in self.segments],
            ks=[k_for(s.rows * s.n_loc, s.rate) for s in self.segments],
            rates=[s.rate for s in self.segments],
            seg_of_block=self.seg_of_block,
            n_blocks=self.n_blocks,
            bm=self.bm,
            lanes=self.lanes,
            nbins=nbins,
            interpret=interpret,
        )
        mean = own
        for ax in self.client_axes:
            mean = jax.lax.pmean(mean, ax)
        new_res = res if res_flat is not None else None
        return mean, own, new_res
