"""Device-resident flat-buffer compression fast path (DESIGN.md §10).

:class:`FlatParamSpace` flattens a parameter pytree ONCE into a single
contiguous block-padded f32 buffer with static per-leaf segment metadata
(offset, size, sparsity rate, survivor count) and then runs the whole
per-round compression as ONE cached jitted call, instead of the per-leaf
Python loop of jnp dispatches in :meth:`ResolvedPolicy.compress`.

Two engines share the layout:

``compress``   the *exact* engine — per-segment two-sided top-k selection
               (``lax.top_k`` on static segment slices), one fused scatter
               building ΔW* for every leaf at once, and a single flat
               residual update.  Output is **bit-identical** to the legacy
               per-leaf path: same LeafCompressed trees (same indices, same
               μ down to the sign of −0.0), same SBW1 bytes after
               ``Wire.pack``, same residuals.  This is what ``fast=True``
               policies dispatch to.

``compress_hist``  the *device* engine — the segment-aware Pallas kernels
               (:mod:`repro.kernels.flat`): two-pass histogram threshold
               selection, masked moments, fused binarize+residual, each
               launched ONCE over the flat buffer.  Approximate survivor
               counts (like :func:`repro.kernels.ops.sbc_compress_hist`,
               whose per-leaf semantics it reproduces); runs interpret-mode
               on CPU, ``interpret=False`` on TPU.

Layout contract (stable; documented in DESIGN.md §10):

  * leaf i's flat segment lives at ``[offset_i, offset_i + size_i)`` where
    ``offset_i`` is block-aligned (blocks of ``bm·lanes`` elements) and the
    tail up to the next block boundary is zero;
  * the error-feedback residual is stored IN THIS LAYOUT as one f32 array —
    compressor state never round-trips through the per-leaf pytree between
    rounds;
  * pytrees cross the boundary only at ``flatten``/``unflatten``.

The speedup is structural, not numeric: the eager per-leaf path (how
``fed.server.ParameterServer.broadcast`` turns around a round) pays one
dispatch per jnp op per leaf; the flat path pays one cached jitted call
for the whole parameter set.  ``benchmarks/compress_e2e.py`` measures both.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stages import LeafCompressed, k_for
from repro.kernels.flat import seg_binarize_apply, seg_hist2side, seg_moments
from repro.kernels.hist2side import SPAN_OCTAVES, bucket_lower_edges
from repro.kernels.ops import _side_threshold, on_tpu

PyTree = Any

def supports(resolved) -> bool:
    """True when every leaf of the resolved policy has a flat-fast codec
    (``Codec.flat_kind`` is not None for every plan)."""
    return all(p.codec.flat_kind is not None for p in resolved.plans)


class Segment(NamedTuple):
    """Static per-leaf slot in the flat buffer."""

    path: str
    shape: Tuple[int, ...]
    dtype: Any
    size: int
    offset: int  # block-aligned start in the padded flat buffer
    kind: str  # "sbc" | "dense" | "skip"
    use_residual: bool


@dataclasses.dataclass(eq=False)
class FlatParamSpace:
    """One policy bound to one pytree layout, flattened to a single buffer.

    Built lazily by :meth:`ResolvedPolicy.flat_space` the first time a
    ``fast=True`` policy compresses; construction needs only leaf shapes,
    so it works under tracing.  ``bm``/``lanes`` fix the block size of the
    padded layout (and the Pallas tile of the ``compress_hist`` engine) —
    they must match between the two engines because the residual buffer is
    shared.
    """

    resolved: Any  # ResolvedPolicy (duck-typed; no import cycle)
    segments: Tuple[Segment, ...]
    bm: int = 8
    lanes: int = 128

    def __post_init__(self) -> None:
        per_block = self.bm * self.lanes
        self.n_blocks = sum(
            max(1, -(-s.size // per_block)) for s in self.segments
        )
        self.n_pad = self.n_blocks * per_block
        self.n_total = sum(s.size for s in self.segments)
        # static per-block segment ids (one leaf per block, by construction)
        seg_of_block = np.zeros((self.n_blocks,), np.int32)
        res_mask = np.zeros((self.n_pad,), bool)
        dense_mask = np.zeros((self.n_pad,), bool)
        for i, s in enumerate(self.segments):
            blk0 = s.offset // per_block
            nblk = max(1, -(-s.size // per_block))
            seg_of_block[blk0:blk0 + nblk] = i
            if s.use_residual:
                res_mask[s.offset:s.offset + s.size] = True
            if s.kind == "dense":
                dense_mask[s.offset:s.offset + s.size] = True
        self.seg_of_block = seg_of_block
        self._res_mask = res_mask
        self._dense_mask = dense_mask
        # padded-position → raw-concat position map + validity mask: turns
        # flatten into ONE gather + ONE select instead of a pad+concat per
        # leaf (pad slots gather position 0 and are masked to zero)
        pad_to_raw = np.zeros((self.n_pad,), np.int32)
        pad_valid = np.zeros((self.n_pad,), bool)
        raw = 0
        for s in self.segments:
            pad_to_raw[s.offset:s.offset + s.size] = np.arange(
                raw, raw + s.size, dtype=np.int32
            )
            pad_valid[s.offset:s.offset + s.size] = True
            raw += s.size
        self._pad_to_raw = pad_to_raw
        self._pad_valid = pad_valid
        # pad slots self-maintain zeros under acc/dense/residual updates, so
        # the mask-free fast branch only needs every LEAF to use residuals
        self._all_residual = all(s.use_residual for s in self.segments)
        self._jitted: Dict[tuple, Any] = {}

    # ------------------------------------------------------------- building

    @classmethod
    def for_resolved(
        cls, resolved, like: PyTree, *, bm: int = 8, lanes: int = 128
    ) -> "FlatParamSpace":
        """Bind ``resolved`` to the concrete leaf shapes of ``like``."""
        leaves = resolved._leaves_of(like)
        per_block = bm * lanes
        segs: List[Segment] = []
        off = 0
        for plan, leaf in zip(resolved.plans, leaves):
            kind = plan.codec.flat_kind
            if kind is None:
                raise ValueError(
                    f"leaf {plan.path!r} codec {plan.codec.spec!r} has no "
                    "flat fast path; guard with repro.core.flat.supports()"
                )
            shape = tuple(getattr(leaf, "shape", np.shape(leaf)))
            size = int(np.prod(shape)) if shape else 1
            segs.append(Segment(
                path=plan.path, shape=shape, dtype=leaf.dtype, size=size,
                offset=off, kind=kind, use_residual=plan.codec.use_residual,
            ))
            off += max(1, -(-size // per_block)) * per_block
        return cls(resolved=resolved, segments=tuple(segs), bm=bm, lanes=lanes)

    # --------------------------------------------------------- flat plumbing

    def flatten(self, tree: PyTree) -> jax.Array:
        """Pytree → one block-padded f32 buffer (the §10 layout)."""
        return self._flatten_leaves(self.resolved._leaves_of(tree))

    def _flatten_leaves(self, leaves) -> jax.Array:
        raw = [
            jnp.asarray(leaf).reshape(-1).astype(jnp.float32)
            for leaf in leaves
        ]
        raw_flat = jnp.concatenate(raw) if len(raw) > 1 else raw[0]
        if self.n_pad == self.n_total:
            return raw_flat  # contiguous layout, no pad slots
        gathered = jnp.take(raw_flat, jnp.asarray(self._pad_to_raw), mode="clip")
        return jnp.where(jnp.asarray(self._pad_valid), gathered, 0.0)

    def unflatten(self, flat: jax.Array, cast: bool = True) -> PyTree:
        """Flat buffer → pytree (inverse of :meth:`flatten`)."""
        out = []
        for seg in self.segments:
            piece = flat[seg.offset:seg.offset + seg.size].reshape(seg.shape)
            out.append(piece.astype(seg.dtype) if cast else piece)
        return jax.tree.unflatten(self.resolved.treedef, out)

    def zeros_residual(self) -> jax.Array:
        return jnp.zeros((self.n_pad,), jnp.float32)

    def _check_rates(self, rates) -> Tuple[float, ...]:
        if not isinstance(rates, tuple):
            rates = (float(rates),) * len(self.segments)
        if len(rates) != len(self.segments):
            raise ValueError(
                f"got {len(rates)} rates for {len(self.segments)} leaves"
            )
        return tuple(float(r) for r in rates)

    def _ks(self, rates: Tuple[float, ...]) -> Tuple[int, ...]:
        return tuple(
            0 if s.kind == "skip"
            else s.size if s.kind == "dense"
            else k_for(s.size, p)
            for s, p in zip(self.segments, rates)
        )

    # ------------------------------------------------------------ exact path

    def compress(self, delta: PyTree, state, rates) -> tuple:
        """Drop-in, bit-identical replacement for the per-leaf
        ``ResolvedPolicy.compress`` — same (ctree, dense_tree, new_state)
        contract, with ``new_state.residual`` kept in the flat layout."""
        rates = self._check_rates(rates)
        fn = self._jitted.get(("exact", rates))
        if fn is None:
            fn = jax.jit(lambda leaves, res, rng:
                         self._compress_exact(leaves, res, rng, rates))
            self._jitted[("exact", rates)] = fn
        leaves = self.resolved._leaves_of(delta)
        residual = state.residual if self.resolved.any_residual else None
        ctree_leaves, dense_leaves, new_res, next_rng = fn(
            leaves, residual, state.rng
        )
        new_state = state._replace(
            residual=new_res if new_res is not None else state.residual,
            rng=next_rng,
            step=state.step + 1,
        )
        return (
            jax.tree.unflatten(self.resolved.treedef, ctree_leaves),
            jax.tree.unflatten(self.resolved.treedef, dense_leaves),
            new_state,
        )

    def _compress_exact(self, leaves, residual, rng, rates):
        segs, ks = self.segments, self._ks(rates)
        # residual-accumulate in ONE flat op (Eq. 2 gather phase)
        delta_flat = self._flatten_leaves(leaves)
        if residual is None:
            acc_flat = delta_flat
        elif self._all_residual:
            acc_flat = delta_flat + residual
        else:
            acc_flat = delta_flat + jnp.where(
                jnp.asarray(self._res_mask), residual, 0.0
            )

        # per-segment exact two-sided top-k (paper Alg. 2 l.1-5).  The
        # selection math is identical to the topk_signed selector, so idx,
        # μ, and the pos/neg side decision match the legacy path bit for bit.
        comp_leaves: List[Optional[LeafCompressed]] = [None] * len(segs)
        gidx, gmu = [], []
        for i, (seg, k, p) in enumerate(zip(segs, ks, rates)):
            acc = acc_flat[seg.offset:seg.offset + seg.size]
            if seg.kind == "skip":
                comp_leaves[i] = LeafCompressed(
                    idx=jnp.zeros((0,), jnp.int32),
                    vals=jnp.zeros((0,), jnp.float32),
                    mean=jnp.zeros((), jnp.float32),
                    dense=jnp.zeros((0,), jnp.float32),
                    nbits=jnp.zeros((), jnp.float32),
                )
                continue
            if seg.kind == "dense":
                codec = self.resolved.plans[i].codec
                comp_leaves[i] = LeafCompressed(
                    idx=jnp.zeros((0,), jnp.int32),
                    vals=jnp.zeros((0,), jnp.float32),
                    mean=jnp.zeros((), jnp.float32),
                    dense=acc,
                    nbits=jnp.asarray(codec.quantizer.value_bits(k), jnp.float32),
                )
                continue
            val_pos, idx_pos = jax.lax.top_k(acc, k)
            val_neg, idx_neg = jax.lax.top_k(-acc, k)
            pos_wins = jnp.mean(val_pos) > jnp.mean(val_neg)
            idx = jnp.where(pos_wins, idx_pos, idx_neg).astype(jnp.int32)
            # μ re-gathers the winning side's ORIGINAL values, exactly like
            # the topk_signed selector + binarize quantizer composition —
            # down to the sign of −0.0 on an all-zero leaf
            mu = jnp.mean(acc[idx])
            codec = self.resolved.plans[i].codec
            nbits = (codec.encoder.position_bits(seg.size, k, p)
                     + codec.quantizer.value_bits(k))
            comp_leaves[i] = LeafCompressed(
                idx=idx,
                vals=jnp.zeros((0,), jnp.float32),
                mean=mu.astype(jnp.float32),
                dense=jnp.zeros((0,), jnp.float32),
                nbits=jnp.asarray(nbits, jnp.float32),
            )
            gidx.append(idx + seg.offset)
            gmu.append(jnp.broadcast_to(mu, (k,)))

        # ΔW* for EVERY sparse leaf in one fused scatter; dense segments
        # pass their acc through via ONE static-mask select (not a chain of
        # per-leaf update-slices); skip segments stay zero.
        dense_flat = jnp.zeros((self.n_pad,), jnp.float32)
        if gidx:
            dense_flat = dense_flat.at[jnp.concatenate(gidx)].set(
                jnp.concatenate(gmu)
            )
        if self._dense_mask.any():
            dense_flat = jnp.where(
                jnp.asarray(self._dense_mask), acc_flat, dense_flat
            )

        # single flat residual update (Eq. 2 scatter phase)
        new_res = None
        if residual is not None:
            if self._all_residual:
                new_res = acc_flat - dense_flat
            else:
                new_res = jnp.where(
                    jnp.asarray(self._res_mask), acc_flat - dense_flat, residual
                )

        dense_leaves = [
            dense_flat[s.offset:s.offset + s.size].reshape(s.shape).astype(s.dtype)
            for s in segs
        ]
        # advance the RNG exactly like the per-leaf path (one split per
        # leaf + carry), so fast/legacy state trajectories stay identical
        next_rng = jax.random.split(rng, len(segs) + 1)[0]
        return comp_leaves, dense_leaves, new_res, next_rng

    # ----------------------------------------------------------- hist engine

    def compress_hist(
        self,
        delta: PyTree,
        state,
        rates,
        *,
        nbins: int = 128,
        interpret: Optional[bool] = None,
    ) -> tuple:
        """Histogram-threshold SBC over the flat buffer — the Pallas engine.

        Per-segment semantics match :func:`repro.kernels.ops.sbc_compress_hist`
        (approximate survivor counts; exact residual identity acc = ΔW* + R),
        but the three passes launch ONCE each over the whole parameter set.
        Requires an all-"sbc" policy.  Returns ``(dense_tree, new_state,
        stats)`` with per-segment ``stats = {mu, count, nbits}``.
        """
        if any(s.kind != "sbc" for s in self.segments):
            raise ValueError(
                "compress_hist needs an all-SBC policy; dense/skip leaves "
                "belong to the exact engine"
            )
        rates = self._check_rates(rates)
        if interpret is None:
            interpret = not on_tpu()
        key = ("hist", rates, nbins, bool(interpret))
        fn = self._jitted.get(key)
        if fn is None:
            fn = jax.jit(lambda leaves, res: self._compress_hist(
                leaves, res, rates, nbins, interpret))
            self._jitted[key] = fn
        leaves = self.resolved._leaves_of(delta)
        residual = state.residual if self.resolved.any_residual else None
        dense_flat, new_res, stats = fn(leaves, residual)
        new_state = state._replace(
            residual=new_res if new_res is not None else state.residual,
            rng=jax.random.split(state.rng, len(self.segments) + 1)[0],
            step=state.step + 1,
        )
        return self.unflatten(dense_flat), new_state, stats

    def _compress_hist(self, leaves, residual, rates, nbins, interpret):
        from repro.core.golomb import expected_position_bits

        segs = self.segments
        ks = self._ks(rates)
        delta_flat = self._flatten_leaves(leaves)
        acc_flat = delta_flat if residual is None else delta_flat + residual
        xpad = acc_flat.reshape(self.n_blocks * self.bm, self.lanes)
        sob = jnp.asarray(self.seg_of_block, jnp.float32)[:, None]
        nseg = len(segs)

        # per-segment |x| range for the coarse pass (same rule as
        # ops.sbc_compress_hist; max is order-independent → exact)
        absmax = jnp.stack([
            jnp.max(jnp.abs(acc_flat[s.offset:s.offset + s.size]))
            for s in segs
        ]) + 1e-30
        lo0 = absmax * 2.0 ** -SPAN_OCTAVES
        hi0 = absmax * 1.0001

        def block_params(*cols, seg: bool = True):
            rows = [c[self.seg_of_block][:, None] for c in cols]
            if seg:
                rows = [sob] + rows
            return jnp.concatenate(rows, axis=1)

        kf = jnp.asarray(ks, jnp.float32)
        vthresh = jax.vmap(_side_threshold)
        vedges = jax.vmap(lambda lo, hi: bucket_lower_edges(lo, hi, nbins))

        h1 = seg_hist2side(
            xpad, block_params(lo0, hi0, lo0, hi0), nseg=nseg, nbins=nbins,
            bm=self.bm, lanes=self.lanes, interpret=interpret,
        )
        edges0 = vedges(lo0, hi0)
        lo_p, hi_p, above_p = vthresh(h1[:, 0], edges0, kf)
        lo_n, hi_n, above_n = vthresh(h1[:, 1], edges0, kf)

        h2 = seg_hist2side(
            xpad, block_params(lo_p, hi_p, lo_n, hi_n), nseg=nseg, nbins=nbins,
            bm=self.bm, lanes=self.lanes, interpret=interpret,
        )
        t_pos, _, _ = vthresh(h2[:, 0], vedges(lo_p, hi_p), kf - above_p)
        t_neg, _, _ = vthresh(h2[:, 1], vedges(lo_n, hi_n), kf - above_n)

        mom = seg_moments(
            xpad, block_params(t_pos, t_neg), nseg=nseg,
            bm=self.bm, lanes=self.lanes, interpret=interpret,
        )
        mu_pos = mom[:, 0, 0] / jnp.maximum(mom[:, 0, 1], 1.0)
        mu_neg = -mom[:, 1, 0] / jnp.maximum(mom[:, 1, 1], 1.0)
        pos_wins = mu_pos > mu_neg
        mu = jnp.where(pos_wins, mu_pos, -mu_neg)
        count = jnp.where(pos_wins, mom[:, 0, 1], mom[:, 1, 1])

        out_pad, res_pad = seg_binarize_apply(
            xpad,
            block_params(t_pos, t_neg, mu, pos_wins.astype(jnp.float32),
                         seg=False),
            bm=self.bm, lanes=self.lanes, interpret=interpret,
        )
        dense_flat = out_pad.reshape(-1)
        new_res = res_pad.reshape(-1) if residual is not None else None

        ebits = jnp.asarray(
            [expected_position_bits(min(p, 1.0)) for p in rates], jnp.float32
        )
        stats = {"mu": mu, "count": count, "nbits": count * ebits + 32.0}
        return dense_flat, new_res, stats
