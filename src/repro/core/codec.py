"""Codec = Selector → Quantizer → Encoder composition (DESIGN.md §2).

A :class:`Codec` glues three registered stages into one per-leaf
compression method with the uniform :class:`~repro.core.stages.LeafCompressed`
IR.  Codecs are cheap frozen dataclasses; the spec string form

    "selector|quantizer|encoder"      e.g. "topk_signed|binarize|golomb"

is what policies, configs, and the wire layer use to name them.  Named
shorthands ("sbc", "topk", "signsgd", …) are registered by
:mod:`repro.core.sbc` / :mod:`repro.core.baselines` through
:func:`register_codec`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import stages
from repro.core.stages import (
    Encoder,
    LeafCompressed,
    Quantizer,
    Selector,
    decompress_leaf,
    get_encoder,
    get_quantizer,
    get_selector,
    k_for,
)


@dataclasses.dataclass(frozen=True)
class Codec:
    """One composed compression method for one tensor.

    ``use_residual``: whether error feedback (Eq. 2) wraps this codec.
    Unbiased stochastic quantizers (terngrad/qsgd) and sign-voting run
    residual-free, everything else accumulates what it did not send.
    """

    selector: Selector
    quantizer: Quantizer
    encoder: Encoder
    use_residual: bool = True

    @property
    def spec(self) -> str:
        return f"{self.selector.name}|{self.quantizer.name}|{self.encoder.name}"

    @property
    def stochastic(self) -> bool:
        return self.selector.stochastic or self.quantizer.stochastic

    @property
    def skip(self) -> bool:
        return self.selector.skip

    @property
    def flat_kind(self):
        """Segment kind in the flat-buffer fast path (core/flat.py §10):
        "sbc" | "dense" | "skip", or None when any stage has no flat form
        (a ``fast=True`` policy then falls back to the per-leaf path)."""
        if not (self.selector.flat_fast and self.quantizer.flat_fast
                and self.encoder.flat_fast):
            return None
        if self.selector.skip:
            return "skip"
        if self.selector.dense and self.quantizer.name == "identity":
            return "dense"
        if self.spec == "topk_signed|binarize|golomb":
            return "sbc"
        return None

    # ------------------------------------------------------------- per leaf

    def compress_leaf(
        self, flat: jax.Array, p: float, rng: Optional[jax.Array]
    ) -> LeafCompressed:
        """flat f32[n] → LeafCompressed.  ``p`` is this leaf's sparsity rate."""
        n = flat.shape[0]
        if rng is not None:
            # independent draws per stage: a stochastic selector composed
            # with a stochastic quantizer must not share randomness
            s_rng, q_rng = jax.random.split(rng)
        else:
            s_rng = q_rng = None
        sel = self.selector(flat, p, s_rng)
        vals_q, scalar = self.quantizer(sel, q_rng)
        if self.selector.skip:
            return LeafCompressed(
                idx=sel.idx,
                vals=jnp.zeros((0,), jnp.float32),
                mean=jnp.zeros((), jnp.float32),
                dense=jnp.zeros((0,), jnp.float32),
                nbits=jnp.zeros((), jnp.float32),
            )
        if self.selector.dense:
            k = n
            nbits = self.quantizer.value_bits(k)  # positions cost 0 bits
            return LeafCompressed(
                idx=jnp.zeros((0,), jnp.int32),
                vals=jnp.zeros((0,), jnp.float32),
                mean=scalar,
                dense=vals_q,
                nbits=jnp.asarray(nbits, jnp.float32),
            )
        k = sel.idx.shape[0]
        nbits = self.encoder.position_bits(n, k, p) + self.quantizer.value_bits(k)
        return LeafCompressed(
            idx=sel.idx,
            vals=vals_q,
            mean=scalar,
            dense=jnp.zeros((0,), jnp.float32),
            nbits=jnp.asarray(nbits, jnp.float32),
        )

    def decompress_leaf(self, comp: LeafCompressed, n: int) -> jax.Array:
        return decompress_leaf(comp, n)


# ------------------------------------------------------------ codec registry


_CODECS: Dict[str, Any] = {}


def register_codec(name: str):
    """Register a named codec factory (kwargs → Codec)."""

    def deco(factory):
        _CODECS[name] = factory
        return factory

    return deco


def make_codec(spec: Union[str, Codec], **kwargs: Any) -> Codec:
    """Build a codec from a named shorthand, a "sel|quant|enc" spec string,
    or pass an already-built Codec through."""
    if isinstance(spec, Codec):
        return spec
    if spec in _CODECS:
        return _CODECS[spec](**kwargs)
    if "|" in spec:
        sel, quant, enc = spec.split("|")
        return Codec(
            selector=get_selector(sel, **kwargs),
            quantizer=get_quantizer(quant, **kwargs),
            encoder=get_encoder(enc, **kwargs),
            use_residual=kwargs.get("use_residual", True),
        )
    raise KeyError(
        f"unknown codec {spec!r}; named codecs: {sorted(_CODECS)}; "
        f"or compose stages as 'selector|quantizer|encoder' from "
        f"{stages.available_stages()}"
    )


def available_codecs() -> list:
    return sorted(_CODECS)


# The two structural codecs every policy can reference.
@register_codec("dense32")
def make_dense32(use_residual: bool = True, **_) -> Codec:
    """Dense 32-bit passthrough — the per-leaf dense-fallback codec."""
    return Codec(
        get_selector("dense"), get_quantizer("identity"), get_encoder("none"),
        use_residual=use_residual,
    )


@register_codec("skip")
def make_skip(**_) -> Codec:
    """Transmit nothing for this leaf (frozen/excluded parameters).
    With use_residual=True the untransmitted update accumulates in the
    residual, so a later non-skip round flushes it (§III hybrid schedules)."""
    return Codec(
        get_selector("skip"), get_quantizer("identity"), get_encoder("none"),
        use_residual=True,
    )


def leaf_k(codec: Codec, n: int, p: float) -> int:
    """Static survivor count of ``codec`` on an n-entry leaf at rate p."""
    if codec.skip:
        return 0
    if codec.selector.dense:
        return n
    return k_for(n, p)
