"""Codec stages: Selector → Quantizer → Encoder (DESIGN.md §2).

The paper's methods decompose into three orthogonal choices per tensor:

  *which* entries survive            → :class:`Selector`
  *how* surviving values are coded   → :class:`Quantizer`
  *how* surviving positions are coded→ :class:`Encoder`

SBC (Alg. 2) is ``topk_signed → binarize → golomb``; Gradient Dropping is
``topk → identity → raw16``; signSGD is ``dense → sign → none``; and so on.
Each stage is a small registered functional unit so new methods are one
composition away instead of one monolithic compressor away.

Every stage is jit/vmap-friendly: selection sizes ``k`` are static functions
of ``(n, p)``, and all per-entry work is fixed-shape.  The host-side byte
serialization of each stage lives in :mod:`repro.core.wire`, keyed by the
stage names recorded here.

The shared intermediate representation is :class:`LeafCompressed` — one
fixed-shape pytree per flattened tensor, decompressible by the single
generic rule in :func:`decompress_leaf` (codec-independent):

  dense payload present → it IS the reconstruction;
  per-entry vals present → scatter vals at idx;
  otherwise              → scatter the per-tensor scalar at idx.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.golomb import expected_position_bits


class LeafCompressed(NamedTuple):
    """Compressed form of ONE flattened tensor (the stage IR).

    Exactly one value encoding is "live" per codec; dead fields are
    zero-size arrays so the pytree structure stays static under jit.

    idx:  int32[k]   positions of surviving entries (empty for dense/skip)
    vals: f32[k] | f32[0]   per-entry values (identity-quantized codecs)
    mean: f32[]      per-tensor scalar (SBC ±μ, sign/ternary/qsgd scale)
    dense: f32[n] | f32[0]  dense payload (dense-selector codecs)
    nbits: f32[]     analytic wire size of this leaf for this round (Eq. 1)
    """

    idx: jax.Array
    vals: jax.Array
    mean: jax.Array
    dense: jax.Array
    nbits: jax.Array


class Selection(NamedTuple):
    """Selector output: surviving positions + their raw values.

    Dense selectors return ``idx`` empty and ``vals`` of length n — the
    position stream costs 0 bits and the encoder is bypassed.
    """

    idx: jax.Array  # int32[k] (int32[0] when dense or skip)
    vals: jax.Array  # f32[k]  (f32[n] when dense, f32[0] when skip)


def k_for(n: int, p: float) -> int:
    """Number of surviving entries at sparsity rate p (at least 1)."""
    return max(1, min(n, int(round(p * n))))


# ------------------------------------------------------------------ selectors


@dataclasses.dataclass(frozen=True)
class Selector:
    """Picks which coordinates of a flat f32[n] tensor survive.

    fn(flat, p, rng) -> Selection with a k that is static in (n, p).
    ``dense``: every coordinate survives (positions are free).
    ``skip``:  nothing survives, nothing is transmitted.
    """

    name: str
    fn: Callable[[jax.Array, float, Optional[jax.Array]], Selection]
    dense: bool = False
    skip: bool = False
    stochastic: bool = False
    # stage is expressible in the flat-buffer fast path (core/flat.py §10);
    # a codec takes the fast path only when all three of its stages are
    flat_fast: bool = False

    def __call__(self, flat: jax.Array, p: float, rng) -> Selection:
        return self.fn(flat, p, rng)


_SELECTORS: Dict[str, Callable[..., Selector]] = {}


def register_selector(name: str):
    def deco(factory):
        _SELECTORS[name] = factory
        return factory

    return deco


def get_selector(name: str, **kw) -> Selector:
    if name not in _SELECTORS:
        raise KeyError(f"unknown selector {name!r}; have {sorted(_SELECTORS)}")
    return _SELECTORS[name](**kw)


@register_selector("dense")
def make_dense_selector(**_) -> Selector:
    def fn(flat, p, rng):
        del p, rng
        return Selection(idx=jnp.zeros((0,), jnp.int32), vals=flat)

    return Selector("dense", fn, dense=True, flat_fast=True)


@register_selector("skip")
def make_skip_selector(**_) -> Selector:
    def fn(flat, p, rng):
        del flat, p, rng
        return Selection(
            idx=jnp.zeros((0,), jnp.int32), vals=jnp.zeros((0,), jnp.float32)
        )

    return Selector("skip", fn, skip=True, flat_fast=True)


@register_selector("topk")
def make_topk_selector(**_) -> Selector:
    """Magnitude top-k (Gradient Dropping / DGC selection)."""

    def fn(flat, p, rng):
        del rng
        k = k_for(flat.shape[0], p)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        return Selection(idx=idx.astype(jnp.int32), vals=flat[idx])

    return Selector("topk", fn)


@register_selector("topk_signed")
def make_topk_signed_selector(**_) -> Selector:
    """SBC's one-sided selection (Alg. 2 l.1-5): top-k of ΔW and of −ΔW,
    keep whichever side has the larger mean magnitude.  Composed with the
    ``binarize`` quantizer this is exactly Sparse Binary Compression."""

    def fn(flat, p, rng):
        del rng
        k = k_for(flat.shape[0], p)
        val_pos, idx_pos = jax.lax.top_k(flat, k)
        val_neg, idx_neg = jax.lax.top_k(-flat, k)
        pos_wins = jnp.mean(val_pos) > jnp.mean(val_neg)
        idx = jnp.where(pos_wins, idx_pos, idx_neg).astype(jnp.int32)
        return Selection(idx=idx, vals=flat[idx])

    return Selector("topk_signed", fn, flat_fast=True)


@register_selector("threshold")
def make_threshold_selector(tau: float = 0.0, **_) -> Selector:
    """Fixed-threshold selection (Strom '15 family): capacity-k slots, but
    entries with |ΔW| < τ transmit an explicit zero.  With τ = 0 this
    degenerates to plain top-k.  Static-shape under jit: the slot count is
    k_for(n, p); the threshold only masks values, never changes shapes."""

    def fn(flat, p, rng):
        del rng
        k = k_for(flat.shape[0], p)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        vals = flat[idx]
        vals = jnp.where(jnp.abs(vals) >= tau, vals, 0.0)
        return Selection(idx=idx.astype(jnp.int32), vals=vals)

    return Selector("threshold", fn)


@register_selector("randomk")
def make_randomk_selector(**_) -> Selector:
    """Random-k mask (sketched updates, Konečný et al. '16)."""

    def fn(flat, p, rng):
        n = flat.shape[0]
        k = k_for(n, p)
        idx = jax.random.choice(rng, n, shape=(k,), replace=False).astype(jnp.int32)
        return Selection(idx=idx, vals=flat[idx])

    return Selector("randomk", fn, stochastic=True)


@register_selector("variance")
def make_variance_selector(block: int = 256, **_) -> Selector:
    """Approximated variance-based selection (Tsuzuku et al. '18): keep the
    entries whose magnitude is large *relative to the local noise level*,
    not merely large in absolute terms.  The ambiguity criterion √V is
    approximated by a blockwise second-moment proxy over the accumulated
    (momentum-normalized) update: each entry's score is |ΔW| divided by
    the RMS of its ``block``-sized neighbourhood, so a coordinate that
    stands out from a quiet block beats a middling coordinate inside a
    loud one.  Deterministic and static-k (exactly ``k_for(n, p)``
    survivors), so it rides the standard sparse wire format unchanged."""

    def fn(flat, p, rng):
        del rng
        n = flat.shape[0]
        k = k_for(n, p)
        b = min(block, n)
        nb = -(-n // b)
        x = jnp.pad(flat, (0, nb * b - n)).reshape(nb, b)
        rms = jnp.sqrt(jnp.mean(x * x, axis=1, keepdims=True) + 1e-24)
        score = (jnp.abs(x) / rms).reshape(-1)[:n]
        _, idx = jax.lax.top_k(score, k)
        return Selection(idx=idx.astype(jnp.int32), vals=flat[idx])

    return Selector("variance", fn)


@register_selector("expert_topk")
def make_expert_topk_selector(experts: int = 8, **_) -> Selector:
    """Per-expert balanced top-k for MoE leaves shaped ``(E, …)``.

    Routing already sparsified the gradient: only the routed experts hold
    signal, and a hot expert would crowd every other expert out of a
    plain global top-k.  Selection therefore ranks candidates in three
    tiers — (1) each expert's local top-⌈k/E⌉ (its fair quota), (2) the
    remaining non-zero coordinates of routed experts, (3) exact zeros
    (unrouted experts) — and takes the global top-k in tier order.  So
    every routed expert keeps its quota, an unrouted all-zero expert
    donates its slots to routed experts instead of shipping zeros
    (skip-if-unrouted), and total survivors are exactly ``k_for(n, p)``
    — byte-compatible with the static-k wire contract.  Leaves whose
    length is not divisible by ``experts`` degrade to plain top-k."""

    def fn(flat, p, rng):
        del rng
        n = flat.shape[0]
        k = k_for(n, p)
        e = experts if (experts > 1 and n % experts == 0) else 1
        if e == 1:
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            return Selection(idx=idx.astype(jnp.int32), vals=flat[idx])
        n_loc = n // e
        q = min(n_loc, k)  # candidates per expert (enough to redistribute)
        quota = -(-k // e)
        bscore, bidx = jax.lax.top_k(jnp.abs(flat).reshape(e, n_loc), q)
        # tiered score bands, non-overlapping since span > max score
        span = jnp.max(bscore) + 1.0
        nz = bscore > 0.0
        in_quota = (jnp.arange(q) < quota)[None, :]
        adj = bscore + 2.0 * span * (nz & in_quota) + span * (nz & ~in_quota)
        base = jnp.arange(e, dtype=jnp.int32)[:, None] * n_loc
        cand = (bidx.astype(jnp.int32) + base).reshape(-1)
        _, sel = jax.lax.top_k(adj.reshape(-1), k)  # e·q ≥ k always
        idx = cand[sel]
        return Selection(idx=idx, vals=flat[idx])

    return Selector("expert_topk", fn)


# ----------------------------------------------------------------- quantizers


@dataclasses.dataclass(frozen=True)
class Quantizer:
    """Codes the surviving values.

    fn(selection, rng) -> (vals_q, scalar):
      vals_q: f32 array shaped like selection.vals, or f32[0] when the
              quantizer collapses all values into the per-tensor scalar;
      scalar: f32[] per-tensor constant (μ, scale, norm; 0 when unused).

    value_bits(k) -> analytic wire bits for k surviving values, including
    any per-tensor scalar overhead.
    """

    name: str
    fn: Callable[[Selection, Optional[jax.Array]], tuple]
    value_bits: Callable[[int], float]
    stochastic: bool = False
    levels: int = 0  # quantization-level count (wire code width); 0 = n/a
    flat_fast: bool = False  # expressible in the flat fast path (§10)

    def __call__(self, sel: Selection, rng) -> tuple:
        return self.fn(sel, rng)


_QUANTIZERS: Dict[str, Callable[..., Quantizer]] = {}


def register_quantizer(name: str):
    def deco(factory):
        _QUANTIZERS[name] = factory
        return factory

    return deco


def get_quantizer(name: str, **kw) -> Quantizer:
    if name not in _QUANTIZERS:
        raise KeyError(f"unknown quantizer {name!r}; have {sorted(_QUANTIZERS)}")
    return _QUANTIZERS[name](**kw)


@register_quantizer("identity")
def make_identity_quantizer(**_) -> Quantizer:
    """Values pass through at full 32-bit precision."""

    def fn(sel, rng):
        del rng
        return sel.vals.astype(jnp.float32), jnp.zeros((), jnp.float32)

    return Quantizer("identity", fn, value_bits=lambda k: 32.0 * k, flat_fast=True)


@register_quantizer("binarize")
def make_binarize_quantizer(**_) -> Quantizer:
    """±μ binarization (SBC Alg. 2 l.4-6): ALL surviving values collapse to
    their single signed mean — 0 value bits per entry, one 32-bit scalar."""

    def fn(sel, rng):
        del rng
        mu = jnp.mean(sel.vals).astype(jnp.float32)
        return jnp.zeros((0,), jnp.float32), mu

    return Quantizer("binarize", fn, value_bits=lambda k: 32.0, flat_fast=True)


@register_quantizer("sign")
def make_sign_quantizer(**_) -> Quantizer:
    """Scaled sign (signSGD/SIGNUM): 1 bit per entry + one 32-bit scale.
    Compressors act on weight-DELTAS, so the bare sign must carry a
    magnitude — mean(|Δ|), one scalar per tensor (DESIGN.md §8).

    Exact zeros quantize to +scale (sign ties go positive): a 1-bit wire
    symbol has no zero, and the sender must emit exactly what a receiver
    can reconstruct from the bitstream."""

    def fn(sel, rng):
        del rng
        v = sel.vals
        scale = jnp.mean(jnp.abs(v)).astype(jnp.float32)
        return jnp.where(v >= 0, scale, -scale).astype(jnp.float32), scale

    return Quantizer("sign", fn, value_bits=lambda k: 1.0 * k + 32.0)


@register_quantizer("two_means")
def make_two_means_quantizer(**_) -> Quantizer:
    """1-bit SGD (Seide et al. '14): per-tensor μ⁺/μ⁻ column means —
    1 bit per entry + two 32-bit scalars."""

    def fn(sel, rng):
        del rng
        v = sel.vals
        pos = v >= 0
        npos = jnp.maximum(jnp.sum(pos), 1)
        nneg = jnp.maximum(v.shape[0] - jnp.sum(pos), 1)
        mu_pos = jnp.sum(jnp.where(pos, v, 0.0)) / npos
        mu_neg = jnp.sum(jnp.where(pos, 0.0, v)) / nneg  # negative number
        out = jnp.where(pos, mu_pos, mu_neg).astype(jnp.float32)
        return out, mu_pos.astype(jnp.float32)

    return Quantizer("two_means", fn, value_bits=lambda k: 1.0 * k + 64.0)


@register_quantizer("ternary")
def make_ternary_quantizer(**_) -> Quantizer:
    """TernGrad (Wen et al. '17): stochastic ternary {−s, 0, +s}."""

    def fn(sel, rng):
        v = sel.vals
        s = jnp.max(jnp.abs(v)) + 1e-12
        keep = jax.random.bernoulli(rng, jnp.abs(v) / s)
        return (s * jnp.sign(v) * keep).astype(jnp.float32), s.astype(jnp.float32)

    return Quantizer(
        "ternary", fn, value_bits=lambda k: math.log2(3.0) * k + 32.0, stochastic=True
    )


@register_quantizer("stochastic")
def make_stochastic_quantizer(levels: int = 15, **_) -> Quantizer:
    """QSGD (Alistarh et al. '17): stochastic uniform quantization on the
    L2 ball with ``levels`` levels; the per-tensor norm rides in the scalar."""

    def fn(sel, rng):
        v = sel.vals
        norm = jnp.linalg.norm(v) + 1e-12
        scaled = jnp.abs(v) / norm * levels
        floor = jnp.floor(scaled)
        quant = floor + jax.random.bernoulli(rng, scaled - floor)
        out = (norm * jnp.sign(v) * quant / levels).astype(jnp.float32)
        return out, norm.astype(jnp.float32)

    bits_per = math.log2(2.0 * levels + 1.0)
    return Quantizer(
        "stochastic", fn, value_bits=lambda k: bits_per * k + 32.0,
        stochastic=True, levels=levels,
    )


# ------------------------------------------------------------------- encoders


@dataclasses.dataclass(frozen=True)
class Encoder:
    """Position stream coding.  Only the *analytic* model lives here;
    the exact byte serialization is in :mod:`repro.core.wire` keyed by
    ``name``.  position_bits(n, k, p) -> analytic wire bits."""

    name: str
    position_bits: Callable[[int, int, float], float]
    flat_fast: bool = False  # expressible in the flat fast path (§10)


_ENCODERS: Dict[str, Callable[..., Encoder]] = {}


def register_encoder(name: str):
    def deco(factory):
        _ENCODERS[name] = factory
        return factory

    return deco


def get_encoder(name: str, **kw) -> Encoder:
    if name not in _ENCODERS:
        raise KeyError(f"unknown encoder {name!r}; have {sorted(_ENCODERS)}")
    return _ENCODERS[name](**kw)


@register_encoder("none")
def make_none_encoder(**_) -> Encoder:
    """Dense / skip codecs: positions are predetermined, 0 bits."""
    return Encoder("none", lambda n, k, p: 0.0, flat_fast=True)


@register_encoder("golomb")
def make_golomb_encoder(**_) -> Encoder:
    """Optimal Golomb position coding (paper Alg. 3, Eq. 5)."""
    return Encoder(
        "golomb", lambda n, k, p: k * expected_position_bits(min(p, 1.0)),
        flat_fast=True,
    )


@register_encoder("bitmask")
def make_bitmask_encoder(**_) -> Encoder:
    """One bit per coordinate; beats Golomb only when p ≳ 0.3."""
    return Encoder("bitmask", lambda n, k, p: 1.0 * n)


@register_encoder("raw16")
def make_raw16_encoder(**_) -> Encoder:
    """The paper's naive fixed-width 16-bit positions (Table I baselines)."""
    return Encoder("raw16", lambda n, k, p: 16.0 * k)


@register_encoder("raw32")
def make_raw32_encoder(**_) -> Encoder:
    return Encoder("raw32", lambda n, k, p: 32.0 * k)


@register_encoder("seed")
def make_seed_encoder(**_) -> Encoder:
    """Random-k positions derivable from a shared 32-bit seed (Konečný et
    al. '16) — one scalar regardless of k.  NOTE: the packed wire format
    (repro.core.wire) still ships explicit raw32 indices so a receiver
    without the shared seed can decode; the analytic model reflects the
    shared-seed in-process exchange."""
    return Encoder("seed", lambda n, k, p: 32.0)


# ---------------------------------------------------------------- decompress


def decompress_leaf(comp: LeafCompressed, n: int) -> jax.Array:
    """Generic, codec-independent reconstruction of one flat tensor.

    Branch is static (zero-size fields are compile-time shapes), so this
    stays jit-friendly for every registered codec.
    """
    if comp.dense.shape[0]:
        return comp.dense
    if comp.vals.shape[0]:
        return jnp.zeros((n,), jnp.float32).at[comp.idx].set(comp.vals)
    # scalar-collapsed values (SBC ±μ); a skip codec has idx empty → zeros
    return jnp.zeros((n,), jnp.float32).at[comp.idx].set(comp.mean)


def available_stages() -> dict:
    return {
        "selectors": sorted(_SELECTORS),
        "quantizers": sorted(_QUANTIZERS),
        "encoders": sorted(_ENCODERS),
    }
