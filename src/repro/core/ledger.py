"""Per-round bandwidth ledger — bidirectional byte accounting.

Every :class:`~repro.core.channel.CommChannel` backend meters its rounds
here (DESIGN.md §12; the ledger grew up fed-only in §9 and moved into the
channel protocol so measured-vs-Eq.1/Eq.5 accounting is uniform across the
local, GSPMD, and federated backends).  Per direction of the wire a round
records

  * ``bytes``      — framed SBW1 buffer sizes that actually crossed the
                     "network" (transport view),
  * ``bits_measured`` — exact payload bits off the buffers, pre byte-padding
                     (what :meth:`repro.core.wire.Wire.measured_bits` meters;
                     the GSPMD backend Golomb-encodes the real per-shard
                     position streams instead),
  * ``bits_analytic`` — the Eq. 1 sum of per-leaf ``nbits`` from the codecs
                     (Golomb positions priced by Eq. 5's expectation).

The federated backend records real per-client buffers both directions; the
local and GSPMD backends meter client 0's upload and extrapolate ×C (every
client's analytic size is identical — shapes and rates are static — and
their measured sizes are one geometric draw each), and their "downstream"
is the in-process aggregate, so the down direction records zero traffic
and reconciles trivially.

``reconcile`` asserts measured ≈ analytic on every round in both
directions: Eq. 5 is the expectation over geometric position gaps while the
bitstream is one draw, so they agree only within Golomb rounding — the same
tolerance :mod:`tests.test_codec_pipeline` uses for the upstream wire.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class RoundRecord:
    """One communication round's traffic, both directions.

    Upstream numbers are summed over the participating clients; downstream
    numbers are per-recipient (one broadcast buffer) times
    ``down_recipients``.
    """

    round: int
    cohort: Tuple[int, ...]
    up_bytes: int
    up_bits_measured: float
    up_bits_analytic: float
    down_bytes: int
    down_bits_measured: float
    down_bits_analytic: float
    down_recipients: int
    # bytes clients sent that the server never aggregated — aborted
    # (straggler) uploads and corrupt buffers the decode rejected.  Kept
    # OUT of up_bytes/up_bits_* so measured-vs-Eq.1/Eq.5 reconcile still
    # balances in rounds with dropouts: the accepted-traffic columns
    # account only for accepted traffic, and the waste is metered here.
    up_bytes_wasted: int = 0

    @property
    def total_bytes(self) -> int:
        return self.up_bytes + self.down_bytes


class BandwidthLedger:
    """Accumulates :class:`RoundRecord` rows and reconciles them with the
    analytic Eq. 1/Eq. 5 prediction."""

    def __init__(self) -> None:
        self.records: List[RoundRecord] = []

    def record(self, rec: RoundRecord) -> None:
        self.records.append(rec)

    def record_up(
        self,
        round_idx: int,
        *,
        clients: Tuple[int, ...],
        up_bytes: int,
        up_bits_measured: float,
        up_bits_analytic: float,
    ) -> None:
        """Upload-only round row — how the local and GSPMD channels meter
        (their aggregate never crosses a wire, so down traffic is zero)."""
        self.record(RoundRecord(
            round=round_idx,
            cohort=tuple(clients),
            up_bytes=up_bytes,
            up_bits_measured=up_bits_measured,
            up_bits_analytic=up_bits_analytic,
            down_bytes=0,
            down_bits_measured=0.0,
            down_bits_analytic=0.0,
            down_recipients=0,
        ))

    # ------------------------------------------------------------- queries

    def totals(self) -> dict:
        """Summed traffic over all recorded rounds."""
        out = {
            "rounds": len(self.records),
            "up_bytes": sum(r.up_bytes for r in self.records),
            "down_bytes": sum(r.down_bytes for r in self.records),
            "up_bytes_wasted": sum(r.up_bytes_wasted for r in self.records),
            "up_bits_measured": sum(r.up_bits_measured for r in self.records),
            "up_bits_analytic": sum(r.up_bits_analytic for r in self.records),
            "down_bits_measured": sum(r.down_bits_measured for r in self.records),
            "down_bits_analytic": sum(r.down_bits_analytic for r in self.records),
        }
        out["total_bytes"] = out["up_bytes"] + out["down_bytes"]
        return out

    def reconcile(self, rel: float = 0.1) -> None:
        """Assert measured-vs-analytic parity per round, both directions.

        ``rel`` bounds |measured − analytic| / analytic; Golomb position
        streams are one geometric draw against Eq. 5's expectation, so a few
        percent of slack is expected at paper-scale tensors and more on tiny
        test leaves.  Zero-traffic directions (e.g. dense-free skip rounds)
        reconcile trivially.

        Rounds with dropouts balance because the ``up_*`` columns meter
        ACCEPTED uploads only: bytes from clients that missed the straggler
        deadline or whose buffers failed decode live in ``up_bytes_wasted``
        and are never compared against the Eq. 1 prediction (which, like
        the aggregation itself, covers only the survivors).
        """
        for r in self.records:
            for side in ("up", "down"):
                measured = getattr(r, f"{side}_bits_measured")
                analytic = getattr(r, f"{side}_bits_analytic")
                if analytic == 0 and measured == 0:
                    continue
                err = abs(measured - analytic) / max(abs(analytic), 1e-9)
                if err > rel:
                    raise AssertionError(
                        f"round {r.round} {side}stream: measured "
                        f"{measured:.0f} bits vs analytic {analytic:.0f} "
                        f"(rel err {err:.3f} > {rel})"
                    )

    def history(self) -> dict:
        """Column-major view for JSON dumps / plotting."""
        cols = ("up_bytes", "down_bytes", "up_bytes_wasted",
                "up_bits_measured", "up_bits_analytic",
                "down_bits_measured", "down_bits_analytic")
        out = {c: [getattr(r, c) for r in self.records] for c in cols}
        out["round"] = [r.round for r in self.records]
        out["cohort_size"] = [len(r.cohort) for r in self.records]
        return out
