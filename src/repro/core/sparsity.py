"""Temporal-vs-gradient sparsity scheduling — paper §III (+ §V future work).

The paper's key observation: communication delay (temporal sparsity 1/n) and
gradient sparsity p multiply into a *total sparsity* n·(1/p) budget, and
validation error is roughly constant along iso-total-sparsity diagonals
(Fig. 3).  Early in training (high LR) temporal sparsity is preferred; after
LR drops, gradient sparsity wins (Fig. 4).

Schedules return ``(delay_n, sparsity_p)`` for a given round.  The adaptive
controller implements the §V "future work" heuristic: follow the LR schedule,
shifting the fixed total-sparsity budget from temporal to gradient sparsity
when the learning rate decays.  This is a beyond-paper feature, recorded in
EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

from repro.core.sbc import SBC_PRESETS


@dataclasses.dataclass(frozen=True)
class SparsitySchedule:
    """delay(round) and sparsity(round), plus the DGC warm-up option."""

    delay: Callable[[int], int]
    sparsity: Callable[[int], float]

    def __call__(self, round_idx: int) -> tuple[int, float]:
        return int(self.delay(round_idx)), float(self.sparsity(round_idx))


def constant(delay: int = 1, sparsity: float = 0.001) -> SparsitySchedule:
    return SparsitySchedule(lambda r: delay, lambda r: sparsity)


def preset(name: str) -> SparsitySchedule:
    """The paper's SBC(1)/(2)/(3) operating points."""
    n, p = SBC_PRESETS[name]
    return constant(delay=n, sparsity=p)


def dgc_warmup(
    target_sparsity: float = 0.001,
    warmup_rounds: int = 4,
    start_sparsity: float = 0.25,
) -> SparsitySchedule:
    """DGC's exponential sparsity warm-up (supplement A): 25% → target.

    The paper finds warm-up speeds early convergence but doesn't change the
    final accuracy; provided for the DGC baseline's faithfulness.
    """

    def sparsity(r: int) -> float:
        if r >= warmup_rounds:
            return target_sparsity
        frac = (r + 1) / warmup_rounds
        # exponential interpolation in log-space
        return float(
            math.exp(
                math.log(start_sparsity) * (1 - frac) + math.log(target_sparsity) * frac
            )
        )

    return SparsitySchedule(lambda r: 1, sparsity)


def adaptive_total_budget(
    total_sparsity: float,
    lr_schedule: Callable[[int], float],
    base_lr: float,
    max_delay: int = 100,
    min_sparsity: float = 1e-4,
) -> SparsitySchedule:
    """§III/§V adaptive controller under a fixed total-sparsity budget.

    total_sparsity = (1/delay) · p  is held constant.  While LR is at its
    base value we push the budget into *temporal* sparsity (large delay);
    after each LR decay we shift toward *gradient* sparsity (delay → 1,
    smaller p), matching the phase behaviour of Fig. 4.
    """

    def split(r: int) -> tuple[int, float]:
        decay = lr_schedule(r) / base_lr  # 1.0 early, <1 after drops
        # fraction of the (log-)budget assigned to temporal sparsity
        temporal_frac = max(0.0, min(1.0, math.log10(max(decay, 1e-8)) / -2.0))
        temporal_frac = 1.0 - temporal_frac  # 1.0 at base lr → 0 after 100× decay
        log_budget = -math.log10(total_sparsity)  # e.g. 1e-3 → 3 decades
        delay = int(round(10 ** (log_budget * temporal_frac)))
        delay = max(1, min(max_delay, delay))
        p = max(min_sparsity, min(1.0, total_sparsity * delay))
        return delay, p

    return SparsitySchedule(lambda r: split(r)[0], lambda r: split(r)[1])


def grid_points(
    delays: tuple[int, ...] = (1, 2, 5, 10, 25, 50, 100),
    sparsities: tuple[float, ...] = (1.0, 0.1, 0.01, 0.001),
) -> list[tuple[int, float]]:
    """The 2-D sweep grid of Fig. 3 (temporal × gradient sparsity)."""
    return [(n, p) for n in delays for p in sparsities]
