"""Baseline compressors the paper compares against (Table I / Table II).

  none        dense 32-bit DSGD (the ×1 baseline)
  topk        Gradient Dropping [Aji & Heafield '17]: top-k by magnitude,
              32-bit values + 16-bit positions, error feedback
  dgc         Deep Gradient Compression [Lin et al. '18]: same wire format as
              topk; momentum correction is implicit in our delayed updates and
              momentum MASKING is honored by the trainer via ``update_mask``
  signsgd     signSGD [Bernstein et al. '18]: 1 bit/coordinate, NO residual
              (server majority vote = mean of signs here)
  onebit      1-bit SGD [Seide et al. '14]: two per-tensor means (like SBC
              without sparsification) + error feedback
  terngrad    TernGrad [Wen et al. '17]: stochastic ternary {−s,0,+s}
  qsgd        QSGD [Alistarh et al. '17]: stochastic uniform quantization on
              the L2 ball, ``levels`` quantization levels
  randomk     sketched updates [Konečný et al. '16]: random-k mask with
              32-bit values; positions derivable from a shared seed

All bit counts follow the accounting the paper uses in Table I.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import api

NAIVE_POS_BITS = 16.0  # the paper's naive fixed-width position encoding


# ------------------------------------------------------------------- dense


def _dense_compress(flat, p, rng):
    del p, rng
    n = flat.shape[0]
    return api.LeafCompressed(
        idx=jnp.zeros((0,), jnp.int32),
        vals=jnp.zeros((0,), jnp.float32),
        mean=jnp.zeros((), jnp.float32),
        dense=flat.astype(jnp.float32),
        nbits=jnp.asarray(32.0 * n, jnp.float32),
    )


def _dense_decompress(comp, n):
    return comp.dense


@api.register("none")
def make_none(**_):
    # use_residual=True: a dense round transmits ΔW + any pending residual
    # in full and leaves R = 0 — identical to vanilla DSGD when used alone,
    # and the correct "flush" semantics in hybrid sparsity schedules.
    return api.Compressor("none", _dense_compress, _dense_decompress, use_residual=True)


@api.register("fedavg")
def make_fedavg(**_):
    # Federated Averaging == dense updates; the saving comes from the delay
    # schedule (temporal sparsity), handled by the trainer.
    return api.Compressor("fedavg", _dense_compress, _dense_decompress, use_residual=False)


# ---------------------------------------------------- top-k (Grad Dropping)


def _topk_compress(flat, p, rng):
    del rng
    n = flat.shape[0]
    k = api.k_for(n, p)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    nbits = jnp.asarray(k * (32.0 + NAIVE_POS_BITS), jnp.float32)
    return api.LeafCompressed(
        idx=idx.astype(jnp.int32),
        vals=vals.astype(jnp.float32),
        mean=jnp.zeros((), jnp.float32),
        dense=jnp.zeros((0,), jnp.float32),
        nbits=nbits,
    )


def _topk_decompress(comp, n):
    return jnp.zeros((n,), jnp.float32).at[comp.idx].set(comp.vals)


@api.register("topk")
def make_topk(**_):
    return api.Compressor("topk", _topk_compress, _topk_decompress, use_residual=True)


@api.register("dgc")
def make_dgc(**_):
    # Wire-identical to topk; the DGC extras (momentum masking, warm-up
    # sparsity schedule) live in the trainer / sparsity schedule.
    return api.Compressor("dgc", _topk_compress, _topk_decompress, use_residual=True)


# ----------------------------------------------------------------- signSGD


def _sign_compress(flat, p, rng):
    # Scaled sign (SIGNUM-style): our compressors act on weight-DELTAS, so
    # the bare sign must carry a magnitude — we use mean(|Δ|), transmitted as
    # one 32-bit scalar per tensor (recorded in DESIGN.md §8).
    del p, rng
    n = flat.shape[0]
    scale = jnp.mean(jnp.abs(flat))
    return api.LeafCompressed(
        idx=jnp.zeros((0,), jnp.int32),
        vals=jnp.zeros((0,), jnp.float32),
        mean=jnp.zeros((), jnp.float32),
        dense=(scale * jnp.sign(flat)).astype(jnp.float32),
        nbits=jnp.asarray(1.0 * n + 32.0, jnp.float32),
    )


@api.register("signsgd")
def make_signsgd(**_):
    return api.Compressor("signsgd", _sign_compress, _dense_decompress, use_residual=False)


# ----------------------------------------------------------------- 1-bit SGD


def _onebit_compress(flat, p, rng):
    del p, rng
    n = flat.shape[0]
    pos = flat >= 0
    npos = jnp.maximum(jnp.sum(pos), 1)
    nneg = jnp.maximum(n - jnp.sum(pos), 1)
    mu_pos = jnp.sum(jnp.where(pos, flat, 0.0)) / npos
    mu_neg = jnp.sum(jnp.where(pos, 0.0, flat)) / nneg  # negative number
    dense = jnp.where(pos, mu_pos, mu_neg).astype(jnp.float32)
    return api.LeafCompressed(
        idx=jnp.zeros((0,), jnp.int32),
        vals=jnp.zeros((0,), jnp.float32),
        mean=jnp.zeros((), jnp.float32),
        dense=dense,
        nbits=jnp.asarray(1.0 * n + 64.0, jnp.float32),
    )


@api.register("onebit")
def make_onebit(**_):
    return api.Compressor("onebit", _onebit_compress, _dense_decompress, use_residual=True)


# ----------------------------------------------------------------- TernGrad


def _terngrad_compress(flat, p, rng):
    del p
    n = flat.shape[0]
    s = jnp.max(jnp.abs(flat)) + 1e-12
    keep = jax.random.bernoulli(rng, jnp.abs(flat) / s)
    dense = (s * jnp.sign(flat) * keep).astype(jnp.float32)
    nbits = jnp.asarray(jnp.log2(3.0) * n + 32.0, jnp.float32)
    return api.LeafCompressed(
        idx=jnp.zeros((0,), jnp.int32),
        vals=jnp.zeros((0,), jnp.float32),
        mean=jnp.zeros((), jnp.float32),
        dense=dense,
        nbits=nbits,
    )


@api.register("terngrad")
def make_terngrad(**_):
    return api.Compressor(
        "terngrad", _terngrad_compress, _dense_decompress, use_residual=False, stochastic=True
    )


# --------------------------------------------------------------------- QSGD


def _qsgd_compress(flat, p, rng, levels: int = 15):
    del p
    n = flat.shape[0]
    norm = jnp.linalg.norm(flat) + 1e-12
    scaled = jnp.abs(flat) / norm * levels
    floor = jnp.floor(scaled)
    prob = scaled - floor
    quant = floor + jax.random.bernoulli(rng, prob)
    dense = (norm * jnp.sign(flat) * quant / levels).astype(jnp.float32)
    bits_per = jnp.log2(2.0 * levels + 1.0)
    return api.LeafCompressed(
        idx=jnp.zeros((0,), jnp.int32),
        vals=jnp.zeros((0,), jnp.float32),
        mean=jnp.zeros((), jnp.float32),
        dense=dense,
        nbits=jnp.asarray(bits_per * n + 32.0, jnp.float32),
    )


@api.register("qsgd")
def make_qsgd(levels: int = 15, **_):
    return api.Compressor(
        "qsgd",
        partial(_qsgd_compress, levels=levels),
        _dense_decompress,
        use_residual=False,
        stochastic=True,
    )


# ------------------------------------------------------------------ randomk


def _randomk_compress(flat, p, rng):
    n = flat.shape[0]
    k = api.k_for(n, p)
    idx = jax.random.choice(rng, n, shape=(k,), replace=False)
    vals = flat[idx]
    # positions derivable from a shared 32-bit seed → only values go on wire
    nbits = jnp.asarray(k * 32.0 + 32.0, jnp.float32)
    return api.LeafCompressed(
        idx=idx.astype(jnp.int32),
        vals=vals.astype(jnp.float32),
        mean=jnp.zeros((), jnp.float32),
        dense=jnp.zeros((0,), jnp.float32),
        nbits=nbits,
    )


@api.register("randomk")
def make_randomk(**_):
    return api.Compressor(
        "randomk", _randomk_compress, _topk_decompress, use_residual=True, stochastic=True
    )
