"""Baseline compressors the paper compares against (Table I / Table II),
expressed as stage compositions (DESIGN.md §2):

  none        dense|identity|none     32-bit DSGD (the ×1 baseline)
  fedavg      dense|identity|none     dense, residual-free (delay does the
                                      saving — temporal sparsity)
  topk        topk|identity|raw16     Gradient Dropping [Aji & Heafield '17]
  dgc         topk|identity|raw16     DGC [Lin et al. '18]: wire-identical to
                                      topk; the DGC extras (per-leaf dense
                                      biases/norms, warm-up schedule,
                                      momentum masking) live in the policy
                                      (:func:`dgc_policy`) and the trainer
  signsgd     dense|sign|none         signSGD [Bernstein et al. '18], NO
                                      residual (majority vote ≈ sign mean)
  onebit      dense|two_means|none    1-bit SGD [Seide et al. '14]
  terngrad    dense|ternary|none      TernGrad [Wen et al. '17]
  qsgd        dense|stochastic|none   QSGD [Alistarh et al. '17]
  randomk     randomk|identity|seed   sketched updates [Konečný et al. '16]

All analytic bit counts follow the accounting the paper uses in Table I;
the exact byte serialization of every composition lives in
:mod:`repro.core.wire`.
"""
from __future__ import annotations

from repro.core import api
from repro.core.codec import Codec, register_codec
from repro.core.policy import CompressionPolicy, PolicyRule
from repro.core.sparsity import dgc_warmup
from repro.core.stages import get_encoder, get_quantizer, get_selector

NAIVE_POS_BITS = 16.0  # the paper's naive fixed-width position encoding


def _codec(sel: str, quant: str, enc: str, *, use_residual: bool = True,
           **kw) -> Codec:
    return Codec(
        selector=get_selector(sel, **kw),
        quantizer=get_quantizer(quant, **kw),
        encoder=get_encoder(enc, **kw),
        use_residual=use_residual,
    )


# ------------------------------------------------------------------- dense


@register_codec("dense")
def make_dense_codec(**_) -> Codec:
    # use_residual=True: a dense round transmits ΔW + any pending residual
    # in full and leaves R = 0 — identical to vanilla DSGD when used alone,
    # and the correct "flush" semantics in hybrid sparsity schedules.
    return _codec("dense", "identity", "none", use_residual=True)


@api.register("none")
def make_none(**_) -> api.Compressor:
    return api.Compressor.from_codec("none", make_dense_codec())


@api.register("fedavg")
def make_fedavg(**_) -> api.Compressor:
    # Federated Averaging == dense updates; the saving comes from the delay
    # schedule (temporal sparsity), handled by the trainer.
    return api.Compressor.from_codec(
        "fedavg", _codec("dense", "identity", "none", use_residual=False)
    )


# ---------------------------------------------------- top-k (Grad Dropping)


@register_codec("topk")
def make_topk_codec(**_) -> Codec:
    return _codec("topk", "identity", "raw16")


@api.register("topk")
def make_topk(**_) -> api.Compressor:
    return api.Compressor.from_codec("topk", make_topk_codec())


@api.register("dgc")
def make_dgc(**_) -> api.Compressor:
    return api.Compressor.from_codec("dgc", make_topk_codec())


def dgc_policy(
    target_sparsity: float = 0.001,
    warmup_rounds: int = 4,
    dense_pattern: str = r"(^|/)(bias|b|scale|norm|ln[^/]*|gamma|beta)$",
) -> CompressionPolicy:
    """The full DGC recipe as a per-leaf policy (Lin et al. '18 §3):
    biases/norm parameters ride dense, matrices get top-k with the
    exponential sparsity warm-up."""
    warm = dgc_warmup(target_sparsity=target_sparsity,
                      warmup_rounds=warmup_rounds)
    return CompressionPolicy(
        default=make_topk_codec(),
        rules=(
            PolicyRule(dense_pattern, codec="dense32"),
            PolicyRule(r".", schedule=lambda r: warm.sparsity(r)),
        ),
        name="dgc",
    )


@api.register("dgc_policy")
def make_dgc_policy(**kw) -> api.Compressor:
    return api.Compressor.from_policy("dgc_policy", dgc_policy(**kw))


# ----------------------------------------------------------------- signSGD


@register_codec("signsgd")
def make_signsgd_codec(**_) -> Codec:
    return _codec("dense", "sign", "none", use_residual=False)


@api.register("signsgd")
def make_signsgd(**_) -> api.Compressor:
    return api.Compressor.from_codec("signsgd", make_signsgd_codec())


# ----------------------------------------------------------------- 1-bit SGD


@register_codec("onebit")
def make_onebit_codec(**_) -> Codec:
    return _codec("dense", "two_means", "none", use_residual=True)


@api.register("onebit")
def make_onebit(**_) -> api.Compressor:
    return api.Compressor.from_codec("onebit", make_onebit_codec())


# ----------------------------------------------------------------- TernGrad


@register_codec("terngrad")
def make_terngrad_codec(**_) -> Codec:
    return _codec("dense", "ternary", "none", use_residual=False)


@api.register("terngrad")
def make_terngrad(**_) -> api.Compressor:
    return api.Compressor.from_codec("terngrad", make_terngrad_codec())


# --------------------------------------------------------------------- QSGD


@register_codec("qsgd")
def make_qsgd_codec(levels: int = 15, **_) -> Codec:
    return _codec("dense", "stochastic", "none", use_residual=False,
                  levels=levels)


@api.register("qsgd")
def make_qsgd(levels: int = 15, **_) -> api.Compressor:
    return api.Compressor.from_codec("qsgd", make_qsgd_codec(levels=levels))


# ------------------------------------------------------------------ randomk


@register_codec("randomk")
def make_randomk_codec(**_) -> Codec:
    # positions derivable from a shared 32-bit seed → only values are
    # metered on the in-process wire (stages.py 'seed' encoder note)
    return _codec("randomk", "identity", "seed")


@api.register("randomk")
def make_randomk(**_) -> api.Compressor:
    return api.Compressor.from_codec("randomk", make_randomk_codec())


# ------------------------------------------- variance selection (Tsuzuku '18)


@register_codec("variance")
def make_variance_codec(**kw) -> Codec:
    # approximated variance criterion over the accumulated update, full
    # 32-bit values, optimal Golomb positions — the "what if DGC selected
    # by SNR instead of magnitude" point of PAPERS.md
    return _codec("variance", "identity", "golomb", **kw)


@api.register("variance")
def make_variance(**kw) -> api.Compressor:
    return api.Compressor.from_codec("variance", make_variance_codec(**kw))
