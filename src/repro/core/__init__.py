"""Sparse Binary Compression core: the paper's contribution as a library.

Layered as a staged codec pipeline (DESIGN.md):
stages → codec → policy → api (compressor shim), with golomb + wire as the
byte-level serialization and bits as the analytic Eq. 1 accounting.
"""
from repro.core import baselines as _baselines  # registers baseline codecs
from repro.core import sbc as _sbc  # registers "sbc"
from repro.core.api import (
    CompressionPolicy,
    Compressor,
    CompressorState,
    LeafCompressed,
    PolicyRule,
    available,
    get_compressor,
    make_compressor,
)
from repro.core.channel import (
    ChannelBits,
    CommChannel,
    FedWireChannel,
    LocalVmapChannel,
    ShardedGspmdChannel,
    resolve_cached,
)
from repro.core.ledger import BandwidthLedger, RoundRecord
from repro.core.baselines import dgc_policy
from repro.core.codec import Codec, available_codecs, make_codec
from repro.core.golomb import (
    decode_positions,
    encode_positions,
    expected_position_bits,
    golomb_bstar,
)
from repro.core.policy import ResolvedPolicy
from repro.core.sbc import SBC_PRESETS
from repro.core.sparsity import SparsitySchedule, adaptive_total_budget, constant, preset
from repro.core.stages import available_stages, decompress_leaf
from repro.core.wire import LeafSpec, Wire, wire_for

__all__ = [
    "BandwidthLedger",
    "ChannelBits",
    "Codec",
    "CommChannel",
    "CompressionPolicy",
    "FedWireChannel",
    "LocalVmapChannel",
    "RoundRecord",
    "ShardedGspmdChannel",
    "Compressor",
    "CompressorState",
    "LeafCompressed",
    "LeafSpec",
    "PolicyRule",
    "ResolvedPolicy",
    "SBC_PRESETS",
    "SparsitySchedule",
    "Wire",
    "adaptive_total_budget",
    "available",
    "available_codecs",
    "available_stages",
    "constant",
    "decode_positions",
    "decompress_leaf",
    "dgc_policy",
    "encode_positions",
    "expected_position_bits",
    "get_compressor",
    "golomb_bstar",
    "make_codec",
    "make_compressor",
    "preset",
    "resolve_cached",
    "wire_for",
]
