"""Sparse Binary Compression core: the paper's contribution as a library."""
from repro.core import baselines as _baselines  # registers baseline compressors
from repro.core import sbc as _sbc  # registers "sbc"
from repro.core.api import (
    Compressor,
    CompressorState,
    LeafCompressed,
    available,
    get_compressor,
)
from repro.core.golomb import (
    decode_positions,
    encode_positions,
    expected_position_bits,
    golomb_bstar,
)
from repro.core.sbc import SBC_PRESETS
from repro.core.sparsity import SparsitySchedule, adaptive_total_budget, constant, preset

__all__ = [
    "Compressor",
    "CompressorState",
    "LeafCompressed",
    "available",
    "get_compressor",
    "encode_positions",
    "decode_positions",
    "expected_position_bits",
    "golomb_bstar",
    "SBC_PRESETS",
    "SparsitySchedule",
    "adaptive_total_budget",
    "constant",
    "preset",
]
