"""One ``CommChannel`` surface over the three compress → exchange →
aggregate → account loops (DESIGN.md §12).

The repo grew three parallel implementations of the paper's communication
round — the vmapped local trainer (:mod:`repro.train.trainer`), the GSPMD
``shard_map`` backend (:mod:`repro.launch.dist`), and the wire-level
federated stack (:mod:`repro.fed`) — each with its own fast-path dispatch
ladder, residual-state shape, and bit accounting.  This module extracts
that loop behind one protocol so a single declarative
:class:`~repro.run.RunSpec` can drive any backend:

  :class:`LocalVmapChannel`    per-client compression as a leading vmap
                               axis; exchange = mean over clients (the
                               CPU-scale paper reproduction).
  :class:`ShardedGspmdChannel` per-shard compression inside ``shard_map``;
                               exchange = packed (positions, μ)
                               all-gather / pmean over the client mesh
                               axes (§4/§11).
  :class:`FedWireChannel`      real packed SBW1 bytes both directions
                               through a parameter server (§9).

Every channel owns

  ``init_state``      allocate the per-client compressor state (residual,
                      RNG, step) in this backend's native layout — flat
                      §10/§11 buffers when the fast path is active,
                      per-leaf pytrees otherwise (the dispatch ladder that
                      used to be copy-pasted per backend lives HERE);
  ``round_exchange``  one round's compress + exchange + aggregate;
  ``bits``            the static Eq. 1/Eq. 5 analytic accounting;
  ``ledger``          a :class:`~repro.core.ledger.BandwidthLedger` of
                      measured-vs-analytic traffic, uniform across
                      backends for the first time.

All three dispatch the §10/§11 flat fast paths and the per-leaf exact path
behind this one surface, bit-identical to the pre-channel code (the parity
matrix in ``tests/test_channel_parity.py`` holds them to that).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import (
    Any,
    Dict,
    NamedTuple,
    Optional,
    Protocol,
    Sequence,
    Tuple,
    Union,
    runtime_checkable,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import Compressor
from repro.core.golomb import encode_positions, expected_position_bits
from repro.core.ledger import BandwidthLedger, RoundRecord
from repro.obs import NULL_TELEMETRY
from repro.core.policy import CompressionPolicy, CompressorState, ResolvedPolicy
from repro.core.wire import Wire, wire_for

try:  # jax >= 0.7 moved shard_map to the top level
    from jax import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_vma=False)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=False)

PyTree = Any


class ChannelBits(NamedTuple):
    """Static analytic wire accounting for one round (Eq. 1 terms)."""

    per_client: float  # upstream bits one client sends per round
    dense: float  # the 32-bit dense equivalent


@runtime_checkable
class CommChannel(Protocol):
    """The backend-agnostic compress→exchange→aggregate→account surface.

    Implementations differ in *where* the exchange runs (vmap mean /
    mesh collective / real bytes), but all expose the same four members,
    which is what :func:`repro.run.build_run` programs against.
    """

    ledger: BandwidthLedger

    def init_state(self, params: PyTree, rng: jax.Array) -> Any:
        """Allocate this backend's per-client compressor state."""
        ...

    def round_exchange(self, *args: Any, **kw: Any) -> Any:
        """One communication round's compress + exchange + aggregate."""
        ...

    def bits(self, *args: Any, **kw: Any) -> ChannelBits:
        """Static Eq. 1/Eq. 5 analytic accounting for one round."""
        ...


# ------------------------------------------------------- policy resolution

# bounded: policies holding fresh closures (e.g. per-call dgc_policy
# schedules) hash by identity, so unbounded growth would pin every
# ResolvedPolicy (and its flat spaces / jit caches) for process lifetime
_RESOLVE_CACHE: Dict[Any, ResolvedPolicy] = {}
_RESOLVE_CACHE_MAX = 64


def _layout_key(params: PyTree) -> Optional[tuple]:
    try:
        flat, treedef = jax.tree.flatten(params)
        return (
            treedef,
            tuple(
                (tuple(getattr(x, "shape", np.shape(x))),
                 str(getattr(x, "dtype", type(x))))
                for x in flat
            ),
        )
    except TypeError:
        return None


def resolve_cached(policy: CompressionPolicy, params: PyTree) -> ResolvedPolicy:
    """Resolve ``policy`` against ``params``' layout ONCE per topology.

    The federated server/pool used to re-resolve the up/down policies on
    every rebuild (``ParameterServer.__post_init__`` on profile changes);
    sharing the bound :class:`ResolvedPolicy` here also shares its flat
    spaces and jit caches across server, pool, and ledger metering.
    """
    layout = _layout_key(params)
    try:
        key = (policy, layout) if layout is not None else None
        hash(key)
    except TypeError:
        key = None
    if key is None:
        return policy.resolve(params)
    got = _RESOLVE_CACHE.get(key)
    if got is None:
        got = policy.resolve(params)
        while len(_RESOLVE_CACHE) >= _RESOLVE_CACHE_MAX:  # FIFO eviction
            _RESOLVE_CACHE.pop(next(iter(_RESOLVE_CACHE)))
        _RESOLVE_CACHE[key] = got
    return got


def analytic_bits(resolved: ResolvedPolicy, leaves: Sequence,
                  rates: Sequence[float]) -> ChannelBits:
    """Static Eq. 1 accounting for ONE client's upload at ``rates``:
    per sparse leaf ``position_bits(n, k, p) + value_bits(k)``, dense
    leaves pay the quantizer's value bits for the full leaf, skipped
    leaves nothing — the one pricing walk every channel shares."""
    from repro.core.stages import k_for

    per_client = dense = 0.0
    for plan, leaf, p in zip(resolved.plans, leaves, rates):
        n = int(np.prod(getattr(leaf, "shape", np.shape(leaf))) or 1)
        dense += 32.0 * n
        codec = plan.codec
        if codec.skip:
            continue
        if codec.selector.dense:
            per_client += float(codec.quantizer.value_bits(n))
            continue
        k = k_for(n, p)
        per_client += float(
            codec.encoder.position_bits(n, k, p) + codec.quantizer.value_bits(k)
        )
    return ChannelBits(per_client=per_client, dense=dense)


# ============================================================ local backend


class LocalExchange(NamedTuple):
    """One vmapped round's exchange outputs (all traced)."""

    mean_delta: PyTree  # ΔW = mean_i ΔW*_i (Alg. 1 l.17)
    transmitted: PyTree  # per-client dense ΔW*_i (leading C axis)
    state: CompressorState  # advanced per-client compressor state
    bits_per_client: jax.Array  # analytic Eq. 1 bits, mean over clients
    compressed0: Optional[PyTree]  # client 0's LeafCompressed tree, or None


@dataclasses.dataclass(eq=False)  # id-hash → usable under jit-static closure
class LocalVmapChannel:
    """Per-client compression along a leading vmap axis; the exchange is a
    mean over that axis — extracted from ``DSGDTrainer.round_step``
    (Alg. 1 l.11-17), bit-identical to the pre-channel trainer."""

    compressor: Compressor
    n_clients: int
    residual_dtype: Any = jnp.float32

    def __post_init__(self) -> None:
        self.ledger = BandwidthLedger()
        self.telemetry = NULL_TELEMETRY  # build_run swaps in an enabled one
        self._resolved: Optional[ResolvedPolicy] = None
        self._wires: Dict[tuple, Wire] = {}

    # ------------------------------------------------------------- protocol

    def resolved(self, params: PyTree) -> ResolvedPolicy:
        if self._resolved is None:
            self._resolved = resolve_cached(self.compressor.policy, params)
        return self._resolved

    def init_state(self, params: PyTree, rng: jax.Array) -> CompressorState:
        """Per-client state with a leading C axis; the residual rides the
        §10 flat layout when the policy's fast path is active."""
        comp = self.compressor.init_state(
            jax.tree.map(lambda x: x.astype(self.residual_dtype), params)
        )
        stack = lambda tree: jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n_clients,) + x.shape).copy(), tree
        )
        return CompressorState(
            residual=stack(comp.residual),
            rng=jax.random.split(rng, self.n_clients),
            step=jnp.zeros((self.n_clients,), jnp.int32),
        )

    def round_exchange(
        self,
        deltas: PyTree,  # per-client ΔW_i, leading C axis (traced)
        state: CompressorState,
        rates: Union[float, Tuple[float, ...]],
        *,
        return_compressed: bool = False,
    ) -> LocalExchange:
        """Compress every client's update with error feedback and average
        (traced; called inside the trainer's jitted round)."""

        def compress_one(delta, comp_state):
            ctree, dense, new_state = self.compressor.compress(
                delta, comp_state, rates
            )
            bits = self.compressor.total_bits(ctree)
            return ctree, dense, new_state, bits

        ctrees, dense, new_state, bits = jax.vmap(compress_one)(deltas, state)
        mean_delta = jax.tree.map(lambda d: jnp.mean(d, axis=0), dense)
        comp0 = (
            jax.tree.map(lambda x: x[0], ctrees) if return_compressed else None
        )
        return LocalExchange(
            mean_delta=mean_delta,
            transmitted=dense,
            state=new_state,
            bits_per_client=jnp.mean(bits),
            compressed0=comp0,
        )

    def bits(self, params: PyTree, rates: Tuple[float, ...],
             n_delay: int = 1) -> ChannelBits:
        """Static Eq. 1 accounting at ``rates`` (host-side floats)."""
        resolved = self.resolved(params)
        b = analytic_bits(resolved, resolved._leaves_of(params), rates)
        return ChannelBits(per_client=b.per_client, dense=b.dense * n_delay)

    # ------------------------------------------------------------ metering

    def wire(self, params: PyTree, rate: float, round_idx: int) -> Wire:
        resolved = self.resolved(params)
        key = resolved.rates(rate, round_idx)
        if key not in self._wires:
            self._wires[key] = wire_for(resolved, params, rate, round_idx)
        return self._wires[key]

    def record_round(
        self,
        round_idx: int,
        *,
        params: PyTree,
        compressed0: PyTree,
        rate: float,
        bits_analytic_per_client: float,
        device_pack: bool = False,
    ) -> float:
        """Meter client 0's real packed upload and extrapolate ×C into the
        ledger (every client's analytic size is identical; measured sizes
        are one geometric draw each).  Returns client 0's measured bits.

        With ``device_pack`` the Golomb position streams are produced by
        the fused select→pack Pallas kernel (byte-identical to the host
        encoder — held by tests/test_channel_parity.py)."""
        with self.telemetry.span("encode", round=round_idx, client=0):
            w = self.wire(params, rate, round_idx)
            blob, bits = w.pack_with_bits(compressed0, device_pack=device_pack)
        measured = float(bits)
        up_bytes = len(blob) * self.n_clients
        self.ledger.record_up(
            round_idx,
            clients=tuple(range(self.n_clients)),
            up_bytes=up_bytes,
            up_bits_measured=measured * self.n_clients,
            up_bits_analytic=float(bits_analytic_per_client) * self.n_clients,
        )
        return measured


# ============================================================ gspmd backend


def _sbc_local(acc_flat: jax.Array, p: float, client_axes, n_clients: int,
               out_dtype=jnp.float32):
    """Inside shard_map: exact per-shard SBC (paper Alg. 2) + sparse exchange.

    acc_flat: (L, n_loc) — residual-accumulated ΔW, THIS device's shard
    (any float dtype; per-layer math runs in f32).
    Returns (mean_delta (L, n_loc), own_delta_star (L, n_loc)) in out_dtype.

    Layers are processed through a lax.scan so only ONE layer's f32
    working set is live at a time (§Perf lowmem iteration — the vmap
    formulation materialized 3 full-leaf f32 buffers).
    """
    L, n_loc = acc_flat.shape
    k = max(1, min(n_loc, int(round(p * n_loc))))

    def one_layer(_, x_row):
        x = x_row.astype(jnp.float32)
        val_pos, idx_pos = jax.lax.top_k(x, k)
        val_neg, idx_neg = jax.lax.top_k(-x, k)
        mu_pos, mu_neg = jnp.mean(val_pos), jnp.mean(val_neg)
        pos_wins = mu_pos > mu_neg
        idx = jnp.where(pos_wins, idx_pos, idx_neg).astype(jnp.int32)
        mu = jnp.where(pos_wins, mu_pos, -mu_neg).astype(jnp.float32)
        own_row = jnp.zeros((n_loc,), out_dtype).at[idx].set(mu.astype(out_dtype))
        return None, (idx, mu, own_row)

    _, (idx, mu, own) = jax.lax.scan(one_layer, None, acc_flat)

    if client_axes and n_clients > 1:
        # THE exchange: tiny (idx, μ) tensors cross the client axes.
        gidx, gmu = idx, mu
        for ax in client_axes:
            gidx = jax.lax.all_gather(gidx, ax)
            gmu = jax.lax.all_gather(gmu, ax)
        gidx = gidx.reshape(n_clients, L, k)
        gmu = gmu.reshape(n_clients, L)

        def dense_layer(_, args):
            rows_i, mus_i = args  # (C, k), (C,)
            row = jnp.zeros((n_loc,), jnp.float32)

            def add(acc, ci):
                return acc.at[rows_i[ci]].add(mus_i[ci] / n_clients), None

            row, _ = jax.lax.scan(add, row, jnp.arange(n_clients))
            return None, row.astype(out_dtype)

        _, dense = jax.lax.scan(
            dense_layer, None, (gidx.transpose(1, 0, 2), gmu.transpose(1, 0))
        )
    else:
        dense = own
    return dense, own


def _dense_local(acc_flat, client_axes, n_clients):
    """Dense baseline: pmean over clients == all-reduce of the full ΔW."""
    out = acc_flat
    for ax in client_axes:
        out = jax.lax.pmean(out, ax)
    return out, acc_flat


class GspmdLeaf(NamedTuple):
    """One leaf's static plan in the GSPMD channel (mesh-free data — the
    launch layer derives it from the mesh + PartitionSpecs)."""

    path: str
    global_shape: Tuple[int, ...]
    dtype: Any
    scanned: bool  # leading scan/stack superblock dim
    mode: str  # "sparse" | "dense" | "skip"
    rate: float  # static per-leaf sparsity rate
    n_shards: int  # distinct shards of the global leaf
    shard_grid: Tuple[int, ...]  # per-dim shard counts (for host metering)


def _iter_shard_blocks(arr: np.ndarray, grid: Tuple[int, ...]):
    """Yield the GSPMD equal-block shards of a global array, in grid order."""
    grid = tuple(grid) + (1,) * (arr.ndim - len(grid))
    sizes = [d // g for d, g in zip(arr.shape, grid)]
    for idx in itertools.product(*[range(g) for g in grid]):
        yield arr[tuple(slice(i * s, (i + 1) * s) for i, s in zip(idx, sizes))]


@dataclasses.dataclass(eq=False)
class ShardedGspmdChannel:
    """Per-shard compression inside ``shard_map``; the exchange crosses the
    client mesh axes as packed (positions, μ) all-gathers (sparse), pmean
    all-reduces (dense), or nothing (skip) — extracted from
    ``repro.launch.dist.make_dist_train``'s exchange bodies + bit
    accounting, bit-identical to the pre-channel lowering.

    ``flat_space`` is the §11 :class:`ShardedFlatParamSpace` when the flat
    fast path applies, else None (per-leaf exchange).  The methods named
    ``exchange*`` are shard_map BODIES: the launch layer owns the mesh and
    wraps them with the right in/out specs.
    """

    leaves: Tuple[GspmdLeaf, ...]
    client_axes: Tuple[str, ...]
    n_clients: int
    residual_dtype: Any = jnp.float32
    flat_space: Any = None  # ShardedFlatParamSpace | None
    flat_engine: str = "exact"  # "exact" | "hist"
    device_pack: bool = False  # pack Golomb wire streams on-device (§11)

    def __post_init__(self) -> None:
        if self.flat_engine not in ("exact", "hist"):
            raise ValueError(f"unknown flat_engine {self.flat_engine!r}")
        if self.flat_engine == "hist" and self.flat_space is None:
            raise ValueError(
                "flat_engine='hist' needs the sharded flat fast path "
                "(fast=True with all-f32 leaves and an f32 residual_dtype)"
            )
        if self.device_pack and (
            self.flat_space is None or self.flat_engine != "exact"
        ):
            raise ValueError(
                "device_pack needs the sharded flat fast path with the "
                "exact engine (fast=True, flat_engine='exact', all-f32 "
                "leaves) — the hist engine and the per-leaf exchange have "
                "no packed position stream to produce on-device"
            )
        self.ledger = BandwidthLedger()
        self.telemetry = NULL_TELEMETRY  # build_run swaps in an enabled one

    # ------------------------------------------------------------- protocol

    def init_state(self, params: PyTree, rng: jax.Array = None) -> PyTree:
        """The per-client error-feedback residual in this channel's native
        layout: ONE flat sharded f32 buffer on the fast path (§11), a
        stacked per-leaf pytree otherwise."""
        if self.flat_space is not None:
            return self.flat_space.zeros_residual()
        return jax.tree.map(
            lambda x: jnp.zeros((self.n_clients,) + x.shape, self.residual_dtype),
            params,
        )

    def round_exchange(self, residual: PyTree, deltas: PyTree,
                       *, mesh, in_specs, res_spec, need_own: bool) -> tuple:
        """One round's compress + exchange under ``shard_map``.

        ``deltas`` is the per-client ΔW tree (leading client axis) and
        ``residual`` this channel's state from :meth:`init_state`; returns
        ``(mean_tree, new_residual, own_tree_or_None)``.  ``need_own``
        materializes each client's ΔW*_i (momentum masking / metering).
        """
        delta_leaves, treedef = jax.tree.flatten(deltas)
        own_specs = (
            tuple(in_specs) if need_own else tuple(type(s)() for s in in_specs)
        )
        packed = None
        if self.device_pack:
            # extra outputs: this round's device-packed Golomb word
            # buffers + exact per-row bit counts for EVERY (client,
            # shard) — same layout/sharding as the flat residual
            mean_leaves, new_residual, own_leaves, packed = shard_map(
                lambda res, *leaves: self.exchange_flat(res, leaves, need_own),
                mesh=mesh, in_specs=(res_spec,) + tuple(in_specs),
                out_specs=(tuple(in_specs), res_spec, own_specs,
                           (res_spec, res_spec)),
            )(residual, *delta_leaves)
        elif self.flat_space is not None:
            mean_leaves, new_residual, own_leaves = shard_map(
                lambda res, *leaves: self.exchange_flat(res, leaves, need_own),
                mesh=mesh, in_specs=(res_spec,) + tuple(in_specs),
                out_specs=(tuple(in_specs), res_spec, own_specs),
            )(residual, *delta_leaves)
        else:
            # residual add (Alg. 1 l.10): acc = R + ΔW
            acc = jax.tree.map(
                lambda r, d: (r.astype(jnp.float32) + d.astype(jnp.float32)).astype(
                    self.residual_dtype
                ),
                residual,
                deltas,
            )
            acc_leaves = jax.tree.leaves(acc)
            mean_leaves, res_leaves, own_leaves = shard_map(
                lambda *leaves: self.exchange_per_leaf(leaves, need_own),
                mesh=mesh, in_specs=tuple(in_specs),
                out_specs=(tuple(in_specs), tuple(in_specs), own_specs),
            )(*acc_leaves)
            new_residual = jax.tree.unflatten(treedef, res_leaves)
        mean_tree = jax.tree.unflatten(treedef, mean_leaves)
        own_tree = (
            jax.tree.unflatten(treedef, own_leaves) if need_own else None
        )
        if self.device_pack:
            return mean_tree, new_residual, own_tree, packed
        return mean_tree, new_residual, own_tree

    # -------------------------------------------------- shard_map bodies

    def exchange_per_leaf(self, leaves: Sequence[jax.Array],
                          need_own: bool) -> tuple:
        """Per-leaf body: compress own shard with the LEAF'S codec, exchange,
        and emit (mean ΔW, NEW residual = acc − own) — own itself never
        leaves the shard_map unless the caller needs it (§Perf B9)."""
        means, residuals, owns = [], [], []
        for leaf, gl in zip(leaves, self.leaves):
            body = leaf[0]  # client dim is locally 1 (sharded over clients)
            L = body.shape[0] if gl.scanned and body.ndim > 1 else 1
            flat = body.reshape(L, -1)
            if gl.mode == "sparse":
                dense, own = _sbc_local(flat, gl.rate, self.client_axes,
                                        self.n_clients, out_dtype=leaf.dtype)
            elif gl.mode == "dense":
                dense, own = _dense_local(flat.astype(jnp.float32),
                                          self.client_axes, self.n_clients)
            else:  # skip: no traffic; the residual keeps the full update
                dense = jnp.zeros_like(flat, dtype=leaf.dtype)
                own = dense
            new_res = (flat.astype(jnp.float32) - own.astype(jnp.float32)).astype(
                self.residual_dtype
            )
            means.append(dense.reshape(body.shape).astype(leaf.dtype)[None])
            residuals.append(new_res.reshape(body.shape).astype(leaf.dtype)[None])
            owns.append(own.reshape(body.shape).astype(leaf.dtype)[None]
                        if need_own else jnp.zeros((1,) * leaf.ndim, leaf.dtype))
        return tuple(means), tuple(residuals), tuple(owns)

    def exchange_flat(self, res: jax.Array, leaves: Sequence[jax.Array],
                      need_own: bool) -> tuple:
        """§11 flat body: residual add + compression + the packed
        (positions, μ) collective all run on ONE flat buffer per device,
        one launch per pass."""
        space = self.flat_space
        bodies = [leaf[0] for leaf in leaves]
        packed = None
        if self.device_pack:
            mean_f, own_f, new_res_f, words, nbits = space.exchange_local(
                bodies, res[0, 0], device_pack=True
            )
            packed = (words[None, None], nbits[None, None])
        else:
            fn = (space.exchange_local if self.flat_engine == "exact"
                  else space.exchange_local_hist)
            mean_f, own_f, new_res_f = fn(bodies, res[0, 0])
        means = tuple(
            m.astype(leaf.dtype)[None] for m, leaf in
            zip(space.unflatten_local(mean_f), leaves)
        )
        if need_own:
            owns = tuple(
                o.astype(leaf.dtype)[None] for o, leaf in
                zip(space.unflatten_local(own_f), leaves)
            )
        else:
            owns = tuple(
                jnp.zeros((1,) * leaf.ndim, leaf.dtype) for leaf in leaves
            )
        if self.device_pack:
            return means, new_res_f[None, None], owns, packed
        return means, new_res_f[None, None], owns

    # ------------------------------------------------------- bit accounting

    def bits(self) -> ChannelBits:
        """Static Eq. 1 bits per round per client: per sparse leaf
        ``L·S_shards·(k_loc·b̄_pos(p_leaf) + 32)``, dense 32 bits/entry,
        skip 0 — summed from the §11 per-(segment, shard) table when the
        fast path is active (same totals)."""
        per_client = dense = 0.0
        for gl in self.leaves:
            size = int(np.prod(gl.global_shape) or 1)
            L = gl.global_shape[0] if gl.scanned and len(gl.global_shape) > 1 else 1
            n_loc = max(1, size // (L * gl.n_shards))
            if gl.mode == "sparse":
                k_loc = max(1, min(n_loc, int(round(gl.rate * n_loc))))
                per_client += L * gl.n_shards * (
                    k_loc * expected_position_bits(gl.rate) + 32.0
                )
            elif gl.mode == "dense":
                per_client += 32.0 * size
            dense += 32.0 * size
        if self.flat_space is not None:
            # same totals, summed from the per-(segment, shard) table (§11)
            per_client = self.flat_space.bits_per_client()
        return ChannelBits(per_client=per_client, dense=dense)

    # ------------------------------------------------------------ metering

    def measured_bits(self, own_tree: PyTree) -> float:
        """Real wire bits of ONE client's transmitted update: per
        (leaf, shard, row), Golomb-encode the ACTUAL surviving positions
        (paper Alg. 3's bitstream, one geometric draw vs Eq. 5) plus one
        32-bit μ; dense leaves pay 32 bits/entry, skip leaves nothing.
        Host-side numpy over the client's dense ΔW*."""
        total = 0.0
        for gl, leaf in zip(self.leaves, jax.tree.leaves(own_tree)):
            arr = np.asarray(leaf)
            if gl.mode == "dense":
                total += 32.0 * arr.size
                continue
            if gl.mode == "skip":
                continue
            for block in _iter_shard_blocks(arr, gl.shard_grid):
                L = block.shape[0] if gl.scanned and block.ndim > 1 else 1
                for row in block.reshape(L, -1):
                    pos = np.flatnonzero(row)
                    total += float(encode_positions(pos, gl.rate).size) + 32.0
        return total

    def measured_bits_per_client(self, packed_nbits) -> list:
        """Real wire bits of EVERY client's upload, from the device-packed
        streams' exact bit counts.

        ``packed_nbits`` is the second ``round_exchange`` packed output:
        i32[n_clients, shards_per_client, n_mu] per-(client, shard, row)
        Golomb position bits.  Each client pays its own position streams
        + one 32-bit μ per (shard, row) + 32 bits/entry for dense leaves
        — no host re-encode, no client-0 sampling.  Unlike the sampled
        :meth:`measured_bits` (which infers positions from the nonzeros
        of the reconstructed ΔW*), these counts meter the stream as
        transmitted, including positions whose μ is exactly zero.
        """
        nb = np.asarray(jax.device_get(packed_nbits))
        dense = sum(
            32.0 * int(np.prod(gl.global_shape) or 1)
            for gl in self.leaves if gl.mode == "dense"
        )
        # The S axis is DEVICES per client, not distinct shards: a segment
        # replicated over a shard axis (n_shards < S) is packed identically
        # on every replica, so weight each μ-row by n_shards/S to count
        # every distinct stream exactly once (matching the sampled host
        # path, which iterates shard_grid blocks).
        S = nb.shape[1]
        sparse = self.flat_space._sparse
        row_w = (
            np.concatenate(
                [np.full((s.rows,), s.n_shards / S) for s in sparse]
            )
            if sparse else np.zeros((0,))
        )
        pos_bits = (nb.astype(np.float64) * row_w[None, None, :]).sum(axis=(1, 2))
        mu_bits = 32.0 * float(row_w.sum()) * S  # one μ per distinct (shard, row)
        return [float(pos_bits[c]) + mu_bits + dense for c in range(nb.shape[0])]

    def record_round(
        self,
        round_idx: int,
        *,
        own_client0: PyTree = None,
        packed_nbits=None,
    ) -> float:
        """Meter the round's uploads into the ledger; returns bits/client.

        With ``packed_nbits`` (device_pack active): EVERY client's real
        packed stream is metered from the device-side bit counts — the
        ledger row is a true cohort sum and the return value the cohort
        mean.  Without it, CLIENT 0's upload is host-encoded and
        extrapolated ×C (one geometric draw, explicitly a sample — see
        docs/wire-format.md).
        """
        analytic = self.bits().per_client
        if packed_nbits is not None:
            with self.telemetry.span("encode", round=round_idx):
                per_client = self.measured_bits_per_client(packed_nbits)
            for ci, b in enumerate(per_client):
                self.telemetry.metrics.gauge(
                    "wire/client_bits_measured", b,
                    round=round_idx, client=ci,
                )
            total = float(sum(per_client))
            self.ledger.record_up(
                round_idx,
                clients=tuple(range(self.n_clients)),
                up_bytes=sum(int(-(-b // 8)) for b in per_client),
                up_bits_measured=total,
                up_bits_analytic=analytic * self.n_clients,
            )
            return total / self.n_clients
        with self.telemetry.span("encode", round=round_idx, client=0):
            measured = self.measured_bits(own_client0)
        self.telemetry.metrics.gauge(
            "wire/own_client0_bits_measured", measured,
            round=round_idx, client=0,
        )
        self.ledger.record_up(
            round_idx,
            clients=tuple(range(self.n_clients)),
            up_bytes=int(-(-measured // 8)) * self.n_clients,
            up_bits_measured=measured * self.n_clients,
            up_bits_analytic=analytic * self.n_clients,
        )
        return measured


# ============================================================== fed backend


@dataclasses.dataclass(eq=False)
class FedWireChannel:
    """Wire-level channel: real packed SBW1 buffers cross in BOTH
    directions through a :class:`~repro.fed.server.ParameterServer`, with
    a cohort of :class:`~repro.fed.clients.ClientPool` members on the
    other end — extracted from ``RoundScheduler.step`` (DESIGN.md §9).

    The server and pool share ONE cached :class:`ResolvedPolicy` per
    (policy, topology) via :func:`resolve_cached`, so profile changes or
    server rebuilds no longer re-resolve the up/down policies, and the
    per-round rate tuples of schedule-free policies are memoized
    (``ResolvedPolicy.rates``).
    """

    server: Any  # repro.fed.server.ParameterServer
    pool: Any  # repro.fed.clients.ClientPool

    def __post_init__(self) -> None:
        self.ledger = BandwidthLedger()
        self.telemetry = NULL_TELEMETRY  # build_run swaps in an enabled one
        # DeltaLog-backed downstream (server.delta_horizon set): per-client
        # last-synced round + one CatchupPlanner over the server's log
        self._last_sync: Dict[int, int] = {}
        self._planner: Any = None
        # a mid-round kill (ServerKilled at post_aggregate) parks the
        # aggregated-but-unbroadcast round here; checkpointable, finished
        # by _finish_round on resume
        self._pending: Optional[dict] = None

    # ------------------------------------------------------------- protocol

    def init_state(self, params: Optional[PyTree] = None,
                   rng: Optional[jax.Array] = None) -> None:
        """Allocate the pool's per-client state from the server replica."""
        self.pool.init(params if params is not None else self.server.estimate,
                       rng)

    def round_exchange(
        self,
        round_idx: int,
        cohort: Sequence[int],
        start_params: PyTree,
        staleness: Optional[np.ndarray] = None,
        faults: Any = None,
        straggler_timeout: Optional[float] = None,
        kill_step: Optional[str] = None,
    ) -> dict:
        """One federated round: run the cohort, pack real uploads, decode +
        aggregate server-side, compress the broadcast, meter both
        directions into the ledger.

        Elasticity (DESIGN.md §14): ``faults`` is a
        :class:`~repro.fed.faults.FaultSchedule` whose slow/corrupt entries
        apply to this round; ``straggler_timeout`` aborts uploads whose
        simulated duration ``profile.delay × slowdown`` exceeds it.  A
        failed participation (straggler abort or decode-rejected corrupt
        upload) rolls the member's pool state back to its pre-round
        snapshot and meters the spent bytes as ``up_bytes_wasted``; the
        ``up_*`` columns cover ACCEPTED uploads only, so partial
        aggregation reconciles like a survivors-only round.
        ``kill_step="post_aggregate"`` raises
        :class:`~repro.fed.faults.ServerKilled` after aggregation with the
        unfinished round parked in ``self._pending`` (resumed via
        :meth:`_finish_round`)."""
        from repro.fed.faults import NO_FAULTS, ServerKilled, straggler_ids
        from repro.fed.server import ClientUpdate

        fsched = faults if faults is not None else NO_FAULTS
        if staleness is None:
            staleness = np.zeros((len(cohort),), np.int64)

        log = getattr(self.server, "delta_log", None)
        catchup = None
        if log is not None:
            # the broadcast rides the DeltaLog: each cohort member PULLS
            # the cheapest catch-up (replay / stacked / full) from its
            # last-synced round up to the current head before training —
            # one plan/encode per distinct lag class, bytes shared within
            # the class — instead of paying a fresh per-member broadcast
            from repro.serve.broadcast import CatchupPlanner

            if self._planner is None or self._planner.log is not log:
                self._planner = CatchupPlanner(log, telemetry=self.telemetry)
            plans: Dict[int, Any] = {}
            down_bytes = 0
            down_m = down_a = 0.0
            for cid in cohort:
                frm = self._last_sync.get(int(cid), -1)
                plan = plans.get(frm)
                if plan is None:
                    plan = plans[frm] = self._planner.plan(frm)
                down_bytes += plan.nbytes
                down_m += plan.bits_measured
                down_a += plan.bits_analytic
                self._last_sync[int(cid)] = log.head
            catchup = (down_bytes, down_m, down_a)

        # at-risk members (stragglers to abort, uploads to corrupt) get a
        # pre-round snapshot: a failed participation must leave residual/
        # momentum/rng bit-identical to never having run
        delays = {int(c): self.pool.profile_of(int(c)).delay for c in cohort}
        stragglers = straggler_ids(
            fsched, round_idx, cohort, delays, straggler_timeout
        )
        corrupts = fsched.corrupts_at(round_idx) & {int(c) for c in cohort}
        at_risk = sorted(stragglers | corrupts)
        snap = self.pool.snapshot_clients(at_risk) if at_risk else None

        tel = self.telemetry
        tel.metrics.gauge("fed/cohort_size", len(cohort), round=round_idx)
        with tel.span("select_quantize", round=round_idx, cohort=len(cohort)):
            result = self.pool.run_cohort(round_idx, cohort, start_params)
            tel.fence(result.losses if hasattr(result, "losses") else None)

        uploads, blob_len, wasted = [], {}, 0
        with tel.span("encode", round=round_idx, cohort=len(cohort)):
            for i, cid in enumerate(result.client_ids):
                wire = self.server.up_wire(result.rates[i], round_idx)
                blob = wire.pack(result.ctrees[i])
                if int(cid) in stragglers:
                    # timed out mid-upload: the work and bytes are spent,
                    # but the server never sees them
                    wasted += len(blob)
                    continue
                if int(cid) in corrupts:
                    blob = fsched.corrupt_blob(blob, round_idx, int(cid))
                blob_len[int(cid)] = len(blob)
                uploads.append(
                    ClientUpdate(
                        client_id=cid, blob=blob, rate=result.rates[i],
                        weight=result.weights[i], staleness=int(staleness[i]),
                    )
                )
        info = self.server.receive(uploads, round_idx)
        accepted = [int(c) for c in info["accepted"]]
        rejected = [int(c) for c in info["rejected"]]
        up_bytes = sum(blob_len[c] for c in accepted)
        wasted += sum(blob_len[c] for c in rejected)
        failed = sorted(stragglers | set(rejected))
        if snap is not None and failed:
            self.pool.restore_clients(snap, only=failed)
        acc_set = set(accepted)
        acc_pos = [
            i for i, c in enumerate(result.client_ids) if int(c) in acc_set
        ]
        pending = {
            "round_idx": int(round_idx),
            "cohort": [int(c) for c in cohort],
            "accepted": accepted,
            "rejected": rejected,
            "stragglers": sorted(stragglers),
            "up_bytes": int(up_bytes),
            "up_bytes_wasted": int(wasted),
            "up_bits_measured": float(info["up_bits_measured"]),
            "up_bits_analytic": float(
                np.sum(np.asarray(result.bits_analytic)[acc_pos])
            ) if acc_pos else 0.0,
            "loss": float(
                np.mean(np.asarray(result.losses)[acc_pos])
            ) if acc_pos else float("nan"),
            "update_norm": float(info["update_norm"]),
            "weights": [float(w) for w in info["weights"]],
            "staleness": [int(s) for s in staleness],
            "catchup": catchup,
        }
        if kill_step == "post_aggregate":
            self._pending = pending
            raise ServerKilled(round_idx, "post_aggregate")
        return self._finish_round(pending)

    def _finish_round(self, pending: dict) -> dict:
        """Broadcast + ledger entry for an aggregated round — the second
        half of :meth:`round_exchange`, callable on its own to resume a
        round interrupted by a ``post_aggregate`` server kill."""
        self._pending = None
        round_idx = pending["round_idx"]
        bc = self.server.broadcast(round_idx)
        recipients = len(pending["cohort"])
        if pending["catchup"] is None:
            down_bytes = len(bc.blob) * recipients
            down_m = bc.bits_measured * recipients
            down_a = bc.bits_analytic * recipients
        else:
            down_bytes, down_m, down_a = pending["catchup"]
        self.ledger.record(
            RoundRecord(
                round=round_idx,
                cohort=tuple(pending["accepted"]),
                up_bytes=pending["up_bytes"],
                up_bits_measured=pending["up_bits_measured"],
                up_bits_analytic=pending["up_bits_analytic"],
                down_bytes=down_bytes,
                down_bits_measured=down_m,
                down_bits_analytic=down_a,
                down_recipients=recipients,
                up_bytes_wasted=pending["up_bytes_wasted"],
            )
        )
        return {
            "round": round_idx,
            "loss": pending["loss"],
            "update_norm": pending["update_norm"],
            "staleness": pending["staleness"],
            "weights": pending["weights"],
            "up_bytes": pending["up_bytes"],
            "down_bytes": down_bytes,
            "accepted": pending["accepted"],
            "rejected": pending["rejected"],
            "stragglers": pending["stragglers"],
            "up_bytes_wasted": pending["up_bytes_wasted"],
        }

    def bits(self, rate: Optional[float] = None,
             round_idx: int = 0) -> ChannelBits:
        """Analytic Eq. 1 upstream bits for ONE client at ``rate`` (default:
        the pool's first profile) against the dense 32-bit equivalent."""
        params = self.server.params
        resolved = self.server._up_resolved
        if rate is None:
            rate = self.pool.profiles[0].sparsity
        return analytic_bits(
            resolved, resolved._leaves_of(params),
            resolved.rates(rate, round_idx),
        )
