"""DSGD trainer — paper Alg. 1 with pluggable compression (Alg. 2 + baselines).

One *communication round* (the jit unit):

  1. every client syncs to the master weights W                    (l.7-9)
  2. runs ``n_delay`` local optimizer steps on its own microbatches (l.10,
     ``SGD_n``; n_delay > 1 = Federated-Averaging-style communication delay)
  3. ΔW_i = R_i + (W_i' − W);  ΔW*_i = compress(ΔW_i);  R_i ← ΔW_i − ΔW*_i
     (l.10-12 — residual add + error feedback live in the policy engine,
     :meth:`repro.core.policy.ResolvedPolicy.compress`)
  4. exchange: ΔW ← mean_i ΔW*_i;  W ← W + ΔW                      (l.17-19)
  5. momentum masking (supplement A): client momentum zeroed at transmitted
     coordinates.

Steps 3-4 plus all bit accounting are one
:class:`~repro.core.channel.LocalVmapChannel` call (``round_exchange``,
DESIGN.md §12): clients are a leading vmap axis, so per-client
weight-updates exist as real tensors *before* any reduction — the thing
that makes per-client compression expressible at all (DESIGN.md §4).

``DSGDTrainer`` itself is the **legacy entry point** for this backend: it
predates the declarative run surface and survives as a documented shim —
``repro.run.build_run(RunSpec(backend="local", ...))`` constructs the same
trainer (bit-identical states; ``tests/test_legacy_api.py`` holds it to
that) and adds the uniform ledger/checkpoint surface on top.  Direct
construction emits a :class:`DeprecationWarning` pointing there.

Bit accounting: ``metrics['bits_per_client']`` is the analytic wire size
(Eq. 1 with Golomb position bits for SBC) of one client's upload this round;
``bits_dense`` is the 32-bit dense equivalent, so compression rate =
``delay · bits_dense / bits_per_client`` cumulated over rounds.  With
``fit(..., measure_wire=True)`` client 0's update is additionally packed to
real bytes every round (:mod:`repro.core.wire`), the *measured* sizes are
recorded next to the analytic ones, and the channel's
:class:`~repro.core.ledger.BandwidthLedger` gets one row per round.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.api import Compressor, CompressorState
from repro.core.channel import LocalVmapChannel
from repro.core.policy import CompressionPolicy, ResolvedPolicy
from repro.models.model import Model
from repro.optim.optimizers import Optimizer

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree  # master weights W (shared by all clients)
    opt_states: PyTree  # per-client local optimizer state (leading C axis)
    comp_state: CompressorState  # per-client compressor state (leading C axis)
    round: jax.Array  # communication-round counter


@dataclasses.dataclass(eq=False)  # id-hash → usable as a jit static arg
class DSGDTrainer:
    model: Model
    compressor: Union[Compressor, CompressionPolicy]
    optimizer: Optimizer
    n_clients: int
    lr: Callable[[jax.Array], jax.Array]  # lr(iteration) schedule
    residual_dtype: Any = jnp.float32
    # None → keep the policy's own flag; True/False → force the flat-buffer
    # fast path (core/flat.py §10) on or off.  With the fast path active the
    # per-client error-feedback residual is stored as ONE flat f32 buffer
    # per client instead of a per-leaf pytree.
    fast: Optional[bool] = None
    # construction provenance: repro.run builds this trainer internally and
    # suppresses the legacy-surface warning
    _from_run: dataclasses.InitVar[bool] = False

    def __post_init__(self, _from_run: bool = False) -> None:
        if not _from_run:
            warnings.warn(
                "constructing DSGDTrainer directly is the legacy local-"
                "backend surface; build it declaratively via "
                "repro.run.build_run(RunSpec(backend='local', ...)) "
                "(bit-identical states, uniform ledger/checkpoint API)",
                DeprecationWarning,
                stacklevel=2,
            )
        if isinstance(self.compressor, CompressionPolicy):
            self.compressor = Compressor.from_policy(
                self.compressor.name, self.compressor
            )
        if self.fast is not None and self.fast != self.compressor.policy.fast:
            self.compressor = Compressor.from_policy(
                self.compressor.name,
                dataclasses.replace(self.compressor.policy, fast=self.fast),
            )
        self.channel = LocalVmapChannel(
            compressor=self.compressor,
            n_clients=self.n_clients,
            residual_dtype=self.residual_dtype,
        )

    @property
    def ledger(self):
        """The channel's bandwidth ledger (rows recorded by
        ``fit(measure_wire=True)`` / the run API)."""
        return self.channel.ledger

    def resolved(self, params: PyTree) -> ResolvedPolicy:
        """The compressor's policy bound to this model's param structure."""
        return self.channel.resolved(params)

    # ------------------------------------------------------------------ init

    def init(self, rng: jax.Array) -> TrainState:
        p_rng, c_rng = jax.random.split(rng)
        params = self.model.init(p_rng)

        def stack_c(tree):
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (self.n_clients,) + x.shape).copy(), tree
            )

        opt_states = stack_c(self.optimizer.init(params))
        comp_state = self.channel.init_state(params, c_rng)
        return TrainState(params, opt_states, comp_state, jnp.zeros((), jnp.int32))

    # ------------------------------------------------------------- one round

    @partial(
        jax.jit,
        static_argnames=("self", "n_delay", "sparsity", "return_compressed"),
    )
    def round_step(
        self,
        state: TrainState,
        batch: PyTree,  # (clients, n_delay, per_client_batch, ...)
        *,
        n_delay: int,
        sparsity: Union[float, Tuple[float, ...]],  # global rate | per-leaf rates
        return_compressed: bool = False,
    ) -> tuple:
        params = state.params
        iteration = state.round * n_delay  # forward-backward passes so far

        def local_update(opt_state, client_batch):
            """n_delay local steps from the master weights (Alg. 1 l.10)."""

            def one(carry, micro):
                p, os, it = carry
                loss, g = jax.value_and_grad(self.model.loss_fn)(p, micro)
                p2, os2 = self.optimizer.apply(os, g, p, self.lr(it), it)
                return (p2, os2, it + 1), loss

            (p_new, os_new, _), losses = jax.lax.scan(
                one, (params, opt_state, iteration), client_batch
            )
            delta = jax.tree.map(
                lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32)).astype(
                    self.residual_dtype
                ),
                p_new,
                params,
            )
            return delta, os_new, jnp.mean(losses)

        deltas, opt_states, losses = jax.vmap(local_update)(state.opt_states, batch)

        # ---- per-client compression + exchange (Alg. 1 l.11-17), one
        # channel call (compress with error feedback, mean over clients,
        # Eq. 1 accounting — DESIGN.md §12)
        ex = self.channel.round_exchange(
            deltas, state.comp_state, sparsity,
            return_compressed=return_compressed,
        )
        new_params = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) + d.astype(jnp.float32)).astype(p.dtype),
            params,
            ex.mean_delta,
        )

        # ---- momentum masking at transmitted coordinates (supplement A)
        transmitted = jax.tree.map(
            lambda d: (d != 0).astype(jnp.float32), ex.transmitted
        )
        opt_states = jax.vmap(self.optimizer.mask)(opt_states, transmitted)

        n_params = sum(x.size for x in jax.tree.leaves(params))
        metrics = {
            "loss": jnp.mean(losses),
            "bits_per_client": ex.bits_per_client,
            "bits_dense": jnp.asarray(32.0 * n_params * n_delay, jnp.float32),
            "update_norm": _tree_norm(ex.mean_delta),
        }
        new_state = TrainState(new_params, opt_states, ex.state, state.round + 1)
        if return_compressed:
            # client 0's compressed tree, for host-side wire measurement
            return new_state, metrics, ex.compressed0
        return new_state, metrics

    # --------------------------------------------------------------- fitting

    def fit(
        self,
        rng: jax.Array,
        batch_fn: Callable[[int], PyTree],  # round -> (C, n_delay, B, ...) batch
        *,
        n_rounds: int,
        n_delay: int,
        sparsity: float,
        eval_fn: Optional[Callable[[PyTree], dict]] = None,
        eval_every: int = 0,
        log_every: int = 0,
        measure_wire: bool = False,
    ) -> tuple:
        """Run ``n_rounds`` communication rounds; returns (state, history)."""
        state = self.init(rng)
        resolved = self.resolved(state.params)
        hist: dict = {"round": [], "loss": [], "bits_per_client": [], "eval": []}
        if measure_wire:
            hist["measured_bits_per_client"] = []
        total_bits = 0.0
        for r in range(n_rounds):
            rates = resolved.rates(sparsity, r)
            step_out = self.round_step(
                state, batch_fn(r), n_delay=n_delay, sparsity=rates,
                return_compressed=measure_wire,
            )
            if measure_wire:
                state, m, comp0 = step_out
                measured = self.channel.record_round(
                    r, params=state.params, compressed0=comp0, rate=sparsity,
                    bits_analytic_per_client=float(m["bits_per_client"]),
                )
                hist["measured_bits_per_client"].append(measured)
            else:
                state, m = step_out
            total_bits += float(m["bits_per_client"])
            hist["round"].append(r)
            hist["loss"].append(float(m["loss"]))
            hist["bits_per_client"].append(float(m["bits_per_client"]))
            if eval_fn and eval_every and (r + 1) % eval_every == 0:
                hist["eval"].append((r, eval_fn(state.params)))
            if log_every and (r + 1) % log_every == 0:
                print(
                    f"round {r+1:5d}  loss {float(m['loss']):.4f}  "
                    f"bits/client {float(m['bits_per_client']):.3e}"
                )
        hist["total_upload_bits"] = total_bits
        n_params = sum(x.size for x in jax.tree.leaves(state.params))
        hist["dense_total_bits"] = 32.0 * n_params * n_rounds * n_delay
        hist["compression_rate"] = hist["dense_total_bits"] / max(total_bits, 1.0)
        if measure_wire and hist["measured_bits_per_client"]:
            hist["measured_total_bits"] = sum(hist["measured_bits_per_client"])
        return state, hist


def _tree_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )
