from repro.train.trainer import DSGDTrainer, TrainState

__all__ = ["DSGDTrainer", "TrainState"]
