"""Serving driver: prefill a batch of prompts, decode new tokens.

CPU-scale demonstration of the serving substrate (the decode shapes of the
dry-run exercise the same ``serve_step`` at production scale).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
      --batch 4 --prompt-len 64 --new-tokens 32

``--subscribers N`` additionally runs the delta-broadcast fan-out on the
same architecture's parameters: a DeltaLog-backed server broadcasting
compressed deltas to N subscribers with heterogeneous sync periods
(docs/broadcast.md).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
      --subscribers 10000 --broadcast-rounds 12
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs.base import get_config, reduced
from repro.models.model import build_model
from repro.serve import ServeEngine


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (default: reduced smoke variant)")
    g = ap.add_argument_group("delta broadcast (docs/broadcast.md)")
    g.add_argument("--subscribers", type=int, default=0,
                   help="also fan the model's deltas out to N subscribers "
                        "through a DeltaLog (0 = skip)")
    g.add_argument("--broadcast-rounds", type=int, default=12,
                   help="broadcast rounds to simulate")
    g.add_argument("--broadcast-sparsity", type=float, default=0.02,
                   help="downstream sparsity of the logged broadcasts")
    g.add_argument("--delta-horizon", type=int, default=8,
                   help="rounds the DeltaLog keeps before forcing full resync")
    from repro.run.flags import add_telemetry_flags

    add_telemetry_flags(ap)
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    model = build_model(cfg)
    engine = ServeEngine(model)

    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = {
        "tokens": jax.random.randint(
            rng, (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
    }
    if cfg.family == "encdec":
        if cfg.modality == "audio":
            batch["enc_frames"] = 0.1 * jax.random.normal(
                rng, (args.batch, args.prompt_len, cfg.d_model)
            )
        else:
            batch["enc_tokens"] = batch["tokens"]
    elif cfg.modality == "vision":
        batch["prefix"] = 0.1 * jax.random.normal(
            rng, (args.batch, cfg.n_prefix, cfg.d_model)
        )

    t0 = time.time()
    out = engine.generate(
        params, batch, max_new_tokens=args.new_tokens, temperature=args.temperature
    )
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({total/dt:.1f} tok/s incl. compile)")
    print("sample token ids:", out[0, :16].tolist())

    if args.subscribers > 0:
        from repro.obs import NULL_TELEMETRY, finish_run, make_telemetry, render_table
        from repro.run.flags import telemetry_requested
        from repro.serve import simulate_fanout

        telemetry = make_telemetry() if telemetry_requested(args) else NULL_TELEMETRY
        m = simulate_fanout(
            params,
            n_subscribers=args.subscribers,
            rounds=args.broadcast_rounds,
            horizon=args.delta_horizon,
            down_sparsity=args.broadcast_sparsity,
            seed=0,
            telemetry=telemetry,
        )
        print(
            f"broadcast: {m['n_subscribers']} subscribers x "
            f"{m['timed_rounds']} rounds  "
            f"{m['bytes_per_subscriber_per_round']:.1f} B/sub/round  "
            f"{m['bytes_saving_vs_full_resync']:.1f}x vs full resync  "
            f"{m['rounds_per_sec']:.2f} rounds/s"
        )
        print(render_table(
            ["lag", "plan", "bytes", "vs full resync"],
            [
                (lag, p["kind"], p["nbytes"],
                 f"x{m['full_resync_bytes'] / max(p['nbytes'], 1):.1f}")
                for lag, p in sorted(
                    m["plan_by_lag"].items(), key=lambda kv: int(kv[0])
                )
            ],
            title="catch-up plan by lag class",
        ))
        if telemetry.enabled:
            finish_run(
                telemetry, trace=args.trace, metrics_out=args.metrics_out,
                meta={"backend": "serve", "subscribers": args.subscribers,
                      "rounds": args.broadcast_rounds},
            )
    return out


if __name__ == "__main__":
    main()
