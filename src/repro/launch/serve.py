"""Serving driver: prefill a batch of prompts, decode new tokens.

CPU-scale demonstration of the serving substrate (the decode shapes of the
dry-run exercise the same ``serve_step`` at production scale).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
      --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs.base import get_config, reduced
from repro.models.model import build_model
from repro.serve import ServeEngine


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (default: reduced smoke variant)")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    model = build_model(cfg)
    engine = ServeEngine(model)

    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = {
        "tokens": jax.random.randint(
            rng, (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
    }
    if cfg.family == "encdec":
        if cfg.modality == "audio":
            batch["enc_frames"] = 0.1 * jax.random.normal(
                rng, (args.batch, args.prompt_len, cfg.d_model)
            )
        else:
            batch["enc_tokens"] = batch["tokens"]
    elif cfg.modality == "vision":
        batch["prefix"] = 0.1 * jax.random.normal(
            rng, (args.batch, cfg.n_prefix, cfg.d_model)
        )

    t0 = time.time()
    out = engine.generate(
        params, batch, max_new_tokens=args.new_tokens, temperature=args.temperature
    )
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({total/dt:.1f} tok/s incl. compile)")
    print("sample token ids:", out[0, :16].tolist())
    return out


if __name__ == "__main__":
    main()
