"""Production meshes.

Single pod : (16, 16) axes ('data', 'model')          — 256 chips (v5e pod)
Multi-pod  : (2, 16, 16) axes ('pod', 'data', 'model') — 512 chips

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax init).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # pin Auto axis types where the API exists: the framework relies on
    # GSPMD sharding propagation (jax v0.9 flips the default to Explicit);
    # older jax (< 0.6) has no AxisType and Auto is already the only mode
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """A 1-device mesh for CPU tests of the distributed code paths."""
    return _make_mesh((1, 1), ("data", "model"))


def axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
