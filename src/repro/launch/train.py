"""End-to-end DSGD training driver (deliverable (b)'s launcher).

Runs the paper's training setting — M clients, communication delay n,
sparsity p, any registered compressor — on a synthetic-but-learnable task
sized by ``--preset``:

  paper-lenet    LeNet5 on blob-MNIST (Adam, the paper's smallest task)
  paper-lstm     CharLSTM on a markov stream
  lm-100m        ~100M-param decoder LM for a few hundred rounds
  <arch id>      a reduced config of any assigned architecture

Per-leaf policies (DESIGN.md §3): ``--dense-pattern`` / ``--skip-pattern``
wrap the chosen compressor in a :class:`CompressionPolicy` so matched
leaves (by path regex) ride dense / are skipped, and ``--measure-wire``
packs client 0's update to real bytes every round next to the analytic
Eq. 1 accounting.

Examples:
  PYTHONPATH=src python -m repro.launch.train --preset lm-100m \
      --compressor sbc --delay 10 --sparsity 0.01 --rounds 200
  PYTHONPATH=src python -m repro.launch.train --preset paper-lenet \
      --compressor topk --sparsity 0.001 --rounds 100
  PYTHONPATH=src python -m repro.launch.train --preset paper-lstm \
      --compressor sbc --sparsity 0.001 \
      --dense-pattern '(^|/)(bias|scale|norm[^/]*)(/|$)' --measure-wire
  PYTHONPATH=src python -m repro.launch.train --compressor dgc_policy ...
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_pytree
from repro.configs.base import ModelConfig, get_config, reduced
from repro.core.api import CompressionPolicy, PolicyRule, get_compressor
from repro.core.baselines import dgc_policy  # noqa: F401 (registration)
from repro.data import client_batches, make_classification_task, make_lm_task
from repro.models.model import build_model
from repro.optim import get_optimizer
from repro.train import DSGDTrainer


def lm_100m_config() -> ModelConfig:
    """~100M decoder: 12L, d=768, 12H, tied 32k vocab."""
    return ModelConfig(
        name="lm-100m", family="decoder", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=3072, vocab_size=32_000, dtype=jnp.float32,
        local_opt="adam", base_lr=3e-4,
    )


def build_preset(name: str, *, batch: int, seq_len: int):
    if name == "paper-lenet":
        cfg = get_config("lenet5")
        task = make_classification_task(
            n_classes=10, img_size=28, channels=1, batch=batch
        )
        return cfg, task
    if name == "paper-lstm":
        cfg = get_config("charlstm")
        task = make_lm_task(vocab=98, batch=batch, seq_len=seq_len, temperature=0.5)
        return cfg, task
    if name == "lm-100m":
        cfg = lm_100m_config()
        task = make_lm_task(vocab=cfg.vocab_size, batch=batch, seq_len=seq_len,
                            temperature=0.5)
        return cfg, task
    # reduced assigned arch
    cfg = reduced(get_config(name))
    if cfg.family == "encdec":
        d = cfg.d_model

        def extra(rng):
            return {"enc_frames": 0.1 * jax.random.normal(rng, (batch, seq_len, d))} \
                if cfg.modality == "audio" else {}

        task = make_lm_task(vocab=cfg.vocab_size, batch=batch, seq_len=seq_len,
                            temperature=0.5, extra_fields=extra)
    elif cfg.modality == "vision":
        d, npre = cfg.d_model, cfg.n_prefix

        def extra(rng):
            return {"prefix": 0.1 * jax.random.normal(rng, (batch, npre, d))}

        task = make_lm_task(vocab=cfg.vocab_size, batch=batch, seq_len=seq_len,
                            temperature=0.5, extra_fields=extra)
    else:
        task = make_lm_task(vocab=cfg.vocab_size, batch=batch, seq_len=seq_len,
                            temperature=0.5)
    return cfg, task


def lr_schedule(base_lr: float, decay_at: tuple[int, ...] = (), factor: float = 0.1):
    def lr(it):
        mult = 1.0
        for d in decay_at:
            mult = jnp.where(it >= d, mult * factor, mult)
        return base_lr * mult

    return lr


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="lm-100m")
    ap.add_argument("--compressor", default="sbc")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--delay", type=int, default=1)
    ap.add_argument("--sparsity", type=float, default=0.001)
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--save", default=None, help="checkpoint path (.npz)")
    ap.add_argument("--history", default=None, help="metrics JSON path")
    ap.add_argument("--dense-pattern", default=None,
                    help="path regex: matched leaves ride dense (DGC-style)")
    ap.add_argument("--skip-pattern", default=None,
                    help="path regex: matched leaves are never transmitted")
    ap.add_argument("--measure-wire", action="store_true",
                    help="pack client 0's update to real bytes every round")
    ap.add_argument("--print-policy", action="store_true",
                    help="print the per-leaf codec resolution and exit")
    ap.add_argument("--fast", action="store_true",
                    help="flat-buffer compression fast path (DESIGN.md §10)")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    cfg, task = build_preset(args.preset, batch=args.batch, seq_len=args.seq_len)
    model = build_model(cfg)
    lr = args.lr if args.lr is not None else cfg.base_lr
    compressor = get_compressor(args.compressor)
    if args.dense_pattern or args.skip_pattern:
        rules = ()
        if args.skip_pattern:
            rules += (PolicyRule(args.skip_pattern, codec="skip"),)
        if args.dense_pattern:
            rules += (PolicyRule(args.dense_pattern, codec="dense32"),)
        # CLI rules take precedence but keep any rules the compressor's own
        # policy already carries (e.g. dgc_policy's warm-up + dense biases)
        compressor = CompressionPolicy(
            default=compressor.codec,
            rules=rules + compressor.policy.rules,
            name=args.compressor + "+rules",
        )
    trainer = DSGDTrainer(
        model=model,
        compressor=compressor,
        optimizer=get_optimizer(cfg.local_opt),
        n_clients=args.clients,
        lr=lr_schedule(lr),
        fast=True if args.fast else None,
    )
    if args.print_policy:
        a_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        print(trainer.resolved(a_params).describe())
        return {}
    batch_fn = client_batches(task, args.clients, args.delay)

    n_params = sum(
        x.size for x in jax.tree.leaves(jax.eval_shape(model.init, jax.random.PRNGKey(0)))
    )
    print(
        f"preset={args.preset} arch={cfg.name} params={n_params/1e6:.1f}M "
        f"compressor={args.compressor} clients={args.clients} "
        f"delay={args.delay} p={args.sparsity}"
    )
    t0 = time.time()
    state, hist = trainer.fit(
        jax.random.PRNGKey(0), batch_fn, n_rounds=args.rounds,
        n_delay=args.delay, sparsity=args.sparsity, log_every=args.log_every,
        measure_wire=args.measure_wire,
    )
    dt = time.time() - t0
    print(
        f"done in {dt:.1f}s: loss {hist['loss'][0]:.4f} → {hist['loss'][-1]:.4f}  "
        f"upload {hist['total_upload_bits']/8e6:.2f} MB/client  "
        f"compression ×{hist['compression_rate']:.0f}"
    )
    if args.measure_wire:
        print(
            f"measured wire: {hist['measured_total_bits']/8e6:.2f} MB/client "
            f"(analytic {hist['total_upload_bits']/8e6:.2f} MB)"
        )
    if args.save:
        save_pytree(args.save, state.params)
        print(f"saved params to {args.save}")
    if args.history:
        os.makedirs(os.path.dirname(os.path.abspath(args.history)), exist_ok=True)
        with open(args.history, "w") as f:
            json.dump({k: v for k, v in hist.items() if k != "eval"}, f)
    return hist


if __name__ == "__main__":
    main()
