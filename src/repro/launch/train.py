"""End-to-end DSGD training launcher — a thin parser over ``repro.run``.

Runs the paper's training setting — M clients, communication delay n,
sparsity p, any registered compressor — on a synthetic-but-learnable task
sized by ``--preset`` (see :mod:`repro.run.presets`).  All flags are the
shared :func:`repro.run.add_run_flags` surface; this module only pins the
backend to "local", re-pins a few defaults, and keeps the two
launcher-specific extras (``--save``, ``--print-policy``).

Examples:
  PYTHONPATH=src python -m repro.launch.train --preset lm-100m \
      --compressor sbc --delay 10 --sparsity 0.01 --rounds 200
  PYTHONPATH=src python -m repro.launch.train --preset paper-lenet \
      --compressor topk --sparsity 0.001 --rounds 100
  PYTHONPATH=src python -m repro.launch.train --preset paper-lstm \
      --compressor sbc --sparsity 0.001 \
      --dense-pattern '(^|/)(bias|scale|norm[^/]*)(/|$)' --measure-wire
  PYTHONPATH=src python -m repro.launch.train --spec-json my_run.json
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.checkpoint import save_pytree
from repro.core.baselines import dgc_policy  # noqa: F401 (registration)
from repro.run.build import build_run, lr_schedule  # noqa: F401 (re-export)
from repro.run.flags import add_run_flags, spec_from_args
from repro.run.presets import build_preset, lm_100m_config  # noqa: F401


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    add_run_flags(
        ap,
        preset="lm-100m",
        backend="local",
        rounds=200,
        seq_len=256,
        log_every=10,
    )
    ap.add_argument("--save", default=None, help="checkpoint path (.npz)")
    ap.add_argument("--print-policy", action="store_true",
                    help="print the per-leaf codec resolution and exit")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)
    spec = spec_from_args(args, backend="local")
    run = build_run(spec)

    if args.print_policy:
        a_params = jax.eval_shape(run.model.init, jax.random.PRNGKey(0))
        print(run.trainer.resolved(a_params).describe())
        return {}

    n_params = sum(
        x.size for x in jax.tree.leaves(
            jax.eval_shape(run.model.init, jax.random.PRNGKey(0))
        )
    )
    print(
        f"preset={spec.preset} arch={run.cfg.name} params={n_params/1e6:.1f}M "
        f"compressor={spec.compressor} clients={spec.clients} "
        f"delay={spec.delay} p={spec.sparsity}"
    )
    t0 = time.time()
    state, hist = run.run(log_every=args.log_every)
    dt = time.time() - t0
    print(
        f"done in {dt:.1f}s: loss {hist['loss'][0]:.4f} → {hist['loss'][-1]:.4f}  "
        f"upload {hist['total_upload_bits']/8e6:.2f} MB/client  "
        f"compression ×{hist['compression_rate']:.0f}"
    )
    if spec.measure_wire:
        print(
            f"measured wire: {hist['measured_total_bits']/8e6:.2f} MB/client "
            f"(analytic {hist['total_upload_bits']/8e6:.2f} MB)"
        )
    if spec.telemetry:
        from repro.obs import finish_run

        finish_run(
            run.telemetry, trace=args.trace, metrics_out=args.metrics_out,
            meta={"backend": "local", "preset": spec.preset,
                  "rounds": spec.rounds},
        )
    if args.save:
        save_pytree(args.save, state.params)
        print(f"saved params to {args.save}")
    if args.history:
        os.makedirs(os.path.dirname(os.path.abspath(args.history)), exist_ok=True)
        with open(args.history, "w") as f:
            json.dump({k: v for k, v in hist.items() if k != "eval"}, f)
    return hist


if __name__ == "__main__":
    main()
