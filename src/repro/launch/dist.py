"""GSPMD distributed DSGD: sharded train_step / serve_step builders.

Design (DESIGN.md §4):

* **Clients.**  ``cfg.client_mode``:
    - 'data': one client per data coordinate (×pod) — per-client ΔW never
      crosses the data axis; the ONLY cross-client traffic is the sparse
      exchange.  Small/mid archs (params replicated over 'data').
    - 'pod':  one client per pod; grads all-reduce densely *inside* a pod
      (fast ICI), SBC compresses the cross-pod exchange (slow DCN).  ≥20B
      archs (params FSDP-sharded over 'data').

* **Shard-wise compression** (the TPU-native re-think of paper Alg. 2):
  compression runs inside ``shard_map`` — every device applies exact
  top-k + binarization to ITS OWN shard of ΔW, so the paper's O(n log n)
  global sort becomes an embarrassingly-local per-shard top-k, and the μ±
  means are per-(tensor, shard) instead of per-tensor (finer granularity,
  same wire format: one 32-bit scalar per shard).  The exchange is an
  explicit ``jax.lax.all_gather`` of (idx[k] int32, μ f32) over the client
  axes — the ×p bandwidth saving is therefore visible in the lowered HLO
  collective schedule, not just in a wire-format codec.

* **Dense baseline** (``compressor='none'``): the exchange is a mean over
  the client axis of the full ΔW — lowers to the dense all-reduce that the
  paper's Eq. 1 baseline counts.

* **Per-leaf policies** (DESIGN.md §3): an optional
  :class:`~repro.core.policy.CompressionPolicy` resolves every param leaf
  to one of this backend's exchange kernels (sparse SBC / dense all-reduce
  / skip) with its own sparsity rate, so DGC-style "dense biases + 0.1%
  matrices" recipes lower to a mixed collective schedule.

Bit accounting is static (shapes and per-leaf rates are compile-time): per
sparse leaf, ``L·S_shards·(k_loc·b̄_pos(p_leaf) + 32)`` wire bits per client
per round; dense leaves count 32 bits/entry; skipped leaves count 0.
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.7 moved shard_map to the top level
    from jax import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_vma=False)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=False)

from repro.configs.base import ModelConfig
from repro.core.codec import Codec, make_codec
from repro.core.flat import ShardedFlatParamSpace
from repro.core.golomb import expected_position_bits
from repro.core.policy import CompressionPolicy, path_str
from repro.models import hints
from repro.models.model import Model, build_model
from repro.optim.optimizers import get_optimizer

PyTree = Any


# ----------------------------------------------------------- client topology


def client_topology(cfg: ModelConfig, mesh: Mesh) -> tuple[int, tuple[str, ...]]:
    """(n_clients, client mesh axes).  See module docstring."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if cfg.client_mode == "pod":
        return (sizes["pod"], ("pod",)) if "pod" in sizes else (1, ())
    axes = tuple(a for a in ("pod", "data") if a in sizes)
    return math.prod(sizes[a] for a in axes), axes


def _lead_spec(client_axes: tuple[str, ...]):
    if not client_axes:
        return None
    return client_axes[0] if len(client_axes) == 1 else client_axes


# ------------------------------------------------------------- spec plumbing


def stacked_specs(inner_specs: PyTree, client_axes: tuple[str, ...]) -> PyTree:
    """Specs for a (C,)+param-shaped tree (residual / momentum / adam)."""
    lead = _lead_spec(client_axes)
    return jax.tree.map(
        lambda s: P(lead, *s), inner_specs, is_leaf=lambda s: isinstance(s, P)
    )


def opt_state_specs(opt_name: str, param_specs: PyTree, client_axes) -> PyTree:
    inner = stacked_specs(param_specs, client_axes)
    if opt_name == "sgd":
        return ()
    if opt_name == "momentum":
        return inner
    if opt_name == "adam":
        from repro.optim.optimizers import AdamState

        return AdamState(inner, inner)
    raise ValueError(opt_name)


def _shards_of(spec: P, mesh_sizes: dict[str, int]) -> int:
    total = 1
    for entry in spec:
        if entry is None:
            continue
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            total *= mesh_sizes.get(ax, 1)
    return total


# ----------------------------------------------- shard-wise compress+exchange


def _sbc_local(acc_flat: jax.Array, p: float, client_axes, n_clients: int,
               out_dtype=jnp.float32):
    """Inside shard_map: exact per-shard SBC (paper Alg. 2) + sparse exchange.

    acc_flat: (L, n_loc) — residual-accumulated ΔW, THIS device's shard
    (any float dtype; per-layer math runs in f32).
    Returns (mean_delta (L, n_loc), own_delta_star (L, n_loc)) in out_dtype.

    Layers are processed through a lax.scan so only ONE layer's f32
    working set is live at a time (§Perf lowmem iteration — the vmap
    formulation materialized 3 full-leaf f32 buffers).
    """
    L, n_loc = acc_flat.shape
    k = max(1, min(n_loc, int(round(p * n_loc))))

    def one_layer(_, x_row):
        x = x_row.astype(jnp.float32)
        val_pos, idx_pos = jax.lax.top_k(x, k)
        val_neg, idx_neg = jax.lax.top_k(-x, k)
        mu_pos, mu_neg = jnp.mean(val_pos), jnp.mean(val_neg)
        pos_wins = mu_pos > mu_neg
        idx = jnp.where(pos_wins, idx_pos, idx_neg).astype(jnp.int32)
        mu = jnp.where(pos_wins, mu_pos, -mu_neg).astype(jnp.float32)
        own_row = jnp.zeros((n_loc,), out_dtype).at[idx].set(mu.astype(out_dtype))
        return None, (idx, mu, own_row)

    _, (idx, mu, own) = jax.lax.scan(one_layer, None, acc_flat)

    if client_axes and n_clients > 1:
        # THE exchange: tiny (idx, μ) tensors cross the client axes.
        gidx, gmu = idx, mu
        for ax in client_axes:
            gidx = jax.lax.all_gather(gidx, ax)
            gmu = jax.lax.all_gather(gmu, ax)
        gidx = gidx.reshape(n_clients, L, k)
        gmu = gmu.reshape(n_clients, L)

        def dense_layer(_, args):
            rows_i, mus_i = args  # (C, k), (C,)
            row = jnp.zeros((n_loc,), jnp.float32)

            def add(acc, ci):
                return acc.at[rows_i[ci]].add(mus_i[ci] / n_clients), None

            row, _ = jax.lax.scan(add, row, jnp.arange(n_clients))
            return None, row.astype(out_dtype)

        _, dense = jax.lax.scan(
            dense_layer, None, (gidx.transpose(1, 0, 2), gmu.transpose(1, 0))
        )
    else:
        dense = own
    return dense, own


def _dense_local(acc_flat, client_axes, n_clients):
    """Dense baseline: pmean over clients == all-reduce of the full ΔW."""
    out = acc_flat
    for ax in client_axes:
        out = jax.lax.pmean(out, ax)
    return out, acc_flat


# ------------------------------------------------- sharded flat param space


def _local_shape(shape, spec: P, mesh_sizes: dict[str, int]) -> tuple[int, ...]:
    """One shard's shape of a leaf under ``spec`` (GSPMD equal blocks)."""
    entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    local = []
    for dim, entry in zip(shape, entries):
        axes = () if entry is None else (
            entry if isinstance(entry, tuple) else (entry,)
        )
        local.append(dim // math.prod(mesh_sizes.get(a, 1) for a in axes))
    return tuple(local)


def _sharded_flat_space(
    cfg: ModelConfig,
    mesh: Mesh,
    flat_p,
    flat_specs,
    scanned,
    modes,
    leaf_rates,
    client_axes: tuple[str, ...],
    n_clients: int,
) -> Optional[ShardedFlatParamSpace]:
    """The §11 sharded flat layout for this (cfg, mesh, policy) — or None
    when the fast path does not apply (non-f32 leaves / non-f32 residual
    fall back to the per-leaf exchange, same rule as PR 3's single-device
    fast path)."""
    if jnp.dtype(cfg.residual_dtype) != jnp.float32:
        return None
    if any(leaf.dtype != jnp.float32 for _, leaf in flat_p):
        return None
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    shard_axes = tuple(a for a in mesh.axis_names if a not in client_axes)
    entries = []
    for (path, leaf), spec, is_scan, mode, p_leaf in zip(
        flat_p, flat_specs, scanned, modes, leaf_rates
    ):
        local = _local_shape(leaf.shape, spec, mesh_sizes)
        rows = local[0] if is_scan and len(local) > 1 else 1
        entries.append(dict(
            path="/".join(
                k.key if hasattr(k, "key") else str(k) for k in path
            ),
            shape=local,
            rows=rows,
            kind=mode,
            rate=p_leaf,
            n_shards=_shards_of(spec, mesh_sizes),
            global_size=leaf.size,
        ))
    return ShardedFlatParamSpace.build(
        entries,
        client_axes=client_axes,
        shard_axes=shard_axes,
        n_clients=n_clients,
        shards_per_client=math.prod(mesh_sizes[a] for a in shard_axes)
        if shard_axes else 1,
    )


# ------------------------------------------------------------ train builder


class DistTrainFns(NamedTuple):
    train_step: Callable  # (state, batch) -> (state, metrics)
    init_state: Callable  # rng -> state (unsharded; dry-run never calls it)
    state_shardings: Any
    batch_shardings: Callable  # batch pytree -> shardings pytree
    abstract_state: Any
    bits_per_client: float  # static Eq. 1 wire bits per round
    bits_dense: float
    # §11 sharded flat fast path (None when the per-leaf exchange runs):
    flat_space: Any = None  # ShardedFlatParamSpace bound to (cfg, mesh)
    residual_to_tree: Optional[Callable] = None  # flat residual → pytree


def _dist_leaf_mode(codec: Codec) -> str:
    """Map a codec onto the shard_map exchange kernels this backend has.

    'sparse' → per-shard SBC + (idx, μ) all-gather; 'dense' → pmean
    all-reduce; 'skip' → no traffic.  Other codec compositions have no
    TPU-native exchange kernel yet and fail loudly.
    """
    if codec.skip:
        return "skip"
    if codec.selector.dense and codec.quantizer.name == "identity":
        return "dense"
    if codec.spec == "topk_signed|binarize|golomb":
        return "sparse"
    raise NotImplementedError(
        f"dist backend has no exchange kernel for codec {codec.spec!r}; "
        "supported: sbc (topk_signed|binarize|golomb), dense32, skip"
    )


def make_dist_train(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    compressor: str = "sbc",
    sparsity: float = 0.001,
    policy: Optional[CompressionPolicy] = None,
    model: Optional[Model] = None,
    opts: frozenset = frozenset(),
    fast: Optional[bool] = None,
    flat_engine: str = "exact",
) -> DistTrainFns:
    """Build the sharded DSGD train_step for (cfg, mesh).

    State = {'params', 'opt', 'residual'}; batch has a leading client axis
    of size ``client_topology(cfg, mesh)[0]``.

    ``policy`` — optional per-leaf :class:`CompressionPolicy` (path-regex
    rules; DESIGN.md §3).  Each leaf resolves to one of this backend's
    exchange kernels (see :func:`_dist_leaf_mode`) with its own sparsity
    rate.  Without a policy, ``compressor`` picks one codec for every leaf
    ("sbc" or any dense codec name), matching the seed behavior.

    ``fast`` — None keeps the policy's own ``fast`` flag; True/False
    forces the §11 sharded flat exchange on or off.  When active, every
    device compresses its shard of ONE block-padded flat buffer inside
    ``shard_map`` (:class:`~repro.core.flat.ShardedFlatParamSpace`), the
    error-feedback residual is stored flat-sharded, and the exchange is
    one all_gather of packed (positions, μ) flat segments.  Output is
    bit-identical to the per-leaf exchange; non-f32 leaves (or a non-f32
    ``cfg.residual_dtype``) fall back to the per-leaf path silently,
    same as PR 3's single-device fast path.

    ``flat_engine`` — 'exact' (default; two-sided per-row top-k) or
    'hist' (the segment-aware Pallas passes, approximate survivor
    counts, dense pmean exchange); 'hist' needs an all-SBC policy and an
    active fast path.

    ``opts`` — §Perf beyond-baseline toggles (baseline = empty set):
      'expert_parallel'  experts shard over 'data', dispatch follows
      'seq_every2'       sequence-parallel hint on every 2nd block only
    """
    from repro.models.model import make_param_specs

    model = model or build_model(cfg)
    n_clients, client_axes = client_topology(cfg, mesh)
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    opt_kw = {} if cfg.local_opt == "sgd" else {"state_dtype": cfg.residual_dtype}
    opt = get_optimizer(cfg.local_opt, **opt_kw)
    if policy is None:
        default = "sbc" if compressor == "sbc" else "dense"
        policy = CompressionPolicy.single(make_codec(default), name=compressor)
    # the cfg's dispatch mode decides the MoE weight sharding rules
    # ('flat_ep'/'grouped' → EP rules; 'flat_fsdp' → baseline fsdp rules)
    ep_rules = cfg.moe_dispatch in ("flat_ep", "grouped")

    # ---- abstract state + shardings
    a_params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_specs = make_param_specs(a_params, mesh, fsdp=cfg.fsdp,
                               expert_parallel=ep_rules)
    flat_p = jax.tree_util.tree_flatten_with_path(a_params)[0]
    scanned = [
        "stack/scan" in "/".join(k.key if hasattr(k, "key") else str(k) for k in path)
        for path, _ in flat_p
    ]
    flat_specs = jax.tree.leaves(p_specs, is_leaf=lambda s: isinstance(s, P))
    lead = _lead_spec(client_axes)
    flat_r_specs = [P(lead, *s) for s in flat_specs]

    # ---- per-leaf policy resolution (codec + sparsity rate by path regex).
    # Rates are compile-time constants here: a per-round schedule would be
    # silently frozen at its round-0 value, so reject it loudly (re-build
    # the train fns per rate change, or use the vmap trainer instead).
    plans = [policy.plan_for(path_str(path)) for path, _ in flat_p]
    scheduled = [pl.path for pl in plans if pl.schedule is not None]
    if scheduled:
        raise NotImplementedError(
            "make_dist_train compiles per-leaf sparsity rates statically; "
            f"policy rules attach per-round schedules to {scheduled[:3]}… — "
            "rebuild the train fns when the rate changes, or pin a fixed "
            "per-leaf `sparsity` in the rule"
        )
    modes = [_dist_leaf_mode(pl.codec) for pl in plans]
    leaf_rates = [pl.rate(sparsity, 0) for pl in plans]

    # ---- §11 sharded flat fast path (None → per-leaf exchange)
    if flat_engine not in ("exact", "hist"):
        raise ValueError(f"unknown flat_engine {flat_engine!r}")
    want_fast = policy.fast if fast is None else bool(fast)
    space = None
    if want_fast:
        space = _sharded_flat_space(
            cfg, mesh, flat_p, flat_specs, scanned, modes, leaf_rates,
            client_axes, n_clients,
        )
    if flat_engine == "hist" and space is None:
        raise ValueError(
            "flat_engine='hist' needs the sharded flat fast path "
            "(fast=True with all-f32 leaves and an f32 residual_dtype)"
        )
    shard_axes = tuple(a for a in mesh.axis_names if a not in client_axes)
    res_spec = P(lead, _lead_spec(shard_axes), None)

    def stack_c(tree):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_clients,) + x.shape).copy(), tree
        )

    def init_state(rng):
        params = model.init(rng)
        if space is not None:
            # §11: the error-feedback residual lives as ONE flat sharded
            # f32 buffer — never round-trips through the per-leaf pytree
            residual = space.zeros_residual()
        else:
            residual = jax.tree.map(
                lambda x: jnp.zeros((n_clients,) + x.shape, cfg.residual_dtype),
                params,
            )
        return {"params": params, "opt": stack_c(opt.init(params)), "residual": residual}

    a_state = jax.eval_shape(init_state, jax.random.PRNGKey(0))
    state_specs = {
        "params": p_specs,
        "opt": opt_state_specs(cfg.local_opt, p_specs, client_axes),
        "residual": res_spec if space is not None else jax.tree.unflatten(
            jax.tree.structure(p_specs, is_leaf=lambda s: isinstance(s, P)), flat_r_specs
        ),
    }
    ns = lambda spec: NamedSharding(mesh, spec)
    state_shardings = jax.tree.map(ns, state_specs, is_leaf=lambda s: isinstance(s, P))

    # ---- static Eq. 1 bit accounting per round per client (per-leaf codec)
    bits_policy = bits_dense = 0.0
    for (path, leaf), spec, is_scan, mode, p_leaf in zip(
        flat_p, flat_specs, scanned, modes, leaf_rates
    ):
        L = leaf.shape[0] if is_scan and leaf.ndim > 1 else 1
        shards = _shards_of(spec, mesh_sizes)
        n_loc = max(1, leaf.size // (L * shards))
        if mode == "sparse":
            k_loc = max(1, min(n_loc, int(round(p_leaf * n_loc))))
            bits_policy += L * shards * (
                k_loc * expected_position_bits(p_leaf) + 32.0
            )
        elif mode == "dense":
            bits_policy += 32.0 * leaf.size
        bits_dense += 32.0 * leaf.size
    if space is not None:
        # same totals, summed from the per-(segment, shard) table (§11)
        bits_policy = space.bits_per_client()

    # ---- batch shardings
    inner = "data" if cfg.client_mode == "pod" else None

    def batch_shardings(batch_tree):
        def one(x):
            return ns(P(lead, inner, *([None] * (x.ndim - 2))))

        return jax.tree.map(one, batch_tree)

    # ---- the step
    def train_step(state, batch):
        params = state["params"]

        def local(opt_state, client_batch):
            loss, g = jax.value_and_grad(model.loss_fn)(params, client_batch)
            p2, os2 = opt.apply(opt_state, g, params, cfg.base_lr, jnp.zeros((), jnp.int32))
            delta = jax.tree.map(
                lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32)).astype(
                    cfg.residual_dtype
                ),
                p2,
                params,
            )
            return delta, os2, loss

        deltas, opt_states, losses = jax.vmap(local)(state["opt"], batch)

        in_specs = tuple(flat_r_specs)
        need_mask = cfg.local_opt != "sgd"  # momentum masking needs ΔW*_i
        own_specs = in_specs if need_mask else tuple(P() for _ in flat_r_specs)

        if space is not None:
            # §11 sharded flat exchange: residual add + compression + the
            # packed (positions, μ) collective all run on ONE flat buffer
            # per device, one launch per pass.
            delta_leaves, acc_def = jax.tree.flatten(deltas)

            def exchange_flat(res, *leaves):
                bodies = [leaf[0] for leaf in leaves]
                fn = (space.exchange_local if flat_engine == "exact"
                      else space.exchange_local_hist)
                mean_f, own_f, new_res_f = fn(bodies, res[0, 0])
                means = tuple(
                    m.astype(leaf.dtype)[None] for m, leaf in
                    zip(space.unflatten_local(mean_f), leaves)
                )
                if need_mask:
                    owns = tuple(
                        o.astype(leaf.dtype)[None] for o, leaf in
                        zip(space.unflatten_local(own_f), leaves)
                    )
                else:
                    owns = tuple(
                        jnp.zeros((1,) * leaf.ndim, leaf.dtype)
                        for leaf in leaves
                    )
                return means, new_res_f[None, None], owns

            mean_leaves, new_residual, own_leaves = shard_map(
                exchange_flat, mesh=mesh, in_specs=(res_spec,) + in_specs,
                out_specs=(in_specs, res_spec, own_specs),
            )(state["residual"], *delta_leaves)
            mean_tree = jax.tree.unflatten(acc_def, mean_leaves)
        else:
            # residual add (Alg. 1 l.10): acc = R + ΔW
            acc = jax.tree.map(
                lambda r, d: (r.astype(jnp.float32) + d.astype(jnp.float32)).astype(
                    cfg.residual_dtype
                ),
                state["residual"],
                deltas,
            )
            acc_leaves, acc_def = jax.tree.flatten(acc)

            def exchange(*leaves):
                """Per-leaf: compress own shard with the LEAF'S codec, exchange,
                and emit (mean ΔW, NEW residual = acc − own) — own itself never
                leaves the shard_map unless momentum masking needs it (§Perf B9)."""
                means, residuals, owns = [], [], []
                for leaf, is_scan, mode, p_leaf in zip(
                    leaves, scanned, modes, leaf_rates
                ):
                    body = leaf[0]  # client dim is locally 1 (sharded over clients)
                    L = body.shape[0] if is_scan and body.ndim > 1 else 1
                    flat = body.reshape(L, -1)
                    if mode == "sparse":
                        dense, own = _sbc_local(flat, p_leaf, client_axes, n_clients,
                                                out_dtype=leaf.dtype)
                    elif mode == "dense":
                        dense, own = _dense_local(flat.astype(jnp.float32),
                                                  client_axes, n_clients)
                    else:  # skip: no traffic; the residual keeps the full update
                        dense = jnp.zeros_like(flat, dtype=leaf.dtype)
                        own = dense
                    new_res = (flat.astype(jnp.float32) - own.astype(jnp.float32)).astype(
                        cfg.residual_dtype
                    )
                    means.append(dense.reshape(body.shape).astype(leaf.dtype)[None])
                    residuals.append(new_res.reshape(body.shape).astype(leaf.dtype)[None])
                    owns.append(own.reshape(body.shape).astype(leaf.dtype)[None]
                                if need_mask else jnp.zeros((1,) * leaf.ndim, leaf.dtype))
                return tuple(means), tuple(residuals), tuple(owns)

            mean_leaves, res_leaves, own_leaves = shard_map(
                exchange, mesh=mesh, in_specs=in_specs,
                out_specs=(in_specs, in_specs, own_specs),
            )(*acc_leaves)

            mean_tree = jax.tree.unflatten(acc_def, mean_leaves)
            new_residual = jax.tree.unflatten(acc_def, res_leaves)

        # every client reconstructs the identical mean update; take client 0
        mean_delta = jax.tree.map(lambda m: m[0], mean_tree)

        new_params = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) + d.astype(jnp.float32)).astype(p.dtype),
            params,
            mean_delta,
        )
        # momentum masking (supplement A) at transmitted coordinates
        if need_mask:
            own_tree = jax.tree.unflatten(acc_def, own_leaves)
            transmitted = jax.tree.map(lambda o: (o != 0).astype(jnp.float32), own_tree)
            opt_states = jax.vmap(opt.mask)(opt_states, transmitted)

        metrics = {"loss": jnp.mean(losses)}
        return (
            {"params": new_params, "opt": opt_states, "residual": new_residual},
            metrics,
        )

    def wrapped(state, batch):
        b_axes = ("data",) if cfg.client_mode == "pod" else None
        with hints.activation_sharding(
            mesh, batch_axes=b_axes, seq_axis="model",
            expert_axis="data" if cfg.moe_dispatch == "flat_ep" else None,
            seq_every=2 if "seq_every2" in opts else 1,
            lean_moe="lean_moe" in opts,
        ):
            return train_step(state, batch)

    jitted = jax.jit(
        wrapped,
        in_shardings=(state_shardings, None),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,),
    )

    residual_to_tree = None
    if space is not None:
        # host-side view of the flat sharded residual as the per-leaf
        # stacked pytree the legacy path stores (tests / checkpoints)
        p_treedef = jax.tree.structure(a_params)

        def _unf(res):
            return tuple(b[None] for b in space.unflatten_local(res[0, 0]))

        unf_jit = jax.jit(shard_map(
            _unf, mesh=mesh, in_specs=(res_spec,),
            out_specs=tuple(flat_r_specs),
        ))

        def residual_to_tree(flat_res):
            return jax.tree.unflatten(p_treedef, unf_jit(flat_res))

    return DistTrainFns(
        jitted, init_state, state_shardings, batch_shardings, a_state,
        bits_per_client=bits_policy,
        bits_dense=bits_dense,
        flat_space=space,
        residual_to_tree=residual_to_tree,
    )


# --------------------------------------------------------------- serve side


def cache_specs(cfg: ModelConfig, mesh: Mesh, a_caches: PyTree) -> PyTree:
    """Shardings for decode caches.

    k/v (B, L, Hkv, hd): batch over ('pod','data') when divisible; kv heads
    over 'model' when divisible, else the cache *sequence* dim over 'model'
    (flash-decoding style — DESIGN.md §4).  SSM states: channels over 'model'.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)
    b_axes = tuple(a for a in ("pod", "data") if a in sizes)
    b_total = math.prod(sizes[a] for a in b_axes) if b_axes else 1
    b_spec = _lead_spec(b_axes)

    def spec_for(path: str, leaf) -> P:
        shape = leaf.shape
        off = 1 if path.startswith("scan/") else 0
        dims: list[Any] = [None] * len(shape)
        name = path.split("/")[-1]
        if name in ("k", "v", "cross_k", "cross_v"):
            B, L, H = shape[off], shape[off + 1], shape[off + 2]
            if b_axes and B % b_total == 0:
                dims[off] = b_spec
            if H % m == 0:
                dims[off + 2] = "model"
            elif L % m == 0:
                dims[off + 1] = "model"
        elif name == "h":  # mamba (B, di, N)
            B, di = shape[off], shape[off + 1]
            if b_axes and B % b_total == 0:
                dims[off] = b_spec
            if di % m == 0:
                dims[off + 1] = "model"
        elif name in ("conv", "tm_prev", "cm_prev"):  # (B, w, ch)
            B, ch = shape[off], shape[-1]
            if b_axes and B % b_total == 0:
                dims[off] = b_spec
            if ch % m == 0:
                dims[-1] = "model"
        elif name == "s":  # rwkv (B, H, hs, hs)
            B, H = shape[off], shape[off + 1]
            if b_axes and B % b_total == 0:
                dims[off] = b_spec
            if H % m == 0:
                dims[off + 1] = "model"
        return P(*dims)

    flat, treedef = jax.tree_util.tree_flatten_with_path(a_caches)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(k.key if hasattr(k, "key") else str(k) for k in path)
        specs.append(spec_for(pstr, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


class DistServeFns(NamedTuple):
    serve_step: Callable
    param_shardings: Any
    cache_shardings: Any
    abstract_caches: Any


def make_dist_serve(
    cfg: ModelConfig, mesh: Mesh, *, batch: int, seq_len: int,
    model: Optional[Model] = None,
) -> DistServeFns:
    """One-token decode step against a ``seq_len``-deep sharded KV/SSM cache."""
    model = model or build_model(cfg)
    a_params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_specs = model.param_specs(a_params, mesh)
    ns = lambda s: NamedSharding(mesh, s)
    p_shard = jax.tree.map(ns, p_specs, is_leaf=lambda s: isinstance(s, P))

    a_caches = jax.eval_shape(lambda: model.init_caches(None, batch, seq_len))
    c_shard = jax.tree.map(
        ns, cache_specs(cfg, mesh, a_caches), is_leaf=lambda s: isinstance(s, P)
    )

    def step(params, tokens, caches, pos):
        with hints.activation_sharding(mesh, batch_axes=None, seq_axis=None):
            return model.decode_step(params, tokens, caches, pos)

    jitted = jax.jit(
        step,
        in_shardings=(p_shard, ns(P(None, None)), c_shard, ns(P())),
        out_shardings=(None, c_shard),
        donate_argnums=(2,),
    )
    return DistServeFns(jitted, p_shard, c_shard, a_caches)


class DistPrefillFns(NamedTuple):
    prefill: Callable
    param_shardings: Any
    batch_shardings: Callable


def make_dist_prefill(
    cfg: ModelConfig, mesh: Mesh, *, model: Optional[Model] = None
) -> DistPrefillFns:
    """Full-sequence prefill returning (hidden, caches) — the prefill_32k unit."""
    model = model or build_model(cfg)
    a_params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_specs = model.param_specs(a_params, mesh)
    ns = lambda s: NamedSharding(mesh, s)
    p_shard = jax.tree.map(ns, p_specs, is_leaf=lambda s: isinstance(s, P))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    b_axes = tuple(a for a in ("pod", "data") if a in sizes)
    b_total = math.prod(sizes[a] for a in b_axes) if b_axes else 1
    lead = _lead_spec(b_axes)

    def pre(params, batch):
        with hints.activation_sharding(mesh, batch_axes=b_axes, seq_axis="model"):
            return model.prefill(params, batch)

    def batch_shardings(batch_tree):
        def one(x):
            head = lead if x.shape[0] % b_total == 0 else None
            return ns(P(head, *([None] * (x.ndim - 1))))

        return jax.tree.map(one, batch_tree)

    jitted = jax.jit(pre, in_shardings=(p_shard, None))
    return DistPrefillFns(jitted, p_shard, batch_shardings)
