"""GSPMD distributed DSGD: sharded train_step / serve_step builders.

Design (DESIGN.md §4):

* **Clients.**  ``cfg.client_mode``:
    - 'data': one client per data coordinate (×pod) — per-client ΔW never
      crosses the data axis; the ONLY cross-client traffic is the sparse
      exchange.  Small/mid archs (params replicated over 'data').
    - 'pod':  one client per pod; grads all-reduce densely *inside* a pod
      (fast ICI), SBC compresses the cross-pod exchange (slow DCN).  ≥20B
      archs (params FSDP-sharded over 'data').

* **Shard-wise compression** (the TPU-native re-think of paper Alg. 2):
  compression runs inside ``shard_map`` — every device applies exact
  top-k + binarization to ITS OWN shard of ΔW, so the paper's O(n log n)
  global sort becomes an embarrassingly-local per-shard top-k, and the μ±
  means are per-(tensor, shard) instead of per-tensor (finer granularity,
  same wire format: one 32-bit scalar per shard).  The exchange is an
  explicit ``jax.lax.all_gather`` of (idx[k] int32, μ f32) over the client
  axes — the ×p bandwidth saving is therefore visible in the lowered HLO
  collective schedule, not just in a wire-format codec.

* **Dense baseline** (``compressor='none'``): the exchange is a mean over
  the client axis of the full ΔW — lowers to the dense all-reduce that the
  paper's Eq. 1 baseline counts.

* **Per-leaf policies** (DESIGN.md §3): an optional
  :class:`~repro.core.policy.CompressionPolicy` resolves every param leaf
  to one of this backend's exchange kernels (sparse SBC / dense all-reduce
  / skip) with its own sparsity rate, so DGC-style "dense biases + 0.1%
  matrices" recipes lower to a mixed collective schedule.

The compress → exchange → aggregate → account loop itself lives in
:class:`repro.core.channel.ShardedGspmdChannel` (DESIGN.md §12): this
module owns the *mesh* — model/param shardings, client topology, batch
specs — derives the channel's mesh-free per-leaf plan from the
PartitionSpecs, and wraps the channel's shard_map bodies with the right
in/out specs.  ``build_dist_train`` is the canonical builder (what
``repro.run.build_run(RunSpec(backend="gspmd"))`` calls);
``make_dist_train`` survives as a deprecated bit-identical shim.

Bit accounting is static (shapes and per-leaf rates are compile-time): per
sparse leaf, ``L·S_shards·(k_loc·b̄_pos(p_leaf) + 32)`` wire bits per client
per round; dense leaves count 32 bits/entry; skipped leaves count 0
(``channel.bits()``), and ``measure=True`` Golomb-encodes client 0's real
per-shard position streams into the channel ledger next to it.
"""
from __future__ import annotations

import math
import warnings
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.channel import (  # noqa: F401  (re-exported shard_map kernels)
    GspmdLeaf,
    ShardedGspmdChannel,
    _dense_local,
    _sbc_local,
    shard_map,
)
from repro.core.codec import Codec, make_codec
from repro.core.flat import ShardedFlatParamSpace
from repro.core.policy import CompressionPolicy, path_str
from repro.models import hints
from repro.models.model import Model, build_model
from repro.optim.optimizers import get_optimizer

PyTree = Any


# ----------------------------------------------------------- client topology


def client_topology(cfg: ModelConfig, mesh: Mesh) -> tuple[int, tuple[str, ...]]:
    """(n_clients, client mesh axes).  See module docstring."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if cfg.client_mode == "pod":
        return (sizes["pod"], ("pod",)) if "pod" in sizes else (1, ())
    axes = tuple(a for a in ("pod", "data") if a in sizes)
    return math.prod(sizes[a] for a in axes), axes


def _lead_spec(client_axes: tuple[str, ...]):
    if not client_axes:
        return None
    return client_axes[0] if len(client_axes) == 1 else client_axes


# ------------------------------------------------------------- spec plumbing


def stacked_specs(inner_specs: PyTree, client_axes: tuple[str, ...]) -> PyTree:
    """Specs for a (C,)+param-shaped tree (residual / momentum / adam)."""
    lead = _lead_spec(client_axes)
    return jax.tree.map(
        lambda s: P(lead, *s), inner_specs, is_leaf=lambda s: isinstance(s, P)
    )


def opt_state_specs(opt_name: str, param_specs: PyTree, client_axes) -> PyTree:
    inner = stacked_specs(param_specs, client_axes)
    if opt_name == "sgd":
        return ()
    if opt_name == "momentum":
        return inner
    if opt_name == "adam":
        from repro.optim.optimizers import AdamState

        return AdamState(inner, inner)
    raise ValueError(opt_name)


def _axes_of(entry) -> tuple:
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


def _shards_of(spec: P, mesh_sizes: dict[str, int]) -> int:
    total = 1
    for entry in spec:
        for ax in _axes_of(entry):
            total *= mesh_sizes.get(ax, 1)
    return total


def _shard_grid(shape, spec: P, mesh_sizes: dict[str, int]) -> tuple[int, ...]:
    """Per-dim shard counts of a leaf under ``spec`` (GSPMD equal blocks)."""
    entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    return tuple(
        math.prod(mesh_sizes.get(a, 1) for a in _axes_of(entry))
        for entry in entries
    )


def _local_shape(shape, spec: P, mesh_sizes: dict[str, int]) -> tuple[int, ...]:
    """One shard's shape of a leaf under ``spec`` (GSPMD equal blocks)."""
    return tuple(
        dim // g for dim, g in zip(shape, _shard_grid(shape, spec, mesh_sizes))
    )


# ------------------------------------------------- sharded flat param space


def _sharded_flat_space(
    cfg: ModelConfig,
    mesh: Mesh,
    flat_p,
    flat_specs,
    scanned,
    modes,
    leaf_rates,
    client_axes: tuple[str, ...],
    n_clients: int,
) -> Optional[ShardedFlatParamSpace]:
    """The §11 sharded flat layout for this (cfg, mesh, policy) — or None
    when the fast path does not apply (non-f32 leaves / non-f32 residual
    fall back to the per-leaf exchange, same rule as PR 3's single-device
    fast path)."""
    if jnp.dtype(cfg.residual_dtype) != jnp.float32:
        return None
    if any(leaf.dtype != jnp.float32 for _, leaf in flat_p):
        return None
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    shard_axes = tuple(a for a in mesh.axis_names if a not in client_axes)
    entries = []
    for (path, leaf), spec, is_scan, mode, p_leaf in zip(
        flat_p, flat_specs, scanned, modes, leaf_rates
    ):
        local = _local_shape(leaf.shape, spec, mesh_sizes)
        rows = local[0] if is_scan and len(local) > 1 else 1
        entries.append(dict(
            path="/".join(
                k.key if hasattr(k, "key") else str(k) for k in path
            ),
            shape=local,
            rows=rows,
            kind=mode,
            rate=p_leaf,
            n_shards=_shards_of(spec, mesh_sizes),
            global_size=leaf.size,
        ))
    return ShardedFlatParamSpace.build(
        entries,
        client_axes=client_axes,
        shard_axes=shard_axes,
        n_clients=n_clients,
        shards_per_client=math.prod(mesh_sizes[a] for a in shard_axes)
        if shard_axes else 1,
    )


# ------------------------------------------------------------ train builder


class DistTrainFns(NamedTuple):
    train_step: Callable  # (state, batch) -> (state, metrics)
    init_state: Callable  # rng -> state (unsharded; dry-run never calls it)
    state_shardings: Any
    batch_shardings: Callable  # batch pytree -> shardings pytree
    abstract_state: Any
    bits_per_client: float  # static Eq. 1 wire bits per round
    bits_dense: float
    # §11 sharded flat fast path (None when the per-leaf exchange runs):
    flat_space: Any = None  # ShardedFlatParamSpace bound to (cfg, mesh)
    residual_to_tree: Optional[Callable] = None  # flat residual → pytree
    channel: Any = None  # the ShardedGspmdChannel driving the exchange


def _dist_leaf_mode(codec: Codec) -> str:
    """Map a codec onto the shard_map exchange kernels this backend has.

    'sparse' → per-shard SBC + (idx, μ) all-gather; 'dense' → pmean
    all-reduce; 'skip' → no traffic.  Other codec compositions have no
    TPU-native exchange kernel yet and fail loudly.
    """
    if codec.skip:
        return "skip"
    if codec.selector.dense and codec.quantizer.name == "identity":
        return "dense"
    if codec.spec == "topk_signed|binarize|golomb":
        return "sparse"
    raise NotImplementedError(
        f"dist backend has no exchange kernel for codec {codec.spec!r}; "
        "supported: sbc (topk_signed|binarize|golomb), dense32, skip"
    )


def make_dist_train(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    compressor: str = "sbc",
    sparsity: float = 0.001,
    policy: Optional[CompressionPolicy] = None,
    model: Optional[Model] = None,
    opts: frozenset = frozenset(),
    fast: Optional[bool] = None,
    flat_engine: str = "exact",
) -> DistTrainFns:
    """Legacy name for :func:`build_dist_train` (the seed API surface).

    Survives as a documented bit-identical shim; new code should build the
    backend declaratively via ``repro.run.build_run(RunSpec(
    backend="gspmd", ...))`` or call :func:`build_dist_train`.
    """
    warnings.warn(
        "make_dist_train() is the legacy GSPMD surface; build it "
        "declaratively via repro.run.build_run(RunSpec(backend='gspmd', "
        "...)) or call repro.launch.dist.build_dist_train() (same "
        "lowering, bit-identical)",
        DeprecationWarning,
        stacklevel=2,
    )
    return build_dist_train(
        cfg, mesh, compressor=compressor, sparsity=sparsity, policy=policy,
        model=model, opts=opts, fast=fast, flat_engine=flat_engine,
    )


def build_dist_train(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    compressor: str = "sbc",
    sparsity: float = 0.001,
    policy: Optional[CompressionPolicy] = None,
    model: Optional[Model] = None,
    opts: frozenset = frozenset(),
    fast: Optional[bool] = None,
    flat_engine: str = "exact",
    measure: bool = False,
    device_pack: bool = False,
) -> DistTrainFns:
    """Build the sharded DSGD train_step for (cfg, mesh).

    State = {'params', 'opt', 'residual'}; batch has a leading client axis
    of size ``client_topology(cfg, mesh)[0]``.

    ``policy`` — optional per-leaf :class:`CompressionPolicy` (path-regex
    rules; DESIGN.md §3).  Each leaf resolves to one of this backend's
    exchange kernels (see :func:`_dist_leaf_mode`) with its own sparsity
    rate.  Without a policy, ``compressor`` picks one codec for every leaf
    ("sbc" or any dense codec name), matching the seed behavior.

    ``fast`` — None keeps the policy's own ``fast`` flag; True/False
    forces the §11 sharded flat exchange on or off.  When active, every
    device compresses its shard of ONE block-padded flat buffer inside
    ``shard_map`` (:class:`~repro.core.flat.ShardedFlatParamSpace`), the
    error-feedback residual is stored flat-sharded, and the exchange is
    one all_gather of packed (positions, μ) flat segments.  Output is
    bit-identical to the per-leaf exchange; non-f32 leaves (or a non-f32
    ``cfg.residual_dtype``) fall back to the per-leaf path silently,
    same as PR 3's single-device fast path.

    ``flat_engine`` — 'exact' (default; two-sided per-row top-k) or
    'hist' (the segment-aware Pallas passes, approximate survivor
    counts, dense pmean exchange); 'hist' needs an all-SBC policy and an
    active fast path.

    ``measure`` — every round, additionally emit client 0's transmitted
    ΔW* (``metrics['own_client0']`` — explicitly a CLIENT-0 SAMPLE, not
    a cohort sum; see docs/wire-format.md) so the channel ledger can
    Golomb-encode the
    real per-shard position streams next to the analytic Eq. 1 bits.

    ``device_pack`` — pack each client's Golomb position streams into
    wire words ON DEVICE (fused select→pack Pallas kernels, §11): the
    all_gather exchanges packed uint32 buffers (~b̄(p) bits/position)
    instead of 32-bit index arrays, and exact per-(client, shard, row)
    bit counts come back with the step so the ledger meters EVERY
    client's real upload (``metrics['packed_nbits']``) — no host
    re-encode, no client-0 sampling.  Needs the flat fast path with the
    exact engine.

    ``opts`` — §Perf beyond-baseline toggles (baseline = empty set):
      'expert_parallel'  experts shard over 'data', dispatch follows
      'seq_every2'       sequence-parallel hint on every 2nd block only
    """
    from repro.models.model import make_param_specs

    model = model or build_model(cfg)
    n_clients, client_axes = client_topology(cfg, mesh)
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    opt_kw = {} if cfg.local_opt == "sgd" else {"state_dtype": cfg.residual_dtype}
    opt = get_optimizer(cfg.local_opt, **opt_kw)
    if policy is None:
        default = "sbc" if compressor == "sbc" else "dense"
        policy = CompressionPolicy.single(make_codec(default), name=compressor)
    # the cfg's dispatch mode decides the MoE weight sharding rules
    # ('flat_ep'/'grouped' → EP rules; 'flat_fsdp' → baseline fsdp rules)
    ep_rules = cfg.moe_dispatch in ("flat_ep", "grouped")

    # ---- abstract state + shardings
    a_params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_specs = make_param_specs(a_params, mesh, fsdp=cfg.fsdp,
                               expert_parallel=ep_rules)
    flat_p = jax.tree_util.tree_flatten_with_path(a_params)[0]
    scanned = [
        "stack/scan" in "/".join(k.key if hasattr(k, "key") else str(k) for k in path)
        for path, _ in flat_p
    ]
    flat_specs = jax.tree.leaves(p_specs, is_leaf=lambda s: isinstance(s, P))
    lead = _lead_spec(client_axes)
    flat_r_specs = [P(lead, *s) for s in flat_specs]

    # ---- per-leaf policy resolution (codec + sparsity rate by path regex).
    # Rates are compile-time constants here: a per-round schedule would be
    # silently frozen at its round-0 value, so reject it loudly (re-build
    # the train fns per rate change, or use the vmap trainer instead).
    plans = [policy.plan_for(path_str(path)) for path, _ in flat_p]
    scheduled = [pl.path for pl in plans if pl.schedule is not None]
    if scheduled:
        raise NotImplementedError(
            "the GSPMD backend compiles per-leaf sparsity rates statically; "
            f"policy rules attach per-round schedules to {scheduled[:3]}… — "
            "rebuild the train fns when the rate changes, or use the local "
            "backend instead"
        )
    modes = [_dist_leaf_mode(pl.codec) for pl in plans]
    leaf_rates = [pl.rate(sparsity, 0) for pl in plans]

    # ---- the channel: §11 sharded flat fast path when it applies, the
    # per-leaf exchange otherwise (the dispatch ladder lives in core now)
    want_fast = policy.fast if fast is None else bool(fast)
    space = None
    if want_fast:
        space = _sharded_flat_space(
            cfg, mesh, flat_p, flat_specs, scanned, modes, leaf_rates,
            client_axes, n_clients,
        )
    channel = ShardedGspmdChannel(
        leaves=tuple(
            GspmdLeaf(
                path=path_str(path),
                global_shape=tuple(leaf.shape),
                dtype=leaf.dtype,
                scanned=is_scan,
                mode=mode,
                rate=p_leaf,
                n_shards=_shards_of(spec, mesh_sizes),
                shard_grid=_shard_grid(leaf.shape, spec, mesh_sizes),
            )
            for (path, leaf), spec, is_scan, mode, p_leaf in zip(
                flat_p, flat_specs, scanned, modes, leaf_rates
            )
        ),
        client_axes=client_axes,
        n_clients=n_clients,
        residual_dtype=cfg.residual_dtype,
        flat_space=space,
        flat_engine=flat_engine,
        device_pack=device_pack,
    )
    shard_axes = tuple(a for a in mesh.axis_names if a not in client_axes)
    res_spec = P(lead, _lead_spec(shard_axes), None)

    def stack_c(tree):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_clients,) + x.shape).copy(), tree
        )

    def init_state(rng):
        params = model.init(rng)
        return {
            "params": params,
            "opt": stack_c(opt.init(params)),
            "residual": channel.init_state(params),
        }

    a_state = jax.eval_shape(init_state, jax.random.PRNGKey(0))
    state_specs = {
        "params": p_specs,
        "opt": opt_state_specs(cfg.local_opt, p_specs, client_axes),
        "residual": res_spec if space is not None else jax.tree.unflatten(
            jax.tree.structure(p_specs, is_leaf=lambda s: isinstance(s, P)), flat_r_specs
        ),
    }
    ns = lambda spec: NamedSharding(mesh, spec)
    state_shardings = jax.tree.map(ns, state_specs, is_leaf=lambda s: isinstance(s, P))

    # ---- static Eq. 1 bit accounting per round per client (channel-owned)
    bits = channel.bits()

    # ---- batch shardings
    inner = "data" if cfg.client_mode == "pod" else None

    def batch_shardings(batch_tree):
        def one(x):
            return ns(P(lead, inner, *([None] * (x.ndim - 2))))

        return jax.tree.map(one, batch_tree)

    # ---- the step
    need_mask = cfg.local_opt != "sgd"  # momentum masking needs ΔW*_i
    need_own = need_mask or measure

    def train_step(state, batch):
        params = state["params"]

        def local(opt_state, client_batch):
            loss, g = jax.value_and_grad(model.loss_fn)(params, client_batch)
            p2, os2 = opt.apply(opt_state, g, params, cfg.base_lr, jnp.zeros((), jnp.int32))
            delta = jax.tree.map(
                lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32)).astype(
                    cfg.residual_dtype
                ),
                p2,
                params,
            )
            return delta, os2, loss

        deltas, opt_states, losses = jax.vmap(local)(state["opt"], batch)

        # ---- compress + exchange + residual, one channel call (§12)
        packed = None
        if device_pack:
            mean_tree, new_residual, own_tree, packed = channel.round_exchange(
                state["residual"], deltas,
                mesh=mesh, in_specs=tuple(flat_r_specs), res_spec=res_spec,
                need_own=need_own,
            )
        else:
            mean_tree, new_residual, own_tree = channel.round_exchange(
                state["residual"], deltas,
                mesh=mesh, in_specs=tuple(flat_r_specs), res_spec=res_spec,
                need_own=need_own,
            )

        # every client reconstructs the identical mean update; take client 0
        mean_delta = jax.tree.map(lambda m: m[0], mean_tree)

        new_params = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) + d.astype(jnp.float32)).astype(p.dtype),
            params,
            mean_delta,
        )
        # momentum masking (supplement A) at transmitted coordinates
        if need_mask:
            transmitted = jax.tree.map(lambda o: (o != 0).astype(jnp.float32), own_tree)
            opt_states = jax.vmap(opt.mask)(opt_states, transmitted)

        metrics = {"loss": jnp.mean(losses)}
        if measure:
            # client 0's transmitted ΔW*, for host-side wire metering
            metrics["own_client0"] = jax.tree.map(lambda o: o[0], own_tree)
            if device_pack:
                # exact per-(client, shard, row) packed wire bits + client
                # 0's packed word buffer (byte-identity tests read it)
                metrics["packed_nbits"] = packed[1]
                metrics["packed_words_client0"] = packed[0][0]
        return (
            {"params": new_params, "opt": opt_states, "residual": new_residual},
            metrics,
        )

    def wrapped(state, batch):
        b_axes = ("data",) if cfg.client_mode == "pod" else None
        with hints.activation_sharding(
            mesh, batch_axes=b_axes, seq_axis="model",
            expert_axis="data" if cfg.moe_dispatch == "flat_ep" else None,
            seq_every=2 if "seq_every2" in opts else 1,
            lean_moe="lean_moe" in opts,
        ):
            return train_step(state, batch)

    jitted = jax.jit(
        wrapped,
        in_shardings=(state_shardings, None),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,),
    )

    residual_to_tree = None
    if space is not None:
        # host-side view of the flat sharded residual as the per-leaf
        # stacked pytree the legacy path stores (tests / checkpoints)
        p_treedef = jax.tree.structure(a_params)

        def _unf(res):
            return tuple(b[None] for b in space.unflatten_local(res[0, 0]))

        unf_jit = jax.jit(shard_map(
            _unf, mesh=mesh, in_specs=(res_spec,),
            out_specs=tuple(flat_r_specs),
        ))

        def residual_to_tree(flat_res):
            return jax.tree.unflatten(p_treedef, unf_jit(flat_res))

    return DistTrainFns(
        jitted, init_state, state_shardings, batch_shardings, a_state,
        bits_per_client=bits.per_client,
        bits_dense=bits.dense,
        flat_space=space,
        residual_to_tree=residual_to_tree,
        channel=channel,
    )


# --------------------------------------------------------------- serve side


def cache_specs(cfg: ModelConfig, mesh: Mesh, a_caches: PyTree) -> PyTree:
    """Shardings for decode caches.

    k/v (B, L, Hkv, hd): batch over ('pod','data') when divisible; kv heads
    over 'model' when divisible, else the cache *sequence* dim over 'model'
    (flash-decoding style — DESIGN.md §4).  SSM states: channels over 'model'.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)
    b_axes = tuple(a for a in ("pod", "data") if a in sizes)
    b_total = math.prod(sizes[a] for a in b_axes) if b_axes else 1
    b_spec = _lead_spec(b_axes)

    def spec_for(path: str, leaf) -> P:
        shape = leaf.shape
        off = 1 if path.startswith("scan/") else 0
        dims: list[Any] = [None] * len(shape)
        name = path.split("/")[-1]
        if name in ("k", "v", "cross_k", "cross_v"):
            B, L, H = shape[off], shape[off + 1], shape[off + 2]
            if b_axes and B % b_total == 0:
                dims[off] = b_spec
            if H % m == 0:
                dims[off + 2] = "model"
            elif L % m == 0:
                dims[off + 1] = "model"
        elif name == "h":  # mamba (B, di, N)
            B, di = shape[off], shape[off + 1]
            if b_axes and B % b_total == 0:
                dims[off] = b_spec
            if di % m == 0:
                dims[off + 1] = "model"
        elif name in ("conv", "tm_prev", "cm_prev"):  # (B, w, ch)
            B, ch = shape[off], shape[-1]
            if b_axes and B % b_total == 0:
                dims[off] = b_spec
            if ch % m == 0:
                dims[-1] = "model"
        elif name == "s":  # rwkv (B, H, hs, hs)
            B, H = shape[off], shape[off + 1]
            if b_axes and B % b_total == 0:
                dims[off] = b_spec
            if H % m == 0:
                dims[off + 1] = "model"
        return P(*dims)

    flat, treedef = jax.tree_util.tree_flatten_with_path(a_caches)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(k.key if hasattr(k, "key") else str(k) for k in path)
        specs.append(spec_for(pstr, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


class DistServeFns(NamedTuple):
    serve_step: Callable
    param_shardings: Any
    cache_shardings: Any
    abstract_caches: Any


def make_dist_serve(
    cfg: ModelConfig, mesh: Mesh, *, batch: int, seq_len: int,
    model: Optional[Model] = None,
) -> DistServeFns:
    """One-token decode step against a ``seq_len``-deep sharded KV/SSM cache."""
    model = model or build_model(cfg)
    a_params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_specs = model.param_specs(a_params, mesh)
    ns = lambda s: NamedSharding(mesh, s)
    p_shard = jax.tree.map(ns, p_specs, is_leaf=lambda s: isinstance(s, P))

    a_caches = jax.eval_shape(lambda: model.init_caches(None, batch, seq_len))
    c_shard = jax.tree.map(
        ns, cache_specs(cfg, mesh, a_caches), is_leaf=lambda s: isinstance(s, P)
    )

    def step(params, tokens, caches, pos):
        with hints.activation_sharding(mesh, batch_axes=None, seq_axis=None):
            return model.decode_step(params, tokens, caches, pos)

    jitted = jax.jit(
        step,
        in_shardings=(p_shard, ns(P(None, None)), c_shard, ns(P())),
        out_shardings=(None, c_shard),
        donate_argnums=(2,),
    )
    return DistServeFns(jitted, p_shard, c_shard, a_caches)


class DistPrefillFns(NamedTuple):
    prefill: Callable
    param_shardings: Any
    batch_shardings: Callable


def make_dist_prefill(
    cfg: ModelConfig, mesh: Mesh, *, model: Optional[Model] = None
) -> DistPrefillFns:
    """Full-sequence prefill returning (hidden, caches) — the prefill_32k unit."""
    model = model or build_model(cfg)
    a_params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_specs = model.param_specs(a_params, mesh)
    ns = lambda s: NamedSharding(mesh, s)
    p_shard = jax.tree.map(ns, p_specs, is_leaf=lambda s: isinstance(s, P))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    b_axes = tuple(a for a in ("pod", "data") if a in sizes)
    b_total = math.prod(sizes[a] for a in b_axes) if b_axes else 1
    lead = _lead_spec(b_axes)

    def pre(params, batch):
        with hints.activation_sharding(mesh, batch_axes=b_axes, seq_axis="model"):
            return model.prefill(params, batch)

    def batch_shardings(batch_tree):
        def one(x):
            head = lead if x.shape[0] % b_total == 0 else None
            return ns(P(head, *([None] * (x.ndim - 1))))

        return jax.tree.map(one, batch_tree)

    jitted = jax.jit(pre, in_shardings=(p_shard, None))
    return DistPrefillFns(jitted, p_shard, batch_shardings)


# -------------------------------------------------------------- launcher


def build_parser():
    """Thin parser over the shared RunSpec surface, pinned to gspmd."""
    import argparse

    from repro.run.flags import add_run_flags

    ap = argparse.ArgumentParser(
        description="GSPMD sharded DSGD launcher (one client per mesh "
        "data coordinate; run under XLA_FLAGS=--xla_force_host_platform_"
        "device_count=N to fan out on CPU)"
    )
    add_run_flags(ap, backend="gspmd", preset="tiny", rounds=10, log_every=5)
    return ap


def main(argv=None):
    from repro.run.build import build_run
    from repro.run.flags import spec_from_args

    args = build_parser().parse_args(argv)
    spec = spec_from_args(args, backend="gspmd")
    run = build_run(spec)
    print(
        f"gspmd: {run.n_clients} clients over {run.mesh.devices.size} "
        f"device(s), p={spec.sparsity}, fast={spec.fast}, "
        f"bits/client/round={run.fns.bits_per_client:.3e} "
        f"(dense {run.fns.bits_dense:.3e})"
    )
    state, hist = run.run(log_every=args.log_every)
    print(f"loss {hist['loss'][0]:.4f} → {hist['loss'][-1]:.4f}  "
          f"compression ×{hist['compression_rate']:.0f}")
    if spec.measure_wire:
        run.ledger.reconcile(rel=0.1)
        t = run.ledger.totals()
        print(
            f"wire: up {t['up_bytes']/1e3:.1f} kB (measured/analytic "
            f"×{t['up_bits_measured']/max(t['up_bits_analytic'],1):.3f})"
        )
    if spec.telemetry:
        from repro.obs import finish_run

        finish_run(
            run.telemetry, trace=args.trace, metrics_out=args.metrics_out,
            meta={"backend": "gspmd", "preset": spec.preset,
                  "rounds": spec.rounds},
        )
    if args.history:
        import json
        import os

        os.makedirs(os.path.dirname(os.path.abspath(args.history)), exist_ok=True)
        with open(args.history, "w") as f:
            json.dump(hist, f, default=float)
    return hist


if __name__ == "__main__":
    main()
