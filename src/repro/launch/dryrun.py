import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × input shape × mesh).

For each live pair this script:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. lowers the appropriate step — train_step (train_4k), prefill
     (prefill_32k) or serve_step (decode_32k / long_500k) — against
     ShapeDtypeStruct stand-ins (zero allocation),
  3. compiles, prints memory_analysis() (proof-of-fit) and cost_analysis(),
  4. derives the three roofline terms (launch.roofline) and appends a JSON
     record to experiments/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs.base import ASSIGNED_ARCHS, INPUT_SHAPES, get_config, input_specs
from repro.launch import roofline
from repro.launch.dist import (
    build_dist_train,
    client_topology,
    make_dist_prefill,
    make_dist_serve,
)
from repro.launch.mesh import make_production_mesh
from repro.paths import experiments_dir
from repro.run.flags import add_compression_flags

OUT_DIR = experiments_dir("dryrun")


def scan_trips_for(cfg) -> int:
    from repro.models.transformer import stack_pattern

    try:
        _, n_scan, _ = stack_pattern(cfg)
        return max(1, n_scan)
    except Exception:
        return 1


def lower_pair(cfg, shape_name: str, mesh, *, compressor: str = "sbc",
               sparsity: float = 0.001, opts: frozenset = frozenset(),
               fast: bool = False):
    """Returns (lowered, compiled, meta dict)."""
    shape = INPUT_SHAPES[shape_name]
    kind = shape["kind"]
    n_dev = mesh.devices.size

    if kind == "train":
        fns = build_dist_train(cfg, mesh, compressor=compressor, sparsity=sparsity,
                               opts=opts, fast=True if fast else None)
        n_clients, _ = client_topology(cfg, mesh)
        batch_sds = input_specs(cfg, shape_name, n_clients=n_clients)
        # drop the labels/tokens etc already shaped (C, per, ...) — attach shardings
        b_shard = fns.batch_shardings(batch_sds)
        batch_sds = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            batch_sds, b_shard,
        )
        state_sds = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            fns.abstract_state, fns.state_shardings,
        )
        lowered = fns.train_step.lower(state_sds, batch_sds)
        meta = {"unit": "train_step", "n_clients": n_clients,
                "bits_per_client": fns.bits_per_client, "bits_dense": fns.bits_dense,
                "flat_fast": fns.flat_space is not None}
    elif kind == "prefill":
        fns = make_dist_prefill(cfg, mesh)
        batch_sds = input_specs(cfg, shape_name)
        b_shard = fns.batch_shardings(batch_sds)
        batch_sds = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            batch_sds, b_shard,
        )
        params_sds = _param_sds(cfg, fns.param_shardings)
        lowered = fns.prefill.lower(params_sds, batch_sds)
        meta = {"unit": "prefill"}
    else:  # decode
        fns = make_dist_serve(cfg, mesh, batch=shape["global_batch"], seq_len=shape["seq_len"])
        params_sds = _param_sds(cfg, fns.param_shardings)
        caches_sds = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            fns.abstract_caches, fns.cache_shardings,
        )
        tok_sds = jax.ShapeDtypeStruct((shape["global_batch"], 1), jax.numpy.int32)
        pos_sds = jax.ShapeDtypeStruct((), jax.numpy.int32)
        lowered = fns.serve_step.lower(params_sds, tok_sds, caches_sds, pos_sds)
        meta = {"unit": "serve_step"}

    compiled = lowered.compile()
    return lowered, compiled, meta


def _param_sds(cfg, p_shardings):
    from repro.models.model import build_model

    model = build_model(cfg)
    a_params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        a_params, p_shardings,
    )


def run_pair(arch: str, shape_name: str, multi_pod: bool, *, compressor="sbc",
             sparsity=0.001, save=True, verbose=True,
             opts: frozenset = frozenset(), fast: bool = False,
             out_dir: str = None) -> dict:
    cfg = get_config(arch)
    mesh_name = "multi" if multi_pod else "single"
    if opts:
        mesh_name += "+" + "+".join(sorted(opts))
    record: dict = {"arch": cfg.name, "shape": shape_name, "mesh": mesh_name,
                    "compressor": compressor, "opts": sorted(opts)}
    reason = cfg.skip_reason(shape_name)
    if reason:
        record["status"] = "skip"
        record["reason"] = reason
        if verbose:
            print(f"[skip]   {cfg.name} × {shape_name}: {reason}")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        lowered, compiled, meta = lower_pair(
            cfg, shape_name, mesh, compressor=compressor, sparsity=sparsity,
            opts=opts, fast=fast,
        )
        record.update(meta)
        mem = compiled.memory_analysis()
        shape = INPUT_SHAPES[shape_name]
        rf = roofline.analyze(
            compiled,
            n_devices=mesh.devices.size,
            model_flops=roofline.model_flops_for(cfg, shape, shape["kind"]),
            pod_group_size=2 if multi_pod else None,
            scan_trips=scan_trips_for(cfg),
        )
        record["status"] = "ok"
        record["compile_s"] = round(time.time() - t0, 1)
        record["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
        record["roofline"] = rf.summary()
        if verbose:
            tb = record["memory"]["temp_bytes"] or 0
            print(
                f"[ok]     {cfg.name} × {shape_name} × {mesh_name}  "
                f"compile {record['compile_s']}s  temp/dev "
                f"{tb/2**30:.2f} GiB  dominant={rf.dominant}  "
                f"(C={rf.compute_s:.3f}s M={rf.memory_s:.3f}s X={rf.collective_s:.3f}s)"
            )
    except Exception as e:
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[ERROR]  {cfg.name} × {shape_name} × {mesh_name}: {record['error'][:200]}")
    if save:
        out_dir = out_dir or OUT_DIR
        os.makedirs(out_dir, exist_ok=True)
        key = cfg.name.replace("/", "_")  # canonical id regardless of alias
        path = os.path.join(out_dir, f"{key}__{shape_name}__{mesh_name}.json")
        slim = {k: v for k, v in record.items() if k != "traceback"}
        with open(path, "w") as f:
            json.dump(slim, f, indent=1, default=str)
    return record


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--opts", default="", help="comma list: expert_parallel,seq_every2")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default=None,
                    help="record directory (default experiments/dryrun)")
    # the shared compression surface (only compressor/sparsity/fast bear on
    # lowering; policy patterns resolve per leaf exactly as in training)
    add_compression_flags(ap)
    return ap


def main():
    args = build_parser().parse_args()
    opts = frozenset(o for o in args.opts.split(",") if o)

    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(
                    run_pair(arch, shape, mp, compressor=args.compressor,
                             sparsity=args.sparsity, opts=opts, fast=args.fast,
                             out_dir=args.out_dir)
                )
    ok = sum(r["status"] == "ok" for r in results)
    skip = sum(r["status"] == "skip" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"\n== dry-run: {ok} ok / {skip} skip / {err} error ==")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
