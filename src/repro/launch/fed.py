"""Federated orchestration launcher (DESIGN.md §9).

Drives the paper's §I parameter-server deployment end to end on the
:mod:`repro.fed` subsystem: M heterogeneous clients, partial participation,
real packed SBW1 buffers in BOTH directions, pluggable aggregation, and
per-round bidirectional byte accounting reconciled against Eq. 1/Eq. 5.

Examples:
  PYTHONPATH=src python -m repro.launch.fed --rounds 2 --clients 4 --cohort 2
  PYTHONPATH=src python -m repro.launch.fed --clients 64 --cohort 8 \
      --rounds 50 --delay 5 --sparsity 0.01 --down-sparsity 0.05 --non-iid
  PYTHONPATH=src python -m repro.launch.fed --async --max-staleness 4 \
      --agg staleness --clients 32 --cohort 8 --rounds 30
  PYTHONPATH=src python -m repro.launch.fed \
      --profiles 1:0.001,5:0.01,25:0.04 --clients 24 --cohort 12

``--profiles d:p[:w],...`` assigns client c the (delay, sparsity[, weight])
triple at index ``c % len(profiles)`` — the paper's temporal-vs-gradient
sparsity trade-off swept *within one run*.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.api import CompressionPolicy, PolicyRule, get_compressor
from repro.core.policy import DENSE_SMALL_PATTERN
from repro.data import make_lm_task, make_non_iid_lm_task
from repro.fed import ClientPool, ClientProfile, ParameterServer, RoundScheduler
from repro.models.model import build_model
from repro.optim import get_optimizer


def fed_tiny_config() -> ModelConfig:
    """The reduced federated preset — small enough for CI smoke rounds."""
    return ModelConfig(
        name="fed-tiny", family="decoder", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab_size=256, dtype=jnp.float32,
    )


def parse_profiles(spec: str, default_delay: int, default_p: float):
    """"d:p[:w],d:p[:w],..." → tuple of ClientProfile; empty → one default."""
    if not spec:
        return (ClientProfile(delay=default_delay, sparsity=default_p),)
    out = []
    for part in spec.split(","):
        fields = part.split(":")
        if len(fields) not in (2, 3):
            raise ValueError(f"bad profile {part!r}; want delay:sparsity[:weight]")
        delay, p = int(fields[0]), float(fields[1])
        w = float(fields[2]) if len(fields) == 3 else 1.0
        out.append(ClientProfile(delay=delay, sparsity=p, weight=w))
    return tuple(out)


def build_policy(compressor: str, fast: bool = False) -> CompressionPolicy:
    """The DGC-style recipe: tiny leaves ride dense, matrices get the
    chosen codec (see DESIGN.md §3).  ``fast=True`` opts client uploads AND
    the server's per-round broadcast re-compression into the flat-buffer
    fast path (DESIGN.md §10)."""
    comp = get_compressor(compressor)
    return CompressionPolicy(
        default=comp.codec,
        rules=(PolicyRule(DENSE_SMALL_PATTERN, codec="dense32"),) + comp.policy.rules,
        name=f"{compressor}+dense-small",
        fast=fast,
    )


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--cohort", type=int, default=None,
                    help="sampled clients per round (default: all)")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--delay", type=int, default=3,
                    help="local steps per round (temporal sparsity)")
    ap.add_argument("--sparsity", type=float, default=0.01,
                    help="upstream gradient sparsity")
    ap.add_argument("--down-sparsity", type=float, default=1.0,
                    help="broadcast sparsity (1.0 = dense downstream)")
    ap.add_argument("--compressor", default="sbc")
    ap.add_argument("--agg", default=None,
                    choices=["mean", "weighted", "staleness"],
                    help="aggregation (default: mean sync / staleness async)")
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="async rounds with stale client starts")
    ap.add_argument("--max-staleness", type=int, default=4)
    ap.add_argument("--staleness-beta", type=float, default=0.5)
    ap.add_argument("--non-iid", action="store_true",
                    help="per-client Markov chains instead of IID shards")
    ap.add_argument("--skew", type=float, default=2.0,
                    help="non-IID interpolation strength")
    ap.add_argument("--profiles", default="",
                    help="heterogeneous clients: 'delay:sparsity[:weight],...'")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--history", default=None, help="metrics JSON path")
    ap.add_argument("--fast", action="store_true",
                    help="flat-buffer compression fast path (DESIGN.md §10)")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    cfg = fed_tiny_config()
    model = build_model(cfg)
    if args.non_iid:
        task = make_non_iid_lm_task(
            vocab=cfg.vocab_size, batch=args.batch, seq_len=args.seq_len,
            n_clients=args.clients, skew=args.skew, temperature=0.5,
            seed=args.seed,
        )
    else:
        task = make_lm_task(vocab=cfg.vocab_size, batch=args.batch,
                            seq_len=args.seq_len, temperature=0.5,
                            seed=args.seed)

    policy = build_policy(args.compressor, fast=args.fast)
    profiles = parse_profiles(args.profiles, args.delay, args.sparsity)
    agg = args.agg or ("staleness" if args.async_mode else "mean")

    params = model.init(jax.random.PRNGKey(args.seed))
    server = ParameterServer(
        params=params, up_policy=policy, down_sparsity=args.down_sparsity,
        aggregator=agg, staleness_beta=args.staleness_beta,
    )
    pool = ClientPool(
        model=model, optimizer=get_optimizer(cfg.local_opt), policy=policy,
        task=task, n_clients=args.clients, lr=lambda it: args.lr,
        profiles=profiles, seed=args.seed,
    )
    sched = RoundScheduler(
        server=server, pool=pool,
        cohort_size=args.cohort or args.clients,
        mode="async" if args.async_mode else "sync",
        max_staleness=args.max_staleness, seed=args.seed,
    )

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(
        f"fed: {args.clients} clients (cohort {sched.cohort_size}), "
        f"{len(profiles)} profile(s), agg={agg}, "
        f"mode={'async' if args.async_mode else 'sync'}, "
        f"{'non-IID' if args.non_iid else 'IID'}, params={n_params/1e6:.2f}M"
    )
    print(pool.resolved(params).describe())

    t0 = time.time()
    hist = sched.run(args.rounds, log_every=args.log_every)
    dt = time.time() - t0
    sched.ledger.reconcile(rel=0.1)
    t = sched.ledger.totals()
    # dense DSGD uploads 32·n_params bits per LOCAL STEP, i.e. ×delay per
    # member per round (delay varies per profile)
    dense_up_bits = sum(
        32.0 * n_params * pool.profile_of(c).delay
        for rec in sched.ledger.records
        for c in rec.cohort
    )
    print(
        f"done in {dt:.1f}s ({args.rounds / dt:.2f} rounds/s): "
        f"loss {hist['loss'][0]:.4f} → {hist['loss'][-1]:.4f}"
    )
    print(
        f"wire: up {t['up_bytes']/1e3:.1f} kB, down {t['down_bytes']/1e3:.1f} kB "
        f"(measured/analytic up ×{t['up_bits_measured']/max(t['up_bits_analytic'],1):.3f}, "
        f"down ×{t['down_bits_measured']/max(t['down_bits_analytic'],1):.3f}); "
        f"dense up would be {dense_up_bits / 8e6:.1f} MB "
        f"(×{dense_up_bits / max(t['up_bytes'] * 8, 1):.0f})"
    )
    if args.history:
        os.makedirs(os.path.dirname(os.path.abspath(args.history)), exist_ok=True)
        with open(args.history, "w") as f:
            json.dump(hist, f, default=float)
        print(f"wrote {args.history}")
    return hist


if __name__ == "__main__":
    main()
