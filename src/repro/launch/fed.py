"""Federated orchestration launcher — a thin parser over ``repro.run``.

Drives the paper's §I parameter-server deployment end to end on the
:mod:`repro.fed` subsystem through a
:class:`~repro.core.channel.FedWireChannel`: M heterogeneous clients,
partial participation, real packed SBW1 buffers in BOTH directions,
pluggable aggregation, and per-round bidirectional byte accounting
reconciled against Eq. 1/Eq. 5.  All flags are the shared
:func:`repro.run.add_run_flags` surface with this launcher's defaults
(fed-tiny preset, DGC-style dense-small policy rule) pinned on top.

Examples:
  PYTHONPATH=src python -m repro.launch.fed --rounds 2 --clients 4 --cohort 2
  PYTHONPATH=src python -m repro.launch.fed --clients 64 --cohort 8 \
      --rounds 50 --delay 5 --sparsity 0.01 --down-sparsity 0.05 --non-iid
  PYTHONPATH=src python -m repro.launch.fed --async --max-staleness 4 \
      --agg staleness --clients 32 --cohort 8 --rounds 30
  PYTHONPATH=src python -m repro.launch.fed \
      --profiles 1:0.001,5:0.01,25:0.04 --clients 24 --cohort 12

``--profiles d:p[:w],...`` assigns client c the (delay, sparsity[, weight])
triple at index ``c % len(profiles)`` — the paper's temporal-vs-gradient
sparsity trade-off swept *within one run*.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax

from repro.core.policy import DENSE_SMALL_PATTERN
from repro.run.build import build_run
from repro.run.flags import add_run_flags, spec_from_args
from repro.run.presets import fed_tiny_config  # noqa: F401 (re-export)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    add_run_flags(
        ap,
        preset="fed-tiny",
        backend="fed",
        clients=16,
        rounds=20,
        delay=3,
        sparsity=0.01,
        lr=0.05,
        log_every=5,
        # the DGC-style recipe: tiny leaves (biases, norm scales) ride
        # dense, matrices get the chosen codec (DESIGN.md §3)
        dense_pattern=DENSE_SMALL_PATTERN,
    )
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)
    spec = spec_from_args(args, backend="fed")
    run = build_run(spec)
    sched = run.init()
    pool, server = sched.pool, sched.server

    params = server.params
    n_params = sum(x.size for x in jax.tree.leaves(params))
    profiles = pool.profiles
    print(
        f"fed: {spec.clients} clients (cohort {sched.cohort_size}), "
        f"{len(profiles)} profile(s), agg={server.aggregator}, "
        f"mode={sched.mode}, "
        f"{'non-IID' if spec.non_iid else 'IID'}, params={n_params/1e6:.2f}M"
    )
    print(pool.resolved(params).describe())

    t0 = time.time()
    if spec.telemetry:
        # route through Run.run so the traced loop wraps every round in a
        # span and ingests the ledger into round-tagged gauges at the end
        _, hist = run.run(spec.rounds, log_every=args.log_every)
    else:
        from repro.fed.checkpoint import restore_fed_state
        from repro.fed.faults import ServerKilled

        hist, start = None, 0
        while hist is None:
            try:
                hist = sched.run(spec.rounds, log_every=args.log_every,
                                 start_round=start)
            except ServerKilled as e:
                # a scheduled --faults kill fired: checkpoint the whole
                # federation, rebuild from scratch, restore, and continue
                # — the CLI surface of bit-identical mid-round resume
                fd, ckpt = tempfile.mkstemp(suffix=".fedckpt.npz")
                os.close(fd)
                print(f"server killed at round {e.round_idx} ({e.step}); "
                      f"checkpoint → restore → resume")
                run.checkpoint(sched, ckpt, rounds_done=e.round_idx)
                run = build_run(spec)
                sched = run.init()
                restore_fed_state(ckpt, sched)
                os.unlink(ckpt)
                pool, server = sched.pool, sched.server
                pending = sched.resume_pending()
                start = e.round_idx + (1 if pending is not None else 0)
    dt = time.time() - t0
    sched.ledger.reconcile(rel=0.1)
    t = sched.ledger.totals()
    # dense DSGD uploads 32·n_params bits per LOCAL STEP, i.e. ×delay per
    # member per round (delay varies per profile)
    dense_up_bits = sum(
        32.0 * n_params * pool.profile_of(c).delay
        for rec in sched.ledger.records
        for c in rec.cohort
    )
    loss_arc = (
        f"loss {hist['loss'][0]:.4f} → {hist['loss'][-1]:.4f}"
        if hist["loss"] else "loss n/a (every round predates the resume)"
    )
    print(f"done in {dt:.1f}s ({spec.rounds / dt:.2f} rounds/s): {loss_arc}")
    print(
        f"wire: up {t['up_bytes']/1e3:.1f} kB, down {t['down_bytes']/1e3:.1f} kB "
        f"(measured/analytic up ×{t['up_bits_measured']/max(t['up_bits_analytic'],1):.3f}, "
        f"down ×{t['down_bits_measured']/max(t['down_bits_analytic'],1):.3f}); "
        f"dense up would be {dense_up_bits / 8e6:.1f} MB "
        f"(×{dense_up_bits / max(t['up_bytes'] * 8, 1):.0f})"
    )
    if t["up_bytes_wasted"]:
        print(
            f"elasticity: {t['up_bytes_wasted']/1e3:.1f} kB of uploads "
            "wasted (straggler aborts + corrupt rejects)"
        )
    if spec.telemetry:
        from repro.obs import finish_run

        finish_run(
            run.telemetry, trace=args.trace, metrics_out=args.metrics_out,
            meta={"backend": "fed", "preset": spec.preset,
                  "rounds": spec.rounds},
        )
    if args.history:
        os.makedirs(os.path.dirname(os.path.abspath(args.history)), exist_ok=True)
        with open(args.history, "w") as f:
            json.dump(hist, f, default=float)
        print(f"wrote {args.history}")
    return hist


if __name__ == "__main__":
    main()
