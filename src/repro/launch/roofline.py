"""Roofline terms from a compiled dry-run artifact (no real hardware).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = Σ per-collective  bytes·steps / ICI_bw

Sources: ``compiled.cost_analysis()`` supplies flops / bytes accessed —
these are PER-DEVICE numbers (the SPMD module is a per-device program).
Collective bytes are NOT in cost_analysis: we parse ``compiled.as_text()``
(post-partitioning optimized HLO, shapes are per-shard) and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, weighting by the ring-step factor for the collective's
group size N:

    all-reduce      2·(N−1)/N     (reduce-scatter + all-gather ring)
    all-gather      (N−1)/N       (output bytes leaving/entering the chip)
    reduce-scatter  (N−1)/N
    all-to-all      (N−1)/N
    collective-permute  1

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
ICI ~50 GB/s/link — we budget 2 links per mesh axis → 100 GB/s of ICI
bandwidth per chip per collective (documented simplification; the 'pod'
axis crosses DCN at ~25 GB/s/chip which we apply to pod-group collectives).

Ops inside loop bodies: HLO while-loops (lax.scan over superblocks /
decode steps) print the body once; cost_analysis already accounts loop trip
counts for flops.  For collective bytes we multiply body collectives by the
scan trip count parsed from the surrounding while loop when detectable; the
dominant scan (layers) has its trip count in the config, so callers pass
``scan_trips`` to scale collectives found inside loop bodies.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 100e9  # bytes/s per chip (2 × 50 GB/s links per axis)
DCN_BW = 25e9  # bytes/s per chip across pods

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c\d+)\[([\d,]*)\]")
_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_REPLICA_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str, dims_str: str) -> int:
    n = 1
    if dims_str:
        for d in dims_str.split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES.get(type_str, 4)


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: float = 0.0  # Σ bytes·ringfactor (per device)
    pod_bytes: float = 0.0  # subset crossing the pod axis (DCN)
    by_kind: Optional[dict] = None
    count: int = 0


def parse_collectives(
    hlo_text: str,
    *,
    n_devices: int,
    pod_group_size: Optional[int] = None,
    scan_trips: int = 1,
) -> CollectiveStats:
    """Sum ring-weighted collective bytes from post-SPMD optimized HLO.

    pod_group_size: group size that indicates a cross-pod collective (e.g.
    2 for the (2,16,16) mesh's pure-pod-axis exchange).  scan_trips scales
    collectives that appear inside while-loop bodies (detected by fusion
    naming ``while``/``body`` context is unreliable; we conservatively scale
    every collective found after the first while-loop header).
    """
    stats = CollectiveStats(by_kind={})
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        # the op name is the token right before '(' on the RHS; output
        # shape(s) sit between '=' and it (tuple outputs list several)
        head, _, _ = rhs.partition("(")
        m = _COLLECTIVE_RE.search(head)
        if not m:
            continue
        kind = m.group(1)
        shapes = _SHAPE_RE.findall(head[: m.start()])
        if not shapes:
            continue
        nbytes = sum(_shape_bytes(t, d) for t, d in shapes)
        # collectives inside lax.scan bodies are tagged with /while/ in their
        # op_name metadata; they execute once per trip
        in_loop_body = "/while/" in line

        # group size
        N = n_devices
        g = _REPLICA_GROUPS_RE.search(line)
        if g and g.group(1).strip():
            first = g.group(1).split("}")[0].strip("{} ")
            N = max(1, len([x for x in first.split(",") if x.strip() != ""]))
        else:
            g2 = _REPLICA_GROUPS_V2_RE.search(line)
            if g2:
                N = max(1, int(g2.group(2)))
        if N <= 1:
            continue

        if kind == "all-reduce":
            factor = 2.0 * (N - 1) / N
        elif kind == "collective-permute":
            factor = 1.0
        else:
            factor = (N - 1) / N

        trips = scan_trips if in_loop_body else 1
        contrib = nbytes * factor * trips
        stats.total_bytes += contrib
        stats.count += 1
        stats.by_kind[kind] = stats.by_kind.get(kind, 0.0) + contrib
        if pod_group_size is not None and N == pod_group_size:
            stats.pod_bytes += contrib
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float  # per device
    hbm_bytes: float  # per device
    coll: CollectiveStats
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float  # 6·N_active·D (whole step, all devices)
    useful_ratio: float  # model_flops / (flops · n_devices)

    def summary(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll.total_bytes,
            "collective_by_kind": self.coll.by_kind,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
        }


def cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def analyze(
    compiled,
    *,
    n_devices: int,
    model_flops: float,
    pod_group_size: Optional[int] = None,
    scan_trips: int = 1,
) -> Roofline:
    ca = cost_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    coll = parse_collectives(
        compiled.as_text(),
        n_devices=n_devices,
        pod_group_size=pod_group_size,
        scan_trips=scan_trips,
    )
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    ici_bytes = coll.total_bytes - coll.pod_bytes
    collective_s = ici_bytes / ICI_BW + coll.pod_bytes / DCN_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops / max(flops * n_devices, 1.0)
    return Roofline(
        flops, hbm, coll, compute_s, memory_s, collective_s, dominant,
        model_flops, useful,
    )


def model_flops_for(cfg, shape: dict, kind: str) -> float:
    """6·N_active·D for training; 2·N_active·D for inference forward."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = shape["global_batch"] * shape["seq_len"]
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape["global_batch"] * shape["seq_len"]
        return 2.0 * n_active * tokens
    # decode: ONE token per sequence
    return 2.0 * n_active * shape["global_batch"]
