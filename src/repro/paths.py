"""One repo-root resolver for every module that writes committed artifacts.

``benchmarks/common.py`` and ``repro.launch.dryrun`` used to each carry
their own ``os.path.dirname(...)`` chains relative to ``__file__`` — path
math that silently breaks the moment a file moves one directory level.
All output-directory derivation now goes through this module:

    from repro.paths import experiments_dir
    OUT_DIR = experiments_dir("benchmarks")

The root is located structurally (the directory that holds ``src/repro``
plus the repo manifests), walking up from this file, so the helpers keep
working from an installed-src layout, a test process, or a launcher run
from any CWD.
"""
from __future__ import annotations

import os


def repo_root() -> str:
    """Absolute path of the repository root (the dir holding ``src/``)."""
    here = os.path.dirname(os.path.abspath(__file__))  # .../src/repro
    cand = os.path.dirname(os.path.dirname(here))  # .../
    if os.path.isdir(os.path.join(cand, "src", "repro")):
        return cand
    # fallback: walk upward until a directory with the src/repro layout
    cur = here
    while True:
        parent = os.path.dirname(cur)
        if parent == cur:
            return cand  # filesystem root reached; best effort
        if os.path.isdir(os.path.join(parent, "src", "repro")):
            return parent
        cur = parent


def experiments_dir(*parts: str, create: bool = False) -> str:
    """``<repo>/experiments/<parts...>`` (optionally mkdir -p'd)."""
    path = os.path.join(repo_root(), "experiments", *parts)
    if create:
        os.makedirs(path, exist_ok=True)
    return path
