"""repro.obs — the unified telemetry layer (tracing + metrics + export).

One :class:`Telemetry` value bundles a tracer and a metrics registry and
travels through the stack: ``build_run`` attaches it to the backend's
:class:`~repro.core.channel.CommChannel` (every channel carries
``NULL_TELEMETRY`` until someone enables it), the fed server/scheduler
and the serve-side planner read it off the objects they already hold,
and the exporters in :mod:`repro.obs.export` turn it into a metrics
JSONL + a Perfetto ``trace.json`` at the end of the run.

Disabled telemetry is the shared :data:`NULL_TELEMETRY` singleton — all
no-ops, identity ``fence`` (no added device synchronization), gated
below 1% step-time overhead by ``benchmarks/run_api_overhead.py``.
"""
from __future__ import annotations

import dataclasses

from repro.obs.export import (
    SCHEMA,
    render_table,
    span_table,
    summary_table,
    write_metrics_jsonl,
    write_trace_json,
)
from repro.obs.metrics import (
    METRIC_NAMES,
    MetricsRegistry,
    NULL_METRICS,
    NullMetrics,
    validate_metric_events,
)
from repro.obs.trace import (
    NULL_TRACER,
    NullTracer,
    SPAN_NAMES,
    Tracer,
    validate_span_events,
)


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """Tracer + metrics registry, passed around as one handle."""

    tracer: object = NULL_TRACER
    metrics: object = NULL_METRICS

    @property
    def enabled(self) -> bool:
        return self.tracer.enabled or self.metrics.enabled

    def span(self, name: str, **args):
        return self.tracer.span(name, **args)

    def fence(self, x):
        return self.tracer.fence(x)


NULL_TELEMETRY = Telemetry()


def make_telemetry() -> Telemetry:
    """A fresh enabled bundle (one per run)."""
    return Telemetry(tracer=Tracer(), metrics=MetricsRegistry())


def finish_run(telemetry: Telemetry, trace: str = None,
               metrics_out: str = None, meta: dict = None,
               print_summary: bool = True) -> dict:
    """End-of-run export: write the requested files, print the console
    summary tables.  The one epilogue every launcher shares."""
    out = {}
    if not telemetry.enabled:
        return out
    if print_summary:
        if telemetry.tracer.events:
            print(span_table(telemetry.tracer))
        if telemetry.metrics.samples:
            print(summary_table(telemetry.metrics))
    if trace:
        out["trace"] = write_trace_json(trace, telemetry.tracer, meta=meta)
        print(f"wrote {out['trace']} (load in ui.perfetto.dev)")
    if metrics_out:
        out["metrics"] = write_metrics_jsonl(
            metrics_out, telemetry.metrics, meta=meta
        )
        print(f"wrote {out['metrics']}")
    return out


__all__ = [
    "METRIC_NAMES",
    "MetricsRegistry",
    "NULL_METRICS",
    "NULL_TELEMETRY",
    "NULL_TRACER",
    "NullMetrics",
    "NullTracer",
    "SCHEMA",
    "SPAN_NAMES",
    "Telemetry",
    "Tracer",
    "finish_run",
    "make_telemetry",
    "render_table",
    "span_table",
    "summary_table",
    "validate_metric_events",
    "validate_span_events",
    "write_metrics_jsonl",
    "write_trace_json",
]
