"""Round-structured tracing: nested spans with device-timing fences.

The :class:`Tracer` records *complete spans* — named, nested intervals
with microsecond wall-clock timestamps — plus point-in-time instants.
One communication round produces one ``round`` span whose children are
the stage spans of that backend (the taxonomy lives in ``SPAN_NAMES``
and docs/observability.md).

Device timing is only meaningful if the traced interval actually waits
for the device: jax dispatch returns before the computation finishes, so
every span that closes over device work must call :meth:`Tracer.fence`
on the outputs before exiting (``jax.block_until_ready``).  The fence is
a no-op on the disabled tracer — tracing off means *no* added
synchronization, not just no recorded events.

Zero-overhead-by-default: :data:`NULL_TRACER` is a singleton whose
``span()`` returns one shared no-op context manager and whose ``fence``
is identity.  Instrumented call sites hold a tracer unconditionally
(never ``if tracer:`` branches around jax calls), so the disabled cost
is one attribute lookup and an empty ``with`` per stage per round —
gated below 1% of step time by ``benchmarks/run_api_overhead.py``.

No dependencies beyond the standard library (jax is imported lazily and
only by an *enabled* fence).
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List

# The span taxonomy — every span a repro component emits is named here
# (docs/observability.md documents each; tests/test_docs_consistency.py
# holds the two lists together so names cannot drift).
SPAN_NAMES: Dict[str, str] = {
    "round": "one communication round (parent of all stage spans)",
    "select_quantize": "client-side selection + quantization compute",
    "encode": "host-side wire encoding (SBW1 pack / Golomb streams)",
    "exchange": "the exchange itself (jitted collective or wire transfer)",
    "decode": "server-side unpack of client uploads",
    "apply": "aggregate + apply the round update to the master weights",
    "plan": "serve-side catch-up planning for one lag class",
    "encode_stacked": "serve-side SBD1 stacked catch-up encode",
    "verify": "serve-side bit-exactness verification of applied plans",
}


class _Span:
    """One open span; records a complete-span event on exit."""

    __slots__ = ("_tracer", "name", "args", "id", "parent_id", "depth", "t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self) -> "_Span":
        tr = self._tracer
        self.id = tr._next_id
        tr._next_id += 1
        self.parent_id = tr._stack[-1].id if tr._stack else None
        self.depth = len(tr._stack)
        tr._stack.append(self)
        self.t0 = tr._now_us()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        tr = self._tracer
        t1 = tr._now_us()
        assert tr._stack and tr._stack[-1] is self, "span closed out of order"
        tr._stack.pop()
        tr.events.append({
            "type": "span",
            "name": self.name,
            "id": self.id,
            "parent": self.parent_id,
            "depth": self.depth,
            "ts_us": self.t0,
            "dur_us": t1 - self.t0,
            "args": self.args,
        })
        return False


class _NullSpan:
    """The shared no-op context manager the disabled tracer hands out."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Collects nested spans + instants as JSONL-able event dicts."""

    enabled = True

    def __init__(self) -> None:
        self.events: List[dict] = []
        self._stack: List[_Span] = []
        self._next_id = 0
        self._epoch_ns = time.perf_counter_ns()

    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._epoch_ns) / 1e3

    # -------------------------------------------------------------- recording

    def span(self, name: str, **args: Any) -> _Span:
        """Open a nested span: ``with tracer.span("encode", leaf=path): ...``"""
        return _Span(self, name, args)

    def instant(self, name: str, **args: Any) -> None:
        self.events.append({
            "type": "instant",
            "name": name,
            "ts_us": self._now_us(),
            "args": args,
        })

    def fence(self, x: Any) -> Any:
        """Block until ``x``'s device computation finished, so the
        enclosing span's duration covers the work it names."""
        if x is not None:
            import jax

            jax.block_until_ready(x)
        return x

    # -------------------------------------------------------------- exporting

    def chrome_events(self) -> List[dict]:
        """Chrome/Perfetto ``traceEvents`` (complete-span ``ph: "X"``)."""
        out = []
        for e in self.events:
            if e["type"] == "span":
                out.append({
                    "ph": "X", "name": e["name"], "cat": "repro",
                    "ts": e["ts_us"], "dur": e["dur_us"],
                    "pid": 0, "tid": 0, "args": e["args"],
                })
            elif e["type"] == "instant":
                out.append({
                    "ph": "i", "name": e["name"], "cat": "repro",
                    "ts": e["ts_us"], "pid": 0, "tid": 0, "s": "t",
                    "args": e["args"],
                })
        return out

    def write_chrome(self, path: str) -> str:
        """Write a Perfetto-loadable ``trace.json`` (ui.perfetto.dev /
        chrome://tracing both open it)."""
        with open(path, "w") as f:
            json.dump({
                "traceEvents": self.chrome_events(),
                "displayTimeUnit": "ms",
            }, f)
        return path


class NullTracer:
    """All no-ops; ``fence`` is identity (adds NO synchronization)."""

    enabled = False
    events: tuple = ()

    __slots__ = ()

    def span(self, name: str, **args: Any) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, **args: Any) -> None:
        return None

    def fence(self, x: Any) -> Any:
        return x


NULL_TRACER = NullTracer()


def validate_span_events(events: List[dict]) -> List[str]:
    """Structural checks on recorded span events: every span closed with a
    non-negative duration, parents exist, children nest inside the parent's
    interval, names come from the taxonomy.  Returns error strings."""
    errs: List[str] = []
    spans: Dict[int, dict] = {}
    for i, e in enumerate(events):
        t = e.get("type")
        if t == "span":
            for field in ("name", "id", "depth", "ts_us", "dur_us", "args"):
                if field not in e:
                    errs.append(f"event {i}: span missing {field!r}")
            if e.get("dur_us", -1) < 0:
                errs.append(f"span {e.get('name')}: negative duration")
            if e.get("name") not in SPAN_NAMES:
                errs.append(f"span name {e.get('name')!r} not in SPAN_NAMES")
            if "id" in e:
                spans[e["id"]] = e
        elif t == "instant":
            if "name" not in e or "ts_us" not in e:
                errs.append(f"event {i}: malformed instant")
        else:
            errs.append(f"event {i}: unknown trace event type {t!r}")
    for e in spans.values():
        pid = e.get("parent")
        if pid is None:
            continue
        p = spans.get(pid)
        if p is None:
            errs.append(f"span {e['name']} (id {e['id']}): parent {pid} "
                        "never closed")
            continue
        eps = 1.0  # µs of clock slack
        if e["ts_us"] < p["ts_us"] - eps or (
            e["ts_us"] + e["dur_us"] > p["ts_us"] + p["dur_us"] + eps
        ):
            errs.append(
                f"span {e['name']} (id {e['id']}) escapes its parent "
                f"{p['name']}'s interval"
            )
        if e["depth"] != p["depth"] + 1:
            errs.append(f"span {e['name']}: depth {e['depth']} under parent "
                        f"depth {p['depth']}")
    return errs
