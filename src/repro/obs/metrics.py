"""Compression-aware metrics: counters, gauges, histograms.

A :class:`MetricsRegistry` collects *samples* — ``(kind, name, value,
tags)`` rows — from the channel layer, the trainer loop, the fed
scheduler, and the serve-side planner, and aggregates them on demand.
Names are declared up front in :data:`METRIC_NAMES` (the table in
docs/observability.md is held to this dict by
``tests/test_docs_consistency.py``); recording an undeclared name
raises, so metric names cannot drift silently.

Bit-exactness contract: :meth:`MetricsRegistry.ingest_ledger` copies the
:class:`~repro.core.ledger.RoundRecord` fields verbatim — the per-round
``wire/*`` gauges sum to exactly ``ledger.totals()`` (asserted at ingest
time and again by ``tests/test_obs.py``), so the telemetry file can
stand in for the ledger in offline triage.

Like the tracer, the registry is dependency-free; :data:`NULL_METRICS`
is the no-op twin used when telemetry is disabled.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Tuple

# name -> (kind, description).  docs/observability.md renders this table;
# the docs-consistency test keeps the two in sync.
METRIC_NAMES: Dict[str, Tuple[str, str]] = {
    # ---- wire accounting (one sample per round, straight off the ledger)
    "wire/up_bytes": ("gauge", "framed upstream SBW1 bytes this round"),
    "wire/up_bits_measured": ("gauge", "exact upstream payload bits (pre-padding)"),
    "wire/up_bits_analytic": ("gauge", "Eq. 1 upstream bits (Golomb priced by Eq. 5)"),
    "wire/up_bytes_wasted": (
        "gauge",
        "upstream bytes the server never aggregated this round (aborted "
        "straggler uploads + corrupt buffers rejected at decode)",
    ),
    "wire/down_bytes": ("gauge", "framed downstream bytes this round"),
    "wire/down_bits_measured": ("gauge", "exact downstream payload bits"),
    "wire/down_bits_analytic": ("gauge", "Eq. 1/Eq. 5 downstream bits"),
    "wire/own_client0_bits_measured": (
        "gauge",
        "host-metered Golomb bits of client 0's shard streams (gspmd; "
        "a 1-client sample, not the cohort sum — see docs/wire-format.md)",
    ),
    "wire/client_bits_measured": (
        "gauge",
        "exact packed wire bits of one client's upload, from the "
        "device-side select→pack kernels (gspmd with --device-pack; "
        "one sample per client per round, tag: client)",
    ),
    # ---- per-leaf compression plan (static per resolved policy)
    "leaf/n": ("gauge", "leaf parameter count (tag: leaf)"),
    "leaf/k": ("gauge", "selected coordinates k = max(1, round(p*n)) (tag: leaf)"),
    "leaf/rate": ("gauge", "resolved per-leaf sparsity rate p (tag: leaf)"),
    "leaf/golomb_bits_pos": (
        "gauge", "Eq. 5 expected Golomb bits per position at rate p (tag: leaf)",
    ),
    # ---- training trajectory
    "train/loss": ("gauge", "mean client loss this round"),
    "train/bits_per_client": ("gauge", "analytic upstream bits per client"),
    "train/residual_norm": ("gauge", "global L2 norm of the error-feedback residual"),
    "train/step_ms": ("gauge", "wall-clock round time (tag: phase=compile|steady)"),
    # ---- federated cohort structure
    "fed/cohort_size": ("gauge", "participating clients this round"),
    "fed/lag_class": ("hist", "subscriber lag (rounds behind) at sync time"),
    # ---- serve-side catch-up planning
    "serve/plan_bytes": ("gauge", "chosen catch-up plan bytes (tags: lag, kind)"),
    "serve/verify_ok": ("counter", "bit-exactness verifications passed"),
    # ---- meta
    "obs/rounds": ("counter", "rounds ingested into this registry"),
}


class MetricsRegistry:
    """Append-only sample store with declared names and typed aggregation."""

    enabled = True

    def __init__(self) -> None:
        self.samples: List[dict] = []

    # ------------------------------------------------------------ recording

    def _record(self, kind: str, name: str, value: float, tags: dict) -> None:
        declared = METRIC_NAMES.get(name)
        if declared is None:
            raise KeyError(
                f"metric {name!r} not declared in METRIC_NAMES; add it there "
                "(and to docs/observability.md) first"
            )
        if declared[0] != kind:
            raise TypeError(
                f"metric {name!r} is declared as a {declared[0]}, "
                f"recorded as a {kind}"
            )
        self.samples.append(
            {"kind": kind, "name": name, "value": float(value), "tags": tags}
        )

    def counter(self, name: str, value: float = 1.0, **tags: Any) -> None:
        self._record("counter", name, value, tags)

    def gauge(self, name: str, value: float, **tags: Any) -> None:
        self._record("gauge", name, value, tags)

    def hist(self, name: str, value: float, **tags: Any) -> None:
        self._record("hist", name, value, tags)

    def ingest_ledger(self, ledger) -> None:
        """Copy every :class:`RoundRecord` into per-round ``wire/*`` gauges,
        verbatim — then assert the copies sum back to ``ledger.totals()``
        bit-exactly (the telemetry file must be able to stand in for the
        ledger)."""
        for rec in ledger.records:
            t = {"round": rec.round}
            self.gauge("wire/up_bytes", rec.up_bytes, **t)
            self.gauge("wire/up_bits_measured", rec.up_bits_measured, **t)
            self.gauge("wire/up_bits_analytic", rec.up_bits_analytic, **t)
            self.gauge("wire/up_bytes_wasted", rec.up_bytes_wasted, **t)
            self.gauge("wire/down_bytes", rec.down_bytes, **t)
            self.gauge("wire/down_bits_measured", rec.down_bits_measured, **t)
            self.gauge("wire/down_bits_analytic", rec.down_bits_analytic, **t)
            self.counter("obs/rounds")
        totals = ledger.totals()
        for col in ("up_bytes", "up_bits_measured", "up_bits_analytic",
                    "up_bytes_wasted", "down_bytes", "down_bits_measured",
                    "down_bits_analytic"):
            # plain sequential sum, NOT fsum: bit-exact against the
            # ledger's own totals() means same addends, same order, same
            # float summation
            mine = sum(
                s["value"] for s in self.samples if s["name"] == f"wire/{col}"
            )
            if mine != float(totals[col]):
                raise AssertionError(
                    f"telemetry wire/{col} gauges sum to {mine!r} but the "
                    f"ledger total is {totals[col]!r} (not bit-exact)"
                )

    # ----------------------------------------------------------- aggregation

    def series(self, name: str) -> List[dict]:
        return [s for s in self.samples if s["name"] == name]

    def summary(self) -> Dict[str, dict]:
        """Aggregate by metric name: counters sum; gauges keep first/last/
        count; histograms get count/min/max/mean."""
        out: Dict[str, dict] = {}
        for s in self.samples:
            name, kind, v = s["name"], s["kind"], s["value"]
            agg = out.setdefault(
                name, {"kind": kind, "count": 0, "sum": 0.0,
                       "min": math.inf, "max": -math.inf,
                       "first": v, "last": v},
            )
            agg["count"] += 1
            agg["sum"] += v
            agg["min"] = min(agg["min"], v)
            agg["max"] = max(agg["max"], v)
            agg["last"] = v
        for agg in out.values():
            agg["mean"] = agg["sum"] / agg["count"]
        return out

    def events(self) -> List[dict]:
        """The JSONL body (one event dict per sample)."""
        return [dict(type="metric", **s) for s in self.samples]


class NullMetrics:
    """No-op twin of :class:`MetricsRegistry` for disabled telemetry."""

    enabled = False
    samples: tuple = ()

    __slots__ = ()

    def counter(self, name: str, value: float = 1.0, **tags: Any) -> None:
        return None

    def gauge(self, name: str, value: float, **tags: Any) -> None:
        return None

    def hist(self, name: str, value: float, **tags: Any) -> None:
        return None

    def ingest_ledger(self, ledger) -> None:
        return None

    def series(self, name: str) -> list:
        return []

    def summary(self) -> dict:
        return {}

    def events(self) -> list:
        return []


NULL_METRICS = NullMetrics()


def validate_metric_events(events: List[dict]) -> List[str]:
    """Schema checks on exported metric events; returns error strings."""
    errs: List[str] = []
    for i, e in enumerate(events):
        if e.get("type") != "metric":
            errs.append(f"event {i}: unknown metric event type {e.get('type')!r}")
            continue
        name = e.get("name")
        declared = METRIC_NAMES.get(name)
        if declared is None:
            errs.append(f"event {i}: metric name {name!r} not in METRIC_NAMES")
        elif e.get("kind") != declared[0]:
            errs.append(
                f"event {i}: {name} recorded as {e.get('kind')!r}, "
                f"declared {declared[0]!r}"
            )
        if not isinstance(e.get("value"), (int, float)):
            errs.append(f"event {i}: non-numeric value {e.get('value')!r}")
        if not isinstance(e.get("tags"), dict):
            errs.append(f"event {i}: tags must be a dict")
    return errs
