"""``python -m repro.obs.view`` — pretty-print, validate, and diff
telemetry files (the regression-triage tool).

  # summarize a metrics JSONL or a trace.json
  PYTHONPATH=src python -m repro.obs.view experiments/benchmarks/fed_round.metrics.jsonl

  # validate schema + span nesting (CI runs this on every emitted file)
  PYTHONPATH=src python -m repro.obs.view --check run.metrics.jsonl trace.json

  # diff two metric files (baseline vs fresh)
  PYTHONPATH=src python -m repro.obs.view --diff old.metrics.jsonl new.metrics.jsonl

File kind is sniffed from the content (schema header vs ``traceEvents``),
not the extension.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Dict, List, Tuple

from repro.obs.export import (
    SCHEMA,
    read_metrics_jsonl,
    read_trace_json,
    render_table,
)
from repro.obs.metrics import validate_metric_events
from repro.obs.trace import SPAN_NAMES


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.obs.view",
        description="pretty-print / validate / diff telemetry files",
    )
    ap.add_argument("files", nargs="+", help="metrics JSONL or trace.json files")
    ap.add_argument("--check", action="store_true",
                    help="validate schema + span nesting; exit 1 on errors")
    ap.add_argument("--diff", action="store_true",
                    help="diff two metric files (per-name aggregate deltas)")
    return ap


def sniff(path: str) -> str:
    """'metrics' | 'trace', by content."""
    with open(path) as f:
        first = f.readline()
    try:
        head = json.loads(first)
    except json.JSONDecodeError:
        # trace.json is one JSON document; the first line may be a fragment
        return "trace"
    if isinstance(head, dict) and head.get("kind") == "metrics":
        return "metrics"
    if isinstance(head, dict) and "traceEvents" in head:
        return "trace"
    raise ValueError(f"{path}: neither a {SCHEMA} metrics JSONL nor a trace")


def _check_trace(path: str) -> List[str]:
    """Validate a Chrome trace: spans must nest (each tid's complete
    events form proper intervals) and carry the known span names."""
    events = read_trace_json(path)
    errs = []
    if not events:
        errs.append(f"{path}: empty traceEvents")
    open_stacks: Dict[tuple, list] = {}
    for i, e in enumerate(events):
        ph = e.get("ph")
        if ph not in ("X", "i"):
            errs.append(f"{path}: event {i} has unsupported ph {ph!r}")
            continue
        if "name" not in e or "ts" not in e:
            errs.append(f"{path}: event {i} missing name/ts")
            continue
        if ph == "X":
            if e.get("dur", -1) < 0:
                errs.append(f"{path}: span {e['name']} negative duration")
            if e["name"] not in SPAN_NAMES:
                errs.append(f"{path}: span name {e['name']!r} not in taxonomy")
    # nesting: within one (pid, tid), sorted complete spans must not
    # partially overlap — each pair is either disjoint or contained
    spans = sorted(
        (e for e in events if e.get("ph") == "X"),
        key=lambda e: (e.get("pid", 0), e.get("tid", 0), e["ts"]),
    )
    eps = 1.0
    for a, b in zip(spans, spans[1:]):
        if (a.get("pid"), a.get("tid")) != (b.get("pid"), b.get("tid")):
            continue
        a_end = a["ts"] + a["dur"]
        if b["ts"] < a_end - eps and b["ts"] + b["dur"] > a_end + eps:
            errs.append(
                f"{path}: spans {a['name']!r} and {b['name']!r} partially "
                "overlap (broken nesting)"
            )
    return errs


def _check_metrics(path: str) -> List[str]:
    try:
        _, events = read_metrics_jsonl(path)
    except ValueError as e:
        return [str(e)]
    return [f"{path}: {m}" for m in validate_metric_events(events)]


def check(paths: List[str]) -> int:
    n_errs = 0
    for path in paths:
        kind = sniff(path)
        errs = _check_trace(path) if kind == "trace" else _check_metrics(path)
        status = "OK" if not errs else f"{len(errs)} error(s)"
        print(f"[{kind}] {path}: {status}")
        for e in errs:
            print(f"  {e}")
        n_errs += len(errs)
    return 1 if n_errs else 0


def _aggregate(path: str) -> Dict[str, Tuple[int, float]]:
    """metric name -> (count, sum) for diffing."""
    _, events = read_metrics_jsonl(path)
    out: Dict[str, Tuple[int, float]] = {}
    for e in events:
        c, s = out.get(e["name"], (0, 0.0))
        out[e["name"]] = (c + 1, s + e["value"])
    return out


def diff(a_path: str, b_path: str) -> int:
    a, b = _aggregate(a_path), _aggregate(b_path)
    rows = []
    for name in sorted(set(a) | set(b)):
        ca, sa = a.get(name, (0, math.nan))
        cb, sb = b.get(name, (0, math.nan))
        if math.isnan(sa) or math.isnan(sb):
            delta = "only in " + (b_path if math.isnan(sa) else a_path)
        elif sa == sb:
            delta = "="
        else:
            rel = (sb - sa) / abs(sa) if sa else math.inf
            delta = f"{rel:+.1%}"
        rows.append((name, ca, round(sa, 3), cb, round(sb, 3), delta))
    print(render_table(
        ("metric", "n(a)", "sum(a)", "n(b)", "sum(b)", "delta"),
        rows, title=f"a = {a_path}\nb = {b_path}",
    ))
    return 0


def show(path: str) -> None:
    kind = sniff(path)
    if kind == "metrics":
        header, events = read_metrics_jsonl(path)
        agg: Dict[str, dict] = {}
        for e in events:
            a = agg.setdefault(
                e["name"],
                {"kind": e["kind"], "count": 0, "sum": 0.0,
                 "min": math.inf, "max": -math.inf, "last": e["value"]},
            )
            a["count"] += 1
            a["sum"] += e["value"]
            a["min"] = min(a["min"], e["value"])
            a["max"] = max(a["max"], e["value"])
            a["last"] = e["value"]
        rows = [
            (n, a["kind"], a["count"], round(a["min"], 3), round(a["max"], 3),
             round(a["sum"] if a["kind"] == "counter" else a["last"], 3))
            for n, a in sorted(agg.items())
        ]
        meta = {k: v for k, v in header.items() if k not in ("schema", "kind")}
        print(render_table(
            ("metric", "kind", "n", "min", "max", "total/last"),
            rows, title=f"{path}  {meta if meta else ''}".rstrip(),
        ))
    else:
        events = read_trace_json(path)
        agg2: Dict[str, List[float]] = {}
        for e in events:
            if e.get("ph") == "X":
                agg2.setdefault(e["name"], []).append(e["dur"])
        rows = [
            (n, len(d), round(sum(d) / len(d) / 1e3, 3), round(sum(d) / 1e3, 3))
            for n, d in sorted(agg2.items(), key=lambda kv: -sum(kv[1]))
        ]
        print(render_table(("span", "n", "mean ms", "total ms"),
                           rows, title=path))


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.check:
        return check(args.files)
    if args.diff:
        if len(args.files) != 2:
            print("--diff needs exactly two metric files", file=sys.stderr)
            return 2
        return diff(args.files[0], args.files[1])
    for path in args.files:
        show(path)
    return 0


if __name__ == "__main__":
    sys.exit(main())
