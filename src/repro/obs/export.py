"""Exporters: JSONL event logs, Chrome/Perfetto traces, console tables.

File formats (both validated by ``python -m repro.obs.view --check``):

  * **metrics JSONL** — line 1 is the schema header
    ``{"schema": "repro-obs-v1", "kind": "metrics", ...}``; every
    following line is one metric event
    (``{"type": "metric", "kind", "name", "value", "tags"}``).
  * **trace JSON** — a Chrome Trace Event file (``{"traceEvents":
    [...]}``) loadable in ui.perfetto.dev or chrome://tracing; spans are
    complete events (``"ph": "X"``, µs timestamps).

``render_table`` is the one console-table helper every surface shares
(end-of-run summaries, the ``launch.serve --subscribers`` lag-class
table, ``repro.obs.view``) — plain text, no dependencies.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

SCHEMA = "repro-obs-v1"


def _ensure_dir(path: str) -> None:
    d = os.path.dirname(os.path.abspath(path))
    if d:
        os.makedirs(d, exist_ok=True)


def write_metrics_jsonl(path: str, metrics, meta: Optional[dict] = None) -> str:
    """Write a registry's samples as schema-headed JSONL."""
    _ensure_dir(path)
    header = {"schema": SCHEMA, "kind": "metrics", **(meta or {})}
    with open(path, "w") as f:
        f.write(json.dumps(header) + "\n")
        for e in metrics.events():
            f.write(json.dumps(e) + "\n")
    return path


def read_metrics_jsonl(path: str) -> Tuple[dict, List[dict]]:
    """Read back a metrics JSONL; raises ValueError on a bad header."""
    with open(path) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    if not lines:
        raise ValueError(f"{path}: empty metrics file")
    header = json.loads(lines[0])
    if header.get("schema") != SCHEMA or header.get("kind") != "metrics":
        raise ValueError(
            f"{path}: bad header {header!r} (want schema={SCHEMA!r}, "
            "kind='metrics')"
        )
    return header, [json.loads(ln) for ln in lines[1:]]


def write_trace_json(path: str, tracer, meta: Optional[dict] = None) -> str:
    """Write a tracer's spans as a Perfetto-loadable trace.json."""
    _ensure_dir(path)
    with open(path, "w") as f:
        json.dump({
            "traceEvents": tracer.chrome_events(),
            "displayTimeUnit": "ms",
            "otherData": {"schema": SCHEMA, **(meta or {})},
        }, f)
    return path


def read_trace_json(path: str) -> List[dict]:
    """Read back a trace.json's traceEvents list."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError(f"{path}: not a Chrome trace (no traceEvents)")
    return doc["traceEvents"]


def render_table(headers: Sequence[str], rows: Sequence[Sequence],
                 title: Optional[str] = None) -> str:
    """Plain aligned console table (numbers right-aligned)."""
    cells = [[str(h) for h in headers]]
    numeric = [True] * len(headers)
    for row in rows:
        rendered = []
        for j, v in enumerate(row):
            if isinstance(v, float):
                rendered.append(f"{v:.3f}".rstrip("0").rstrip(".") or "0")
            else:
                rendered.append(str(v))
                if not isinstance(v, int):
                    numeric[j] = False
        cells.append(rendered)
    widths = [max(len(r[j]) for r in cells) for j in range(len(headers))]
    lines = []
    if title:
        lines.append(title)
    for i, r in enumerate(cells):
        line = "  ".join(
            c.rjust(widths[j]) if numeric[j] and i > 0 else c.ljust(widths[j])
            for j, c in enumerate(r)
        )
        lines.append(line.rstrip())
        if i == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)


def summary_table(metrics, top: int = 0) -> str:
    """The end-of-run console summary: one row per metric name."""
    summ = metrics.summary()
    rows = []
    for name in sorted(summ):
        a = summ[name]
        if a["kind"] == "counter":
            shown = a["sum"]
        elif a["kind"] == "hist":
            shown = a["mean"]
        else:
            shown = a["last"]
        rows.append((name, a["kind"], a["count"],
                     round(a["min"], 3), round(a["max"], 3), round(shown, 3)))
    if top:
        rows = rows[:top]
    return render_table(
        ("metric", "kind", "n", "min", "max", "total/last"),
        rows, title="telemetry summary",
    )


def span_table(tracer, max_rows: int = 0) -> str:
    """Aggregate span durations by name for the console summary."""
    agg: Dict[str, List[float]] = {}
    for e in tracer.events:
        if e.get("type") == "span":
            agg.setdefault(e["name"], []).append(e["dur_us"])
    rows = []
    for name in sorted(agg, key=lambda n: -sum(agg[n])):
        durs = agg[name]
        rows.append((name, len(durs),
                     round(sum(durs) / len(durs) / 1e3, 3),
                     round(sum(durs) / 1e3, 3)))
    if max_rows:
        rows = rows[:max_rows]
    return render_table(("span", "n", "mean ms", "total ms"),
                        rows, title="span summary")
