"""Native JAX optimizers for the DSGD trainer.

These are the CLIENT-side optimizers of paper Alg. 1 (``SGD_n(W_i, D_i)``):
each client runs n local iterations with its own optimizer state.  The
server-side update is always ``W ← W + mean_i(ΔW*_i)`` (Alg. 1 l.19) and
needs no state.

Momentum masking (paper supplement A / DGC): after a communication round the
trainer calls :meth:`Optimizer.mask` with a 0/1 pytree marking coordinates
that were just transmitted; momentum there is zeroed so stale momentum does
not carry the optimization in an outdated direction.

``state_dtype`` lets big-model configs keep momentum in bf16 (recorded in
DESIGN.md §8 — at 400B params per-client f32 momentum does not fit HBM).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[PyTree], PyTree]
    # (state, grads, params, lr, step) -> (new_params, new_state)
    apply: Callable[..., tuple[PyTree, PyTree]]
    # (state, transmitted_mask) -> state with momentum zeroed where mask==1
    mask: Callable[[PyTree, PyTree], PyTree]


def sgd() -> Optimizer:
    def init(params):
        return ()

    def apply(state, grads, params, lr, step):
        new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return new, state

    return Optimizer("sgd", init, apply, lambda s, m: s)


def momentum(beta: float = 0.9, state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, state_dtype), params)

    def apply(state, grads, params, lr, step):
        new_m = jax.tree.map(
            lambda m, g: (beta * m.astype(jnp.float32) + g.astype(jnp.float32)).astype(state_dtype),
            state, grads,
        )
        new_p = jax.tree.map(lambda p, m: p - (lr * m.astype(jnp.float32)).astype(p.dtype), params, new_m)
        return new_p, new_m

    def mask(state, transmitted):
        # DGC momentum masking: zero momentum at transmitted coordinates
        return jax.tree.map(
            lambda m, t: m * (1.0 - t.astype(jnp.float32)).astype(m.dtype), state, transmitted
        )

    return Optimizer("momentum", init, apply, mask)


class AdamState(NamedTuple):
    m: PyTree
    v: PyTree


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return AdamState(jax.tree.map(z, params), jax.tree.map(z, params))

    def apply(state, grads, params, lr, step):
        t = step.astype(jnp.float32) + 1.0
        new_m = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(state_dtype),
            state.m, grads,
        )
        new_v = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(state_dtype),
            state.v, grads,
        )
        def upd(p, m, v):
            mh = m.astype(jnp.float32) / (1 - b1**t)
            vh = v.astype(jnp.float32) / (1 - b2**t)
            return p - (lr * mh / (jnp.sqrt(vh) + eps)).astype(p.dtype)

        return jax.tree.map(upd, params, new_m, new_v), AdamState(new_m, new_v)

    def mask(state, transmitted):
        zero = lambda m, t: m * (1.0 - t.astype(jnp.float32)).astype(m.dtype)
        return AdamState(jax.tree.map(zero, state.m, transmitted), state.v)

    return Optimizer("adam", init, apply, mask)


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adam": adam}[name](**kw)
