from repro.optim.optimizers import Optimizer, adam, get_optimizer, momentum, sgd

__all__ = ["Optimizer", "sgd", "momentum", "adam", "get_optimizer"]
