from repro.serve.broadcast import (
    CatchupPlan,
    CatchupPlanner,
    SubscriberPool,
    simulate_fanout,
)
from repro.serve.deltalog import (
    CatchupMessage,
    DeltaLog,
    apply_catchup,
    apply_catchup_flat,
)
from repro.serve.engine import ServeEngine

__all__ = [
    "CatchupMessage",
    "CatchupPlan",
    "CatchupPlanner",
    "DeltaLog",
    "ServeEngine",
    "SubscriberPool",
    "apply_catchup",
    "apply_catchup_flat",
    "simulate_fanout",
]
