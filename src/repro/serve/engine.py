"""Batched serving engine: prefill → iterative one-token decode.

``serve_step`` (one new token against a ``seq_len``-deep cache) is the unit
the decode_32k / long_500k dry-run shapes lower; ``generate`` drives it for
the runnable examples.  Sampling is greedy or temperature-categorical.

The engine is stateless — caches are explicit pytrees — so the same step
function serves any number of concurrent batched sessions.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model

PyTree = Any


@dataclasses.dataclass(eq=False)
class ServeEngine:
    model: Model

    def prefill(self, params: PyTree, batch: dict) -> tuple[jax.Array, PyTree]:
        """Run the full-sequence forward; returns (next_token_logits, caches)."""
        hidden, caches = self.model.prefill(params, batch)
        from repro.models import transformer

        emb = transformer.output_embedding(params, self.model.cfg)
        logits = hidden[:, -1:, :].astype(jnp.float32) @ emb.T.astype(jnp.float32)
        return logits, caches

    @partial(jax.jit, static_argnames=("self",))
    def serve_step(
        self, params: PyTree, tokens: jax.Array, caches: PyTree, pos: jax.Array
    ) -> tuple[jax.Array, PyTree]:
        """ONE new token for the whole batch.  tokens: (B, 1) int32."""
        return self.model.decode_step(params, tokens, caches, pos)

    def generate(
        self,
        params: PyTree,
        batch: dict,
        *,
        max_new_tokens: int,
        rng: Optional[jax.Array] = None,
        temperature: float = 0.0,
    ) -> jax.Array:
        """Prefill then decode ``max_new_tokens``; returns (B, max_new_tokens)."""
        logits, caches = self.prefill(params, batch)
        prompt_len = batch["tokens"].shape[1]
        B = batch["tokens"].shape[0]

        def pick(lg, r):
            if temperature <= 0.0:
                return jnp.argmax(lg[:, -1, :], axis=-1)
            return jax.random.categorical(r, lg[:, -1, :] / temperature)

        rng = rng if rng is not None else jax.random.PRNGKey(0)
        toks = []
        # split BEFORE the first sample: consuming the caller's key raw
        # would correlate the first decode step with any other use of it
        rng, r = jax.random.split(rng)
        tok = pick(logits, r)
        toks.append(tok)
        for i in range(1, max_new_tokens):
            rng, r = jax.random.split(rng)
            logits, caches = self.serve_step(
                params, tok[:, None].astype(jnp.int32), caches, jnp.asarray(prompt_len + i - 1)
            )
            tok = pick(logits, r)
            toks.append(tok)
        return jnp.stack(toks, axis=1)
