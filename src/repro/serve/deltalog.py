"""Round-indexed log of broadcast deltas + stacked catch-up coding (§13).

The downstream half of the paper's economics: SBC compresses the *upstream*
by orders of magnitude while the server re-broadcasts near-full state —
``experiments/benchmarks/fed_round.json`` measures ~150× more down- than
up-bytes per round.  A :class:`DeltaLog` fixes the fan-out half of that
cost: the server encodes its SBW1 downstream buffer ONCE per round,
appends it here, and every receiver — cohort member or serving subscriber
— shares those bytes instead of triggering a per-client re-compression.

For a receiver lagging k rounds the log offers three catch-up forms:

  replay    the k stored SBW1 blobs, applied in order (what a live
            receiver would have downloaded anyway);
  stacked   ONE ``SBD1`` message: per leaf, the union of the positions
            transmitted in rounds (a, b] Golomb-coded at the union's own
            density, plus the FINAL replica values at those positions;
  full      the whole replica Ŵ_b as dense f32 — the only option once
            the log has evicted past the horizon.

Bit-exactness of ``stacked`` is by construction, not by float luck: the
replica Ŵ_r is deterministic on every receiver (it advances ONLY by
decoded wire content, the :class:`~repro.fed.server.ParameterServer`
invariant), so the stacked message carries Ŵ_b's bytes at the union
positions and applies them with scatter-SET.  Positions untouched in
(a, b] are bit-identical between Ŵ_a and Ŵ_b up to one ±0.0 subtlety:
sequential application adds a full dense array per round, so a stored
−0.0 flips to +0.0 (−0.0 + 0.0 = +0.0) — the apply path reproduces that
with a single +0.0 add before scattering.  Every touched position is in
the union because the union is computed from the *transmitted* index
sets — not from ``nonzero(dense)``, which would miss a transmitted +0.0
landing on a stored −0.0.  Summing the k sparse values per position
would NOT be exact: f32 addition is non-associative, so
``(Ŵ+v₁)+v₂ ≠ Ŵ+(v₁+v₂)`` in general; shipping the final bytes
sidesteps the reassociation.

``SBD1`` catch-up framing (little-endian, mirrors wire.py's SBW1):

    header:  b"SBD1"  u8 kind (0=stacked, 1=full)
             i32 from_round  i32 to_round  u32 n_leaves
    leaf i:  u8 mode
      0 empty   → (nothing: no position transmitted in the window)
      1 sparse  → u32 k, u32 bit_count, Golomb bitstream at p=k/n,
                  k f32 final replica values (ascending position order)
      2 dense   → n f32 final replica values (n from the shared contract)

Like SBW1, the framing (magic, kind, rounds, k/bit-count fields) is
transport overhead; metered bits are the Golomb stream + 32/value.
"""
from __future__ import annotations

import collections
import struct
from typing import Any, List, NamedTuple, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import golomb
from repro.core.wire import Wire, leaf_dense

PyTree = Any

CATCHUP_MAGIC = b"SBD1"
KIND_STACKED = 0
KIND_FULL = 1
_KINDS = {KIND_STACKED: "stacked", KIND_FULL: "full"}
MODE_EMPTY, MODE_SPARSE, MODE_DENSE = 0, 1, 2
_HEADER = struct.Struct("<Bii")  # kind, from_round, to_round
_HEADER_BYTES = 4 + _HEADER.size + 4  # magic + header + u32 n_leaves


def _need(blob: bytes, nbytes: int, what: str) -> None:
    if len(blob) < nbytes:
        raise ValueError(
            f"truncated SBD1 catch-up message: {what} needs {nbytes} bytes, "
            f"have {len(blob)}"
        )


class LogEntry(NamedTuple):
    """One appended round: the broadcast bytes plus the decoded view of
    them every receiver shares."""

    round: int
    blob: bytes  # the round's framed SBW1 broadcast buffer
    touched: Tuple[Optional[np.ndarray], ...]  # per-leaf transmitted
    # positions (sorted int64); None = every position (dense-codec leaf)
    dense: Tuple[np.ndarray, ...]  # per-leaf decoded flat f32 ΔW*
    bits_measured: float
    bits_analytic: float

    @property
    def nbytes(self) -> int:
        return len(self.blob)


class CatchupMessage(NamedTuple):
    """One encoded SBD1 catch-up buffer plus its byte/bit accounting."""

    kind: str  # "stacked" | "full"
    from_round: int
    to_round: int
    blob: bytes
    bits_measured: float
    bits_analytic: float

    @property
    def nbytes(self) -> int:
        return len(self.blob)


class DeltaLog:
    """Horizon-bounded, round-indexed log of the server's broadcasts.

    ``append`` decodes the round's SBW1 blob exactly as a receiver would
    and advances the running replica Ŵ by the decoded content (numpy f32
    IEEE adds — the same trajectory every receiver computes), so
    ``encode_stacked``'s final values are the bytes any up-to-date replica
    holds.  Entries older than ``horizon`` rounds are evicted; the replica
    itself always remains available for a full resync.
    """

    def __init__(self, params: PyTree, horizon: int = 16) -> None:
        if horizon < 1:
            raise ValueError(f"delta horizon must be >= 1, got {horizon}")
        self.horizon = int(horizon)
        leaves, self.treedef = jax.tree.flatten(params)
        self._shapes: Tuple[Tuple[int, ...], ...] = tuple(
            tuple(np.shape(x)) for x in leaves
        )
        self._replica: List[np.ndarray] = [
            np.asarray(x, np.float32).reshape(-1).copy() for x in leaves
        ]
        self._entries: collections.deque = collections.deque()
        self._head = -1

    # -------------------------------------------------------------- queries

    @property
    def head(self) -> int:
        """Last appended round (−1 before the first broadcast)."""
        return self._head

    @property
    def oldest(self) -> int:
        """Oldest round still held (head+1 when the log is empty)."""
        return self._entries[0].round if self._entries else self._head + 1

    @property
    def n_params(self) -> int:
        return sum(r.size for r in self._replica)

    def replica(self) -> PyTree:
        """The current Ŵ as an f32 pytree (a copy; safe to mutate)."""
        return jax.tree.unflatten(
            self.treedef,
            [r.reshape(s).copy() for r, s in zip(self._replica, self._shapes)],
        )

    def replica_flat(self) -> List[np.ndarray]:
        """Flat f32 leaves of the current Ŵ (copies)."""
        return [r.copy() for r in self._replica]

    def can_stack(self, from_round: int) -> bool:
        """True when every round in (from_round, head] is still held."""
        return self.oldest - 1 <= from_round <= self._head

    def entries_since(self, from_round: int) -> Tuple[LogEntry, ...]:
        """The contiguous entries covering (from_round, head]."""
        if not self.can_stack(from_round):
            raise ValueError(
                f"rounds ({from_round}, {self._head}] not fully held; "
                f"log covers [{self.oldest}, {self._head}]"
            )
        return tuple(e for e in self._entries if e.round > from_round)

    # ------------------------------------------------------------- appending

    def _decode_entry(
        self,
        round_idx: int,
        blob: bytes,
        wire: Wire,
        bits_analytic: Optional[float] = None,
    ) -> LogEntry:
        """Decode one broadcast blob through ``wire`` (the exact receiver
        path) into a :class:`LogEntry` — no replica/log mutation, so
        :meth:`restore` can rebuild evicted-window entries from bytes."""
        comps = wire.unpack_compressed(blob)
        leaves = wire.treedef.flatten_up_to(comps)
        if len(leaves) != len(self._replica):
            raise ValueError(
                f"wire has {len(leaves)} leaves, log replica has "
                f"{len(self._replica)}"
            )
        touched, denses = [], []
        bits = 0.0
        for comp, spec, shape in zip(leaves, wire.specs, self._shapes):
            if tuple(spec.shape) != shape:
                raise ValueError(
                    f"leaf {spec.path!r} shape {spec.shape} != replica "
                    f"shape {shape}"
                )
            denses.append(
                np.asarray(leaf_dense(comp, spec), np.float32).reshape(-1)
            )
            bits += float(comp.nbits)
            if spec.selector == "dense":
                touched.append(None)  # every position transmitted
            elif spec.selector == "skip":
                touched.append(np.zeros((0,), np.int64))
            else:
                touched.append(np.asarray(comp.idx, np.int64))
        return LogEntry(
            round=round_idx,
            blob=bytes(blob),
            touched=tuple(touched),
            dense=tuple(denses),
            bits_measured=bits,
            bits_analytic=float(bits if bits_analytic is None else bits_analytic),
        )

    def append(
        self,
        round_idx: int,
        blob: bytes,
        wire: Wire,
        bits_analytic: Optional[float] = None,
    ) -> LogEntry:
        """Log one round's broadcast: decode ``blob`` through ``wire`` (the
        exact receiver path), record the transmitted position sets, and
        advance the replica by the decoded dense content."""
        if round_idx != self._head + 1:
            raise ValueError(
                f"DeltaLog rounds must be contiguous: got {round_idx}, "
                f"expected {self._head + 1}"
            )
        entry = self._decode_entry(round_idx, blob, wire, bits_analytic)
        for rep, d in zip(self._replica, entry.dense):
            rep += d  # f32 IEEE add — identical on every receiver
        self._entries.append(entry)
        self._head = round_idx
        while self._entries and self._entries[0].round <= self._head - self.horizon:
            self._entries.popleft()
        return entry

    # --------------------------------------------------------- checkpointing

    def state_dict(self) -> dict:
        """The log's full restorable state: head, flat replica leaves, and
        the held window as raw (round, blob, bits_analytic) rows —
        entries re-decode on :meth:`restore`, so only bytes persist."""
        return {
            "head": self._head,
            "replica": [r.copy() for r in self._replica],
            "entries": [
                (e.round, e.blob, e.bits_analytic) for e in self._entries
            ],
        }

    def restore(self, state: dict, wire_for_round) -> None:
        """Restore :meth:`state_dict` output.  ``wire_for_round(round)``
        yields the decode contract for each held blob (the server's
        ``down_wire``); the replica is set directly — entry decode must
        NOT advance it a second time."""
        self._head = int(state["head"])
        if len(state["replica"]) != len(self._replica):
            raise ValueError(
                f"checkpoint has {len(state['replica'])} replica leaves, "
                f"log has {len(self._replica)}"
            )
        for rep, saved in zip(self._replica, state["replica"]):
            if rep.size != np.size(saved):
                raise ValueError(
                    f"replica leaf size {np.size(saved)} != {rep.size}"
                )
            rep[:] = np.asarray(saved, np.float32).reshape(-1)
        self._entries.clear()
        for round_idx, blob, bits_analytic in state["entries"]:
            self._entries.append(
                self._decode_entry(
                    int(round_idx), bytes(blob), wire_for_round(int(round_idx)),
                    bits_analytic,
                )
            )

    # ------------------------------------------------------------- encoding

    def encode_stacked(self, from_round: int) -> CatchupMessage:
        """ONE message that moves a replica from round ``from_round`` to
        head: per leaf the union of transmitted positions over the window,
        Golomb-coded at the union's own density k/n, plus the final
        replica values there (scatter-SET on apply — see module doc)."""
        if from_round >= self._head:
            raise ValueError(
                f"nothing to stack: from_round {from_round} >= head {self._head}"
            )
        ents = self.entries_since(from_round)
        parts = [
            CATCHUP_MAGIC,
            _HEADER.pack(KIND_STACKED, from_round, self._head),
            struct.pack("<I", len(self._replica)),
        ]
        bits_m = bits_a = 0.0
        for i, rep in enumerate(self._replica):
            n = rep.size
            if any(e.touched[i] is None for e in ents):
                union = None  # a dense round touched everything
            else:
                idxs = [e.touched[i] for e in ents if e.touched[i].size]
                union = (
                    np.unique(np.concatenate(idxs))
                    if idxs else np.zeros((0,), np.int64)
                )
                if union.size >= n:
                    union = None
            if union is None:
                parts.append(struct.pack("<B", MODE_DENSE))
                parts.append(rep.astype("<f4").tobytes())
                bits_m += 32.0 * n
                bits_a += 32.0 * n
            elif union.size == 0:
                parts.append(struct.pack("<B", MODE_EMPTY))
            else:
                k = int(union.size)
                p_eff = k / n
                packed, pos_bits = golomb.encode_positions_packed(union, p_eff)
                parts.append(struct.pack("<BII", MODE_SPARSE, k, pos_bits))
                parts.append(packed)
                parts.append(rep[union].astype("<f4").tobytes())
                bits_m += pos_bits + 32.0 * k
                bits_a += k * (golomb.expected_position_bits(p_eff) + 32.0)
        return CatchupMessage(
            kind="stacked", from_round=from_round, to_round=self._head,
            blob=b"".join(parts), bits_measured=bits_m, bits_analytic=bits_a,
        )

    def encode_full(self) -> CatchupMessage:
        """Full-state resync: the whole replica as dense f32 (applies from
        ANY round — the fallback once the horizon has evicted)."""
        parts = [
            CATCHUP_MAGIC,
            _HEADER.pack(KIND_FULL, -1, self._head),
            struct.pack("<I", len(self._replica)),
        ]
        bits = 0.0
        for rep in self._replica:
            parts.append(struct.pack("<B", MODE_DENSE))
            parts.append(rep.astype("<f4").tobytes())
            bits += 32.0 * rep.size
        return CatchupMessage(
            kind="full", from_round=-1, to_round=self._head,
            blob=b"".join(parts), bits_measured=bits, bits_analytic=bits,
        )

    def full_nbytes(self) -> int:
        """Exact byte size of :meth:`encode_full` without materializing it
        (the planner prices the resync candidate every round)."""
        return _HEADER_BYTES + sum(1 + 4 * r.size for r in self._replica)


# ---------------------------------------------------------------- receiving


def apply_catchup_flat(
    flats: Sequence[np.ndarray], blob: bytes
) -> Tuple[List[np.ndarray], int, int]:
    """Decode one SBD1 message against flat f32 replica leaves.

    Returns ``(new_flats, from_round, to_round)``.  Malformed buffers
    raise ``ValueError`` (same hardening contract as ``Wire.unpack``).
    """
    _need(blob, _HEADER_BYTES, "header")
    if blob[:4] != CATCHUP_MAGIC:
        raise ValueError("bad catch-up magic; not an SBD1 buffer")
    kind, from_round, to_round = _HEADER.unpack_from(blob, 4)
    if kind not in _KINDS:
        raise ValueError(f"unknown SBD1 kind {kind}")
    (n_leaves,) = struct.unpack_from("<I", blob, 4 + _HEADER.size)
    if n_leaves != len(flats):
        raise ValueError(
            f"buffer has {n_leaves} leaves, replica has {len(flats)}"
        )
    out = [np.asarray(f, np.float32).reshape(-1).copy() for f in flats]
    if kind == KIND_STACKED:
        # sequential application adds a FULL dense array every round, so a
        # stored −0.0 at an untransmitted position flips to +0.0 on the
        # first add (−0.0 + 0.0 = +0.0) and stays; one +0.0 add reproduces
        # k ≥ 1 such adds bit-exactly, keeping the scatter-SET below
        # bit-identical to replay even at untouched positions
        out = [f + np.float32(0.0) for f in out]
    off = _HEADER_BYTES
    for i, flat in enumerate(out):
        n = flat.size
        _need(blob, off + 1, f"leaf {i} mode")
        mode = blob[off]
        off += 1
        if mode == MODE_EMPTY:
            continue
        if mode == MODE_DENSE:
            _need(blob, off + 4 * n, f"leaf {i}: {n} f32 values")
            out[i] = np.frombuffer(blob, "<f4", count=n, offset=off).copy()
            off += 4 * n
        elif mode == MODE_SPARSE:
            _need(blob, off + 8, f"leaf {i} sparse header")
            k, bit_count = struct.unpack_from("<II", blob, off)
            off += 8
            if not 0 < k < n:
                raise ValueError(
                    f"corrupt SBD1 leaf {i}: k={k} outside (0, {n})"
                )
            nb = (bit_count + 7) // 8
            _need(blob, off + nb, f"leaf {i} Golomb stream of {bit_count} bits")
            bits = np.unpackbits(
                np.frombuffer(blob[off:off + nb], np.uint8)
            )[:bit_count]
            idx = golomb.decode_positions(bits, k / n)
            if idx.size != k:
                raise ValueError(
                    f"corrupt SBD1 leaf {i}: decoded {idx.size} positions, "
                    f"header says {k}"
                )
            if int(idx.max()) >= n:
                raise ValueError(
                    f"corrupt SBD1 leaf {i}: position {int(idx.max())} "
                    f"outside [0, {n})"
                )
            off += nb
            _need(blob, off + 4 * k, f"leaf {i}: {k} f32 values")
            vals = np.frombuffer(blob, "<f4", count=k, offset=off)
            off += 4 * k
            flat[idx] = vals  # scatter-SET: the final replica bytes
        else:
            raise ValueError(f"unknown SBD1 leaf mode {mode}")
    return out, from_round, to_round


def apply_catchup(replica: PyTree, blob: bytes) -> Tuple[PyTree, int, int]:
    """Pytree form of :func:`apply_catchup_flat`: move an f32 replica at
    the message's ``from_round`` to its ``to_round`` state, bit-identical
    to applying the window's broadcasts sequentially."""
    leaves, treedef = jax.tree.flatten(replica)
    flats, from_round, to_round = apply_catchup_flat(leaves, blob)
    shaped = [f.reshape(np.shape(x)) for f, x in zip(flats, leaves)]
    return jax.tree.unflatten(treedef, shaped), from_round, to_round
