"""Delta-broadcast fan-out: one encode per round, shared by 10k+ subscribers.

Three layers over :class:`~repro.serve.deltalog.DeltaLog` (DESIGN.md §13):

  :class:`CatchupPlanner`   prices the three catch-up forms for a receiver
                            lagging k rounds — replay (the k stored SBW1
                            blobs), stacked (one SBD1 union message), full
                            (dense resync) — and picks the fewest bytes;
                            lag past the horizon forces full.
  :class:`SubscriberPool`   10k–100k simulated subscribers as bulk (S,)
                            arrays (the tiled per-member-state pattern of
                            ``fed/clients.py`` at fan-out scale).  Each
                            round costs one plan/encode per DISTINCT lag
                            class — every subscriber in a class shares the
                            same bytes — and the per-subscriber state
                            advance is a single jitted gather/scatter.
  :func:`simulate_fanout`   drives the production broadcast path
                            (:class:`~repro.fed.server.ParameterServer`
                            with a log attached) with synthetic updates
                            and fans it out; ``launch/serve.py`` and
                            ``benchmarks/broadcast_fanout.py`` both call
                            this.

Every chosen plan is metered through the core
:class:`~repro.core.ledger.BandwidthLedger` (measured AND analytic bits),
so ``reconcile()`` holds on the broadcast path exactly as it does for the
upstream wire.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ledger import BandwidthLedger, RoundRecord
from repro.obs import NULL_TELEMETRY
from repro.serve.deltalog import DeltaLog, apply_catchup_flat

PyTree = Any


class CatchupPlan(NamedTuple):
    """One receiver class's chosen catch-up: what crosses and what it costs."""

    kind: str  # "none" | "replay" | "stacked" | "full"
    from_round: int
    to_round: int
    nbytes: int
    bits_measured: float
    bits_analytic: float
    blobs: Tuple[bytes, ...]  # k SBW1 blobs (replay) or one SBD1 message
    candidates: Tuple[Tuple[str, int], ...]  # every (kind, nbytes) priced


@dataclasses.dataclass(eq=False)
class CatchupPlanner:
    """Min-byte catch-up choice against one :class:`DeltaLog`.

    The full-resync candidate is priced arithmetically
    (:meth:`DeltaLog.full_nbytes`) and only materialized when chosen;
    replay is priced off the stored blob lengths; stacked must be encoded
    to be priced (the union's density is data-dependent), and the encoding
    IS the payload when it wins.
    """

    log: DeltaLog
    telemetry: Any = NULL_TELEMETRY

    def plan(self, from_round: int) -> CatchupPlan:
        with self.telemetry.span("plan", from_round=from_round):
            return self._plan(from_round)

    def _plan(self, from_round: int) -> CatchupPlan:
        head = self.log.head
        if from_round >= head:
            return CatchupPlan("none", from_round, head, 0, 0.0, 0.0, (), ())
        costs: Dict[str, int] = {"full": self.log.full_nbytes()}
        stacked = None
        if self.log.can_stack(from_round):
            ents = self.log.entries_since(from_round)
            costs["replay"] = sum(e.nbytes for e in ents)
            with self.telemetry.span("encode_stacked", from_round=from_round):
                stacked = self.log.encode_stacked(from_round)
            costs["stacked"] = stacked.nbytes
        order = ("stacked", "replay", "full")  # tie-break: fewest messages
        kind = min(costs, key=lambda c: (costs[c], order.index(c)))
        candidates = tuple(sorted(costs.items()))
        if kind == "replay":
            return CatchupPlan(
                "replay", from_round, head, costs["replay"],
                sum(e.bits_measured for e in ents),
                sum(e.bits_analytic for e in ents),
                tuple(e.blob for e in ents), candidates,
            )
        if kind == "stacked":
            return CatchupPlan(
                "stacked", from_round, head, stacked.nbytes,
                stacked.bits_measured, stacked.bits_analytic,
                (stacked.blob,), candidates,
            )
        full = self.log.encode_full()
        return CatchupPlan(
            "full", from_round, head, full.nbytes,
            full.bits_measured, full.bits_analytic,
            (full.blob,), candidates,
        )


@dataclasses.dataclass(eq=False)
class SubscriberPool:
    """Per-subscriber lag state at fan-out scale.

    Subscriber s syncs at rounds where ``round % period[s] == phase[s]``
    (period from ``periods`` round-robin, phase ``s % period``) — a
    deterministic wake pattern that produces a stable spectrum of lag
    classes.  State is three (S,) arrays; the per-round advance is one
    jitted call, so 100k subscribers are a ~400 KB working set.

    ``verify_classes`` > 0 maintains a real replica for the first V
    (period, phase) classes and applies each chosen plan to it, asserting
    bit-identity with the log's replica — the bit-exactness contract
    checked live at fan-out scale (per class, not per subscriber).
    """

    log: DeltaLog
    n_subscribers: int
    periods: Tuple[int, ...] = (1,)
    verify_classes: int = 0
    telemetry: Any = NULL_TELEMETRY

    def __post_init__(self) -> None:
        if self.n_subscribers < 1:
            raise ValueError("need at least one subscriber")
        if not self.periods or any(int(p) < 1 for p in self.periods):
            raise ValueError(f"periods must be >= 1, got {self.periods}")
        self.periods = tuple(int(p) for p in self.periods)
        self.planner = CatchupPlanner(self.log, telemetry=self.telemetry)
        self.ledger = BandwidthLedger()
        s = np.arange(self.n_subscribers)
        period = np.asarray(
            [self.periods[i % len(self.periods)] for i in range(self.n_subscribers)],
            np.int32,
        )
        self._period = jnp.asarray(period)
        self._phase = jnp.asarray((s % period).astype(np.int32))
        start = int(self.log.head)
        self._synced = jnp.full((self.n_subscribers,), start, jnp.int32)
        # exact byte totals live in the ledger (host ints); the per-
        # subscriber counter is for distribution stats at int32 range
        self._bytes = jnp.zeros((self.n_subscribers,), jnp.int32)
        self._syncs = jnp.zeros((self.n_subscribers,), jnp.int32)
        self.down_bytes_full_equiv = 0  # if every sync were a full resync
        self._verify: Dict[Tuple[int, int], dict] = {}
        classes = sorted({(int(p), int(ph)) for p, ph in
                          zip(period.tolist(), (s % period).tolist())})
        for p, ph in classes[: max(0, int(self.verify_classes))]:
            self._verify[(p, ph)] = {
                "flats": self.log.replica_flat(),
                "synced": start,
            }
        self._verify_failures = 0
        self.verified_syncs = 0

    # ------------------------------------------------------------- advance

    @partial(jax.jit, static_argnames=("self",))
    def _advance(self, synced, bytes_down, syncs, round_idx, byte_table):
        """Tiled bulk state update: who wakes, what their class's plan
        costs (lag-indexed table built host-side), advance to head."""
        awake = (round_idx % self._period) == self._phase
        lag = jnp.clip(round_idx - synced, 0, byte_table.shape[0] - 1)
        add = jnp.where(awake, byte_table[lag], 0)
        return (
            jnp.where(awake, round_idx, synced),
            bytes_down + add,
            syncs + awake.astype(jnp.int32),
        )

    def sync_round(self, round_idx: int) -> dict:
        """Fan this round out: one plan per distinct lag class, bytes
        shared across the class, everything metered into the ledger.

        Call AFTER the round's broadcast was appended (head == round_idx).
        """
        if round_idx != self.log.head:
            raise ValueError(
                f"sync_round({round_idx}) but log head is {self.log.head}; "
                "append the round's broadcast first"
            )
        synced = np.asarray(self._synced)
        period = np.asarray(self._period)
        phase = np.asarray(self._phase)
        awake = (round_idx % period) == phase
        n_awake = int(awake.sum())
        uniq, counts = np.unique(synced[awake], return_counts=True)

        plans: Dict[int, CatchupPlan] = {}
        down_bytes = 0
        bits_m = bits_a = 0.0
        max_lag = int(round_idx - uniq.min()) if uniq.size else 0
        table = np.zeros((max_lag + 1,), np.int64)
        for frm, cnt in zip(uniq.tolist(), counts.tolist()):
            plan = self.planner.plan(int(frm))
            plans[int(frm)] = plan
            down_bytes += plan.nbytes * int(cnt)
            bits_m += plan.bits_measured * int(cnt)
            bits_a += plan.bits_analytic * int(cnt)
            table[round_idx - int(frm)] = plan.nbytes
            lag = round_idx - int(frm)
            self.telemetry.metrics.gauge(
                "serve/plan_bytes", plan.nbytes,
                round=round_idx, lag=lag, kind=plan.kind,
            )
            self.telemetry.metrics.hist(
                "fed/lag_class", lag, round=round_idx, count=int(cnt),
            )
        self.down_bytes_full_equiv += n_awake * self.log.full_nbytes()

        self._synced, self._bytes, self._syncs = self._advance(
            self._synced, self._bytes, self._syncs,
            jnp.int32(round_idx), jnp.asarray(np.clip(table, 0, 2**31 - 1),
                                              jnp.int32),
        )
        self.ledger.record(RoundRecord(
            round=round_idx, cohort=(), up_bytes=0,
            up_bits_measured=0.0, up_bits_analytic=0.0,
            down_bytes=int(down_bytes), down_bits_measured=bits_m,
            down_bits_analytic=bits_a, down_recipients=n_awake,
        ))
        self._verify_round(round_idx, plans)
        return {
            "round": round_idx,
            "awake": n_awake,
            "classes": {round_idx - f: p.kind for f, p in plans.items()},
            "down_bytes": int(down_bytes),
        }

    # ---------------------------------------------------------- verification

    def _apply_plan(self, flats: List[np.ndarray], plan: CatchupPlan):
        if plan.kind == "replay":
            for e in self.log.entries_since(plan.from_round):
                flats = [f + d for f, d in zip(flats, e.dense)]
            return flats
        if plan.kind in ("stacked", "full"):
            out, _, _ = apply_catchup_flat(flats, plan.blobs[0])
            return out
        return flats

    def _verify_round(self, round_idx: int, plans: Dict[int, CatchupPlan]):
        if not self._verify:
            return
        with self.telemetry.span("verify", round=round_idx,
                                 classes=len(self._verify)):
            for (p, ph), state in self._verify.items():
                if round_idx % p != ph:
                    continue
                plan = plans.get(state["synced"])
                if plan is None:  # class empty this round (shouldn't happen)
                    continue
                state["flats"] = self._apply_plan(state["flats"], plan)
                state["synced"] = round_idx
                self.verified_syncs += 1
                ok = True
                for got, want in zip(state["flats"], self.log._replica):
                    if not np.array_equal(
                        got.view(np.uint32), want.view(np.uint32)
                    ):
                        self._verify_failures += 1
                        ok = False
                        break
                if ok:
                    self.telemetry.metrics.counter(
                        "serve/verify_ok", 1, round=round_idx, period=p,
                    )

    @property
    def verify_ok(self) -> bool:
        """True iff every verified class sync was bit-identical to the
        log replica (trivially True with verify_classes=0)."""
        return self._verify_failures == 0

    # -------------------------------------------------------------- queries

    @property
    def synced_round(self) -> np.ndarray:
        return np.asarray(self._synced)

    @property
    def bytes_down(self) -> np.ndarray:
        return np.asarray(self._bytes)

    def totals(self) -> dict:
        t = self.ledger.totals()
        rounds = max(1, t["rounds"])
        t["bytes_per_subscriber_per_round"] = (
            t["down_bytes"] / (self.n_subscribers * rounds)
        )
        t["down_bytes_full_equiv"] = self.down_bytes_full_equiv
        t["bytes_saving_vs_full_resync"] = (
            self.down_bytes_full_equiv / max(1, t["down_bytes"])
        )
        t["syncs"] = int(np.asarray(self._syncs).sum())
        return t


# ------------------------------------------------------------- simulation


def simulate_fanout(
    params: PyTree,
    *,
    n_subscribers: int,
    rounds: int,
    horizon: int = 8,
    down_sparsity: float = 0.02,
    periods: Tuple[int, ...] = (1, 2, 4, 8),
    seed: int = 0,
    update_scale: float = 1e-2,
    verify_classes: int = 3,
    policy: Optional[Any] = None,
    telemetry: Any = NULL_TELEMETRY,
) -> dict:
    """Drive the PRODUCTION broadcast path at fan-out scale.

    Each round applies a synthetic deterministic update to a
    :class:`~repro.fed.server.ParameterServer` carrying a
    :class:`DeltaLog`, broadcasts (one encode), and fans the log out to
    ``n_subscribers`` through a :class:`SubscriberPool`.  Returns the
    byte/throughput metrics ``benchmarks/broadcast_fanout.py`` gates.
    """
    from repro.core.api import CompressionPolicy, PolicyRule
    from repro.core.codec import make_codec
    from repro.core.policy import DENSE_SMALL_PATTERN
    from repro.fed.server import ParameterServer

    if policy is None:
        policy = CompressionPolicy(
            default=make_codec("sbc"),
            rules=(PolicyRule(DENSE_SMALL_PATTERN, codec="dense32"),),
            name="sbc+dense-small",
        )
    f32 = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), params)
    server = ParameterServer(
        params=f32, up_policy=policy, down_sparsity=down_sparsity,
        delta_horizon=horizon,
    )
    server.telemetry = telemetry
    pool = SubscriberPool(
        log=server.delta_log, n_subscribers=n_subscribers,
        periods=periods, verify_classes=verify_classes,
        telemetry=telemetry,
    )
    leaves, treedef = jax.tree.flatten(server.params)
    rng = jax.random.PRNGKey(seed)
    t0 = time.perf_counter()
    for r in range(rounds):
        rng, sub = jax.random.split(rng)
        keys = jax.random.split(sub, len(leaves))
        leaves = [
            x + update_scale * jax.random.normal(k, np.shape(x), x.dtype)
            for x, k in zip(leaves, keys)
        ]
        server.params = jax.tree.unflatten(treedef, leaves)
        with telemetry.span("round", round=r):
            server.broadcast(r)
            pool.sync_round(r)
    dt = time.perf_counter() - t0

    log = server.delta_log
    planner = pool.planner
    full_cost = log.full_nbytes()
    lag_report = {}
    beats_full = True
    for lag in range(1, min(horizon, log.head + 1) + 1):
        plan = planner.plan(log.head - lag)
        lag_report[str(lag)] = {
            "kind": plan.kind,
            "nbytes": plan.nbytes,
            "candidates": dict(plan.candidates),
        }
        beats_full &= plan.nbytes < full_cost
    pool.ledger.reconcile(rel=0.1)
    telemetry.metrics.ingest_ledger(pool.ledger)

    t = pool.totals()
    return {
        "n_subscribers": n_subscribers,
        "timed_rounds": rounds,
        "horizon": horizon,
        "n_params": log.n_params,
        "down_sparsity": down_sparsity,
        "periods": list(periods),
        "bytes_per_subscriber_per_round": t["bytes_per_subscriber_per_round"],
        "full_resync_bytes": full_cost,
        "bytes_saving_vs_full_resync": t["bytes_saving_vs_full_resync"],
        "down_bytes_total": t["down_bytes"],
        "catchup_beats_full_all_lags": bool(beats_full),
        "stack_bit_exact": bool(pool.verify_ok and pool.verified_syncs > 0),
        "ledger_reconciles": True,  # reconcile(rel=0.1) raised otherwise
        "plan_by_lag": lag_report,
        "rounds_per_sec": rounds / dt,
        "subscriber_syncs_per_sec": t["syncs"] / dt,
    }
