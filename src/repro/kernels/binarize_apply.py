"""Pallas TPU kernel: fused sparse-binarize apply + residual update.

The final pass of SBC compression (paper Alg. 2 lines 5-8 + Eq. 2):

    mask  = pos_wins ? (x ≥ t⁺) : (x ≤ −t⁻)
    ΔW*   = μ · mask                     (μ already signed: +μ⁺ or −μ⁻)
    R_new = x − ΔW*                      (x is the residual-accumulated ΔW)

Unfused this is ~4 HBM round-trips (mask, select, subtract, write); fused it
is one read and two writes, which matters because compression streams the
ENTIRE parameter set once per communication round.  Elementwise over
(BM, LANES) VMEM tiles; padding zeros produce ΔW* = 0 and R = 0 in the pad
region, which the caller slices off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.hist2side import DEFAULT_BM, DEFAULT_LANES, _pad_2d


def _apply_kernel(x_ref, tpos_ref, tneg_ref, mu_ref, side_ref, out_ref, res_ref):
    x = x_ref[...]
    tpos = tpos_ref[0, 0]
    tneg = tneg_ref[0, 0]
    mu = mu_ref[0, 0]
    pos_wins = side_ref[0, 0] > 0.5

    mask = jnp.where(pos_wins, x >= tpos, x <= -tneg)
    out = jnp.where(mask, mu, 0.0)
    out_ref[...] = out
    res_ref[...] = x - out


@functools.partial(jax.jit, static_argnames=("bm", "lanes", "interpret"))
def binarize_apply(
    flat: jax.Array,
    t_pos: jax.Array,
    t_neg: jax.Array,
    mu: jax.Array,
    pos_wins: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    lanes: int = DEFAULT_LANES,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (ΔW*, R_new), both f32 of the original flat length."""
    n = flat.shape[0]
    x, nblocks = _pad_2d(flat, bm, lanes)
    scal = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)

    out, res = pl.pallas_call(
        _apply_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((bm, lanes), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, lanes), lambda i: (i, 0)),
            pl.BlockSpec((bm, lanes), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, jnp.float32),
            jax.ShapeDtypeStruct(x.shape, jnp.float32),
        ],
        interpret=interpret,
    )(x, scal(t_pos), scal(t_neg), scal(mu), scal(pos_wins))
    return out.reshape(-1)[:n], res.reshape(-1)[:n]
