"""Segment-aware Pallas kernels: the SBC pipeline over ONE flat buffer.

The per-leaf kernels in :mod:`hist2side` / :mod:`moments` /
:mod:`binarize_apply` each launch once per tensor — L pallas_calls per
communication round for an L-leaf model.  These variants launch each pass
ONCE over the whole parameter set, laid out as a single block-padded flat
buffer by :class:`repro.core.flat.FlatParamSpace` (DESIGN.md §10):

    leaf i occupies whole (bm, lanes) blocks [blk_off[i], blk_off[i+1]);
    the tail of its last block is zero-padded, so every grid step touches
    exactly one leaf.

Per-block parameters ride in a ``(nblocks, P)`` side array whose row ``i``
is the owning segment's scalars (threshold, μ, side, …), delivered with a
``(1, P)`` BlockSpec — the flat analogue of the per-leaf kernels' ``(1, 1)``
scalar operands.  Reductions (histogram, moments) accumulate into an
``(nseg, …)`` output block through a one-hot segment mask; because each
segment's blocks are visited in the same order as a per-leaf launch over
that segment, the per-segment float accumulation order — and therefore the
result, bit for bit — matches the per-leaf kernels.

HBM traffic per pass is unchanged from the per-leaf kernels (each is
memory-bound at ~4 B/element read); what the flat launch removes is the
L× kernel-dispatch and the per-leaf pad/reshape round-trips.  On CPU every
kernel runs with ``interpret=True`` (set ``interpret=False`` on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _seg_hist_kernel(x_ref, params_ref, hist_ref, *, nbins: int, nseg: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    x = x_ref[...]  # (bm, lanes) f32, one segment's data (zero-padded tail)
    seg = params_ref[0, 0].astype(jnp.int32)
    absx = jnp.abs(x)
    bins = jax.lax.broadcasted_iota(jnp.int32, (nbins, 1, 1), 0)

    rows = []
    # side 0 bins positive entries, side 1 bins |negative| entries — the
    # same two-sided rule as hist2side._hist_kernel, with this block's
    # per-side [lo, hi) ranges read from its params row.
    for side, sel in ((0, x > 0.0), (1, x < 0.0)):
        lo = params_ref[0, 1 + 2 * side]
        hi = params_ref[0, 2 + 2 * side]
        in_range = sel & (absx >= lo) & (absx < hi)
        log_lo = jnp.log2(jnp.maximum(lo, 1e-38))
        log_hi = jnp.log2(jnp.maximum(hi, 2e-38))
        f = (jnp.log2(jnp.maximum(absx, 1e-38)) - log_lo) / (log_hi - log_lo)
        bucket = jnp.clip((f * nbins).astype(jnp.int32), 0, nbins - 1)
        match = bucket[None, :, :] == bins  # (nbins, bm, lanes)
        rows.append(jnp.sum(jnp.where(match & in_range[None], 1.0, 0.0), axis=(1, 2)))

    block = jnp.stack(rows, axis=0)  # (2, nbins)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (nseg, 1, 1), 0) == seg
    ).astype(jnp.float32)
    hist_ref[...] += onehot * block[None]


@functools.partial(
    jax.jit, static_argnames=("nseg", "nbins", "bm", "lanes", "interpret")
)
def seg_hist2side(
    xpad: jax.Array,
    params: jax.Array,
    *,
    nseg: int,
    nbins: int = 128,
    bm: int = 8,
    lanes: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """(nseg, 2, nbins) two-sided log-magnitude histograms, one flat launch.

    xpad:   f32[nblocks*bm, lanes] block-padded flat buffer.
    params: f32[nblocks, 5] rows ``(seg, lo⁺, hi⁺, lo⁻, hi⁻)``.
    """
    nblocks = xpad.shape[0] // bm
    return pl.pallas_call(
        functools.partial(_seg_hist_kernel, nbins=nbins, nseg=nseg),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((bm, lanes), lambda i: (i, 0)),
            pl.BlockSpec((1, 5), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((nseg, 2, nbins), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nseg, 2, nbins), jnp.float32),
        interpret=interpret,
    )(xpad, params)


def _seg_moments_kernel(x_ref, params_ref, out_ref, *, nseg: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]
    seg = params_ref[0, 0].astype(jnp.int32)
    tpos = params_ref[0, 1]
    tneg = params_ref[0, 2]

    pos = x >= tpos
    neg = x <= -tneg
    block = jnp.array(
        [
            [jnp.sum(jnp.where(pos, x, 0.0)), jnp.sum(jnp.where(pos, 1.0, 0.0))],
            [jnp.sum(jnp.where(neg, x, 0.0)), jnp.sum(jnp.where(neg, 1.0, 0.0))],
        ],
        jnp.float32,
    )
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (nseg, 1, 1), 0) == seg
    ).astype(jnp.float32)
    out_ref[...] += onehot * block[None]


@functools.partial(jax.jit, static_argnames=("nseg", "bm", "lanes", "interpret"))
def seg_moments(
    xpad: jax.Array,
    params: jax.Array,
    *,
    nseg: int,
    bm: int = 8,
    lanes: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """(nseg, 2, 2) masked moments [[Σ⁺, n⁺], [Σ⁻, n⁻]] per segment.

    params: f32[nblocks, 3] rows ``(seg, t⁺, t⁻)``.  Padding zeros are never
    selected because t⁺, t⁻ > 0.
    """
    nblocks = xpad.shape[0] // bm
    return pl.pallas_call(
        functools.partial(_seg_moments_kernel, nseg=nseg),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((bm, lanes), lambda i: (i, 0)),
            pl.BlockSpec((1, 3), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((nseg, 2, 2), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nseg, 2, 2), jnp.float32),
        interpret=interpret,
    )(xpad, params)


def _seg_apply_kernel(x_ref, params_ref, out_ref, res_ref):
    x = x_ref[...]
    tpos = params_ref[0, 0]
    tneg = params_ref[0, 1]
    mu = params_ref[0, 2]
    pos_wins = params_ref[0, 3] > 0.5

    mask = jnp.where(pos_wins, x >= tpos, x <= -tneg)
    out = jnp.where(mask, mu, 0.0)
    out_ref[...] = out
    res_ref[...] = x - out


@functools.partial(jax.jit, static_argnames=("bm", "lanes", "interpret"))
def seg_binarize_apply(
    xpad: jax.Array,
    params: jax.Array,
    *,
    bm: int = 8,
    lanes: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused (ΔW*, R) over the whole flat buffer — 1 read, 2 writes.

    params: f32[nblocks, 4] rows ``(t⁺, t⁻, μ, pos_wins)``.  Padding zeros
    yield ΔW* = 0 and R = 0 in the pad region (t⁺, t⁻ > 0).
    """
    nblocks = xpad.shape[0] // bm
    return pl.pallas_call(
        _seg_apply_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((bm, lanes), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, lanes), lambda i: (i, 0)),
            pl.BlockSpec((bm, lanes), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xpad.shape, jnp.float32),
            jax.ShapeDtypeStruct(xpad.shape, jnp.float32),
        ],
        interpret=interpret,
    )(xpad, params)
