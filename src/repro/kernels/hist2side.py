"""Pallas TPU kernel: two-sided log-magnitude range histogram of ΔW.

This is the streaming pass of the TPU-native replacement for the paper's
O(n log n) top-p% sort (DESIGN.md §2).  One HBM→VMEM pass bins the positive
entries of ΔW (row 0) and the magnitudes of the negative entries (row 1)
into ``nbins`` log2-spaced buckets over the half-open magnitude range
``[lo, hi)``; out-of-range values are ignored (the caller tracks them via
survival counts from the previous, coarser pass).

Survival counts over the histogram give the top-k thresholds t⁺/t⁻ to one
bucket's resolution; a second zoomed-in pass over the winning bucket refines
them to nbins² effective resolution (see ops.threshold_two_pass).

Layout: the flat tensor is padded with zeros and reshaped to (R, LANES);
zeros are out-of-range for any lo > 0 so padding needs no mask.  The grid
walks row-blocks sequentially and accumulates into a single (2, nbins)
output block — the canonical Pallas grid-reduction pattern.  VMEM working
set per step ≈ BM·LANES·4 B ≈ 1 MiB at the default (256, 1024).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SPAN_OCTAVES = 30.0  # dynamic range of the coarse pass: [absmax·2⁻³⁰, absmax)

DEFAULT_BM = 256
DEFAULT_LANES = 1024


def _hist_kernel(x_ref, lo_ref, hi_ref, hist_ref, *, nbins: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    x = x_ref[...]  # (bm, lanes) f32
    absx = jnp.abs(x)
    bins = jax.lax.broadcasted_iota(jnp.int32, (nbins, 1, 1), 0)

    rows = []
    # side 0 bins positive entries, side 1 bins |negative| entries; each side
    # has its own [lo, hi) range so the refinement pass can zoom per side.
    for side, sel in ((0, x > 0.0), (1, x < 0.0)):
        lo = lo_ref[0, side]
        hi = hi_ref[0, side]
        in_range = sel & (absx >= lo) & (absx < hi)
        log_lo = jnp.log2(jnp.maximum(lo, 1e-38))
        log_hi = jnp.log2(jnp.maximum(hi, 2e-38))
        f = (jnp.log2(jnp.maximum(absx, 1e-38)) - log_lo) / (log_hi - log_lo)
        bucket = jnp.clip((f * nbins).astype(jnp.int32), 0, nbins - 1)
        match = bucket[None, :, :] == bins  # (nbins, bm, lanes)
        rows.append(jnp.sum(jnp.where(match & in_range[None], 1.0, 0.0), axis=(1, 2)))

    hist_ref[...] += jnp.stack(rows, axis=0)


def _pad_2d(flat: jax.Array, bm: int, lanes: int) -> tuple[jax.Array, int]:
    n = flat.shape[0]
    per_block = bm * lanes
    nblocks = max(1, pl.cdiv(n, per_block))
    padded = nblocks * per_block
    x = jnp.zeros((padded,), jnp.float32).at[:n].set(flat.astype(jnp.float32))
    return x.reshape(nblocks * bm, lanes), nblocks


@functools.partial(jax.jit, static_argnames=("nbins", "bm", "lanes", "interpret"))
def hist2side(
    flat: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    *,
    nbins: int = 128,
    bm: int = DEFAULT_BM,
    lanes: int = DEFAULT_LANES,
    interpret: bool = True,
) -> jax.Array:
    """(2, nbins) histogram: row 0 = positive entries, row 1 = |negatives|.

    ``lo``/``hi`` broadcast to shape (2,): per-side magnitude ranges.
    """
    x, nblocks = _pad_2d(flat, bm, lanes)
    lo2 = jnp.broadcast_to(jnp.asarray(lo, jnp.float32), (2,)).reshape(1, 2)
    hi2 = jnp.broadcast_to(jnp.asarray(hi, jnp.float32), (2,)).reshape(1, 2)

    return pl.pallas_call(
        functools.partial(_hist_kernel, nbins=nbins),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((bm, lanes), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((2, nbins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((2, nbins), jnp.float32),
        interpret=interpret,
    )(x, lo2, hi2)


def bucket_lower_edges(lo: jax.Array, hi: jax.Array, nbins: int) -> jax.Array:
    """Lower magnitude edge of every bucket, shape (nbins,), log2-spaced."""
    f = jnp.arange(nbins, dtype=jnp.float32) / nbins
    log_lo = jnp.log2(jnp.maximum(lo, 1e-38))
    log_hi = jnp.log2(jnp.maximum(hi, 2e-38))
    return 2.0 ** (log_lo + f * (log_hi - log_lo))
