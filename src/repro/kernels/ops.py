"""Public jit'd wrappers over the Pallas SBC kernels.

Pipeline (the TPU-native replacement for the paper's top-p% sort):

  1. ``threshold_two_pass`` — coarse (2, nbins) log-magnitude histogram over
     [absmax·2⁻³⁰, absmax), survival counts pick the bucket holding the k-th
     largest entry per side; a second histogram zoomed into that bucket
     refines the threshold to nbins² effective resolution (~0.03 octaves at
     nbins=128, i.e. ≤2% relative threshold error).
  2. ``masked_moments`` — μ⁺/μ⁻ over the selected entries (Alg. 2 l.4).
  3. ``binarize_apply`` — fused ΔW* write + residual update (Eq. 2).

Three streaming passes total vs. an O(n log n) sort; each pass is
memory-bound at ~4 B/element read.  On CPU (this container) every kernel
runs with ``interpret=True``; on TPU set ``interpret=False``.

``sbc_compress_hist`` composes the full pipeline and returns everything the
trainer's exchange needs.  ``sbc_compress_exact`` is the faithful
``lax.top_k`` path (the baseline recorded in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.golomb import expected_position_bits
from repro.kernels.binarize_apply import binarize_apply
from repro.kernels.hist2side import SPAN_OCTAVES, bucket_lower_edges, hist2side
from repro.kernels.moments import masked_moments

def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _side_threshold(
    hist_row: jax.Array, edges: jax.Array, k: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Pick the bucket of the k-th largest entry from survival counts.

    Returns (bucket_lo_edge, bucket_hi_edge, count_above_bucket).
    If the side has fewer than k entries the threshold collapses to the
    lowest edge (select everything on that side).
    """
    nbins = hist_row.shape[0]
    # survival[b] = number of entries in bucket >= b
    survival = jnp.cumsum(hist_row[::-1])[::-1]
    feasible = survival >= k
    any_feasible = jnp.any(feasible)
    # largest feasible bucket index (survival is non-increasing)
    bstar = jnp.where(any_feasible, jnp.sum(feasible.astype(jnp.int32)) - 1, 0)
    lo_edge = jnp.where(any_feasible, edges[bstar], edges[0])
    hi_edge = jnp.where(
        bstar + 1 < nbins,
        edges[jnp.minimum(bstar + 1, nbins - 1)],
        edges[nbins - 1] * 2.0,
    )
    above = jnp.where(
        bstar + 1 < nbins,
        jnp.concatenate([survival[1:], jnp.zeros((1,))])[bstar],
        0.0,
    )
    return lo_edge, hi_edge, above


@functools.partial(jax.jit, static_argnames=("k", "nbins", "interpret"))
def threshold_two_pass(
    flat: jax.Array,
    k: int,
    *,
    nbins: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(t⁺, t⁻): approximate k-th-largest thresholds for each side of ΔW."""
    x = flat.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) + 1e-30
    lo0 = scale * 2.0**-SPAN_OCTAVES
    hi0 = scale * 1.0001

    h1 = hist2side(x, lo0, hi0, nbins=nbins, interpret=interpret)
    edges0 = bucket_lower_edges(lo0, hi0, nbins)

    kf = jnp.asarray(k, jnp.float32)
    lo_p, hi_p, above_p = _side_threshold(h1[0], edges0, kf)
    lo_n, hi_n, above_n = _side_threshold(h1[1], edges0, kf)

    # pass 2: zoom into the winning bucket per side
    h2 = hist2side(
        x,
        jnp.stack([lo_p, lo_n]),
        jnp.stack([hi_p, hi_n]),
        nbins=nbins,
        interpret=interpret,
    )
    edges_p = bucket_lower_edges(lo_p, hi_p, nbins)
    edges_n = bucket_lower_edges(lo_n, hi_n, nbins)
    t_pos, _, _ = _side_threshold(h2[0], edges_p, kf - above_p)
    t_neg, _, _ = _side_threshold(h2[1], edges_n, kf - above_n)
    return t_pos, t_neg


class SBCCompressed(NamedTuple):
    """Everything one SBC compression of a flat tensor produces."""

    delta_star: jax.Array  # dense ΔW* (f32[n])
    residual: jax.Array  # new residual = acc − ΔW* (f32[n])
    mean: jax.Array  # signed μ (f32[])
    count: jax.Array  # number of surviving entries m (f32[])
    nbits: jax.Array  # analytic wire bits: m·b̄_pos(p) + 32


@functools.partial(jax.jit, static_argnames=("p", "nbins", "interpret"))
def sbc_compress_hist(
    acc: jax.Array,
    *,
    p: float,
    nbins: int = 128,
    interpret: bool = True,
) -> SBCCompressed:
    """Histogram-threshold SBC over a residual-accumulated flat update."""
    n = acc.shape[0]
    k = max(1, min(n, int(round(p * n))))
    x = acc.astype(jnp.float32)

    t_pos, t_neg = threshold_two_pass(x, k, nbins=nbins, interpret=interpret)
    mom = masked_moments(x, t_pos, t_neg, interpret=interpret)
    mu_pos = mom[0, 0] / jnp.maximum(mom[0, 1], 1.0)
    mu_neg = -mom[1, 0] / jnp.maximum(mom[1, 1], 1.0)  # positive magnitude

    pos_wins = mu_pos > mu_neg
    mu = jnp.where(pos_wins, mu_pos, -mu_neg)
    count = jnp.where(pos_wins, mom[0, 1], mom[1, 1])

    out, res = binarize_apply(
        x, t_pos, t_neg, mu, pos_wins.astype(jnp.float32), interpret=interpret
    )
    nbits = count * expected_position_bits(p) + 32.0
    return SBCCompressed(out, res, mu, count, nbits)


@functools.partial(jax.jit, static_argnames=("p",))
def sbc_compress_exact(acc: jax.Array, *, p: float) -> SBCCompressed:
    """Faithful Alg. 2 via lax.top_k (exactly k survivors)."""
    n = acc.shape[0]
    k = max(1, min(n, int(round(p * n))))
    x = acc.astype(jnp.float32)

    val_pos, idx_pos = jax.lax.top_k(x, k)
    val_neg, idx_neg = jax.lax.top_k(-x, k)
    mu_pos = jnp.mean(val_pos)
    mu_neg = jnp.mean(val_neg)
    pos_wins = mu_pos > mu_neg
    idx = jnp.where(pos_wins, idx_pos, idx_neg)
    mu = jnp.where(pos_wins, mu_pos, -mu_neg)

    out = jnp.zeros_like(x).at[idx].set(mu)
    nbits = jnp.asarray(k * expected_position_bits(p) + 32.0, jnp.float32)
    return SBCCompressed(out, x - out, mu, jnp.asarray(k, jnp.float32), nbits)


def dense_to_sparse(dense: jax.Array, k_cap: int) -> tuple[jax.Array, jax.Array]:
    """Extract (idx[k_cap], valid[k_cap]) from a dense masked tensor.

    Used by the exchange when the survivor count is only approximately k
    (histogram path).  Padding slots carry valid=0 so scatter-adds are no-ops.
    """
    idx = jnp.nonzero(dense, size=k_cap, fill_value=0)[0].astype(jnp.int32)
    m = jnp.sum((dense != 0).astype(jnp.int32))
    valid = (jnp.arange(k_cap) < m).astype(jnp.float32)
    return idx, valid
