"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hist2side_ref(flat: jax.Array, lo, hi, nbins: int = 128) -> jax.Array:
    """Oracle for kernels.hist2side.hist2side (identical binning rule).

    ``lo``/``hi`` broadcast to (2,): per-side magnitude ranges.
    """
    x = flat.astype(jnp.float32)
    absx = jnp.abs(x)
    lo = jnp.broadcast_to(jnp.asarray(lo, jnp.float32), (2,))
    hi = jnp.broadcast_to(jnp.asarray(hi, jnp.float32), (2,))
    rows = []
    for side, sel in ((0, x > 0.0), (1, x < 0.0)):
        in_range = sel & (absx >= lo[side]) & (absx < hi[side])
        log_lo = jnp.log2(jnp.maximum(lo[side], 1e-38))
        log_hi = jnp.log2(jnp.maximum(hi[side], 2e-38))
        f = (jnp.log2(jnp.maximum(absx, 1e-38)) - log_lo) / (log_hi - log_lo)
        bucket = jnp.clip((f * nbins).astype(jnp.int32), 0, nbins - 1)
        rows.append(jnp.zeros((nbins,)).at[bucket].add(jnp.where(in_range, 1.0, 0.0)))
    return jnp.stack(rows, axis=0)


def masked_moments_ref(flat: jax.Array, t_pos, t_neg) -> jax.Array:
    x = flat.astype(jnp.float32)
    pos = x >= t_pos
    neg = x <= -t_neg
    return jnp.array(
        [
            [jnp.sum(jnp.where(pos, x, 0.0)), jnp.sum(pos.astype(jnp.float32))],
            [jnp.sum(jnp.where(neg, x, 0.0)), jnp.sum(neg.astype(jnp.float32))],
        ],
        jnp.float32,
    )


def binarize_apply_ref(flat, t_pos, t_neg, mu, pos_wins):
    x = flat.astype(jnp.float32)
    mask = jnp.where(pos_wins > 0.5, x >= t_pos, x <= -t_neg)
    out = jnp.where(mask, jnp.asarray(mu, jnp.float32), 0.0)
    return out, x - out


def sbc_exact_ref(flat: jax.Array, k: int) -> jax.Array:
    """Exact top-k SBC (paper Alg. 2) — the oracle the histogram pipeline
    approximates.  Returns the dense ΔW*."""
    val_pos, idx_pos = jax.lax.top_k(flat, k)
    val_neg, idx_neg = jax.lax.top_k(-flat, k)
    mu_pos = jnp.mean(val_pos)
    mu_neg = jnp.mean(val_neg)
    pos_wins = mu_pos > mu_neg
    idx = jnp.where(pos_wins, idx_pos, idx_neg)
    mean = jnp.where(pos_wins, mu_pos, -mu_neg)
    return jnp.zeros_like(flat).at[idx].set(mean)
