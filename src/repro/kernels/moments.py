"""Pallas TPU kernel: masked first moments for sparse binarization.

Given the top-k thresholds t⁺ and t⁻ (from the histogram passes), one
streaming HBM→VMEM pass computes, per paper Alg. 2 lines 3-4:

    sum⁺ = Σ x·[x ≥ t⁺]      cnt⁺ = Σ [x ≥ t⁺]
    sum⁻ = Σ x·[x ≤ −t⁻]     cnt⁻ = Σ [x ≤ −t⁻]

so that μ⁺ = sum⁺/cnt⁺ and μ⁻ = −sum⁻/cnt⁻.  Output is a single (2, 2)
block accumulated across the sequential grid: [[sum⁺, cnt⁺], [sum⁻, cnt⁻]].

Padding zeros are never selected because t⁺, t⁻ > 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.hist2side import DEFAULT_BM, DEFAULT_LANES, _pad_2d


def _moments_kernel(x_ref, tpos_ref, tneg_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]
    tpos = tpos_ref[0, 0]
    tneg = tneg_ref[0, 0]

    pos = x >= tpos
    neg = x <= -tneg
    sum_pos = jnp.sum(jnp.where(pos, x, 0.0))
    cnt_pos = jnp.sum(jnp.where(pos, 1.0, 0.0))
    sum_neg = jnp.sum(jnp.where(neg, x, 0.0))
    cnt_neg = jnp.sum(jnp.where(neg, 1.0, 0.0))

    out_ref[...] += jnp.array([[sum_pos, cnt_pos], [sum_neg, cnt_neg]], jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "lanes", "interpret"))
def masked_moments(
    flat: jax.Array,
    t_pos: jax.Array,
    t_neg: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    lanes: int = DEFAULT_LANES,
    interpret: bool = True,
) -> jax.Array:
    """Returns (2,2) f32: [[sum⁺, cnt⁺], [sum⁻, cnt⁻]]."""
    x, nblocks = _pad_2d(flat, bm, lanes)
    tp = jnp.asarray(t_pos, jnp.float32).reshape(1, 1)
    tn = jnp.asarray(t_neg, jnp.float32).reshape(1, 1)

    return pl.pallas_call(
        _moments_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((bm, lanes), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((2, 2), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((2, 2), jnp.float32),
        interpret=interpret,
    )(x, tp, tn)
