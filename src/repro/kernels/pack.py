"""Device-side Golomb position packing: fused select→pack Pallas kernels.

The host encoder (:mod:`repro.core.golomb`) produces the paper's Alg. 3
bitstream with numpy; every byte the wire sees is therefore a host
round-trip, which is exactly the overhead that erases sparse-training
speedups in practice (Lin et al.; Eghlidi & Jaggi).  This module moves
byte production on-device:

  * :func:`seg_packbits` — the whole-flat-set pass: a Pallas kernel that
    folds a 0/1 bit-plane buffer into packed ``uint32`` words by
    bit-shift/mask accumulation, grid-launched over word blocks exactly
    like the ``seg_*`` passes in :mod:`repro.kernels.flat`;
  * :func:`seg_select_pack` — the fused variant: one Pallas launch per
    (segment, row) grid that consumes the two-sided top-k MASK directly
    and emits packed words + exact bit counts, so surviving positions
    never materialize as an index array;
  * :func:`golomb_decode_rows` — the matching device decoder (pointer
    doubling over the next-codeword-start map, O(B·log k) fully
    parallel work), used by the sharded exchange to recover positions
    from all-gathered word buffers.

Bit-layout contract (what makes device output BYTE-identical to the host
``encode_positions_packed``): stream bit ``b`` lives in word ``b >> 5``
at bit position ``31 - (b & 31)``, so a big-endian view of the word
buffer, truncated to ``ceil(nbits/8)`` bytes, equals
``np.packbits(bits).tobytes()`` (see ``golomb.packed_words_to_bytes``).

Everything is static-shaped: a row with ``k`` survivors out of ``n``
candidates needs at most ``((n - k) >> b*) + k·(1 + b*)`` stream bits
(``Σ (d_i - 1) ≤ n - k`` bounds the unary runs), so the per-row word
capacity — and with it the whole concatenated stream layout — is known
at trace time.  On CPU every kernel runs with ``interpret=True`` (set
``interpret=False`` on TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def row_bit_capacity(n: int, k: int, bstar: int) -> int:
    """Worst-case stream bits for k survivors of n slots (static bound)."""
    if k <= 0:
        return 0
    return ((n - k) >> bstar) + k * (1 + bstar)


def row_words(n: int, k: int, bstar: int) -> int:
    """uint32 words needed for one row's packed stream (static bound)."""
    return -(-row_bit_capacity(n, k, bstar) // 32)


# ------------------------------------------------------ bit-stream builders


def _codeword_bits(dm1: jax.Array, *, bstar: int, cap32: int) -> tuple:
    """Golomb codewords for gap-minus-one values ``dm1`` → 0/1 bit array.

    Per codeword: ``q = dm1 >> b*`` unary ones, a terminating 0, then b*
    big-endian remainder bits — the same layout as the host encoder.  The
    unary runs are one ±1 scatter + cumsum; the remainder bits are one
    vectorized scatter.  Returns ``(bits u32[cap32], nbits i32)`` with
    every bit past ``nbits`` zero (byte padding falls out for free).
    """
    k = dm1.shape[0]
    if k == 0:
        return jnp.zeros((cap32,), jnp.uint32), jnp.zeros((), jnp.int32)
    q = dm1 >> bstar
    lens = q + 1 + bstar
    starts = jnp.cumsum(lens) - lens  # exclusive
    nbits = starts[-1] + lens[-1]
    delta = (
        jnp.zeros((cap32 + 1,), jnp.int32)
        .at[starts].add(1, mode="drop")
        .at[starts + q].add(-1, mode="drop")
    )
    bits = (jnp.cumsum(delta)[:cap32] > 0).astype(jnp.uint32)
    if bstar:
        r = dm1 & ((1 << bstar) - 1)
        j = jnp.arange(bstar, dtype=jnp.int32)
        rem_pos = (starts + q + 1)[:, None] + j[None, :]
        rem_val = (r[:, None] >> (bstar - 1 - j)[None, :]) & 1
        bits = bits.at[rem_pos.reshape(-1)].add(
            rem_val.reshape(-1).astype(jnp.uint32), mode="drop"
        )
    return bits, nbits.astype(jnp.int32)


def bits_from_positions(pos: jax.Array, *, bstar: int, cap32: int) -> tuple:
    """Sorted ascending positions (one row) → Golomb stream bits."""
    dm1 = jnp.diff(pos.astype(jnp.int32), prepend=jnp.int32(-1)) - 1
    return _codeword_bits(dm1, bstar=bstar, cap32=cap32)


def bits_from_mask(mask: jax.Array, *, k: int, bstar: int, cap32: int) -> tuple:
    """Selection mask (one row) → Golomb stream bits, index-array-free.

    ``zb[i]`` counts unselected slots up to and including ``i``; for the
    r-th selected slot, ``zb`` jumps by exactly ``gap - 1`` from the
    (r−1)-th, so scattering ``zb`` by selection rank yields the
    gap-minus-one sequence directly — positions never materialize.
    """
    m = mask.astype(jnp.int32)
    zb = jnp.cumsum(1 - m)
    rank = jnp.cumsum(m)
    tgt = jnp.where(m == 1, rank - 1, k)
    z = jnp.zeros((k,), jnp.int32).at[tgt].set(zb, mode="drop")
    dm1 = z - jnp.concatenate([jnp.zeros((1,), jnp.int32), z[:-1]])
    return _codeword_bits(dm1, bstar=bstar, cap32=cap32)


# ------------------------------------------------------- seg_packbits pass


def _packbits_kernel(bits_ref, words_ref):
    planes = bits_ref[...]  # (32, lanes) u32 bit planes of one word block
    acc = jnp.zeros_like(planes[0])
    for j in range(32):  # bit-shift/mask accumulation into uint32 words
        acc = acc | (planes[j] << jnp.uint32(31 - j))
    words_ref[...] = acc[None]


@functools.partial(jax.jit, static_argnames=("lanes", "interpret"))
def seg_packbits(
    bits_pl: jax.Array, *, lanes: int = 128, interpret: bool = True
) -> jax.Array:
    """One flat launch: bit planes → packed ``uint32`` word buffer.

    bits_pl: u32[32, nwords] where ``bits_pl[j, w]`` is stream bit
    ``32·w + j`` (i.e. the row-major bit buffer reshaped ``(-1, 32)`` and
    transposed); nwords must be a multiple of ``lanes``.  Returns
    u32[nwords] with bit ``b`` of the stream at word ``b >> 5``, bit
    position ``31 - (b & 31)``.
    """
    nwords = bits_pl.shape[1]
    nblocks = nwords // lanes
    out = pl.pallas_call(
        _packbits_kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((32, lanes), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, lanes), jnp.uint32),
        interpret=interpret,
    )(bits_pl)
    return out.reshape(-1)


def pack_bit_rows(
    bits: jax.Array, *, lanes: int = 128, interpret: bool = True
) -> jax.Array:
    """Convenience wrapper: u32[..., cap32] bit rows → u32[..., cap32/32]
    words via ONE :func:`seg_packbits` launch over the concatenation."""
    cap32 = bits.shape[-1]
    flat = bits.reshape(-1)
    pad = -flat.shape[0] % (32 * lanes)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    planes = flat.reshape(-1, 32).T
    words = seg_packbits(planes, lanes=lanes, interpret=interpret)
    nw = bits.size // 32 if bits.size else 0
    return words[:nw].reshape(bits.shape[:-1] + (cap32 // 32,))


# ------------------------------------------------- fused select→pack pass


def _select_pack_kernel(mask_ref, words_ref, nbits_ref, *, k, bstar, cap32):
    m = mask_ref[0, :]
    bits, nbits = bits_from_mask(m, k=k, bstar=bstar, cap32=cap32)
    grouped = bits.reshape(-1, 32)
    acc = jnp.zeros((grouped.shape[0],), jnp.uint32)
    for j in range(32):
        acc = acc | (grouped[:, j] << jnp.uint32(31 - j))
    words_ref[...] = acc[None]
    nbits_ref[...] = nbits[None, None]


@functools.partial(jax.jit, static_argnames=("k", "bstar", "interpret"))
def seg_select_pack(
    mask: jax.Array, *, k: int, bstar: int, interpret: bool = True
) -> tuple:
    """Fused select→pack: two-sided top-k masks straight to packed words.

    mask: bool/int[rows, n] with exactly ``k`` selected slots per row.
    One grid step per row builds the row's Golomb stream from the mask
    (no index array) and folds it into ``uint32`` words in-kernel.
    Returns ``(words u32[rows, W], nbits i32[rows])`` with
    ``W = row_words(n, k, b*)``.
    """
    rows, n = mask.shape
    cap32 = 32 * row_words(n, k, bstar)
    words, nbits = pl.pallas_call(
        functools.partial(_select_pack_kernel, k=k, bstar=bstar, cap32=cap32),
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, cap32 // 32), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cap32 // 32), jnp.uint32),
            jax.ShapeDtypeStruct((rows, 1), jnp.int32),
        ],
        interpret=interpret,
    )(mask.astype(jnp.int32))
    return words, nbits[:, 0]


# ------------------------------------------------------------ device decode


def _decode_row(words: jax.Array, *, k: int, bstar: int) -> jax.Array:
    """u32[W] packed stream (≥ k codewords) → i32[k] ascending positions.

    Sequential-looking, but log-parallel: the cursor recurrence
    ``c' = nz[c] + 1 + b*`` iterates ONE map, so codeword starts are
    ``f^r(0)`` and pointer doubling gives all k of them in ``log2 k``
    gather rounds instead of a k-step scan.
    """
    shifts = (31 - jnp.arange(32, dtype=jnp.uint32)).astype(jnp.uint32)
    bits = ((words[:, None] >> shifts[None, :]) & 1).astype(jnp.int32)
    bits = bits.reshape(-1)
    ext = bits.shape[0] + bstar + 2  # zero tail: nz always finds a 0
    bits_e = jnp.concatenate(
        [bits, jnp.zeros((ext + bstar - bits.shape[0],), jnp.int32)]
    )
    iota = jnp.arange(ext, dtype=jnp.int32)
    cand = jnp.where(bits_e[:ext] == 0, iota, ext - 1)
    nz = jax.lax.associative_scan(jnp.minimum, cand, reverse=True)
    rem = jnp.zeros((ext,), jnp.int32)
    for j in range(bstar):
        rem = rem + (bits_e[j : j + ext] << (bstar - 1 - j))
    nxt = jnp.minimum(nz + 1 + bstar, ext - 1)  # next-codeword-start map
    cursors = jnp.zeros((k,), jnp.int32)
    ranks = jnp.arange(k, dtype=jnp.int32)
    table = nxt
    for j in range(max(1, (k - 1).bit_length())):
        if (k - 1) >> j == 0:
            break
        cursors = jnp.where(((ranks >> j) & 1) == 1, table[cursors], cursors)
        table = table[table]  # f^(2^j) → f^(2^(j+1))
    z = nz[cursors]
    q = z - cursors
    dm1 = (q << bstar) + rem[jnp.minimum(z + 1, ext - 1)]
    return (jnp.cumsum(dm1 + 1) - 1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "bstar", "interpret"))
def golomb_decode_rows(
    words: jax.Array, *, k: int, bstar: int, interpret: bool = True
) -> jax.Array:
    """u32[..., W] packed streams → i32[..., k] ascending positions."""
    del interpret  # decode is pure jnp; kept for call-site symmetry
    fn = functools.partial(_decode_row, k=k, bstar=bstar)
    lead = words.shape[:-1]
    out = jax.vmap(fn)(words.reshape((-1,) + words.shape[-1:]))
    return out.reshape(lead + (k,))
