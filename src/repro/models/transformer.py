"""Decoder / encoder-decoder assembly for every assigned architecture.

Layer stacks compile as a ``lax.scan`` over *superblocks*: one superblock is
the smallest repeating pattern of the architecture (jamba: 7 mamba + 1 attn
with MoE every 2nd → period 8; gemma3: 5 local + 1 global → period 6;
homogeneous archs → period 1).  Remainder layers (26 = 4·6 + 2 for gemma3)
are unrolled.  Compile time therefore scales with the period, not n_layers
(DESIGN.md §7).

Block kinds come from ``cfg.layer_kinds``:
  attn / attn_window / attn_local / attn_chunk → attention block + MLP/MoE
  mamba → Mamba block (no separate MLP)
  rwkv6 → RWKV time-mix + channel-mix pair

Three execution modes share the block code: train (full seq, no caches),
prefill (full seq, returns caches), decode (1 token, carries caches).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import hints
from repro.models import moe as moe_lib
from repro.models import ssm
from repro.models.layers import embed_lookup, init_embed, init_mlp, init_norm, mlp_apply, norm_apply

PyTree = Any


# ------------------------------------------------------------------ blocks


def init_block(rng, cfg, kind: str, use_moe: bool, *, cross: bool = False) -> dict:
    ks = jax.random.split(rng, 5)
    p: dict = {"norm1": init_norm(cfg.d_model, cfg.norm, cfg.dtype)}
    if kind == "mamba":
        p["inner"] = ssm.init_mamba(ks[0], cfg)
        if cfg.ssm_ffn:  # jamba: mamba mixer + FFN/MoE (arXiv:2403.19887)
            p["norm2"] = init_norm(cfg.d_model, cfg.norm, cfg.dtype)
            if use_moe:
                p["moe"] = moe_lib.init_moe(ks[2], cfg)
            else:
                p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff,
                                    gated=cfg.gated_mlp, dtype=cfg.dtype)
        return p  # pure-mamba archs: no separate MLP
    if kind == "rwkv6":
        p["inner"] = ssm.init_rwkv6(ks[0], cfg)
        p["norm2"] = init_norm(cfg.d_model, cfg.norm, cfg.dtype)
        return p  # channel-mix lives inside the rwkv params
    p["inner"] = attn.init_attention(ks[0], cfg)
    if cross:
        p["norm_x"] = init_norm(cfg.d_model, cfg.norm, cfg.dtype)
        p["cross"] = attn.init_attention(ks[1], cfg, cross=True)
    p["norm2"] = init_norm(cfg.d_model, cfg.norm, cfg.dtype)
    if use_moe:
        p["moe"] = moe_lib.init_moe(ks[2], cfg)
    else:
        p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp, dtype=cfg.dtype)
    return p


def _block_train(params, x, cfg, kind, use_moe, positions, enc_out=None, want_cache=False):
    """Returns (x, aux, cache_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    h = norm_apply(params["norm1"], x, cfg.norm)
    if kind == "mamba":
        y, h_final, conv_tail = ssm.mamba_train(params["inner"], h, cfg)
        x = x + y
        if "norm2" in params:  # jamba FFN/MoE
            h2 = norm_apply(params["norm2"], x, cfg.norm)
            if use_moe:
                y2, aux = moe_lib.moe_apply(params["moe"], h2, cfg)
            else:
                y2 = mlp_apply(params["mlp"], h2)
            x = x + y2
        if want_cache:
            # exact decode continuity: carried SSM state + true conv window
            cache = {"h": h_final, "conv": conv_tail}
        return x, aux, cache
    if kind == "rwkv6":
        B = x.shape[0]
        st = ssm.rwkv6_init_state(cfg, B)
        y, s_final, tm_prev = ssm.rwkv6_time_mix(params["inner"], h, cfg, st["s"], st["tm_prev"])
        x = x + y
        h2 = norm_apply(params["norm2"], x, cfg.norm)
        y2, cm_prev = ssm.rwkv6_channel_mix(params["inner"], h2, cfg, st["cm_prev"])
        x = x + y2
        if want_cache:
            cache = {"s": s_final, "tm_prev": tm_prev, "cm_prev": cm_prev}
        return x, aux, cache

    # attention block
    y, kv = attn.attn_train(
        params["inner"], h, cfg, kind, positions=positions, return_cache_seq=want_cache
    )
    x = x + y
    if "cross" in params:
        hx = norm_apply(params["norm_x"], x, cfg.norm)
        yx, cross_kv = attn.attn_train(
            params["cross"], hx, cfg, "cross", kv_x=enc_out, return_cache_seq=want_cache
        )
        x = x + yx
    h2 = norm_apply(params["norm2"], x, cfg.norm)
    if use_moe:
        y2, aux = moe_lib.moe_apply(params["moe"], h2, cfg)
    else:
        y2 = mlp_apply(params["mlp"], h2)
    x = x + y2
    if want_cache:
        S = x.shape[1]
        c = attn.init_cache(cfg, kind, x.shape[0], S, cfg.dtype)
        cache = attn.fill_cache_from_prefill(c, kind, cfg, kv[0], kv[1])
        if "cross" in params:
            cache["cross_k"], cache["cross_v"] = cross_kv
    return x, aux, cache


def _block_decode(params, x, cfg, kind, use_moe, cache, pos):
    """One-token step.  Returns (x, new_cache)."""
    h = norm_apply(params["norm1"], x, cfg.norm)
    if kind == "mamba":
        y, new_state = ssm.mamba_decode(params["inner"], h, cfg, cache)
        x = x + y
        if "norm2" in params:  # jamba FFN/MoE
            h2 = norm_apply(params["norm2"], x, cfg.norm)
            if use_moe:
                y2, _ = moe_lib.moe_apply(params["moe"], h2, cfg, full_capacity=True)
            else:
                y2 = mlp_apply(params["mlp"], h2)
            x = x + y2
        return x, new_state
    if kind == "rwkv6":
        y, s_final, tm_prev = ssm.rwkv6_time_mix(
            params["inner"], h, cfg, cache["s"], cache["tm_prev"]
        )
        x = x + y
        h2 = norm_apply(params["norm2"], x, cfg.norm)
        y2, cm_prev = ssm.rwkv6_channel_mix(params["inner"], h2, cfg, cache["cm_prev"])
        x = x + y2
        return x, {"s": s_final, "tm_prev": tm_prev, "cm_prev": cm_prev}

    attn_cache = {k: cache[k] for k in ("k", "v", "pos")}
    y, new_attn_cache = attn.attn_decode(params["inner"], h, cfg, kind, attn_cache, pos)
    x = x + y
    new_cache = dict(new_attn_cache)
    if "cross" in params:
        hx = norm_apply(params["norm_x"], x, cfg.norm)
        yx, _ = attn.attn_decode(
            params["cross"], hx, cfg, "cross", None, pos,
            cross_memory=(cache["cross_k"], cache["cross_v"]),
        )
        x = x + yx
        new_cache["cross_k"], new_cache["cross_v"] = cache["cross_k"], cache["cross_v"]
    h2 = norm_apply(params["norm2"], x, cfg.norm)
    if use_moe:
        y2, _ = moe_lib.moe_apply(params["moe"], h2, cfg, full_capacity=True)
    else:
        y2 = mlp_apply(params["mlp"], h2)
    return x + y2, new_cache


# ------------------------------------------------------- stack organization


def stack_pattern(cfg) -> tuple[int, int, int]:
    """(period, n_scan_superblocks, n_remainder_layers)."""
    def lcm(a, b):
        return a * b // math.gcd(a, b)

    period = 1
    if cfg.ssm_kind and cfg.attn_every > 1:
        period = lcm(period, cfg.attn_every)
    if cfg.local_global_ratio:
        period = lcm(period, cfg.local_global_ratio + 1)
    if cfg.global_every:
        period = lcm(period, cfg.global_every)
    if cfg.moe_experts:
        period = lcm(period, cfg.moe_every)
    if not cfg.scan_layers:
        return cfg.n_layers, 1 if cfg.n_layers else 0, cfg.n_layers % max(cfg.n_layers, 1)
    n_scan = cfg.n_layers // period
    rem = cfg.n_layers - n_scan * period
    return period, n_scan, rem


def layer_desc(cfg, i: int) -> tuple[str, bool]:
    return cfg.layer_kinds[i], cfg.layer_moe[i]


def init_stack(rng, cfg, *, cross: bool = False) -> dict:
    """Stacked superblock params (+ remainder).  Structure:
    {'scan': {bj: stacked-over-superblocks}, 'rem': {bj: params}}"""
    period, n_scan, rem = stack_pattern(cfg)
    ks = jax.random.split(rng, max(n_scan, 1) * period + rem + 1)
    ki = iter(ks)

    def superblock(base_layer: int) -> dict:
        return {
            f"b{j}": init_block(next(ki), cfg, *layer_desc(cfg, base_layer + j), cross=cross)
            for j in range(period)
        }

    out: dict = {}
    if n_scan:
        blocks = [superblock(sb * period) for sb in range(n_scan)]
        out["scan"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    if rem:
        out["rem"] = {
            f"b{j}": init_block(next(ki), cfg, *layer_desc(cfg, n_scan * period + j), cross=cross)
            for j in range(rem)
        }
    return out


def _apply_stack_train(stack, x, cfg, positions, enc_out=None, want_cache=False, cross=False):
    """Run all layers.  Returns (x, aux_total, caches)."""
    period, n_scan, rem = stack_pattern(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    caches: dict = {}

    def block_fn(kind: str, use_moe: bool):
        def fn(p, x, positions, enc_out):
            x, a, c = _block_train(p, x, cfg, kind, use_moe, positions, enc_out,
                                   want_cache=want_cache)
            # sequence-parallel checkpoint boundary (no-op without a mesh ctx)
            return hints.act(x), a, c
        return jax.checkpoint(fn) if cfg.remat else fn

    def superblock_body(carry, sb_params):
        x, aux = carry
        cs = {}
        for j in range(period):
            kind, use_moe = layer_desc(cfg, j)  # pattern is period-invariant
            x, a, c = block_fn(kind, use_moe)(sb_params[f"b{j}"], x, positions, enc_out)
            aux = aux + a
            if want_cache:
                cs[f"b{j}"] = c
        return (x, aux), cs

    if n_scan:
        (x, aux_total), scan_caches = jax.lax.scan(superblock_body, (x, aux_total), stack["scan"])
        if want_cache:
            caches["scan"] = scan_caches
    if rem:
        rem_caches = {}
        for j in range(rem):
            kind, use_moe = layer_desc(cfg, n_scan * period + j)
            x, a, c = block_fn(kind, use_moe)(
                stack["rem"][f"b{j}"], x, positions, enc_out,
            )
            aux_total = aux_total + a
            if want_cache:
                rem_caches[f"b{j}"] = c
        if want_cache:
            caches["rem"] = rem_caches
    return x, aux_total, caches


def _apply_stack_decode(stack, x, cfg, caches, pos):
    period, n_scan, rem = stack_pattern(cfg)

    def superblock_body(x, args):
        sb_params, sb_caches = args
        new_cs = {}
        for j in range(period):
            kind, use_moe = layer_desc(cfg, j)
            x, nc = _block_decode(sb_params[f"b{j}"], x, cfg, kind, use_moe, sb_caches[f"b{j}"], pos)
            new_cs[f"b{j}"] = nc
        return x, new_cs

    new_caches: dict = {}
    if n_scan:
        x, new_caches["scan"] = jax.lax.scan(superblock_body, x, (stack["scan"], caches["scan"]))
    if rem:
        new_caches["rem"] = {}
        for j in range(rem):
            kind, use_moe = layer_desc(cfg, n_scan * period + j)
            x, nc = _block_decode(
                stack["rem"][f"b{j}"], x, cfg, kind, use_moe, caches["rem"][f"b{j}"], pos
            )
            new_caches["rem"][f"b{j}"] = nc
    return x, new_caches


# ------------------------------------------------------------ full models


def init_decoder_lm(rng, cfg) -> dict:
    ks = jax.random.split(rng, 4)
    p = {
        "embed": init_embed(ks[0], cfg.vocab_size, cfg.d_model, cfg.dtype),
        "stack": init_stack(ks[1], cfg),
        "final_norm": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = init_embed(ks[2], cfg.vocab_size, cfg.d_model, cfg.dtype)
    if cfg.family == "encdec":
        import dataclasses

        enc_cfg = dataclasses.replace(
            cfg, n_layers=cfg.enc_layers, ssm_kind="", moe_experts=0,
            local_global_ratio=0, global_every=0, window=0,
        )
        p["encoder"] = {
            "stack": init_stack(ks[3], enc_cfg),
            "final_norm": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
        }
        p["stack"] = init_stack(ks[1], cfg, cross=True)
    return p


def _embed_inputs(params, tokens, cfg, prefix=None):
    x = embed_lookup(params["embed"], tokens) * math.sqrt(cfg.d_model)
    x = x.astype(cfg.dtype)
    if prefix is not None:
        # modality stub: precomputed frame/patch embeddings occupy the first
        # n_prefix positions (early fusion)
        npre = prefix.shape[-2]
        x = jnp.concatenate([prefix.astype(cfg.dtype), x[..., npre:, :]], axis=-2)
    return x


def _enc_cfg(cfg):
    import dataclasses

    return dataclasses.replace(
        cfg, n_layers=cfg.enc_layers, ssm_kind="", moe_experts=0, family="decoder",
        local_window=0, local_global_ratio=0, global_every=0, window=0,
        bidirectional=True, attn_every=1,
    )


def _encode(params, enc_inp, cfg):
    """Encoder forward.  ``enc_inp`` is either int token ids (B, S) or — for
    the audio modality stub — precomputed frame embeddings (B, S, d)."""
    enc_cfg = _enc_cfg(cfg)
    if jnp.issubdtype(enc_inp.dtype, jnp.floating):
        x = enc_inp.astype(cfg.dtype)
    else:
        x = _embed_inputs(params, enc_inp, cfg)
    positions = jnp.arange(x.shape[-2])
    x, _, _ = _apply_stack_train(params["encoder"]["stack"], x, enc_cfg, positions)
    return norm_apply(params["encoder"]["final_norm"], x, cfg.norm)


def decoder_hidden(params, tokens, cfg, *, prefix=None, enc_tokens=None, enc_frames=None):
    """(B,S) tokens → final hidden (B,S,d).  Runs encoder first for encdec."""
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(params, enc_frames if enc_frames is not None else enc_tokens, cfg)
    x = _embed_inputs(params, tokens, cfg, prefix)
    positions = jnp.arange(tokens.shape[-1])
    x, aux, _ = _apply_stack_train(params["stack"], x, cfg, positions, enc_out=enc_out)
    x = norm_apply(params["final_norm"], x, cfg.norm)
    return x, aux


def output_embedding(params, cfg) -> jax.Array:
    head = params["head"] if "head" in params else params["embed"]
    return head["embedding"]


def decoder_prefill(params, tokens, cfg, *, prefix=None, enc_tokens=None, enc_frames=None):
    """Full-sequence forward that also returns decode caches."""
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(params, enc_frames if enc_frames is not None else enc_tokens, cfg)
    x = _embed_inputs(params, tokens, cfg, prefix)
    positions = jnp.arange(tokens.shape[-1])
    x, aux, caches = _apply_stack_train(
        params["stack"], x, cfg, positions, enc_out=enc_out, want_cache=True
    )
    x = norm_apply(params["final_norm"], x, cfg.norm)
    return x, caches


def decoder_decode_step(params, tokens, cfg, caches, pos):
    """tokens: (B,1) new token ids; pos: scalar position.  → (logits, caches)."""
    x = _embed_inputs(params, tokens, cfg)
    x, new_caches = _apply_stack_decode(params["stack"], x, cfg, caches, pos)
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = x.astype(jnp.float32) @ output_embedding(params, cfg).T.astype(jnp.float32)
    return logits, new_caches


def init_decode_caches(params, cfg, batch: int, seq_len: int):
    """Zero caches shaped for a ``seq_len``-deep decode session."""
    period, n_scan, rem = stack_pattern(cfg)

    def one(kind: str) -> dict:
        if kind == "mamba":
            return ssm.mamba_init_state(cfg, batch)
        if kind == "rwkv6":
            return ssm.rwkv6_init_state(cfg, batch)
        c = attn.init_cache(cfg, kind, batch, seq_len, cfg.dtype)
        if cfg.family == "encdec":
            hd, Hkv = cfg.head_dim, cfg.n_kv_heads
            c["cross_k"] = jnp.zeros((batch, seq_len, Hkv, hd), cfg.dtype)
            c["cross_v"] = jnp.zeros((batch, seq_len, Hkv, hd), cfg.dtype)
        return c

    caches: dict = {}
    if n_scan:
        per = {f"b{j}": one(layer_desc(cfg, j)[0]) for j in range(period)}
        caches["scan"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_scan,) + x.shape), per
        )
    if rem:
        caches["rem"] = {
            f"b{j}": one(layer_desc(cfg, n_scan * period + j)[0]) for j in range(rem)
        }
    return caches
