"""State-space / linear-recurrence blocks: Mamba (jamba) and RWKV6 (finch).

Both are implemented as exact sequential recurrences via ``lax.scan`` in f32
state — the faithful baseline.  DESIGN.md §Perf notes the chunked-parallel
(GLA-style) reformulation as the TPU optimization target; the recurrence
here is the correctness oracle for it.

Decode is a single recurrence step carrying the state pytree, which is what
makes ``long_500k`` O(1) memory per token for these architectures.

Fidelity notes (recorded in DESIGN.md):
  * Mamba: ZOH discretization simplified to Ā=exp(ΔA), B̄=Δ·B (the common
    "Euler-B" simplification used by most reimplementations).
  * RWKV6: the five data-dependent token-shift LoRAs are reduced to static
    per-channel mixes except the decay ``w`` which keeps its LoRA
    (data-dependent decay is the defining Finch feature, arXiv:2404.05892).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense

# ===================================================================== Mamba


def mamba_dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return di, dt_rank, cfg.ssm_state


def init_mamba(rng, cfg) -> dict:
    d = cfg.d_model
    di, dt_rank, N = mamba_dims(cfg)
    ks = jax.random.split(rng, 6)
    dt = cfg.dtype
    p = {
        "in_proj": init_dense(ks[0], d, 2 * di, dtype=dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, 1, di), jnp.float32) * 0.2).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": init_dense(ks[2], di, dt_rank + 2 * N, dtype=dt),
        "dt_proj": init_dense(ks[3], dt_rank, di, bias=True, dtype=dt),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": init_dense(ks[4], di, d, dtype=dt),
    }
    return p


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  x: (B,S,di), w: (width,1,di)."""
    width = w.shape[0]
    di = x.shape[-1]
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1,),
        padding=[(width - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=di,
    )
    return (y + b.astype(jnp.float32)).astype(x.dtype)


def mamba_ssm_params(params, x_in, cfg):
    """Shared projection math.  x_in: (..., di) post-conv activations.

    Returns (dt, Bs, Cs, A): dt (..., di), Bs/Cs (..., N), A (di, N)."""
    di, dt_rank, N = mamba_dims(cfg)
    proj = dense(params["x_proj"], x_in).astype(jnp.float32)
    dt_in, Bs, Cs = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        dt_in @ params["dt_proj"]["w"].astype(jnp.float32)
        + params["dt_proj"]["b"].astype(jnp.float32)
    )
    A = -jnp.exp(params["A_log"])  # (di, N), negative
    return dt, Bs, Cs, A


def mamba_train(params, x, cfg):
    """x: (B,S,d) → (out, final_state (B,di,N), conv_tail (B,w−1,di)).

    ``conv_tail`` is the last w−1 PRE-conv activations — the exact conv
    state a subsequent decode step needs (prefill → decode continuity)."""
    B, S, d = x.shape
    di, dt_rank, N = mamba_dims(cfg)

    xz = dense(params["in_proj"], x)
    x_raw, z = jnp.split(xz, 2, axis=-1)
    w = cfg.ssm_conv
    if S >= w - 1:
        conv_tail = x_raw[:, S - (w - 1):, :].astype(jnp.float32)
    else:
        conv_tail = jnp.concatenate(
            [jnp.zeros((B, w - 1 - S, di), jnp.float32), x_raw.astype(jnp.float32)],
            axis=1,
        )
    x_in = jax.nn.silu(_causal_conv(x_raw, params["conv_w"], params["conv_b"]).astype(jnp.float32))

    dt, Bs, Cs, A = mamba_ssm_params(params, x_in.astype(x.dtype), cfg)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # (B,di), (B,di), (B,N), (B,N)
        a = jnp.exp(dtt[..., None] * A[None])  # (B,di,N)
        u = (dtt * xt)[..., None] * Bt[:, None, :]  # (B,di,N)
        h = a * h + u
        y = jnp.einsum("bdn,bn->bd", h, Ct)
        return h, y

    h0 = jnp.zeros((B, di, N), jnp.float32)
    xs = (
        x_in.transpose(1, 0, 2),
        dt.transpose(1, 0, 2),
        Bs.transpose(1, 0, 2),
        Cs.transpose(1, 0, 2),
    )
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + x_in * params["D"][None, None, :]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = dense(params["out_proj"], y.astype(x.dtype))
    return out, h_final, conv_tail


def mamba_init_state(cfg, batch: int) -> dict:
    di, _, N = mamba_dims(cfg)
    return {
        "h": jnp.zeros((batch, di, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), jnp.float32),
    }


def mamba_decode(params, x, cfg, state):
    """x: (B,1,d) one token.  state: {'h': (B,di,N), 'conv': (B,w-1,di)}."""
    B = x.shape[0]
    xz = dense(params["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)  # (B,1,di)

    # causal conv over the carried window
    win = jnp.concatenate([state["conv"], x_in.astype(jnp.float32)], axis=1)  # (B,w,di)
    w = params["conv_w"].astype(jnp.float32)  # (w,1,di)
    y = jnp.sum(win * w[:, 0, :][None], axis=1) + params["conv_b"].astype(jnp.float32)
    x_c = jax.nn.silu(y)[:, None, :]  # (B,1,di)

    dt, Bs, Cs, A = mamba_ssm_params(params, x_c.astype(x.dtype), cfg)
    dtt, Bt, Ct = dt[:, 0], Bs[:, 0], Cs[:, 0]
    a = jnp.exp(dtt[..., None] * A[None])
    u = (dtt * x_c[:, 0].astype(jnp.float32))[..., None] * Bt[:, None, :]
    h = a * state["h"] + u
    yt = jnp.einsum("bdn,bn->bd", h, Ct) + x_c[:, 0].astype(jnp.float32) * params["D"][None]
    yt = yt * jax.nn.silu(z[:, 0].astype(jnp.float32))
    out = dense(params["out_proj"], yt[:, None, :].astype(x.dtype))
    new_state = {"h": h, "conv": win[:, 1:]}
    return out, new_state


# ===================================================================== RWKV6

RWKV_HEAD = 64  # Finch head size


def rwkv_dims(cfg):
    H = cfg.d_model // RWKV_HEAD
    return H, RWKV_HEAD


def init_rwkv6(rng, cfg) -> dict:
    d = cfg.d_model
    H, hs = rwkv_dims(cfg)
    ks = jax.random.split(rng, 10)
    dt = cfg.dtype
    lora = 64
    return {
        # time-mix
        "mix": jnp.full((4, d), 0.5, jnp.float32),  # static shift mixes r,k,v,g
        "mix_w": jnp.full((d,), 0.5, jnp.float32),
        "wr": init_dense(ks[0], d, d, dtype=dt),
        "wk": init_dense(ks[1], d, d, dtype=dt),
        "wv": init_dense(ks[2], d, d, dtype=dt),
        "wg": init_dense(ks[3], d, d, dtype=dt),
        "w0": jnp.linspace(-6.0, -1.0, d, dtype=jnp.float32),  # base decay logits
        "w_lora_a": init_dense(ks[4], d, lora, dtype=dt),
        "w_lora_b": init_dense(ks[5], lora, d, dtype=dt),
        "bonus": jnp.zeros((H, hs), jnp.float32),  # u
        "ln_x": jnp.ones((d,), jnp.float32),  # per-head group-norm scale
        "wo": init_dense(ks[6], d, d, dtype=dt),
        # channel-mix
        "cmix_k": jnp.full((d,), 0.5, jnp.float32),
        "cmix_r": jnp.full((d,), 0.5, jnp.float32),
        "ck": init_dense(ks[7], d, cfg.d_ff, dtype=dt),
        "cv": init_dense(ks[8], cfg.d_ff, d, dtype=dt),
        "cr": init_dense(ks[9], d, d, dtype=dt),
    }


def _shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """Token shift: x_{t-1} with ``prev`` as the t=0 predecessor.

    x: (B,S,d); prev: (B,1,d)."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _rwkv_projections(params, x, xprev, cfg):
    """Compute r,k,v,g,w for a (B,S,d) slab given shifted predecessors."""
    mix = params["mix"]

    def lerp(i):
        m = mix[i][None, None].astype(jnp.float32)
        return (x.astype(jnp.float32) * m + xprev.astype(jnp.float32) * (1 - m)).astype(x.dtype)

    r = dense(params["wr"], lerp(0))
    k = dense(params["wk"], lerp(1))
    v = dense(params["wv"], lerp(2))
    g = dense(params["wg"], lerp(3))
    mw = params["mix_w"][None, None].astype(jnp.float32)
    xw = (x.astype(jnp.float32) * mw + xprev.astype(jnp.float32) * (1 - mw)).astype(x.dtype)
    # data-dependent decay (the Finch contribution): w = exp(-exp(w0 + lora))
    lora = dense(params["w_lora_b"], jnp.tanh(dense(params["w_lora_a"], xw).astype(jnp.float32)).astype(x.dtype))
    wlog = params["w0"][None, None] + lora.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wlog))  # (B,S,d) in (0,1)
    return r, k, v, g, w


def _heads(x, H, hs):
    return x.reshape(x.shape[:-1] + (H, hs))


def rwkv6_time_mix(params, x, cfg, state_s, prev_tok):
    """x: (B,S,d).  state_s: (B,H,hs,hs) wkv state; prev_tok: (B,1,d).

    Returns (out, new_state_s, new_prev_tok)."""
    B, S, d = x.shape
    H, hs = rwkv_dims(cfg)
    xprev = _shift(x, prev_tok)
    r, k, v, g, w = _rwkv_projections(params, x, xprev, cfg)
    rh = _heads(r.astype(jnp.float32), H, hs)
    kh = _heads(k.astype(jnp.float32), H, hs)
    vh = _heads(v.astype(jnp.float32), H, hs)
    wh = _heads(w, H, hs)
    u = params["bonus"][None]  # (1,H,hs)

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,hs) each
        # o_j = Σ_i r_i s_ij + (Σ_i r_i u_i k_i) v_j
        o = jnp.einsum("bhi,bhij->bhj", rt, s) + jnp.einsum(
            "bhi,bhi->bh", rt, u * kt
        )[..., None] * vt
        s = wt[..., None] * s + kt[..., None] * vt[..., None, :]
        return s, o

    xs = (
        rh.transpose(1, 0, 2, 3),
        kh.transpose(1, 0, 2, 3),
        vh.transpose(1, 0, 2, 3),
        wh.transpose(1, 0, 2, 3),
    )
    s_final, os = jax.lax.scan(step, state_s, xs)
    o = os.transpose(1, 0, 2, 3).reshape(B, S, d)  # (B,S,d) f32

    # per-head group norm, then gate
    oh = o.reshape(B, S, H, hs)
    oh = oh * jax.lax.rsqrt(jnp.mean(jnp.square(oh), axis=-1, keepdims=True) + 1e-6)
    o = oh.reshape(B, S, d) * params["ln_x"][None, None]
    o = o * jax.nn.silu(g.astype(jnp.float32))
    out = dense(params["wo"], o.astype(x.dtype))
    return out, s_final, x[:, -1:, :]


def rwkv6_channel_mix(params, x, cfg, prev_tok):
    """RWKV ffn with token shift.  Returns (out, new_prev_tok)."""
    xprev = _shift(x, prev_tok)
    mk = params["cmix_k"][None, None].astype(jnp.float32)
    mr = params["cmix_r"][None, None].astype(jnp.float32)
    xk = (x.astype(jnp.float32) * mk + xprev.astype(jnp.float32) * (1 - mk)).astype(x.dtype)
    xr = (x.astype(jnp.float32) * mr + xprev.astype(jnp.float32) * (1 - mr)).astype(x.dtype)
    k = dense(params["ck"], xk).astype(jnp.float32)
    k = jnp.square(jax.nn.relu(k)).astype(x.dtype)
    r = jax.nn.sigmoid(dense(params["cr"], xr).astype(jnp.float32))
    out = r * dense(params["cv"], k).astype(jnp.float32)
    return out.astype(x.dtype), x[:, -1:, :]


def rwkv6_init_state(cfg, batch: int) -> dict:
    H, hs = rwkv_dims(cfg)
    return {
        "s": jnp.zeros((batch, H, hs, hs), jnp.float32),
        "tm_prev": jnp.zeros((batch, 1, cfg.d_model), cfg.dtype),
        "cm_prev": jnp.zeros((batch, 1, cfg.d_model), cfg.dtype),
    }
