"""Losses.  ``chunked_softmax_xent`` never materializes (B, S, V) logits —
essential for the 150k-262k vocab architectures at seq 4k-32k, where full
logits would be terabytes (DESIGN.md §7).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy.  logits (..., V), labels (...) int."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    return jnp.mean(lse - gold)


def chunked_softmax_xent(
    hidden: jax.Array,
    embedding: jax.Array,
    labels: jax.Array,
    *,
    chunk: int = 512,
) -> jax.Array:
    """Cross-entropy from final hidden states and (V, d) output embedding.

    hidden: (B, S, d); labels: (B, S).  Scans over S in ``chunk``-sized
    slabs with remat, so peak logit memory is (B, chunk, V).
    """
    B, S, d = hidden.shape
    if S % chunk != 0:
        chunk = S  # small sequences: single slab
    n = S // chunk
    h = hidden.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    y = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def slab(carry, xs):
        hc, yc = xs
        logits = hc.astype(jnp.float32) @ embedding.T.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(slab, jnp.zeros((), jnp.float32), (h, y))
    return total / (B * S)
