"""Activation-sharding hints (GSPMD ``with_sharding_constraint`` wrappers).

The launch layer installs an ambient (mesh, batch_axes, seq_axis) context;
model code calls :func:`act` on the residual stream between blocks.  With a
seq_axis this is *sequence parallelism*: checkpointed activations shard over
the model axis between layers (16× less live activation memory at 4k-32k
sequence lengths), at the cost of an all-gather feeding each attention/ssm
block — GSPMD inserts those automatically.

On CPU tests no context is installed and every hint is a no-op.
"""
from __future__ import annotations

import contextlib
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_CTX: dict[str, Any] = {"mesh": None, "batch": None, "seq": None, "expert": None,
                        "seq_every": 1, "_block": 0, "lean_moe": False}


def lean_moe() -> bool:
    """§Perf: bf16 MoE combine + capacity factor 1.0 (set by launch opts)."""
    return bool(_CTX["lean_moe"])


@contextlib.contextmanager
def activation_sharding(mesh, *, batch_axes=None, seq_axis: Optional[str] = "model",
                        expert_axis: Optional[str] = None, seq_every: int = 1,
                        lean_moe: bool = False):
    """Install hints for the duration of a trace.

    batch_axes  shards the leading batch dim of residual-stream activations
    seq_axis    ('model') sequence parallelism between blocks
    expert_axis ('data')  MoE expert parallelism: dispatch buffers align
                their expert dim with the expert-sharded weights (§Perf)
    seq_every   apply the sequence hint only on every k-th block (trades
                all-gather count against live activation memory — §Perf)
    """
    old = dict(_CTX)
    _CTX.update(mesh=mesh, batch=batch_axes, seq=seq_axis, expert=expert_axis,
                seq_every=max(1, seq_every), _block=0, lean_moe=lean_moe)
    try:
        yield
    finally:
        _CTX.update(old)


def _fits(mesh, axes, dim) -> bool:
    if not axes:
        return False
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        total *= sizes.get(a, 1)
    return dim % total == 0


def act(x: jax.Array) -> jax.Array:
    """Hint for a (B, S, d) residual-stream activation (between blocks)."""
    mesh = _CTX["mesh"]
    if mesh is None or x.ndim < 3:
        return x
    blk = _CTX["_block"]
    _CTX["_block"] = blk + 1
    if blk % _CTX["seq_every"] != 0:
        return x
    b_ax = _CTX["batch"] if _fits(mesh, _CTX["batch"], x.shape[0]) else None
    s_ax = _CTX["seq"] if x.shape[1] > 1 and _fits(mesh, _CTX["seq"], x.shape[1]) else None
    if b_ax is None and s_ax is None:
        return x
    spec = P(b_ax, s_ax, *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def expert_mode(n_experts: int) -> str:
    """'ep' when experts divide the expert axis (flat dispatch + expert
    parallelism — llama4/jamba), 'group' otherwise (grouped per-row
    dispatch — mixtral) or when no launch context is installed."""
    mesh = _CTX["mesh"]
    ax = _CTX["expert"]
    if mesh is None or ax is None:
        return "group"
    return "ep" if _fits(mesh, ax, n_experts) else "group"


def expert_flat(x: jax.Array) -> jax.Array:
    """Hint for a flat-dispatch (E, C, d) buffer: experts over the expert
    axis (weights stay local; dispatch reshard lowers as a2a)."""
    mesh = _CTX["mesh"]
    ax = _CTX["expert"]
    if mesh is None or ax is None or not _fits(mesh, ax, x.shape[0]):
        return x
    spec = P(ax, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def expert_grouped(x: jax.Array) -> jax.Array:
    """Hint for a grouped-dispatch buffer (B, E, C, d): the GROUP dim
    shards over the batch axes — compute stays where the tokens are and
    the data-replicated, model-sharded expert weights broadcast."""
    mesh = _CTX["mesh"]
    b_ax = _CTX["batch"]
    if mesh is None or not _fits(mesh, b_ax, x.shape[0]):
        return x
    lead = b_ax if not isinstance(b_ax, tuple) or len(b_ax) > 1 else b_ax[0]
    spec = P(lead, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
