"""CNN classifiers — the paper's LeNet5-Caffe (MNIST) and a ResNet-32
CIFAR-style residual network (He et al. '16: 3 stages × 5 basic blocks,
widths 16/32/64).

BatchNorm uses batch statistics in both train and eval (no running-stat
state) — adequate at reproduction scale and keeps everything functional;
noted in DESIGN.md §8.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _conv_init(rng, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return jax.random.normal(rng, (kh, kw, cin, cout), jnp.float32) * math.sqrt(2.0 / fan_in)


def conv(p, x, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, p, (stride, stride), padding, dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def batchnorm(p, x, eps=1e-5):
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


def _bn_init(c):
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


# ------------------------------------------------------------------- LeNet5


def init_lenet5(rng, cfg) -> dict:
    ks = jax.random.split(rng, 4)
    return {
        "c1": _conv_init(ks[0], 5, 5, cfg.img_channels, 20),
        "c2": _conv_init(ks[1], 5, 5, 20, 50),
        "f1": jax.random.normal(ks[2], ((cfg.img_size // 4) ** 2 * 50, 500), jnp.float32)
        * math.sqrt(2.0 / ((cfg.img_size // 4) ** 2 * 50)),
        "f1b": jnp.zeros((500,), jnp.float32),
        "f2": jax.random.normal(ks[3], (500, cfg.n_classes), jnp.float32) * math.sqrt(2.0 / 500),
        "f2b": jnp.zeros((cfg.n_classes,), jnp.float32),
    }


def lenet5_apply(params, images, cfg):
    x = conv(params["c1"], images)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = conv(params["c2"], x)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["f1"] + params["f1b"])
    return x @ params["f2"] + params["f2b"]


# ------------------------------------------------------------------ ResNet32


def init_resnet32(rng, cfg, blocks_per_stage: int = 5, widths=(16, 32, 64)) -> dict:
    ks = iter(jax.random.split(rng, 3 * blocks_per_stage * 3 + 8))
    p = {"stem": _conv_init(next(ks), 3, 3, cfg.img_channels, widths[0]), "stem_bn": _bn_init(widths[0])}
    cin = widths[0]
    for s, w in enumerate(widths):
        for b in range(blocks_per_stage):
            blk = {
                "c1": _conv_init(next(ks), 3, 3, cin, w),
                "bn1": _bn_init(w),
                "c2": _conv_init(next(ks), 3, 3, w, w),
                "bn2": _bn_init(w),
            }
            if cin != w:
                blk["proj"] = _conv_init(next(ks), 1, 1, cin, w)
            p[f"s{s}b{b}"] = blk
            cin = w
    p["head"] = jax.random.normal(next(ks), (widths[-1], cfg.n_classes), jnp.float32) * math.sqrt(
        2.0 / widths[-1]
    )
    p["head_b"] = jnp.zeros((cfg.n_classes,), jnp.float32)
    return p


def resnet32_apply(params, images, cfg, blocks_per_stage: int = 5, widths=(16, 32, 64)):
    x = jax.nn.relu(batchnorm(params["stem_bn"], conv(params["stem"], images)))
    for s, w in enumerate(widths):
        for b in range(blocks_per_stage):
            blk = params[f"s{s}b{b}"]
            stride = 2 if (s > 0 and b == 0) else 1
            h = jax.nn.relu(batchnorm(blk["bn1"], conv(blk["c1"], x, stride)))
            h = batchnorm(blk["bn2"], conv(blk["c2"], h))
            sc = x if "proj" not in blk else conv(blk["proj"], x, stride)
            x = jax.nn.relu(h + sc)
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["head"] + params["head_b"]
