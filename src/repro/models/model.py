"""build_model(cfg) → Model: init/loss/prefill/decode + sharding specs.

Sharding is path-rule based (Megatron-style TP over 'model', optional FSDP
over 'data' for ≥20B configs).  Rules silently fall back to replication when
a dimension doesn't divide the mesh axis (e.g. seamless' 256206 vocab), so
every config lowers on every mesh.  Leaves smaller than 1 MiB replicate.
"""
from __future__ import annotations

import re
from typing import Any, Callable, NamedTuple, Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import cnn, lstm, transformer
from repro.models.losses import chunked_softmax_xent, softmax_xent

PyTree = Any


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[[jax.Array], PyTree]
    loss_fn: Callable[[PyTree, dict], jax.Array]  # batch → scalar loss
    prefill: Optional[Callable]  # (params, batch) → (hidden, caches)
    decode_step: Optional[Callable]  # (params, tokens, caches, pos) → (logits, caches)
    init_caches: Optional[Callable]  # (params, batch, seq_len) → caches
    param_specs: Callable[[PyTree, Any], PyTree]  # (params, mesh) → specs


# -------------------------------------------------------------- spec rules
# (regex over '/'-joined path, spec per dimension). '+data' marks the dim
# that additionally shards over 'data' in FSDP mode.

_RULES: list[tuple[str, tuple[Optional[str], ...], Optional[int]]] = [
    # pattern, per-dim axes, fsdp_dim (index that gains 'data')
    (r"embedding$", ("model", None), 1),
    (r"(wq|wk|wv|wg|wr)/w$", (None, "model"), 0),
    (r"(wq|wk|wv|wg|wr)/b$", ("model",), None),
    (r"wo/w$", ("model", None), 1),
    (r"(up|gate)/w$", (None, "model"), 0),
    (r"down/w$", ("model", None), 1),
    (r"moe/router$", (None, None), None),
    (r"moe/(up|gate)$", (None, None, "model"), 1),
    (r"moe/down$", (None, "model", None), 2),
]

# §Perf expert-parallel variant: experts shard over 'data' (weights never
# all-gather; dispatch buffers follow via hints.expert) and the contraction
# dims stay UNSHARDED over 'data' — kills the partial-sum all-reduce the
# baseline fsdp rules induce.  Falls back to the baseline rule when E does
# not divide the data axis (mixtral's 8 experts on a 16-way axis).
_EP_RULES: list[tuple[str, tuple, Optional[int]]] = [
    (r"moe/(up|gate)$", ("data", None, "model"), None),
    (r"moe/down$", ("data", "model", None), None),
    (r"in_proj/w$", (None, "model"), 0),
    (r"conv_w$", (None, None, "model"), None),
    (r"(conv_b|D)$", ("model",), None),
    (r"x_proj/w$", ("model", None), None),
    (r"dt_proj/w$", (None, "model"), None),
    (r"dt_proj/b$", ("model",), None),
    (r"A_log$", ("model", None), None),
    (r"out_proj/w$", ("model", None), 1),
    (r"(ck|cr)/w$", (None, "model"), 0),
    (r"cv/w$", ("model", None), 1),
    (r"(w0|ln_x|cmix_k|cmix_r|mix_w)$", ("model",), None),
]

_MIN_SHARD_BYTES = 1 << 20


def _spec_for(path: str, leaf: jax.Array, mesh, fsdp: bool, scan_prefix: bool,
              expert_parallel: bool = False) -> P:
    if leaf.size * leaf.dtype.itemsize < _MIN_SHARD_BYTES:
        return P()
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    rules = (_EP_RULES + _RULES) if expert_parallel else _RULES

    for pat, axes, fsdp_dim in rules:
        if re.search(pat, path):
            # scanned stacks have a leading superblock dim → shift right
            offset = 1 if scan_prefix else 0
            ndim = leaf.ndim
            dims: list[Any] = [None] * ndim
            for i, ax in enumerate(axes):
                j = i + offset
                if ax is None or j >= ndim:
                    continue
                if leaf.shape[j] % axis_size.get(ax, 1) == 0:
                    dims[j] = ax
            # NOTE (§Perf A2 lesson): when the expert dim does not divide
            # 'data' (mixtral: 8/16), EP keeps MoE weights data-replicated;
            # that is only safe because hints.expert() then shards the
            # dispatch CAPACITY dim over 'data' — without that constraint
            # XLA replicates the expert compute (10× flops).
            if expert_parallel and "data" in dims:
                fsdp_dim = None  # expert dim already consumed the data axis
            if fsdp and fsdp_dim is not None and "data" not in dims:
                j = fsdp_dim + offset
                if j < ndim and dims[j] is None:
                    need = axis_size.get("data", 1)
                    if leaf.shape[j] % need == 0:
                        dims[j] = "data"
            return P(*dims)
    return P()


def make_param_specs(params: PyTree, mesh, *, fsdp: bool = False,
                     expert_parallel: bool = False) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(
            k.key if hasattr(k, "key") else str(k) for k in path
        )
        scan_prefix = "stack/scan" in pstr or pstr.startswith("scan")
        specs.append(_spec_for(pstr, leaf, mesh, fsdp, scan_prefix, expert_parallel))
    return jax.tree_util.tree_unflatten(treedef, specs)


# --------------------------------------------------------------- builders


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "cnn":
        return _build_cnn(cfg)
    if cfg.family == "lstm":
        return _build_lstm(cfg)
    return _build_transformer(cfg)


AUX_WEIGHT = 0.01  # MoE load-balance loss coefficient


def _build_transformer(cfg: ModelConfig) -> Model:
    def init(rng):
        return transformer.init_decoder_lm(rng, cfg)

    def _kwargs(batch):
        return {k: batch[k] for k in ("prefix", "enc_tokens", "enc_frames") if k in batch}

    def loss_fn(params, batch):
        hidden, aux = transformer.decoder_hidden(params, batch["tokens"], cfg, **_kwargs(batch))
        emb = transformer.output_embedding(params, cfg)
        loss = chunked_softmax_xent(hidden, emb, batch["labels"])
        return loss + AUX_WEIGHT * aux

    def prefill(params, batch):
        return transformer.decoder_prefill(params, batch["tokens"], cfg, **_kwargs(batch))

    def decode_step(params, tokens, caches, pos):
        return transformer.decoder_decode_step(params, tokens, cfg, caches, pos)

    def init_caches(params, batch, seq_len):
        return transformer.init_decode_caches(params, cfg, batch, seq_len)

    def param_specs(params, mesh):
        return make_param_specs(
            params, mesh, fsdp=cfg.fsdp,
            expert_parallel=cfg.moe_dispatch in ("flat_ep", "grouped"),
        )

    return Model(cfg, init, loss_fn, prefill, decode_step, init_caches, param_specs)


def _build_lstm(cfg: ModelConfig) -> Model:
    def init(rng):
        return lstm.init_lstm_lm(rng, cfg)

    def loss_fn(params, batch):
        logits = lstm.lstm_lm_apply(params, batch["tokens"], cfg)
        return softmax_xent(logits, batch["labels"])

    def param_specs(params, mesh):
        return jax.tree.map(lambda _: P(), params)

    return Model(cfg, init, loss_fn, None, None, None, param_specs)


def _build_cnn(cfg: ModelConfig) -> Model:
    is_lenet = cfg.name == "lenet5"

    def init(rng):
        return cnn.init_lenet5(rng, cfg) if is_lenet else cnn.init_resnet32(rng, cfg)

    def loss_fn(params, batch):
        apply = cnn.lenet5_apply if is_lenet else cnn.resnet32_apply
        logits = apply(params, batch["images"], cfg)
        return softmax_xent(logits, batch["labels"])

    def param_specs(params, mesh):
        return jax.tree.map(lambda _: P(), params)

    return Model(cfg, init, loss_fn, None, None, None, param_specs)
