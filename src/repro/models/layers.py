"""Shared building blocks (functional, no framework dependency).

Params are nested dicts of jnp arrays.  Every ``init_*`` returns a dict,
every ``*_apply`` is a pure function.  Compute-sensitive reductions (norms,
softmax) run in f32 regardless of the param dtype.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def init_dense(rng, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.bfloat16,
               scale: Optional[float] = None) -> dict:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_norm(d: int, kind: str = "rmsnorm", dtype=jnp.bfloat16) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p: dict, x: jax.Array, kind: str = "rmsnorm", eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def init_embed(rng, vocab: int, d: int, dtype=jnp.bfloat16) -> dict:
    e = jax.random.normal(rng, (vocab, d), jnp.float32) * (1.0 / math.sqrt(d))
    return {"embedding": e.astype(dtype)}


def embed_lookup(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


# ----------------------------------------------------------------- rotary


def rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """Rotary embedding.  x: (..., seq, heads, head_dim); positions: (seq,)
    or broadcastable to x's seq dim."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# -------------------------------------------------------------------- MLP


def init_mlp(rng, d: int, ff: int, *, gated: bool = True, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(rng, 3)
    p = {"up": init_dense(ks[0], d, ff, dtype=dtype), "down": init_dense(ks[1], ff, d, dtype=dtype)}
    if gated:
        p["gate"] = init_dense(ks[2], d, ff, dtype=dtype)
    return p


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    h = dense(p["up"], x)
    if "gate" in p:
        h = jax.nn.silu(dense(p["gate"], x)) * h
    else:
        h = jax.nn.gelu(h)
    return dense(p["down"], h)
