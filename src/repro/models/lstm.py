"""Multi-layer LSTM language models — the paper's WordLSTM / CharLSTM
(Zaremba et al. '14 "medium" style: embedding → n-layer LSTM → tied head).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import init_embed


def init_lstm_cell(rng, d_in: int, d_hidden: int, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(rng)
    s = 1.0 / math.sqrt(d_hidden)
    return {
        "wx": (jax.random.normal(k1, (d_in, 4 * d_hidden), jnp.float32) * s).astype(dtype),
        "wh": (jax.random.normal(k2, (d_hidden, 4 * d_hidden), jnp.float32) * s).astype(dtype),
        "b": jnp.zeros((4 * d_hidden,), dtype),
    }


def lstm_cell(p: dict, x: jax.Array, h: jax.Array, c: jax.Array):
    gates = (x @ p["wx"] + h @ p["wh"] + p["b"]).astype(jnp.float32)
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h.astype(x.dtype), c


def init_lstm_lm(rng, cfg) -> dict:
    ks = jax.random.split(rng, cfg.n_layers + 2)
    d = cfg.lstm_hidden
    p = {"embed": init_embed(ks[0], cfg.vocab_size, d, dtype=jnp.float32)}
    for i in range(cfg.n_layers):
        p[f"cell{i}"] = init_lstm_cell(ks[i + 1], d, d)
    p["head"] = {
        "w": (jax.random.normal(ks[-1], (d, cfg.vocab_size), jnp.float32) / math.sqrt(d))
    }
    return p


def lstm_lm_apply(params: dict, tokens: jax.Array, cfg) -> jax.Array:
    """tokens: (B, S) → logits (B, S, V)."""
    B, S = tokens.shape
    d = cfg.lstm_hidden
    x = jnp.take(params["embed"]["embedding"], tokens, axis=0)  # (B,S,d)

    def step(carry, xt):
        hs, cs = carry
        new_h, new_c = [], []
        inp = xt
        for i in range(cfg.n_layers):
            h, c = lstm_cell(params[f"cell{i}"], inp, hs[i], cs[i])
            new_h.append(h)
            new_c.append(c)
            inp = h
        return (tuple(new_h), tuple(new_c)), inp

    h0 = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(cfg.n_layers))
    c0 = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(cfg.n_layers))
    _, hs = jax.lax.scan(step, (h0, c0), x.transpose(1, 0, 2))
    out = hs.transpose(1, 0, 2)  # (B,S,d)
    return out @ params["head"]["w"]
