"""Attention: MHA/GQA/MQA, causal / sliding-window / chunked-local / cross.

Three entry points:
  * ``attn_train``   — full-sequence training/prefill forward (optionally
                       returning a decode cache), q-chunked flash-style scan
                       so scores never materialize at (S, S).
  * ``attn_decode``  — one-token step against a cache.
  * ``init_cache``   — per-layer cache pytree (k, v, pos).

GQA is computed in grouped form (no repeat of KV heads), so a 1-kv-head
model (granite, gemma3) never materializes H-sized KV tensors.

Attention kinds (cfg.layer_kinds):
  attn         full causal
  attn_window  sliding window of cfg.window
  attn_local   sliding window of cfg.local_window (gemma3 local layers)
  attn_chunk   chunked-local of cfg.chunk_attn (llama4): tokens attend only
               within their chunk
  cross        full bidirectional over encoder memory
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense, rope

NEG_INF = -1e30


def window_for(kind: str, cfg) -> int:
    if kind == "attn_window":
        return cfg.window
    if kind == "attn_local":
        return cfg.local_window or cfg.window
    return 0


def _round128(n: int) -> int:
    return ((n + 127) // 128) * 128


def cache_len_for(kind: str, cfg, seq_len: int, margin: int = 8) -> int:
    """Decode-cache depth for a layer of this kind.

    Rounded up to a multiple of 128 so the cache sequence dim stays
    shardable over the 16-way model axis (DESIGN.md §4).
    """
    if kind in ("attn_window", "attn_local"):
        return min(window_for(kind, cfg), _round128(seq_len + margin))
    if kind == "attn_chunk":
        return min(cfg.chunk_attn, _round128(seq_len + margin))
    return _round128(seq_len + margin)  # full / global


def init_attention(rng, cfg, *, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    ks = jax.random.split(rng, 4)
    bias = cfg.qkv_bias
    return {
        "wq": init_dense(ks[0], d, nq, bias=bias, dtype=cfg.dtype),
        "wk": init_dense(ks[1], d, nkv, bias=bias, dtype=cfg.dtype),
        "wv": init_dense(ks[2], d, nkv, bias=bias, dtype=cfg.dtype),
        "wo": init_dense(ks[3], nq, d, dtype=cfg.dtype),
    }


def _split_heads(x: jax.Array, n_heads: int, hd: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n_heads, hd))


def _gqa_scores(q, k):
    """q: (B,Sq,Hkv,G,hd)  k: (B,Sk,Hkv,hd) → (B,Hkv,G,Sq,Sk) f32."""
    return jnp.einsum(
        "bqhgd,bkhd->bhgqk", q.astype(jnp.float32), k.astype(jnp.float32)
    )


def _gqa_out(probs, v):
    """probs: (B,Hkv,G,Sq,Sk)  v: (B,Sk,Hkv,hd) → (B,Sq,Hkv,G,hd)."""
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))


def _masked_attention(q, k, v, mask, scale):
    """Grouped attention core.  mask broadcastable to (B,1,1,Sq,Sk)."""
    scores = _gqa_scores(q, k) * scale
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows (e.g. cache slots empty) produce uniform probs over
    # NEG_INF; zero them so they contribute nothing.
    probs = jnp.where(jnp.any(mask, axis=-1, keepdims=True), probs, 0.0)
    return _gqa_out(probs, v)


def attn_train(
    params: dict,
    x: jax.Array,
    cfg,
    kind: str,
    *,
    positions: Optional[jax.Array] = None,
    kv_x: Optional[jax.Array] = None,
    q_chunk: int = 0,
    return_cache_seq: bool = False,
):
    """Full-sequence attention.  x: (B, S, d).

    kv_x: encoder memory for cross-attention (no causal mask, no RoPE
    relative semantics issues — positions of memory used directly).
    Returns (out, (k, v)) — roped K/V returned when return_cache_seq so the
    serving engine can build a decode cache from prefill.
    """
    B, S, _ = x.shape
    hd, Hkv = cfg.head_dim, cfg.n_kv_heads
    G = cfg.n_heads // Hkv
    scale = 1.0 / math.sqrt(hd)
    cross = kind == "cross"
    causal = kind not in ("cross", "attn_bidir")

    if positions is None:
        positions = jnp.arange(S)

    q = _split_heads(dense(params["wq"], x), cfg.n_heads, hd)
    src = kv_x if cross else x
    Sk = src.shape[1]
    if q_chunk == 0:
        # bound the (B, H, q_chunk, Sk) f32 score tile; the chunk body is
        # rematerialized (checkpointed) below so only ~2 tiles are ever
        # live — without that remat the scan saves EVERY chunk's probs for
        # backward, i.e. the full (B,H,S,Sk) matrix (§Perf iteration B6/B7)
        q_chunk = max(128, min(1024, (1 << 22) // max(Sk, 1)))
    k = _split_heads(dense(params["wk"], src), Hkv, hd)
    v = _split_heads(dense(params["wv"], src), Hkv, hd)

    if not cross:
        kv_positions = positions if src is x else jnp.arange(Sk)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    q = q.reshape(B, S, Hkv, G, hd)

    window = window_for(kind, cfg)
    chunk = cfg.chunk_attn if kind == "attn_chunk" else 0

    def mask_fn(qi: jax.Array, kj: jax.Array) -> jax.Array:
        """qi: (Sq,) global query positions; kj: (Sk,) key positions."""
        m = jnp.ones((qi.shape[0], kj.shape[0]), bool)
        if causal:
            m &= kj[None, :] <= qi[:, None]
        if window:
            m &= kj[None, :] > qi[:, None] - window
        if chunk:
            m &= (kj[None, :] // chunk) == (qi[:, None] // chunk)
        m &= kj[None, :] >= 0
        return m

    if S <= q_chunk:
        mask = mask_fn(positions, positions if not cross else jnp.arange(Sk))
        out = _masked_attention(q, k, v, mask[None, None, None], scale)
    else:
        n_chunks = S // q_chunk
        assert S % q_chunk == 0, f"seq {S} not divisible by q_chunk {q_chunk}"
        qc = q.reshape(B, n_chunks, q_chunk, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
        kj = positions if not cross else jnp.arange(Sk)

        @jax.checkpoint  # recompute scores/probs per chunk in backward
        def chunk_attn(qch, i):
            qi = positions[0] + i * q_chunk + jnp.arange(q_chunk)
            mask = mask_fn(qi, kj)
            return _masked_attention(qch, k, v, mask[None, None, None], scale)

        def body(carry, args):
            i, qch = args
            return carry, chunk_attn(qch, i)

        _, outs = jax.lax.scan(body, (), (jnp.arange(n_chunks), qc))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, Hkv, G, hd)

    out = out.reshape(B, S, cfg.n_heads * hd).astype(x.dtype)
    out = dense(params["wo"], out)
    return (out, (k, v)) if return_cache_seq else (out, None)


# ------------------------------------------------------------------ decode


def init_cache(cfg, kind: str, batch: int, seq_len: int, dtype) -> dict:
    L = cache_len_for(kind, cfg, seq_len)
    hd, Hkv = cfg.head_dim, cfg.n_kv_heads
    return {
        "k": jnp.zeros((batch, L, Hkv, hd), dtype),
        "v": jnp.zeros((batch, L, Hkv, hd), dtype),
        "pos": jnp.full((L,), -1, jnp.int32),
    }


def cache_slot(kind: str, cfg, pos: jax.Array) -> jax.Array:
    window = window_for(kind, cfg)
    if window:
        return pos % window
    if kind == "attn_chunk":
        return pos % cfg.chunk_attn
    return pos


def fill_cache_from_prefill(cache: dict, kind: str, cfg, k: jax.Array, v: jax.Array) -> dict:
    """Scatter prefill K/V (already roped) into the rolling decode cache."""
    S = k.shape[1]
    pos = jnp.arange(S)
    slots = cache_slot(kind, cfg, pos)
    # later positions overwrite earlier ones in rolling buffers: scatter in
    # increasing position order (jnp scatter applies updates in order).
    new_k = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
    new_v = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
    new_pos = cache["pos"].at[slots].set(pos.astype(jnp.int32))
    return {"k": new_k, "v": new_v, "pos": new_pos}


def attn_decode(
    params: dict,
    x: jax.Array,
    cfg,
    kind: str,
    cache: dict,
    pos: jax.Array,
    *,
    cross_memory: Optional[tuple[jax.Array, jax.Array]] = None,
):
    """One-token attention.  x: (B, 1, d); pos: scalar current position.

    Returns (out (B,1,d), new_cache).  For kind == 'cross', ``cross_memory``
    is the (k, v) of the encoder output and the cache is untouched.
    """
    B = x.shape[0]
    hd, Hkv = cfg.head_dim, cfg.n_kv_heads
    G = cfg.n_heads // Hkv
    scale = 1.0 / math.sqrt(hd)

    q = _split_heads(dense(params["wq"], x), cfg.n_heads, hd)

    if kind == "cross":
        k, v = cross_memory
        mask = jnp.ones((1, k.shape[1]), bool)
        q = q.reshape(B, 1, Hkv, G, hd)
        out = _masked_attention(q, k, v, mask[None, None, None], scale)
        out = dense(params["wo"], out.reshape(B, 1, cfg.n_heads * hd).astype(x.dtype))
        return out, cache

    q = rope(q, pos[None], cfg.rope_theta).reshape(B, 1, Hkv, G, hd)
    k_new = rope(_split_heads(dense(params["wk"], x), Hkv, hd), pos[None], cfg.rope_theta)
    v_new = _split_heads(dense(params["wv"], x), Hkv, hd)

    slot = cache_slot(kind, cfg, pos)
    new_cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, 1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, 1),
        "pos": jax.lax.dynamic_update_slice_in_dim(cache["pos"], pos[None].astype(jnp.int32), slot, 0),
    }

    cpos = new_cache["pos"]
    valid = (cpos >= 0) & (cpos <= pos)
    window = window_for(kind, cfg)
    if window:
        valid &= cpos > pos - window
    if kind == "attn_chunk":
        valid &= cpos >= (pos // cfg.chunk_attn) * cfg.chunk_attn

    out = _masked_attention(
        q, new_cache["k"], new_cache["v"], valid[None, None, None, None, :], scale
    )
    out = dense(params["wo"], out.reshape(B, 1, cfg.n_heads * hd).astype(x.dtype))
    return out, new_cache
