"""Mixture-of-Experts MLP: top-k router + capacity-based gather dispatch.

Dispatch strategy (TPU/GSPMD-friendly, no ragged shapes):

  1. router logits → top-k experts per token, renormalized gates
  2. position-in-expert via cumulative one-hot counts; tokens beyond the
     per-expert capacity ``C = ceil(T·k/E · capacity_factor)`` are DROPPED
     (their gate contribution is zero — residual stream passes through)
  3. a (E, C) token-index buffer gathers tokens into (E, C, d), experts run
     as one batched einsum against stacked weights (E, d, ff), and results
     scatter-add back weighted by gates.

Expert weights are stacked on a leading E axis so expert parallelism is a
PartitionSpec away (llama4: E sharded over 'data'; mixtral: ff sharded over
('data','model')).  The aux load-balance loss is the standard
Shazeer/Switch form the MoE sources use.
"""
from __future__ import annotations

import math
import jax
import jax.numpy as jnp

from repro.models import hints


def init_moe(rng, cfg) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.moe_experts
    ks = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * s).astype(jnp.float32),
        "up": (jax.random.normal(ks[1], (E, d, ff), jnp.float32) * s).astype(cfg.dtype),
        "down": (jax.random.normal(ks[2], (E, ff, d), jnp.float32) / math.sqrt(ff)).astype(cfg.dtype),
    }
    if cfg.gated_mlp:
        p["gate"] = (jax.random.normal(ks[3], (E, d, ff), jnp.float32) * s).astype(cfg.dtype)
    return p


def moe_apply(
    params: dict,
    x: jax.Array,
    cfg,
    *,
    capacity_factor: float = 0.0,
    full_capacity: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (out (B,S,d), aux_loss scalar).

    Two GSPMD-verified dispatch strategies (§Perf iterations A2-A5/B10):

    * **grouped** (GShard-style; default, and whenever E does not divide
      the expert-parallel axis — mixtral's 8 experts on 16 chips): routing,
      capacity ranking, gather and combine all happen PER BATCH ROW.  With
      the batch dim sharded over 'data' every gather/scatter is device-
      local; replicated/model-sharded weights broadcast.  Flat token-level
      gathers instead force full rematerialization in SPMD (unaligned
      indices): −32 GiB/layer and −69% collective time on mixtral.
    * **flat + expert parallelism** (when E divides the axis — llama4 128,
      jamba 16): one global (E, C, d) buffer whose expert dim shards over
      'data'; the dispatch reshard lowers as an all-to-all and both expert
      einsums stay local with fully-sharded weights.

    ``full_capacity=True`` → dropless (decode path: prefill/decode
    consistency requires no capacity drops).
    """
    mode = getattr(cfg, "moe_dispatch", "grouped")
    if mode == "grouped":
        return _moe_grouped(params, x, cfg, capacity_factor, full_capacity)
    return _moe_flat(params, x, cfg, capacity_factor, full_capacity,
                     use_hint=(mode == "flat_ep"))


def _moe_grouped(params, x, cfg, capacity_factor, full_capacity):
    B, S, d = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    capacity_factor = capacity_factor or cfg.moe_capacity_factor
    if full_capacity:
        C = S
    else:
        if hints.lean_moe():
            capacity_factor = min(capacity_factor, 1.0)  # §Perf B8
        C = max(1, int(math.ceil(S * k / E * capacity_factor)))
    acc_dtype = x.dtype if hints.lean_moe() else jnp.float32  # §Perf B8

    def route_group(xg: jax.Array):
        """One group (S, d) → (gathered (E,C,d), buf, gate_buf, aux terms)."""
        logits = (xg.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)  # (S, E)
        gates, experts = jax.lax.top_k(probs, k)  # (S, k)
        gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

        flat_e = experts.reshape(-1)  # (S·k,)
        flat_g = gates.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(S), k)

        one_hot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (S·k, E)
        pos = jnp.sum((jnp.cumsum(one_hot, axis=0) - 1) * one_hot, axis=-1)
        keep = pos < C

        buf = jnp.full((E * C,), S, jnp.int32)  # sentinel S → pad row
        addr = jnp.where(keep, flat_e * C + pos, E * C)
        buf = buf.at[addr].set(flat_tok.astype(jnp.int32), mode="drop")
        gate_buf = jnp.zeros((E * C,), acc_dtype).at[addr].set(
            jnp.where(keep, flat_g, 0.0).astype(acc_dtype), mode="drop"
        )
        xpad = jnp.concatenate([xg, jnp.zeros((1, d), xg.dtype)], axis=0)
        gathered = xpad[buf].reshape(E, C, d)
        # aux load-balance terms (Switch/Mixtral form), summed over groups
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(experts[:, 0], E), axis=0)
        return gathered, buf, gate_buf, me, ce

    gathered, buf, gate_buf, me, ce = jax.vmap(route_group)(x)
    aux = E * jnp.sum(jnp.mean(me, 0) * jnp.mean(ce, 0))

    # dispatch buffers: groups over 'data' / experts over the expert axis
    # (hints are no-ops outside a launch context)
    gathered = hints.expert_grouped(gathered)

    # ---- expert computation: batched einsum over stacked weights
    h = jnp.einsum("becd,edf->becf", gathered, params["up"])
    if "gate" in params:
        g = jnp.einsum("becd,edf->becf", gathered, params["gate"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    expert_out = hints.expert_grouped(jnp.einsum("becf,efd->becd", h, params["down"]))

    # ---- combine: per-group scatter-add back, weighted by gate
    def combine_group(eo, buf_g, gate_g):
        contrib = eo.reshape(E * C, d).astype(acc_dtype) * gate_g[:, None]
        return jnp.zeros((S + 1, d), acc_dtype).at[buf_g].add(contrib)[:S]

    out = jax.vmap(combine_group)(expert_out, buf, gate_buf)
    out = hints.act(out)
    return out.astype(x.dtype), aux


def _moe_flat(params, x, cfg, capacity_factor, full_capacity, use_hint=True):
    """Flat token-level dispatch, optionally with expert-parallel hints."""
    B, S, d = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    T = B * S
    xt = x.reshape(T, d)
    capacity_factor = capacity_factor or cfg.moe_capacity_factor
    if full_capacity:
        C = T
    else:
        if hints.lean_moe():
            capacity_factor = min(capacity_factor, 1.0)  # §Perf B8
        C = max(1, int(math.ceil(T * k / E * capacity_factor)))
    acc_dtype = x.dtype if hints.lean_moe() else jnp.float32  # §Perf B8

    logits = (xt.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, k)  # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(experts[:, 0], E), axis=0)
    aux = E * jnp.sum(me * ce)

    flat_e = experts.reshape(-1)
    flat_g = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    one_hot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.sum((jnp.cumsum(one_hot, axis=0) - 1) * one_hot, axis=-1)
    keep = pos < C

    buf = jnp.full((E * C,), T, jnp.int32)
    addr = jnp.where(keep, flat_e * C + pos, E * C)
    buf = buf.at[addr].set(flat_tok.astype(jnp.int32), mode="drop")

    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    gathered = xpad[buf].reshape(E, C, d)
    if use_hint:
        gathered = hints.expert_flat(gathered)

    h = jnp.einsum("ecd,edf->ecf", gathered, params["up"])
    if "gate" in params:
        g = jnp.einsum("ecd,edf->ecf", gathered, params["gate"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["down"])
    if use_hint:
        expert_out = hints.expert_flat(expert_out)
    expert_out = expert_out.reshape(E * C, d)

    gate_buf = jnp.zeros((E * C,), acc_dtype).at[addr].set(
        jnp.where(keep, flat_g, 0.0).astype(acc_dtype), mode="drop"
    )
    contrib = expert_out.astype(acc_dtype) * gate_buf[:, None]
    out = jnp.zeros((T + 1, d), acc_dtype).at[buf].add(contrib)[:T]
    out = hints.act(out.reshape(B, S, d))
    return out.astype(x.dtype), aux
