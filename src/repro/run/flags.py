"""The ONE argparse surface every launcher shares.

``add_compression_flags`` / ``add_run_flags`` replace the three copies of
the same argparse block that ``launch/{train,dist,fed}.py`` used to carry;
each launcher is now ``add_run_flags(parser, **its_defaults)`` plus
``spec_from_args``.  ``tests/test_docs_consistency.py`` walks this parser:
every flag added here must be documented in README's CLI table.

``--spec-json FILE`` loads a committed :class:`~repro.run.spec.RunSpec`
verbatim (the other CLI flags are ignored for that invocation — the file
IS the config), so benchmark configs are reproducible artifacts instead of
shell strings.
"""
from __future__ import annotations

import argparse
from typing import Optional, Tuple

from repro.run.spec import BACKENDS, RunSpec


def add_compression_flags(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """The compression-policy knobs (DESIGN.md §3/§10/§11)."""
    g = ap.add_argument_group("compression policy")
    g.add_argument("--compressor", default="sbc",
                   help="registered compressor name (see repro.core.api)")
    g.add_argument("--sparsity", type=float, default=0.001,
                   help="upstream gradient sparsity rate p")
    g.add_argument("--dense-pattern", default=None,
                   help="path regex: matched leaves ride dense (DGC-style)")
    g.add_argument("--skip-pattern", default=None,
                   help="path regex: matched leaves are never transmitted")
    g.add_argument("--fast", action="store_true",
                   help="flat-buffer compression fast path (DESIGN.md §10/§11)")
    g.add_argument("--flat-engine", choices=["exact", "hist"], default="exact",
                   help="fast-path engine (gspmd backend; DESIGN.md §11)")
    g.add_argument("--device-pack", action="store_true",
                   help="pack Golomb wire words on-device (fused select→pack "
                        "Pallas kernels; gspmd fast path, exact engine)")
    g.add_argument("--measure-wire", action="store_true",
                   help="meter real wire bytes into the channel ledger")
    return ap


def add_telemetry_flags(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """The repro.obs export knobs (either flag enables telemetry)."""
    g = ap.add_argument_group("telemetry (repro.obs; docs/observability.md)")
    g.add_argument("--trace", default=None, metavar="PATH",
                   help="write a Perfetto-loadable trace.json of the run")
    g.add_argument("--metrics-out", default=None, metavar="PATH",
                   help="write the run's metrics as schema-headed JSONL")
    return ap


def telemetry_requested(args: argparse.Namespace) -> bool:
    return bool(getattr(args, "trace", None) or
                getattr(args, "metrics_out", None))


def add_run_flags(ap: argparse.ArgumentParser, **defaults) -> argparse.ArgumentParser:
    """The full shared RunSpec surface; ``defaults`` re-pins per-launcher
    defaults (e.g. the fed launcher's dense-small pattern) without
    re-declaring any flag."""
    ap.add_argument("--preset", default="lenet5",
                    help="model+task preset (repro.run.presets)")
    ap.add_argument("--backend", choices=list(BACKENDS), default="local",
                    help="which CommChannel backend runs the rounds")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=None,
                    help="learning rate (default: the preset's base_lr)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--delay", type=int, default=1,
                    help="local steps per round (temporal sparsity)")
    add_compression_flags(ap)
    g = ap.add_argument_group("federated topology (fed backend)")
    g.add_argument("--cohort", type=int, default=None,
                   help="sampled clients per round (default: all)")
    g.add_argument("--profiles", default="",
                   help="heterogeneous clients: 'delay:sparsity[:weight],...'")
    g.add_argument("--down-sparsity", type=float, default=1.0,
                   help="broadcast sparsity (1.0 = dense downstream)")
    g.add_argument("--agg", default=None,
                   choices=["mean", "weighted", "staleness"],
                   help="aggregation (default: mean sync / staleness async)")
    g.add_argument("--async", dest="async_mode", action="store_true",
                   help="async rounds with stale client starts")
    g.add_argument("--max-staleness", type=int, default=4)
    g.add_argument("--staleness-beta", type=float, default=0.5)
    g.add_argument("--non-iid", action="store_true",
                   help="per-client Markov chains instead of IID shards")
    g.add_argument("--skew", type=float, default=2.0,
                   help="non-IID interpolation strength")
    g.add_argument("--broadcast-log", action="store_true",
                   help="downstream rides a round-indexed DeltaLog: lagging "
                        "cohort members pull stacked/replay catch-ups")
    g.add_argument("--delta-horizon", type=int, default=16,
                   help="rounds the DeltaLog keeps before forcing full resync")
    e = ap.add_argument_group("federated elasticity (fed backend; DESIGN.md §14)")
    e.add_argument("--cohort-tile", type=int, default=None,
                   help="clients per compiled cohort step (default: the whole "
                        "profile group in one vmap); bounds device memory")
    e.add_argument("--client-store", choices=["device", "host", "memmap"],
                   default="device",
                   help="where per-client pool state lives between rounds "
                        "(memmap scales to 10k+ simulated clients)")
    e.add_argument("--straggler-timeout", type=float, default=None,
                   help="abort uploads whose simulated duration "
                        "delay×slowdown exceeds this (partial aggregation)")
    e.add_argument("--faults", default=None,
                   help="deterministic FaultSchedule: inline JSON or a path "
                        "(drops/slow/corrupt/kill_server)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--history", default=None, help="metrics JSON path")
    ap.add_argument("--spec-json", default=None,
                    help="load a committed RunSpec JSON (other flags ignored)")
    add_telemetry_flags(ap)
    if defaults:
        ap.set_defaults(**defaults)
    return ap


def build_parser(**defaults) -> argparse.ArgumentParser:
    """The shared parser (what ``python -m repro.run`` uses, and what the
    docs-consistency test walks)."""
    ap = argparse.ArgumentParser(
        description="One declarative RunSpec over the local/gspmd/fed backends"
    )
    add_run_flags(ap, **defaults)
    return ap


def parse_profiles(spec_str: str) -> Tuple[Tuple[int, float, float], ...]:
    """'d:p[:w],d:p[:w],...' → ((delay, sparsity, weight), ...); '' → ()."""
    if not spec_str:
        return ()
    out = []
    for part in spec_str.split(","):
        fields = part.split(":")
        if len(fields) not in (2, 3):
            raise ValueError(f"bad profile {part!r}; want delay:sparsity[:weight]")
        out.append((
            int(fields[0]), float(fields[1]),
            float(fields[2]) if len(fields) == 3 else 1.0,
        ))
    return tuple(out)


def profiles_from_spec(spec: RunSpec):
    """Spec profile triples → ClientProfile tuple (one homogeneous default
    profile at (delay, sparsity) when none are named)."""
    from repro.fed import ClientProfile

    if not spec.profiles:
        return (ClientProfile(delay=spec.delay, sparsity=spec.sparsity),)
    return tuple(
        ClientProfile(delay=d, sparsity=p, weight=w) for d, p, w in spec.profiles
    )


def spec_from_args(args: argparse.Namespace,
                   backend: Optional[str] = None) -> RunSpec:
    """argparse namespace → frozen RunSpec.  ``backend`` pins the launcher's
    backend regardless of the flag (e.g. ``repro.launch.fed`` is always
    fed); ``--spec-json`` wins over every other flag."""
    if getattr(args, "spec_json", None):
        with open(args.spec_json) as f:
            spec = RunSpec.from_json(f.read())
        if backend:
            spec = spec.replace(backend=backend)
        if telemetry_requested(args):
            spec = spec.replace(telemetry=True)
        return spec
    return RunSpec(
        preset=args.preset,
        backend=backend or args.backend,
        rounds=args.rounds,
        batch=args.batch,
        seq_len=args.seq_len,
        lr=args.lr,
        seed=args.seed,
        compressor=args.compressor,
        sparsity=args.sparsity,
        dense_pattern=args.dense_pattern,
        skip_pattern=args.skip_pattern,
        fast=args.fast,
        flat_engine=args.flat_engine,
        device_pack=args.device_pack,
        measure_wire=args.measure_wire,
        clients=args.clients,
        delay=args.delay,
        cohort=args.cohort,
        profiles=parse_profiles(args.profiles),
        down_sparsity=args.down_sparsity,
        agg=args.agg,
        async_rounds=args.async_mode,
        max_staleness=args.max_staleness,
        staleness_beta=args.staleness_beta,
        non_iid=args.non_iid,
        skew=args.skew,
        broadcast_log=args.broadcast_log,
        delta_horizon=args.delta_horizon,
        cohort_tile=args.cohort_tile,
        client_store=args.client_store,
        straggler_timeout=args.straggler_timeout,
        faults=args.faults,
        telemetry=telemetry_requested(args),
    )
