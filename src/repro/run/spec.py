"""The declarative run specification — ONE frozen value drives any backend.

A :class:`RunSpec` names everything the three launchers used to assemble
imperatively: the preset, the backend, the compression policy knobs, the
cohort/topology, the schedule, and the fast/engine flags.  It is

  * **frozen + hashable** — usable as a jit-static arg and a cache key;
  * **JSON round-trippable** (``to_json`` / ``from_json``) — benchmark
    configs get committed as files (``--spec-json``) instead of
    reconstructed from CLI strings;
  * **backend-portable** — the same spec builds the vmapped local loop, the
    GSPMD shard_map step, or the federated wire deployment
    (:func:`repro.run.build_run`), and the parity matrix in
    ``tests/test_channel_parity.py`` holds the backends to bit-identical
    compression semantics.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple

BACKENDS = ("local", "gspmd", "fed")


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Everything one training run needs, as plain data.

    Profiles are (delay, sparsity, weight) triples — the federated
    heterogeneity axis (``ClientProfile``); empty means one homogeneous
    profile at (``delay``, ``sparsity``, 1.0).
    """

    # ---- what to train
    preset: str = "lenet5"
    backend: str = "local"  # "local" | "gspmd" | "fed"
    rounds: int = 20
    batch: int = 8
    seq_len: int = 64
    lr: Optional[float] = None  # None → the preset config's base_lr
    seed: int = 0

    # ---- compression policy (DESIGN.md §3)
    compressor: str = "sbc"
    sparsity: float = 0.001
    dense_pattern: Optional[str] = None  # path regex → dense32 fallback
    skip_pattern: Optional[str] = None  # path regex → never transmitted
    fast: bool = False  # §10/§11 flat-buffer fast path
    flat_engine: str = "exact"  # "exact" | "hist" (gspmd fast path)
    device_pack: bool = False  # pack Golomb wire words on-device (gspmd)
    measure_wire: bool = False  # meter real bytes into the ledger
    telemetry: bool = False  # repro.obs tracing + metrics (off = no-ops)

    # ---- client topology / schedule
    clients: int = 4
    delay: int = 1  # local steps per round (temporal sparsity)
    cohort: Optional[int] = None  # sampled clients per round (fed; None=all)
    profiles: Tuple[Tuple[int, float, float], ...] = ()  # (delay, p, weight)

    # ---- federated downstream / aggregation (fed backend only)
    down_sparsity: float = 1.0  # 1.0 = dense broadcast
    agg: Optional[str] = None  # None → mean sync / staleness async
    async_rounds: bool = False
    max_staleness: int = 4
    staleness_beta: float = 0.5
    non_iid: bool = False
    skew: float = 2.0
    broadcast_log: bool = False  # downstream rides a serve/ DeltaLog
    delta_horizon: int = 16  # rounds the DeltaLog keeps for catch-ups

    # ---- elasticity / memory (fed backend only, DESIGN.md §14)
    cohort_tile: Optional[int] = None  # members per compiled step (None=all)
    client_store: str = "device"  # "device" | "host" | "memmap" pool state
    straggler_timeout: Optional[float] = None  # abort uploads slower than this
    faults: Optional[str] = None  # FaultSchedule: inline JSON or a file path

    def __post_init__(self) -> None:
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; have {BACKENDS}"
            )
        if self.flat_engine not in ("exact", "hist"):
            raise ValueError(f"unknown flat_engine {self.flat_engine!r}")
        if self.device_pack and (
            self.backend != "gspmd" or not self.fast or self.flat_engine != "exact"
        ):
            raise ValueError(
                "device_pack packs wire words inside the gspmd flat "
                "exchange; it needs backend='gspmd', fast=True and "
                "flat_engine='exact'"
            )
        if self.client_store not in ("device", "host", "memmap"):
            raise ValueError(
                f"unknown client_store {self.client_store!r}; "
                "have ('device', 'host', 'memmap')"
            )
        # normalize JSON-born lists into the hashable tuple form
        object.__setattr__(
            self,
            "profiles",
            tuple(
                (int(d), float(p), float(w))
                for d, p, w in (tuple(t) for t in self.profiles)
            ),
        )

    # ------------------------------------------------------------ (de)spec

    def to_json(self, indent: Optional[int] = 1) -> str:
        """Serialize to JSON (committable; inverse of :meth:`from_json`)."""
        return json.dumps(dataclasses.asdict(self), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "RunSpec":
        """Parse a spec committed by :meth:`to_json`; unknown keys raise
        (a typo'd field must not silently fall back to a default)."""
        data = json.loads(text)
        if not isinstance(data, dict):
            raise ValueError(f"RunSpec JSON must be an object, got {type(data)}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(data) - known
        if unknown:
            raise ValueError(
                f"unknown RunSpec fields {sorted(unknown)}; have {sorted(known)}"
            )
        return cls(**data)

    def replace(self, **kw) -> "RunSpec":
        return dataclasses.replace(self, **kw)
