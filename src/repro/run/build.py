"""``build_run(spec) -> Run``: one declarative spec drives any backend.

The Run object is the uniform driver surface (DESIGN.md §12):

  ``init``        allocate the backend's full training state
  ``step``        one communication round (state, metrics)
  ``evaluate``    held-out loss of the current master weights
  ``checkpoint``  persist the state via :mod:`repro.checkpoint`
  ``run``         the init+step loop with the backend's native history
  ``channel``     the :class:`~repro.core.channel.CommChannel` underneath
                  (its ``ledger`` carries the measured-vs-Eq.1/Eq.5 rows)

Backends:

  local   :class:`~repro.train.trainer.DSGDTrainer` over a
          :class:`~repro.core.channel.LocalVmapChannel` (clients = vmap axis)
  gspmd   :func:`~repro.launch.dist.build_dist_train` over a
          :class:`~repro.core.channel.ShardedGspmdChannel` (clients = mesh
          axes; this builder places one "data" axis over all local devices)
  fed     :class:`~repro.fed.scheduler.RoundScheduler` over a
          :class:`~repro.core.channel.FedWireChannel` (real SBW1 bytes)

Every backend constructs its compression policy through the SAME
:func:`policy_from_spec`, so a (policy, backend) point is one field away
from any other — the API redesign the paper's sparsity-vs-topology
trade-off needs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import (
    CompressionPolicy,
    Compressor,
    PolicyRule,
    make_compressor,
)
from repro.obs import NULL_TELEMETRY, Telemetry, make_telemetry
from repro.run.spec import RunSpec

PyTree = Any


# ------------------------------------------------------------ shared pieces


def policy_from_spec(spec: RunSpec) -> Union[Compressor, CompressionPolicy]:
    """The spec's compression policy — compressor + path-regex rules + fast
    flag, identical composition to the legacy launchers (skip rules first,
    then dense fallbacks, then the compressor's own rules)."""
    comp = make_compressor(spec.compressor)
    rules: Tuple[PolicyRule, ...] = ()
    if spec.skip_pattern:
        rules += (PolicyRule(spec.skip_pattern, codec="skip"),)
    if spec.dense_pattern:
        rules += (PolicyRule(spec.dense_pattern, codec="dense32"),)
    if rules:
        return CompressionPolicy(
            default=comp.codec,
            rules=rules + comp.policy.rules,
            name=spec.compressor + "+rules",
            fast=spec.fast,
        )
    # fast=True opts in; False keeps the compressor's own flag (the legacy
    # launchers' `fast=True if args.fast else None` semantics)
    if spec.fast and not comp.policy.fast:
        return Compressor.from_policy(
            comp.name, dataclasses.replace(comp.policy, fast=True)
        )
    return comp


def as_policy(thing: Union[Compressor, CompressionPolicy]) -> CompressionPolicy:
    return thing.policy if isinstance(thing, Compressor) else thing


def lr_schedule(base_lr: float, decay_at: tuple[int, ...] = (), factor: float = 0.1):
    def lr(it):
        mult = 1.0
        for d in decay_at:
            mult = jnp.where(it >= d, mult * factor, mult)
        return base_lr * mult

    return lr


def _preset_for(spec: RunSpec):
    from repro.run.presets import build_preset

    return build_preset(spec.preset, batch=spec.batch, seq_len=spec.seq_len,
                        seed=spec.seed)


# ---------------------------------------------------------------- Run base


@dataclasses.dataclass(eq=False)
class Run:
    """A built backend: the init/step/eval/checkpoint driver surface."""

    spec: RunSpec
    cfg: Any
    model: Any
    task: Any
    channel: Any = None  # set by the backend builder
    telemetry: Telemetry = NULL_TELEMETRY  # enabled iff spec.telemetry

    # ------------------------------------------------------------ protocol

    def init(self, rng: Optional[jax.Array] = None):
        raise NotImplementedError

    def step(self, state, round_idx: int) -> tuple:
        raise NotImplementedError

    def evaluate(self, state) -> dict:
        """Held-out loss: a batch stream no training client consumes.

        Uses the backend's REAL client count (gspmd derives it from the
        mesh, not from spec.clients), so the held-out stream is genuinely
        untouched by training.
        """
        params = self.params_of(state)
        n_training = getattr(self, "n_clients", 0) or self.spec.clients
        batch = self.task.sample(0, n_training + 1)
        return {"loss": float(self.model.loss_fn(params, batch))}

    def checkpoint(self, state, path: str) -> None:
        raise NotImplementedError

    def params_of(self, state) -> PyTree:
        raise NotImplementedError

    @property
    def ledger(self):
        return self.channel.ledger

    def run(self, n_rounds: Optional[int] = None, log_every: int = 0) -> tuple:
        """init + step loop with the backend's native history dict."""
        raise NotImplementedError

    # ----------------------------------------------------------- telemetry

    def _init_for_run(self):
        """The state the traced loop starts from (fed reuses a live
        scheduler instead of rebuilding)."""
        return self.init()

    def _leaf_table(self, state) -> list:
        """Per-leaf static compression plan rows ``(path, n, k, rate)``
        for the leaf/* gauges; backends override (None-k = dense/skip)."""
        return []

    def _residual_of(self, state) -> Optional[PyTree]:
        """The error-feedback residual in pytree/flat form, or None when
        this backend doesn't expose one."""
        return None

    def _finalize_hist(self, hist: dict, n_rounds: int) -> dict:
        """Backend-specific derived history fields (compression totals)."""
        return hist

    def _record_static_gauges(self, state) -> None:
        from repro.core.golomb import expected_position_bits

        metrics = self.telemetry.metrics
        for path, n, k, rate in self._leaf_table(state):
            metrics.gauge("leaf/n", n, leaf=path)
            metrics.gauge("leaf/rate", rate, leaf=path)
            if k is not None:
                metrics.gauge("leaf/k", k, leaf=path)
                if 0.0 < rate < 1.0:
                    metrics.gauge(
                        "leaf/golomb_bits_pos", expected_position_bits(rate),
                        leaf=path,
                    )

    def _traced_run(self, n_rounds: Optional[int] = None,
                    log_every: int = 0) -> tuple:
        """The telemetry-instrumented init+step loop: one ``round`` span
        per round (stage spans open inside the backends/channels), the
        train/* gauges, and a final bit-exact ledger ingest.

        Replaces the backends' native ``run`` loops when
        ``spec.telemetry`` is on — same step semantics (it drives the
        same :meth:`step`), plus :meth:`_finalize_hist` reconstructs each
        backend's derived history fields.
        """
        import time

        tel = self.telemetry
        n_rounds = self.spec.rounds if n_rounds is None else n_rounds
        state = self._init_for_run()
        self._record_static_gauges(state)
        hist: dict = {"round": [], "loss": [], "bits_per_client": []}
        for r in range(n_rounds):
            t0 = time.perf_counter()
            with tel.span("round", round=r):
                state, m = self.step(state, r)
                tel.fence(self.params_of(state))
            step_ms = (time.perf_counter() - t0) * 1e3
            tel.metrics.gauge("train/step_ms", step_ms, round=r,
                              phase="compile" if r == 0 else "steady")
            tel.metrics.gauge("train/loss", float(m["loss"]), round=r)
            if "bits_per_client" in m:
                tel.metrics.gauge("train/bits_per_client",
                                  float(m["bits_per_client"]), round=r)
            res = self._residual_of(state)
            if res is not None:
                norm = float(jnp.sqrt(sum(
                    jnp.sum(jnp.square(x.astype(jnp.float32)))
                    for x in jax.tree.leaves(res)
                )))
                tel.metrics.gauge("train/residual_norm", norm, round=r)
            hist["round"].append(r)
            hist["loss"].append(float(m["loss"]))
            hist["bits_per_client"].append(float(m.get("bits_per_client", 0.0)))
            if "measured_bits_per_client" in m:
                hist.setdefault("measured_bits_per_client", []).append(
                    float(m["measured_bits_per_client"])
                )
            if log_every and (r + 1) % log_every == 0:
                print(f"round {r+1:5d}  loss {float(m['loss']):.4f}  "
                      f"step {step_ms:.1f} ms")
        tel.metrics.ingest_ledger(self.ledger)
        return state, self._finalize_hist(hist, n_rounds)


# ------------------------------------------------------------ local backend


@dataclasses.dataclass(eq=False)
class LocalRun(Run):
    trainer: Any = None
    batch_fn: Callable = None

    def init(self, rng: Optional[jax.Array] = None):
        if rng is None:
            rng = jax.random.PRNGKey(self.spec.seed)
        return self.trainer.init(rng)

    def step(self, state, round_idx: int) -> tuple:
        resolved = self.trainer.resolved(state.params)
        rates = resolved.rates(self.spec.sparsity, round_idx)
        # local select/quantize/exchange/apply fuse into ONE jitted round
        # (docs/observability.md) — honestly traced as one fused exchange
        with self.telemetry.span("exchange", round=round_idx, fused=True):
            out = self.trainer.round_step(
                state, self.batch_fn(round_idx), n_delay=self.spec.delay,
                sparsity=rates, return_compressed=self.spec.measure_wire,
            )
            self.telemetry.fence(out[0].params)
        if self.spec.measure_wire:
            state, m, comp0 = out
            m = dict(m)
            m["measured_bits_per_client"] = self.channel.record_round(
                round_idx, params=state.params, compressed0=comp0,
                rate=self.spec.sparsity,
                bits_analytic_per_client=float(m["bits_per_client"]),
            )
        else:
            state, m = out
        return state, {k: v for k, v in m.items()}

    def checkpoint(self, state, path: str) -> None:
        from repro.checkpoint.io import save_train_state

        save_train_state(path, state)

    def params_of(self, state) -> PyTree:
        return state.params

    def _leaf_table(self, state) -> list:
        from repro.core.stages import k_for

        resolved = self.trainer.resolved(state.params)
        rates = resolved.rates(self.spec.sparsity, 0)
        rows = []
        for plan, leaf, p in zip(
            resolved.plans, resolved._leaves_of(state.params), rates
        ):
            n = int(np.prod(np.shape(leaf)) or 1)
            sparse = not (plan.codec.skip or plan.codec.selector.dense)
            rows.append((plan.path, n, k_for(n, p) if sparse else None,
                         float(p)))
        return rows

    def _residual_of(self, state) -> Optional[PyTree]:
        return state.comp_state.residual

    def _finalize_hist(self, hist: dict, n_rounds: int) -> dict:
        total_bits = sum(hist["bits_per_client"])
        hist["total_upload_bits"] = total_bits
        n_params = sum(
            x.size for x in jax.tree.leaves(
                jax.eval_shape(self.model.init, jax.random.PRNGKey(0))
            )
        )
        hist["dense_total_bits"] = 32.0 * n_params * n_rounds * self.spec.delay
        hist["compression_rate"] = hist["dense_total_bits"] / max(total_bits, 1.0)
        if hist.get("measured_bits_per_client"):
            hist["measured_total_bits"] = sum(hist["measured_bits_per_client"])
        return hist

    def run(self, n_rounds: Optional[int] = None, log_every: int = 0) -> tuple:
        if self.telemetry.enabled:
            return self._traced_run(n_rounds, log_every)
        return self.trainer.fit(
            jax.random.PRNGKey(self.spec.seed),
            self.batch_fn,
            n_rounds=self.spec.rounds if n_rounds is None else n_rounds,
            n_delay=self.spec.delay,
            sparsity=self.spec.sparsity,
            log_every=log_every,
            measure_wire=self.spec.measure_wire,
        )


def _build_local(spec: RunSpec) -> LocalRun:
    from repro.data import client_batches
    from repro.models.model import build_model
    from repro.optim import get_optimizer
    from repro.train import DSGDTrainer

    cfg, task = _preset_for(spec)
    model = build_model(cfg)
    lr = spec.lr if spec.lr is not None else cfg.base_lr
    trainer = DSGDTrainer(
        model=model,
        compressor=policy_from_spec(spec),
        optimizer=get_optimizer(cfg.local_opt),
        n_clients=spec.clients,
        lr=lr_schedule(lr),
        _from_run=True,
    )
    return LocalRun(
        spec=spec, cfg=cfg, model=model, task=task,
        channel=trainer.channel,
        trainer=trainer,
        batch_fn=client_batches(task, spec.clients, spec.delay),
    )


# ------------------------------------------------------------ gspmd backend


@dataclasses.dataclass(eq=False)
class GspmdRun(Run):
    mesh: Any = None
    fns: Any = None  # DistTrainFns
    n_clients: int = 0

    def init(self, rng: Optional[jax.Array] = None):
        if rng is None:
            rng = jax.random.PRNGKey(self.spec.seed)
        return self.fns.init_state(rng)

    def _batch(self, round_idx: int) -> PyTree:
        ids = np.arange(self.n_clients)
        if self.task.sample_many is not None:
            return self.task.sample_many(
                np.full((self.n_clients,), round_idx), ids
            )
        per = [self.task.sample(round_idx, int(c)) for c in ids]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    def step(self, state, round_idx: int) -> tuple:
        # the shard_map round (compress + collective + apply) is one jitted
        # fused call — traced as one exchange span (docs/observability.md)
        with self.telemetry.span("exchange", round=round_idx, fused=True):
            state, m = self.fns.train_step(state, self._batch(round_idx))
            self.telemetry.fence(state["params"])
        m = dict(m)
        if self.spec.measure_wire:
            own_client0 = m.pop("own_client0")
            packed_nbits = m.pop("packed_nbits", None)
            m.pop("packed_words_client0", None)
            m["measured_bits_per_client"] = self.channel.record_round(
                round_idx, own_client0=own_client0, packed_nbits=packed_nbits
            )
        m["bits_per_client"] = self.fns.bits_per_client
        m["bits_dense"] = self.fns.bits_dense
        return state, m

    def checkpoint(self, state, path: str) -> None:
        from repro.checkpoint.io import save_pytree

        save_pytree(path, state)

    def params_of(self, state) -> PyTree:
        return state["params"]

    def _leaf_table(self, state) -> list:
        rows = []
        for gl in self.channel.leaves:
            n = int(np.prod(gl.global_shape) or 1)
            if gl.mode == "sparse":
                L = (gl.global_shape[0]
                     if gl.scanned and len(gl.global_shape) > 1 else 1)
                n_loc = max(1, n // (L * gl.n_shards))
                k_loc = max(1, min(n_loc, int(round(gl.rate * n_loc))))
                k = L * gl.n_shards * k_loc
            else:
                k = None
            rows.append((gl.path, n, k, float(gl.rate)))
        return rows

    def _residual_of(self, state) -> Optional[PyTree]:
        return state.get("residual")

    def _finalize_hist(self, hist: dict, n_rounds: int) -> dict:
        hist["total_upload_bits"] = float(self.fns.bits_per_client) * n_rounds
        hist["dense_total_bits"] = float(self.fns.bits_dense) * n_rounds
        hist["compression_rate"] = hist["dense_total_bits"] / max(
            hist["total_upload_bits"], 1.0
        )
        return hist

    def run(self, n_rounds: Optional[int] = None, log_every: int = 0) -> tuple:
        if self.telemetry.enabled:
            return self._traced_run(n_rounds, log_every)
        n_rounds = self.spec.rounds if n_rounds is None else n_rounds
        state = self.init()
        hist: dict = {"round": [], "loss": [], "bits_per_client": []}
        for r in range(n_rounds):
            state, m = self.step(state, r)
            hist["round"].append(r)
            hist["loss"].append(float(m["loss"]))
            hist["bits_per_client"].append(float(m["bits_per_client"]))
            if log_every and (r + 1) % log_every == 0:
                print(f"round {r+1:5d}  loss {float(m['loss']):.4f}")
        hist["total_upload_bits"] = float(self.fns.bits_per_client) * n_rounds
        hist["dense_total_bits"] = float(self.fns.bits_dense) * n_rounds
        hist["compression_rate"] = hist["dense_total_bits"] / max(
            hist["total_upload_bits"], 1.0
        )
        return state, hist


def _build_gspmd(spec: RunSpec, mesh=None) -> GspmdRun:
    from jax.sharding import Mesh

    from repro.launch.dist import build_dist_train, client_topology
    from repro.models.model import build_model

    cfg, task = _preset_for(spec)
    if mesh is None:
        # one "data" client axis over every local device (plus a size-1
        # "model" axis for the sharding hints) — the in-process topology;
        # production meshes come from repro.launch.mesh and enter through
        # the ``mesh=`` override
        mesh = Mesh(np.asarray(jax.devices()).reshape(-1, 1), ("data", "model"))
    model = build_model(cfg)
    policy = policy_from_spec(spec)
    fns = build_dist_train(
        cfg, mesh,
        compressor=spec.compressor,
        sparsity=spec.sparsity,
        policy=as_policy(policy) if not isinstance(policy, Compressor) else None,
        model=model,
        fast=True if spec.fast else None,
        flat_engine=spec.flat_engine,
        measure=spec.measure_wire,
        device_pack=spec.device_pack,
    )
    n_clients, _ = client_topology(cfg, mesh)
    return GspmdRun(
        spec=spec, cfg=cfg, model=model, task=task,
        channel=fns.channel, mesh=mesh, fns=fns, n_clients=n_clients,
    )


# -------------------------------------------------------------- fed backend


@dataclasses.dataclass(eq=False)
class FedRun(Run):
    scheduler: Any = None  # the stateful RoundScheduler IS the run state

    def init(self, rng: Optional[jax.Array] = None):
        from repro.fed import ClientPool, ParameterServer, RoundScheduler
        from repro.optim import get_optimizer
        from repro.run.flags import profiles_from_spec

        spec = self.spec
        params = self.model.init(
            rng if rng is not None else jax.random.PRNGKey(spec.seed)
        )
        policy = as_policy(policy_from_spec(spec))
        agg = spec.agg or ("staleness" if spec.async_rounds else "mean")
        lr = spec.lr if spec.lr is not None else self.cfg.base_lr
        server = ParameterServer(
            params=params, up_policy=policy, down_sparsity=spec.down_sparsity,
            aggregator=agg, staleness_beta=spec.staleness_beta,
            delta_horizon=spec.delta_horizon if spec.broadcast_log else None,
        )
        pool = ClientPool(
            model=self.model, optimizer=get_optimizer(self.cfg.local_opt),
            policy=policy, task=self.task, n_clients=spec.clients,
            lr=lambda it: lr, profiles=profiles_from_spec(spec),
            seed=spec.seed,
            cohort_tile=spec.cohort_tile, store=spec.client_store,
        )
        faults = None
        if spec.faults:
            from repro.fed.faults import FaultSchedule

            faults = FaultSchedule.parse(spec.faults)
        self.scheduler = RoundScheduler(
            server=server, pool=pool,
            cohort_size=spec.cohort or spec.clients,
            mode="async" if spec.async_rounds else "sync",
            max_staleness=spec.max_staleness, seed=spec.seed,
            straggler_timeout=spec.straggler_timeout, faults=faults,
        )
        self.channel = self.scheduler.channel
        # thread the telemetry handle to the wire endpoints (stage spans:
        # select_quantize/encode in the channel, decode/apply/encode in
        # the server)
        self.channel.telemetry = self.telemetry
        server.telemetry = self.telemetry
        return self.scheduler

    def step(self, state, round_idx: int) -> tuple:
        return state, state.step(round_idx)

    def checkpoint(self, state, path: str,
                   rounds_done: Optional[int] = None) -> None:
        """Full-federation snapshot (server + pool + channel + DeltaLog):
        ``repro.fed.checkpoint`` makes a restored run continue
        bit-identically, mid-round included."""
        from repro.fed.checkpoint import save_fed_state

        save_fed_state(path, state, rounds_done=rounds_done)

    def restore(self, path: str) -> dict:
        """Restore a :meth:`checkpoint` file into a freshly-initialized
        scheduler; returns the checkpoint meta (``rounds_done`` etc.)."""
        from repro.fed.checkpoint import restore_fed_state

        state = self.init() if self.scheduler is None else self.scheduler
        return restore_fed_state(path, state)

    def params_of(self, state) -> PyTree:
        return state.server.params

    def _init_for_run(self):
        return self.init() if self.scheduler is None else self.scheduler

    def _leaf_table(self, state) -> list:
        from repro.core.stages import k_for

        resolved = state.server._up_resolved
        params = state.server.params
        rates = resolved.rates(self.spec.sparsity, 0)
        rows = []
        for plan, leaf, p in zip(
            resolved.plans, resolved._leaves_of(params), rates
        ):
            n = int(np.prod(np.shape(leaf)) or 1)
            sparse = not (plan.codec.skip or plan.codec.selector.dense)
            rows.append((plan.path, n, k_for(n, p) if sparse else None,
                         float(p)))
        return rows

    def _finalize_hist(self, hist: dict, n_rounds: int) -> dict:
        hist.update({f"wire_{k}": v for k, v in self.ledger.history().items()})
        hist.update(self.ledger.totals())
        return hist

    def run(self, n_rounds: Optional[int] = None, log_every: int = 0) -> tuple:
        if self.telemetry.enabled:
            return self._traced_run(n_rounds, log_every)
        state = self.init() if self.scheduler is None else self.scheduler
        hist = state.run(
            self.spec.rounds if n_rounds is None else n_rounds,
            log_every=log_every,
        )
        return state, hist


def _build_fed(spec: RunSpec) -> FedRun:
    from repro.data import make_non_iid_lm_task
    from repro.models.model import build_model

    cfg, task = _preset_for(spec)
    if spec.non_iid:
        if cfg.family not in ("decoder",):
            raise ValueError(
                f"non_iid needs an LM preset; {spec.preset!r} is {cfg.family}"
            )
        task = make_non_iid_lm_task(
            vocab=cfg.vocab_size, batch=spec.batch, seq_len=spec.seq_len,
            n_clients=spec.clients, skew=spec.skew, temperature=0.5,
            seed=spec.seed,
        )
    model = build_model(cfg)
    return FedRun(spec=spec, cfg=cfg, model=model, task=task)


# ------------------------------------------------------------- entry point

_BUILDERS = {
    "local": _build_local,
    "gspmd": _build_gspmd,
    "fed": _build_fed,
}


def build_run(spec: RunSpec, **backend_kw) -> Run:
    """Construct the backend a spec names.  ``backend_kw`` carries the few
    non-declarative objects a backend can accept (e.g. ``mesh=`` for
    gspmd).

    ``spec.telemetry`` attaches one enabled :class:`~repro.obs.Telemetry`
    bundle to the run AND its channel (disabled runs keep the shared
    no-op ``NULL_TELEMETRY`` — zero overhead by construction).
    """
    run = _BUILDERS[spec.backend](spec, **backend_kw)
    if spec.telemetry:
        run.telemetry = make_telemetry()
        if run.channel is not None:  # fed attaches at init() time
            run.channel.telemetry = run.telemetry
    return run
