"""``build_run(spec) -> Run``: one declarative spec drives any backend.

The Run object is the uniform driver surface (DESIGN.md §12):

  ``init``        allocate the backend's full training state
  ``step``        one communication round (state, metrics)
  ``evaluate``    held-out loss of the current master weights
  ``checkpoint``  persist the state via :mod:`repro.checkpoint`
  ``run``         the init+step loop with the backend's native history
  ``channel``     the :class:`~repro.core.channel.CommChannel` underneath
                  (its ``ledger`` carries the measured-vs-Eq.1/Eq.5 rows)

Backends:

  local   :class:`~repro.train.trainer.DSGDTrainer` over a
          :class:`~repro.core.channel.LocalVmapChannel` (clients = vmap axis)
  gspmd   :func:`~repro.launch.dist.build_dist_train` over a
          :class:`~repro.core.channel.ShardedGspmdChannel` (clients = mesh
          axes; this builder places one "data" axis over all local devices)
  fed     :class:`~repro.fed.scheduler.RoundScheduler` over a
          :class:`~repro.core.channel.FedWireChannel` (real SBW1 bytes)

Every backend constructs its compression policy through the SAME
:func:`policy_from_spec`, so a (policy, backend) point is one field away
from any other — the API redesign the paper's sparsity-vs-topology
trade-off needs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import (
    CompressionPolicy,
    Compressor,
    PolicyRule,
    make_compressor,
)
from repro.run.spec import RunSpec

PyTree = Any


# ------------------------------------------------------------ shared pieces


def policy_from_spec(spec: RunSpec) -> Union[Compressor, CompressionPolicy]:
    """The spec's compression policy — compressor + path-regex rules + fast
    flag, identical composition to the legacy launchers (skip rules first,
    then dense fallbacks, then the compressor's own rules)."""
    comp = make_compressor(spec.compressor)
    rules: Tuple[PolicyRule, ...] = ()
    if spec.skip_pattern:
        rules += (PolicyRule(spec.skip_pattern, codec="skip"),)
    if spec.dense_pattern:
        rules += (PolicyRule(spec.dense_pattern, codec="dense32"),)
    if rules:
        return CompressionPolicy(
            default=comp.codec,
            rules=rules + comp.policy.rules,
            name=spec.compressor + "+rules",
            fast=spec.fast,
        )
    # fast=True opts in; False keeps the compressor's own flag (the legacy
    # launchers' `fast=True if args.fast else None` semantics)
    if spec.fast and not comp.policy.fast:
        return Compressor.from_policy(
            comp.name, dataclasses.replace(comp.policy, fast=True)
        )
    return comp


def as_policy(thing: Union[Compressor, CompressionPolicy]) -> CompressionPolicy:
    return thing.policy if isinstance(thing, Compressor) else thing


def lr_schedule(base_lr: float, decay_at: tuple[int, ...] = (), factor: float = 0.1):
    def lr(it):
        mult = 1.0
        for d in decay_at:
            mult = jnp.where(it >= d, mult * factor, mult)
        return base_lr * mult

    return lr


def _preset_for(spec: RunSpec):
    from repro.run.presets import build_preset

    return build_preset(spec.preset, batch=spec.batch, seq_len=spec.seq_len,
                        seed=spec.seed)


# ---------------------------------------------------------------- Run base


@dataclasses.dataclass(eq=False)
class Run:
    """A built backend: the init/step/eval/checkpoint driver surface."""

    spec: RunSpec
    cfg: Any
    model: Any
    task: Any
    channel: Any = None  # set by the backend builder

    # ------------------------------------------------------------ protocol

    def init(self, rng: Optional[jax.Array] = None):
        raise NotImplementedError

    def step(self, state, round_idx: int) -> tuple:
        raise NotImplementedError

    def evaluate(self, state) -> dict:
        """Held-out loss: a batch stream no training client consumes.

        Uses the backend's REAL client count (gspmd derives it from the
        mesh, not from spec.clients), so the held-out stream is genuinely
        untouched by training.
        """
        params = self.params_of(state)
        n_training = getattr(self, "n_clients", 0) or self.spec.clients
        batch = self.task.sample(0, n_training + 1)
        return {"loss": float(self.model.loss_fn(params, batch))}

    def checkpoint(self, state, path: str) -> None:
        raise NotImplementedError

    def params_of(self, state) -> PyTree:
        raise NotImplementedError

    @property
    def ledger(self):
        return self.channel.ledger

    def run(self, n_rounds: Optional[int] = None, log_every: int = 0) -> tuple:
        """init + step loop with the backend's native history dict."""
        raise NotImplementedError


# ------------------------------------------------------------ local backend


@dataclasses.dataclass(eq=False)
class LocalRun(Run):
    trainer: Any = None
    batch_fn: Callable = None

    def init(self, rng: Optional[jax.Array] = None):
        if rng is None:
            rng = jax.random.PRNGKey(self.spec.seed)
        return self.trainer.init(rng)

    def step(self, state, round_idx: int) -> tuple:
        resolved = self.trainer.resolved(state.params)
        rates = resolved.rates(self.spec.sparsity, round_idx)
        out = self.trainer.round_step(
            state, self.batch_fn(round_idx), n_delay=self.spec.delay,
            sparsity=rates, return_compressed=self.spec.measure_wire,
        )
        if self.spec.measure_wire:
            state, m, comp0 = out
            m = dict(m)
            m["measured_bits_per_client"] = self.channel.record_round(
                round_idx, params=state.params, compressed0=comp0,
                rate=self.spec.sparsity,
                bits_analytic_per_client=float(m["bits_per_client"]),
            )
        else:
            state, m = out
        return state, {k: v for k, v in m.items()}

    def checkpoint(self, state, path: str) -> None:
        from repro.checkpoint.io import save_train_state

        save_train_state(path, state)

    def params_of(self, state) -> PyTree:
        return state.params

    def run(self, n_rounds: Optional[int] = None, log_every: int = 0) -> tuple:
        return self.trainer.fit(
            jax.random.PRNGKey(self.spec.seed),
            self.batch_fn,
            n_rounds=self.spec.rounds if n_rounds is None else n_rounds,
            n_delay=self.spec.delay,
            sparsity=self.spec.sparsity,
            log_every=log_every,
            measure_wire=self.spec.measure_wire,
        )


def _build_local(spec: RunSpec) -> LocalRun:
    from repro.data import client_batches
    from repro.models.model import build_model
    from repro.optim import get_optimizer
    from repro.train import DSGDTrainer

    cfg, task = _preset_for(spec)
    model = build_model(cfg)
    lr = spec.lr if spec.lr is not None else cfg.base_lr
    trainer = DSGDTrainer(
        model=model,
        compressor=policy_from_spec(spec),
        optimizer=get_optimizer(cfg.local_opt),
        n_clients=spec.clients,
        lr=lr_schedule(lr),
        _from_run=True,
    )
    return LocalRun(
        spec=spec, cfg=cfg, model=model, task=task,
        channel=trainer.channel,
        trainer=trainer,
        batch_fn=client_batches(task, spec.clients, spec.delay),
    )


# ------------------------------------------------------------ gspmd backend


@dataclasses.dataclass(eq=False)
class GspmdRun(Run):
    mesh: Any = None
    fns: Any = None  # DistTrainFns
    n_clients: int = 0

    def init(self, rng: Optional[jax.Array] = None):
        if rng is None:
            rng = jax.random.PRNGKey(self.spec.seed)
        return self.fns.init_state(rng)

    def _batch(self, round_idx: int) -> PyTree:
        ids = np.arange(self.n_clients)
        if self.task.sample_many is not None:
            return self.task.sample_many(
                np.full((self.n_clients,), round_idx), ids
            )
        per = [self.task.sample(round_idx, int(c)) for c in ids]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    def step(self, state, round_idx: int) -> tuple:
        state, m = self.fns.train_step(state, self._batch(round_idx))
        m = dict(m)
        if self.spec.measure_wire:
            own0 = m.pop("own0")
            m["measured_bits_per_client"] = self.channel.record_round(
                round_idx, own0=own0
            )
        m["bits_per_client"] = self.fns.bits_per_client
        m["bits_dense"] = self.fns.bits_dense
        return state, m

    def checkpoint(self, state, path: str) -> None:
        from repro.checkpoint.io import save_pytree

        save_pytree(path, state)

    def params_of(self, state) -> PyTree:
        return state["params"]

    def run(self, n_rounds: Optional[int] = None, log_every: int = 0) -> tuple:
        n_rounds = self.spec.rounds if n_rounds is None else n_rounds
        state = self.init()
        hist: dict = {"round": [], "loss": [], "bits_per_client": []}
        for r in range(n_rounds):
            state, m = self.step(state, r)
            hist["round"].append(r)
            hist["loss"].append(float(m["loss"]))
            hist["bits_per_client"].append(float(m["bits_per_client"]))
            if log_every and (r + 1) % log_every == 0:
                print(f"round {r+1:5d}  loss {float(m['loss']):.4f}")
        hist["total_upload_bits"] = float(self.fns.bits_per_client) * n_rounds
        hist["dense_total_bits"] = float(self.fns.bits_dense) * n_rounds
        hist["compression_rate"] = hist["dense_total_bits"] / max(
            hist["total_upload_bits"], 1.0
        )
        return state, hist


def _build_gspmd(spec: RunSpec, mesh=None) -> GspmdRun:
    from jax.sharding import Mesh

    from repro.launch.dist import build_dist_train, client_topology
    from repro.models.model import build_model

    cfg, task = _preset_for(spec)
    if mesh is None:
        # one "data" client axis over every local device (plus a size-1
        # "model" axis for the sharding hints) — the in-process topology;
        # production meshes come from repro.launch.mesh and enter through
        # the ``mesh=`` override
        mesh = Mesh(np.asarray(jax.devices()).reshape(-1, 1), ("data", "model"))
    model = build_model(cfg)
    policy = policy_from_spec(spec)
    fns = build_dist_train(
        cfg, mesh,
        compressor=spec.compressor,
        sparsity=spec.sparsity,
        policy=as_policy(policy) if not isinstance(policy, Compressor) else None,
        model=model,
        fast=True if spec.fast else None,
        flat_engine=spec.flat_engine,
        measure=spec.measure_wire,
    )
    n_clients, _ = client_topology(cfg, mesh)
    return GspmdRun(
        spec=spec, cfg=cfg, model=model, task=task,
        channel=fns.channel, mesh=mesh, fns=fns, n_clients=n_clients,
    )


# -------------------------------------------------------------- fed backend


@dataclasses.dataclass(eq=False)
class FedRun(Run):
    scheduler: Any = None  # the stateful RoundScheduler IS the run state

    def init(self, rng: Optional[jax.Array] = None):
        from repro.fed import ClientPool, ParameterServer, RoundScheduler
        from repro.optim import get_optimizer
        from repro.run.flags import profiles_from_spec

        spec = self.spec
        params = self.model.init(
            rng if rng is not None else jax.random.PRNGKey(spec.seed)
        )
        policy = as_policy(policy_from_spec(spec))
        agg = spec.agg or ("staleness" if spec.async_rounds else "mean")
        lr = spec.lr if spec.lr is not None else self.cfg.base_lr
        server = ParameterServer(
            params=params, up_policy=policy, down_sparsity=spec.down_sparsity,
            aggregator=agg, staleness_beta=spec.staleness_beta,
            delta_horizon=spec.delta_horizon if spec.broadcast_log else None,
        )
        pool = ClientPool(
            model=self.model, optimizer=get_optimizer(self.cfg.local_opt),
            policy=policy, task=self.task, n_clients=spec.clients,
            lr=lambda it: lr, profiles=profiles_from_spec(spec),
            seed=spec.seed,
        )
        self.scheduler = RoundScheduler(
            server=server, pool=pool,
            cohort_size=spec.cohort or spec.clients,
            mode="async" if spec.async_rounds else "sync",
            max_staleness=spec.max_staleness, seed=spec.seed,
        )
        self.channel = self.scheduler.channel
        return self.scheduler

    def step(self, state, round_idx: int) -> tuple:
        return state, state.step(round_idx)

    def checkpoint(self, state, path: str) -> None:
        from repro.checkpoint.io import save_pytree

        save_pytree(path, {
            "params": state.server.params,
            "estimate": state.server.estimate,
        })

    def params_of(self, state) -> PyTree:
        return state.server.params

    def run(self, n_rounds: Optional[int] = None, log_every: int = 0) -> tuple:
        state = self.init() if self.scheduler is None else self.scheduler
        hist = state.run(
            self.spec.rounds if n_rounds is None else n_rounds,
            log_every=log_every,
        )
        return state, hist


def _build_fed(spec: RunSpec) -> FedRun:
    from repro.data import make_non_iid_lm_task
    from repro.models.model import build_model

    cfg, task = _preset_for(spec)
    if spec.non_iid:
        if cfg.family not in ("decoder",):
            raise ValueError(
                f"non_iid needs an LM preset; {spec.preset!r} is {cfg.family}"
            )
        task = make_non_iid_lm_task(
            vocab=cfg.vocab_size, batch=spec.batch, seq_len=spec.seq_len,
            n_clients=spec.clients, skew=spec.skew, temperature=0.5,
            seed=spec.seed,
        )
    model = build_model(cfg)
    return FedRun(spec=spec, cfg=cfg, model=model, task=task)


# ------------------------------------------------------------- entry point

_BUILDERS = {
    "local": _build_local,
    "gspmd": _build_gspmd,
    "fed": _build_fed,
}


def build_run(spec: RunSpec, **backend_kw) -> Run:
    """Construct the backend a spec names.  ``backend_kw`` carries the few
    non-declarative objects a backend can accept (e.g. ``mesh=`` for
    gspmd)."""
    return _BUILDERS[spec.backend](spec, **backend_kw)
