"""One declarative run surface over the local, GSPMD, and federated
backends (DESIGN.md §12).

  >>> from repro.run import RunSpec, build_run
  >>> run = build_run(RunSpec(preset="lenet5", backend="local",
  ...                         sparsity=0.01, rounds=10))
  >>> state, hist = run.run()

The spec is frozen, hashable, and JSON round-trippable; ``build_run``
dispatches it to one :class:`~repro.core.channel.CommChannel` backend with
bit-identical compression semantics across all three.  CLI:
``python -m repro.run --preset lenet5 --backend {local,gspmd,fed}``.
"""
from repro.run.build import Run, build_run, policy_from_spec
from repro.run.flags import (
    add_compression_flags,
    add_run_flags,
    build_parser,
    spec_from_args,
)
from repro.run.presets import build_preset
from repro.run.spec import BACKENDS, RunSpec

__all__ = [
    "BACKENDS",
    "Run",
    "RunSpec",
    "add_compression_flags",
    "add_run_flags",
    "build_parser",
    "build_preset",
    "build_run",
    "policy_from_spec",
    "spec_from_args",
]
