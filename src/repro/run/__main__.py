"""``python -m repro.run``: the declarative launcher.

Examples:
  PYTHONPATH=src python -m repro.run --preset lenet5 --backend local \
      --rounds 5 --sparsity 0.01
  PYTHONPATH=src python -m repro.run --preset fed-tiny --backend fed \
      --clients 8 --cohort 4 --rounds 3 --fast
  PYTHONPATH=src python -m repro.run --spec-json experiments/specs/my_run.json
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.run.build import build_run
from repro.run.flags import build_parser, spec_from_args


def main(argv=None):
    args = build_parser().parse_args(argv)
    spec = spec_from_args(args)
    run = build_run(spec)

    n_params = sum(
        x.size for x in jax.tree.leaves(
            jax.eval_shape(run.model.init, jax.random.PRNGKey(0))
        )
    )
    clients = getattr(run, "n_clients", 0) or spec.clients
    print(
        f"run: backend={spec.backend} preset={spec.preset} "
        f"arch={run.cfg.name} params={n_params/1e6:.2f}M "
        f"compressor={spec.compressor} clients={clients} "
        f"delay={spec.delay} p={spec.sparsity} fast={spec.fast}"
    )
    t0 = time.time()
    state, hist = run.run(log_every=args.log_every)
    dt = time.time() - t0
    print(
        f"done in {dt:.1f}s: loss {hist['loss'][0]:.4f} → {hist['loss'][-1]:.4f}"
    )
    if "compression_rate" in hist:
        print(
            f"upload {hist['total_upload_bits']/8e6:.2f} MB/client  "
            f"compression ×{hist['compression_rate']:.0f}"
        )
    if run.channel is not None and run.ledger.records:
        t = run.ledger.totals()
        print(
            f"wire: up {t['up_bytes']/1e3:.1f} kB, down {t['down_bytes']/1e3:.1f} kB "
            f"(measured/analytic up "
            f"×{t['up_bits_measured']/max(t['up_bits_analytic'],1):.3f})"
        )
    if spec.telemetry:
        from repro.obs import finish_run

        finish_run(
            run.telemetry, trace=args.trace, metrics_out=args.metrics_out,
            meta={"backend": spec.backend, "preset": spec.preset,
                  "rounds": spec.rounds},
        )
    if args.history:
        os.makedirs(os.path.dirname(os.path.abspath(args.history)), exist_ok=True)
        with open(args.history, "w") as f:
            json.dump({k: v for k, v in hist.items() if k != "eval"}, f,
                      default=float)
        print(f"wrote {args.history}")
    return hist


if __name__ == "__main__":
    main()
