"""Named presets: one string → (ModelConfig, synthetic Task).

The preset registry every backend shares (``RunSpec.preset``).  Moved out
of ``repro.launch.train`` so the local, GSPMD, and federated launchers
resolve sizes through ONE function instead of three:

  lenet5 / paper-lenet   LeNet5 on blob-MNIST (Adam, the paper's smallest)
  charlstm / paper-lstm  CharLSTM on a markov stream
  lm-100m                ~100M-param decoder LM
  fed-tiny               2-layer decoder sized for CI smoke rounds
  tiny                   2-layer d=64 decoder (test/parity-matrix scale)
  <arch id>              a reduced config of any assigned architecture
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config, reduced
from repro.data import make_classification_task, make_lm_task


def lm_100m_config() -> ModelConfig:
    """~100M decoder: 12L, d=768, 12H, tied 32k vocab."""
    return ModelConfig(
        name="lm-100m", family="decoder", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=3072, vocab_size=32_000, dtype=jnp.float32,
        local_opt="adam", base_lr=3e-4,
    )


def fed_tiny_config() -> ModelConfig:
    """The reduced federated preset — small enough for CI smoke rounds."""
    return ModelConfig(
        name="fed-tiny", family="decoder", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab_size=256, dtype=jnp.float32,
    )


def tiny_config() -> ModelConfig:
    """Sub-CI decoder for parity matrices and unit tests."""
    return ModelConfig(
        name="tiny", family="decoder", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=97, dtype=jnp.float32,
    )


def build_preset(name: str, *, batch: int, seq_len: int, seed: int = 0):
    """Resolve a preset name to ``(cfg, task)``."""
    if name in ("paper-lenet", "lenet5"):
        cfg = get_config("lenet5")
        task = make_classification_task(
            n_classes=10, img_size=28, channels=1, batch=batch
        )
        return cfg, task
    if name in ("paper-lstm", "charlstm"):
        cfg = get_config("charlstm")
        task = make_lm_task(vocab=98, batch=batch, seq_len=seq_len,
                            temperature=0.5, seed=seed)
        return cfg, task
    if name == "lm-100m":
        cfg = lm_100m_config()
        task = make_lm_task(vocab=cfg.vocab_size, batch=batch, seq_len=seq_len,
                            temperature=0.5, seed=seed)
        return cfg, task
    if name in ("fed-tiny", "tiny"):
        cfg = fed_tiny_config() if name == "fed-tiny" else tiny_config()
        task = make_lm_task(vocab=cfg.vocab_size, batch=batch, seq_len=seq_len,
                            temperature=0.5, seed=seed)
        return cfg, task
    # reduced assigned arch
    cfg = reduced(get_config(name))
    if cfg.family == "encdec":
        d = cfg.d_model

        def extra(rng):
            return {"enc_frames": 0.1 * jax.random.normal(rng, (batch, seq_len, d))} \
                if cfg.modality == "audio" else {}

        task = make_lm_task(vocab=cfg.vocab_size, batch=batch, seq_len=seq_len,
                            temperature=0.5, extra_fields=extra, seed=seed)
    elif cfg.modality == "vision":
        d, npre = cfg.d_model, cfg.n_prefix

        def extra(rng):
            return {"prefix": 0.1 * jax.random.normal(rng, (batch, npre, d))}

        task = make_lm_task(vocab=cfg.vocab_size, batch=batch, seq_len=seq_len,
                            temperature=0.5, extra_fields=extra, seed=seed)
    else:
        task = make_lm_task(vocab=cfg.vocab_size, batch=batch, seq_len=seq_len,
                            temperature=0.5, seed=seed)
    return cfg, task
