"""Jamba v0.1 (52B total / 12B active) [arXiv:2403.19887].

Hybrid Mamba+attention 1:7 interleave (one attention layer per 8), MoE with
16 experts top-2 on every second layer.  The Mamba state makes long_500k
viable (attention layers are an O(L) cache read at decode).
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="decoder",
    source="arXiv:2403.19887",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    ssm_kind="mamba",
    ssm_ffn=True,  # every Jamba layer = (attn|mamba) mixer + (MLP|MoE) FFN
    attn_every=8,  # 1 attention : 7 mamba
    moe_experts=16,
    moe_top_k=2,
    moe_every=2,
    moe_dispatch="grouped",
    fsdp=True,
    client_mode="pod",
    local_opt="sgd",
    base_lr=3e-4,
    residual_dtype=jnp.bfloat16,
)
