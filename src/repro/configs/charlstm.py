"""CharLSTM on Shakespeare — paper §IV-A (2×200 LSTM over a 98-character
vocabulary, plain SGD @ 1.0).
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="charlstm",
    family="lstm",
    source="paper §IV-A",
    n_layers=2,
    d_model=0,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=98,
    lstm_hidden=200,
    local_opt="sgd",
    base_lr=1.0,
    dtype=jnp.float32,
    scan_layers=False,
    remat=False,
)
