"""SeamlessM4T-medium backbone [arXiv:2308.11596].

Encoder-decoder, multimodal (speech→text).  Per the assignment carve-out the
conformer/mel frontend is a stub: ``input_specs`` provides precomputed frame
embeddings (``enc_frames``) consumed directly by the text-decoder-facing
transformer encoder.  12L refers to each stack; 16 heads with kv=16 (MHA),
LayerNorm + non-gated MLP (standard seq2seq transformer block).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    source="arXiv:2308.11596",
    n_layers=12,
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    norm="layernorm",
    gated_mlp=False,
    modality="audio",
    tie_embeddings=True,
    client_mode="data",
    local_opt="adam",
    base_lr=1e-4,
)
