"""WordLSTM on PTB — paper §IV-A (Zaremba et al. "medium": 2×650 LSTM,
10000-word vocab, plain SGD @ 1.0 with decay 0.8).
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="wordlstm",
    family="lstm",
    source="paper §IV-A / Zaremba et al. 2014",
    n_layers=2,
    d_model=0,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=10_000,
    lstm_hidden=650,
    local_opt="sgd",
    base_lr=1.0,
    dtype=jnp.float32,
    scan_layers=False,
    remat=False,
)
