"""RWKV6 "Finch" 1.6B [arXiv:2404.05892].

Attention-free linear-recurrence LM with data-dependent decay (the defining
Finch feature, kept as a LoRA in our implementation).  O(1) state per token →
runs every decode shape including long_500k.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="decoder",
    source="arXiv:2404.05892",
    n_layers=24,
    d_model=2048,
    n_heads=32,  # rwkv heads = d_model / 64 (used for state bookkeeping)
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    ssm_kind="rwkv6",
    norm="layernorm",
    client_mode="data",
    local_opt="adam",
    base_lr=3e-4,
)
