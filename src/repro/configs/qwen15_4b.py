"""Qwen1.5-4B [hf:Qwen/Qwen1.5-0.5B family].

Dense decoder, MHA-equal GQA (kv=heads=20), QKV *biases* (the family's
signature), 151936 vocab.  Pure full attention → long_500k skipped.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="decoder",
    source="hf:Qwen/Qwen1.5-0.5B",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    gated_mlp=True,
    tie_embeddings=False,
    client_mode="data",
    local_opt="adam",
    base_lr=3e-4,
)
