"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01].

Dense decoder with GQA (64 q heads / 8 kv), no biases anywhere, 256k vocab
(the largest in the pool — exercises the chunked-xent path hard).  Pure full
attention → long_500k skipped.  ≥20B: FSDP + pod-mode clients.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="decoder",
    source="hf:CohereForAI/c4ai-command-r-v01",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    gated_mlp=True,
    qkv_bias=False,
    fsdp=True,
    client_mode="pod",
    local_opt="sgd",
    base_lr=3e-4,
    residual_dtype=jnp.bfloat16,
)
