"""ResNet-32 on CIFAR-10 — paper §IV-A (He et al. '16, 3×5 basic blocks).

Momentum SGD @ 0.1 decay, batch 128×4 clients (paper Table III uses lr 0.01
with decays at 30k/50k iterations).
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="resnet32",
    family="cnn",
    source="paper §IV-A / He et al. 2016",
    n_layers=0,
    d_model=0,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=0,
    img_size=32,
    img_channels=3,
    n_classes=10,
    local_opt="momentum",
    base_lr=0.01,
    dtype=jnp.float32,
    scan_layers=False,
    remat=False,
)
