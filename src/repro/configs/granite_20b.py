"""Granite-20B-Code [arXiv:2405.04324].

Dense decoder, 52L, d=6144, 48 heads with ONE kv head (MQA, kv=1) — the
extreme GQA point in the pool; pure full attention (long_500k skipped).
≥20B: FSDP over 'data', pod-mode clients, bf16 residual.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="decoder",
    source="arXiv:2405.04324",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    gated_mlp=False,  # gpt_bigcode-style 2-matrix MLP (20B total)
    norm="layernorm",
    fsdp=True,
    client_mode="pod",
    local_opt="sgd",
    base_lr=3e-4,
    residual_dtype=jnp.bfloat16,
)
