"""Phi-3-vision 4.2B [hf:microsoft/Phi-3-vision-128k-instruct].

phi3-mini text backbone + CLIP vision encoder.  The vision tower/projector
is the assignment's stub: ``input_specs`` provides 576 precomputed patch
embeddings (CLIP ViT-L/14 @ 336px) as an early-fusion prefix.  Full
attention (long_500k skipped — LongRoPE extends range but stays quadratic).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="decoder",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    modality="vision",
    n_prefix=576,
    gated_mlp=True,
    client_mode="data",
    local_opt="adam",
    base_lr=1e-4,
)
