"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family].

The scale ceiling of the pool: 128-expert top-1 MoE on alternating layers
(dense MLP between), chunked-local attention (8k chunks) with a global
layer every 4th → long_500k viable.  Early-fusion multimodal in the source
model; the assignment pins the text backbone (vision tower would be a stub,
but the 400B config is exercised text-only).

Distribution: experts shard over 'data' (expert parallelism) AND ff over
'model'; pod-mode clients with bf16 residual — per-data-coordinate client
state is physically impossible at 400B (DESIGN.md §4).
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="decoder",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    moe_experts=128,
    moe_top_k=1,
    moe_every=2,  # alternating dense / MoE (Maverick interleave)
    moe_dispatch="flat_ep",
    chunk_attn=8192,
    global_every=4,
    fsdp=True,
    client_mode="pod",
    local_opt="sgd",
    base_lr=3e-4,
    residual_dtype=jnp.bfloat16,
)
