"""Gemma 3 1B [hf:google/gemma-3-1b-pt].

5:1 local:global attention interleave (local sliding window 512), MQA
(kv=1), head_dim 256 ≠ d_model/heads, 262144 vocab (largest embedding
table relative to model size in the pool).  The 5:1 pattern bounds most of
the KV cache → long_500k runs (global layers are O(L) decode reads).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="decoder",
    source="hf:google/gemma-3-1b-pt",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    local_global_ratio=5,
    local_window=512,
    rope_theta=1_000_000.0,
    gated_mlp=True,
    client_mode="data",
    local_opt="adam",
    base_lr=3e-4,
)
