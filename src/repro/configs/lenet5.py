"""LeNet5-Caffe on MNIST — the paper's smallest benchmark (§IV-A).

Trained with Adam @ 1e-3, batch 128×4 clients (paper Table III).
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="lenet5",
    family="cnn",
    source="paper §IV-A / Caffe MNIST tutorial",
    n_layers=0,
    d_model=0,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=0,
    img_size=28,
    img_channels=1,
    n_classes=10,
    local_opt="adam",
    base_lr=1e-3,
    dtype=jnp.float32,
    scan_layers=False,
    remat=False,
)
