"""Model / training configuration system.

Every assigned architecture is a :class:`ModelConfig` in its own module
(``src/repro/configs/<id>.py``) selectable via ``--arch <id>``.  The paper's
own models (LeNet5, ResNet32, Word/CharLSTM) are configs too, so the
reproduction experiments run through the same trainer as the 10 assigned
architectures.

``input_specs(cfg, shape)`` produces ``jax.ShapeDtypeStruct`` stand-ins for
every model input — weak-type-correct, shardable, zero allocation — which is
what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Optional

import jax
import jax.numpy as jnp

# ------------------------------------------------------------- input shapes

INPUT_SHAPES: dict[str, dict[str, int]] = {
    # name: seq_len, global_batch, kind
    "train_4k": dict(seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524_288, global_batch=1, kind="decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Fields cover every family in the assigned pool."""

    name: str
    family: str  # 'decoder' | 'encdec' | 'lstm' | 'cnn'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // n_heads
    source: str = ""  # paper / model-card citation

    # --- MoE
    moe_experts: int = 0
    moe_top_k: int = 2
    moe_every: int = 1  # MoE MLP every k-th layer (jamba: 2)
    moe_capacity_factor: float = 1.25  # train-time capacity (decode is dropless)
    # MoE dispatch strategy (§Perf A2-A5/B10 — GSPMD-verified per family):
    #   'grouped'   per-batch-row dispatch, weights replicated over 'data'
    #               (mixtral: E doesn't divide the data axis)
    #   'flat_ep'   global dispatch, experts sharded over 'data' (llama4)
    #   'flat_fsdp' global dispatch, fsdp-sharded weights (jamba)
    moe_dispatch: str = "grouped"

    # --- attention pattern
    window: int = 0  # sliding-window size (mixtral); 0 = full
    chunk_attn: int = 0  # chunked-local attention size (llama4)
    local_window: int = 0  # window of "local" layers in local:global mix
    local_global_ratio: int = 0  # gemma3: N local layers per 1 global
    global_every: int = 0  # llama4: full-attn layer every k-th (others chunked)

    # --- hybrid / SSM
    attn_every: int = 1  # jamba: attention every 8th layer, rest SSM
    ssm_kind: str = ""  # 'mamba' | 'rwkv6' ('' = attention everywhere)
    ssm_ffn: bool = False  # jamba: FFN/MoE after every mamba mixer too
    ssm_state: int = 16  # mamba N
    ssm_expand: int = 2  # mamba d_inner = expand·d_model
    ssm_conv: int = 4  # mamba depthwise conv width

    # --- misc transformer knobs
    qkv_bias: bool = False  # qwen1.5
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"  # 'rmsnorm' | 'layernorm'
    tie_embeddings: bool = True
    gated_mlp: bool = True  # SwiGLU-style
    dropout: float = 0.0

    # --- encoder-decoder
    enc_layers: int = 0
    bidirectional: bool = False  # encoder stacks: non-causal self-attention

    # --- modality frontend stub (audio/vision): inputs are precomputed
    # frame/patch embeddings of shape (batch, n_prefix, d_model)
    modality: str = "text"  # 'text' | 'audio' | 'vision'
    n_prefix: int = 0  # number of stub embedding positions

    # --- cnn / lstm (paper's own models)
    img_size: int = 0
    img_channels: int = 3
    n_classes: int = 10
    lstm_hidden: int = 0

    # --- distribution
    fsdp: bool = False  # shard params over 'data' too (≥20B archs)
    # DSGD client granularity on the production mesh (DESIGN.md §4):
    #   'data' — one client per data coordinate (16/pod); per-client residual
    #            lives on the client's model-axis chips.  Small/mid archs.
    #   'pod'  — one client per pod; dense all-reduce inside the pod (fast
    #            ICI), SBC compresses the cross-pod (DCN) exchange; residual
    #            shards over ('data','model').  Required for ≥20B archs where
    #            per-data-coordinate full-model state cannot fit.
    client_mode: str = "data"
    local_opt: str = "momentum"  # client-side optimizer for this arch
    base_lr: float = 0.01
    residual_dtype: Any = jnp.float32  # bf16 for ≥20B archs (DESIGN.md §8)
    remat: bool = True
    scan_layers: bool = True
    dtype: Any = jnp.bfloat16

    # --- which input shapes apply ('' reason = runs)
    skip_shapes: tuple[tuple[str, str], ...] = ()

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------- helpers

    @property
    def layer_kinds(self) -> list[str]:
        """Per-layer block kind: 'attn' | 'attn_local' | 'attn_chunk' | ssm."""
        kinds = []
        for i in range(self.n_layers):
            if self.ssm_kind and self.attn_every > 1:
                # jamba-style: attention on every `attn_every`-th layer
                # (placed mid-period as in the released model)
                kind = "attn" if (i % self.attn_every) == self.attn_every // 2 else self.ssm_kind
            elif self.ssm_kind:
                kind = self.ssm_kind
            elif self.local_global_ratio:
                r = self.local_global_ratio
                kind = "attn" if (i % (r + 1)) == r else "attn_local"
            elif self.global_every:
                kind = "attn" if (i % self.global_every) == self.global_every - 1 else "attn_chunk"
            elif self.window:
                kind = "attn_window"
            else:
                kind = "attn"
            if self.bidirectional and kind == "attn":
                kind = "attn_bidir"
            kinds.append(kind)
        return kinds

    @property
    def layer_moe(self) -> list[bool]:
        if not self.moe_experts:
            return [False] * self.n_layers
        return [(i % self.moe_every) == self.moe_every - 1 for i in range(self.n_layers)]

    @property
    def sub_quadratic(self) -> bool:
        """Bounded or recurrent context per token → long_500k applies."""
        if self.family in ("lstm",):
            return True
        if self.ssm_kind:
            return True
        # window / chunked / local-global bound MOST layers; the sparse
        # global layers are O(L) reads at decode, which is sub-quadratic.
        return bool(self.window or self.chunk_attn or self.local_global_ratio)

    def skip_reason(self, shape_name: str) -> Optional[str]:
        for s, reason in self.skip_shapes:
            if s == shape_name:
                return reason
        shape = INPUT_SHAPES[shape_name]
        if shape["kind"] == "decode" and self.family == "cnn":
            return "encoder-only CNN: no autoregressive decode step"
        if shape_name == "long_500k" and not self.sub_quadratic:
            return "pure full attention: long-context decode requires sub-quadratic attention"
        return None

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        total = V * d * (1 if self.tie_embeddings else 2)
        for kind, moe in zip(self.layer_kinds, self.layer_moe):
            if kind.startswith("attn"):
                total += d * n_q + 2 * d * n_kv + n_q * d
            else:  # ssm block
                di = self.ssm_expand * d
                if kind == "mamba":
                    total += d * 2 * di + di * d + di * (2 * self.ssm_state + 2)
                else:  # rwkv6: r,k,v,g,o,cr projections + decay LoRA + channel mix
                    total += 6 * d * d + 2 * d * self.d_ff + 2 * d * 64
            mlp = 3 * d * ff if self.gated_mlp else 2 * d * ff
            if moe:
                total += self.moe_experts * mlp + d * self.moe_experts
            elif not kind.startswith("rwkv"):
                total += mlp
            total += 2 * d  # norms
        if self.enc_layers:
            total += self.enc_layers * (2 * (d * n_q + 2 * d * n_kv + n_q * d) + 3 * d * ff)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.moe_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        mlp = 3 * d * ff if self.gated_mlp else 2 * d * ff
        inactive = sum(
            (self.moe_experts - self.moe_top_k) * mlp for m in self.layer_moe if m
        )
        return int(self.param_count() - inactive)


# ------------------------------------------------------------- input specs


def input_specs(cfg: ModelConfig, shape_name: str, n_clients: int = 1) -> dict:
    """ShapeDtypeStruct stand-ins for every input of (cfg, shape).

    train:    tokens/labels (clients, per_client_batch, seq) int32
              (+ prefix embeddings for audio/vision stubs)
    prefill:  tokens (batch, seq)
    decode:   tokens (batch, 1) + cache built by serve.init_cache specs
    """
    shape = INPUT_SHAPES[shape_name]
    S, B, kind = shape["seq_len"], shape["global_batch"], shape["kind"]
    f = jax.ShapeDtypeStruct

    if cfg.family == "cnn":
        img = (B, cfg.img_size, cfg.img_size, cfg.img_channels)
        if kind == "train":
            per = max(1, B // n_clients)
            return {
                "images": f((n_clients, per) + img[1:], jnp.float32),
                "labels": f((n_clients, per), jnp.int32),
            }
        return {"images": f(img, jnp.float32)}

    def _extras(lead: tuple[int, ...]) -> dict:
        """Modality-stub / encoder inputs (the DESIGN.md §7 carve-out)."""
        ex = {}
        if cfg.family == "encdec":
            if cfg.modality == "audio":
                # precomputed conformer-frontend frame embeddings
                ex["enc_frames"] = f(lead + (S, cfg.d_model), cfg.dtype)
            else:
                ex["enc_tokens"] = f(lead + (S,), jnp.int32)
        elif cfg.modality in ("audio", "vision"):
            # decoder-only early fusion: patch/frame embeddings as prefix
            ex["prefix"] = f(lead + (cfg.n_prefix, cfg.d_model), cfg.dtype)
        return ex

    if kind == "train":
        per = max(1, B // n_clients)
        specs = {
            "tokens": f((n_clients, per, S), jnp.int32),
            "labels": f((n_clients, per, S), jnp.int32),
        }
        specs.update(_extras((n_clients, per)))
        return specs

    if kind == "prefill":
        specs = {"tokens": f((B, S), jnp.int32)}
        specs.update(_extras((B,)))
        return specs

    # decode: one new token against a seq_len-deep cache
    return {"tokens": f((B, 1), jnp.int32)}


# ---------------------------------------------------------------- registry

ASSIGNED_ARCHS = [
    "seamless_m4t_medium",
    "granite_20b",
    "rwkv6_1p6b",
    "jamba_v01_52b",
    "mixtral_8x7b",
    "phi3_vision_4p2b",
    "command_r_35b",
    "qwen15_4b",
    "gemma3_1b",
    "llama4_maverick_400b_a17b",
]
PAPER_ARCHS = ["lenet5", "resnet32", "charlstm", "wordlstm"]


def get_config(name: str, **overrides: Any) -> ModelConfig:
    """Load ``src/repro/configs/<name>.py`` and return its CONFIG.

    Accepts either the module key (``qwen15_4b``) or the display id
    (``qwen1.5-4b``) — several dot/dash normalizations are tried.
    """
    aliases = {
        "phi-3-vision-4.2b": "phi3_vision_4p2b",
        "qwen1.5-4b": "qwen15_4b",
        "jamba-v0.1-52b": "jamba_v01_52b",
        "rwkv6-1.6b": "rwkv6_1p6b",
    }
    base = aliases.get(name, name).replace("-", "_")
    candidates = [name, base, base.replace(".", "p"), base.replace(".", ""),
                  base.replace(".", "_")]
    mod = None
    for key in candidates:
        try:
            mod = importlib.import_module(f"repro.configs.{key}")
            break
        except ModuleNotFoundError:
            continue
    if mod is None:
        raise KeyError(f"no config module found for {name!r} (tried {candidates})")
    cfg = mod.CONFIG
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def reduced(cfg: ModelConfig, **extra: Any) -> ModelConfig:
    """Smoke-test variant: ≤2 layers, d_model ≤ 256, ≤4 experts, tiny vocab.

    Keeps the FAMILY (layer pattern, MoE, SSM kind, GQA ratio) so smoke tests
    exercise the same code paths as the full config.
    """
    d = min(cfg.d_model, 256)
    heads = max(1, min(cfg.n_heads, 4))
    kv = max(1, min(cfg.n_kv_heads, heads))
    hd = max(8, d // heads)
    period = max(cfg.attn_every, (cfg.local_global_ratio + 1) if cfg.local_global_ratio else 1,
                 cfg.global_every or 1, cfg.moe_every)
    n_layers = min(cfg.n_layers, max(2, period))
    changes: dict[str, Any] = dict(
        n_layers=n_layers,
        d_model=d,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=hd,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        moe_experts=min(cfg.moe_experts, 4),
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_experts else cfg.moe_top_k,
        moe_capacity_factor=8.0,  # smoke scale: no capacity drops

        window=min(cfg.window, 64) if cfg.window else 0,
        chunk_attn=min(cfg.chunk_attn, 64) if cfg.chunk_attn else 0,
        local_window=min(cfg.local_window, 64) if cfg.local_window else 0,
        enc_layers=min(cfg.enc_layers, 2) if cfg.enc_layers else 0,
        n_prefix=min(cfg.n_prefix, 8) if cfg.n_prefix else 0,
        ssm_state=min(cfg.ssm_state, 8),
        lstm_hidden=min(cfg.lstm_hidden, 64) if cfg.lstm_hidden else 0,
        fsdp=False,
        dtype=jnp.float32,
    )
    changes.update(extra)
    return dataclasses.replace(cfg, **changes)
