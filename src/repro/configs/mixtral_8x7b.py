"""Mixtral 8x7B [arXiv:2401.04088].

Sparse MoE: 8 experts, top-2 routing on every layer; sliding-window
attention (W=4096) bounds the KV cache → long_500k runs.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="decoder",
    source="arXiv:2401.04088",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    moe_experts=8,
    moe_top_k=2,
    moe_every=1,
    window=4096,  # SWA
    moe_dispatch="grouped",
    fsdp=True,
    client_mode="pod",
    local_opt="sgd",
    base_lr=3e-4,
    residual_dtype=jnp.bfloat16,
)
