"""Delta-broadcast subsystem: SubscriberPool fan-out + fed-backend wiring.

Covers the §13 serving layers end-to-end: the per-lag-class plan/encode
sharing, the live bit-exactness verification, the BandwidthLedger
reconciliation on the broadcast path, the planner's byte-minimizing
choice (including the horizon-evicted full fallback), and the RunSpec /
FedWireChannel integration that lets the fed backend's downstream ride
the log instead of per-client re-compression.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import CompressionPolicy
from repro.fed.server import ParameterServer
from repro.serve.broadcast import CatchupPlanner, SubscriberPool, simulate_fanout


def small_server(horizon=4, down_sparsity=0.05):
    rng = np.random.default_rng(42)
    params = {
        "w": jnp.asarray(rng.normal(size=(2000,)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(50,)), jnp.float32),
    }
    return ParameterServer(
        params=params,
        up_policy=CompressionPolicy.single("sbc"),
        down_sparsity=down_sparsity,
        delta_horizon=horizon,
    )


def drive(server, pool, rounds, scale=1e-2, seed=0):
    rng = jax.random.PRNGKey(seed)
    infos = []
    for r in range(int(server.delta_log.head) + 1,
                   int(server.delta_log.head) + 1 + rounds):
        rng, sub = jax.random.split(rng)
        keys = jax.random.split(sub, 2)
        leaves, treedef = jax.tree.flatten(server.params)
        leaves = [
            x + scale * jax.random.normal(k, np.shape(x), x.dtype)
            for x, k in zip(leaves, keys)
        ]
        server.params = jax.tree.unflatten(treedef, leaves)
        server.broadcast(r)
        infos.append(pool.sync_round(r))
    return infos


class TestSubscriberPool:
    def test_fanout_reconciles_and_verifies(self):
        server = small_server(horizon=4)
        pool = SubscriberPool(
            log=server.delta_log, n_subscribers=500,
            periods=(1, 2, 6), verify_classes=4,
        )
        infos = drive(server, pool, rounds=12)
        pool.ledger.reconcile(rel=0.1)
        assert pool.verify_ok and pool.verified_syncs > 0
        # period-1 subscribers woke every round; period-6 only twice
        assert sum(i["awake"] for i in infos) > 12 * 500 / 3
        # lag-6 syncs exceeded horizon 4 — the evicted window forces full
        kinds = {k for i in infos for k in i["classes"].values()}
        assert "full" in kinds
        assert kinds & {"replay", "stacked"}  # in-horizon lags stay cheap

    def test_chosen_plan_beats_full_within_horizon(self):
        server = small_server(horizon=6)
        pool = SubscriberPool(log=server.delta_log, n_subscribers=10)
        drive(server, pool, rounds=8)
        planner = CatchupPlanner(server.delta_log)
        full = server.delta_log.full_nbytes()
        head = server.delta_log.head
        for lag in range(1, 7):
            plan = planner.plan(head - lag)
            assert plan.nbytes < full, f"lag {lag}: {plan.candidates}"

    def test_round_ordering_contract(self):
        server = small_server()
        pool = SubscriberPool(log=server.delta_log, n_subscribers=5)
        with pytest.raises(ValueError, match="append"):
            pool.sync_round(0)  # broadcast 0 not appended yet

    def test_pool_validation(self):
        server = small_server()
        with pytest.raises(ValueError, match="subscriber"):
            SubscriberPool(log=server.delta_log, n_subscribers=0)
        with pytest.raises(ValueError, match="periods"):
            SubscriberPool(log=server.delta_log, n_subscribers=4, periods=(0,))

    def test_simulate_fanout_metrics(self):
        rng = np.random.default_rng(1)
        params = {
            "w": jnp.asarray(rng.normal(size=(3000,)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(40,)), jnp.float32),
        }
        m = simulate_fanout(params, n_subscribers=300, rounds=8, horizon=4,
                            down_sparsity=0.02, periods=(1, 2, 4), seed=0)
        assert m["ledger_reconciles"] and m["stack_bit_exact"]
        assert m["catchup_beats_full_all_lags"]
        assert m["bytes_saving_vs_full_resync"] > 1.0
        assert m["bytes_per_subscriber_per_round"] > 0
        assert set(m["plan_by_lag"]) == {"1", "2", "3", "4"}


class TestFedIntegration:
    def test_broadcast_log_rides_the_channel(self):
        """The fed backend with --broadcast-log meters per-member catch-up
        plans instead of a per-member re-broadcast."""
        from repro.run.build import build_run
        from repro.run.spec import RunSpec

        spec = RunSpec(preset="lenet5", backend="fed", rounds=3, clients=4,
                       cohort=2, batch=4, seq_len=16, sparsity=0.01,
                       down_sparsity=0.05, broadcast_log=True, delta_horizon=4)
        run = build_run(spec)
        state = run.init()
        infos = [run.step(state, r)[1] for r in range(3)]
        # round 0: head is -1 before the first broadcast — nothing to pull
        assert infos[0]["down_bytes"] == 0
        assert infos[1]["down_bytes"] > 0
        log = run.channel.server.delta_log
        assert log is not None and log.head == 2
        recs = run.channel.ledger.records
        assert all(r.down_recipients == 2 for r in recs)
        # downstream measured-vs-analytic parity on the catch-up path
        for r in recs:
            if r.down_bits_analytic > 0:
                rel = abs(r.down_bits_measured - r.down_bits_analytic)
                assert rel <= 0.15 * r.down_bits_analytic

    def test_log_disabled_by_default(self):
        from repro.run.build import build_run
        from repro.run.spec import RunSpec

        spec = RunSpec(preset="lenet5", backend="fed", rounds=1, clients=2,
                       batch=4, seq_len=16)
        run = build_run(spec)
        run.init()
        assert run.channel.server.delta_log is None

    def test_spec_json_roundtrip_and_flags(self):
        from repro.run.flags import build_parser, spec_from_args
        from repro.run.spec import RunSpec

        spec = RunSpec(backend="fed", broadcast_log=True, delta_horizon=9)
        back = RunSpec.from_json(spec.to_json())
        assert back.broadcast_log is True and back.delta_horizon == 9
        args = build_parser().parse_args(
            ["--backend", "fed", "--broadcast-log", "--delta-horizon", "7"]
        )
        got = spec_from_args(args)
        assert got.broadcast_log is True and got.delta_horizon == 7
        assert spec_from_args(build_parser().parse_args([])).broadcast_log is False
