"""Docs cannot silently rot: every registry name, codec spec, CLI flag,
and module reference in README/docs/DESIGN must exist in the code.

Two directions:
  * accuracy — names the docs mention must exist (flags in some launcher
    parser, stage names in the registries, `a|b|c` specs composable,
    referenced modules importable);
  * completeness — every registered selector/quantizer/encoder/codec/
    compressor name must be documented somewhere.
"""
import importlib
import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = [REPO / "README.md", REPO / "DESIGN.md",
             *sorted((REPO / "docs").glob("*.md"))]

# launcher + harness modules that expose build_parser()
PARSER_MODULES = [
    "repro.run",
    "repro.launch.train",
    "repro.launch.dist",
    "repro.launch.fed",
    "repro.launch.serve",
    "repro.launch.dryrun",
    "repro.obs.view",
    "repro.scale",
    "benchmarks.run",
]


def doc_text() -> str:
    assert DOC_FILES[0].exists(), "README.md missing"
    return "\n".join(p.read_text() for p in DOC_FILES if p.exists())


def all_parser_flags() -> set:
    flags = set()
    for mod in PARSER_MODULES:
        ap = importlib.import_module(mod).build_parser()
        for action in ap._actions:
            flags.update(o for o in action.option_strings if o.startswith("--"))
    return flags


def registries():
    from repro.core import api
    from repro.core.codec import available_codecs
    from repro.core.stages import available_stages

    stages = available_stages()
    return {
        "selectors": set(stages["selectors"]),
        "quantizers": set(stages["quantizers"]),
        "encoders": set(stages["encoders"]),
        "codecs": set(available_codecs()),
        "compressors": set(api.available()),
    }


def test_documented_cli_flags_exist():
    """Every `--flag` in the docs parses in at least one launcher."""
    documented = set(re.findall(r"`(--[a-z][a-z0-9-]*)", doc_text()))
    assert documented, "docs mention no CLI flags — README table missing?"
    known = all_parser_flags()
    unknown = documented - known
    assert not unknown, f"docs mention nonexistent CLI flags: {sorted(unknown)}"


def test_shared_run_flags_are_documented():
    """Completeness: every flag on the SHARED add_run_flags parser (the
    surface every launcher builds on) must appear in README's CLI table —
    a new run flag cannot ship undocumented."""
    import argparse

    from repro.run.flags import add_run_flags

    ap = add_run_flags(argparse.ArgumentParser())
    flags = {
        o for action in ap._actions for o in action.option_strings
        if o.startswith("--") and o != "--help"
    }
    assert flags, "shared parser exposes no flags?"
    documented = set(re.findall(r"`(--[a-z][a-z0-9-]*)`", doc_text()))
    missing = flags - documented
    assert not missing, (
        f"shared add_run_flags() flags missing from the docs: "
        f"{sorted(missing)} — document them in README's CLI table"
    )


def test_registered_stage_and_codec_names_are_documented():
    """Completeness: every registered name appears in README/docs."""
    text = doc_text()
    missing = {
        kind: sorted(n for n in names if f"`{n}`" not in text)
        for kind, names in registries().items()
    }
    missing = {k: v for k, v in missing.items() if v}
    assert not missing, f"registered but undocumented names: {missing}"


def test_documented_spec_strings_compose():
    """Every `sel|quant|enc` spec in the docs is buildable from the
    registries (catches renames that orphan doc examples)."""
    regs = registries()
    specs = re.findall(r"`([a-z_0-9]+)\|([a-z_0-9]+)\|([a-z_0-9]+)`", doc_text())
    assert specs, "docs mention no codec spec strings"
    for sel, quant, enc in specs:
        assert sel in regs["selectors"], f"unknown selector {sel!r} in docs"
        assert quant in regs["quantizers"], f"unknown quantizer {quant!r} in docs"
        assert enc in regs["encoders"], f"unknown encoder {enc!r} in docs"


def test_referenced_modules_import():
    """`repro.launch.*` / `benchmarks.*` names in the docs must import."""
    text = doc_text()
    mods = set(re.findall(r"\b(repro\.launch\.[a-z_]+)\b", text))
    mods |= set(re.findall(r"\b(benchmarks\.[a-z_0-9]+)\b", text))
    assert mods
    for mod in sorted(mods):
        importlib.import_module(mod)


def test_benchmark_files_referenced_in_docs_exist():
    """`benchmarks/foo.py` / `docs/foo.md` paths in the docs must exist."""
    text = doc_text()
    for rel in set(re.findall(r"`((?:benchmarks|docs|experiments)/[\w./-]+)`", text)):
        assert (REPO / rel).exists(), f"docs reference missing file {rel!r}"


def test_observability_doc_covers_span_and_metric_registries():
    """Completeness both ways for the telemetry layer: every span name in
    SPAN_NAMES and every metric in METRIC_NAMES must appear (backticked)
    in docs/observability.md — a new instrumentation point cannot ship
    undocumented, and the doc cannot name spans/metrics that don't
    exist."""
    from repro.obs import METRIC_NAMES, SPAN_NAMES

    doc = (REPO / "docs" / "observability.md").read_text()
    missing = [n for n in SPAN_NAMES if f"`{n}`" not in doc]
    assert not missing, f"spans undocumented in docs/observability.md: {missing}"
    missing = [n for n in METRIC_NAMES if f"`{n}`" not in doc]
    assert not missing, f"metrics undocumented in docs/observability.md: {missing}"
    # accuracy: backticked span-like tokens in the doc's span table rows
    # must be registered names
    documented_spans = set(re.findall(r"^\| `([a-z_]+)` \|", doc, re.M))
    unknown = documented_spans - set(SPAN_NAMES) - set(METRIC_NAMES)
    assert not unknown, f"docs/observability.md names unknown spans: {unknown}"


def test_design_section_10_documents_flat_path():
    """DESIGN.md must carry the §10 FlatParamSpace layout contract the
    fast-path code points at."""
    design = (REPO / "DESIGN.md").read_text()
    assert "§10" in design and "FlatParamSpace" in design
    assert "fast=True" in design
