"""Golomb position coding (paper Alg. 3/4, Eq. 5) — exact round-trip +
property tests + agreement between the analytic bit model and the real
bitstream."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dev dep — fixed-grid fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import golomb


def test_bstar_paper_value():
    """Paper quotes b̄_pos = 8.38 at p = 0.01, but its OWN Eq. 5 formula
    b* = 1 + floor(log2(log(φ−1)/log(1−p))) gives b* = 6 → 8.11 bits
    (8.38 corresponds to b* = 7, which Eq. 5 rates strictly worse).  We
    follow the formula: the measured bitstream (test below) confirms 8.11
    bits/position — slightly BETTER than the paper's quoted figure.
    Recorded in EXPERIMENTS.md §Repro."""
    assert golomb.golomb_bstar(0.01) == 6
    assert abs(golomb.expected_position_bits(0.01) - 8.108) < 0.01
    # the paper's ×1.9-vs-16-bit claim still holds (ours is ×1.97)
    assert 16.0 / golomb.expected_position_bits(0.01) > 1.9


@pytest.mark.parametrize("p", [0.3, 0.1, 0.01, 0.001, 0.0001])
def test_roundtrip_random(p):
    rng = np.random.default_rng(42)
    n = 50_000
    mask = rng.random(n) < p
    idx = np.nonzero(mask)[0]
    if idx.size == 0:
        idx = np.array([7])
    bits = golomb.encode_positions(idx, p)
    back = golomb.decode_positions(bits, p)
    np.testing.assert_array_equal(idx, back)


@pytest.mark.parametrize("p", [0.1, 0.01, 0.001])
def test_bits_match_analytic_model(p):
    """Real bitstream length ≈ Eq. 5 expectation (±5%) on geometric data."""
    rng = np.random.default_rng(0)
    n = 2_000_000
    idx = np.nonzero(rng.random(n) < p)[0]
    bits = golomb.encode_positions(idx, p)
    per_pos = bits.size / idx.size
    expected = golomb.expected_position_bits(p)
    assert abs(per_pos - expected) / expected < 0.05


@given(
    idx=st.lists(st.integers(0, 10_000), min_size=1, max_size=200, unique=True),
    p=st.sampled_from([0.2, 0.05, 0.01, 0.002]),
)
@settings(max_examples=50, deadline=None)
def test_roundtrip_property(idx, p):
    idx = np.sort(np.asarray(idx))
    bits = golomb.encode_positions(idx, p)
    back = golomb.decode_positions(bits, p)
    np.testing.assert_array_equal(idx, back)


def test_message_roundtrip():
    idx = np.array([3, 77, 2048, 9999])
    msg = golomb.encode_sbc_message(idx, mean=0.125, p=0.01)
    dense = golomb.decode_sbc_message(msg, n=10_000)
    assert dense[idx].tolist() == [0.125] * 4
    assert np.count_nonzero(dense) == 4
    assert golomb.message_bits(msg) == msg["nbits_positions"] + 32


def test_worst_case_gap():
    # single survivor at the last position of a large tensor
    idx = np.array([999_999])
    bits = golomb.encode_positions(idx, 0.001)
    back = golomb.decode_positions(bits, 0.001)
    np.testing.assert_array_equal(idx, back)
