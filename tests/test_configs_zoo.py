"""Every config in ``repro/configs/`` abstract-evals end-to-end (ISSUE 10
satellite): parameters build as shapes via ``jax.eval_shape`` (zero
allocation — a 400B config must cost nothing but trace time), the model's
PartitionSpecs derive on the production-mesh rules of
:mod:`repro.models.model` against a device-free stub mesh, and the specs
are well-formed: known axes only, no axis reuse, divisible shard dims.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import (
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    PAPER_ARCHS,
    get_config,
    input_specs,
)
from repro.models.model import build_model
from repro.scale.costs import StubMesh

ALL = PAPER_ARCHS + ASSIGNED_ARCHS
MESH = StubMesh(shape=(16, 16))  # the 256-chip production mesh shape


@pytest.fixture(scope="module")
def abstract_params():
    cache = {}

    def build(name):
        if name not in cache:
            model = build_model(get_config(name))
            cache[name] = (
                model,
                jax.eval_shape(model.init, jax.random.PRNGKey(0)),
            )
        return cache[name]

    return build


@pytest.mark.parametrize("name", ALL)
def test_params_build_abstractly(name, abstract_params):
    """Full-size init traces without allocating a single parameter."""
    _, params = abstract_params(name)
    leaves = jax.tree_util.tree_leaves(params)
    assert leaves, name
    for leaf in leaves:
        assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)
    total = sum(int(np.prod(x.shape)) if x.shape else 1 for x in leaves)
    assert total > 0


@pytest.mark.parametrize(
    "name", [n for n in ALL if get_config(n).family in ("decoder", "encdec")]
)
def test_analytic_param_count_tracks_abstract_total(name, abstract_params):
    """``cfg.param_count()`` (what the analytic planner tier prices) must
    stay within a small band of the true abstract total — transformer
    families only; the formula is explicitly not for cnn/lstm."""
    _, params = abstract_params(name)
    total = sum(
        int(np.prod(x.shape)) if x.shape else 1
        for x in jax.tree_util.tree_leaves(params)
    )
    est = get_config(name).param_count()
    assert 1 / 3 < est / total < 3, (est, total)


@pytest.mark.parametrize("name", ALL)
def test_partition_specs_validate_on_stub_mesh(name, abstract_params):
    model, params = abstract_params(name)
    specs = model.param_specs(params, MESH)
    p_leaves, p_def = jax.tree_util.tree_flatten(params)
    s_leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: x is None or hasattr(x, "_normalized_spec")
        or type(x).__name__ == "PartitionSpec"
    )
    assert len(s_leaves) == len(p_leaves), name
    axis_size = MESH.shape_map
    for leaf, spec in zip(p_leaves, s_leaves):
        entries = tuple(spec)
        assert len(entries) <= leaf.ndim, (spec, leaf.shape)
        used = []
        for j, entry in enumerate(entries):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for ax in axes:
                assert ax in MESH.axis_names, (name, spec)
                assert ax not in used, f"{name}: axis {ax} reused in {spec}"
                used.append(ax)
                assert leaf.shape[j] % axis_size[ax] == 0, (
                    f"{name}: dim {j} of {leaf.shape} not divisible by "
                    f"{ax}={axis_size[ax]} in {spec}"
                )


@pytest.mark.parametrize("name", ALL)
def test_input_specs_cover_applicable_shapes(name):
    """Every (config, input-shape) pair either declares a skip reason or
    produces ShapeDtypeStruct stand-ins for all inputs."""
    cfg = get_config(name)
    saw_one = False
    for shape_name in INPUT_SHAPES:
        if cfg.skip_reason(shape_name):
            continue
        saw_one = True
        specs = input_specs(cfg, shape_name, n_clients=4)
        assert specs
        for v in specs.values():
            assert isinstance(v, jax.ShapeDtypeStruct)
            assert all(d > 0 for d in v.shape)
    assert saw_one, f"{name} skips every input shape"
