"""Pallas kernel validation: shape/dtype sweeps, allclose vs ref.py oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dev dep — fixed-grid fallback
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.binarize_apply import binarize_apply
from repro.kernels.hist2side import SPAN_OCTAVES, hist2side
from repro.kernels.moments import masked_moments

SHAPES = [63, 1024, 4096, 100_000, 262_145]
DTYPES = [jnp.float32, jnp.bfloat16]


def _x(seed, n, dtype=jnp.float32):
    return (jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 2.0).astype(dtype)


class TestHist2Side:
    @pytest.mark.parametrize("n", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_ref(self, n, dtype):
        x = _x(0, n, dtype)
        absmax = float(jnp.max(jnp.abs(x.astype(jnp.float32)))) + 1e-30
        lo, hi = absmax * 2.0**-SPAN_OCTAVES, absmax * 1.0001
        got = hist2side(x.astype(jnp.float32), lo, hi, nbins=64, bm=32, lanes=128)
        want = ref.hist2side_ref(x.astype(jnp.float32), lo, hi, nbins=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))

    def test_total_count(self):
        x = _x(1, 10_000)
        absmax = float(jnp.max(jnp.abs(x))) + 1e-30
        h = hist2side(x, absmax * 2.0**-SPAN_OCTAVES, absmax * 1.0001)
        # all nonzero entries land in some bucket
        assert float(jnp.sum(h)) == float(jnp.sum(x != 0))

    def test_per_side_ranges(self):
        x = jnp.array([0.5, -0.5, 2.0, -2.0, 0.01, -0.01])
        lo = jnp.array([0.4, 1.0])  # side 0 (pos) range vs side 1 (neg) range
        hi = jnp.array([1.0, 4.0])
        got = hist2side(x, lo, hi, nbins=8, bm=8, lanes=128)
        want = ref.hist2side_ref(x, lo, hi, nbins=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))
        assert float(jnp.sum(got[0])) == 1  # only +0.5
        assert float(jnp.sum(got[1])) == 1  # only -2.0


class TestMaskedMoments:
    @pytest.mark.parametrize("n", SHAPES)
    def test_matches_ref(self, n):
        x = _x(2, n)
        got = masked_moments(x, 0.7, 0.9, bm=32, lanes=128)
        want = ref.masked_moments_ref(x, 0.7, 0.9)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


class TestBinarizeApply:
    @pytest.mark.parametrize("n", SHAPES)
    def test_matches_ref(self, n):
        x = _x(3, n)
        for pos_wins in (1.0, 0.0):
            got_out, got_res = binarize_apply(x, 0.5, 0.6, 0.55, pos_wins,
                                              bm=32, lanes=128)
            want_out, want_res = ref.binarize_apply_ref(x, 0.5, 0.6, 0.55, pos_wins)
            np.testing.assert_allclose(np.asarray(got_out), np.asarray(want_out))
            np.testing.assert_allclose(np.asarray(got_res), np.asarray(want_res))

    def test_residual_identity(self):
        x = _x(4, 5000)
        out, res = binarize_apply(x, 0.5, 0.5, 1.0, 1.0)
        np.testing.assert_allclose(np.asarray(out + res), np.asarray(x), rtol=1e-6)


class TestFullPipeline:
    @pytest.mark.parametrize("n", [4096, 50_000])
    @pytest.mark.parametrize("p", [0.05, 0.01])
    def test_hist_close_to_exact(self, n, p):
        """Histogram-threshold SBC ≈ exact top-k SBC (the paper's Alg. 2):
        survivor count within ±2% of k, means within 2%."""
        x = _x(5, n)
        got = ops.sbc_compress_hist(x, p=p)
        want = ops.sbc_compress_exact(x, p=p)
        k = max(1, round(p * n))
        assert abs(float(got.count) - k) <= max(2, 0.02 * k)
        assert abs(float(got.mean) - float(want.mean)) <= 0.02 * abs(float(want.mean))

    def test_exact_matches_oracle(self):
        x = _x(6, 8192)
        k = 82
        got = ops.sbc_compress_exact(x, p=0.01)
        want = ref.sbc_exact_ref(x, k)
        np.testing.assert_allclose(np.asarray(got.delta_star), np.asarray(want),
                                   rtol=1e-5)

    @given(seed=st.integers(0, 40), logn=st.integers(8, 14))
    @settings(max_examples=20, deadline=None)
    def test_hist_residual_identity_property(self, seed, logn):
        n = 2**logn + seed % 7  # off-aligned sizes exercise padding
        x = _x(seed, n)
        out = ops.sbc_compress_hist(x, p=0.02)
        np.testing.assert_allclose(
            np.asarray(out.delta_star + out.residual), np.asarray(x), rtol=1e-5,
            atol=1e-6,
        )

    def test_all_equal_values(self):
        """Degenerate input: all entries identical."""
        x = jnp.ones((1000,))
        out = ops.sbc_compress_hist(x, p=0.01)
        assert bool(jnp.all(jnp.isfinite(out.delta_star)))

    def test_dense_to_sparse_extraction(self):
        x = jnp.zeros((100,)).at[jnp.array([3, 50, 99])].set(2.5)
        idx, valid = ops.dense_to_sparse(x, k_cap=8)
        assert set(np.asarray(idx[:3]).tolist()) == {3, 50, 99}
        np.testing.assert_array_equal(np.asarray(valid), [1, 1, 1, 0, 0, 0, 0, 0])
