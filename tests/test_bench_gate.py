"""The benchmark regression gate (benchmarks/check_regression.py) must
pass on identical dirs, tolerate noisy-but-sane timing drift, and fail on
correctness drift or large speed regressions."""

import json

import pytest

from benchmarks.check_regression import RATIO_BAND, main

BASE = {
    "n_devices": 8,
    "n_clients": 4,
    "n_params": 1000,
    "bits_per_client": 5e4,
    "speedup": 3.0,
    "compile_speedup": 1.5,
    "parity": True,
    "bits_equal": True,
}


def write(dirpath, payload):
    dirpath.mkdir(exist_ok=True)
    (dirpath / "dist_flat.json").write_text(json.dumps(payload))


def run_gate(tmp_path, fresh):
    write(tmp_path / "base", BASE)
    write(tmp_path / "fresh", fresh)
    base_dir = str(tmp_path / "base")
    fresh_dir = str(tmp_path / "fresh")
    return main(["--baseline", base_dir, "--fresh", fresh_dir])


def test_identical_passes(tmp_path):
    assert run_gate(tmp_path, dict(BASE)) == 0


def test_timing_noise_within_band_passes(tmp_path):
    fresh = dict(BASE, speedup=BASE["speedup"] / (RATIO_BAND - 0.5))
    assert run_gate(tmp_path, fresh) == 0


def test_speed_regression_fails(tmp_path):
    fresh = dict(BASE, speedup=BASE["speedup"] / (RATIO_BAND + 1.0))
    assert run_gate(tmp_path, fresh) == 1


def test_parity_flip_fails(tmp_path):
    assert run_gate(tmp_path, dict(BASE, parity=False)) == 1


def test_structural_drift_fails(tmp_path):
    assert run_gate(tmp_path, dict(BASE, n_params=999)) == 1
    assert run_gate(tmp_path, dict(BASE, bits_per_client=6e4)) == 1


def test_empty_intersection_fails(tmp_path):
    (tmp_path / "base").mkdir()
    (tmp_path / "fresh").mkdir()
    base_dir = str(tmp_path / "base")
    fresh_dir = str(tmp_path / "fresh")
    assert main(["--baseline", base_dir, "--fresh", fresh_dir]) == 1


def test_missing_gated_field_fails(tmp_path):
    fresh = dict(BASE)
    del fresh["speedup"]
    assert run_gate(tmp_path, fresh) == 1


@pytest.mark.parametrize("field", ["parity", "bits_equal"])
def test_true_fields_must_be_present(tmp_path, field):
    fresh = dict(BASE)
    del fresh[field]
    assert run_gate(tmp_path, fresh) == 1
