"""The benchmark regression gate (benchmarks/check_regression.py) must
pass on identical dirs, tolerate noisy-but-sane timing drift, and fail on
correctness drift or large speed regressions."""

import json

import pytest

from benchmarks.check_regression import RATIO_BAND, main

BASE = {
    "n_devices": 8,
    "n_clients": 4,
    "n_params": 1000,
    "bits_per_client": 5e4,
    "speedup": 3.0,
    "compile_speedup": 1.5,
    "wire_speedup": 1.3,
    "wire_bytes": 25921,
    "parity": True,
    "pack_parity": True,
    "bits_equal": True,
    "wire_bytes_equal": True,
}


def write(dirpath, payload, stem="dist_flat"):
    dirpath.mkdir(exist_ok=True)
    (dirpath / f"{stem}.json").write_text(json.dumps(payload))


def run_gate(tmp_path, fresh):
    write(tmp_path / "base", BASE)
    write(tmp_path / "fresh", fresh)
    base_dir = str(tmp_path / "base")
    fresh_dir = str(tmp_path / "fresh")
    return main(["--baseline", base_dir, "--fresh", fresh_dir])


def test_identical_passes(tmp_path):
    assert run_gate(tmp_path, dict(BASE)) == 0


def test_timing_noise_within_band_passes(tmp_path):
    fresh = dict(BASE, speedup=BASE["speedup"] / (RATIO_BAND - 0.5))
    assert run_gate(tmp_path, fresh) == 0


def test_speed_regression_fails(tmp_path):
    fresh = dict(BASE, speedup=BASE["speedup"] / (RATIO_BAND + 1.0))
    assert run_gate(tmp_path, fresh) == 1


def test_parity_flip_fails(tmp_path):
    assert run_gate(tmp_path, dict(BASE, parity=False)) == 1


def test_structural_drift_fails(tmp_path):
    assert run_gate(tmp_path, dict(BASE, n_params=999)) == 1
    assert run_gate(tmp_path, dict(BASE, bits_per_client=6e4)) == 1


def test_empty_intersection_fails(tmp_path):
    (tmp_path / "base").mkdir()
    (tmp_path / "fresh").mkdir()
    base_dir = str(tmp_path / "base")
    fresh_dir = str(tmp_path / "fresh")
    assert main(["--baseline", base_dir, "--fresh", fresh_dir]) == 1


def test_missing_gated_field_fails(tmp_path):
    fresh = dict(BASE)
    del fresh["speedup"]
    assert run_gate(tmp_path, fresh) == 1


@pytest.mark.parametrize(
    "field", ["parity", "pack_parity", "bits_equal", "wire_bytes_equal"]
)
def test_true_fields_must_be_present(tmp_path, field):
    fresh = dict(BASE)
    del fresh[field]
    assert run_gate(tmp_path, fresh) == 1


def test_wire_speedup_regression_fails(tmp_path):
    fresh = dict(BASE, wire_speedup=BASE["wire_speedup"] / (RATIO_BAND + 1.0))
    assert run_gate(tmp_path, fresh) == 1


def test_unruled_fresh_json_fails(tmp_path):
    # a fresh JSON with no RULES entry must fail loudly, not pass silently
    write(tmp_path / "base", BASE)
    write(tmp_path / "fresh", dict(BASE))
    write(tmp_path / "fresh", {"anything": 1}, stem="mystery_bench")
    base_dir = str(tmp_path / "base")
    fresh_dir = str(tmp_path / "fresh")
    assert main(["--baseline", base_dir, "--fresh", fresh_dir]) == 1


def test_ungated_and_trace_artifacts_are_exempt(tmp_path):
    write(tmp_path / "base", BASE)
    write(tmp_path / "fresh", dict(BASE))
    # fed_round is on the UNGATED record; telemetry traces are validated
    # by repro.obs.view --check, not the regression gate
    write(tmp_path / "fresh", {"rounds": 2}, stem="fed_round")
    (tmp_path / "fresh" / "smoke.trace.json").write_text("{}")
    base_dir = str(tmp_path / "base")
    fresh_dir = str(tmp_path / "fresh")
    assert main(["--baseline", base_dir, "--fresh", fresh_dir]) == 0


def test_list_rows_match_by_rule_key(tmp_path):
    # wire_throughput rows key on "codec", not the default "arch"
    rows = [
        {
            "codec": "sbc",
            "n": 10,
            "p": 0.01,
            "packed_bytes": 64,
            "measured_bits": 512.0,
        }
    ]
    write(tmp_path / "base", BASE)
    write(tmp_path / "base", rows, stem="wire_throughput")
    write(tmp_path / "fresh", dict(BASE))
    write(tmp_path / "fresh", rows, stem="wire_throughput")
    base_dir = str(tmp_path / "base")
    fresh_dir = str(tmp_path / "fresh")
    assert main(["--baseline", base_dir, "--fresh", fresh_dir]) == 0
    drifted = [dict(rows[0], packed_bytes=99)]
    write(tmp_path / "fresh", drifted, stem="wire_throughput")
    assert main(["--baseline", base_dir, "--fresh", fresh_dir]) == 1
