"""Elastic fault tolerance (DESIGN.md §14): every claim is bit-level.

The elasticity contract this file pins, scenario by scenario, on the
deterministic :mod:`faults` harness:

  * a client whose participation FAILS (drop, straggler abort, corrupt
    upload) leaves its pooled residual/momentum/rng byte-identical to
    never having run — error feedback must not double-count;
  * partial aggregation IS survivors-only aggregation: a server that
    rejects k of n uploads lands on exactly the bytes of a server that
    only ever saw the n−k survivors (property-tested across all three
    aggregators);
  * aborted and rejected uploads are metered as wasted bytes, and the
    ledger still reconciles measured-vs-analytic in dropout rounds;
  * a rejoining failed client re-enters at its TRUE staleness (rounds
    since its last successful download), not a random draw;
  * the tiled cohort executor and the spilled (host/memmap) client
    stores are bit-transparent: tiling/spilling changes memory, never
    results;
  * a ``kill_server`` fault raises :class:`ServerKilled` exactly once,
    and a ``post_aggregate`` kill resumes through
    :meth:`RoundScheduler.resume_pending` onto the uninterrupted
    trajectory.
"""
import dataclasses

import numpy as np
import pytest

from faults import (
    NO_FAULTS,
    FaultSchedule,
    ServerKilled,
    assert_trees_bitwise,
    capture_state,
    craft_upload,
    make_federation,
    run_rounds,
    straggler_ids,
)

try:  # property-based when hypothesis is installed, fixed grid otherwise
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st


# ---------------------------------------------------------- schedule object


class TestFaultSchedule:
    def test_json_round_trip(self):
        fs = FaultSchedule(
            seed=3, drops=((1, 2), (4, 0)), slow=((2, 1, 8.0),),
            corrupt=((3, 5),), kill_server=((2, "post_aggregate"),),
        )
        assert FaultSchedule.from_json(fs.to_json()) == fs
        assert FaultSchedule.parse(fs.to_json()) == fs

    def test_parse_file(self, tmp_path):
        fs = FaultSchedule(drops=((0, 1),))
        p = tmp_path / "faults.json"
        p.write_text(fs.to_json())
        assert FaultSchedule.parse(str(p)) == fs
        with pytest.raises(ValueError, match="neither"):
            FaultSchedule.parse(str(tmp_path / "missing.json"))

    def test_validation(self):
        with pytest.raises(ValueError, match="slowdown"):
            FaultSchedule(slow=((0, 1, 0.5),))
        with pytest.raises(ValueError, match="kill_server step"):
            FaultSchedule(kill_server=((0, "mid_broadcast"),))
        with pytest.raises(ValueError, match="one kill_server"):
            FaultSchedule(kill_server=((0, "pre_round"), (0, "post_aggregate")))
        with pytest.raises(ValueError, match="unknown FaultSchedule fields"):
            FaultSchedule.from_json('{"dropz": []}')

    def test_queries(self):
        fs = FaultSchedule(
            drops=((1, 2), (1, 3)), slow=((2, 1, 8.0),), corrupt=((3, 5),),
            kill_server=((4, "pre_round"),),
        )
        assert fs.drops_at(1) == frozenset({2, 3}) and fs.drops_at(0) == frozenset()
        assert fs.corrupts_at(3) == frozenset({5})
        assert fs.slowdown_of(2, 1) == 8.0 and fs.slowdown_of(2, 0) == 1.0
        assert fs.kill_at(4) == "pre_round" and fs.kill_at(1) is None
        assert fs.last_round() == 4 and NO_FAULTS.last_round() == -1

    def test_corrupt_blob_is_seeded_and_damaging(self):
        blob = bytes(range(256)) * 4
        fs = FaultSchedule(seed=7)
        a = fs.corrupt_blob(blob, 2, 5)
        assert a == fs.corrupt_blob(blob, 2, 5), "same (seed,round,client) must repeat"
        assert a != blob and len(a) < len(blob), "must truncate"
        assert a != fs.corrupt_blob(blob, 2, 6), "different client, different damage"
        assert a != FaultSchedule(seed=8).corrupt_blob(blob, 2, 5)
        assert fs.corrupt_blob(b"1234", 0, 0) == b""

    def test_straggler_ids(self):
        fs = FaultSchedule(slow=((1, 4, 100.0), (1, 5, 2.0)))
        delays = {c: 2 for c in range(8)}
        assert straggler_ids(fs, 1, range(8), delays, None) == frozenset()
        # delay 2 × slowdown {100, 2} vs timeout 10: only ×100 exceeds it
        assert straggler_ids(fs, 1, range(8), delays, 10.0) == frozenset({4})
        # a tight timeout stalls everyone even with no scheduled slowdowns
        assert straggler_ids(None, 0, range(3), delays, 1.0) == frozenset({0, 1, 2})


# ---------------------------------------- partial aggregation == survivors


class TestPartialAggregationProperty:
    """ISSUE 8 satellite: receive() with rejects must land bit-identically
    on the survivors-only aggregation, for every aggregator — the
    survivor-weighted mean is renormalized over survivors by construction,
    so no reference rerun with a different weight vector can diverge."""

    @settings(max_examples=24, deadline=None)
    @given(
        agg=st.sampled_from(["mean", "weighted", "staleness"]),
        n_uploads=st.integers(min_value=2, max_value=5),
        mask_seed=st.integers(min_value=0, max_value=2),
    )
    def test_partial_equals_survivors_only(self, agg, n_uploads, mask_seed):
        sched = make_federation(agg=agg)
        srv = sched.server
        ups = [
            craft_upload(srv, c, seed=11, weight=1.0 + c, staleness=c % 3)
            for c in range(n_uploads)
        ]
        rng = np.random.default_rng([mask_seed, n_uploads])
        corrupt = {int(c) for c in rng.choice(n_uploads, size=rng.integers(1, n_uploads), replace=False)}
        fs = FaultSchedule(seed=mask_seed)
        damaged = [
            u._replace(blob=fs.corrupt_blob(u.blob, 0, u.client_id))
            if u.client_id in corrupt else u
            for u in ups
        ]
        m = srv.receive(damaged, 0)
        assert sorted(m["rejected"]) == sorted(corrupt)
        assert m["accepted"] == [u.client_id for u in ups if u.client_id not in corrupt]

        ref = make_federation(agg=agg).server
        m_ref = ref.receive([u for u in ups if u.client_id not in corrupt], 0)
        assert_trees_bitwise(srv.params, ref.params, "params")
        assert np.asarray(m["weights"]).tobytes() == np.asarray(m_ref["weights"]).tobytes()
        assert m["up_bits_measured"] == m_ref["up_bits_measured"]
        if m["accepted"]:
            assert np.asarray(m["weights"]).sum() == pytest.approx(1.0)

    def test_zero_survivors_is_a_zero_update(self):
        sched = make_federation()
        srv = sched.server
        before = capture_state(sched)
        ups = [craft_upload(srv, c, seed=1) for c in range(3)]
        fs = FaultSchedule(seed=0)
        m = srv.receive(
            [u._replace(blob=fs.corrupt_blob(u.blob, 0, u.client_id)) for u in ups], 0
        )
        assert m["accepted"] == [] and sorted(m["rejected"]) == [0, 1, 2]
        assert m["update_norm"] == 0.0 and m["up_bits_measured"] == 0.0
        assert_trees_bitwise(capture_state(sched), before, "all-rejected round")


# ------------------------------------------------------------ drop / rejoin


class TestDropoutRejoin:
    def test_dropped_client_state_untouched_and_unmetered(self):
        # round-1 cohort of the seed-0 micro federation contains client 2
        fs = FaultSchedule(drops=((1, 2),))
        sched = make_federation(faults=fs)
        run_rounds(sched, 1)
        assert 2 in set(int(c) for c in sched.pool.sample_cohort(1, 5))
        before = sched.pool.snapshot_clients([2])
        m = sched.step(1)
        assert m["dropped"] == [2] and 2 not in m["accepted"]
        after = sched.pool.snapshot_clients([2])
        assert_trees_bitwise(after, before, "dropped client rows")
        rec = sched.ledger.records[-1]
        assert 2 not in rec.cohort, "dropped client must not be in the record"
        # excluded BEFORE download: a drop costs nothing in either direction
        assert rec.down_recipients == len(rec.cohort) == 4
        assert rec.up_bytes_wasted == 0
        sched.ledger.reconcile(rel=0.12)

    def test_rejoin_reenters_at_true_staleness(self):
        fs = FaultSchedule(drops=((1, 2),))
        sched = make_federation(faults=fs)
        downloads = {}
        for r in range(6):
            cohort = [int(c) for c in sched.pool.sample_cohort(r, 5)]
            participants = [c for c in cohort if (r, c) not in {(1, 2)}]
            m = sched.step(r)
            assert m["accepted"] == participants  # only the drop fault fires
            if r > 1 and 2 in participants:
                cap = min(sched.max_staleness, r)  # ring holds r+1 entries
                # last successful download, or the ring cap if it never did
                expect = min(r - downloads[2], cap) if 2 in downloads else cap
                got = int(np.asarray(m["staleness"])[participants.index(2)])
                assert got == expect, (
                    f"round {r}: rejoin staleness {got} != true lag {expect}"
                )
                break
            for c in participants:
                downloads[c] = r
        else:
            pytest.fail("client 2 never rejoined within 6 rounds")

    def test_failure_free_schedule_is_the_original_trajectory(self):
        """Attaching an EMPTY schedule (or none) must not perturb a run —
        the fault machinery is bit-transparent when nothing fires."""
        a = make_federation(faults=None)
        b = make_federation(faults=NO_FAULTS, straggler_timeout=1e9)
        run_rounds(a, 3), run_rounds(b, 3)
        assert_trees_bitwise(capture_state(a), capture_state(b), "no-op faults")
        assert [dataclasses.asdict(r) for r in a.ledger.records] == \
               [dataclasses.asdict(r) for r in b.ledger.records]


# ------------------------------------------------------- straggler timeouts


class TestStragglerTimeout:
    def test_straggler_rolled_back_and_metered_as_waste(self):
        fs = FaultSchedule(slow=((1, 4, 100.0),))
        sched = make_federation(faults=fs, straggler_timeout=10.0)
        run_rounds(sched, 1)
        before = sched.pool.snapshot_clients([4])
        m = sched.step(1)
        assert m["stragglers"] == [4] and 4 not in m["accepted"]
        # work was done, bytes were wasted — but state is as if it never ran
        assert m["up_bytes_wasted"] > 0
        assert_trees_bitwise(
            sched.pool.snapshot_clients([4]), before, "straggler rows"
        )
        rec = sched.ledger.records[-1]
        assert 4 not in rec.cohort
        assert rec.up_bytes_wasted == m["up_bytes_wasted"]
        # the straggler DID download (it started the round)
        assert rec.down_recipients == len(rec.cohort) + 1
        sched.ledger.reconcile(rel=0.12)
        assert sched.ledger.totals()["up_bytes_wasted"] == m["up_bytes_wasted"]

    def test_all_stragglers_apply_a_zero_update(self):
        sched = make_federation(straggler_timeout=0.5)  # delay=2 > 0.5: everyone
        w_before = capture_state(sched)["server/params"]
        m = sched.step(0)
        assert m["accepted"] == [] and len(m["stragglers"]) == 5
        assert np.isnan(m["loss"]) and m["update_norm"] == 0.0
        assert_trees_bitwise(
            capture_state(sched)["server/params"], w_before, "zero-survivor W"
        )
        sched.ledger.reconcile(rel=0.12)


# ------------------------------------------------------ corrupt-upload fuzz


class TestCorruptUploadFuzz:
    def test_corrupt_uploads_never_poison_state(self):
        """Seeded corruption across several rounds: the server drops the
        client cleanly, finishes the round over the survivors, the victim's
        pool rows stay bitwise pristine, and it is re-accepted on its next
        clean round."""
        fs = FaultSchedule(seed=5, corrupt=((1, 3), (2, 5), (2, 7)))
        sched = make_federation(faults=fs)
        victims = {1: [3], 2: [5, 7]}
        reaccepted = False
        for r in range(4):
            cohort = {int(c) for c in sched.pool.sample_cohort(r, 5)}
            hit = sorted(set(victims.get(r, [])) & cohort)
            before = sched.pool.snapshot_clients(hit)
            m = sched.step(r)
            assert m["rejected"] == hit
            assert not set(hit) & set(m["accepted"])
            if hit:
                assert_trees_bitwise(
                    sched.pool.snapshot_clients(hit), before,
                    f"round {r} corrupt-victim rows",
                )
                assert m["up_bytes_wasted"] > 0
            if r > 2 and set(m["accepted"]) & {3, 5, 7}:
                reaccepted = True
            if m["accepted"]:
                assert np.isfinite(m["loss"])
        assert reaccepted, "no corrupt victim was ever accepted again"
        sched.ledger.reconcile(rel=0.12)

    def test_many_corruption_seeds_all_reject(self):
        """Fuzz the decode surface: every seeded damage pattern of a real
        SBW1 upload must be REJECTED (never mis-decoded) and must leave the
        server untouched."""
        sched = make_federation()
        srv = sched.server
        up = craft_upload(srv, 0, seed=2)
        before = capture_state(sched)
        for seed in range(8):
            bad = FaultSchedule(seed=seed).corrupt_blob(up.blob, 0, 0)
            m = srv.receive([up._replace(blob=bad)], 0)
            assert m["rejected"] == [0] and m["accepted"] == []
        assert_trees_bitwise(capture_state(sched), before, "fuzzed server")


# ------------------------------------------- tiled executor / spilled store


class TestTiledExecutorParity:
    def test_tile_and_store_are_bit_transparent(self, tmp_path):
        """The tiled executor + host/memmap spill change WHERE client state
        lives and how many members one compiled step covers — never a bit
        of the result."""
        ref = make_federation()
        run_rounds(ref, 2)
        want = capture_state(ref)
        for tile, store in ((3, "host"), (2, "memmap")):
            alt = make_federation(
                cohort_tile=tile, store=store,
                store_dir=str(tmp_path / store) if store == "memmap" else None,
            )
            run_rounds(alt, 2)
            assert_trees_bitwise(
                capture_state(alt), want, f"tile={tile} store={store}"
            )
            assert [dataclasses.asdict(r) for r in alt.ledger.records] == \
                   [dataclasses.asdict(r) for r in ref.ledger.records]

    def test_tile_one_is_sequential_but_identical(self):
        ref = make_federation()
        alt = make_federation(cohort_tile=1)
        run_rounds(ref, 1), run_rounds(alt, 1)
        assert_trees_bitwise(capture_state(alt), capture_state(ref), "tile=1")

    def test_memmap_store_is_lazy(self, tmp_path):
        """Zero-initialized leaves are never written at init: a fresh
        spilled pool's logical bytes dwarf what a cohort actually touches."""
        sched = make_federation(store="memmap", store_dir=str(tmp_path / "m"),
                                cohort_tile=2)
        sched.pool.init(sched.server.params)
        logical = sched.pool.state_nbytes()
        assert logical > 0
        import os
        on_disk = sum(
            os.stat(os.path.join(dp, f)).st_blocks * 512
            for dp, _, fs in os.walk(tmp_path) for f in fs
        )
        assert on_disk < logical, (
            f"memmap init materialized {on_disk}B of {logical}B logical state"
        )


# ----------------------------------------------------------- server kills


class TestServerKill:
    def test_pre_round_kill_fires_exactly_once(self):
        fs = FaultSchedule(kill_server=((1, "pre_round"),))
        sched = make_federation(faults=fs)
        run_rounds(sched, 1)
        with pytest.raises(ServerKilled) as ei:
            sched.step(1)
        assert ei.value.round_idx == 1 and ei.value.step == "pre_round"
        # the fired kill is consumed: the retried round proceeds normally
        m = sched.step(1)
        assert m["round"] == 1 and m["accepted"]

    def test_post_aggregate_kill_resumes_onto_the_same_trajectory(self):
        fs = FaultSchedule(drops=((1, 2),), kill_server=((2, "post_aggregate"),))
        sched = make_federation(faults=fs, delta_horizon=4)
        run_rounds(sched, 2)
        with pytest.raises(ServerKilled):
            sched.step(2)
        assert sched.channel._pending is not None
        m = sched.resume_pending()
        assert m["round"] == 2 and sched.channel._pending is None
        assert sched.resume_pending() is None
        run_rounds(sched, 5, start=3)

        ref = make_federation(faults=FaultSchedule(drops=((1, 2),)),
                              delta_horizon=4)
        run_rounds(ref, 5)
        assert_trees_bitwise(capture_state(sched), capture_state(ref),
                             "killed-and-resumed vs uninterrupted")
        assert sched.ledger.totals() == ref.ledger.totals()
