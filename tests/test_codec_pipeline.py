"""Staged codec pipeline: stage composition, per-leaf policies, and the
packed wire format (DESIGN.md §2-§5).

Covers the PR's acceptance criteria: byte-exact pack/unpack round-trips for
every registered codec, regex policy resolution (dense biases/norms, skip
rules), measured-vs-analytic bit parity within Golomb rounding, and a
per-leaf policy training end-to-end through DSGDTrainer with the
``get_compressor`` shim intact.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, baselines, sbc  # noqa: F401 (registration)
from repro.core.api import CompressionPolicy, PolicyRule
from repro.core.codec import make_codec
from repro.core.golomb import expected_position_bits
from repro.core.policy import path_str
from repro.core.stages import available_stages, decompress_leaf
from repro.core.wire import wire_for

ALL = ["none", "fedavg", "topk", "dgc", "signsgd", "onebit", "terngrad",
       "qsgd", "randomk", "sbc"]


def _delta(seed=0):
    return {
        "w": jax.random.normal(jax.random.PRNGKey(seed), (128, 32)) * 0.1,
        "bias": jax.random.normal(jax.random.PRNGKey(seed + 1), (32,)) * 0.1,
    }


# ----------------------------------------------------------- codec plumbing


class TestCodecComposition:
    def test_sbc_is_a_stage_composition(self):
        comp = api.get_compressor("sbc")
        assert comp.codec.spec == "topk_signed|binarize|golomb"

    def test_spec_string_builds_codec(self):
        c = make_codec("topk|binarize|golomb")
        x = jax.random.normal(jax.random.PRNGKey(0), (1024,))
        leaf = c.compress_leaf(x, 0.01, None)
        dense = decompress_leaf(leaf, 1024)
        # top-|k| selection binarized: k nonzeros, one shared magnitude... the
        # mean of SIGNED top-|k| values (a valid non-paper composition)
        assert int(jnp.sum(dense != 0)) == 10

    def test_stage_registries_populated(self):
        s = available_stages()
        assert {"topk", "topk_signed", "dense", "threshold", "randomk",
                "skip"} <= set(s["selectors"])
        assert {"identity", "binarize", "sign", "ternary", "stochastic",
                "two_means"} <= set(s["quantizers"])
        assert {"golomb", "bitmask", "raw16", "raw32", "none",
                "seed"} <= set(s["encoders"])

    def test_unknown_codec_raises(self):
        with pytest.raises(KeyError):
            make_codec("nope")

    def test_threshold_selector_masks_small_values(self):
        c = make_codec("threshold|identity|golomb", tau=100.0)
        x = jax.random.normal(jax.random.PRNGKey(0), (1024,))
        leaf = c.compress_leaf(x, 0.05, None)
        # nothing exceeds τ=100 → capacity slots transmit explicit zeros
        assert leaf.idx.shape[0] == 51
        np.testing.assert_array_equal(np.asarray(leaf.vals), 0.0)


# --------------------------------------------------------- policy resolution


class TestPolicyResolution:
    def _policy(self):
        return CompressionPolicy(
            default=make_codec("sbc"),
            rules=(
                PolicyRule(r"(^|/)(bias|scale|norm[^/]*)(/|$)", codec="dense32"),
                PolicyRule(r"frozen", codec="skip"),
            ),
            name="test",
        )

    def test_regex_rules_hit_biases_and_norms(self):
        tree = {
            "block0": {"w": jnp.zeros((8, 8)), "bias": jnp.zeros((8,))},
            "norm_f": {"scale": jnp.zeros((8,))},
            "frozen_emb": jnp.zeros((4, 4)),
        }
        resolved = self._policy().resolve(tree)
        by_path = {p.path: p.codec for p in resolved.plans}
        assert by_path["block0/w"].spec == "topk_signed|binarize|golomb"
        assert by_path["block0/bias"].spec == "dense|identity|none"
        assert by_path["norm_f/scale"].spec == "dense|identity|none"
        assert by_path["frozen_emb"].skip

    def test_first_match_wins(self):
        pol = CompressionPolicy(
            default=make_codec("sbc"),
            rules=(PolicyRule(r"w", codec="dense32"),
                   PolicyRule(r"w", codec="skip")),
        )
        plan = pol.plan_for("w")
        assert plan.codec.spec == "dense|identity|none"

    def test_fixed_sparsity_and_schedule_overrides(self):
        pol = CompressionPolicy(
            default=make_codec("sbc"),
            rules=(PolicyRule(r"w", sparsity=0.5),
                   PolicyRule(r"v", schedule=lambda r: 0.1 / (r + 1))),
        )
        resolved = pol.resolve({"w": jnp.zeros((4,)), "v": jnp.zeros((4,)),
                                "u": jnp.zeros((4,))})
        assert resolved.rates(0.01, 0) == (0.01, 0.1, 0.5)   # leaves: u, v, w
        assert resolved.rates(0.01, 9) == (0.01, 0.01, 0.5)

    def test_skip_leaf_accumulates_residual(self):
        pol = CompressionPolicy(default=make_codec("sbc"),
                                rules=(PolicyRule(r"bias", codec="skip"),))
        delta = _delta()
        resolved = pol.resolve(delta)
        state = resolved.init_state(delta)
        ctree, dense, state = resolved.compress(delta, state, resolved.rates(0.01))
        np.testing.assert_array_equal(np.asarray(dense["bias"]), 0.0)
        np.testing.assert_allclose(np.asarray(state.residual["bias"]),
                                   np.asarray(delta["bias"]), rtol=1e-6)
        assert float(ctree["bias"].nbits) == 0.0

    def test_dense_fallback_leaf_has_zero_residual(self):
        pol = CompressionPolicy(default=make_codec("sbc"),
                                rules=(PolicyRule(r"bias", codec="dense32"),))
        delta = _delta()
        resolved = pol.resolve(delta)
        state = resolved.init_state(delta)
        _, dense, state = resolved.compress(delta, state, resolved.rates(0.01))
        np.testing.assert_allclose(np.asarray(dense["bias"]),
                                   np.asarray(delta["bias"]), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(state.residual["bias"]), 0.0,
                                   atol=1e-7)

    def test_path_str_forms(self):
        tree = {"a": {"b": [jnp.zeros(2), jnp.zeros(3)]}}
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        assert [path_str(p) for p, _ in flat] == ["a/b/0", "a/b/1"]


# ----------------------------------------------------- structural decompress


class TestDecompressStructure:
    def test_decompress_through_treedef(self):
        comp = api.get_compressor("sbc")
        delta = _delta()
        state = comp.init_state(delta)
        ctree, dense, _ = comp.compress(delta, state, 0.01)
        rec = comp.decompress(ctree, delta)
        for k in delta:
            np.testing.assert_allclose(np.asarray(rec[k]), np.asarray(dense[k]))

    def test_structure_mismatch_raises(self):
        comp = api.get_compressor("sbc")
        delta = _delta()
        state = comp.init_state(delta)
        ctree, _, _ = comp.compress(delta, state, 0.01)
        with pytest.raises(Exception):
            comp.decompress(ctree, {"w": delta["w"]})  # missing leaf
        with pytest.raises(Exception):
            comp.decompress({"w": ctree["w"]}, delta)  # mismatched comp tree


# -------------------------------------------------------------- wire format


class TestWireRoundTrip:
    @pytest.mark.parametrize("name", ALL)
    def test_pack_unpack_byte_exact(self, name):
        comp = api.get_compressor(name)
        delta = _delta()
        state = comp.init_state(delta)
        ctree, dense, _ = comp.compress(delta, state, 0.01)
        wire = wire_for(comp.resolve(delta), delta, 0.01)

        blob = wire.pack(ctree)
        rec = wire.unpack(blob)
        for k in delta:
            np.testing.assert_array_equal(
                np.asarray(rec[k]), np.asarray(dense[k], np.float32),
                err_msg=f"{name}/{k}",
            )
        # byte-exact: decode → re-encode reproduces the identical buffer
        assert wire.pack(wire.unpack_compressed(blob)) == blob, name

    @pytest.mark.parametrize(
        "spec", ["topk|identity|golomb", "topk|identity|bitmask",
                 "topk|identity|raw16", "topk|binarize|golomb",
                 "threshold|identity|golomb", "topk_signed|binarize|bitmask"]
    )
    def test_stage_compositions_roundtrip(self, spec):
        pol = CompressionPolicy.single(make_codec(spec))
        delta = _delta(3)
        resolved = pol.resolve(delta)
        state = resolved.init_state(delta)
        ctree, dense, _ = resolved.compress(delta, state, resolved.rates(0.05))
        wire = wire_for(resolved, delta, 0.05)
        rec = wire.unpack(wire.pack(ctree))
        for k in delta:
            np.testing.assert_array_equal(np.asarray(rec[k]),
                                          np.asarray(dense[k], np.float32))

    def test_mixed_policy_roundtrip(self):
        pol = CompressionPolicy(
            default=make_codec("sbc"),
            rules=(PolicyRule(r"bias", codec="dense32"),),
        )
        delta = _delta(7)
        resolved = pol.resolve(delta)
        state = resolved.init_state(delta)
        ctree, dense, _ = resolved.compress(delta, state, resolved.rates(0.01))
        wire = wire_for(resolved, delta, 0.01)
        blob = wire.pack(ctree)
        rec = wire.unpack(blob)
        for k in delta:
            np.testing.assert_array_equal(np.asarray(rec[k]),
                                          np.asarray(dense[k], np.float32))

    def test_bad_magic_rejected(self):
        pol = CompressionPolicy.single(make_codec("sbc"))
        delta = _delta()
        resolved = pol.resolve(delta)
        wire = wire_for(resolved, delta, 0.01)
        with pytest.raises(ValueError):
            wire.unpack(b"XXXX" + b"\x00" * 16)


class TestMeasuredVsAnalytic:
    def test_sbc_measured_matches_eq1_eq5(self):
        """Measured packed bits == analytic Eq. 1/Eq. 5 within Golomb
        rounding: Eq. 5 is the expectation over geometric gaps, the
        bitstream is one draw — they agree to a few percent at this size."""
        n, p = 200_000, 0.01
        delta = {"w": jax.random.normal(jax.random.PRNGKey(0), (n,))}
        comp = api.get_compressor("sbc")
        state = comp.init_state(delta)
        ctree, _, _ = comp.compress(delta, state, p)
        wire = wire_for(comp.resolve(delta), delta, p)

        measured = wire.measured_bits(ctree)
        analytic = float(comp.total_bits(ctree))
        k = n * p
        assert analytic == pytest.approx(k * expected_position_bits(p) + 32)
        assert measured == pytest.approx(analytic, rel=0.05)
        # byte-padded framing stays within one byte per leaf + header
        assert wire.packed_bytes(ctree) <= (measured + 7) // 8 + 8 + 4 + 4

    def test_exact_codecs_measure_exactly(self):
        """Codecs with no entropy coding measure EXACTLY their analytic
        bits (identity values, raw16 positions, sign bits, two means)."""
        delta = _delta(11)
        for name in ["none", "topk", "signsgd", "onebit"]:
            comp = api.get_compressor(name)
            state = comp.init_state(delta)
            ctree, _, _ = comp.compress(delta, state, 0.01)
            wire = wire_for(comp.resolve(delta), delta, 0.01)
            assert wire.measured_bits(ctree) == float(comp.total_bits(ctree)), name


# ------------------------------------------------------- end-to-end training


class TestPolicyTraining:
    def test_per_leaf_policy_trains_through_dsgd(self):
        """Dense biases + 0.1% top-k matrices trains end-to-end, and the
        get_compressor('sbc') shim still drives the same trainer."""
        from repro.data import client_batches
        from repro.optim import get_optimizer
        from repro.train import DSGDTrainer

        from conftest import tiny_lm_setup

        cfg, model, task = tiny_lm_setup()
        policy = CompressionPolicy(
            default=make_codec("topk"),
            rules=(PolicyRule(r"(^|/)(bias|scale|norm[^/]*)(/|$)",
                              codec="dense32"),),
            name="dgc-ish",
        )
        tr = DSGDTrainer(model=model, compressor=policy,
                         optimizer=get_optimizer("momentum"),
                         n_clients=2, lr=lambda it: 0.05)
        state, hist = tr.fit(jax.random.PRNGKey(0), client_batches(task, 2, 1),
                             n_rounds=8, n_delay=1, sparsity=0.001,
                             measure_wire=True)
        assert hist["loss"][-1] < hist["loss"][0]
        assert len(hist["measured_bits_per_client"]) == 8
        # the dense-bias leaves dominate neither accounting: measured within
        # 20% of analytic (raw16+f32 values are exact; framing excluded)
        np.testing.assert_allclose(hist["measured_bits_per_client"][-1],
                                   hist["bits_per_client"][-1], rtol=0.2)

        # shim path still works on the same model
        tr2 = DSGDTrainer(model=model, compressor=api.get_compressor("sbc"),
                          optimizer=get_optimizer("momentum"),
                          n_clients=2, lr=lambda it: 0.05)
        _, hist2 = tr2.fit(jax.random.PRNGKey(0), client_batches(task, 2, 1),
                           n_rounds=4, n_delay=1, sparsity=0.01)
        assert hist2["loss"][-1] < hist2["loss"][0]
