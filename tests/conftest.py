"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only launch/dryrun.py fakes 512 devices (in its own process).

The suite is XLA-compile dominated, so two layers of caching keep wall time
down (ISSUE 2 satellite):

  * a persistent on-disk XLA compilation cache (``tests/.jax_cache``,
    gitignored) — repeat local runs skip almost every compile;
  * session-scoped model/param builders (``arch_setup``, ``lm_setup``) —
    each reduced architecture is built and initialized ONCE and shared by
    every test that exercises it, so ``model.init``/``loss_fn`` jit caches
    hit across tests instead of recompiling per test function.
"""
import functools
import os

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig

try:  # persistent compile cache: first run pays, reruns are fast
    jax.config.update(
        "jax_compilation_cache_dir",
        os.path.join(os.path.dirname(os.path.abspath(__file__)), ".jax_cache"),
    )
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)
except Exception:  # older jax without the flags — caching is best-effort
    pass


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_decoder(**kw) -> ModelConfig:
    base = dict(
        name="tiny", family="decoder", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=97, dtype=jnp.float32,
    )
    base.update(kw)
    return ModelConfig(**base)


@functools.lru_cache(maxsize=None)
def arch_setup(arch: str):
    """(cfg, model, params) for one REDUCED architecture, built once per
    session.  Sharing the *same* model object across tests lets later
    ``model.init`` / ``loss_fn`` calls hit the jit cache instead of
    recompiling (params are immutable jax arrays, safe to share)."""
    from repro.configs.base import get_config, reduced
    from repro.models.model import build_model

    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@functools.lru_cache(maxsize=None)
def tiny_lm_setup():
    """(cfg, model, task) for the tiny decoder LM shared by the trainer and
    codec-pipeline integration tests (identical config → one compile set)."""
    from repro.data import make_lm_task
    from repro.models.model import build_model

    cfg = tiny_decoder()
    model = build_model(cfg)
    task = make_lm_task(vocab=cfg.vocab_size, batch=8, seq_len=32,
                        temperature=0.3)
    return cfg, model, task


@pytest.fixture(scope="session")
def lm_setup():
    return tiny_lm_setup()


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
