"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only launch/dryrun.py fakes 512 devices (in its own process).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_decoder(**kw) -> ModelConfig:
    base = dict(
        name="tiny", family="decoder", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=97, dtype=jnp.float32,
    )
    base.update(kw)
    return ModelConfig(**base)
