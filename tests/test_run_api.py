"""The declarative run surface: RunSpec JSON round-trips, the shared
``--spec-json`` flag on every launcher, and the Run
init/step/evaluate/checkpoint driver contract (ISSUE 5 satellites)."""
import importlib
import json

import numpy as np
import pytest

from repro.run import RunSpec, build_run, build_parser, spec_from_args
from repro.run.build import policy_from_spec
from repro.run.flags import parse_profiles

from test_channel_parity import assert_trees_equal

LAUNCHER_PARSERS = [
    "repro.launch.train",
    "repro.launch.fed",
    "repro.launch.dist",
]


# ----------------------------------------------------------------- RunSpec


class TestRunSpec:
    def test_json_round_trip(self):
        spec = RunSpec(
            preset="tiny", backend="fed", clients=8, cohort=3,
            profiles=((1, 0.001, 1.0), (5, 0.01, 2.0)),
            dense_pattern=r"bias", fast=True, async_rounds=True,
        )
        assert RunSpec.from_json(spec.to_json()) == spec
        assert hash(RunSpec.from_json(spec.to_json())) == hash(spec)

    def test_json_lists_normalize_to_tuples(self):
        data = json.loads(RunSpec().to_json())
        data["profiles"] = [[2, 0.05, 1.0]]  # JSON has no tuples
        spec = RunSpec.from_json(json.dumps(data))
        assert spec.profiles == ((2, 0.05, 1.0),)

    def test_unknown_field_raises(self):
        with pytest.raises(ValueError, match="unknown RunSpec fields"):
            RunSpec.from_json('{"sparsityy": 0.1}')

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="unknown backend"):
            RunSpec(backend="mpi")

    def test_profiles_parse(self):
        assert parse_profiles("1:0.001,5:0.01:2.5") == (
            (1, 0.001, 1.0), (5, 0.01, 2.5)
        )
        assert parse_profiles("") == ()
        with pytest.raises(ValueError):
            parse_profiles("5")


# ---------------------------------------------------------------- the flag


class TestSpecJsonFlag:
    @pytest.mark.parametrize("mod", LAUNCHER_PARSERS + ["repro.run"])
    def test_every_launcher_takes_spec_json(self, mod, tmp_path):
        spec = RunSpec(preset="tiny", rounds=7, sparsity=0.123)
        path = tmp_path / "spec.json"
        path.write_text(spec.to_json())
        if mod == "repro.run":
            ap = build_parser()
        else:
            ap = importlib.import_module(mod).build_parser()
        args = ap.parse_args(["--spec-json", str(path)])
        got = spec_from_args(args)
        # launchers pin their backend; everything else comes from the file
        assert got.rounds == 7 and got.sparsity == 0.123
        assert got.replace(backend="local") == spec

    def test_spec_json_wins_over_flags(self, tmp_path):
        path = tmp_path / "spec.json"
        path.write_text(RunSpec(rounds=3).to_json())
        args = build_parser().parse_args(
            ["--spec-json", str(path), "--rounds", "99"]
        )
        assert spec_from_args(args).rounds == 3


# -------------------------------------------------------------- Run driver


class TestRunDriver:
    def test_init_step_eval_checkpoint(self, tmp_path):
        from repro.checkpoint.io import restore_train_state

        spec = RunSpec(preset="tiny", backend="local", rounds=2, batch=4,
                       seq_len=16, clients=2, sparsity=0.05)
        run = build_run(spec)
        state = run.init()
        state, m = run.step(state, 0)
        assert np.isfinite(m["loss"]) and m["bits_per_client"] > 0
        ev = run.evaluate(state)
        assert np.isfinite(ev["loss"])
        path = str(tmp_path / "ckpt.npz")
        run.checkpoint(state, path)
        restored = restore_train_state(path, state)
        assert_trees_equal(restored.params, state.params, "checkpoint")

    def test_policy_fast_semantics(self):
        """spec.fast=True opts in; False keeps the compressor's flag —
        the legacy `fast=True if args.fast else None` contract."""
        from repro.core.api import Compressor

        on = policy_from_spec(RunSpec(fast=True))
        off = policy_from_spec(RunSpec(fast=False))
        assert isinstance(on, Compressor) and on.policy.fast
        assert isinstance(off, Compressor) and not off.policy.fast
        ruled = policy_from_spec(RunSpec(dense_pattern="bias", fast=True))
        assert ruled.fast and ruled.rules


def test_fed_step_surface():
    """The fed Run exposes the same driver verbs over the stateful
    scheduler (state handle = the scheduler itself)."""
    spec = RunSpec(preset="tiny", backend="fed", rounds=1, batch=4,
                   seq_len=16, clients=2, sparsity=0.05)
    run = build_run(spec)
    state = run.init()
    state, m = run.step(state, 0)
    assert np.isfinite(m["loss"]) and m["up_bytes"] > 0
    assert len(run.ledger.records) == 1
    assert np.isfinite(run.evaluate(state)["loss"])
    bits = run.channel.bits()
    assert 0 < bits.per_client < bits.dense
