"""DSGD trainer integration: convergence, equivalence and bit accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import get_compressor
from repro.core.golomb import expected_position_bits
from repro.data import client_batches, make_lm_task
from repro.optim import get_optimizer
from repro.train import DSGDTrainer



def _trainer(model, compressor="sbc", opt="momentum", clients=4, lr=0.05):
    return DSGDTrainer(
        model=model, compressor=get_compressor(compressor),
        optimizer=get_optimizer(opt), n_clients=clients, lr=lambda it: lr,
    )


# lm_setup is the session-scoped (cfg, model, task) fixture from conftest —
# shared with test_codec_pipeline so the tiny decoder compiles once.


class TestConvergence:
    def test_sbc_learns(self, lm_setup, rng):
        _, model, task = lm_setup
        tr = _trainer(model, "sbc")
        _, hist = tr.fit(rng, client_batches(task, 4, 1), n_rounds=22,
                         n_delay=1, sparsity=0.01)
        assert hist["loss"][-1] < hist["loss"][0] - 0.8

    def test_delay_matches_budget(self, lm_setup, rng):
        """SBC(2)-style delayed training also converges (Fig. 5/6 claim:
        delay does not significantly slow convergence per iteration)."""
        _, model, task = lm_setup
        tr = _trainer(model, "sbc")
        _, hist = tr.fit(rng, client_batches(task, 4, 5), n_rounds=6,
                         n_delay=5, sparsity=0.01)
        assert hist["loss"][-1] < hist["loss"][0] - 0.8

    def test_compression_rate_matches_theory(self, lm_setup, rng):
        _, model, task = lm_setup
        p, delay = 0.01, 2
        tr = _trainer(model, "sbc")
        _, hist = tr.fit(rng, client_batches(task, 4, delay), n_rounds=3,
                         n_delay=delay, sparsity=p)
        # expected: delay × 32 / (p · (b̄_pos + 0)) up to per-tensor overheads
        expect = delay * 32.0 / (p * expected_position_bits(p))
        assert 0.7 * expect < hist["compression_rate"] < 1.3 * expect

    def test_dense_equals_plain_sgd(self, lm_setup, rng):
        """compressor='none', 1 client, delay 1 == vanilla training."""
        cfg, model, _ = lm_setup
        task = make_lm_task(vocab=cfg.vocab_size, batch=8, seq_len=32)
        tr = _trainer(model, "none", opt="sgd", clients=1, lr=0.1)
        state = tr.init(rng)
        batch = client_batches(task, 1, 1)(0)
        new_state, m = tr.round_step(state, batch, n_delay=1, sparsity=1.0)

        # manual SGD step
        loss, g = jax.value_and_grad(model.loss_fn)(
            state.params, jax.tree.map(lambda x: x[0, 0], batch)
        )
        manual = jax.tree.map(lambda p, gg: p - 0.1 * gg, state.params, g)
        for a, b in zip(jax.tree.leaves(new_state.params), jax.tree.leaves(manual)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                       atol=2e-6)

    def test_momentum_masking_applied(self, lm_setup, rng):
        _, model, task = lm_setup
        tr = _trainer(model, "sbc", opt="momentum")
        state = tr.init(rng)
        state, _ = tr.round_step(state, client_batches(task, 4, 1)(0),
                                 n_delay=1, sparsity=0.05)
        # momentum must be exactly zero at ≥ the sparsity fraction of coords
        mom = jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(state.opt_states)])
        frac_zero = float(jnp.mean(mom == 0.0))
        assert frac_zero >= 0.04  # ~5% transmitted → zeroed


class TestBaselineCompressorsTrain:
    @pytest.mark.parametrize("name,p", [
        ("topk", 0.01), ("signsgd", 1.0), ("terngrad", 1.0), ("qsgd", 1.0),
        ("randomk", 0.01), ("onebit", 1.0), ("fedavg", 1.0),
    ])
    def test_each_baseline_learns(self, lm_setup, rng, name, p):
        _, model, task = lm_setup
        # sign updates and random-k's unbiased 1% picks move slower
        rounds = 22 if name in ("signsgd", "randomk") else 14
        tr = _trainer(model, name, lr=0.05)
        _, hist = tr.fit(rng, client_batches(task, 4, 1), n_rounds=rounds,
                         n_delay=1, sparsity=p)
        assert hist["loss"][-1] < hist["loss"][0] - 0.35, name


class TestClientSemantics:
    def test_clients_see_distinct_data(self, lm_setup):
        _, _, task = lm_setup
        b = client_batches(task, 4, 1)(0)
        toks = b["tokens"]
        assert toks.shape[0] == 4
        assert not bool(jnp.all(toks[0] == toks[1]))

    def test_round_deterministic(self, lm_setup, rng):
        _, model, task = lm_setup
        tr = _trainer(model, "sbc")
        s1 = tr.init(rng)
        s2 = tr.init(rng)
        batch = client_batches(task, 4, 1)(0)
        o1, m1 = tr.round_step(s1, batch, n_delay=1, sparsity=0.01)
        o2, m2 = tr.round_step(s2, batch, n_delay=1, sparsity=0.01)
        assert float(m1["loss"]) == float(m2["loss"])
        for a, b in zip(jax.tree.leaves(o1.params), jax.tree.leaves(o2.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
