"""The repro.obs telemetry layer (ISSUE 7).

Covers, in order:

  * BandwidthLedger.reconcile failure paths — the tolerance gate is load-
    bearing (every backend + CI calls it), so its message format and its
    trivial-pass cases are pinned here;
  * Tracer span structure (nesting, ordering, validation);
  * MetricsRegistry declared-name discipline and the ingest_ledger
    bit-exactness contract (telemetry wire/* gauges == ledger.totals());
  * the JSONL / Chrome-trace export schema round trip + repro.obs.view;
  * NULL_TELEMETRY zero-overhead semantics (no-ops, identity fence);
  * an end-to-end traced tiny run: round → stage span decomposition and
    gauges reconciled against the run's own ledger.
"""
from __future__ import annotations

import json

import pytest

from repro.core.ledger import BandwidthLedger, RoundRecord
from repro.obs import (
    METRIC_NAMES,
    MetricsRegistry,
    NULL_TELEMETRY,
    Telemetry,
    Tracer,
    make_telemetry,
    render_table,
    validate_metric_events,
    validate_span_events,
    write_metrics_jsonl,
    write_trace_json,
)
from repro.obs.export import read_metrics_jsonl, read_trace_json
from repro.obs.view import check as view_check


def _rec(round_idx=0, cohort=(0, 1), up_bytes=100, up_m=800.0, up_a=800.0,
         down_bytes=0, down_m=0.0, down_a=0.0, down_recipients=0):
    return RoundRecord(
        round=round_idx, cohort=cohort, up_bytes=up_bytes,
        up_bits_measured=up_m, up_bits_analytic=up_a,
        down_bytes=down_bytes, down_bits_measured=down_m,
        down_bits_analytic=down_a, down_recipients=down_recipients,
    )


# ------------------------------------------------- ledger reconcile paths


class TestLedgerReconcile:
    def test_empty_ledger_reconciles(self):
        BandwidthLedger().reconcile(rel=0.0)  # no rounds, nothing to violate

    def test_zero_traffic_direction_trivially_passes(self):
        led = BandwidthLedger()
        led.record(_rec(up_m=1000.0, up_a=1000.0, down_m=0.0, down_a=0.0))
        led.reconcile(rel=1e-12)

    def test_upstream_violation_message(self):
        led = BandwidthLedger()
        led.record(_rec(round_idx=3, up_m=1500.0, up_a=1000.0))
        with pytest.raises(AssertionError) as ei:
            led.reconcile(rel=0.1)
        msg = str(ei.value)
        assert "round 3 upstream" in msg
        assert "measured 1500 bits vs analytic 1000" in msg
        assert "rel err 0.500 > 0.1" in msg

    def test_downstream_violation_named_separately(self):
        led = BandwidthLedger()
        led.record(_rec(round_idx=1, down_bytes=10, down_m=50.0, down_a=500.0,
                        down_recipients=2))
        with pytest.raises(AssertionError, match="round 1 downstream"):
            led.reconcile(rel=0.1)

    def test_first_violating_round_raises_not_the_last(self):
        led = BandwidthLedger()
        led.record(_rec(round_idx=0))  # fine
        led.record(_rec(round_idx=1, up_m=2000.0, up_a=1000.0))  # bad
        led.record(_rec(round_idx=2, up_m=9000.0, up_a=1000.0))  # worse
        with pytest.raises(AssertionError, match="round 1 "):
            led.reconcile(rel=0.1)

    def test_measured_zero_against_nonzero_analytic_fails(self):
        led = BandwidthLedger()
        led.record(_rec(up_m=0.0, up_a=640.0))
        with pytest.raises(AssertionError, match="rel err 1.000"):
            led.reconcile(rel=0.5)

    def test_tolerance_boundary(self):
        led = BandwidthLedger()
        led.record(_rec(up_m=1100.0, up_a=1000.0))  # rel err exactly 0.1
        led.reconcile(rel=0.1)  # > is strict: 0.1 is not > 0.1
        with pytest.raises(AssertionError):
            led.reconcile(rel=0.09)


# ------------------------------------------------------------------ tracer


class TestTracer:
    def test_nested_spans_record_parentage(self):
        tr = Tracer()
        with tr.span("round", round=0):
            with tr.span("encode", client=0):
                pass
            with tr.span("decode"):
                pass
        assert validate_span_events(tr.events) == []
        by_name = {e["name"]: e for e in tr.events}
        parent = by_name["round"]
        assert parent["parent"] is None and parent["depth"] == 0
        for child in ("encode", "decode"):
            assert by_name[child]["parent"] == parent["id"]
            assert by_name[child]["depth"] == 1
        assert by_name["encode"]["args"] == {"client": 0}

    def test_children_close_before_parent(self):
        tr = Tracer()
        with tr.span("round"):
            with tr.span("encode"):
                pass
        names = [e["name"] for e in tr.events]
        assert names == ["encode", "round"]  # completion order

    def test_validation_flags_unknown_name_and_orphan(self):
        errs = validate_span_events([
            {"type": "span", "name": "nonsense", "id": 0, "parent": 7,
             "depth": 1, "ts_us": 0.0, "dur_us": 1.0, "args": {}},
        ])
        assert any("not in SPAN_NAMES" in e for e in errs)
        assert any("parent 7 never closed" in e for e in errs)

    def test_fence_none_is_safe_and_identity(self):
        tr = Tracer()
        assert tr.fence(None) is None
        obj = [1, 2]
        assert NULL_TELEMETRY.fence(obj) is obj


# ----------------------------------------------------------------- metrics


class TestMetricsRegistry:
    def test_undeclared_name_raises_keyerror(self):
        reg = MetricsRegistry()
        with pytest.raises(KeyError, match="not declared in METRIC_NAMES"):
            reg.gauge("wire/typo_bits", 1.0)

    def test_kind_mismatch_raises_typeerror(self):
        reg = MetricsRegistry()
        with pytest.raises(TypeError, match="declared as a gauge"):
            reg.counter("train/loss")

    def test_ingest_ledger_is_bit_exact(self):
        led = BandwidthLedger()
        # float-summation-hostile values (0.1+0.2+0.3 != fsum of same):
        # bit-exactness holds because ingest replays the ledger's own
        # addends in order with the same sequential summation
        led.record(_rec(round_idx=0, up_bytes=3, up_m=0.1, up_a=0.1))
        led.record(_rec(round_idx=1, up_bytes=5, up_m=0.2, up_a=0.2))
        led.record(_rec(round_idx=2, up_bytes=7, up_m=0.3, up_a=0.3))
        reg = MetricsRegistry()
        reg.ingest_ledger(led)
        totals = led.totals()
        for col in ("up_bytes", "up_bits_measured", "up_bits_analytic",
                    "down_bytes", "down_bits_measured", "down_bits_analytic"):
            mine = sum(s["value"] for s in reg.series(f"wire/{col}"))
            assert mine == float(totals[col])
        assert [s["tags"]["round"] for s in reg.series("wire/up_bytes")] == \
            [0, 1, 2]
        assert sum(s["value"] for s in reg.series("obs/rounds")) == 3

    def test_summary_aggregates_by_kind(self):
        reg = MetricsRegistry()
        reg.gauge("train/loss", 3.0)
        reg.gauge("train/loss", 2.0)
        reg.counter("serve/verify_ok")
        reg.counter("serve/verify_ok")
        s = reg.summary()
        assert s["train/loss"]["last"] == 2.0 and s["train/loss"]["count"] == 2
        assert s["serve/verify_ok"]["sum"] == 2.0

    def test_every_declared_kind_is_valid(self):
        assert set(k for k, _ in METRIC_NAMES.values()) <= {
            "counter", "gauge", "hist"
        }


# ------------------------------------------------------------ export/view


class TestExportSchema:
    def _populated(self):
        tel = make_telemetry()
        with tel.span("round", round=0):
            with tel.span("encode"):
                pass
        tel.metrics.gauge("train/loss", 1.25, round=0)
        tel.metrics.counter("obs/rounds")
        return tel

    def test_metrics_jsonl_round_trip(self, tmp_path):
        tel = self._populated()
        path = str(tmp_path / "m.jsonl")
        write_metrics_jsonl(path, tel.metrics, meta={"backend": "test"})
        header, events = read_metrics_jsonl(path)
        assert header["schema"] == "repro-obs-v1"
        assert header["kind"] == "metrics" and header["backend"] == "test"
        assert validate_metric_events(events) == []
        assert {e["name"] for e in events} == {"train/loss", "obs/rounds"}
        with open(path) as f:
            first = json.loads(f.readline())
        assert first["schema"] == "repro-obs-v1"  # header is LINE 1

    def test_bad_header_raises(self, tmp_path):
        path = tmp_path / "bad.jsonl"
        path.write_text('{"schema": "other-v9"}\n')
        with pytest.raises(ValueError, match="bad header"):
            read_metrics_jsonl(str(path))

    def test_trace_json_is_chrome_loadable(self, tmp_path):
        tel = self._populated()
        path = str(tmp_path / "t.json")
        write_trace_json(path, tel.tracer, meta={"backend": "test"})
        evs = read_trace_json(path)
        assert all(e["ph"] in ("X", "i") for e in evs)
        assert {e["name"] for e in evs} == {"round", "encode"}
        x = [e for e in evs if e["name"] == "round"][0]
        assert {"ts", "dur", "pid", "tid"} <= set(x)

    def test_view_check_accepts_both_and_rejects_garbage(self, tmp_path,
                                                         capsys):
        tel = self._populated()
        m = str(tmp_path / "m.jsonl")
        t = str(tmp_path / "t.json")
        write_metrics_jsonl(m, tel.metrics)
        write_trace_json(t, tel.tracer)
        assert view_check([t, m]) == 0
        bad = tmp_path / "bad.json"
        bad.write_text('{"traceEvents": [{"ph": "X", "name": "bogus", '
                       '"ts": 0, "dur": 1, "pid": 0, "tid": 0}]}')
        assert view_check([str(bad)]) == 1
        capsys.readouterr()

    def test_render_table_alignment(self):
        out = render_table(["name", "n"], [("a", 1), ("bb", 22)])
        lines = out.splitlines()
        assert lines[0].split() == ["name", "n"]
        assert lines[2].endswith(" 1")  # numbers right-aligned


# ----------------------------------------------------- disabled telemetry


class TestNullTelemetry:
    def test_disabled_is_all_noops(self):
        assert not NULL_TELEMETRY.enabled
        with NULL_TELEMETRY.span("round", round=0) as s1:
            with NULL_TELEMETRY.span("encode") as s2:
                assert s1 is s2  # ONE shared null span, no allocation
        NULL_TELEMETRY.metrics.gauge("train/loss", 1.0)
        NULL_TELEMETRY.metrics.ingest_ledger(BandwidthLedger())
        assert NULL_TELEMETRY.metrics.events() == []
        assert NULL_TELEMETRY.tracer.events == ()

    def test_telemetry_default_is_disabled(self):
        assert not Telemetry().enabled
        assert make_telemetry().enabled


# ------------------------------------------------------- end-to-end traced


class TestTracedRun:
    @pytest.fixture(scope="class")
    def traced(self):
        from repro.run import RunSpec, build_run

        spec = RunSpec(preset="tiny", backend="local", rounds=2, batch=4,
                       seq_len=16, clients=2, sparsity=0.05,
                       measure_wire=True, telemetry=True)
        run = build_run(spec)
        _, hist = run.run()
        return run, hist

    def test_round_stage_decomposition(self, traced):
        run, _ = traced
        assert validate_span_events(run.telemetry.tracer.events) == []
        spans = [e for e in run.telemetry.tracer.events
                 if e["type"] == "span"]
        rounds = [e for e in spans if e["name"] == "round"]
        assert len(rounds) == 2
        kids = {e["name"] for e in spans if e["parent"] is not None}
        assert "exchange" in kids and "encode" in kids

    def test_gauges_reconcile_with_run_ledger(self, traced):
        run, _ = traced
        reg = run.telemetry.metrics
        totals = run.ledger.totals()
        for col in ("up_bytes", "up_bits_measured", "up_bits_analytic"):
            mine = sum(s["value"] for s in reg.series(f"wire/{col}"))
            assert mine == float(totals[col])

    def test_hist_keys_preserved_in_traced_mode(self, traced):
        _, hist = traced
        for key in ("loss", "round", "total_upload_bits",
                    "compression_rate", "measured_bits_per_client",
                    "measured_total_bits"):
            assert key in hist, key
        assert len(hist["loss"]) == 2

    def test_exports_validate(self, traced, tmp_path):
        run, _ = traced
        t = str(tmp_path / "run.trace.json")
        m = str(tmp_path / "run.metrics.jsonl")
        write_trace_json(t, run.telemetry.tracer)
        write_metrics_jsonl(m, run.telemetry.metrics)
        assert view_check([t, m]) == 0
