"""repro.scale cost model + planner (ISSUE 10 tentpole parts 1-2).

The acceptance-critical property: the analytic cost model reconciles
BIT-EXACTLY (floating-point equality, not a tolerance band) with the
measured :class:`~repro.core.ledger.BandwidthLedger` totals on real runs
of two executable configs — the host replay of the device's f32
accumulation is the same number the trainer hands the ledger.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import make_compressor
from repro.core.channel import analytic_bits
from repro.core.codec import make_codec
from repro.core.policy import CompressionPolicy, PolicyRule, moe_rules
from repro.core.wire import wire_for
from repro.scale import costs, planner
from repro.scale.costs import StubMesh


def _resolve(tree, policy=None):
    pol = policy or make_compressor("sbc").policy
    return pol.resolve(tree)


# ------------------------------------------------------- Eq. 1 walk parity


class TestUpstreamBits:
    def test_matches_channel_analytic_bits_float64(self):
        """costs.leaf_bits must be the same arithmetic as the channel's
        pricing walk, leaf for leaf, on a mixed skip/dense/sparse tree."""
        tree = {
            "bias": jnp.zeros(7),
            "w": jnp.zeros(4096),
            "emb": jnp.zeros((128, 64)),
        }
        pol = CompressionPolicy(
            default=make_codec("sbc"),
            rules=(PolicyRule(r"bias", codec="dense32"),
                   PolicyRule(r"emb", codec="skip")),
        )
        res = _resolve(tree, pol)
        leaves = res.treedef.flatten_up_to(tree)
        rates = res.rates(0.01)
        truth = analytic_bits(res, leaves, rates)
        sizes = [int(np.prod(np.shape(x))) for x in leaves]
        f64, f32 = costs.upstream_bits(res, sizes, rates)
        assert f64 == truth.per_client
        assert abs(f32 - f64) <= 1e-5 * f64

    def test_framing_constants_match_sbw1_container(self):
        """The framing constants mirror the real SBW1 layout: magic+count
        header, then one u32 length prefix per leaf — parse the packed
        blob and recover exactly ``framing_bytes(n_leaves)`` of overhead
        beyond the per-leaf payloads."""
        import struct

        tree = {"a": jnp.asarray(np.random.default_rng(0)
                                 .standard_normal(2048), jnp.float32),
                "b": jnp.asarray(np.random.default_rng(1)
                                 .standard_normal((32, 16)), jnp.float32)}
        res = _resolve(tree)
        state = res.init_state(tree)
        ctree, _, _ = res.compress(tree, state, res.rates(0.05))
        ctree = jax.tree.map(np.asarray, ctree)
        wire = wire_for(res, tree, 0.05)
        blob = wire.pack(ctree)
        assert blob[:4] == b"SBW1"
        (n_leaves,) = struct.unpack_from("<I", blob, 4)
        assert n_leaves == 2
        off, payload = costs.SBW1_HEADER_BYTES, 0
        for _ in range(n_leaves):
            (ln,) = struct.unpack_from("<I", blob, off)
            off += costs.SBW1_PER_LEAF_BYTES + ln
            payload += ln
        assert off == len(blob)
        assert len(blob) - payload == costs.framing_bytes(n_leaves)

    def test_memory_costs(self):
        tree = {"w": jnp.zeros(1000), "v": jnp.zeros(24)}
        pol = CompressionPolicy(
            default=make_codec("sbc"),
            rules=(PolicyRule(r"v", codec=make_codec(
                "dense|identity|none", use_residual=False)),),
        )
        # sizes in plan order (dict keys flatten sorted: "v" before "w")
        mem = costs.memory_bytes(_resolve(tree, pol),
                                 [24, 1000], opt="adam")
        assert mem["param_bytes"] == 4 * 1024
        assert mem["residual_bytes"] == 4 * 1000  # no-residual leaf excluded
        assert mem["optimizer_bytes"] == 2 * 4 * 1024


# ------------------------------------------------------- sharded exchange


class TestShardedExchange:
    def test_stub_mesh_needs_no_devices(self):
        mesh = StubMesh(shape=(16, 16))
        assert mesh.shape_map == {"data": 16, "model": 16}
        assert mesh.devices.nbytes == 256  # int8 placeholders, not chips

    def test_shard_count_and_scan_rows_price_like_gspmd(self):
        """The per-(leaf, shard, scan-row) table: an (L, d, ff) scanned
        stack sharded over 'model' prices L·S local blocks, each with its
        own Golomb stream + one 32-bit scalar."""
        from jax.sharding import PartitionSpec as P

        codec = make_codec("sbc")
        pol = CompressionPolicy(default=codec)
        tree = {"stack/scan/mlp": jnp.zeros((4, 256, 1024))}
        res = pol.resolve(tree)
        mesh = StubMesh(shape=(2, 8))
        rate = 0.01
        got = costs.sharded_exchange_bits(
            res, [jax.ShapeDtypeStruct((4, 256, 1024), jnp.float32)],
            ["stack/scan/mlp"], [P(None, None, "model")], [rate], mesh,
        )
        L, S = 4, 8
        n_loc = (4 * 256 * 1024) // (L * S)
        k_loc = max(1, int(round(rate * n_loc)))
        want = L * S * (codec.encoder.position_bits(n_loc, k_loc, rate)
                        + codec.quantizer.value_bits(k_loc))
        assert got == pytest.approx(want)

    def test_replicated_leaf_prices_once(self):
        from jax.sharding import PartitionSpec as P

        pol = CompressionPolicy(default=make_codec("sbc"))
        tree = {"w": jnp.zeros(4096)}
        res = pol.resolve(tree)
        one = costs.sharded_exchange_bits(
            res, [jax.ShapeDtypeStruct((4096,), jnp.float32)], ["w"],
            [P()], [0.01], StubMesh())
        sizes = [4096]
        f64, _ = costs.upstream_bits(res, sizes, res.rates(0.01))
        assert one == pytest.approx(f64)


# ------------------------------------------------ planner classification


class TestClassification:
    def test_paper_smalls_go_real(self):
        mode, reason = planner.classify("lenet5")
        assert mode == "real" and "budget" in reason

    def test_cnn_without_preset_goes_dryrun(self):
        mode, reason = planner.classify("resnet32")
        assert mode == "dryrun" and "family" in reason

    def test_largest_goes_analytic(self):
        mode, reason = planner.classify("llama4_maverick_400b_a17b")
        assert mode == "analytic" and "cap" in reason

    def test_mode_forced(self):
        mode, reason = planner.classify("lenet5", mode="analytic")
        assert mode == "analytic" and "forced" in reason
        with pytest.raises(ValueError):
            planner.classify("lenet5", mode="bogus")

    def test_budget_moves_the_real_frontier(self):
        assert planner.classify("lenet5", budget_mb=0)[0] == "dryrun"


# ------------------------------------------------ the bit-exact reconcile


@pytest.mark.parametrize("arch", ["lenet5", "charlstm"])
def test_real_mode_reconciles_bit_exactly(arch):
    """Acceptance criterion 3: on executable configs the cost model's
    f32-ledger replay equals the measured ledger total EXACTLY."""
    rec, run = planner.plan_real(arch, rounds=3, sparsity=0.01)
    assert rec["mode"] == "real"
    assert rec["reconciles"] is True
    r = rec["real"]
    assert r["up_bits_predicted"] == r["up_bits_ledger"]  # bit-exact
    assert r["up_bits_ledger"] > 0
    assert len(run.ledger.records) == 3
    # the wire actually moved bytes, within the Eq. 5 expectation band
    assert 0.5 < r["measured_ratio"] < 2.0


def test_dryrun_record_schema_and_moe_pricing():
    """Dryrun emits a complete schema-v1 record; MoE rules price the
    expert stacks below their unscaled bill."""
    rec = planner.plan_dryrun("mixtral_8x7b", sparsity=0.001)
    for key in ("schema", "arch", "mode", "params", "up_bits_per_step",
                "up_bits_f32_ledger", "dense_bits", "compression_rate",
                "exchange_bits_per_step", "roofline_est", "reconciles"):
        assert key in rec, key
    assert rec["schema"] == planner.SCHEMA
    assert rec["reconciles"] is True
    assert rec["exchange_bits_per_step"] >= rec["up_bits_per_step"]
    plain = planner.plan_dryrun("mixtral_8x7b", sparsity=0.001,
                                compressor="topk")
    assert rec["up_bits_per_step"] < plain["up_bits_per_step"]


def test_analytic_record_prices_largest_config():
    rec = planner.plan_analytic("llama4_maverick_400b_a17b", sparsity=0.001)
    assert rec["n_leaves"] is None
    assert rec["params"] > 300e9
    assert rec["compression_rate"] > 1000
    assert rec["roofline_est"]["step_s"] > 0
