"""Deterministic fault-injection harness for the elastic fed backend
(DESIGN.md §14 — the ISSUE 8 load-bearing deliverable).

Everything the elasticity tests and the chaos benchmark share lives here:

  * the :class:`repro.fed.FaultSchedule` surface re-exported under one
    roof (``FaultSchedule``, ``ServerKilled``, ``NO_FAULTS``,
    ``KILL_STEPS``, ``straggler_ids``);
  * :func:`make_federation` — a micro federation factory with every
    elasticity knob (faults, straggler timeout, cohort tile, spilled
    client store, DeltaLog horizon) as a keyword, built on the SAME
    cached micro model as ``test_fed`` so the suite pays its compiles
    once;
  * bit-level state capture/compare: :func:`capture_state` grabs every
    array the federation owns (server W/Ŵ/residual, the full pooled
    client state) and :func:`assert_trees_bitwise` holds two captures to
    byte equality — elasticity claims in an error-feedback system are
    bit-level claims, so every assertion here is ``tobytes`` equality,
    never ``allclose``;
  * :func:`craft_upload` — a REAL packed SBW1 upload (compress → pack
    through the shared wire contract) without paying a cohort compile,
    for server-level aggregation properties.

Fault scenarios are *data* (a frozen seeded schedule), so a test names
exactly which client fails how in which round and replays it bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed import (  # noqa: F401  (re-exports: the harness surface)
    KILL_STEPS,
    NO_FAULTS,
    ClientPool,
    ClientProfile,
    ClientUpdate,
    FaultSchedule,
    ParameterServer,
    RoundScheduler,
    ServerKilled,
)
from repro.fed.faults import straggler_ids  # noqa: F401
from repro.optim import get_optimizer

from test_fed import _policy, micro_setup


def micro_params():
    """The micro model's initial parameters (deterministic, cached model)."""
    _, model, _ = micro_setup()
    return model.init(jax.random.PRNGKey(0))


def make_federation(
    *,
    n_clients: int = 8,
    cohort: int = 5,
    mode: str = "async",
    max_staleness: int = 2,
    agg: str = "staleness",
    faults: FaultSchedule | None = None,
    straggler_timeout: float | None = None,
    cohort_tile: int | None = None,
    store: str = "device",
    store_dir: str | None = None,
    delta_horizon: int | None = None,
    down_sparsity: float = 0.1,
    profiles=(ClientProfile(delay=2, sparsity=0.05),),
    seed: int = 0,
) -> RoundScheduler:
    """One micro federation with every elasticity knob exposed.

    Two calls with identical arguments build bit-identical federations
    (same params, same cohort draws, same fault replay) — the reference
    construction every scenario test compares against.
    """
    _, model, task = micro_setup()
    server = ParameterServer(
        params=model.init(jax.random.PRNGKey(0)), up_policy=_policy(),
        down_sparsity=down_sparsity, aggregator=agg, staleness_beta=0.5,
        delta_horizon=delta_horizon,
    )
    pool = ClientPool(
        model=model, optimizer=get_optimizer("momentum"), policy=_policy(),
        task=task, n_clients=n_clients, lr=lambda it: 0.05,
        profiles=profiles, seed=seed, cohort_tile=cohort_tile,
        store=store, store_dir=store_dir,
    )
    return RoundScheduler(
        server=server, pool=pool, cohort_size=cohort, mode=mode,
        max_staleness=max_staleness, seed=seed,
        straggler_timeout=straggler_timeout, faults=faults,
    )


def run_rounds(sched: RoundScheduler, n_rounds: int, start: int = 0) -> list:
    """Drive rounds ``start..n_rounds−1``; returns the per-round metrics."""
    return [sched.step(r) for r in range(start, n_rounds)]


# ------------------------------------------------------- bit-level capture


def capture_state(sched: RoundScheduler) -> dict:
    """Every array the federation owns, as one host-side dict: master
    weights W, replica Ŵ, the server's downstream residual, and the FULL
    pooled client state (optimizer moments, error-feedback residuals,
    RNG keys, step counters for all N clients)."""
    return jax.device_get({
        "server/params": sched.server.params,
        "server/estimate": sched.server.estimate,
        "server/down_residual": sched.server.down_residual,
        "pool": sched.pool.export_state(),
    })


def assert_trees_bitwise(a, b, what: str = "state") -> None:
    """Hold two pytrees to BYTE equality, leaf by leaf (dtype, shape, and
    raw bits — ``allclose`` has no standing in an error-feedback system)."""
    la, pa = jax.tree_util.tree_flatten(a)
    lb, pb = jax.tree_util.tree_flatten(b)
    assert pa == pb, f"{what}: tree structures differ"
    for i, (x, y) in enumerate(zip(la, lb)):
        xa, ya = np.asarray(x), np.asarray(y)
        assert xa.dtype == ya.dtype and xa.shape == ya.shape, (
            f"{what}: leaf {i} is {xa.dtype}{xa.shape} vs {ya.dtype}{ya.shape}"
        )
        assert xa.tobytes() == ya.tobytes(), f"{what}: leaf {i} differs bitwise"


def trees_equal_bitwise(a, b) -> bool:
    try:
        assert_trees_bitwise(a, b)
        return True
    except AssertionError:
        return False


# -------------------------------------------------- server-level uploads


def _rand_update(params, key, scale: float = 0.05):
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, [
        scale * jax.random.normal(k, leaf.shape, jnp.float32)
        for k, leaf in zip(keys, leaves)
    ])


def craft_upload(
    server: ParameterServer,
    client_id: int,
    *,
    rate: float = 0.05,
    round_idx: int = 0,
    seed: int = 0,
    weight: float = 1.0,
    staleness: int = 0,
) -> ClientUpdate:
    """A genuine packed SBW1 upload — a random dense update compressed and
    packed through the server's own upstream contract — so server-level
    aggregation properties run on real wire bytes without a cohort
    compile."""
    resolved = server._up_resolved
    delta = _rand_update(
        server.params, jax.random.fold_in(jax.random.PRNGKey(seed), client_id)
    )
    state = resolved.init_state(
        jax.tree.map(lambda x: x.astype(jnp.float32), server.params)
    )
    rates = resolved.rates(rate, round_idx)
    ctree, _, _ = resolved.compress(delta, state, rates)
    blob = server.up_wire(rate, round_idx).pack(ctree)
    return ClientUpdate(client_id=client_id, blob=blob, rate=rate,
                        weight=weight, staleness=staleness)
