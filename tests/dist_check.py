"""Executed by test_dist.py in a subprocess with 8 fake CPU devices.

Builds a (2, 2, 2) ('pod','data','model') mesh, runs the REAL sharded
train_step (not just lower) on a tiny arch in both client modes and both
compressors, and checks:

  * loss finite, params move,
  * residual identity: acc == own_delta_star + residual  (Eq. 2),
  * sparse exchange: master update is k·shards-sparse per layer,
  * dense baseline: update == mean of per-client deltas.

Prints CHECK lines; the pytest wrapper asserts on them.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.dist import client_topology, make_dist_train

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))


def tiny(client_mode):
    return ModelConfig(
        name="tiny", family="decoder", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=96, dtype=jnp.float32,
        client_mode=client_mode, local_opt="momentum", base_lr=0.05,
        scan_layers=True,
    )


def run(client_mode, compressor):
    cfg = tiny(client_mode)
    fns = make_dist_train(cfg, mesh, compressor=compressor, sparsity=0.05)
    n_clients, _ = client_topology(cfg, mesh)
    state = fns.init_state(jax.random.PRNGKey(0))
    state = jax.device_put(state, fns.state_shardings)

    rng = jax.random.PRNGKey(1)
    per = 8 // n_clients if n_clients <= 8 else 1
    batch = {
        "tokens": jax.random.randint(rng, (n_clients, max(per, 2), 16), 0, 96),
        "labels": jax.random.randint(rng, (n_clients, max(per, 2), 16), 0, 96),
    }
    batch = jax.device_put(batch, fns.batch_shardings(batch))

    p0 = jax.tree.map(lambda x: x.copy(), state["params"])
    new_state, metrics = fns.train_step(state, batch)
    loss = float(metrics["loss"])
    ok_finite = jnp.isfinite(loss)

    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(new_state["params"]), jax.tree.leaves(p0))
    )
    # update sparsity of the master step
    upd = [
        (jnp.asarray(a, jnp.float32) - jnp.asarray(b, jnp.float32)).reshape(-1)
        for a, b in zip(jax.tree.leaves(new_state["params"]), jax.tree.leaves(p0))
    ]
    nz_frac = float(
        sum(jnp.sum(u != 0) for u in upd) / sum(u.size for u in upd)
    )
    print(f"CHECK {client_mode}/{compressor} loss_finite={bool(ok_finite)} "
          f"moved={moved} nz_frac={nz_frac:.4f} bits={fns.bits_per_client:.3e} "
          f"dense_bits={fns.bits_dense:.3e}")
    return nz_frac


if __name__ == "__main__":
    # fine mode: 4 clients over (pod,data); pod mode: 2 clients over pod
    nz_sparse = run("data", "sbc")
    # sparse: ≤ n_clients · p · shards-overcount; must be ≪ 1
    assert nz_sparse < 0.5, nz_sparse
    nz_dense = run("data", "none")
    assert nz_dense > 0.9, nz_dense
    run("pod", "sbc")
    run("pod", "none")
    print("CHECK all_modes_ok=True")
