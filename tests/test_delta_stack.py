"""Stacked catch-up exactness: composing k SBW1 deltas == applying them
sequentially, to the BIT (DESIGN.md §13).

The contract under test: for any window (a, b] of logged broadcasts, the
one SBD1 message ``DeltaLog.encode_stacked(a)`` moves a replica at round a
to the byte-identical state that applying the k stored broadcasts in
order produces — across sparse, dense, and skip leaf paths, including the
residual-carrying codecs and the ±0.0 sign-bit edge cases.  Buffers are
fuzzed with the same truncation/corruption harness as
``tests/test_wire_fuzz.py``: malformed SBD1 bytes must raise a clean
``ValueError``, never another exception.
"""
import random
import struct

import jax
import numpy as np
import pytest

from repro.core import api
from repro.core.codec import make_codec
from repro.core.policy import CompressionPolicy, PolicyRule
from repro.core.wire import wire_for
from repro.serve.broadcast import CatchupPlanner
from repro.serve.deltalog import (
    CATCHUP_MAGIC,
    DeltaLog,
    apply_catchup,
    apply_catchup_flat,
)

CODECS = ["sbc", "topk", "signsgd", "qsgd", "none"]


def rate_of(name: str) -> float:
    return 0.01 if name in ("sbc", "topk") else 1.0


def drive_log(name: str, p: float, rounds: int = 6, horizon: int = 16):
    """Log ``rounds`` real compressed broadcasts; returns (log, snapshots)
    where snapshots[r] is the replica AFTER round r (r=-1: initial)."""
    comp = api.make_compressor(name)
    key = jax.random.PRNGKey(11)
    params = {
        "w": jax.random.normal(key, (3000,)) * 0.01,
        "b": jax.random.normal(jax.random.PRNGKey(12), (61,)),
    }
    log = DeltaLog(params, horizon=horizon)
    state = comp.init_state(params)
    wire = wire_for(comp.resolve(params), params, p)
    snaps = {-1: log.replica_flat()}
    for r in range(rounds):
        key, k1, k2 = jax.random.split(key, 3)
        delta = {
            "w": 0.01 * jax.random.normal(k1, (3000,)),
            "b": 0.1 * jax.random.normal(k2, (61,)),
        }
        ctree, _, state = comp.compress(delta, state, p)
        log.append(r, wire.pack(jax.tree.map(np.asarray, ctree)), wire)
        snaps[r] = log.replica_flat()
    return log, snaps


def assert_bits_equal(got, want, ctx=""):
    for i, (a, b) in enumerate(zip(got, want)):
        np.testing.assert_array_equal(
            a.view(np.uint32), b.view(np.uint32),
            err_msg=f"leaf {i} not bit-identical {ctx}",
        )


@pytest.mark.parametrize("name", CODECS)
def test_stacked_equals_sequential_every_lag(name):
    """From every held round: stacked-apply == sequential replay == the
    log's replica, compared on raw u32 bit patterns."""
    log, snaps = drive_log(name, rate_of(name))
    final = log.replica_flat()
    for frm in range(-1, log.head):
        seq = [f.copy() for f in snaps[frm]]
        for e in log.entries_since(frm):
            seq = [f + d for f, d in zip(seq, e.dense)]
        msg = log.encode_stacked(frm)
        stk, f0, t0 = apply_catchup_flat(snaps[frm], msg.blob)
        assert (f0, t0) == (frm, log.head)
        assert_bits_equal(stk, seq, f"(stacked vs sequential, from {frm})")
        assert_bits_equal(stk, final, f"(stacked vs replica, from {frm})")


def test_skip_and_sparse_leaves_compose():
    """A policy mixing a skipped leaf with a sparse one: the skipped leaf
    rides MODE_EMPTY yet still normalizes like a sequential receiver."""
    policy = CompressionPolicy(
        default=make_codec("sbc"),
        rules=(PolicyRule("b", codec="skip"),),
        name="sbc+skip-b",
    )
    params = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (2000,)) * 0.01,
        "b": np.float32([1.0, -0.0, 2.0, 0.0, -3.0]),
    }
    resolved = policy.resolve(params)
    state = resolved.init_state(params)
    log = DeltaLog(params, horizon=8)
    wire = wire_for(resolved, params, 0.02)
    snap0 = log.replica_flat()
    key = jax.random.PRNGKey(5)
    for r in range(4):
        key, k1 = jax.random.split(key)
        delta = {
            "w": 0.01 * jax.random.normal(k1, (2000,)),
            "b": np.float32([0.5] * 5),  # never transmitted
        }
        ctree, _, state = resolved.compress(
            delta, state, resolved.rates(0.02, r)
        )
        log.append(r, wire.pack(jax.tree.map(np.asarray, ctree)), wire)
    seq = [f.copy() for f in snap0]
    for e in log.entries_since(-1):
        seq = [f + d for f, d in zip(seq, e.dense)]
    msg = log.encode_stacked(-1)
    stk, _, _ = apply_catchup_flat(snap0, msg.blob)
    assert_bits_equal(stk, seq)
    assert_bits_equal(stk, log.replica_flat())
    # the skipped leaf kept its values — but its −0.0 flipped to +0.0,
    # exactly as k dense adds of 0.0 flip it on a sequential receiver
    b = stk[0] if stk[0].size == 5 else stk[1]
    assert b[0] == 1.0 and b[2] == 2.0
    assert not np.signbit(b[1])


def test_minus_zero_transmitted_position_flips_sign():
    """A transmitted +0.0 landing on a stored −0.0 flips the sign bit while
    staying 'zero' — the union MUST come from the transmitted index sets
    (``nonzero(dense)`` would drop the position and keep −0.0)."""
    from repro.core.stages import LeafCompressed

    params = {"w": np.float32([0, 0, -0.0, 0, 0, -0.0, 0, 0])}
    assert np.signbit(params["w"][2]) and np.signbit(params["w"][5])
    comp = api.make_compressor("topk")
    wire = wire_for(comp.resolve(params), params, 0.125)  # k_for(8,.125)=1
    log = DeltaLog(params, horizon=4)
    snap0 = log.replica_flat()
    ctree = {
        "w": LeafCompressed(
            idx=np.int32([5]),
            vals=np.float32([0.0]),  # transmitted value: +0.0
            mean=np.zeros((), np.float32),
            dense=np.zeros((0,), np.float32),
            nbits=np.zeros((), np.float32),
        )
    }
    log.append(0, wire.pack(ctree), wire)
    # sequential: −0.0 + 0.0 = +0.0 at BOTH the transmitted position and
    # the untransmitted one (the dense add covers every position)
    assert not np.signbit(log._replica[0][5])
    assert not np.signbit(log._replica[0][2])
    msg = log.encode_stacked(-1)
    stk, _, _ = apply_catchup_flat(snap0, msg.blob)
    assert_bits_equal(stk, log.replica_flat())


def test_residual_codec_window_interior():
    """sbc carries a residual: values transmitted late in the window
    depend on what earlier rounds dropped.  Stacking from a mid-window
    round must still reproduce the replica exactly."""
    log, snaps = drive_log("sbc", 0.01, rounds=8)
    for frm in (2, 4, 6):
        msg = log.encode_stacked(frm)
        stk, _, _ = apply_catchup_flat(snaps[frm], msg.blob)
        assert_bits_equal(stk, log.replica_flat(), f"(from {frm})")


def test_stacked_wins_for_dense_broadcasts():
    """Dense rounds make replay pay 4N bytes per round; the stacked union
    collapses the window to one dense message (== one resync)."""
    log, snaps = drive_log("none", 1.0, rounds=5)
    planner = CatchupPlanner(log)
    plan = planner.plan(log.head - 3)
    costs = dict(plan.candidates)
    assert plan.kind == "stacked"
    assert plan.nbytes < costs["replay"]
    stk, _, _ = apply_catchup_flat(snaps[log.head - 3], plan.blobs[0])
    assert_bits_equal(stk, log.replica_flat())


def test_full_resync_applies_from_anywhere():
    """After eviction the planner falls back to full, which restores even
    a garbage replica to the exact head state."""
    log, _ = drive_log("sbc", 0.01, rounds=8, horizon=3)
    assert log.oldest == 5  # holds the horizon's 3 rounds: 5, 6, 7
    planner = CatchupPlanner(log)
    plan = planner.plan(0)  # lag 7 > horizon — window evicted
    assert plan.kind == "full"
    garbage = [np.full((3000,), 9.9, np.float32),
               np.full((61,), -7.7, np.float32)]
    leaves = garbage if garbage[0].size == log._replica[0].size else garbage[::-1]
    got, frm, to = apply_catchup_flat(leaves, plan.blobs[0])
    assert to == log.head
    assert_bits_equal(got, log.replica_flat())


def test_apply_catchup_pytree_roundtrip():
    log, snaps = drive_log("topk", 0.01, rounds=4)
    replica = log.treedef.unflatten(
        [f.copy() for f in snaps[1]]
    )
    msg = log.encode_stacked(1)
    tree, frm, to = apply_catchup(replica, msg.blob)
    assert (frm, to) == (1, log.head)
    got = [np.asarray(x).reshape(-1) for x in jax.tree.leaves(tree)]
    assert_bits_equal(got, log.replica_flat())


# ------------------------------------------------------------- fuzz/harden


def _stacked_blob():
    log, snaps = drive_log("sbc", 0.01, rounds=5)
    return log, snaps[-1], log.encode_stacked(-1).blob


def test_truncation_sweep():
    """Every prefix either applies or raises ValueError (never IndexError,
    struct.error, or a giant allocation)."""
    log, flats, blob = _stacked_blob()
    step = max(1, len(blob) // 80)
    for cut in list(range(0, len(blob), step)) + [len(blob) - 1]:
        try:
            apply_catchup_flat(flats, blob[:cut])
        except ValueError:
            pass


def test_random_corruption():
    log, flats, blob = _stacked_blob()
    rng = random.Random(99)
    for _ in range(200):
        b = bytearray(blob)
        for _ in range(rng.randint(1, 8)):
            b[rng.randrange(len(b))] = rng.randrange(256)
        try:
            apply_catchup_flat(flats, bytes(b))
        except ValueError:
            pass


def test_bad_magic_kind_and_leaf_count():
    log, flats, blob = _stacked_blob()
    with pytest.raises(ValueError, match="magic"):
        apply_catchup_flat(flats, b"XXXX" + blob[4:])
    b = bytearray(blob)
    b[4] = 77  # kind byte
    with pytest.raises(ValueError, match="kind"):
        apply_catchup_flat(flats, bytes(b))
    b = bytearray(blob)
    struct.pack_into("<I", b, 4 + 9, 1000)  # n_leaves field
    with pytest.raises(ValueError, match="leaves"):
        apply_catchup_flat(flats, bytes(b))
    with pytest.raises(ValueError, match="truncated"):
        apply_catchup_flat(flats, blob[:8])
    assert blob[:4] == CATCHUP_MAGIC


def test_log_contract_errors():
    params = {"w": np.zeros((64,), np.float32)}
    with pytest.raises(ValueError, match="horizon"):
        DeltaLog(params, horizon=0)
    log = DeltaLog(params, horizon=4)
    with pytest.raises(ValueError, match="contiguous"):
        comp = api.make_compressor("topk")
        wire = wire_for(comp.resolve(params), params, 0.1)
        state = comp.init_state(params)
        ctree, _, _ = comp.compress({"w": np.ones((64,), np.float32)}, state, 0.1)
        log.append(3, wire.pack(jax.tree.map(np.asarray, ctree)), wire)
    with pytest.raises(ValueError, match="stack"):
        log.encode_stacked(-1)  # nothing appended yet
