"""Compressor semantics (paper Alg. 2 + Table I baselines) as property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dev dep — fixed-grid fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import api, baselines, sbc  # noqa: F401 (registration)
from repro.core.golomb import expected_position_bits


def _flat(seed=0, n=4096):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,))


# ------------------------------------------------------------------ SBC


class TestSBC:
    def test_one_sided_binary(self):
        """ΔW* has exactly k non-zeros, all equal to the single mean μ."""
        x = _flat()
        comp = sbc.sbc_compress_leaf(x, 0.01, None)
        dense = sbc.sbc_decompress_leaf(comp, x.shape[0])
        nz = dense[dense != 0]
        assert nz.shape[0] == api.k_for(x.shape[0], 0.01)
        np.testing.assert_allclose(nz, float(comp.mean), rtol=1e-6)

    def test_picks_dominant_side(self):
        x = jnp.concatenate([jnp.full((10,), 5.0), -0.1 * jnp.ones((990,))])
        comp = sbc.sbc_compress_leaf(x, 0.01, None)
        assert float(comp.mean) > 0  # positive tail dominates
        x = -x
        comp = sbc.sbc_compress_leaf(x, 0.01, None)
        assert float(comp.mean) < 0

    def test_mean_matches_topk_mean(self):
        x = _flat(3)
        k = api.k_for(x.shape[0], 0.01)
        comp = sbc.sbc_compress_leaf(x, 0.01, None)
        vals = jax.lax.top_k(x, k)[0]
        vneg = jax.lax.top_k(-x, k)[0]
        expect = float(jnp.where(jnp.mean(vals) > jnp.mean(vneg),
                                 jnp.mean(vals), -jnp.mean(vneg)))
        assert abs(float(comp.mean) - expect) < 1e-6

    def test_zero_value_bits_accounting(self):
        x = _flat(1)
        p = 0.01
        comp = sbc.sbc_compress_leaf(x, p, None)
        k = api.k_for(x.shape[0], p)
        assert abs(float(comp.nbits) - (k * expected_position_bits(p) + 32)) < 1e-3

    @given(seed=st.integers(0, 50), p=st.sampled_from([0.1, 0.01, 0.002]))
    @settings(max_examples=25, deadline=None)
    def test_sbc_reduces_error_vs_zero(self, seed, p):
        """ΔW* is a better approximation of ΔW than sending nothing."""
        x = _flat(seed, 2048)
        comp = sbc.sbc_compress_leaf(x, p, None)
        dense = sbc.sbc_decompress_leaf(comp, x.shape[0])
        assert float(jnp.linalg.norm(x - dense)) <= float(jnp.linalg.norm(x)) + 1e-6


# ------------------------------------------------------------- error feedback


class TestResidual:
    def test_compress_updates_residual(self):
        comp = api.get_compressor("sbc")
        params = {"w": jnp.zeros((1000,))}
        st0 = comp.init_state(params)
        delta = {"w": _flat(5, 1000)}
        ctree, dense, st1 = comp.compress(delta, st0, 0.01)
        np.testing.assert_allclose(
            np.asarray(st1.residual["w"]),
            np.asarray(delta["w"] - dense["w"]),
            rtol=1e-5, atol=1e-6,
        )

    def test_residual_preserves_information(self):
        """Eq. 2: over T rounds, Σ transmitted + residual == Σ deltas."""
        comp = api.get_compressor("sbc")
        params = {"w": jnp.zeros((512,))}
        state = comp.init_state(params)
        total_delta = jnp.zeros((512,))
        total_sent = jnp.zeros((512,))
        for t in range(5):
            delta = {"w": _flat(t, 512)}
            _, dense, state = comp.compress(delta, state, 0.05)
            total_delta = total_delta + delta["w"]
            total_sent = total_sent + dense["w"]
        np.testing.assert_allclose(
            np.asarray(total_sent + state.residual["w"]),
            np.asarray(total_delta),
            rtol=1e-4, atol=1e-5,
        )


# --------------------------------------------------------------- baselines


ALL = ["none", "fedavg", "topk", "dgc", "signsgd", "onebit", "terngrad", "qsgd", "randomk", "sbc"]


class TestBaselines:
    @pytest.mark.parametrize("name", ALL)
    def test_roundtrip_shape_and_finite(self, name):
        comp = api.get_compressor(name)
        x = _flat(7)
        leaf = comp.compress_leaf(x, 0.01, jax.random.PRNGKey(0))
        dense = comp.decompress_leaf(leaf, x.shape[0])
        assert dense.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(dense)))
        assert float(leaf.nbits) > 0

    def test_dense_is_identity(self):
        comp = api.get_compressor("none")
        x = _flat(9)
        leaf = comp.compress_leaf(x, 1.0, None)
        np.testing.assert_allclose(np.asarray(comp.decompress_leaf(leaf, x.shape[0])),
                                   np.asarray(x))
        assert float(leaf.nbits) == 32.0 * x.shape[0]

    def test_topk_keeps_largest(self):
        comp = api.get_compressor("topk")
        x = _flat(11)
        leaf = comp.compress_leaf(x, 0.01, None)
        dense = comp.decompress_leaf(leaf, x.shape[0])
        k = api.k_for(x.shape[0], 0.01)
        thresh = jnp.sort(jnp.abs(x))[-k]
        picked = jnp.abs(dense) > 0
        assert bool(jnp.all(jnp.abs(x)[picked] >= thresh - 1e-6))

    def test_signsgd_is_scaled_sign(self):
        comp = api.get_compressor("signsgd")
        x = _flat(13)
        dense = comp.decompress_leaf(comp.compress_leaf(x, 1.0, None), x.shape[0])
        s = float(jnp.mean(jnp.abs(x)))
        np.testing.assert_allclose(np.asarray(dense), np.asarray(jnp.sign(x) * s),
                                   rtol=1e-5)

    def test_terngrad_unbiased(self):
        """E[quantized] == input (stochastic ternary is unbiased)."""
        comp = api.get_compressor("terngrad")
        x = jnp.array([0.5, -0.25, 0.1, 0.0])
        n_trials = 3000
        keys = jax.random.split(jax.random.PRNGKey(0), n_trials)
        out = jax.vmap(lambda k: comp.decompress_leaf(
            comp.compress_leaf(x, 1.0, k), 4))(keys)
        np.testing.assert_allclose(np.asarray(jnp.mean(out, 0)), np.asarray(x),
                                   atol=0.03)

    def test_qsgd_unbiased(self):
        comp = api.get_compressor("qsgd")
        x = jnp.array([0.5, -0.25, 0.1, 0.0])
        keys = jax.random.split(jax.random.PRNGKey(1), 3000)
        out = jax.vmap(lambda k: comp.decompress_leaf(
            comp.compress_leaf(x, 1.0, k), 4))(keys)
        np.testing.assert_allclose(np.asarray(jnp.mean(out, 0)), np.asarray(x),
                                   atol=0.02)

    def test_table1_ordering(self):
        """Theoretical compression rates preserve the paper's Table I order:
        dense < sign/tern < topk/dgc < fedavg(100) < sbc2 < sbc3."""
        from repro.core.bits import paper_table1

        rows = {r.name: r.compression_rate(25_000_000) for r in paper_table1()}
        assert rows["baseline"] == 1.0
        assert rows["signsgd"] < rows["gradient_dropping"]
        assert rows["gradient_dropping"] < rows["sbc2"]
        assert rows["sbc2"] < rows["sbc3"]
        assert rows["sbc3"] > 20_000  # paper: "up to ×40000"
