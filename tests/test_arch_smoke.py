"""Per-architecture smoke tests (deliverable (f)).

Each assigned architecture instantiates a REDUCED variant of the same family
(≤2 superblock periods, d_model ≤ 256, ≤4 experts) and runs one forward +
one train step on CPU, asserting output shapes and absence of NaNs.  The
FULL configs are exercised only by the dry-run (ShapeDtypeStructs).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ASSIGNED_ARCHS, PAPER_ARCHS
from repro.core.api import get_compressor
from repro.optim import get_optimizer
from repro.train import DSGDTrainer

from conftest import arch_setup

SEQ = 32
BATCH = 2


def _batch_for(cfg, rng):
    if cfg.family == "cnn":
        return {
            "images": jax.random.normal(rng, (BATCH, cfg.img_size, cfg.img_size,
                                               cfg.img_channels)),
            "labels": jnp.zeros((BATCH,), jnp.int32),
        }
    b = {
        "tokens": jax.random.randint(rng, (BATCH, SEQ), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (BATCH, SEQ), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        if cfg.modality == "audio":
            b["enc_frames"] = 0.1 * jax.random.normal(rng, (BATCH, SEQ, cfg.d_model))
        else:
            b["enc_tokens"] = b["tokens"]
    elif cfg.modality == "vision":
        b["prefix"] = 0.1 * jax.random.normal(rng, (BATCH, cfg.n_prefix, cfg.d_model))
    return b


def _no_nan(tree) -> bool:
    return not any(bool(jnp.any(jnp.isnan(x))) for x in jax.tree.leaves(tree)
                   if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + PAPER_ARCHS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch, rng):
        cfg, model, params = arch_setup(arch)
        batch = _batch_for(cfg, rng)

        loss = model.loss_fn(params, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

        grads = jax.grad(model.loss_fn)(params, batch)
        assert _no_nan(grads), f"{arch}: NaN grads"
        assert jax.tree.structure(grads) == jax.tree.structure(params)

    def test_one_dsgd_round(self, arch, rng):
        """One SBC communication round updates weights and stays finite."""
        cfg, model, _ = arch_setup(arch)
        trainer = DSGDTrainer(
            model=model, compressor=get_compressor("sbc"),
            optimizer=get_optimizer("sgd"), n_clients=2, lr=lambda it: 0.05,
        )
        state = trainer.init(rng)
        batch = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (2, 1) + x.shape),
            _batch_for(cfg, rng),
        )
        new_state, m = trainer.round_step(state, batch, n_delay=1, sparsity=0.05)
        assert bool(jnp.isfinite(m["loss"]))
        assert float(m["bits_per_client"]) < float(m["bits_dense"])
        assert _no_nan(new_state.params)
        # weights actually moved
        moved = any(
            bool(jnp.any(a != b))
            for a, b in zip(jax.tree.leaves(new_state.params),
                            jax.tree.leaves(state.params))
        )
        assert moved, f"{arch}: no parameter moved after a round"


DECODE_ARCHS = [a for a in ASSIGNED_ARCHS]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_prefill(arch, rng):
    """Prefill-then-decode logits ≈ one-shot forward logits at the next
    position (exercises KV-cache / SSM-state correctness per arch)."""
    cfg, model, params = arch_setup(arch)
    batch = _batch_for(cfg, rng)

    hidden, caches = model.prefill(params, batch)
    next_tok = jnp.ones((BATCH, 1), jnp.int32)
    logits, _ = model.decode_step(params, next_tok, caches, jnp.asarray(SEQ))
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # reference: run the full sequence + the new token through prefill again
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], next_tok], axis=1)
    if "enc_frames" in batch2:
        pass  # encoder input unchanged
    hidden2, _ = model.prefill(params, batch2)
    from repro.models import transformer

    emb = transformer.output_embedding(params, cfg)
    ref = hidden2[:, -1:, :].astype(jnp.float32) @ emb.T.astype(jnp.float32)
    # SSM decode paths accumulate fp differences over the state; tolerance
    # is loose but catches index/slot bugs (which produce wildly different
    # logits, not 1e-2 drift)
    err = float(jnp.max(jnp.abs(logits - ref)))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    assert err / scale < 0.05, f"{arch}: decode/prefill mismatch {err/scale:.3f}"
