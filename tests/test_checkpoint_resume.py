"""Checkpoint/resume of a DSGDTrainer mid-run (ISSUE 4 satellite).

A fast=True trainer's per-client error-feedback residual is ONE flat f32
buffer per client (core/flat.py §10).  Saving the full TrainState —
params, per-client optimizer state, the flat residual, RNG keys, round
counter — through checkpoint/io.py and restoring it must continue the
run BIT-identically to an uninterrupted one: error feedback means a
lossy checkpoint would silently change every later update.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import restore_train_state, save_train_state
from repro.core.api import get_compressor
from repro.core.policy import (
    DENSE_SMALL_PATTERN,
    CompressionPolicy,
    PolicyRule,
)
from repro.data import client_batches
from repro.optim import get_optimizer
from repro.train import DSGDTrainer

N_CLIENTS = 2
SPARSITY = 0.02


def make_trainer(lm_setup):
    cfg, model, task = lm_setup
    policy = CompressionPolicy(
        default=get_compressor("sbc").codec,
        rules=(PolicyRule(DENSE_SMALL_PATTERN, codec="dense32"),),
        name="sbc+dense-small",
        fast=True,
    )
    trainer = DSGDTrainer(
        model=model,
        compressor=policy,
        optimizer=get_optimizer("momentum"),
        n_clients=N_CLIENTS,
        lr=lambda it: 0.1,
    )
    return trainer, client_batches(task, N_CLIENTS, 1)


def run_rounds(trainer, batch_fn, state, rates, start, n):
    for r in range(start, start + n):
        state, _ = trainer.round_step(
            state, batch_fn(r), n_delay=1, sparsity=rates
        )
    return state


def assert_state_bitwise(a, b):
    la = jax.tree.leaves(a._asdict())
    lb = jax.tree.leaves(b._asdict())
    assert len(la) == len(lb)
    for xa, xb in zip(la, lb):
        na, nb = np.asarray(xa), np.asarray(xb)
        assert na.dtype == nb.dtype and na.shape == nb.shape
        assert na.tobytes() == nb.tobytes()


def test_resume_mid_run_is_bit_identical(tmp_path, lm_setup):
    trainer, batch_fn = make_trainer(lm_setup)
    state = trainer.init(jax.random.PRNGKey(0))
    rates = trainer.resolved(state.params).rates(SPARSITY, 0)

    # the fast path stores the residual FLAT: (clients, n_pad) f32
    assert state.comp_state.residual.ndim == 2
    assert state.comp_state.residual.shape[0] == N_CLIENTS
    assert state.comp_state.residual.dtype == jnp.float32

    # 2 rounds → checkpoint → 2 more rounds, against 4 straight rounds
    mid = run_rounds(trainer, batch_fn, state, rates, 0, 2)
    path = str(tmp_path / "mid.npz")
    save_train_state(path, mid)
    uninterrupted = run_rounds(trainer, batch_fn, mid, rates, 2, 2)

    like = trainer.init(jax.random.PRNGKey(7))  # template only
    restored = restore_train_state(path, like)
    assert_state_bitwise(restored, mid)  # the checkpoint itself is lossless
    assert int(restored.round) == 2
    resumed = run_rounds(trainer, batch_fn, restored, rates, 2, 2)

    assert_state_bitwise(resumed, uninterrupted)


def test_restore_rejects_mismatched_structure(tmp_path, lm_setup):
    import pytest

    trainer, batch_fn = make_trainer(lm_setup)
    state = trainer.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "state.npz")
    save_train_state(path, state)

    wrong = DSGDTrainer(
        model=trainer.model,
        compressor=CompressionPolicy.single(
            get_compressor("sbc").codec, name="sbc", fast=True
        ),
        optimizer=get_optimizer("momentum"),
        n_clients=N_CLIENTS + 1,
        lr=lambda it: 0.1,
    )
    with pytest.raises(ValueError):
        restore_train_state(path, wrong.init(jax.random.PRNGKey(0)))


# ------------------- federated backend (ISSUE 8 satellite) -------------------
#
# The fed checkpoint covers MUCH more than a TrainState: master weights W,
# the replica Ŵ, the server's downstream residual, every client's pooled
# optimizer/compressor rows, the async snapshot ring, the DeltaLog horizon,
# the bandwidth ledger, and a mid-round pending half-round.  Same contract
# as above, federation-wide: restore must continue bit-identically.

from faults import (  # noqa: E402
    FaultSchedule,
    ServerKilled,
    assert_trees_bitwise,
    capture_state,
    make_federation,
)
from faults import run_rounds as run_fed_rounds  # noqa: E402
from repro.fed.checkpoint import restore_fed_state, save_fed_state  # noqa: E402


def _log_state(sched):
    return sched.server.delta_log.state_dict()


def assert_federation_bitwise(a, b):
    """Full-federation equality: state arrays, ledger rows, DeltaLog."""
    assert_trees_bitwise(capture_state(a), capture_state(b), "federation")
    assert a.ledger.totals() == b.ledger.totals()
    assert [vars(r) for r in a.ledger.records] == \
           [vars(r) for r in b.ledger.records]
    la, lb = _log_state(a), _log_state(b)
    assert la["head"] == lb["head"] and la["entries"] == lb["entries"]
    assert_trees_bitwise(la["replica"], lb["replica"], "DeltaLog replica")


def test_fed_resume_at_round_boundary_is_bit_identical(tmp_path):
    sched = make_federation(delta_horizon=4)
    run_fed_rounds(sched, 2)
    path = str(tmp_path / "fed.npz")
    save_fed_state(path, sched, rounds_done=2)
    run_fed_rounds(sched, 4, start=2)  # sched becomes the 4-round reference

    fresh = make_federation(delta_horizon=4)
    meta = restore_fed_state(path, fresh)
    assert meta["rounds_done"] == 2
    run_fed_rounds(fresh, 4, start=2)
    assert_federation_bitwise(fresh, sched)


def test_fed_resume_mid_round_is_bit_identical(tmp_path):
    """Kill the server AFTER partial aggregation of a dropout round, restore
    the checkpoint into a freshly built federation, finish the parked
    half-round, continue — and land on the bytes of a never-killed run."""
    import pytest

    faulted = FaultSchedule(drops=((1, 2),), kill_server=((2, "post_aggregate"),))
    sched = make_federation(faults=faulted, delta_horizon=4)
    run_fed_rounds(sched, 2)
    with pytest.raises(ServerKilled):
        sched.step(2)
    path = str(tmp_path / "fed-mid.npz")
    save_fed_state(path, sched, rounds_done=2)

    fresh = make_federation(faults=faulted, delta_horizon=4)
    meta = restore_fed_state(path, fresh)
    assert meta["rounds_done"] == 2
    # the fired kill is in the checkpoint: the resumed run sails past it
    assert (2, "post_aggregate") in fresh._kills_fired
    m = fresh.resume_pending()
    assert m is not None and m["round"] == 2
    run_fed_rounds(fresh, 5, start=3)

    # reference: the SAME faults minus the kill, never interrupted
    ref = make_federation(faults=FaultSchedule(drops=((1, 2),)), delta_horizon=4)
    run_fed_rounds(ref, 5)
    assert_federation_bitwise(fresh, ref)
    fresh.ledger.reconcile(rel=0.12)


def test_fed_restore_rejects_mismatched_federation(tmp_path):
    import pytest

    sched = make_federation(delta_horizon=4)
    run_fed_rounds(sched, 1)
    path = str(tmp_path / "fed.npz")
    save_fed_state(path, sched)
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_fed_state(path, make_federation(n_clients=6, delta_horizon=4))
    with pytest.raises(ValueError, match="delta_horizon"):
        restore_fed_state(path, make_federation())
