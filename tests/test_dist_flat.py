"""Sharded flat dist exchange (DESIGN.md §11).

Two layers:

  * the 8-fake-device parity matrix runs in a subprocess
    (``dist_flat_check.py``): both client modes must produce bit-identical
    params, residuals, optimizer state, and Eq. 1/Eq. 5 bit counts
    against the per-leaf shard_map path, and the Pallas hist engine must
    execute inside shard_map;
  * single-device unit tests of :class:`ShardedFlatParamSpace` — layout
    invariants, flatten/unflatten round-trip, bit accounting equal to the
    per-leaf static loop, fallback gating.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flat import ShardedFlatParamSpace

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_sharded_flat_parity_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "dist_flat_check.py")],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    out = proc.stdout + proc.stderr
    assert proc.returncode == 0, out[-3000:]
    assert "CHECK all_parity_ok=True" in out
    for line in out.splitlines():
        if line.startswith("CHECK ") and "params_identical" in line:
            for field in ("params_identical", "residual_identical",
                          "opt_identical", "bits_identical",
                          "loss_identical"):
                assert f"{field}=True" in line, line


def _toy_space(kinds=("sparse", "sparse", "dense", "skip")):
    shapes = [(2, 40, 8), (123,), (40,), (7, 3)]
    entries = [
        dict(path=f"leaf{i}", shape=s, rows=s[0] if len(s) > 1 else 1,
             kind=k, rate=0.05, n_shards=1,
             global_size=int(np.prod(s)))
        for i, (s, k) in enumerate(zip(shapes, kinds))
    ]
    return ShardedFlatParamSpace.build(
        entries, client_axes=(), shard_axes=(), n_clients=1,
        shards_per_client=1,
    )


class TestShardedSpace:
    def test_layout_invariants(self):
        space = _toy_space()
        per_block = space.bm * space.lanes
        for seg in space.segments:
            assert seg.offset % per_block == 0
            assert seg.n_loc * seg.rows == int(np.prod(seg.shape))
        assert space.n_pad == space.n_blocks * per_block
        # sparse position slots: one per (row, k)
        n_pos = sum(s.rows * s.k for s in space.segments if s.kind == "sparse")
        assert space.n_pos == n_pos

    def test_flatten_unflatten_roundtrip(self):
        space = _toy_space()
        bodies = [
            jax.random.normal(jax.random.PRNGKey(i), seg.shape)
            for i, seg in enumerate(space.segments)
        ]
        flat = space.flatten_local(bodies)
        assert flat.shape == (space.n_pad,)
        back = space.unflatten_local(flat)
        for b, r in zip(bodies, back):
            np.testing.assert_array_equal(np.asarray(b), np.asarray(r))

    def test_exchange_local_single_client(self):
        """No client axes: mean == own, residual identity acc = ΔW* + R,
        sparse rows keep exactly k nonzeros with one shared magnitude."""
        space = _toy_space()
        bodies = [
            0.1 * jax.random.normal(jax.random.PRNGKey(i), seg.shape)
            for i, seg in enumerate(space.segments)
        ]
        res = jnp.zeros((space.n_pad,), jnp.float32)
        mean, own, new_res = jax.jit(space.exchange_local)(bodies, res)
        np.testing.assert_array_equal(np.asarray(mean), np.asarray(own))
        acc = space.flatten_local(bodies)
        np.testing.assert_allclose(
            np.asarray(acc), np.asarray(own + new_res), rtol=1e-6, atol=1e-7
        )
        for seg in space.segments:
            block = np.asarray(
                own[seg.offset:seg.offset + seg.rows * seg.n_loc]
            ).reshape(seg.rows, seg.n_loc)
            if seg.kind == "sparse":
                for row in block:
                    nz = row[row != 0]
                    assert len(nz) == seg.k
                    assert len(set(np.abs(nz).tolist())) == 1
            elif seg.kind == "skip":
                assert not block.any()

    def test_bits_match_per_leaf_static_loop(self):
        """space.bits_per_client() == the per-leaf Eq. 1/Eq. 5 loop on an
        unsharded host mesh (exact float equality)."""
        from repro.configs.base import ModelConfig
        from repro.launch.dist import make_dist_train
        from repro.launch.mesh import make_host_mesh

        cfg = ModelConfig(name="t", family="decoder", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=96,
                          dtype=jnp.float32, client_mode="data",
                          local_opt="sgd", scan_layers=True)
        mesh = make_host_mesh()
        slow = make_dist_train(cfg, mesh, sparsity=0.01)
        fast = make_dist_train(cfg, mesh, sparsity=0.01, fast=True)
        assert fast.flat_space is not None
        assert fast.bits_per_client == slow.bits_per_client
        assert fast.bits_dense == slow.bits_dense

    def test_non_f32_residual_falls_back(self):
        """bf16 residual_dtype keeps the per-leaf exchange (PR 3 rule)."""
        from repro.configs.base import ModelConfig
        from repro.launch.dist import make_dist_train
        from repro.launch.mesh import make_host_mesh

        cfg = ModelConfig(name="t", family="decoder", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=96,
                          dtype=jnp.float32, residual_dtype=jnp.bfloat16,
                          client_mode="data", local_opt="sgd",
                          scan_layers=True)
        fns = make_dist_train(cfg, make_host_mesh(), sparsity=0.01, fast=True)
        assert fns.flat_space is None
        assert fns.residual_to_tree is None

    def test_hist_engine_requires_fast_path(self):
        from repro.configs.base import ModelConfig
        from repro.launch.dist import make_dist_train
        from repro.launch.mesh import make_host_mesh

        cfg = ModelConfig(name="t", family="decoder", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=96,
                          dtype=jnp.float32, residual_dtype=jnp.bfloat16,
                          client_mode="data", local_opt="sgd",
                          scan_layers=True)
        with pytest.raises(ValueError, match="hist"):
            make_dist_train(cfg, make_host_mesh(), sparsity=0.01, fast=True,
                            flat_engine="hist")
