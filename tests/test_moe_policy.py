"""MoE-aware compression policies (ISSUE 10 tentpole part 3).

The ``expert_topk`` selector + ``rate_scale`` reduced-k multiplier +
:func:`repro.core.policy.moe_rules`, exercised on ``mixtral_8x7b``-shaped
tiny stand-ins: selection semantics (per-expert quota, skip-if-unrouted),
rate flow through ``ResolvedPolicy.rates`` → analytic bits → wire specs,
byte-exact SBW1 round-trip, and bit-identical output between the
``fast=True`` engine (which falls back per-leaf for non-flat codecs by
contract) and the exact per-leaf path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core.channel import analytic_bits
from repro.core.codec import make_codec
from repro.core.policy import (
    CompressionPolicy,
    MOE_EXPERT_PATTERN,
    PolicyRule,
    moe_rules,
)
from repro.core.stages import get_selector, k_for
from repro.core.wire import wire_for
from repro.models.model import build_model

E = 4  # reduced() caps experts at 4 — the mixtral stand-in's E


def moe_policy(fast: bool = False) -> CompressionPolicy:
    return CompressionPolicy(
        default=make_codec("sbc"),
        rules=moe_rules(E, top_k=2),
        name="sbc+moe",
        fast=fast,
    )


@pytest.fixture(scope="module")
def mixtral_delta():
    """A gradient-shaped pytree from the reduced mixtral_8x7b config."""
    cfg = reduced(get_config("mixtral_8x7b"))
    assert cfg.moe_experts == E
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    leaves, treedef = jax.tree_util.tree_flatten(params)
    rng = np.random.default_rng(1)
    fake = [
        jnp.asarray(rng.standard_normal(np.shape(x)), jnp.float32)
        for x in leaves
    ]
    return jax.tree_util.tree_unflatten(treedef, fake)


# ------------------------------------------------------- selector semantics


class TestExpertTopkSelector:
    def test_exact_k_and_per_expert_cap(self):
        rng = np.random.default_rng(0)
        n, p = E * 64, 0.1
        flat = jnp.asarray(rng.standard_normal(n), jnp.float32)
        sel = get_selector("expert_topk", experts=E)(flat, p, None)
        k = k_for(n, p)
        idx = np.asarray(sel.idx)
        assert idx.size == k
        assert np.unique(idx).size == k  # distinct positions
        quota = -(-k // E)
        per_expert = np.bincount(idx // (n // E), minlength=E)
        assert per_expert.max() <= quota

    def test_unrouted_experts_skip_themselves(self):
        """Experts whose gradient block is exactly zero (no tokens routed)
        win no contested slot — the quota flows to routed experts' noise
        floor only when slots outnumber non-zero candidates."""
        rng = np.random.default_rng(2)
        n = E * 64
        blocks = rng.standard_normal((E, n // E)).astype(np.float32)
        blocks[1] = 0.0  # experts 1 and 3 unrouted this step
        blocks[3] = 0.0
        flat = jnp.asarray(blocks.reshape(-1))
        sel = get_selector("expert_topk", experts=E)(flat, 0.1, None)
        owners = np.asarray(sel.idx) // (n // E)
        assert set(owners.tolist()) <= {0, 2}
        assert not np.any(np.asarray(sel.vals) == 0.0)

    def test_hot_expert_cannot_crowd_out_others(self):
        """Global top-k would give every slot to the ×100 expert; the
        per-expert quota guarantees the others keep representation."""
        rng = np.random.default_rng(3)
        n = E * 64
        blocks = rng.standard_normal((E, n // E)).astype(np.float32)
        blocks[0] *= 100.0
        flat = jnp.asarray(blocks.reshape(-1))
        k = k_for(n, 0.2)
        sel = get_selector("expert_topk", experts=E)(flat, 0.2, None)
        per_expert = np.bincount(
            np.asarray(sel.idx) // (n // E), minlength=E
        )
        assert per_expert[0] <= -(-k // E)
        assert np.all(per_expert > 0)

    def test_indivisible_leaf_degrades_to_topk(self):
        rng = np.random.default_rng(4)
        flat = jnp.asarray(rng.standard_normal(257), jnp.float32)
        a = get_selector("expert_topk", experts=E)(flat, 0.05, None)
        b = get_selector("topk")(flat, 0.05, None)
        np.testing.assert_array_equal(
            np.sort(np.asarray(a.idx)), np.sort(np.asarray(b.idx))
        )


# ----------------------------------------------------------- rate_scale flow


class TestRateScale:
    def test_scale_composes_with_global_rate_and_schedule(self):
        rule = PolicyRule(r"w", rate_scale=0.5)
        pol = CompressionPolicy(default=make_codec("sbc"), rules=(rule,))
        res = pol.resolve({"w": jnp.zeros(8), "v": jnp.zeros(8)})
        by_path = dict(zip((p.path for p in res.plans), res.rates(0.1)))
        assert by_path["w"] == pytest.approx(0.05)
        assert by_path["v"] == pytest.approx(0.1)

        sched = PolicyRule(r"w", schedule=lambda r: 0.2 / (r + 1),
                           rate_scale=0.5)
        res = CompressionPolicy(
            default=make_codec("sbc"), rules=(sched,)
        ).resolve({"w": jnp.zeros(8)})
        assert res.rates(1.0, round_idx=1)[0] == pytest.approx(0.05)

    def test_scaled_rates_price_fewer_bits(self, mixtral_delta):
        """The reduced-k multiplier flows into Eq. 1 pricing: expert
        leaves cost ~top_k/E of their unscaled bill."""
        res = moe_policy().resolve(mixtral_delta)
        leaves = res.treedef.flatten_up_to(mixtral_delta)
        scaled = analytic_bits(res, leaves, res.rates(0.1))
        unscaled = analytic_bits(
            res, leaves, tuple(p.rate(0.1) / p.rate_scale for p in res.plans)
        )
        assert scaled.per_client < unscaled.per_client
        import re

        for plan, lo, hi in zip(
            res.plans,
            _per_leaf(res, leaves, res.rates(0.1)),
            _per_leaf(res, leaves, tuple(
                p.rate(0.1) / p.rate_scale for p in res.plans
            )),
        ):
            if re.search(MOE_EXPERT_PATTERN, plan.path):
                assert lo < hi


def _per_leaf(res, leaves, rates):
    out = []
    for plan, leaf, p in zip(res.plans, leaves, rates):
        n = int(np.prod(np.shape(leaf)))
        c = plan.codec
        if c.skip:
            out.append(0.0)
        elif c.selector.dense:
            out.append(float(c.quantizer.value_bits(n)))
        else:
            k = k_for(n, p)
            out.append(float(c.encoder.position_bits(n, k, p)
                             + c.quantizer.value_bits(k)))
    return out


# ------------------------------------------------- engine + wire parity


class TestMixtralStandInParity:
    def test_fast_engine_falls_back_bit_identically(self, mixtral_delta):
        """expert_topk has no flat form, so a fast=True MoE policy must
        take the per-leaf path and produce bit-identical output (the
        documented silent-fallback contract of DESIGN.md §10)."""
        exact = moe_policy(fast=False).resolve(mixtral_delta)
        fast = moe_policy(fast=True).resolve(mixtral_delta)
        assert not fast.fast_compatible
        se = exact.init_state(mixtral_delta)
        sf = fast.init_state(mixtral_delta)
        ce, de, _ = exact.compress(mixtral_delta, se, exact.rates(0.05))
        cf, df, _ = fast.compress(mixtral_delta, sf, fast.rates(0.05))
        for a, b in zip(jax.tree.leaves(de), jax.tree.leaves(df)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert float(exact.total_bits(ce)) == float(fast.total_bits(cf))

    def test_wire_round_trip_byte_exact(self, mixtral_delta):
        res = moe_policy().resolve(mixtral_delta)
        state = res.init_state(mixtral_delta)
        ctree, dense, _ = res.compress(mixtral_delta, state, res.rates(0.05))
        ctree = jax.tree.map(np.asarray, ctree)
        wire = wire_for(res, mixtral_delta, 0.05)
        blob = wire.pack(ctree)
        rec = wire.unpack(blob)
        flat_d, _ = jax.tree_util.tree_flatten(dense)
        flat_r, _ = jax.tree_util.tree_flatten(rec)
        assert len(flat_d) == len(flat_r)
        for a, b in zip(flat_d, flat_r):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32).reshape(-1),
                np.asarray(b).reshape(-1),
            )

    def test_router_rides_dense_and_experts_scaled(self, mixtral_delta):
        res = moe_policy().resolve(mixtral_delta)
        import re

        saw_router = saw_expert = False
        for plan in res.plans:
            if re.search(r"moe/router", plan.path):
                assert plan.codec.selector.dense
                saw_router = True
            elif re.search(MOE_EXPERT_PATTERN, plan.path):
                assert plan.codec.selector.name == "expert_topk"
                assert plan.rate_scale == pytest.approx(2.0 / E)
                saw_expert = True
        assert saw_router and saw_expert
