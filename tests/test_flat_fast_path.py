"""Flat-buffer fast path (core/flat.py, DESIGN.md §10).

Three layers of guarantees:

  * the EXACT engine is bit-identical to the legacy per-leaf path — same
    LeafCompressed trees, same SBW1 bytes, same residuals, same RNG
    trajectory — across rounds, under vmap, and on the edge cases the
    layout makes interesting (non-block-multiple "padded tail" leaves,
    all-zero leaves, skip/dense segments);
  * the segment-aware Pallas kernels (kernels/flat.py, interpret mode)
    match the pure-jnp oracles in kernels/ref.py and the per-leaf kernels
    bit for bit at matching tile shapes;
  * the HIST engine reproduces per-leaf ``ops.sbc_compress_hist`` per
    segment and keeps the acc == ΔW* + R residual identity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flat as flatmod
from repro.core.api import get_compressor
from repro.core.policy import (
    DENSE_SMALL_PATTERN,
    CompressionPolicy,
    CompressorState,
    PolicyRule,
)
from repro.core.wire import wire_for
from repro.kernels import ops, ref
from repro.kernels.flat import seg_binarize_apply, seg_hist2side, seg_moments
from repro.kernels.hist2side import SPAN_OCTAVES, hist2side
from repro.kernels.moments import masked_moments

BM, LANES = 8, 128


def tree_like():
    """A pytree exercising every flat segment kind and edge case:
    2-D matrices, a dense-ridden bias, a skipped leaf, a non-block-multiple
    tail (17), and an all-zero leaf."""
    return {
        "layer0": {"w": jnp.zeros((50, 40)), "bias": jnp.zeros((40,))},
        "layer1": {"w": jnp.zeros((123,)), "frozen": jnp.zeros((7, 3))},
        "tail": jnp.zeros((17,)),
        "zero": jnp.zeros((65,)),
    }


def sbc_policy(fast: bool) -> CompressionPolicy:
    return CompressionPolicy(
        default=get_compressor("sbc").codec,
        rules=(PolicyRule(r"frozen", codec="skip"),
               PolicyRule(DENSE_SMALL_PATTERN, codec="dense32")),
        name="sbc+rules",
        fast=fast,
    )


def rand_delta(seed: int = 3):
    params = tree_like()
    delta = jax.tree.map(
        lambda x: 0.1 * jax.random.normal(jax.random.PRNGKey(seed), x.shape),
        params,
    )
    delta["zero"] = jnp.zeros((65,))  # all-zero leaf keeps its edge case
    return params, delta


def assert_trees_bitwise(a, b, what=""):
    la = jax.tree_util.tree_flatten_with_path(a)[0]
    lb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(la) == len(lb)
    for (pa, xa), (_, xb) in zip(la, lb):
        na, nb = np.asarray(xa), np.asarray(xb)
        assert na.shape == nb.shape and na.tobytes() == nb.tobytes(), (
            f"{what} mismatch at {jax.tree_util.keystr(pa)}"
        )


class TestExactEngine:
    def test_bit_identical_over_rounds(self):
        params, delta = rand_delta()
        res_legacy = sbc_policy(fast=False).resolve(params)
        res_fast = sbc_policy(fast=True).resolve(params)
        assert res_fast.fast_compatible
        sl = res_legacy.init_state(params)
        sf = res_fast.init_state(params)
        # fast residual is ONE flat buffer, not a pytree
        assert hasattr(sf.residual, "ndim") and sf.residual.ndim == 1
        rates = res_legacy.rates(0.05, 0)
        space = res_fast.flat_space(params)
        wire = wire_for(res_legacy, params, 0.05)

        for _ in range(3):  # residual feedback must stay in lockstep
            ctL, dnL, sl = res_legacy.compress(delta, sl, rates)
            ctF, dnF, sf = res_fast.compress(delta, sf, rates)
            assert_trees_bitwise(ctL, ctF, "ctree")
            assert_trees_bitwise(dnL, dnF, "dense")
            assert np.asarray(space.flatten(sl.residual)).tobytes() == \
                np.asarray(sf.residual).tobytes()
            assert wire.pack(jax.device_get(ctL)) == wire.pack(jax.device_get(ctF))
            assert np.array_equal(np.asarray(sl.rng), np.asarray(sf.rng))

    def test_all_zero_leaf(self):
        """top_k on an all-zero leaf ties everywhere: both paths pick the
        first k indices of the losing-side tiebreak and a μ of exactly +0.0
        (the sign bit is packed as f32, so it must match bitwise too —
        covered by test_bit_identical_over_rounds; this pins the values)."""
        params, delta = rand_delta()
        res_fast = sbc_policy(fast=True).resolve(params)
        ct, dn, _ = res_fast.compress(delta, res_fast.init_state(params),
                                      res_fast.rates(0.05, 0))
        mu = np.asarray(ct["zero"].mean)
        assert mu == 0.0 and not np.signbit(mu)
        k = ct["zero"].idx.shape[0]
        np.testing.assert_array_equal(np.sort(np.asarray(ct["zero"].idx)),
                                      np.arange(k))
        assert not np.asarray(dn["zero"]).any()

    def test_vmapped_client_axis(self):
        params, _ = rand_delta()
        C = 3
        deltas = jax.tree.map(
            lambda x: 0.1 * jax.random.normal(jax.random.PRNGKey(7), (C,) + x.shape),
            params,
        )
        res_legacy = sbc_policy(fast=False).resolve(params)
        res_fast = sbc_policy(fast=True).resolve(params)
        rates = res_legacy.rates(0.05, 0)
        rngs = jax.random.split(jax.random.PRNGKey(5), C)
        sl = CompressorState(
            residual=jax.tree.map(
                lambda x: jnp.zeros((C,) + x.shape, x.dtype),
                res_legacy.init_state(params).residual,
            ),
            rng=rngs, step=jnp.zeros((C,), jnp.int32),
        )
        n_pad = res_fast.flat_space(params).n_pad
        sf = CompressorState(
            residual=jnp.zeros((C, n_pad), jnp.float32),
            rng=rngs, step=jnp.zeros((C,), jnp.int32),
        )
        ctL, dnL, _ = jax.vmap(lambda d, s: res_legacy.compress(d, s, rates))(deltas, sl)
        ctF, dnF, _ = jax.vmap(lambda d, s: res_fast.compress(d, s, rates))(deltas, sf)
        assert_trees_bitwise(ctL, ctF, "vmapped ctree")
        assert_trees_bitwise(dnL, dnF, "vmapped dense")

    def test_unsupported_codec_falls_back_to_per_leaf(self):
        """A fast=True policy whose codec has no flat form must silently
        use the legacy path (pytree residual, identical output)."""
        params, delta = rand_delta()
        pol = CompressionPolicy.single(get_compressor("topk").codec, name="topk")
        res_slow = pol.resolve(params)
        res_fast = dataclasses.replace(pol, fast=True).resolve(params)
        assert not res_fast.fast_compatible
        assert res_fast.flat_space(params) is None
        sl = res_slow.init_state(params)
        sf = res_fast.init_state(params)
        assert jax.tree_util.tree_structure(sl.residual) == \
            jax.tree_util.tree_structure(sf.residual)
        ctL, _, _ = res_slow.compress(delta, sl, 0.05)
        ctF, _, _ = res_fast.compress(delta, sf, 0.05)
        assert_trees_bitwise(ctL, ctF, "fallback ctree")

    def test_non_f32_leaves_fall_back_to_per_leaf(self):
        """bf16 trees stay on the legacy path: the flat residual is f32,
        but the per-leaf engine re-quantizes the residual to the leaf
        dtype each round (DESIGN.md §8 configs) — the fast path must not
        silently change that trajectory."""
        params = {"w": jnp.zeros((64, 8), jnp.bfloat16),
                  "v": jnp.zeros((33,), jnp.bfloat16)}
        delta = jax.tree.map(
            lambda x: (0.1 * jax.random.normal(jax.random.PRNGKey(0), x.shape)
                       ).astype(x.dtype),
            params,
        )
        pol = CompressionPolicy.single(get_compressor("sbc").codec)
        res_fast = dataclasses.replace(pol, fast=True).resolve(params)
        assert res_fast.flat_space(params) is None
        sf = res_fast.init_state(params)
        # pytree residual, leaf-dtype preserved (legacy behavior)
        assert jax.tree_util.tree_structure(sf.residual) == \
            jax.tree_util.tree_structure(params)
        res_slow = pol.resolve(params)
        ctL, _, slL = res_slow.compress(delta, res_slow.init_state(params), 0.05)
        ctF, _, sfF = res_fast.compress(delta, sf, 0.05)
        assert_trees_bitwise(ctL, ctF, "bf16 fallback ctree")
        assert_trees_bitwise(slL.residual, sfF.residual, "bf16 residual")

    def test_decompress_and_total_bits_work_on_fast_output(self):
        params, delta = rand_delta()
        res_fast = sbc_policy(fast=True).resolve(params)
        ct, dn, _ = res_fast.compress(delta, res_fast.init_state(params),
                                      res_fast.rates(0.05, 0))
        rec = res_fast.decompress(ct, params)
        assert_trees_bitwise(rec, dn, "decompress")
        assert float(res_fast.total_bits(ct)) > 0


class TestSegKernels:
    """Flat segment kernels vs the per-leaf kernels and ref.py oracles."""

    # (sizes) per segment: padded tail + block-multiple + all-zero
    SIZES = (1000, BM * LANES, 65, 17)

    def _layout(self, seed=0):
        per_block = BM * LANES
        rng = np.random.default_rng(seed)
        segs = []
        off = 0
        for i, s in enumerate(self.SIZES):
            x = (rng.standard_normal(s) * 2.0).astype(np.float32)
            if s == 65:
                x[:] = 0.0  # all-zero segment
            segs.append((off, s, x))
            off += max(1, -(-s // per_block)) * per_block
        xpad = np.zeros((off,), np.float32)
        seg_of_block = np.zeros((off // per_block,), np.int32)
        for i, (o, s, x) in enumerate(segs):
            xpad[o:o + s] = x
            seg_of_block[o // per_block:(o + s - 1) // per_block + 1] = i
        return segs, xpad.reshape(-1, LANES), seg_of_block

    def test_seg_hist2side_matches_per_leaf_and_ref(self):
        segs, xpad, sob = self._layout()
        nbins = 32
        los = np.array([max(np.abs(x).max(), 1e-30) * 2.0**-SPAN_OCTAVES
                        for _, _, x in segs], np.float32)
        his = np.array([max(np.abs(x).max(), 1e-30) * 1.0001
                        for _, _, x in segs], np.float32)
        params = np.stack([sob.astype(np.float32), los[sob], his[sob],
                           los[sob], his[sob]], axis=1)
        got = seg_hist2side(jnp.asarray(xpad), jnp.asarray(params),
                            nseg=len(segs), nbins=nbins, bm=BM, lanes=LANES)
        for i, (_, _, x) in enumerate(segs):
            want_leaf = hist2side(jnp.asarray(x), los[i], his[i],
                                  nbins=nbins, bm=BM, lanes=LANES)
            want_ref = ref.hist2side_ref(jnp.asarray(x), los[i], his[i], nbins=nbins)
            np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want_leaf))
            np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want_ref))

    def test_seg_moments_matches_per_leaf_and_ref(self):
        segs, xpad, sob = self._layout(1)
        tp = np.array([0.7, 0.5, 0.1, 0.3], np.float32)
        tn = np.array([0.9, 0.6, 0.1, 0.2], np.float32)
        params = np.stack([sob.astype(np.float32), tp[sob], tn[sob]], axis=1)
        got = seg_moments(jnp.asarray(xpad), jnp.asarray(params),
                          nseg=len(segs), bm=BM, lanes=LANES)
        for i, (_, _, x) in enumerate(segs):
            want_leaf = masked_moments(jnp.asarray(x), tp[i], tn[i],
                                       bm=BM, lanes=LANES)
            want_ref = ref.masked_moments_ref(jnp.asarray(x), tp[i], tn[i])
            np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want_leaf))
            np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want_ref),
                                       rtol=1e-4)

    def test_seg_binarize_apply_matches_ref(self):
        segs, xpad, sob = self._layout(2)
        tp = np.array([0.5, 0.4, 0.1, 0.2], np.float32)
        tn = np.array([0.6, 0.5, 0.1, 0.3], np.float32)
        mu = np.array([0.55, -0.45, 0.2, 0.1], np.float32)
        side = np.array([1.0, 0.0, 1.0, 0.0], np.float32)
        params = np.stack([tp[sob], tn[sob], mu[sob], side[sob]], axis=1)
        out, res = seg_binarize_apply(jnp.asarray(xpad), jnp.asarray(params),
                                      bm=BM, lanes=LANES)
        out, res = np.asarray(out).reshape(-1), np.asarray(res).reshape(-1)
        for i, (o, s, x) in enumerate(segs):
            w_out, w_res = ref.binarize_apply_ref(
                jnp.asarray(x), tp[i], tn[i], mu[i], side[i])
            np.testing.assert_array_equal(out[o:o + s], np.asarray(w_out))
            np.testing.assert_array_equal(res[o:o + s], np.asarray(w_res))
        # padding region: ΔW* = 0 and R = 0 (caller slices it off)
        pad = np.ones((xpad.size,), bool)
        for o, s, _ in segs:
            pad[o:o + s] = False
        assert not out[pad].any() and not res[pad].any()


class TestHistEngine:
    def test_matches_per_leaf_sbc_compress_hist(self):
        """Flat hist pipeline == per-leaf kernel pipeline per segment:
        identical block partition → identical accumulation order → μ,
        counts, ΔW*, and residuals match bit for bit."""
        params = {"a": jnp.zeros((70, 80)), "b": jnp.zeros((333,)),
                  "c": jnp.zeros((17,)), "z": jnp.zeros((50,))}
        delta = jax.tree.map(
            lambda x: 0.1 * jax.random.normal(jax.random.PRNGKey(11), x.shape),
            params,
        )
        delta["z"] = jnp.zeros((50,))
        pol = dataclasses.replace(
            CompressionPolicy.single(get_compressor("sbc").codec), fast=True
        )
        res = pol.resolve(params)
        space = flatmod.FlatParamSpace.for_resolved(res, params, bm=BM, lanes=LANES)
        state = res.init_state(params)
        rates = res.rates(0.05, 0)
        dense_tree, new_state, stats = space.compress_hist(
            delta, state, rates, nbins=32
        )

        from repro.core.golomb import expected_position_bits
        from repro.kernels.binarize_apply import binarize_apply
        from repro.kernels.hist2side import bucket_lower_edges

        for i, name in enumerate(["a", "b", "c", "z"]):
            x = delta[name].reshape(-1).astype(jnp.float32)
            n = x.shape[0]
            k = max(1, min(n, int(round(rates[i] * n))))
            scale = jnp.max(jnp.abs(x)) + 1e-30
            lo0, hi0 = scale * 2.0**-SPAN_OCTAVES, scale * 1.0001
            h1 = hist2side(x, lo0, hi0, nbins=32, bm=BM, lanes=LANES)
            e0 = bucket_lower_edges(lo0, hi0, 32)
            kf = jnp.asarray(k, jnp.float32)
            lo_p, hi_p, ab_p = ops._side_threshold(h1[0], e0, kf)
            lo_n, hi_n, ab_n = ops._side_threshold(h1[1], e0, kf)
            h2 = hist2side(x, jnp.stack([lo_p, lo_n]), jnp.stack([hi_p, hi_n]),
                           nbins=32, bm=BM, lanes=LANES)
            t_pos, _, _ = ops._side_threshold(
                h2[0], bucket_lower_edges(lo_p, hi_p, 32), kf - ab_p)
            t_neg, _, _ = ops._side_threshold(
                h2[1], bucket_lower_edges(lo_n, hi_n, 32), kf - ab_n)
            mom = masked_moments(x, t_pos, t_neg, bm=BM, lanes=LANES)
            mu_pos = mom[0, 0] / jnp.maximum(mom[0, 1], 1.0)
            mu_neg = -mom[1, 0] / jnp.maximum(mom[1, 1], 1.0)
            win = mu_pos > mu_neg
            mu = jnp.where(win, mu_pos, -mu_neg)
            cnt = jnp.where(win, mom[0, 1], mom[1, 1])
            out, _ = binarize_apply(x, t_pos, t_neg, mu, win.astype(jnp.float32),
                                    bm=BM, lanes=LANES)
            assert np.asarray(dense_tree[name]).reshape(-1).tobytes() == \
                np.asarray(out).tobytes()
            assert np.asarray(stats["mu"][i]).tobytes() == np.asarray(mu).tobytes()
            assert float(stats["count"][i]) == float(cnt)
            want_bits = float(cnt) * expected_position_bits(rates[i]) + 32.0
            np.testing.assert_allclose(float(stats["nbits"][i]), want_bits,
                                       rtol=1e-5)

        # Eq. 2 residual identity over the whole buffer
        acc = space.flatten(delta)
        recon = space.flatten(dense_tree) + new_state.residual
        np.testing.assert_allclose(np.asarray(acc), np.asarray(recon),
                                   rtol=1e-6, atol=1e-7)

    def test_rejects_non_sbc_policies(self):
        params, delta = rand_delta()
        res = sbc_policy(fast=True).resolve(params)  # has dense/skip leaves
        space = res.flat_space(params)
        with pytest.raises(ValueError, match="all-SBC"):
            space.compress_hist(delta, res.init_state(params),
                                res.rates(0.05, 0))
