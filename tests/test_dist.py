"""Distributed-path tests.

The sharded train_step must EXECUTE correctly, not only lower — we run it
in a subprocess with 8 fake CPU devices on a (2,2,2) pod/data/model mesh
(tests in this process keep the single real device, per the dry-run rule).
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dist_train_step_executes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "dist_check.py")],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    out = proc.stdout + proc.stderr
    assert proc.returncode == 0, out[-3000:]
    assert "CHECK all_modes_ok=True" in out
    for line in out.splitlines():
        if line.startswith("CHECK ") and "loss_finite" in line:
            assert "loss_finite=True" in line, line
            assert "moved=True" in line, line
