"""Executed by test_dist_flat.py in a subprocess with 8 fake CPU devices.

Parity of the §11 sharded flat dist exchange against the per-leaf
shard_map path on a (2, 2, 2) ('pod', 'data', 'model') mesh — the ISSUE 4
acceptance matrix:

  * both client modes ('data': 4 clients, 'pod': 2 clients),
  * aggregated params BIT-IDENTICAL per step,
  * the flat sharded residual, viewed as a pytree, BIT-IDENTICAL to the
    per-leaf residual,
  * momentum state bit-identical (exercises the own/ΔW*_i masking path),
  * static Eq. 1/Eq. 5 bit accounting exactly equal,
  * a mixed per-leaf policy (sparse + dense-small + skip) rides the same
    flat buffer,
  * the Pallas hist engine ('flat_engine="hist"') executes inside
    shard_map (loss finite, params move; approximate by design).

Prints CHECK lines; the pytest wrapper asserts on them.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")  # forced devices are CPU-only

import jax
import jax.numpy as jnp
import numpy as np

try:  # reuse the suite's persistent compile cache (conftest.py does the
    # same for in-process tests; this child pays the dominant compiles)
    jax.config.update(
        "jax_compilation_cache_dir",
        os.path.join(os.path.dirname(os.path.abspath(__file__)), ".jax_cache"),
    )
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)
except Exception:  # pragma: no cover - older jax without the flags
    pass

from repro.configs.base import ModelConfig
from repro.core.codec import make_codec
from repro.core.policy import DENSE_SMALL_PATTERN, CompressionPolicy, PolicyRule
from repro.launch.dist import client_topology, make_dist_train
from repro.models.model import build_model

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))


def tiny(client_mode):
    return ModelConfig(
        name="tiny", family="decoder", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=96, dtype=jnp.float32,
        client_mode=client_mode, local_opt="momentum", base_lr=0.05,
        scan_layers=True,
    )


def mixed_policy(fast):
    return CompressionPolicy(
        default=make_codec("sbc"),
        rules=(PolicyRule(r"(^|/)wv(/|$)", codec="skip"),
               PolicyRule(DENSE_SMALL_PATTERN, codec="dense32")),
        name="sbc+rules",
        fast=fast,
    )


def tree_bytes_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all(
        np.asarray(x).tobytes() == np.asarray(y).tobytes()
        for x, y in zip(la, lb)
    )


def make_batch(cfg, n_clients, seed=1):
    rng = jax.random.PRNGKey(seed)
    per = max(8 // n_clients, 2)
    return {
        "tokens": jax.random.randint(rng, (n_clients, per, 16), 0, 96),
        "labels": jax.random.randint(rng, (n_clients, per, 16), 0, 96),
    }


def run_parity(client_mode, policy_fn=None, tag=""):
    cfg = tiny(client_mode)
    model = build_model(cfg)
    kw = {}
    if policy_fn is not None:
        kw["policy"] = policy_fn(False)
    slow = make_dist_train(cfg, mesh, sparsity=0.05, model=model, **kw)
    if policy_fn is not None:
        kw["policy"] = policy_fn(True)
    fast = make_dist_train(cfg, mesh, sparsity=0.05, model=model, fast=True, **kw)
    assert fast.flat_space is not None, "sharded flat fast path did not engage"
    n_clients, _ = client_topology(cfg, mesh)

    bits_ok = (slow.bits_per_client == fast.bits_per_client
               and slow.bits_dense == fast.bits_dense)
    batch = make_batch(cfg, n_clients)
    states = {}
    for name, fns in (("slow", slow), ("fast", fast)):
        state = jax.device_put(
            fns.init_state(jax.random.PRNGKey(0)), fns.state_shardings
        )
        b = jax.device_put(batch, fns.batch_shardings(batch))
        for _ in range(3):
            state, metrics = fns.train_step(state, b)
        states[name] = (state, metrics)

    s_state, s_metrics = states["slow"]
    f_state, f_metrics = states["fast"]
    params_ok = tree_bytes_equal(s_state["params"], f_state["params"])
    opt_ok = tree_bytes_equal(s_state["opt"], f_state["opt"])
    res_ok = tree_bytes_equal(
        s_state["residual"], fast.residual_to_tree(f_state["residual"])
    )
    loss_ok = float(s_metrics["loss"]) == float(f_metrics["loss"])
    label = tag or client_mode
    print(f"CHECK {label} params_identical={params_ok} "
          f"residual_identical={res_ok} opt_identical={opt_ok} "
          f"bits_identical={bits_ok} loss_identical={loss_ok} "
          f"bits={fast.bits_per_client:.6e}")
    return params_ok and res_ok and opt_ok and bits_ok and loss_ok


def run_hist_smoke():
    cfg = tiny("data")
    model = build_model(cfg)
    fns = make_dist_train(cfg, mesh, sparsity=0.05, model=model, fast=True,
                          flat_engine="hist")
    n_clients, _ = client_topology(cfg, mesh)
    batch = make_batch(cfg, n_clients)
    state = jax.device_put(
        fns.init_state(jax.random.PRNGKey(0)), fns.state_shardings
    )
    b = jax.device_put(batch, fns.batch_shardings(batch))
    p0 = jax.tree.map(lambda x: x.copy(), state["params"])
    state, metrics = fns.train_step(state, b)
    finite = bool(jnp.isfinite(metrics["loss"]))
    moved = any(
        bool(jnp.any(a != c))
        for a, c in zip(jax.tree.leaves(state["params"]), jax.tree.leaves(p0))
    )
    print(f"CHECK hist loss_finite={finite} moved={moved}")
    return finite and moved


if __name__ == "__main__":
    ok = run_parity("data")
    ok &= run_parity("pod")
    ok &= run_parity("data", policy_fn=mixed_policy, tag="data+policy")
    ok &= run_hist_smoke()
    print(f"CHECK all_parity_ok={bool(ok)}")
