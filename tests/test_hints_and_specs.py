"""Sharding-hint no-op behavior + parameter-spec rule tests (single device)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, get_config
from repro.launch.mesh import make_production_mesh  # noqa: F401 (not built here)
from repro.models import hints
from repro.models.model import build_model, make_param_specs


class TestHintsNoop:
    def test_act_identity_without_context(self):
        x = jnp.ones((2, 8, 4))
        np.testing.assert_array_equal(np.asarray(hints.act(x)), np.asarray(x))

    def test_expert_hints_identity_without_context(self):
        x = jnp.ones((4, 2, 3, 5))
        np.testing.assert_array_equal(np.asarray(hints.expert_grouped(x)), np.asarray(x))
        np.testing.assert_array_equal(np.asarray(hints.expert_flat(x)), np.asarray(x))

    def test_lean_moe_default_off(self):
        assert hints.lean_moe() is False


class _FakeMesh:
    """Shape-only stand-in so spec rules can be tested on one device."""

    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        import numpy as _np

        self.devices = _np.empty(tuple(sizes.values()), dtype=object)


class TestParamSpecRules:
    def _specs(self, cfg, **kw):
        model = build_model(cfg)
        a = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        mesh = _FakeMesh({"data": 16, "model": 16})
        return a, make_param_specs(a, mesh, **kw)

    def test_attention_tp_rules(self):
        cfg = ModelConfig(name="t", family="decoder", n_layers=2, d_model=1024,
                          n_heads=8, n_kv_heads=8, d_ff=4096, vocab_size=32000,
                          dtype=jnp.bfloat16)
        a, specs = self._specs(cfg)
        got = {}
        for path, s in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]:
            got["/".join(k.key for k in path)] = s
        # scanned stack: leading superblock dim must stay unsharded
        wq = [v for k, v in got.items() if k.endswith("inner/wq/w")][0]
        assert wq == P(None, None, "model")
        wo = [v for k, v in got.items() if k.endswith("inner/wo/w")][0]
        assert wo == P(None, "model", None)
        emb = got["embed/embedding"]
        assert emb == P("model", None)

    def test_small_leaves_replicate(self):
        cfg = ModelConfig(name="t", family="decoder", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=96,
                          dtype=jnp.float32)
        a, specs = self._specs(cfg)
        for path, s in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]:
            assert s == P(), path  # every tiny leaf replicated

    def test_expert_parallel_rules(self):
        cfg = get_config("llama4-maverick-400b-a17b")
        model = build_model(cfg)
        a = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        mesh = _FakeMesh({"data": 16, "model": 16})
        specs = make_param_specs(a, mesh, fsdp=True, expert_parallel=True)
        got = {}
        for path, s in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]:
            got["/".join(k.key for k in path)] = s
        up = [v for k, v in got.items() if k.endswith("moe/up")][0]
        # (scan, E, d, ff): experts over data, ff over model, d UNSHARDED
        assert up == P(None, "data", None, "model")
        down = [v for k, v in got.items() if k.endswith("moe/down")][0]
        assert down == P(None, "data", "model", None)

    def test_mixtral_grouped_rules_keep_weights_data_free(self):
        cfg = get_config("mixtral-8x7b")
        model = build_model(cfg)
        a = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        mesh = _FakeMesh({"data": 16, "model": 16})
        specs = make_param_specs(a, mesh, fsdp=True, expert_parallel=True)
        for path, s in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]:
            key = "/".join(k.key for k in path)
            if "moe/" in key and key.split("/")[-1] in ("up", "gate", "down"):
                flat_axes = [a for e in s for a in
                             (e if isinstance(e, tuple) else (e,)) if a]
                assert "data" not in flat_axes, (key, s)
