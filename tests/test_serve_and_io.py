"""Serving engine + checkpoint + data-pipeline tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.configs.base import INPUT_SHAPES, get_config, input_specs
from repro.data import make_classification_task, make_lm_task, split_among_clients
from repro.models.model import build_model
from repro.serve import ServeEngine

from conftest import tiny_decoder


class TestServeEngine:
    def test_greedy_deterministic(self, rng):
        cfg = tiny_decoder()
        model = build_model(cfg)
        params = model.init(rng)
        engine = ServeEngine(model)
        batch = {"tokens": jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)}
        out1 = engine.generate(params, batch, max_new_tokens=8)
        out2 = engine.generate(params, batch, max_new_tokens=8)
        assert out1.shape == (2, 8)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))

    def test_generation_consistent_with_rerun(self, rng):
        """Greedy decode == iterated argmax over full re-forwards."""
        cfg = tiny_decoder()
        model = build_model(cfg)
        params = model.init(rng)
        engine = ServeEngine(model)
        toks = jax.random.randint(rng, (1, 12), 0, cfg.vocab_size)
        out = engine.generate(params, {"tokens": toks}, max_new_tokens=4)

        from repro.models import transformer

        cur = toks
        ref = []
        for _ in range(4):
            hidden, _ = transformer.decoder_hidden(params, cur, cfg)
            emb = transformer.output_embedding(params, cfg)
            logits = hidden[:, -1, :].astype(jnp.float32) @ emb.T.astype(jnp.float32)
            nxt = jnp.argmax(logits, -1)
            ref.append(int(nxt[0]))
            cur = jnp.concatenate([cur, nxt[:, None].astype(jnp.int32)], axis=1)
        assert np.asarray(out)[0].tolist() == ref

    def test_temperature_sampling_runs(self, rng):
        cfg = tiny_decoder()
        model = build_model(cfg)
        params = model.init(rng)
        engine = ServeEngine(model)
        batch = {"tokens": jnp.ones((3, 8), jnp.int32)}
        out = engine.generate(params, batch, max_new_tokens=5, temperature=1.0, rng=rng)
        assert out.shape == (3, 5)
        assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))

    def test_first_token_uses_split_key(self, rng):
        """The first sample must consume a SPLIT of the caller's key, not
        the key itself — reusing it raw would correlate the first decode
        step with any other use of the same key."""
        cfg = tiny_decoder()
        model = build_model(cfg)
        params = model.init(rng)
        engine = ServeEngine(model)
        batch = {"tokens": jnp.ones((4, 8), jnp.int32)}
        key = jax.random.PRNGKey(123)
        out = engine.generate(
            params, batch, max_new_tokens=1, temperature=1.0, rng=key
        )
        logits, _ = engine.prefill(params, batch)
        _, r = jax.random.split(key)
        want = jax.random.categorical(r, logits[:, -1, :] / 1.0)
        np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(want))
        raw = jax.random.categorical(key, logits[:, -1, :] / 1.0)
        assert not np.array_equal(np.asarray(out[:, 0]), np.asarray(raw))


class TestCheckpoint:
    def test_roundtrip_with_bf16(self, tmp_path, rng):
        tree = {
            "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nest": {"b": jnp.ones((5,), jnp.bfloat16) * 1.5,
                     "c": jnp.array([1, 2, 3], jnp.int32)},
        }
        path = os.path.join(tmp_path, "ckpt.npz")
        save_pytree(path, tree)
        back = load_pytree(path, like=tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_structure_mismatch_raises(self, tmp_path):
        path = os.path.join(tmp_path, "c.npz")
        save_pytree(path, {"a": jnp.zeros((2,))})
        with pytest.raises(ValueError, match="mismatch"):
            load_pytree(path, like={"b": jnp.zeros((2,))})
        with pytest.raises(ValueError, match="shape"):
            load_pytree(path, like={"a": jnp.zeros((3,))})

    def test_model_params_roundtrip(self, tmp_path, rng):
        cfg = tiny_decoder()
        model = build_model(cfg)
        params = model.init(rng)
        path = os.path.join(tmp_path, "m.npz")
        save_pytree(path, params)
        back = load_pytree(path, like=params)
        batch = {"tokens": jnp.ones((1, 8), jnp.int32),
                 "labels": jnp.ones((1, 8), jnp.int32)}
        np.testing.assert_allclose(float(model.loss_fn(params, batch)),
                                   float(model.loss_fn(back, batch)), rtol=1e-6)


class TestData:
    def test_markov_task_determinism_and_floor(self):
        task = make_lm_task(vocab=50, batch=4, seq_len=16, temperature=0.3, seed=7)
        b1 = task.sample(3, 1)
        b2 = task.sample(3, 1)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
        assert 0.0 < task.entropy_floor < np.log(50)
        # labels are next tokens
        np.testing.assert_array_equal(np.asarray(b1["labels"][:, :-1]),
                                      np.asarray(b1["tokens"][:, 1:]))

    def test_affine_task_is_deterministic_sequence(self):
        task = make_lm_task(vocab=97, batch=2, seq_len=8, kind="affine")
        b = task.sample(0, 0)
        t, l = np.asarray(b["tokens"]), np.asarray(b["labels"])
        np.testing.assert_array_equal((3 * t[:, 0] + 7) % 97, l[:, 0])

    def test_classification_blobs_separable(self):
        task = make_classification_task(n_classes=4, img_size=8, channels=1,
                                        batch=64, noise=0.05)
        b = task.sample(0, 0)
        assert b["images"].shape == (64, 8, 8, 1)
        assert set(np.unique(np.asarray(b["labels"]))) <= set(range(4))

    def test_client_split_disjoint_streams(self):
        task = make_lm_task(vocab=50, batch=2, seq_len=8)
        bf = split_among_clients(task, 3)
        b = bf(0)
        assert b["tokens"].shape[0] == 3
        assert not np.array_equal(np.asarray(b["tokens"][0]),
                                  np.asarray(b["tokens"][1]))


class TestInputSpecs:
    @pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
    def test_specs_have_expected_fields(self, shape_name):
        cfg = get_config("qwen1.5-4b")
        if cfg.skip_reason(shape_name):
            pytest.skip("documented skip")
        specs = input_specs(cfg, shape_name, n_clients=4)
        kind = INPUT_SHAPES[shape_name]["kind"]
        if kind == "train":
            assert specs["tokens"].shape[0] == 4
            assert specs["tokens"].shape[-1] == INPUT_SHAPES[shape_name]["seq_len"]
        elif kind == "prefill":
            assert specs["tokens"].shape == (
                INPUT_SHAPES[shape_name]["global_batch"],
                INPUT_SHAPES[shape_name]["seq_len"],
            )

    def test_modality_stub_fields(self):
        seam = get_config("seamless-m4t-medium")
        s = input_specs(seam, "train_4k", n_clients=2)
        assert "enc_frames" in s and s["enc_frames"].shape[-1] == seam.d_model
        phi = get_config("phi-3-vision-4.2b")
        s = input_specs(phi, "train_4k", n_clients=2)
        assert "prefix" in s and s["prefix"].shape[-2] == phi.n_prefix
