"""Single-device unit tests of the launch-layer compression kernels.

``_sbc_local`` (the shard-mapped per-shard compressor) must agree with the
paper-faithful Alg. 2 oracle (kernels/ops.sbc_compress_exact) on every row
— this ties the distributed path to the same reference as the Pallas
kernels.  Run WITHOUT a mesh (client_axes=()), where the exchange
degenerates to the identity over one client.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dev dep — fixed-grid fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.golomb import expected_position_bits
from repro.kernels import ops
from repro.launch.dist import _dense_local, _sbc_local


class TestSBCLocal:
    @pytest.mark.parametrize("L,n", [(1, 4096), (3, 2048), (8, 517)])
    @pytest.mark.parametrize("p", [0.05, 0.01])
    def test_matches_alg2_oracle(self, L, n, p):
        flat = jax.random.normal(jax.random.PRNGKey(0), (L, n))
        dense, own = _sbc_local(flat, p, (), 1)
        assert dense.shape == (L, n)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(own))
        for row in range(L):
            want = ops.sbc_compress_exact(flat[row], p=p)
            np.testing.assert_allclose(
                np.asarray(own[row]), np.asarray(want.delta_star), rtol=1e-5,
                atol=1e-7,
            )

    def test_bf16_output_dtype(self):
        flat = jax.random.normal(jax.random.PRNGKey(1), (2, 1024))
        dense, own = _sbc_local(flat, 0.01, (), 1, out_dtype=jnp.bfloat16)
        assert dense.dtype == jnp.bfloat16
        assert own.dtype == jnp.bfloat16
        # still k-sparse with a single shared magnitude per row
        for row in np.asarray(own, np.float32):
            nz = row[row != 0]
            assert len(set(np.abs(nz).tolist())) == 1

    @given(seed=st.integers(0, 30), logn=st.integers(6, 12))
    @settings(max_examples=15, deadline=None)
    def test_row_sparsity_property(self, seed, logn):
        n = 2**logn
        p = 0.02
        flat = jax.random.normal(jax.random.PRNGKey(seed), (2, n))
        _, own = _sbc_local(flat, p, (), 1)
        k = max(1, round(p * n))
        for row in np.asarray(own):
            assert np.count_nonzero(row) == k

    def test_dense_local_identity_no_axes(self):
        flat = jax.random.normal(jax.random.PRNGKey(2), (2, 100))
        dense, own = _dense_local(flat, (), 1)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(flat))
        np.testing.assert_array_equal(np.asarray(own), np.asarray(flat))


class TestStaticBits:
    def test_bits_match_trainer_accounting(self):
        """make_dist_train's static Eq. 1 bits == the laptop trainer's
        per-leaf analytic nbits for an unsharded 1-client mesh."""
        from repro.configs.base import ModelConfig
        from repro.launch.dist import make_dist_train
        from repro.launch.mesh import make_host_mesh

        cfg = ModelConfig(name="t", family="decoder", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=96,
                          dtype=jnp.float32, client_mode="data",
                          local_opt="sgd", scan_layers=True)
        mesh = make_host_mesh()
        p = 0.01
        fns = make_dist_train(cfg, mesh, sparsity=p)
        # recompute by hand: per leaf, L·(k_loc·b̄_pos + 32)
        import jax as _jax

        from repro.models.model import build_model

        a = _jax.eval_shape(lambda: build_model(cfg).init(_jax.random.PRNGKey(0)))
        total = 0.0
        flat = _jax.tree_util.tree_flatten_with_path(a)[0]
        for path, leaf in flat:
            pstr = "/".join(k.key for k in path)
            L = leaf.shape[0] if "stack/scan" in pstr and leaf.ndim > 1 else 1
            n_loc = leaf.size // L
            k = max(1, min(n_loc, round(p * n_loc)))
            total += L * (k * expected_position_bits(p) + 32.0)
        assert abs(fns.bits_per_client - total) / total < 1e-6
        assert fns.bits_dense == 32.0 * sum(l.size for _, l in flat)


class TestSparsitySchedules:
    def test_presets(self):
        from repro.core.sparsity import preset

        assert preset("sbc1")(0) == (1, 0.001)
        assert preset("sbc2")(5) == (10, 0.01)
        assert preset("sbc3")(9) == (100, 0.01)

    def test_dgc_warmup_monotone(self):
        from repro.core.sparsity import dgc_warmup

        s = dgc_warmup(target_sparsity=0.001, warmup_rounds=4)
        vals = [s(r)[1] for r in range(6)]
        assert vals[0] > vals[1] > vals[2] > vals[3]
        assert vals[4] == vals[5] == 0.001

    def test_adaptive_budget_conserved(self):
        """§III: the adaptive controller keeps total sparsity ≈ budget and
        shifts from temporal to gradient sparsity after the LR drop."""
        from repro.core.sparsity import adaptive_total_budget

        budget = 1e-3
        lr = lambda r: 0.1 if r < 10 else 0.001  # 100× decay at round 10
        s = adaptive_total_budget(budget, lr, base_lr=0.1, max_delay=1000)
        early_delay, early_p = s(0)
        late_delay, late_p = s(20)
        assert early_delay > late_delay  # temporal early
        assert late_p < early_p  # gradient late
        for r in (0, 20):
            d, p = s(r)
            total = p / d
            assert 0.1 * budget < total < 10 * budget  # within a decade
