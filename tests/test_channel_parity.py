"""The shared CommChannel parity matrix (ISSUE 5 acceptance).

Same :class:`RunSpec` → bit-identical params / residuals / Eq. 1+Eq. 5
bits between the legacy per-backend entry points (``DSGDTrainer``,
``make_dist_train``, ``ParameterServer``+``RoundScheduler``) and the
declarative ``repro.run.build_run`` surface, for the exact AND the
``fast=True`` flat engines — and ``BandwidthLedger.reconcile()`` passes on
the local and GSPMD backends (not just fed).

The GSPMD leg runs on whatever devices this process has (1 locally; the
``tests-multidevice`` CI job forces 8 host devices so the collectives are
real).
"""
import functools
import warnings

import jax
import numpy as np
import pytest

from repro.core.api import CompressionPolicy, PolicyRule, make_compressor
from repro.core.codec import make_codec
from repro.core.policy import DENSE_SMALL_PATTERN
from repro.data import client_batches
from repro.models.model import build_model
from repro.optim import get_optimizer
from repro.run import RunSpec, build_run
from repro.run.build import lr_schedule
from repro.run.presets import build_preset

BATCH, SEQ = 4, 16


def base_spec(**kw) -> RunSpec:
    base = dict(
        preset="tiny", backend="local", rounds=2, batch=BATCH, seq_len=SEQ,
        clients=2, delay=2, sparsity=0.05,
    )
    base.update(kw)
    return RunSpec(**base)


@functools.lru_cache(maxsize=None)
def tiny_setup():
    cfg, task = build_preset("tiny", batch=BATCH, seq_len=SEQ)
    model = build_model(cfg)
    return cfg, model, task


def assert_trees_equal(a, b, what=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), what
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


# ============================================================ local backend


class TestLocalParity:
    @pytest.mark.parametrize("fast", [False, True])
    def test_runspec_matches_legacy_trainer(self, fast):
        """build_run(local spec) ≡ a hand-built DSGDTrainer, bitwise."""
        from repro.train import DSGDTrainer

        spec = base_spec(fast=fast)
        cfg, model, task = tiny_setup()
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            trainer = DSGDTrainer(
                model=model,
                compressor=make_compressor(spec.compressor),
                optimizer=get_optimizer(cfg.local_opt),
                n_clients=spec.clients,
                lr=lr_schedule(cfg.base_lr),
                fast=True if fast else None,
            )
        legacy_state, legacy_hist = trainer.fit(
            jax.random.PRNGKey(spec.seed),
            client_batches(task, spec.clients, spec.delay),
            n_rounds=spec.rounds, n_delay=spec.delay, sparsity=spec.sparsity,
        )

        run = build_run(spec)
        state, hist = run.run()

        assert_trees_equal(state.params, legacy_state.params, "params")
        assert_trees_equal(state.comp_state.residual,
                           legacy_state.comp_state.residual, "residuals")
        assert hist["bits_per_client"] == legacy_hist["bits_per_client"]
        assert hist["total_upload_bits"] == legacy_hist["total_upload_bits"]

    def test_fast_and_exact_engines_agree(self):
        """One spec, both engines: bit-identical params + analytic bits
        (the §10 layout contract through the RunSpec surface)."""
        s_exact, _ = build_run(base_spec(fast=False)).run()
        s_fast, _ = build_run(base_spec(fast=True)).run()
        assert_trees_equal(s_exact.params, s_fast.params, "engine params")

    def test_ledger_reconciles(self):
        """measure_wire=True fills the channel ledger and the measured
        bits agree with Eq. 1/Eq. 5 within Golomb rounding."""
        run = build_run(base_spec(measure_wire=True, sparsity=0.02))
        _, hist = run.run()
        assert len(run.ledger.records) == 2
        run.ledger.reconcile(rel=0.1)
        t = run.ledger.totals()
        assert t["up_bytes"] > 0 and t["down_bytes"] == 0


# ============================================================ gspmd backend


class TestGspmdParity:
    @pytest.mark.parametrize("fast", [False, True])
    def test_runspec_matches_legacy_make_dist_train(self, fast):
        """build_run(gspmd spec) ≡ the deprecated make_dist_train shim,
        driven with identical batches: bitwise params/residual, equal
        analytic bits."""
        from repro.launch.dist import make_dist_train

        spec = base_spec(backend="gspmd", fast=fast)
        run = build_run(spec)
        with pytest.warns(DeprecationWarning):
            legacy = make_dist_train(
                run.cfg, run.mesh, compressor=spec.compressor,
                sparsity=spec.sparsity, model=run.model,
                fast=True if fast else None,
            )
        assert legacy.bits_per_client == run.fns.bits_per_client
        assert legacy.bits_dense == run.fns.bits_dense

        state = run.init()
        legacy_state = legacy.init_state(jax.random.PRNGKey(spec.seed))
        for r in range(spec.rounds):
            batch = run._batch(r)
            state, _ = run.step(state, r)
            legacy_state, _ = legacy.train_step(legacy_state, batch)
        assert_trees_equal(state["params"], legacy_state["params"], "params")
        assert_trees_equal(state["residual"], legacy_state["residual"],
                           "residuals")

    def test_engines_agree_and_ledger_reconciles(self):
        """exact vs fast=True: bit-identical params + Eq. 1 totals; the
        channel ledger's measured Golomb streams reconcile (the first
        non-fed backend with wire accounting)."""
        exact = build_run(base_spec(backend="gspmd", measure_wire=True))
        fast = build_run(base_spec(backend="gspmd", measure_wire=True,
                                   fast=True))
        assert exact.fns.bits_per_client == fast.fns.bits_per_client
        se, _ = exact.run()
        sf, _ = fast.run()
        assert_trees_equal(se["params"], sf["params"], "engine params")
        # residual layouts differ (flat §11 vs per-leaf); compare through
        # the channel's pytree view
        res_fast = fast.fns.residual_to_tree(sf["residual"])
        assert_trees_equal(se["residual"], res_fast, "engine residuals")
        for run in (exact, fast):
            assert len(run.ledger.records) == run.spec.rounds
            run.ledger.reconcile(rel=0.1)


# ============================================================== fed backend


class TestFedParity:
    @pytest.mark.parametrize("fast", [False, True])
    def test_runspec_matches_legacy_stack(self, fast):
        """build_run(fed spec) ≡ hand-built ParameterServer + ClientPool +
        RoundScheduler (the pre-channel fed launcher body): bitwise server
        params and replica, identical ledger rows."""
        from repro.fed import ClientPool, ClientProfile, ParameterServer, \
            RoundScheduler

        spec = base_spec(
            backend="fed", dense_pattern=DENSE_SMALL_PATTERN, fast=fast,
            clients=4, cohort=2, down_sparsity=0.05, rounds=2,
        )
        cfg, model, task = tiny_setup()

        comp = make_compressor(spec.compressor)
        policy = CompressionPolicy(
            default=comp.codec,
            rules=(PolicyRule(DENSE_SMALL_PATTERN, codec="dense32"),)
            + comp.policy.rules,
            name="sbc+dense-small",
            fast=fast,
        )
        params = model.init(jax.random.PRNGKey(spec.seed))
        server = ParameterServer(
            params=params, up_policy=policy,
            down_sparsity=spec.down_sparsity, aggregator="mean",
        )
        pool = ClientPool(
            model=model, optimizer=get_optimizer(cfg.local_opt),
            policy=policy, task=task, n_clients=spec.clients,
            lr=lambda it: cfg.base_lr,
            profiles=(ClientProfile(delay=spec.delay,
                                    sparsity=spec.sparsity),),
            seed=spec.seed,
        )
        sched = RoundScheduler(server=server, pool=pool,
                               cohort_size=spec.cohort, seed=spec.seed)
        legacy_hist = sched.run(spec.rounds)

        run = build_run(spec)
        state, hist = run.run()

        assert_trees_equal(state.server.params, server.params, "params")
        assert_trees_equal(state.server.estimate, server.estimate, "replica")
        assert_trees_equal(state.server.down_residual, server.down_residual,
                           "down residual")
        for col in ("wire_up_bits_analytic", "wire_up_bits_measured",
                    "wire_down_bits_analytic", "wire_down_bits_measured",
                    "wire_up_bytes", "wire_down_bytes"):
            assert hist[col] == legacy_hist[col], col
        run.ledger.reconcile(rel=0.1)


# ========================================================= device-side pack


class TestDevicePackParity:
    """--device-pack acceptance: device-packed wire bytes byte-identical
    to the host ``Wire.pack`` for the policy shapes all three backends
    ship (plain sbc = local, sbc + dense-small rules = fed, mixed
    sparse/dense/skip = gspmd leaf table), and the gspmd device_pack run
    bit-identical to the host-packed run with every client metered."""

    POLICIES = {
        "local-sbc": lambda: CompressionPolicy.single(make_codec("sbc")),
        "fed-dense-small": lambda: CompressionPolicy(
            default=make_codec("sbc"),
            rules=(PolicyRule(DENSE_SMALL_PATTERN, codec="dense32"),),
        ),
        "gspmd-mixed": lambda: CompressionPolicy(
            default=make_codec("sbc"),
            rules=(PolicyRule(r"bias", codec="dense32"),
                   PolicyRule(r"skipme", codec="skip")),
        ),
    }

    @pytest.mark.parametrize("name", sorted(POLICIES))
    def test_wire_pack_device_byte_identical(self, name):
        from repro.core.wire import wire_for

        rng = np.random.default_rng(3)
        delta = {
            "w": jax.numpy.asarray(rng.standard_normal(4096), jax.numpy.float32),
            "v": jax.numpy.asarray(
                rng.standard_normal((64, 8)), jax.numpy.float32
            ),
            "bias": jax.numpy.asarray(rng.standard_normal(16), jax.numpy.float32),
            "skipme": jax.numpy.asarray(rng.standard_normal(32), jax.numpy.float32),
        }
        resolved = self.POLICIES[name]().resolve(delta)
        state = resolved.init_state(delta)
        ctree, _, _ = resolved.compress(delta, state, resolved.rates(0.02))
        wire = wire_for(resolved, delta, 0.02)
        host_blob, host_bits = wire.pack_with_bits(ctree)
        dev_blob, dev_bits = wire.pack_with_bits(ctree, device_pack=True)
        assert dev_bits == host_bits, name
        assert dev_blob == host_blob, name
        assert wire.pack_device(ctree) == host_blob, name

    def test_gspmd_device_pack_run_parity(self):
        """device_pack=True vs False through build_run: bit-identical
        params/residual/loss, a real per-client ledger row, and the
        device-metered client-0 bits equal to the host-sampled value."""
        host = build_run(base_spec(backend="gspmd", fast=True,
                                   measure_wire=True))
        dev = build_run(base_spec(backend="gspmd", fast=True,
                                  measure_wire=True, device_pack=True))
        sh, sd = host.init(), dev.init()
        for r in range(host.spec.rounds):
            sh, mh = host.step(sh, r)
            sd, md = dev.step(sd, r)
            # host path: client 0 sampled; device path: cohort mean of
            # EVERY client's real stream — client 0's draw must agree
            assert md["measured_bits_per_client"] > 0
            if dev.n_clients == 1:
                assert md["measured_bits_per_client"] == \
                    mh["measured_bits_per_client"]
        assert_trees_equal(sh["params"], sd["params"], "params")
        assert_trees_equal(sh["residual"], sd["residual"], "residuals")
        assert len(dev.ledger.records) == dev.spec.rounds
        dev.ledger.reconcile(rel=0.1)
        # the cohort row is a true sum over every client, not client-0 × C
        rec = dev.ledger.records[-1]
        assert rec.up_bits_measured > 0

    def test_spec_rejects_device_pack_without_fast_path(self):
        with pytest.raises(ValueError, match="device_pack"):
            base_spec(backend="gspmd", device_pack=True)
        with pytest.raises(ValueError, match="device_pack"):
            base_spec(backend="local", fast=True, device_pack=True)


# ================================================== variance-based selection


class TestVarianceSelection:
    """ISSUE 10 satellite: the Tsuzuku-style ``variance`` selector behaves
    like any other static-k sparse codec on every backend — byte-exact
    SBW1 round-trip, and a reconciling ledger wherever wire accounting
    exists."""

    def test_sbw1_round_trip_byte_exact(self):
        from repro.core.wire import wire_for

        rng = np.random.default_rng(7)
        delta = {
            "w": jax.numpy.asarray(rng.standard_normal(4096), jax.numpy.float32),
            "v": jax.numpy.asarray(
                rng.standard_normal((64, 8)), jax.numpy.float32
            ),
        }
        comp = make_compressor("variance")
        resolved = comp.resolve(delta)
        state = resolved.init_state(delta)
        ctree, dense, _ = resolved.compress(delta, state, resolved.rates(0.02))
        ctree = jax.tree.map(np.asarray, ctree)
        wire = wire_for(resolved, delta, 0.02)
        blob, bits = wire.pack_with_bits(ctree)
        assert wire.pack(ctree) == blob  # packing is deterministic
        rec = wire.unpack(blob)
        for key in delta:
            np.testing.assert_array_equal(
                rec[key].reshape(-1),
                np.asarray(dense[key], np.float32).reshape(-1),
                err_msg=key,
            )
        assert bits == wire.measured_bits(ctree) > 0

    @pytest.mark.parametrize("backend", ["local", "gspmd", "fed"])
    def test_backend_runs_and_ledger_reconciles(self, backend):
        kw = dict(compressor="variance", sparsity=0.05, backend=backend)
        if backend == "fed":
            kw.update(clients=4, cohort=2)
        else:
            kw.update(measure_wire=True)
        run = build_run(base_spec(**kw))
        _, hist = run.run()
        assert len(run.ledger.records) == run.spec.rounds
        run.ledger.reconcile(rel=0.1)
        assert run.ledger.totals()["up_bytes"] > 0


# ===================================================== cross-backend checks


def test_local_and_gspmd_agree_on_analytic_bits():
    """The SAME spec prices one client's upload identically through the
    local channel's Eq. 1 accounting and the GSPMD channel's
    per-(leaf, shard) table when every leaf is one unscanned shard (1
    device per client, no scan superblocks — scanned leaves price one μ
    per ROW in the dist backend by design) — the uniform-accounting claim
    of DESIGN.md §12, on the lenet5 preset."""
    spec = base_spec(preset="lenet5", sparsity=0.01)
    local = build_run(spec)
    gspmd = build_run(spec.replace(backend="gspmd"))
    if gspmd.mesh.devices.size != gspmd.n_clients:
        pytest.skip("leaves sharded within a client; totals differ by design")
    assert not any(gl.scanned for gl in gspmd.channel.leaves)
    state = local.init()
    resolved = local.trainer.resolved(state.params)
    bits = local.channel.bits(
        state.params, resolved.rates(spec.sparsity, 0)
    )
    assert bits.per_client == pytest.approx(gspmd.fns.bits_per_client)
