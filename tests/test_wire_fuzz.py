"""Wire.unpack robustness: truncated / corrupted SBW1 buffers.

A parameter server decodes untrusted client bytes; a malformed buffer must
surface as a clean ``ValueError`` — never an uncaught struct.error,
IndexError, numpy broadcast crash, or silent out-of-bounds scatter.
Valid buffers must still round-trip exactly (the hardening adds checks,
not behavior).
"""
import random
import struct

import jax
import numpy as np
import pytest

from repro.core import api
from repro.core.wire import MAGIC, wire_for

CODECS = ["sbc", "topk", "signsgd", "terngrad", "qsgd", "none"]


def make_blob(name: str, p: float):
    comp = api.get_compressor(name)
    delta = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (3000,)) * 0.01,
        "b": jax.random.normal(jax.random.PRNGKey(1), (61,)),
    }
    state = comp.init_state(delta)
    ctree, dense, _ = comp.compress(delta, state, p)
    ctree = jax.tree.map(np.asarray, ctree)
    wire = wire_for(comp.resolve(delta), delta, p)
    return wire, wire.pack(ctree), dense


def rate_of(name: str) -> float:
    return 0.01 if name in ("sbc", "topk") else 1.0


@pytest.mark.parametrize("name", CODECS)
def test_roundtrip_still_exact(name):
    wire, blob, dense = make_blob(name, rate_of(name))
    rec = wire.unpack(blob)
    np.testing.assert_allclose(rec["w"], np.asarray(dense["w"], np.float32),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("name", CODECS)
def test_truncation_sweep(name):
    """Every prefix of a valid buffer either parses or raises ValueError."""
    wire, blob, _ = make_blob(name, rate_of(name))
    step = max(1, len(blob) // 60)
    for cut in list(range(0, len(blob), step)) + [len(blob) - 1]:
        try:
            wire.unpack(blob[:cut])
        except ValueError:
            pass  # the contract: clean decode error


@pytest.mark.parametrize("name", CODECS)
def test_random_corruption(name):
    """Seeded byte-flips: parse or ValueError, never another exception."""
    wire, blob, _ = make_blob(name, rate_of(name))
    rng = random.Random(1234)
    for _ in range(200):
        b = bytearray(blob)
        for _ in range(rng.randint(1, 8)):
            b[rng.randrange(len(b))] = rng.randrange(256)
        try:
            wire.unpack(bytes(b))
        except ValueError:
            pass


def test_bad_magic_and_leaf_count():
    wire, blob, _ = make_blob("sbc", 0.01)
    with pytest.raises(ValueError, match="magic"):
        wire.unpack(b"XXXX" + blob[4:])
    with pytest.raises(ValueError, match="leaves"):
        wire.unpack(MAGIC + struct.pack("<I", 99) + blob[8:])
    with pytest.raises(ValueError, match="truncated"):
        wire.unpack(blob[:6])


def test_oversized_golomb_bitcount_is_clean():
    """A corrupted golomb bit-count field claiming gigabits must raise,
    not attempt a giant allocation or a short silent parse."""
    wire, blob, _ = make_blob("sbc", 0.01)
    # first leaf payload starts at byte 12 (magic+count+len); its first
    # field is the u32 golomb bit count
    b = bytearray(blob)
    struct.pack_into("<I", b, 12, 1 << 31)
    with pytest.raises(ValueError):
        wire.unpack(bytes(b))


def test_out_of_range_positions_are_clean():
    """raw16 positions pointing past the tensor must raise ValueError
    instead of scattering out of bounds at reconstruction."""
    comp = api.get_compressor("topk")  # topk|identity|raw16
    delta = {"w": jax.random.normal(jax.random.PRNGKey(0), (500,)) * 0.01}
    state = comp.init_state(delta)
    ctree, _, _ = comp.compress(delta, state, 0.02)
    ctree = jax.tree.map(np.asarray, ctree)
    wire = wire_for(comp.resolve(delta), delta, 0.02)
    blob = bytearray(wire.pack(ctree))
    # overwrite the first position with an index far past n=500
    struct.pack_into("<H", blob, 12, 0xFFFF)
    with pytest.raises(ValueError, match="outside"):
        wire.unpack(bytes(blob))
