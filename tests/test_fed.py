"""Federated orchestration subsystem (DESIGN.md §9).

Covers the ISSUE 2 satellite checklist: deterministic cohort sampling,
closed-form async staleness weighting, byte-exact server residual +
downstream compression through :class:`repro.core.wire.Wire`, and ledger
bytes reconciling with the analytic Eq. 1/Eq. 5 prediction within Golomb
rounding (the same measured-vs-analytic tolerance style as
``test_codec_pipeline.TestMeasuredVsAnalytic``).
"""
import functools

import jax
import numpy as np
import pytest

from repro.core.api import CompressionPolicy, PolicyRule
from repro.core.codec import make_codec
from repro.core.policy import DENSE_SMALL_PATTERN
from repro.data import make_lm_task, make_non_iid_lm_task
from repro.fed import (
    AGGREGATORS,
    ClientPool,
    ClientProfile,
    ClientUpdate,
    ParameterServer,
    RoundScheduler,
    staleness_weights,
)
from repro.models.model import build_model
from repro.optim import get_optimizer

from conftest import tiny_decoder, tiny_lm_setup


def _policy():
    return CompressionPolicy(
        default=make_codec("sbc"),
        rules=(PolicyRule(DENSE_SMALL_PATTERN, codec="dense32"),),
        name="sbc+dense-small",
    )


@functools.lru_cache(maxsize=None)
def micro_setup():
    """A sub-tiny decoder for the fed tests that need their own cohort-step
    compile (profiles/async) — keeps each extra trace ~1 s."""
    cfg = tiny_decoder(name="micro", n_layers=1, d_model=32, n_heads=2,
                       n_kv_heads=1, d_ff=64, vocab_size=64)
    model = build_model(cfg)
    task = make_lm_task(vocab=cfg.vocab_size, batch=4, seq_len=16,
                        temperature=0.3)
    return cfg, model, task


def _pool(model, task, n_clients=4, profiles=(ClientProfile(delay=2, sparsity=0.05),),
          seed=0):
    return ClientPool(
        model=model, optimizer=get_optimizer("momentum"), policy=_policy(),
        task=task, n_clients=n_clients, lr=lambda it: 0.05,
        profiles=profiles, seed=seed,
    )


# ------------------------------------------------------------ cohort sampling


class TestCohortSampling:
    def test_deterministic_under_seed(self):
        _, model, task = micro_setup()
        a = _pool(model, task, n_clients=12, seed=7)
        b = _pool(model, task, n_clients=12, seed=7)
        for r in range(5):
            np.testing.assert_array_equal(a.sample_cohort(r, 5),
                                          b.sample_cohort(r, 5))

    def test_varies_by_round_and_seed(self):
        _, model, task = micro_setup()
        pool = _pool(model, task, n_clients=32, seed=0)
        draws = [tuple(pool.sample_cohort(r, 8)) for r in range(6)]
        assert len(set(draws)) > 1, "every round sampled the same cohort"
        other = _pool(model, task, n_clients=32, seed=1)
        assert any(tuple(other.sample_cohort(r, 8)) != draws[r] for r in range(6))

    def test_cohort_is_valid_subset(self):
        _, model, task = micro_setup()
        pool = _pool(model, task, n_clients=10)
        ids = pool.sample_cohort(3, 4)
        assert ids.size == 4 == np.unique(ids).size
        assert np.all((0 <= ids) & (ids < 10))
        # oversized request clamps to the pool
        assert pool.sample_cohort(0, 99).size == 10


# --------------------------------------------------------- staleness weights


class TestStalenessWeights:
    def test_closed_form(self):
        s, beta = [0, 1, 3, 7], 0.7
        w = staleness_weights(s, beta)
        expect = (1.0 + np.asarray(s, np.float64)) ** (-beta)
        np.testing.assert_allclose(w, expect / expect.sum(), rtol=1e-12)
        assert w.sum() == pytest.approx(1.0)
        assert np.all(np.diff(w) < 0), "staler updates must weigh less"

    def test_base_weights_compose(self):
        w = staleness_weights([0, 2], beta=1.0, base=[3.0, 1.0])
        expect = np.asarray([3.0, 1.0 / 3.0])
        np.testing.assert_allclose(w, expect / expect.sum(), rtol=1e-12)

    def test_aggregator_registry_matches(self):
        ups = [
            ClientUpdate(client_id=0, blob=b"", rate=0.01, weight=2.0, staleness=0),
            ClientUpdate(client_id=1, blob=b"", rate=0.01, weight=1.0, staleness=4),
        ]
        w = AGGREGATORS["staleness"](ups, 0.5)
        np.testing.assert_allclose(
            w, staleness_weights([0, 4], 0.5, [2.0, 1.0]), rtol=1e-12
        )
        np.testing.assert_allclose(AGGREGATORS["mean"](ups, 0.5), [0.5, 0.5])
        np.testing.assert_allclose(
            AGGREGATORS["weighted"](ups, 0.5), [2 / 3, 1 / 3]
        )
        assert AGGREGATORS["staleness"](ups, 0.0)[0] == pytest.approx(2 / 3)


# ----------------------------------------------- downstream wire + residual


class TestDownstreamBroadcast:
    def test_roundtrip_byte_exact_and_residual(self):
        """Server residual + compressed broadcast round-trip byte-exactly:
        re-packing the decoded buffer reproduces the identical bytes, the
        replica advances by exactly the wire content, and W − Ŵ equals the
        server-side residual (Eq. 2 applied downstream)."""
        _, model, _ = micro_setup()
        params = model.init(jax.random.PRNGKey(0))
        server = ParameterServer(params=params, up_policy=_policy(),
                                 down_sparsity=0.05)
        rng = jax.random.PRNGKey(1)
        for r in range(3):
            rng, k = jax.random.split(rng)
            # stand-in for an aggregated client update
            server.params = jax.tree.map(
                lambda p, kk=k: p + 0.01 * jax.random.normal(
                    jax.random.fold_in(kk, p.size), p.shape, p.dtype),
                server.params,
            )
            est_before = server.estimate
            bc = server.broadcast(r)
            wire = server.down_wire(r)

            # byte-exact: decode → re-encode reproduces the identical buffer
            assert wire.pack(wire.unpack_compressed(bc.blob)) == bc.blob
            decoded = wire.unpack(bc.blob)
            for a, b in zip(jax.tree.leaves(decoded), jax.tree.leaves(bc.dense)):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b, np.float32))
            # replica advances by exactly the decoded wire content
            for e0, e1, d in zip(jax.tree.leaves(est_before),
                                 jax.tree.leaves(server.estimate),
                                 jax.tree.leaves(decoded)):
                np.testing.assert_allclose(np.asarray(e0) + np.asarray(d),
                                           np.asarray(e1), rtol=1e-6)
            # Eq. 2 downstream: what wasn't broadcast sits in the residual
            for w_, e, res in zip(jax.tree.leaves(server.params),
                                  jax.tree.leaves(server.estimate),
                                  jax.tree.leaves(server.down_residual)):
                np.testing.assert_allclose(np.asarray(w_) - np.asarray(e),
                                           np.asarray(res), atol=1e-6)

    def test_dense_downstream_is_lossless(self):
        _, model, _ = micro_setup()
        params = model.init(jax.random.PRNGKey(0))
        server = ParameterServer(params=params, up_policy=_policy())  # p_down=1
        server.params = jax.tree.map(lambda p: p + 0.5, server.params)
        server.broadcast(0)
        for w_, e in zip(jax.tree.leaves(server.params),
                         jax.tree.leaves(server.estimate)):
            np.testing.assert_allclose(np.asarray(w_), np.asarray(e), atol=1e-6)


# ------------------------------------------------------- end-to-end + ledger


@pytest.fixture(scope="module")
def sync_run():
    """One shared 4-round sync run (cohort 3 of 4) on the tiny decoder."""
    _, model, task = tiny_lm_setup()
    server = ParameterServer(params=model.init(jax.random.PRNGKey(0)),
                             up_policy=_policy(), down_sparsity=0.1)
    pool = _pool(model, task)
    sched = RoundScheduler(server=server, pool=pool, cohort_size=3)
    hist = sched.run(4)
    return sched, hist


class TestEndToEnd:
    def test_sync_loss_decreases(self, sync_run):
        _, hist = sync_run
        assert hist["loss"][-1] < hist["loss"][0]

    def test_ledger_reconciles_with_eq1(self, sync_run):
        """Measured wire bits match the analytic Eq. 1/Eq. 5 sum within
        Golomb rounding, per round, both directions."""
        sched, _ = sync_run
        assert len(sched.ledger.records) == 4
        sched.ledger.reconcile(rel=0.12)
        for rec in sched.ledger.records:
            assert len(rec.cohort) == 3
            # framed bytes ≥ payload bits (framing adds, padding rounds up)
            assert rec.up_bytes * 8 >= rec.up_bits_measured
            assert rec.down_bytes * 8 >= rec.down_bits_measured
            assert rec.up_bits_measured > 0 and rec.down_bits_measured > 0

    def test_cohorts_recorded_match_sampler(self, sync_run):
        sched, _ = sync_run
        fresh = _pool(*micro_setup()[1:], n_clients=4)  # same seed=0
        for rec in sched.ledger.records:
            np.testing.assert_array_equal(
                rec.cohort, fresh.sample_cohort(rec.round, 3)
            )

    def test_async_staleness_run(self):
        _, model, task = micro_setup()
        server = ParameterServer(params=model.init(jax.random.PRNGKey(0)),
                                 up_policy=_policy(), down_sparsity=0.1,
                                 aggregator="staleness", staleness_beta=0.5)
        pool = _pool(model, task, n_clients=6,
                     profiles=(ClientProfile(delay=1, sparsity=0.05),))
        sched = RoundScheduler(server=server, pool=pool, cohort_size=3,
                               mode="async", max_staleness=2, seed=3)
        hist = sched.run(5)
        assert all(np.isfinite(l) for l in hist["loss"])
        assert max(hist["mean_staleness"]) > 0, "async run never went stale"
        sched.ledger.reconcile(rel=0.15)

    def test_heterogeneous_profiles(self):
        """Clients bound to different (delay, sparsity) profiles produce
        per-member rates/weights that follow the c % len(profiles) rule."""
        _, model, task = micro_setup()
        profiles = (ClientProfile(delay=1, sparsity=0.02, weight=1.0),
                    ClientProfile(delay=3, sparsity=0.05, weight=2.0))
        pool = _pool(model, task, n_clients=4, profiles=profiles)
        params = model.init(jax.random.PRNGKey(0))
        pool.init(params)
        res = pool.run_cohort(0, np.arange(4), params)
        assert res.rates == (0.02, 0.05, 0.02, 0.05)
        assert res.weights == (1.0, 6.0, 1.0, 6.0)  # weight · delay
        assert np.all(res.losses != 0) and np.all(res.bits_analytic > 0)
        # higher-rate members spend more upstream bits
        assert res.bits_analytic[1] > res.bits_analytic[0]


# ------------------------------------------------------------ non-IID shards


def _bigrams(task, client, steps, vocab):
    """Empirical bigram distribution of one client's stream over ``steps``."""
    h = np.zeros((vocab, vocab))
    for s in steps:
        t = np.asarray(task.sample(s, client)["tokens"])
        np.add.at(h, (t[:, :-1].ravel(), t[:, 1:].ravel()), 1)
    return h / h.sum()


class TestNonIID:
    def test_clients_draw_from_distinct_chains(self):
        task = make_non_iid_lm_task(vocab=32, batch=8, seq_len=64, n_clients=4,
                                    skew=5.0, temperature=0.3, seed=0)
        a = _bigrams(task, 0, [0], 32)
        b = _bigrams(task, 1, [0], 32)
        # different clients → different transition structure, not just noise
        assert np.abs(a - b).sum() > 0.3
        assert task.entropy_floor > 0

    def test_skew_zero_is_shared_chain(self):
        """skew=0 must degenerate to the IID split: cross-client bigram
        distance is indistinguishable from same-client sampling noise."""
        task = make_non_iid_lm_task(vocab=32, batch=8, seq_len=64, n_clients=4,
                                    skew=0.0, temperature=0.3, seed=0)
        noise = np.abs(_bigrams(task, 0, [0, 1], 32)
                       - _bigrams(task, 0, [2, 3], 32)).sum()
        cross = np.abs(_bigrams(task, 0, [0, 1], 32)
                       - _bigrams(task, 1, [0, 1], 32)).sum()
        assert cross < 2.0 * noise + 0.05


class TestFlatFastPool:
    def test_fast_pool_matches_legacy_pool_bitwise(self):
        """ClientPool(fast=True) routes member compression through the
        flat-buffer fast path (DESIGN.md §10): one cohort round must match
        the legacy pool bit for bit — losses, analytic bits, and every
        member's compressed tree — with the pooled residual stored as one
        (n_clients, n_pad) buffer instead of a stacked pytree."""
        cfg, model, task = micro_setup()
        params = model.init(jax.random.PRNGKey(0))
        pools = {
            fast: ClientPool(
                model=model, optimizer=get_optimizer("momentum"),
                policy=_policy(), task=task, n_clients=4, lr=lambda it: 0.05,
                profiles=(ClientProfile(delay=2, sparsity=0.05),),
                fast=fast,
            )
            for fast in (False, True)
        }
        for pool in pools.values():
            pool.init(params)
        assert hasattr(pools[True]._comp_state.residual, "ndim")
        assert pools[True]._comp_state.residual.ndim == 2  # (clients, n_pad)

        outs = {}
        for fast, pool in pools.items():
            ids = pool.sample_cohort(0, 3)
            outs[fast] = pool.run_cohort(0, ids, params)
        a, b = outs[False], outs[True]
        assert a.client_ids == b.client_ids
        np.testing.assert_array_equal(a.losses, b.losses)
        np.testing.assert_array_equal(a.bits_analytic, b.bits_analytic)
        for ca, cb in zip(a.ctrees, b.ctrees):
            for xa, xb in zip(jax.tree.leaves(ca), jax.tree.leaves(cb)):
                assert np.asarray(xa).tobytes() == np.asarray(xb).tobytes()


    def test_fast_server_broadcast_matches_legacy_bitwise(self):
        """ParameterServer with a fast=True, sparse downstream policy must
        broadcast the same bytes as the legacy server — including the flat
        server-side residual being viewed as a pytree for the W − Ŵ gap
        subtraction (regression: this used to crash on round 0)."""
        import dataclasses as _dc

        cfg, model, task = micro_setup()
        params = model.init(jax.random.PRNGKey(0))
        bumped = jax.tree.map(lambda p: p + 0.01, params)
        servers = {}
        for fast in (False, True):
            pol = _dc.replace(_policy(), fast=fast)
            srv = ParameterServer(params=params, up_policy=pol,
                                  down_sparsity=0.05)
            srv.params = bumped
            servers[fast] = srv
        for r in range(2):  # round 1 exercises the stored flat residual
            a = servers[False].broadcast(r)
            b = servers[True].broadcast(r)
            assert a.blob == b.blob
            for xa, xb in zip(jax.tree.leaves(a.dense), jax.tree.leaves(b.dense)):
                assert np.asarray(xa).tobytes() == np.asarray(xb).tobytes()
            for xa, xb in zip(jax.tree.leaves(servers[False].down_residual),
                              jax.tree.leaves(servers[True].down_residual)):
                assert np.asarray(xa).tobytes() == np.asarray(xb).tobytes()
