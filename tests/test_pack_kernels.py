"""Device-side Golomb packing (repro.kernels.pack) vs the host encoder.

The whole point of the fused select→pack kernels is BYTE identity: the
uint32 word buffers they emit, viewed big-endian and truncated to
``ceil(nbits/8)``, must equal ``golomb.encode_positions_packed`` for the
same positions — per row, for every row of a packed multi-row buffer.
These tests drive that contract over adversarial run-length shapes
(single survivor at either edge, all-selected rows, maximal gaps,
codewords straddling word boundaries) plus a hypothesis property over
random masks, and round-trip the pointer-doubling device decoder.

Everything runs in interpret mode, so the suite is backend-independent
(the ``kernels-interpret`` CI job runs exactly this file + the flat
fast-path suite).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dev dep — fixed-grid fallback
    from _hypothesis_fallback import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import golomb
from repro.kernels.pack import (
    bits_from_mask,
    bits_from_positions,
    golomb_decode_rows,
    pack_bit_rows,
    row_bit_capacity,
    row_words,
    seg_packbits,
    seg_select_pack,
)

# keep the (n, k, b*) combinations SMALL: every distinct triple is a fresh
# jit specialization of three kernels
N_GRID = (8, 64, 200)
P_GRID = (0.01, 0.05, 0.5)  # b* = 6, 4, 0


def _positions(n, k, seed):
    rng = np.random.default_rng(seed)
    return np.sort(rng.choice(n, size=k, replace=False)).astype(np.int32)


def _host_bytes(pos, p):
    return golomb.encode_positions_packed(np.asarray(pos, np.int64), p)


def _device_bytes_from_positions(pos, n, p):
    """positions → bits_from_positions → seg_packbits → transport bytes."""
    b = golomb.golomb_bstar(p)
    cap32 = 32 * row_words(n, len(pos), b)
    bits, nbits = bits_from_positions(jnp.asarray(pos), bstar=b, cap32=cap32)
    words = pack_bit_rows(bits[None], interpret=True)[0]
    return golomb.packed_words_to_bytes(np.asarray(words), int(nbits)), int(nbits)


def _device_bytes_from_mask(pos, n, p):
    """mask → fused seg_select_pack → transport bytes."""
    b = golomb.golomb_bstar(p)
    mask = np.zeros((n,), np.int32)
    mask[np.asarray(pos)] = 1
    words, nbits = seg_select_pack(
        jnp.asarray(mask)[None], k=len(pos), bstar=b, interpret=True
    )
    return (
        golomb.packed_words_to_bytes(np.asarray(words[0]), int(nbits[0])),
        int(nbits[0]),
    )


# ------------------------------------------------------- adversarial shapes


class TestAdversarialRuns:
    """Hand-picked run-length patterns that stress every codeword path."""

    CASES = [
        # (n, p, positions) — single survivor at both edges and mid-row
        (64, 0.01, [0]),
        (64, 0.01, [63]),  # maximal single gap: longest unary run
        (64, 0.01, [31]),
        # all-selected: k = n, every gap 1, stream is k dense codewords
        (8, 0.5, list(range(8))),
        (64, 0.05, list(range(64))),
        # first/last + a big interior gap
        (64, 0.01, [0, 63]),
        (200, 0.01, [0, 1, 2, 197, 198, 199]),
        # codewords straddling uint32 word boundaries: b*=4 remainders
        # land across bit 32/64/96 for these spacings
        (200, 0.05, [6, 13, 20, 27, 34, 41, 48, 55]),
        # geometric-ish bursts + voids
        (200, 0.05, [0, 1, 2, 3, 50, 51, 52, 120, 199]),
    ]

    @pytest.mark.parametrize("n,p,pos", CASES)
    def test_bytes_identical_both_kernels(self, n, p, pos):
        ref, ref_bits = _host_bytes(pos, p)
        dev, dev_bits = _device_bytes_from_positions(pos, n, p)
        assert dev_bits == ref_bits
        assert dev == ref
        fused, fused_bits = _device_bytes_from_mask(pos, n, p)
        assert fused_bits == ref_bits
        assert fused == ref

    @pytest.mark.parametrize("n,p,pos", CASES)
    def test_decode_roundtrip(self, n, p, pos):
        b = golomb.golomb_bstar(p)
        k = len(pos)
        cap32 = 32 * row_words(n, k, b)
        bits, _ = bits_from_positions(jnp.asarray(np.asarray(pos, np.int32)),
                                      bstar=b, cap32=cap32)
        words = pack_bit_rows(bits[None], interpret=True)
        back = golomb_decode_rows(words, k=k, bstar=b)
        np.testing.assert_array_equal(np.asarray(back[0]), np.asarray(pos))

    def test_empty_row_is_empty_stream(self):
        """k = 0 matches the host's (b'', 0) empty-encode contract."""
        assert row_bit_capacity(64, 0, 6) == 0
        bits, nbits = bits_from_positions(
            jnp.zeros((0,), jnp.int32), bstar=6, cap32=32
        )
        assert int(nbits) == 0
        assert not np.asarray(bits).any()
        assert golomb.packed_words_to_bytes(np.zeros((1,), np.uint32), 0) == b""
        assert _host_bytes([], 0.01) == (b"", 0)

    def test_capacity_bound_is_sharp_enough(self):
        """The static bound dominates the real stream for the worst
        single-gap row AND the all-selected row."""
        for n, p in [(64, 0.01), (200, 0.05), (8, 0.5)]:
            b = golomb.golomb_bstar(p)
            for pos in ([n - 1], list(range(n))):
                _, bits = _host_bytes(pos, p)
                assert bits <= row_bit_capacity(n, len(pos), b)


# ----------------------------------------------------- multi-row buffers


class TestMultiRowBuffers:
    """One packed buffer, many rows: each row's word slice must be
    byte-identical to its own host encode (no bleed across the static
    per-row word boundaries) — the (leaf, shard, row) contract the
    sharded exchange relies on."""

    def test_rows_stay_byte_identical(self):
        n, p, rows = 200, 0.05, 6
        b = golomb.golomb_bstar(p)
        k = 7
        cap32 = 32 * row_words(n, k, b)
        pos_rows = [_positions(n, k, seed) for seed in range(rows)]
        bits = jnp.stack(
            [
                bits_from_positions(jnp.asarray(pr), bstar=b, cap32=cap32)[0]
                for pr in pos_rows
            ]
        )
        words = np.asarray(pack_bit_rows(bits, interpret=True))
        assert words.shape == (rows, cap32 // 32)
        for r, pr in enumerate(pos_rows):
            ref, ref_bits = _host_bytes(pr, p)
            got = golomb.packed_words_to_bytes(words[r], ref_bits)
            assert got == ref, f"row {r}"

    def test_fused_rows_and_decode(self):
        n, p, rows, k = 64, 0.05, 5, 4
        b = golomb.golomb_bstar(p)
        pos_rows = [_positions(n, k, 100 + seed) for seed in range(rows)]
        mask = np.zeros((rows, n), np.int32)
        for r, pr in enumerate(pos_rows):
            mask[r, pr] = 1
        words, nbits = seg_select_pack(jnp.asarray(mask), k=k, bstar=b,
                                       interpret=True)
        back = golomb_decode_rows(words, k=k, bstar=b)
        for r, pr in enumerate(pos_rows):
            ref, ref_bits = _host_bytes(pr, p)
            assert int(nbits[r]) == ref_bits
            got = golomb.packed_words_to_bytes(np.asarray(words[r]),
                                               int(nbits[r]))
            assert got == ref, f"row {r}"
            np.testing.assert_array_equal(np.asarray(back[r]), pr)


# --------------------------------------------------------- property tests


@given(
    n=st.sampled_from(N_GRID),
    kfrac=st.sampled_from([1, 2, 7]),  # k = max(1, n // kfrac): dense→sparse
    p=st.sampled_from(P_GRID),
    seed=st.integers(0, 3),
)
@settings(max_examples=24, deadline=None)
def test_roundtrip_property(n, kfrac, p, seed):
    """Random masks: device bytes == host bytes (both kernels), decoder
    recovers the exact index set, and nbits never exceeds the static
    capacity bound."""
    k = max(1, n // kfrac)
    pos = _positions(n, k, seed)
    b = golomb.golomb_bstar(p)
    ref, ref_bits = _host_bytes(pos, p)
    assert ref_bits <= row_bit_capacity(n, k, b)

    dev, dev_bits = _device_bytes_from_positions(pos, n, p)
    assert (dev_bits, dev) == (ref_bits, ref)
    fused, fused_bits = _device_bytes_from_mask(pos, n, p)
    assert (fused_bits, fused) == (ref_bits, ref)

    cap32 = 32 * row_words(n, k, b)
    bits, _ = bits_from_positions(jnp.asarray(pos), bstar=b, cap32=cap32)
    words = pack_bit_rows(bits[None], interpret=True)
    back = golomb_decode_rows(words, k=k, bstar=b)
    np.testing.assert_array_equal(np.asarray(back[0]), pos)


def test_bits_from_mask_equals_bits_from_positions():
    """The index-free mask→gaps path produces the identical bit buffer."""
    n, p, k = 200, 0.05, 9
    b = golomb.golomb_bstar(p)
    cap32 = 32 * row_words(n, k, b)
    pos = _positions(n, k, 7)
    mask = np.zeros((n,), np.int32)
    mask[pos] = 1
    bp, nbp = bits_from_positions(jnp.asarray(pos), bstar=b, cap32=cap32)
    bm, nbm = bits_from_mask(jnp.asarray(mask), k=k, bstar=b, cap32=cap32)
    assert int(nbp) == int(nbm)
    np.testing.assert_array_equal(np.asarray(bp), np.asarray(bm))


def test_seg_packbits_matches_np_packbits():
    """The bit-layout contract itself: seg_packbits == np.packbits on a
    big-endian word view, for an arbitrary bit buffer."""
    rng = np.random.default_rng(0)
    lanes = 128
    nwords = 2 * lanes
    bits = rng.integers(0, 2, size=32 * nwords).astype(np.uint32)
    planes = jnp.asarray(bits.reshape(-1, 32).T)
    words = np.asarray(seg_packbits(planes, lanes=lanes, interpret=True))
    ref = np.packbits(bits.astype(np.uint8)).tobytes()
    assert words.astype(">u4").tobytes() == ref


# ------------------------------------------------- sharded space integration


def test_sharded_space_pack_matches_host_per_row():
    """ShardedFlatParamSpace.exchange_local(device_pack=True): identical
    mean/own/residual, and every (segment, row) slice of the packed word
    buffer is byte-identical to host-encoding that row's positions."""
    from repro.core.flat import ShardedFlatParamSpace

    shapes = [(2, 40, 8), (123,), (40,), (7, 3)]
    kinds = ("sparse", "sparse", "dense", "skip")
    entries = [
        dict(path=f"leaf{i}", shape=s, rows=s[0] if len(s) > 1 else 1,
             kind=kd, rate=0.05, n_shards=1, global_size=int(np.prod(s)))
        for i, (s, kd) in enumerate(zip(shapes, kinds))
    ]
    space = ShardedFlatParamSpace.build(
        entries, client_axes=(), shard_axes=(), n_clients=1,
        shards_per_client=1,
    )
    bodies = [
        0.1 * jax.random.normal(jax.random.PRNGKey(i), seg.shape)
        for i, seg in enumerate(space.segments)
    ]
    res = jnp.zeros((space.n_pad,), jnp.float32)
    mean0, own0, nr0 = jax.jit(space.exchange_local)(bodies, res)
    mean1, own1, nr1, words, nbits = space.exchange_local(
        bodies, res, device_pack=True
    )
    for a, c in ((mean0, mean1), (own0, own1), (nr0, nr1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    words_np = np.asarray(words)
    nbits_np = np.asarray(nbits)
    own_np = np.asarray(own1)
    mi = 0
    for s, (b, w, off) in zip(space._sparse, space._pack_info):
        block = own_np[s.offset:s.offset + s.rows * s.n_loc].reshape(
            s.rows, s.n_loc
        )
        for r in range(s.rows):
            rowpos = np.flatnonzero(block[r])
            assert rowpos.size == s.k
            ref, ref_bits = golomb.encode_positions_packed(rowpos, s.rate)
            assert int(nbits_np[mi]) == ref_bits, (s.path, r)
            got = golomb.packed_words_to_bytes(
                words_np[off + r * w: off + (r + 1) * w], ref_bits
            )
            assert got == ref, (s.path, r)
            mi += 1
    assert mi == space.n_mu == len(nbits_np)
