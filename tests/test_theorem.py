"""Theorem II.1 — residual accumulation minimizes the accumulated error.

If transferred updates live in a subspace S, then
ΔW*_T = Proj_S(R_{T−1} + ΔW_T) uniquely minimizes
err(ΔW*_T) = ‖Σ_t (ΔW_t − ΔW*_t)‖ over S.  We verify numerically for
fixed-support subspaces (a true linear subspace — the paper's setting)
AND for the top-k union-of-subspaces used in practice.
"""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dev dep — fixed-grid fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import residual


def _history(seed, T, n):
    return jax.random.normal(jax.random.PRNGKey(seed), (T, n))


@given(seed=st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_projection_minimizes_fixed_support(seed):
    T, n = 4, 32
    deltas = _history(seed, T, n)
    support = jax.random.bernoulli(jax.random.PRNGKey(seed + 1), 0.3, (n,))

    # run T−1 rounds of residual-accumulated projection
    res = jnp.zeros(n)
    sent = []
    for t in range(T - 1):
        star = residual.project_fixed_support(res + deltas[t], support)
        res = res + deltas[t] - star
        sent.append(star)

    # round T: the theorem's choice
    v = residual.project_fixed_support(res + deltas[T - 1], support)
    err_v = residual.accumulated_error(deltas, jnp.stack(sent + [v]))

    # any other element of S does no better
    rng = np.random.default_rng(seed)
    for _ in range(20):
        other = residual.project_fixed_support(
            jnp.asarray(rng.normal(size=n), jnp.float32), support
        )
        err_o = residual.accumulated_error(deltas, jnp.stack(sent + [other]))
        assert float(err_v) <= float(err_o) + 1e-4

    # and the error of the theorem's choice equals the off-support mass
    expect = jnp.linalg.norm(jnp.where(support, 0.0, jnp.sum(deltas, 0)))
    np.testing.assert_allclose(float(err_v), float(expect), rtol=1e-4, atol=1e-5)


@given(seed=st.integers(0, 60))
@settings(max_examples=20, deadline=None)
def test_topk_projection_is_best_k_sparse(seed):
    """top-k-with-values is the metric projection onto k-sparse vectors."""
    n, k = 64, 8
    v = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    proj = residual.topk_projection(v, k)
    # any other k-sparse candidate is farther from v
    rng = np.random.default_rng(seed)
    for _ in range(20):
        idx = rng.choice(n, k, replace=False)
        cand = jnp.zeros(n).at[idx].set(v[idx])  # best values on that support
        assert float(jnp.linalg.norm(v - proj)) <= float(jnp.linalg.norm(v - cand)) + 1e-5


def test_residual_identity_eq2():
    """R_τ = Σ(ΔW_t − ΔW*_t) — the unrolled form of Eq. 2."""
    T, n = 6, 40
    deltas = _history(0, T, n)
    stars = _history(1, T, n) * 0.1
    res = jnp.zeros(n)
    for t in range(T):
        res = residual.residual_update(res, deltas[t], stars[t])
    np.testing.assert_allclose(
        np.asarray(res), np.asarray(jnp.sum(deltas - stars, 0)), rtol=1e-4, atol=1e-5
    )
